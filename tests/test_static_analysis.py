"""Tests for the static-analysis subsystem (``repro.analysis``): the
scan-aware jaxpr walker, the async-aware HLO parser, the kernel/sharded
contract checker (including a deliberately broken kernel that MUST be
flagged), and the repo-invariant AST lint."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------------ jaxpr walker


def test_nested_scan_trip_count_product():
    """Nested scans multiply their trip counts (outer x inner) — the
    regression the walker refactor pins."""
    from repro.analysis import structural_flops

    W = jax.ShapeDtypeStruct((3, 5, 16, 16), jnp.float32)
    X = jax.ShapeDtypeStruct((4, 16), jnp.float32)

    def f(x, ws):
        def outer(c, wrow):
            def inner(c2, w):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, wrow)
            return c, None
        out, _ = jax.lax.scan(outer, x, ws)
        return out

    assert structural_flops(f, X, W) == 3 * 5 * 2 * 4 * 16 * 16


def test_conv_general_dilated_flops():
    from repro.analysis import structural_flops

    def f(x, k):
        return jax.lax.conv_general_dilated(
            x, k, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))

    X = jax.ShapeDtypeStruct((2, 8, 8, 3), jnp.float32)
    K = jax.ShapeDtypeStruct((3, 3, 3, 7), jnp.float32)
    # 2 x output points x kernel spatial x in-channels-per-group
    assert structural_flops(f, X, K) == 2 * (2 * 8 * 8 * 7) * (3 * 3) * 3


def test_pallas_grid_multiplier():
    """The kernel body is counted once per grid cell: a blocked GEMM
    kernel must trace to exactly 2*M*N*K."""
    from repro.analysis import trace_counts
    from repro.kernels.gemm_softmax import gemm_softmax

    M, K, N = 256, 256, 128

    def f(a, b):
        return gemm_softmax(a, b, block_m=128, block_k=128)

    tc = trace_counts(f, jax.ShapeDtypeStruct((M, K), jnp.bfloat16),
                      jax.ShapeDtypeStruct((K, N), jnp.bfloat16))
    assert tc.flops == 2 * M * K * N
    assert tc.total_collective_dv() == 0.0


def test_cond_counts_max_branch():
    from repro.analysis import structural_flops

    def f(p, a, b):
        return jax.lax.cond(p, lambda: a @ b,
                            lambda: jnp.zeros((64, 16), jnp.float32))

    P = jax.ShapeDtypeStruct((), jnp.bool_)
    A = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    B = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    assert structural_flops(f, P, A, B) == 2 * 64 * 32 * 16


def test_collective_count_scan_multiplier():
    """A psum inside a scan inside a shard_map is counted scan-length
    times (and classified as an AllReduce)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.analysis import trace_counts

    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))

    def body(xs):
        def step(c, x):
            return c + jax.lax.psum(x, "x"), None
        out, _ = jax.lax.scan(step, jnp.zeros_like(xs[0]), xs)
        return out

    f = shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                  check_rep=False)
    tc = trace_counts(f, jax.ShapeDtypeStruct((5, 8), jnp.float32))
    recs = list(tc.collectives.values())
    assert len(recs) == 1
    assert recs[0].col_type == "AllReduce"
    assert recs[0].count == 5.0


def test_launch_shims_reexport():
    """launch/jaxpr_analysis + launch/hlo_analysis stay importable and
    hand back the moved implementations, not copies."""
    import warnings

    import repro.analysis as an
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.launch import hlo_analysis as shim_h
        from repro.launch import jaxpr_analysis as shim_j
    assert shim_j.structural_flops is an.structural_flops
    assert shim_j.trace_counts is an.trace_counts
    assert shim_h.parse_collectives is an.parse_collectives
    assert shim_h.shape_bytes is an.shape_bytes


def test_launch_shims_warn_deprecation():
    """Importing either compat shim emits DeprecationWarning (module-level,
    so re-importing an already-loaded shim needs a reload to re-fire)."""
    import importlib

    from repro.launch import hlo_analysis, jaxpr_analysis
    for shim in (jaxpr_analysis, hlo_analysis):
        with pytest.warns(DeprecationWarning, match="deprecated compat shim"):
            importlib.reload(shim)


# ------------------------------------------------------------- HLO parser

ASYNC_HLO = """
HloModule async_sample

ENTRY %main (p0: bf16[16,128]) -> bf16[64,128] {
  %ags = (bf16[16,128], bf16[64,128]) all-gather-start(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %agd = bf16[64,128] all-gather-done(%ags)
  %ars = f32[128] all-reduce-start(%q), replica_groups={{0,1}}, to_apply=%add
  %ard = f32[128] all-reduce-done(%ars)
  %rss = (f32[64,128], f32[16,128]) reduce-scatter-start(%r), replica_groups={{0,1,2,3}}, dimensions={0}
  %rsd = f32[16,128] reduce-scatter-done(%rss)
  %rag = bf16[32,64] ragged-all-to-all(%s, %t), replica_groups={{0,1,2,3}}
  ROOT %out = bf16[64,128] copy(%agd)
}
"""


def test_hlo_async_pairs_counted_once():
    """-start carries the volume, -done contributes nothing: each async
    collective is counted exactly once (no double- or zero-counting)."""
    from repro.analysis import parse_collectives
    d = parse_collectives(ASYNC_HLO).to_dict()
    assert d["all-gather"]["count"] == 1
    assert d["all-reduce"]["count"] == 1
    assert d["reduce-scatter"]["count"] == 1
    # all-gather-start result tuple: max element = the GATHERED result
    assert d["all-gather"]["raw_bytes"] == 64 * 128 * 2
    assert d["all-gather"]["wire_bytes"] == pytest.approx(
        64 * 128 * 2 * 3 / 4)
    # all-reduce-start: single-shape result, wire = 2(G-1)/G x bytes
    assert d["all-reduce"]["raw_bytes"] == 128 * 4
    assert d["all-reduce"]["wire_bytes"] == pytest.approx(128 * 4 * 1.0)
    # reduce-scatter-start: max tuple element is the INPUT; raw bytes is
    # input/G (the sync form's scattered output), wire = out x (G-1)
    assert d["reduce-scatter"]["raw_bytes"] == 64 * 128 * 4 // 4
    assert d["reduce-scatter"]["wire_bytes"] == pytest.approx(
        16 * 128 * 4 * 3)


def test_hlo_ragged_all_to_all_not_dropped():
    """ragged-all-to-all must precede all-to-all in the regex alternation
    or the op is silently dropped — pinned here."""
    from repro.analysis import parse_collectives
    d = parse_collectives(ASYNC_HLO).to_dict()
    assert d["ragged-all-to-all"]["count"] == 1
    assert d["ragged-all-to-all"]["raw_bytes"] == 32 * 64 * 2
    assert d["ragged-all-to-all"]["wire_bytes"] == pytest.approx(
        32 * 64 * 2 * 3 / 4)
    assert "all-to-all" not in d  # not mis-binned either


# -------------------------------------------------------------- contracts


def test_kernel_contracts_smoke_shapes():
    """One shape per family: plan-resolved blocks trace to exactly the
    compound op's GEMM FLOPs and zero collectives."""
    from repro.analysis.contracts import kernel_contract_checks
    shapes = {"gemm_epilogue_blocks": [(512, 4096, 128)],
              "attention_blocks": [(1024, 1024, 64)],
              "ssd_chunk_len": [(4096, 64, 128)]}
    checks = kernel_contract_checks(shapes)
    families = {c.detail["family"] for c in checks}
    assert families == {"gemm_softmax", "gemm_layernorm",
                        "flash_attention", "ssd"}
    bad = [c.describe() for c in checks if not c.ok]
    assert not bad, "\n".join(bad)


@pytest.mark.slow
def test_kernel_contracts_all_paper_shapes():
    from repro.analysis.contracts import kernel_contract_checks
    checks = kernel_contract_checks()
    assert len(checks) >= 2 * (2 * 3 + 4 + 1)  # 2 checks per (family, shape)
    bad = [c.describe() for c in checks if not c.ok]
    assert not bad, "\n".join(bad)


def test_broken_kernel_is_flagged():
    """A Pallas kernel that issues the dot twice per grid cell (double
    work) MUST fail its FLOP contract with an actionable report."""
    from jax.experimental import pallas as pl
    from repro.analysis import trace_counts
    from repro.analysis.contracts import kernel_contract_checks

    def _trace_broken(co, blocks):
        bm, bk = blocks
        M, K = co.dim_sizes["M"], co.dim_sizes["K"]
        N = co.dim_sizes["N"]

        def kernel(a_ref, b_ref, o_ref):
            a = a_ref[...].astype(jnp.float32)
            b = b_ref[...].astype(jnp.float32)
            # BROKEN: the dot is issued twice -> 2x the contracted FLOPs
            o_ref[...] = (jnp.dot(a, b) + jnp.dot(a, b)).astype(o_ref.dtype)

        def fn(a, b):
            return pl.pallas_call(
                kernel,
                grid=(M // bm, K // bk),
                in_specs=[pl.BlockSpec((bm, bk), lambda mi, ki: (mi, ki)),
                          pl.BlockSpec((bk, N), lambda mi, ki: (ki, 0))],
                out_specs=pl.BlockSpec((bm, N), lambda mi, ki: (mi, 0)),
                out_shape=jax.ShapeDtypeStruct((M, N), jnp.bfloat16),
                interpret=True,
            )(a, b)

        return trace_counts(fn, jax.ShapeDtypeStruct((M, K), jnp.bfloat16),
                            jax.ShapeDtypeStruct((K, N), jnp.bfloat16))

    checks = kernel_contract_checks(
        shapes={"gemm_epilogue_blocks": [(512, 4096, 128)]},
        tracers={"gemm_softmax": _trace_broken})
    bad = [c for c in checks if not c.ok
           and c.name.startswith("gemm_softmax")]
    assert bad, "broken kernel slipped through the contract check"
    fail = bad[0]
    assert fail.kind == "gemm_flops"
    # traced exactly double the prediction
    assert fail.traced == pytest.approx(2 * fail.predicted)
    # the report says which plan lied and by how much
    msg = fail.describe()
    assert "MISMATCH" in msg and "op_sig=" in msg and "predicted=" in msg
    # ...while the untouched sibling kernel still passes
    assert all(c.ok for c in checks if c.name.startswith("gemm_layernorm"))


def test_sharded_contracts_single_device_degrades():
    """On a 1-device mesh the schedule is empty and only the FLOP
    contract remains — and it holds."""
    from jax.sharding import Mesh
    from repro.analysis.contracts import sharded_contract_checks
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    checks = sharded_contract_checks(mesh=mesh)
    assert checks
    assert all(c.kind == "gemm_flops" for c in checks)
    bad = [c.describe() for c in checks if not c.ok]
    assert not bad, "\n".join(bad)


@pytest.mark.slow
def test_cli_smoke_multidevice():
    """`python -m repro.analysis --smoke` in a subprocess: the CLI forces
    8 virtual CPU devices, so the sharded arm runs a REAL 2x4 mesh
    contract check; both arms must pass and emit the JSON schema."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    try:
        r = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "--smoke"],
            env=env, capture_output=True, text=True, timeout=900)
    except (OSError, PermissionError) as e:
        pytest.skip(f"sandbox cannot spawn the CLI subprocess: {e!r}")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    data = json.loads(r.stdout)
    assert data["schema"] == "repro/static-analysis/v2"
    assert data["ok"] and data["contracts"]["ok"] and data["lint"]["ok"]
    names = [c["name"] for c in data["contracts"]["checks"]]
    # the sharded arm ran on a multi-device mesh (2x4 from 8 devices)
    assert any("sharded_softmax_xent[dist" in n for n in names)
    assert any("@P4" in n for n in names)


@pytest.mark.slow
def test_sharded_contracts_equal_axis_sizes():
    """Regression: on a mesh where data and model axes have the SAME size
    (e.g. the 16x16 production mesh), the model-axis stat All-Reduces and
    the data-parallel scalar All-Reduces share a (type, participants)
    tracer bucket — the declared schedule must be aggregated by that key
    before comparison or both checks spuriously fail."""
    script = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'\n"
        "import jax\n"
        "from jax.sharding import Mesh\n"
        "import numpy as np\n"
        "from repro.analysis.contracts import sharded_contract_checks\n"
        "mesh = Mesh(np.array(jax.devices()).reshape(2, 2),\n"
        "            ('data', 'model'))\n"
        "checks = sharded_contract_checks(mesh)\n"
        "bad = [c.describe() for c in checks if not c.ok]\n"
        "assert not bad, '\\n'.join(bad)\n"
        "keys = [c.name for c in checks if 'AllReduce@P2' in c.name]\n"
        "assert keys, 'merged AllReduce@P2 bucket missing'\n"
        "print('EQUAL_AXIS_OK', len(checks))\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    try:
        r = subprocess.run([sys.executable, "-c", script], env=env,
                           capture_output=True, text=True, timeout=900)
    except (OSError, PermissionError) as e:
        pytest.skip(f"sandbox cannot spawn the subprocess: {e!r}")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "EQUAL_AXIS_OK" in r.stdout


def test_softmax_collective_schedule_declaration():
    """The declared schedule (what the planner costs AND what the
    contract checker audits against) — shape pinned."""
    from repro.parallel.collective_planner import softmax_collective_schedule
    d = softmax_collective_schedule("dist", 128, 4096, 8, dp_participants=2)
    assert ("AllReduce", 128 * 4.0, 8, 3) in d      # 3 stat ARs, f32 rows
    assert ("AllReduce", 4.0, 2, 2) in d            # 2 scalar data psums
    g = softmax_collective_schedule("gather", 128, 4096, 8)
    assert g == [("AllGather", 128 * 4096 * 4.0, 8, 1)]  # f32 gathered
    assert softmax_collective_schedule("dist", 128, 4096, 1) == []


# ------------------------------------------------------------------- lint


def test_lint_poly_math_rule():
    from repro.analysis.lint import lint_source
    src = "import math\ndef f(x):\n    return math.ceil(x)\n"
    assert any(f.rule == "poly-no-math" for f in lint_source(src, "core/cost.py"))
    # rule only applies on the polymorphic path
    assert lint_source(src, "models/layers.py") == []
    # allowlisted scalar-only helper in collectives.py
    src_ok = "import math\ndef _factor_table(x):\n    return math.ceil(x)\n"
    assert lint_source(src_ok, "core/collectives.py") == []


def test_lint_poly_array_branch_rule():
    from repro.analysis.lint import lint_source
    bad = "def f(dv):\n    if dv <= 0:\n        return 0\n    return dv\n"
    assert any(f.rule == "poly-array-branch"
               for f in lint_source(bad, "core/cost.py"))
    # the scalar-ok pragma silences an audited site
    ok = ("def f(dv):\n    if dv <= 0:  # scalar-ok: audited\n"
          "        return 0\n    return dv\n")
    assert lint_source(ok, "core/cost.py") == []
    # string compares / len() guards are recognized as scalar
    scalar = ("def f(mode, xs):\n    if mode == 'tree':\n        return 1\n"
              "    if len(xs) > 2:\n        return 2\n    return 0\n")
    assert lint_source(scalar, "core/cost.py") == []


def test_lint_builtin_max_rule():
    from repro.analysis.lint import lint_source
    bad = "def f(a, b):\n    return max(a, b)\n"
    assert any(f.rule == "poly-array-branch"
               for f in lint_source(bad, "core/numerics.py"))
    ok = "def f(a, b):\n    return max(a, b)  # scalar-ok: ints\n"
    assert lint_source(ok, "core/numerics.py") == []


def test_lint_kernel_no_host_rule():
    from repro.analysis.lint import lint_source
    src = ("import numpy as np\nimport jax.numpy as jnp\n"
           "def _foo_kernel(x_ref, o_ref):\n"
           "    s = np.sum(x_ref[...])\n"
           "    v = s.item()\n"
           "    o_ref[...] = jnp.asarray(v, jnp.float64)\n"
           "def host_helper(x):\n"
           "    return np.sum(x)\n")
    findings = lint_source(src, "kernels/foo.py")
    assert {f.rule for f in findings} == {"kernel-no-host"}
    msgs = "\n".join(f.message for f in findings)
    assert "np.sum" in msgs and ".item" in msgs and "float64" in msgs
    # only the kernel body is constrained, not host code
    assert all(f.line <= 6 for f in findings)
    # autotune (host-side planner) is exempt
    assert lint_source(src, "kernels/autotune.py") == []


def test_lint_core_sqlite_rule():
    from repro.analysis.lint import lint_source
    assert any(f.rule == "core-no-sqlite"
               for f in lint_source("import sqlite3\n", "core/foo.py"))
    assert any(f.rule == "core-no-sqlite"
               for f in lint_source("from sqlite3 import connect\n",
                                    "core/foo.py"))
    assert lint_source("import sqlite3\n", "core/planstore.py") == []
    assert lint_source("import sqlite3\n", "serve/api.py") == []


def test_lint_repo_clean():
    """The repo itself must pass its own lint — this is the same gate CI
    runs via `python -m repro.analysis --lint`."""
    from repro.analysis.lint import lint_repo
    findings = lint_repo()
    assert findings == [], "\n".join(f.describe() for f in findings)


def test_vmem_budget_catches_oversized_blocks(tmp_path):
    from repro.analysis.lint import vmem_findings
    kdir = tmp_path / "kernels"
    kdir.mkdir()
    # a gemm kernel declaring blocks 1024x larger than the autotuner's
    # candidates: must blow the double-buffered VMEM budget
    (kdir / "gemm_softmax.py").write_text(
        "from jax.experimental import pallas as pl\n"
        "def run(a, b, block_m, block_k, N):\n"
        "    return pl.pallas_call(\n"
        "        _k,\n"
        "        in_specs=[pl.BlockSpec((block_m * 1024, block_k),\n"
        "                               lambda i, j: (i, j))],\n"
        "        out_specs=pl.BlockSpec((block_m * 1024, N),\n"
        "                               lambda i, j: (i, 0)),\n"
        "    )(a, b)\n")
    findings = vmem_findings(tmp_path)
    assert findings and findings[0].rule == "vmem-budget"
    assert "exceeds" in findings[0].message


def test_vmem_budget_flags_extraction_rot(tmp_path):
    """A kernel file with no recognizable pallas_call is itself a finding
    — the static extraction must not silently rot."""
    from repro.analysis.lint import vmem_findings
    kdir = tmp_path / "kernels"
    kdir.mkdir()
    (kdir / "flash_attention.py").write_text("def f():\n    return 1\n")
    findings = vmem_findings(tmp_path)
    assert findings and findings[0].rule == "vmem-budget"
    assert "no pallas_call" in findings[0].message
