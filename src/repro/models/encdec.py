"""Encoder-decoder model (Seamless-M4T backbone).

The speech/text frontend is a stub per the brief: ``input_specs()`` feeds
precomputed frame embeddings (B, S_enc, d) straight into the encoder.
Encoder layers are bidirectional; decoder layers add causal self-attention
+ cross-attention over the encoder output.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from . import attention as attn
from .config import ModelConfig
from .layers import (apply_norm, embed_apply, embed_specs, mlp_apply,
                     mlp_specs, norm_specs, unembed_apply)
from .transformer import _remat, _unroll, constrain, dp_axes

__all__ = ["encdec_specs", "encdec_forward", "encdec_prefill", "encdec_decode",
           "encdec_init_cache"]


def _enc_layer_specs(cfg: ModelConfig, L: int) -> Dict[str, Any]:
    return {"norm1": norm_specs(cfg, L), "attn": attn.gqa_specs(cfg, L),
            "norm2": norm_specs(cfg, L), "mlp": mlp_specs(cfg, L)}


def _dec_layer_specs(cfg: ModelConfig, L: int) -> Dict[str, Any]:
    return {"norm1": norm_specs(cfg, L), "attn": attn.gqa_specs(cfg, L),
            "norm_x": norm_specs(cfg, L), "cross": attn.cross_specs(cfg, L),
            "norm2": norm_specs(cfg, L), "mlp": mlp_specs(cfg, L)}


def encdec_specs(cfg: ModelConfig) -> Dict[str, Any]:
    s: Dict[str, Any] = dict(embed_specs(cfg))
    s["enc_layers"] = _enc_layer_specs(cfg, cfg.n_enc_layers)
    s["dec_layers"] = _dec_layer_specs(cfg, cfg.n_layers)
    s["enc_final_norm"] = norm_specs(cfg)
    s["final_norm"] = norm_specs(cfg)
    return s


# ---------------------------------------------------------------- encoder


def _encode(cfg: ModelConfig, params: Dict, src: jax.Array,
            mesh: Optional[Mesh]) -> jax.Array:
    dp = dp_axes(mesh)

    def body(x, pl):
        h = attn.attn_train(cfg, pl["attn"], apply_norm(cfg, pl["norm1"], x),
                            causal=False)
        x = x + h
        x = x + mlp_apply(cfg, pl["mlp"], apply_norm(cfg, pl["norm2"], x))
        return constrain(x, mesh, P(dp if dp else None, None, None)), None

    body = _remat(cfg, body)
    x, _ = jax.lax.scan(body, src.astype(jnp.dtype(cfg.dtype)),
                        params["enc_layers"],
                        unroll=_unroll(cfg, cfg.n_enc_layers))
    return apply_norm(cfg, params["enc_final_norm"], x)


# ---------------------------------------------------------------- forward


def encdec_forward(cfg: ModelConfig, params: Dict, src_embeds: jax.Array,
                   tokens: jax.Array, mesh: Optional[Mesh] = None) -> jax.Array:
    dp = dp_axes(mesh)
    enc = _encode(cfg, params, src_embeds, mesh)
    x = embed_apply(params, tokens).astype(jnp.dtype(cfg.dtype))

    def body(x, pl):
        x = x + attn.attn_train(cfg, pl["attn"],
                                apply_norm(cfg, pl["norm1"], x))
        x = x + attn.cross_train(cfg, pl["cross"],
                                 apply_norm(cfg, pl["norm_x"], x), enc)
        x = x + mlp_apply(cfg, pl["mlp"], apply_norm(cfg, pl["norm2"], x))
        return constrain(x, mesh, P(dp if dp else None, None, None)), None

    body = _remat(cfg, body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"],
                        unroll=_unroll(cfg, cfg.n_layers))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed_apply(cfg, params, x)
    return constrain(logits, mesh, P(dp if dp else None, None, "model"))


# ------------------------------------------------------------------ cache


def encdec_init_cache(cfg: ModelConfig, B: int, cache_len: int) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    Se = max(1, cache_len // cfg.enc_ratio)
    self_c = attn.init_attn_cache(cfg, B, cache_len, dt)
    cross_c = {"k": jnp.zeros((B, Se, cfg.n_kv_heads, cfg.hd), dt),
               "v": jnp.zeros((B, Se, cfg.n_kv_heads, cfg.hd), dt)}
    stack = lambda c: jax.tree.map(
        lambda a: jnp.broadcast_to(a, (L,) + a.shape).copy(), c)
    return {"pos": jnp.zeros((B,), jnp.int32), "self": stack(self_c),
            "cross": stack(cross_c)}


def encdec_prefill(cfg: ModelConfig, params: Dict, src_embeds: jax.Array,
                   tokens: jax.Array, cache_len: int,
                   mesh: Optional[Mesh] = None) -> Tuple[jax.Array, Dict]:
    dp = dp_axes(mesh)
    enc = _encode(cfg, params, src_embeds, mesh)
    x = embed_apply(params, tokens).astype(jnp.dtype(cfg.dtype))
    B, S = tokens.shape

    def body(x, pl):
        h, ca = attn.attn_prefill(cfg, pl["attn"],
                                  apply_norm(cfg, pl["norm1"], x))
        x = x + h
        cc = attn.make_cross_cache(cfg, pl["cross"], enc)
        x = x + attn.cross_train(cfg, pl["cross"],
                                 apply_norm(cfg, pl["norm_x"], x), enc)
        x = x + mlp_apply(cfg, pl["mlp"], apply_norm(cfg, pl["norm2"], x))
        x = constrain(x, mesh, P(dp if dp else None, None, None))
        return x, {"self": ca, "cross": cc}

    x, caches = jax.lax.scan(body, x, params["dec_layers"])
    pad = cache_len - S

    def grow(a):
        # stacked self-attn leaves: k/v (L, B, S, H, hd) and the per-row
        # kpos (L, B, S) both pad the sequence axis 2; int32 leaves are
        # position indices padded with -1 (= empty slot), not 0.
        widths = [(0, 0)] * 2 + [(0, pad)] + [(0, 0)] * (a.ndim - 3)
        if a.dtype == jnp.int32:
            return jnp.pad(a, widths, constant_values=-1)
        return jnp.pad(a, widths)

    self_c = jax.tree.map(grow, caches["self"])
    x = apply_norm(cfg, params["final_norm"], x[:, -1:, :])
    logits = unembed_apply(cfg, params, x)
    return logits, {"pos": jnp.full((B,), S, jnp.int32), "self": self_c,
                    "cross": caches["cross"]}


def encdec_decode(cfg: ModelConfig, params: Dict, cache: Dict,
                  tokens: jax.Array, mesh: Optional[Mesh] = None
                  ) -> Tuple[jax.Array, Dict]:
    dp = dp_axes(mesh)
    # scalar or per-row (B,) positions — see transformer.decode
    pos = jnp.broadcast_to(jnp.asarray(cache["pos"], jnp.int32),
                           (tokens.shape[0],))
    x = embed_apply(params, tokens).astype(jnp.dtype(cfg.dtype))

    def body(carry, xs):
        pl, cs, cc = xs
        h, nc = attn.attn_decode(cfg, pl["attn"],
                                 apply_norm(cfg, pl["norm1"], carry), cs, pos)
        carry = carry + h
        carry = carry + attn.cross_decode(cfg, pl["cross"],
                                          apply_norm(cfg, pl["norm_x"], carry), cc)
        carry = carry + mlp_apply(cfg, pl["mlp"],
                                  apply_norm(cfg, pl["norm2"], carry))
        return carry, nc

    x, new_self = jax.lax.scan(body, x, (params["dec_layers"], cache["self"],
                                         cache["cross"]))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed_apply(cfg, params, x)
    logits = constrain(logits, mesh, P(dp if dp else None, None, "model"))
    return logits, {"pos": pos + 1, "self": new_self, "cross": cache["cross"]}
