"""Unit tests for the COMET core: workloads, collectives, cost model,
mapping IR, validation and search."""
import math

import pytest

from repro.core import (attention, flash_attention, gemm, gemm_layernorm,
                        gemm_softmax)
from repro.core.collectives import collective_cost, noc_latency
from repro.core.cost import CostModel, systolic_gemm_cycles
from repro.core.hardware import cloud, edge, tpu_v5e
from repro.core.ir import MappingSpec, evaluate_mapping
from repro.core.search import search
from repro.core.validate import residency_report, validate_tree


# ----------------------------------------------------------------- workload

def test_workload_flops():
    co = gemm(128, 256, 64)
    assert co.total_flops() == 2 * 128 * 256 * 64
    sm = gemm_softmax(128, 256, 64)
    # gemm + 5 simd ops over (M,N)
    assert sm.total_flops() == 2 * 128 * 256 * 64 + 5 * 128 * 256


def test_workload_validation_ordering():
    co = gemm_softmax(8, 8, 8)
    co.validate()  # must not raise
    ln = gemm_layernorm(8, 8, 8)
    assert len(ln.simd_ops()) > len(co.simd_ops())  # LN has more elementary ops


def test_attention_decomposition():
    co = attention(64, 32, 64, 32)
    assert len(co.gemm_ops()) == 2
    fa = flash_attention(64, 32, 64, 32)
    # FA adds online-softmax SIMD work (the paper's SIMD-latency increase)
    assert len(fa.simd_ops()) > len(co.simd_ops())


# --------------------------------------------------------------- collectives

def test_collective_volumes():
    noc = edge().cluster_noc
    dv = 1024.0
    ar = collective_cost("AllReduce", dv, 4, noc)
    ag = collective_cost("AllGather", dv, 4, noc)
    rs = collective_cost("ReduceScatter", dv, 4, noc)
    # AR = RS + AG, each (P-1)/P * DV
    assert ar.volume_bytes == pytest.approx(rs.volume_bytes + ag.volume_bytes)
    assert ag.volume_bytes == pytest.approx(dv * 3 / 4)
    assert rs.volume_bytes == pytest.approx(dv * 3 / 4)
    # single participant: free
    assert collective_cost("AllReduce", dv, 1, noc).volume_bytes == 0


def test_collective_monotone_in_participants():
    noc = cloud().cluster_noc
    lats = []
    for p in (2, 4, 8, 16):
        cc = collective_cost("AllReduce", 1 << 20, p, noc)
        lats.append(noc_latency(cc, noc) + cc.volume_bytes / noc.channel_bandwidth)
    assert all(b >= a for a, b in zip(lats, lats[1:]))


# ----------------------------------------------------------------- cost model

def test_systolic_cycles():
    # one fold: rows + m + cols - 1
    assert systolic_gemm_cycles(16, 32, 32, 32, 32, 1) == 32 + 16 + 31
    # k=64 -> 2 folds on one array
    assert systolic_gemm_cycles(16, 32, 64, 32, 32, 1) == 2 * (32 + 16 + 31)
    # 64 arrays absorb 64 folds
    assert systolic_gemm_cycles(16, 256, 256, 32, 32, 64) == 32 + 16 + 31


def test_eq2_structure():
    """Latency = N*MW + CS + OS: doubling temporal iterations ~doubles
    the window term."""
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    r1 = evaluate_mapping(co, arch, MappingSpec(variant="fused_dist",
                                                m_tiles=4, k_tiles=2))
    r2 = evaluate_mapping(co, arch, MappingSpec(variant="fused_dist",
                                                m_tiles=8, k_tiles=2))
    assert r1.valid and r2.valid
    assert r1.latency > 0 and r2.latency > 0


def test_fusion_reduces_dram_energy():
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    unf = evaluate_mapping(co, arch, MappingSpec(variant="unfused", m_tiles=8,
                                                 k_tiles=2))
    fus = evaluate_mapping(co, arch, MappingSpec(variant="fused_dist",
                                                 m_tiles=8, k_tiles=2))
    assert fus.cost.energy_breakdown["DRAM"] < unf.cost.energy_breakdown["DRAM"]
    assert fus.latency < unf.latency


def test_explicit_collectives_present_only_in_dist():
    from repro.core.mapping import CollectiveNode, walk
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    dist = evaluate_mapping(co, arch, MappingSpec(variant="fused_dist",
                                                  m_tiles=8, k_tiles=2))
    n_col = sum(isinstance(n, CollectiveNode) for n in walk(dist.root))
    assert n_col == 2      # AR(max) + AR(add), Fig 4(c)
    std = evaluate_mapping(co, arch, MappingSpec(variant="fused_std",
                                                 m_tiles=8, k_tiles=2))
    kinds = [n.col_type for n in walk(std.root)
             if isinstance(n, CollectiveNode)]
    assert kinds == ["Gather"]


def test_stats_granularity_cheaper():
    """Beyond-paper: M×1-stats collectives always <= M×N-tile collectives."""
    co = gemm_softmax(512, 4096, 128)
    arch = cloud()
    tile = evaluate_mapping(co, arch, MappingSpec(variant="fused_dist",
                                                  m_tiles=8, k_tiles=2,
                                                  collective_gran="tile"))
    stats = evaluate_mapping(co, arch, MappingSpec(variant="fused_dist",
                                                   m_tiles=8, k_tiles=2,
                                                   collective_gran="stats"))
    assert stats.cost.lat_breakdown["collective"] < \
        tile.cost.lat_breakdown["collective"]


def test_layernorm_fusion_beats_softmax_fusion():
    """Paper: GEMM-LN fusion win (3.46x) > GEMM-SM fusion win (1.42x)."""
    arch = cloud()
    M, N, K = 512, 4096, 128
    def ratio(wl):
        co = wl(M, N, K)
        unf = search(co, arch, budget=150, seed=0, variants=["unfused"]).latency
        fus = search(co, arch, budget=150, seed=0,
                     variants=["fused_dist"]).latency
        return unf / fus
    assert ratio(gemm_layernorm) > ratio(gemm_softmax) * 0.9


# --------------------------------------------------------------- validation

def test_memory_validation_rejects_oversized():
    co = gemm_softmax(8192, 8192, 128)
    arch = edge()
    # m_tiles=1 -> full M rows staged in 2MB GB: must be invalid
    r = evaluate_mapping(co, arch, MappingSpec(variant="fused_std", m_tiles=1))
    assert not r.valid


def test_residency_report_levels():
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    r = evaluate_mapping(co, arch, MappingSpec(variant="fused_dist",
                                               m_tiles=8, k_tiles=2))
    levels = {lvl for lvl, *_ in residency_report(r.root, arch, r.tiling,
                                                  co.tensors)}
    assert levels == {"DRAM", "GB", "OB"}


# ------------------------------------------------------------------- search

def test_search_deterministic_and_improving():
    co = gemm_softmax(512, 2048, 128)
    arch = cloud()
    r1 = search(co, arch, budget=200, seed=3)
    r2 = search(co, arch, budget=200, seed=3)
    assert r1.latency == r2.latency
    # search beats the default spec
    default = evaluate_mapping(co, arch, MappingSpec())
    assert r1.latency <= default.latency
    assert r1.best.valid


def test_search_attention_prefers_fa_for_large_M():
    arch = cloud()
    res = search(flash_attention(2048, 256, 2048, 256), arch, budget=150,
                 seed=0, variants=["fa"])
    ua = search(attention(2048, 256, 2048, 256), arch, budget=150, seed=0,
                variants=["ua"])
    assert res.latency < ua.latency


# -------------------------------------------------------------------- YAML

def test_yaml_roundtrip():
    from repro.core import yamlio
    doc = yamlio.load_spec("""
workload: {kind: gemm_softmax, dims: {M: 256, N: 1024, K: 64}}
architecture: edge
mapping: {variant: fused_dist, m_tiles: 4, k_tiles: 2}
""")
    r = yamlio.run_spec(doc)
    assert r.valid and r.latency > 0
    doc2 = yamlio.load_spec("""
workload: {kind: gemm_softmax, dims: {M: 256, N: 1024, K: 64}}
architecture: edge
constraints: {budget: 100, seed: 1}
""")
    s = yamlio.run_spec(doc2)
    assert s.latency <= r.latency * 10
