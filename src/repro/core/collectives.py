"""Collective-operation hop/volume models (COMET §IV-B, Eq. 3/4).

The paper uses the recursive doubling/halving algorithms [30] to compute
both the total number of hops and the total data volume moved for each
collective type.  Participants are peer memory instances at one level of
the hierarchy (e.g. the GBs of all clusters), laid out row-major on the
level's NoC mesh; hop distances are Manhattan distances between exchange
partners.

Conventions
-----------
``data_volume`` (DV) passed in is the *logical tensor size in bytes* on
which the collective operates (the full tensor for All-Reduce / the
gathered result for All-Gather, matching the paper's Tensor annotation on
CO nodes).  Each model returns:

    CollectiveCost(volume_bytes, hops, steps)

where ``volume_bytes`` is the total bytes moved across the NoC per
participant (the busiest node's traffic, which Eq. 3 charges), and
``hops`` is the summed hop distance of its exchange schedule.

Tabulated factors
-----------------
For every collective type the busiest-node volume is ``DV * f(P)`` where
``f`` depends only on the participant count (and the NoC, for All-to-All
hops) — the per-partition communication-factor formulation of DFModel and
of the multi-commodity-flow view of collectives.  Both the scalar path
and the batched array path therefore read one precomputed, per-NoC cached
``P -> (volume_factor, hops, steps)`` table (:func:`_factor_table`): the
scalar path indexes it at one P, the array path gathers it with a single
``np.take``, so the two are bit-identical by construction no matter how
many unique participant counts a divisor-complete fanout grid produces.

Non-power-of-two participants use the dissemination (Bruck) exchange
schedule: step ``i`` moves ``min(2^i, P - 2^i)`` shards of ``DV/P``,
which sums to exactly ``(P-1)/P * DV`` for *every* P.  For powers of two
this equals the recursive halving/doubling volumes; for other P it
replaces the old next-power-of-two round-up that silently overcharged
3/5/6-way fanouts.
"""
from __future__ import annotations

import math
import threading
from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

from .hardware import NoCParams
from .numerics import is_array

__all__ = [
    "CollectiveCost",
    "collective_cost",
    "collective_latency_terms",
    "collective_seconds",
    "collective_overlap_terms",
    "overlapped_collective_seconds",
    "noc_latency",
    "collective_cache_clear",
    "COLLECTIVE_TYPES",
]

COLLECTIVE_TYPES = (
    "AllReduce",
    "AllGather",
    "ReduceScatter",
    "Gather",
    "Broadcast",
    "AllToAll",
)


@dataclass(frozen=True)
class CollectiveCost:
    volume_bytes: float   # bytes through the busiest participant
    hops: int             # summed exchange-partner hop distance
    steps: int            # number of communication steps


def _step_distances(noc: NoCParams, participants: int) -> Tuple[int, ...]:
    """Manhattan distance of the partner at linear offset 2^i, for each
    dissemination step i (ceil(log2 P) steps)."""
    if participants <= 1:
        return ()
    steps = max(1, math.ceil(math.log2(participants)))
    return tuple(
        noc.manhattan(0, min((1 << i), noc.num_nodes - 1) if noc.num_nodes > 1 else 0)
        or 1
        for i in range(steps)
    )


# ----------------------------------------------------- per-P factor tables


@dataclass(frozen=True)
class _FactorTable:
    """P-indexed (volume_factor, hops, steps) arrays for one (NoC,
    collective type): ``volume_bytes = DV * volume_factor[P]``.  Arrays are
    read-only — they are shared across every query against this NoC."""

    volume_factor: np.ndarray   # float64, vol = DV * volume_factor[P]
    hops: np.ndarray            # int64
    steps: np.ndarray           # int64

    @property
    def size(self) -> int:
        return int(self.volume_factor.shape[0])


# NoCParams is a frozen dataclass, so instances hash by parameter value:
# equal-parameter NoCs share one table.  search_many fans searches out over
# threads that share these caches, hence the lock around table builds.
_FACTOR_TABLES: Dict[Tuple[NoCParams, str], _FactorTable] = {}
_MESH_AVG_CACHE: Dict[NoCParams, float] = {}
_TABLE_LOCK = threading.Lock()


def collective_cache_clear() -> None:
    """Drop the per-NoC factor tables and mesh-distance cache (tests)."""
    with _TABLE_LOCK:
        _FACTOR_TABLES.clear()
        _MESH_AVG_CACHE.clear()


def _scalar_factors(col_type: str, P: int, noc: NoCParams
                    ) -> Tuple[float, int, int]:
    """(volume_factor, hops, steps) for one participant count — the single
    source of truth the table is built from.

    Dissemination (Bruck) schedule: step i moves min(2^i, P-2^i) shards of
    DV/P, so every type's busiest-node volume is exactly (P-1)/P * DV
    (recursive halving/doubling recovers the same volumes at power-of-two
    P); All-Reduce is ReduceScatter + AllGather.  Gather/Broadcast are
    binomial trees whose root moves (P-1)/P * DV; All-to-All is P-1 paired
    direct exchanges at the mesh-average Manhattan distance.
    """
    if P <= 1:
        return 0.0, 0, 0
    if col_type == "AllReduce":
        vf, hops, steps = _scalar_factors("ReduceScatter", P, noc)
        return 2.0 * vf, 2 * hops, 2 * steps
    if col_type == "AllToAll":
        avg = _mesh_avg_distance(noc)
        return (P - 1) / P, int(round(avg * (P - 1))), P - 1
    dists = _step_distances(noc, P)
    if col_type in ("ReduceScatter", "AllGather", "Gather", "Broadcast"):
        return (P - 1) / P, sum(dists), len(dists)
    raise ValueError(f"unknown collective type {col_type!r}")


def _factor_table(noc: NoCParams, col_type: str, max_p: int) -> _FactorTable:
    """Cached (noc, col_type) -> P-indexed factor table covering at least
    ``max_p`` participants (tables are built to the NoC node count up
    front, so divisor-complete fanout grids never rebuild them)."""
    key = (noc, col_type)
    tbl = _FACTOR_TABLES.get(key)
    if tbl is not None and tbl.size > max_p:
        return tbl
    with _TABLE_LOCK:
        tbl = _FACTOR_TABLES.get(key)
        if tbl is not None and tbl.size > max_p:
            return tbl
        size = max(max_p, noc.num_nodes, 1) + 1
        vf = np.zeros(size, dtype=np.float64)
        hops = np.zeros(size, dtype=np.int64)
        steps = np.zeros(size, dtype=np.int64)
        for p in range(2, size):
            vf[p], hops[p], steps[p] = _scalar_factors(col_type, p, noc)
        for arr in (vf, hops, steps):
            arr.flags.writeable = False
        tbl = _FactorTable(vf, hops, steps)
        _FACTOR_TABLES[key] = tbl
        return tbl


def collective_cost(
    col_type: str,
    data_volume: float,
    participants: int,
    noc: NoCParams,
) -> CollectiveCost:
    """Volume/hops for one collective over ``participants`` peers.

    Every type moves (P-1)/P * DV through the busiest node (All-Reduce =
    RS + AG => 2*DV*(P-1)/P); see :func:`_scalar_factors` for the exchange
    schedules.  Both the scalar path and the array path read the cached
    per-NoC factor table, so array results are bit-identical elementwise
    to the scalar-P calls.

    ``participants`` may be a NumPy int array (the batched engine folds
    the spatial-fanout axes into its grid, so CO nodes carry one
    participant count per grid point); the result is then a
    :class:`CollectiveCost` of arrays gathered from the same table.
    """
    if is_array(participants):
        return _collective_cost_array(col_type, data_volume, participants,
                                      noc)
    P = int(participants)
    if P <= 1:  # scalar-ok: int() cast above
        return CollectiveCost(0.0, 0, 0)
    if is_array(data_volume):
        if np.all(data_volume <= 0):
            return CollectiveCost(0.0, 0, 0)
    elif data_volume <= 0:  # scalar-ok: is_array branch above
        return CollectiveCost(0.0, 0, 0)
    if col_type not in COLLECTIVE_TYPES:
        raise ValueError(f"unknown collective type {col_type!r}")

    tbl = _factor_table(noc, col_type, P)
    vol = data_volume * tbl.volume_factor[P]
    hops = int(tbl.hops[P])
    steps = int(tbl.steps[P])
    if is_array(vol):
        # Batched-DV path: grid points with dv <= 0 move nothing (the
        # scalar path short-circuits those to a zero CollectiveCost above).
        vol = np.where(np.asarray(data_volume) > 0, vol, 0.0)
        return CollectiveCost(vol, hops, steps)
    return CollectiveCost(float(vol), hops, steps)


def _collective_cost_array(col_type: str, data_volume, participants,
                           noc: NoCParams) -> CollectiveCost:
    """Batched participants: gather (volume_factor, hops, steps) from the
    cached per-NoC table with one ``np.take`` per field.  The scalar path
    reads the same table entries, so results are bit-identical elementwise
    regardless of how many unique participant counts the grid holds."""
    if col_type not in COLLECTIVE_TYPES:
        raise ValueError(f"unknown collective type {col_type!r}")
    P = np.asarray(participants)
    dv = np.asarray(data_volume, dtype=np.float64)
    shape = np.broadcast_shapes(P.shape, dv.shape)
    max_p = int(P.max()) if P.size else 1
    tbl = _factor_table(noc, col_type, max_p)
    # P <= 1 rows in the table are zero, matching the scalar short-circuit;
    # negative requests clamp onto the zero row.
    idx = np.maximum(P, 0)
    vf = np.take(tbl.volume_factor, idx)
    vol = np.where(dv > 0, dv * vf, 0.0)
    hops = np.broadcast_to(np.take(tbl.hops, idx), shape)
    steps = np.broadcast_to(np.take(tbl.steps, idx), shape)
    return CollectiveCost(np.broadcast_to(vol, shape), hops, steps)


def _mesh_avg_distance(noc: NoCParams) -> float:
    """Mean Manhattan distance between distinct nodes of the NoC mesh,
    cached per NoCParams — the O(nodes^2) scan runs once per NoC, not once
    per All-to-All query (a 16x16 mesh is ~65k ``manhattan`` calls)."""
    hit = _MESH_AVG_CACHE.get(noc)
    if hit is not None:
        return hit
    r, c = noc.mesh
    if r * c <= 1:
        out = 1.0
    else:
        total = 0
        for a in range(r * c):
            for b in range(r * c):
                if a != b:
                    total += noc.manhattan(a, b)
        out = total / (r * c * (r * c - 1))
    _MESH_AVG_CACHE[noc] = out
    return out


def collective_latency_terms(
    col_type: str,
    data_volume: float,
    participants: int,
    noc: NoCParams,
) -> Tuple[CollectiveCost, float, float]:
    """End-to-end seconds for ONE collective execution, decomposed.

    Returns ``(cost, mem_lat, total)`` where ``mem_lat`` is the Eq. 1
    MemLat term charged at the NoC channel bandwidth (the collective's
    boundary-transfer time) and ``total = mem_lat + NoCLat`` is the full
    Eq. 4 latency.  This is the single prediction the cost model
    (:meth:`repro.core.cost.CostModel.collective_cost_node`) and the
    measured-collective calibration loop (``repro.calibrate``) both
    charge — the calibration fitter inverts exactly this formula, so a
    fitted ``NoCParams`` fed back through here reproduces the measured
    sweep by construction.  Array-polymorphic like :func:`collective_cost`.
    """
    cc = collective_cost(col_type, data_volume, participants, noc)
    mem_lat = cc.volume_bytes / noc.channel_bandwidth
    return cc, mem_lat, mem_lat + noc_latency(cc, noc)


def collective_seconds(
    col_type: str,
    data_volume: float,
    participants: int,
    noc: NoCParams,
) -> float:
    """Eq. 4 total seconds for one collective (convenience over
    :func:`collective_latency_terms`)."""
    return collective_latency_terms(col_type, data_volume, participants,
                                    noc)[2]


def collective_overlap_terms(
    col_type: str,
    data_volume: float,
    participants: int,
    noc: NoCParams,
) -> Tuple[float, float]:
    """Per-collective-type ``(hideable, exposed)`` decomposition, seconds.

    ``hideable`` is the Eq. 1 MemLat term — the channel-bandwidth transfer
    time a double-buffered fused kernel can run concurrently with compute
    (gather chunk *i+1* in flight while chunk *i* is consumed).  ``exposed``
    is the Eq. 3 NoCLat enqueue/router term that stays serial no matter the
    schedule: every chunk still pays its injection and routing cost.  The
    cost model's overlap factor scales only the hideable term, and the
    calibration fitter (``repro.calibrate.overlap``) expresses measured
    concurrent sweeps in exactly this split, so a fitted *achievable*
    overlap plugs into the model without unit conversion.
    """
    cc, mem_lat, total = collective_latency_terms(
        col_type, data_volume, participants, noc)
    return mem_lat, total - mem_lat


def overlapped_collective_seconds(
    col_type: str,
    data_volume: float,
    participants: int,
    noc: NoCParams,
    *,
    overlap: float = 0.0,
    compute_seconds: float = math.inf,
) -> float:
    """Eq. 4 seconds for one collective with ``overlap`` of its hideable
    time hidden under ``compute_seconds`` of dependency-adjacent compute
    (the scalar analog of the ``TileNode.overlap`` window adjustment in
    :meth:`repro.core.cost.CostModel.tile_cost`).  ``overlap=0`` is exactly
    :func:`collective_seconds`; the result never drops below the exposed
    enqueue term."""
    hideable, exposed = collective_overlap_terms(
        col_type, data_volume, participants, noc)
    hidden = overlap * min(hideable, compute_seconds)
    return hideable + exposed - hidden


def noc_latency(cost: CollectiveCost, noc: NoCParams) -> float:
    """Eq. 3: NoCLat = t_router * hops + t_enq * DV / W  (seconds)."""
    if is_array(cost.volume_bytes):
        lat = (noc.t_router * cost.hops
               + noc.t_enq * (cost.volume_bytes / noc.channel_width))
        return np.where(cost.volume_bytes > 0, lat, 0.0)
    if cost.volume_bytes <= 0:  # scalar-ok: is_array returned above
        return 0.0
    return noc.t_router * cost.hops + noc.t_enq * (cost.volume_bytes / noc.channel_width)
