"""End-to-end training driver.

Wires together: model zoo (--arch, reduced or full config), synthetic data
pipeline, sharded TrainState (ZeRO-1), jitted train step (optional µbatch
accumulation, COMET-planned loss collectives, int8 grad compression),
async checkpointing with keep-k retention, exact restart from the latest
checkpoint, and straggler monitoring.

Example (CPU, reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, get_smoke_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import Model
from repro.parallel.sharding import (batch_sharding, param_shardings,
                                     zero1_shardings)
from repro.train.checkpoint import AsyncCheckpointer, latest_step, \
    restore_checkpoint
from repro.train.data import SyntheticLM
from repro.train.elastic import StragglerMonitor
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import TrainState, make_train_step

__all__ = ["train_loop", "main"]


def warmup_kernel_plans(model: Model, seq: int) -> Dict[str, int]:
    """Pre-solve the COMET block-selection plans the training step's
    kernels will ask for (attention blocks at the training sequence
    length, SSD chunk lengths) through the shared PlanCache, so tracing
    the first step hits the store instead of searching."""
    from repro.core.plan import get_plan_cache
    from repro.kernels.autotune import plan_jobs

    cfg = model.cfg
    shapes: Dict[str, Any] = {}
    if not cfg.has_ssm or cfg.family == "hybrid":
        shapes["attention_blocks"] = [(seq, seq, cfg.hd)]
    if cfg.has_ssm:
        shapes["ssd_chunk_len"] = [(seq, cfg.ssm_headdim, cfg.ssm_state)]
    return get_plan_cache().warmup(plan_jobs(shapes),
                                   sweep_id="train-warmup")


def train_loop(model: Model, *, steps: int, batch: int, seq: int,
               mesh=None, opt_cfg: Optional[OptConfig] = None,
               microbatches: int = 1, use_planner_loss: bool = False,
               ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
               keep: int = 3, seed: int = 0,
               log_every: int = 10,
               warmup_plans: bool = False) -> Dict[str, Any]:
    cfg = model.cfg
    opt_cfg = opt_cfg or OptConfig(total_steps=steps)
    if warmup_plans:
        from repro.core.plan import get_plan_cache
        ws = warmup_kernel_plans(model, seq)
        store = get_plan_cache().store_stats()["store"]
        print(f"[train] plan warmup: {ws['solved']} solved, "
              f"{ws['hits']} already cached "
              f"(store: {store.get('backend')}, "
              f"{store.get('plans', 0)} plans)")
    data = SyntheticLM(cfg.vocab_size, seq, batch, seed=seed,
                       encdec=cfg.is_encdec, d_model=cfg.d_model,
                       enc_ratio=cfg.enc_ratio)

    params = model.init(jax.random.PRNGKey(seed))
    opt = init_opt_state(params, compression=opt_cfg.grad_compression)
    state = TrainState(params, opt)

    if mesh is not None:
        ax, ab = model.param_axes(), model.abstract_params()
        psh = param_shardings(ax, ab, mesh)
        zsh = zero1_shardings(ax, ab, mesh)
        state = TrainState(
            params=jax.device_put(state.params, psh),
            opt=state.opt._replace(
                m=jax.device_put(state.opt.m, zsh),
                v=jax.device_put(state.opt.v, zsh),
                err=(jax.device_put(state.opt.err, zsh)
                     if state.opt.err is not None else None)))

    start_step = 0
    ckptr = None
    if ckpt_dir:
        ckptr = AsyncCheckpointer(ckpt_dir, keep=keep)
        if latest_step(ckpt_dir) is not None:
            state, start_step, extra = restore_checkpoint(ckpt_dir, state)
            print(f"[train] restored step {start_step} from {ckpt_dir}")

    step_fn = jax.jit(
        make_train_step(model, opt_cfg, mesh, microbatches=microbatches,
                        use_planner_loss=use_planner_loss),
        donate_argnums=(0,))

    mon = StragglerMonitor()
    losses = []
    t_start = time.time()
    for step in range(start_step, steps):
        b = data.batch(step)
        jb = {k: jnp.asarray(v) for k, v in b.items()}
        if mesh is not None:
            jb = {k: jax.device_put(v, batch_sharding(mesh, batch, v.ndim))
                  for k, v in jb.items()}
        mon.start()
        state, metrics = step_fn(state, jb)
        loss = float(metrics["loss"])
        straggler = mon.stop(step)
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):8.3f} "
                  f"lr {float(metrics['lr']):.2e}"
                  + (" STRAGGLER" if straggler else ""), flush=True)
        if ckptr and (step + 1) % ckpt_every == 0:
            ckptr.save(step + 1, state)
    if ckptr:
        ckptr.save(steps, state)
        ckptr.wait()
        if ckptr.errors:
            raise RuntimeError(f"checkpoint errors: {ckptr.errors}")
    wall = time.time() - t_start
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "wall_s": wall, "straggler_events": mon.events,
            "steps_done": steps - start_step}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--planner-loss", action="store_true")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", choices=["none", "host", "production",
                                       "production-multi"], default="none")
    ap.add_argument("--plan-cache", default=None, metavar="DIR",
                    help="mapping-plan store directory "
                         "(default: $REPRO_PLAN_CACHE or ~/.cache/repro-plans)")
    ap.add_argument("--warmup-plans", action="store_true",
                    help="pre-solve kernel block-selection plans at startup")
    args = ap.parse_args()

    if args.plan_cache:
        os.environ["REPRO_PLAN_CACHE"] = args.plan_cache
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    mesh = None
    if args.mesh == "host":
        mesh = make_host_mesh()
    elif args.mesh.startswith("production"):
        mesh = make_production_mesh(multi_pod=args.mesh.endswith("multi"))
    out = train_loop(
        model, steps=args.steps, batch=args.batch, seq=args.seq, mesh=mesh,
        opt_cfg=OptConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(1, args.steps // 10),
                          grad_compression=args.grad_compression),
        microbatches=args.microbatches, use_planner_loss=args.planner_loss,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        warmup_plans=args.warmup_plans)
    print(json.dumps({"final_loss": out["final_loss"],
                      "wall_s": round(out["wall_s"], 1),
                      "steps": out["steps_done"]}))


if __name__ == "__main__":
    main()
