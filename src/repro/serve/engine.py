"""Batched serving engine: prefill + greedy decode with fixed-shape jitted
steps and slot-based continuous batching (finished sequences are replaced
from the request queue without recompiling — the decode step shape never
changes).

Continuous batching is *correct* continuous batching: when a slot frees
mid-decode, the request that takes it over is **re-prefilled** — all slots
refilled in the same step share one batched prefill call — and its rows of
the KV cache, per-slot position vector and last-token vector are spliced
in while the other slots keep decoding undisturbed.  (The per-slot
positions come from the model layer: ``cache['pos']`` is a (B,) vector and
attention masks/RoPE are per-row, so a freshly prefilled slot decodes
exactly as it would in a batch of its own.)

Startup also **warms the mapping-plan cache** (`repro.core.plan`): the
engine pre-solves the COMET block-selection plans for its prefill and
decode kernel shapes through ``PlanCache.warmup``, so the first traced
kernel finds its plan already on disk instead of running a search inside
the trace.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..models.model import Model

__all__ = ["Request", "ServeEngine"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # wall-clock decode budget from slot admission; a request that blows
    # it is force-finished (``timed_out``) so it cannot pin a slot until
    # the engine-global ``max_steps``
    deadline_s: Optional[float] = None
    # filled by the engine:
    output: List[int] = field(default_factory=list)
    done: bool = False
    timed_out: bool = False


class ServeEngine:
    """Fixed batch of decode slots; requests stream through them.

    Per-request guards: ``Request.max_new_tokens`` (optionally clamped
    by the engine's ``max_new_cap``) bounds tokens, and
    ``Request.deadline_s`` (default ``default_deadline_s``) bounds wall
    time per slot occupancy — one runaway request degrades to a
    truncated answer instead of holding a decode slot hostage."""

    def __init__(self, model: Model, params, *, batch_size: int,
                 cache_len: int, prompt_len: int,
                 mesh: Optional[Mesh] = None,
                 plan_warmup: bool = True,
                 max_new_cap: Optional[int] = None,
                 default_deadline_s: Optional[float] = None):
        self.model = model
        self.params = params
        self.mesh = mesh
        self.B = batch_size
        self.cache_len = cache_len
        self.prompt_len = prompt_len
        self.max_new_cap = max_new_cap
        self.default_deadline_s = default_deadline_s
        cfg = model.cfg

        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len, mesh))
        self._decode = jax.jit(
            lambda p, c, t: model.decode(p, c, t, mesh),
            donate_argnums=(1,))
        self.stats: Dict[str, float] = {"prefill_calls": 0, "decode_steps": 0,
                                        "tokens_out": 0, "timeouts": 0}
        if plan_warmup:
            self.warm_plans()

    # ------------------------------------------------------------- plans
    def plan_shapes(self) -> Dict[str, List]:
        """The kernel shapes this engine's prefill/decode steps can ask
        the autotuner for (``PAPER_KERNEL_SHAPES``-style table): the
        prefill self-attention block (prompt_len x prompt_len), the
        decode block over the full cache (1 x cache_len — the CPU decode
        path uses dense einsums, but a kernelized flash-decoding backend
        asks for exactly this shape, so the plan is pre-solved either
        way), and — for SSD families — the chunk-length sweep for the
        prompt length."""
        cfg = self.model.cfg
        shapes: Dict[str, List] = {}
        if not cfg.has_ssm or cfg.family == "hybrid":
            shapes["attention_blocks"] = [
                (self.prompt_len, self.prompt_len, cfg.hd),   # prefill
                (1, self.cache_len, cfg.hd),                  # decode
            ]
        if cfg.has_ssm:
            shapes["ssd_chunk_len"] = [
                (self.prompt_len, cfg.ssm_headdim, cfg.ssm_state)]
        return shapes

    def warm_plans(self) -> Dict[str, int]:
        """Pre-solve the block-selection plans for this engine's kernel
        shapes in one ``search_many`` sweep and persist them (PlanCache
        disk store), so neither this process nor any later one re-solves
        at trace time."""
        from ..kernels.autotune import plan_jobs
        from ..core.plan import get_plan_cache

        t0 = time.time()
        stats = get_plan_cache().warmup(plan_jobs(self.plan_shapes()),
                                        sweep_id="serve-warmup")
        self.stats["plan_warmup_hits"] = stats["hits"]
        self.stats["plan_warmup_solved"] = stats["solved"]
        self.stats["plan_warmup_s"] = time.time() - t0
        return stats

    # ------------------------------------------------------------- serving
    def _pad_prompts(self, rows: Sequence[Optional[Request]]) -> np.ndarray:
        """(B, prompt_len) token rows, right-aligned; ``None`` rows (empty
        or not-being-refilled slots) stay zero."""
        toks = np.zeros((self.B, self.prompt_len), np.int32)
        for i, r in enumerate(rows):
            if r is None:
                continue
            t = r.prompt[-self.prompt_len:]
            toks[i, -len(t):] = t          # right-aligned
        return toks

    def _prefill_batch(self, rows: Sequence[Optional[Request]]):
        """One batched prefill over ``rows`` (None rows carry zeros).
        Returns (last-token vector, cache with per-slot positions)."""
        batch = {"tokens": jnp.asarray(self._pad_prompts(rows))}
        if self.model.cfg.is_encdec:
            Se = max(1, self.prompt_len // self.model.cfg.enc_ratio)
            batch["src_embeds"] = jnp.zeros(
                (self.B, Se, self.model.cfg.d_model), jnp.float32)
        logits, cache = self._prefill(self.params, batch)
        self.stats["prefill_calls"] += 1
        last = jnp.argmax(logits[:, -1, :self.model.cfg.vocab_size], -1)
        cache = dict(cache)
        # per-slot decode positions from the start: the merged cache keeps
        # one compiled decode shape whether or not slots ever diverge
        cache["pos"] = jnp.broadcast_to(
            jnp.asarray(cache["pos"], jnp.int32), (self.B,))
        return last, cache

    def _refill_prefill(self, active: Sequence[Optional[Request]],
                        idxs: List[int], cache, last):
        """Prefill the newly refilled slots (one batched call however many
        freed this step) and splice their rows — KV/state cache, position,
        last token — into the live decode state."""
        rows = [r if i in idxs else None for i, r in enumerate(active)]
        fresh_last, fresh = self._prefill_batch(rows)
        if cache is None:                  # initial fill: take it wholesale
            return fresh_last, fresh
        sel = np.zeros(self.B, dtype=bool)
        sel[idxs] = True
        selj = jnp.asarray(sel)

        def splice(old, new):
            # stacked cache leaves are (L, B, ...): batch axis 1
            shape = [1] * old.ndim
            shape[1] = self.B
            return jnp.where(selj.reshape(shape), new, old)

        merged = {"pos": jnp.where(selj, fresh["pos"], cache["pos"])}
        for key in cache:
            if key != "pos":
                merged[key] = jax.tree.map(splice, cache[key], fresh[key])
        return jnp.where(selj, fresh_last, last), merged

    def _token_budget(self, r: Request) -> int:
        return (r.max_new_tokens if self.max_new_cap is None
                else min(r.max_new_tokens, self.max_new_cap))

    def run(self, requests: List[Request], *, max_steps: int = 10_000
            ) -> List[Request]:
        """Process all requests with continuous slot reuse."""
        queue = list(requests)
        active: List[Optional[Request]] = [None] * self.B
        admitted: List[float] = [0.0] * self.B    # slot admission times

        def refill() -> List[int]:
            new = []
            for i in range(self.B):
                if active[i] is None and queue:
                    active[i] = queue.pop(0)
                    admitted[i] = time.monotonic()
                    new.append(i)
            return new

        last, cache = self._refill_prefill(active, refill(), None, None)

        for _step in range(max_steps):
            if all(r is None or r.done for r in active) and not queue:
                break
            tok = last[:, None].astype(jnp.int32)
            logits, cache = self._decode(self.params, cache, tok)
            self.stats["decode_steps"] += 1
            last = jnp.argmax(logits[:, -1, :self.model.cfg.vocab_size], -1)
            host = np.asarray(last)
            now = time.monotonic()
            for i, r in enumerate(active):
                if r is None or r.done:
                    continue
                r.output.append(int(host[i]))
                self.stats["tokens_out"] += 1
                deadline = (r.deadline_s if r.deadline_s is not None
                            else self.default_deadline_s)
                if deadline is not None and now - admitted[i] >= deadline:
                    # runaway guard: force-finish instead of pinning the
                    # slot until the engine-global max_steps
                    r.timed_out = True
                    self.stats["timeouts"] += 1
                elif not (len(r.output) >= self._token_budget(r)
                          or (r.eos_id is not None and host[i] == r.eos_id)):
                    continue
                r.done = True
                active[i] = None           # slot freed (continuous batching)
            new = refill()
            if new:
                # the bug this fixes: refilled slots used to inherit the
                # previous occupant's KV cache and last token
                last, cache = self._refill_prefill(active, new, cache, last)
        return [r for r in requests]
