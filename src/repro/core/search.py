"""Map-space search (COMET §V-A).

The 4-D design space of Fig. 1 — tiling factors x loop order/spatial
unrolling x collective strategy x scheduling — factors into a handful of
discrete *topologies* and a dense grid per topology (see
:mod:`.batcheval`): temporal tiling counts, the ``sp_cluster``/``sp_core``
spatial unrolling fanouts and the schedule mask are all grid axes.  For
the paper's compound ops the whole enumerable space is a few thousand
points, so ``search()`` is **exhaustive by default**: every topology's
grid is evaluated in one vectorized pass and the global optimum is
returned.  When the grid exceeds ``exhaustive_limit`` (custom candidate
sets, huge dims) it falls back to the paper's randomized + hill-climb
sampling (budget up to 10,000 iterations, deterministic under ``seed``),
now served through a shared LRU evaluation cache.

``objective='pareto'`` returns the latency/energy Pareto front instead of
a single scalar winner: ``SearchResult.front`` holds the non-dominated
(latency, energy_pj, spec) points in ascending-latency order and
``SearchResult.best`` is the front's minimum-latency mapping.

``search_many()`` fans independent (workload, arch, kwargs) search cells
out over a ``concurrent.futures`` pool — the sweep driver used by the
benchmark harnesses.
"""
from __future__ import annotations

import math
import os
import random
import warnings
from concurrent.futures import (BrokenExecutor, ProcessPoolExecutor,
                                ThreadPoolExecutor)
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .batcheval import (OBJECTIVES, enumerate_topologies, evaluate_cached,
                        evaluate_topology_grid, grid_size, pareto_merge)
from .hardware import Arch
from .ir import MappingResult, MappingSpec, evaluate_mapping
from .workload import CompoundOp

__all__ = ["SearchResult", "search", "search_many", "parallel_map",
           "candidate_specs", "pow2_tilings", "EXHAUSTIVE_LIMIT"]

# Exhaustive enumeration cap: above this many grid points per search the
# randomized fallback kicks in.  The paper-space grids are ~1e3 points.
EXHAUSTIVE_LIMIT = 65536


@dataclass
class SearchResult:
    best: MappingResult
    evaluated: int
    valid: int
    history: List[Tuple[int, float]] = field(default_factory=list)  # (iter, best latency)
    mode: str = "randomized"    # 'exhaustive' | 'randomized'
    # objective='pareto': non-dominated (latency, energy_pj, spec) points,
    # ascending latency.  None for scalar objectives.
    front: Optional[List[Tuple[float, float, MappingSpec]]] = None

    @property
    def latency(self) -> float:
        return self.best.latency

    @property
    def energy_pj(self) -> float:
        return self.best.energy_pj


def pow2_tilings(size: int, cap: int = 4096) -> List[int]:
    """Candidate temporal tile counts for a dimension: powers of two up to
    min(size, cap), always including 1 and the full size when small."""
    out = [1]
    t = 2
    while t <= min(size, cap):
        out.append(t)
        t *= 2
    if size <= cap and size not in out:
        out.append(size)
    return out


def candidate_specs(co: CompoundOp, arch: Arch, *,
                    variants: Optional[Sequence[str]] = None,
                    allow_stats_gran: bool = False) -> Dict[str, List]:
    """The discrete choice sets for each MappingSpec field."""
    M = co.dim_sizes.get("M", 1)
    K = co.dim_sizes.get("K", 1)
    N = co.dim_sizes.get("N", 1)
    if variants is None:
        if co.name in ("attention", "flash_attention"):
            variants = ["ua", "pfa", "fa"]
        elif co.name in ("gemm_softmax", "gemm_layernorm"):
            variants = ["unfused", "fused_epilogue", "fused_std", "fused_dist"]
        else:
            variants = ["unfused", "fused_dist"]
    grans = ["tile", "stats"] if allow_stats_gran else ["tile"]
    return {
        "variant": list(variants),
        "m_tiles": pow2_tilings(M),
        "k_tiles": pow2_tilings(K, cap=64),
        "n_tiles": pow2_tilings(N, cap=256),
        # Spatial unrolling fanouts (Fig. 1 axis 2): powers of two up to
        # the physical instance counts; free grid axes of the batched
        # engine, no longer frozen to the §V-C2 full-fanout choice.
        "sp_cluster": pow2_tilings(arch.num_clusters),
        "sp_core": pow2_tilings(arch.cores_per_cluster),
        "schedule": ["sequential", "pipelined"],
        "collective_gran": grans,
        "loop_order_gb": [("M", "N"), ("N", "M")],
    }


def _sample(rng: random.Random, cands: Dict[str, List]) -> MappingSpec:
    return MappingSpec(
        variant=rng.choice(cands["variant"]),
        m_tiles=rng.choice(cands["m_tiles"]),
        k_tiles=rng.choice(cands["k_tiles"]),
        n_tiles=rng.choice(cands["n_tiles"]),
        sp_cluster=rng.choice(cands["sp_cluster"]),
        sp_core=rng.choice(cands["sp_core"]),
        schedule=rng.choice(cands["schedule"]),
        collective_gran=rng.choice(cands["collective_gran"]),
        loop_order_gb=rng.choice(cands["loop_order_gb"]),
    )


def _mutate(rng: random.Random, spec: MappingSpec, cands: Dict[str, List]) -> MappingSpec:
    fieldname = rng.choice(list(cands.keys()))
    return replace(spec, **{fieldname: rng.choice(cands[fieldname])})


def _score_of(latency: float, energy_pj: float, valid: bool,
              objective: str) -> float:
    if not valid:
        return math.inf
    if objective == "latency":
        return latency
    if objective == "energy":
        return energy_pj
    return latency * energy_pj


# ------------------------------------------------------------------ search


def search(co: CompoundOp, arch: Arch, *,
           budget: int = 2000,
           seed: int = 0,
           objective: str = "latency",
           variants: Optional[Sequence[str]] = None,
           allow_stats_gran: bool = False,
           hillclimb_frac: float = 0.5,
           mode: str = "auto",
           exhaustive_limit: int = EXHAUSTIVE_LIMIT) -> SearchResult:
    """Map-space search.  ``objective`` is 'latency', 'energy', 'edp'
    (energy-delay product) or 'pareto' (latency/energy front; see
    ``SearchResult.front``).

    ``mode``: 'exhaustive' evaluates the whole enumerable space through
    the batched engine; 'randomized' is the paper's sampling + hill-climb;
    'auto' (default) picks exhaustive whenever the space fits within
    ``exhaustive_limit`` points — which is both faster and provably
    no-worse than any sampled subset of the same space.
    """
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}")
    cands = candidate_specs(co, arch, variants=variants,
                            allow_stats_gran=allow_stats_gran)
    if mode == "auto":
        topos = enumerate_topologies(co, cands)
        total = len(topos) * grid_size(co, cands)
        mode = "exhaustive" if total <= exhaustive_limit else "randomized"
    if mode == "exhaustive":
        return _search_exhaustive(co, arch, cands, objective)
    if mode == "randomized":
        return _search_randomized(co, arch, cands, budget=budget, seed=seed,
                                  objective=objective,
                                  hillclimb_frac=hillclimb_frac)
    raise ValueError(f"unknown search mode {mode!r}")


def _search_exhaustive(co: CompoundOp, arch: Arch, cands: Dict[str, List],
                       objective: str) -> SearchResult:
    pareto = objective == "pareto"
    best_spec: Optional[MappingSpec] = None
    best_score = math.inf
    best_latency = math.inf
    evaluated = valid = 0
    history: List[Tuple[int, float]] = []
    front_pts: List[Tuple[float, float, MappingSpec]] = []
    for topo in enumerate_topologies(co, cands):
        br = evaluate_topology_grid(co, arch, topo, cands)
        evaluated += br.size
        valid += int(br.valid.sum())
        if pareto:
            # per-topology vectorized skyline; merged globally below
            front_pts.extend(
                (float(br.latency[i]), float(br.energy_pj[i]), br.spec_at(i))
                for i in br.pareto_front())
            continue
        i = br.best_index(objective)
        if i is None:
            continue
        s = float(br.scores(objective)[i])
        if s < best_score:
            best_score = s
            best_spec = br.spec_at(i)
            best_latency = float(br.latency[i])
            history.append((evaluated, best_latency))
    front: Optional[List[Tuple[float, float, MappingSpec]]] = None
    if pareto:
        front = pareto_merge(front_pts)
        if front:
            best_latency, _, best_spec = front[0]
            history.append((evaluated, best_latency))
    if best_spec is None:
        raise RuntimeError(f"no valid mapping found for {co.name} on {arch.name}")
    best = evaluate_mapping(co, arch, best_spec)
    return SearchResult(best=best, evaluated=evaluated, valid=valid,
                        history=history, mode="exhaustive", front=front)


def _search_randomized(co: CompoundOp, arch: Arch, cands: Dict[str, List], *,
                       budget: int, seed: int, objective: str,
                       hillclimb_frac: float) -> SearchResult:
    pareto = objective == "pareto"
    # Pareto mode archives every valid sample and extracts the front at
    # the end; latency steers the hill-climb.
    scalar_objective = "latency" if pareto else objective
    rng = random.Random(seed)
    best_spec: Optional[MappingSpec] = None
    best_score = math.inf
    evaluated = valid = 0
    history: List[Tuple[int, float]] = []
    archive: List[Tuple[float, float, MappingSpec]] = []
    seen = set()

    explore = max(1, int(budget * (1.0 - hillclimb_frac)))
    for i in range(budget):
        if best_spec is None or i < explore:
            spec = _sample(rng, cands)
        else:
            spec = _mutate(rng, best_spec, cands)
        if spec in seen:
            continue
        seen.add(spec)
        r = evaluate_cached(co, arch, spec)
        if r is None:
            continue
        latency, energy_pj, is_valid = r
        evaluated += 1
        if is_valid:
            valid += 1
            if pareto:
                archive.append((latency, energy_pj, spec))
        s = _score_of(latency, energy_pj, is_valid, scalar_objective)
        if s < best_score:
            best_spec, best_score = spec, s
            history.append((i, latency))

    if best_spec is None:
        raise RuntimeError(f"no valid mapping found for {co.name} on {arch.name}")
    best = evaluate_mapping(co, arch, best_spec)
    return SearchResult(best=best, evaluated=evaluated, valid=valid,
                        history=history, mode="randomized",
                        front=pareto_merge(archive) if pareto else None)


# ------------------------------------------------------------ sweep driver


def _norm_job(job) -> Tuple[CompoundOp, Arch, Dict]:
    if isinstance(job, dict):
        kw = dict(job)
        return kw.pop("co"), kw.pop("arch"), kw
    if len(job) == 2:
        co, arch = job
        return co, arch, {}
    co, arch, kw = job
    return co, arch, dict(kw)


def _run_search_job(job) -> SearchResult:
    co, arch, kw = _norm_job(job)
    return search(co, arch, **kw)


def parallel_map(fn: Callable, items: Sequence, *,
                 max_workers: Optional[int] = None,
                 executor: str = "auto") -> List:
    """Order-preserving parallel map over independent work items.

    ``executor``: 'thread' (default under 'auto' — shares the in-process
    evaluation caches and NumPy releases the GIL in the hot loops),
    'process' (bypasses the GIL; items/results must pickle), or 'serial'.
    Falls back to serial execution when a pool cannot be created (e.g.
    sandboxed environments without working multiprocessing primitives),
    and — for the items not yet completed — when the pool *breaks*
    mid-sweep (a worker killed by the OOM killer or a signal raises
    ``BrokenProcessPool`` out of ``pool.map``); a RuntimeWarning is
    emitted so the degradation is visible.  Ordinary exceptions raised by
    ``fn`` itself always propagate.
    """
    items = list(items)
    if executor == "serial" or len(items) <= 1:
        return [fn(it) for it in items]
    pool_cls = ProcessPoolExecutor if executor == "process" else ThreadPoolExecutor
    try:
        pool = pool_cls(max_workers=max_workers)
    except (OSError, PermissionError, ImportError):
        # Pool creation failed (e.g. sandbox without multiprocessing
        # primitives) — errors raised by fn itself still propagate below.
        return [fn(it) for it in items]
    results: List = []
    try:
        with pool:
            if executor == "process":
                # Amortize per-item pickling for short tasks.
                chunk = max(1, len(items)
                            // (32 * (max_workers or os.cpu_count() or 4)))
                it = pool.map(fn, items, chunksize=chunk)
            else:
                it = pool.map(fn, items)
            for r in it:
                results.append(r)
    except BrokenExecutor as e:
        # A worker died mid-sweep (e.g. OOM-killed): salvage the completed
        # prefix and finish the remaining items serially instead of losing
        # the whole sweep.
        warnings.warn(
            f"parallel_map: worker pool broke after {len(results)}/"
            f"{len(items)} items ({e!r}); finishing remaining items "
            "serially", RuntimeWarning, stacklevel=2)
        results.extend(fn(it) for it in items[len(results):])
    return results


def search_many(jobs: Sequence, *,
                max_workers: Optional[int] = None,
                executor: str = "auto") -> List[SearchResult]:
    """Parallel sweep driver: run many independent searches concurrently.

    Each job is ``(co, arch)``, ``(co, arch, kwargs)`` or a dict with
    ``co``/``arch`` keys plus search kwargs.  Results come back in job
    order.  Used by ``benchmarks/paper_tables.py`` and friends to fan out
    (workload, arch, variant) cells.
    """
    return parallel_map(_run_search_job, jobs, max_workers=max_workers,
                        executor=executor)
