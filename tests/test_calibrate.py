"""The measured-collective calibration loop (repro.calibrate).

Covers the tentpole contract end to end: sweep-harness fault matrix
(raise / NaN / non-monotone mid-sweep degrade to a partial fit, one
warning per cause, never a crash), fitter ground-truth recovery
(noise-free within 1%, bounded jitter within 10%, across pow2 and
non-pow2 participant counts and degenerate meshes), persistence
(bit-identical roundtrip, stale-provenance refusal, corrupt-file
quarantine, NaN-residual write refusal), the ``Arch``
``calibrated=`` override, the driver's reuse semantics, the
``python -m repro.calibrate`` CLI, and the ``_pearson`` edge cases of
benchmarks/costmodel_compare.
"""
import json
import math
import os
import subprocess
import sys
import warnings
from dataclasses import replace
from pathlib import Path

import pytest

import faults
from repro.calibrate import (CALIBRATED_TYPES, Calibration, MeasuredPoint,
                             SweepConfig, calibrate_once,
                             calibration_from_fit, fit_noc_params,
                             load_calibration, log_sizes, relative_errors,
                             run_sweep, save_calibration,
                             synthetic_measure_fn)
from repro.calibrate import harness as harness_mod
from repro.core.collectives import (collective_cost, collective_latency_terms,
                                    collective_seconds, noc_latency)
from repro.core.hardware import apply_calibration, tpu_v5e

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT))
from benchmarks.costmodel_compare import _pearson  # noqa: E402

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                      # pragma: no cover
    given = None


REF = replace(tpu_v5e().cluster_noc, mesh=(1, 8))
FAST = SweepConfig(n_sizes=4, iters=2, warmup=0)


@pytest.fixture(autouse=True)
def _fresh_warn_state():
    """Per-test warn-once registry (production semantics are
    per-process; tests assert per-cause counts)."""
    harness_mod._reset_warned()
    yield
    harness_mod._reset_warned()


def _rel(a, b):
    return abs(a - b) / abs(b)


def _worst_err(fit, true):
    p = fit.params
    return max(_rel(p.channel_bandwidth, true.channel_bandwidth),
               _rel(p.t_router, true.t_router), _rel(p.t_enq, true.t_enq))


def _cal_warnings(rec):
    return [w for w in rec if issubclass(w.category, RuntimeWarning)]


# --------------------------------------------------------------- harness


def test_log_sizes_ascending_dedup_multiple():
    sizes = log_sizes(1 << 12, 1 << 24, 8, multiple=4 * 8 * 8)
    assert sizes == sorted(set(sizes))
    assert all(s % (4 * 8 * 8) == 0 for s in sizes)
    assert sizes[0] >= 256 and sizes[-1] >= (1 << 24) - 4 * 8 * 8
    assert len(sizes) == 8


def test_log_sizes_edges():
    assert log_sizes(1024, 4096, 0) == []
    assert log_sizes(1024, 4096, 1, multiple=4) == [4096]
    # n larger than distinct rounded values: dedup keeps it ascending
    tight = log_sizes(64, 128, 10, multiple=64)
    assert tight == [64, 128]


def test_sweep_full_grid_no_faults():
    sweep = run_sweep(synthetic_measure_fn(REF), [2, 4, 8], config=FAST)
    assert sweep.dropped == {}
    assert len(sweep.points) == len(CALIBRATED_TYPES) * 3 * FAST.n_sizes
    assert sweep.participants == (2, 4, 8)
    assert all(p.seconds > 0 for p in sweep.points)


def test_sweep_accepts_single_participant_count():
    sweep = run_sweep(synthetic_measure_fn(REF), 8, config=FAST)
    assert sweep.participants == (8,)
    assert {p.participants for p in sweep.points} == {8}


@pytest.mark.parametrize("mode,cause", [("raise", "error"),
                                        ("nan", "not-finite"),
                                        ("tiny", "non-monotone")])
def test_sweep_fault_degrades_with_one_warning(mode, cause):
    # fail a mid-sweep call (index 5 lands past the first, smallest size
    # of the first type, so 'tiny' reads as non-monotone noise)
    mf = faults.faulty_measure_fn(synthetic_measure_fn(REF),
                                  fail_at=range(4, 8), mode=mode)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        sweep = run_sweep(mf, 8, config=FAST)
    assert sweep.dropped.get(cause, 0) >= 1
    full = len(CALIBRATED_TYPES) * FAST.n_sizes
    assert 0 < len(sweep.points) < full
    assert len(_cal_warnings(rec)) == 1          # one per cause, not per point
    # the partial sweep still fits
    fit = fit_noc_params(sweep.points, REF)
    assert not fit.degenerate
    assert _worst_err(fit, REF) < 0.01


def test_sweep_two_causes_two_warnings():
    inner = synthetic_measure_fn(REF)

    def mf(ct, dv, p):
        t = inner(ct, dv, p)
        if ct == "AllGather":
            raise RuntimeError("boom")
        if ct == "AllToAll":
            return float("inf")
        return t

    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        sweep = run_sweep(mf, 8, config=FAST)
    assert sweep.dropped["error"] == FAST.n_sizes
    assert sweep.dropped["not-finite"] == FAST.n_sizes
    assert len(_cal_warnings(rec)) == 2


def test_warn_once_reset_hook():
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        harness_mod._warn_once(("x",), "first")
        harness_mod._warn_once(("x",), "suppressed")
        harness_mod._reset_warned()
        harness_mod._warn_once(("x",), "again")
    assert len(rec) == 2


# ---------------------------------------------------------------- fitter


def test_noise_free_recovery_pow2():
    sweep = run_sweep(synthetic_measure_fn(REF), [2, 4, 8])
    fit = fit_noc_params(sweep.points, REF)
    assert _worst_err(fit, REF) < 1e-9
    assert fit.max_rel_err < 1e-9
    assert not fit.identifiable          # split came from the reference


def test_noise_free_recovery_non_pow2():
    true = replace(REF, mesh=(1, 7), t_router=3e-8, t_enq=2e-9)
    sweep = run_sweep(synthetic_measure_fn(true), [3, 5, 7], config=FAST)
    fit = fit_noc_params(sweep.points, true)
    assert _worst_err(fit, true) < 0.01


def test_jitter_recovery_within_10pct():
    sweep = run_sweep(synthetic_measure_fn(REF, jitter=0.03, seed=11),
                      [2, 4, 8])
    fit = fit_noc_params(sweep.points, REF)
    assert _worst_err(fit, REF) < 0.10
    assert fit.max_rel_err < 0.10


def test_degenerate_single_participant():
    # a (1,1) mesh's sweep only ever sees P=1 — the model predicts zero
    # and the fitter must return the reference untouched, not invent one
    pts = [MeasuredPoint("AllReduce", 4096 * i, 1, 1e-6 * i)
           for i in range(1, 6)]
    fit = fit_noc_params(pts, REF)
    assert fit.degenerate
    assert fit.params == REF


def test_degenerate_too_few_points():
    fit = fit_noc_params([], REF)
    assert fit.degenerate and fit.params == REF
    one = [MeasuredPoint("AllReduce", 65536, 8, 1e-4)]
    assert fit_noc_params(one, REF).degenerate


def test_per_type_diagnostics_and_residuals():
    sweep = run_sweep(synthetic_measure_fn(REF, jitter=0.02, seed=5),
                      [2, 4, 8])
    fit = fit_noc_params(sweep.points, REF)
    assert {t.col_type for t in fit.per_type} == set(CALIBRATED_TYPES)
    assert len(fit.residuals) == fit.n_points
    assert all(math.isfinite(r) for r in fit.residuals)
    assert fit.max_rel_err >= fit.median_rel_err >= 0.0
    res = relative_errors(fit.points, fit.params)
    assert max(abs(r) for r in res) == pytest.approx(fit.max_rel_err)


if given is not None:

    @settings(max_examples=25, deadline=None)
    @given(bw=st.floats(min_value=1e9, max_value=1e12),
           t_router=st.floats(min_value=1e-9, max_value=1e-6),
           t_enq=st.floats(min_value=1e-10, max_value=1e-7))
    def test_property_noise_free_recovery(bw, t_router, t_enq):
        true = replace(REF, channel_bandwidth=bw, t_router=t_router,
                       t_enq=t_enq)
        sweep = run_sweep(synthetic_measure_fn(true), [2, 4, 8],
                          config=FAST)
        fit = fit_noc_params(sweep.points, true)
        assert _worst_err(fit, true) < 0.01

    @settings(max_examples=15, deadline=None)
    @given(jitter=st.floats(min_value=0.0, max_value=0.03),
           seed=st.integers(min_value=0, max_value=2**16),
           participants=st.sampled_from([(2, 4, 8), (3, 6), (2, 7, 8)]))
    def test_property_jittered_recovery(jitter, seed, participants):
        sweep = run_sweep(
            synthetic_measure_fn(REF, jitter=jitter, seed=seed),
            list(participants), config=SweepConfig(n_sizes=6, iters=3,
                                                   warmup=0))
        fit = fit_noc_params(sweep.points, REF)
        assert not fit.degenerate
        assert _worst_err(fit, REF) < 0.10


# ----------------------------------------------------------- persistence


def _make_cal(jitter=0.0, **prov):
    sweep = run_sweep(synthetic_measure_fn(REF, jitter=jitter), [2, 4, 8],
                      config=FAST)
    fit = fit_noc_params(sweep.points, REF)
    kw = dict(backend="synthetic", jax_version="testver", now=lambda: 123.0)
    kw.update(prov)
    return calibration_from_fit(fit, **kw)


def test_roundtrip_bit_identical(tmp_path):
    cal = _make_cal()
    p1 = save_calibration(cal, tmp_path / "a.json")
    loaded = load_calibration(p1)
    assert loaded is not None
    p2 = save_calibration(loaded, tmp_path / "b.json")
    assert p1.read_bytes() == p2.read_bytes()
    assert loaded.params == cal.params
    assert loaded.points == cal.points
    assert loaded.provenance == cal.provenance


def test_stale_provenance_refused(tmp_path):
    cal = _make_cal()
    path = save_calibration(cal, tmp_path / "c.json")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        got = load_calibration(path, expect={"backend": "synthetic",
                                             "mesh": (1, 8),
                                             "jax_version": "OTHER"})
    assert got is None
    msgs = [str(w.message) for w in _cal_warnings(rec)]
    assert len(msgs) == 1 and "stale" in msgs[0]
    assert "repro.calibrate" in msgs[0]    # actionable: names the fix
    # matching expectations load fine
    assert load_calibration(path, expect={"backend": "synthetic",
                                          "mesh": (1, 8),
                                          "jax_version": "testver"})


def test_stale_mesh_refused(tmp_path):
    path = save_calibration(_make_cal(), tmp_path / "d.json")
    assert load_calibration(path, expect={"mesh": (4, 4)}) is None


def test_corrupt_file_quarantined(tmp_path):
    path = save_calibration(_make_cal(), tmp_path / "e.json")
    faults.torn_file(path, keep=0.4)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        got = load_calibration(path)
    assert got is None
    assert not path.exists()                      # moved, not left rotting
    assert (tmp_path / "corrupt" / "e.json").exists()
    msgs = [str(w.message) for w in _cal_warnings(rec)]
    assert len(msgs) == 1 and "quarantined" in msgs[0]


def test_nan_residuals_never_persisted(tmp_path):
    cal = _make_cal()
    bad = Calibration(params=cal.params, provenance=cal.provenance,
                      per_type=cal.per_type, points=cal.points,
                      residuals=cal.residuals + (float("nan"),),
                      max_rel_err=cal.max_rel_err,
                      median_rel_err=cal.median_rel_err)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = save_calibration(bad, tmp_path / "f.json")
    assert out is None
    assert not (tmp_path / "f.json").exists()
    assert list(tmp_path.iterdir()) == []         # not even a tmp file
    assert len(_cal_warnings(rec)) == 1


def test_missing_file_is_silent(tmp_path):
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert load_calibration(tmp_path / "nope.json") is None
    assert _cal_warnings(rec) == []


# ------------------------------------------------- arch override / model


def test_apply_calibration_and_preset_kwarg(tmp_path):
    path = save_calibration(_make_cal(), tmp_path / "g.json")
    base = tpu_v5e()
    cal = load_calibration(path)
    patched = apply_calibration(base, cal)
    assert patched.cluster_noc.channel_bandwidth == \
        cal.params.channel_bandwidth
    assert patched.cluster_noc.mesh == base.cluster_noc.mesh  # geometry kept
    assert patched.core_noc == base.core_noc
    # calibrated machines must fingerprint differently everywhere
    assert patched.signature() != base.signature()
    # path / Calibration / NoCParams all accepted; presets thread it
    assert tpu_v5e(calibrated=str(path)).cluster_noc == patched.cluster_noc
    assert tpu_v5e(calibrated=cal.params).cluster_noc == patched.cluster_noc
    with_core = apply_calibration(base, cal, core_noc=True)
    assert with_core.core_noc.channel_bandwidth == \
        cal.params.channel_bandwidth


def test_apply_calibration_none_is_identity():
    base = tpu_v5e()
    assert apply_calibration(base, None) is base
    assert tpu_v5e(calibrated=None).signature() == base.signature()


def test_collective_latency_terms_matches_model():
    cc, mem_lat, lat = collective_latency_terms("AllReduce", 1 << 20, 8, REF)
    assert cc.volume_bytes == collective_cost("AllReduce", 1 << 20, 8,
                                              REF).volume_bytes
    assert mem_lat == pytest.approx(cc.volume_bytes / REF.channel_bandwidth)
    assert lat == pytest.approx(mem_lat + noc_latency(cc, REF))
    assert collective_seconds("AllReduce", 1 << 20, 8, REF) == lat


# ----------------------------------------------------- _pearson edge case


def test_pearson_degenerate_series_return_zero():
    assert _pearson([], []) == 0.0
    assert _pearson([1.0], [1.0]) == 0.0
    assert _pearson([2.0, 2.0, 2.0], [1.0, 2.0, 3.0]) == 0.0
    assert _pearson([1.0, 2.0, 3.0], [5.0, 5.0, 5.0]) == 0.0


def test_pearson_correlated_series():
    assert _pearson([1.0, 2.0, 3.0], [2.0, 4.0, 6.0]) == pytest.approx(1.0)
    assert _pearson([1.0, 2.0, 3.0], [3.0, 2.0, 1.0]) == pytest.approx(-1.0)


# ------------------------------------------------------------ driver/CLI


def test_calibrate_once_reuse_semantics(tmp_path):
    kw = dict(backend="synthetic", jax_version="testver",
              store=str(tmp_path), config=FAST, now=lambda: 99.0)
    s1 = calibrate_once(synthetic_measure_fn(REF), REF, [2, 4, 8], **kw)
    assert s1["fits_solved"] == 1 and not s1["reused"]
    assert s1["persisted"] and s1["gate_ok"]
    store_file = tmp_path / "calibrated_noc.json"
    bytes1 = store_file.read_bytes()
    s2 = calibrate_once(synthetic_measure_fn(REF), REF, [2, 4, 8], **kw)
    assert s2["reused"] and s2["fits_solved"] == 0
    assert store_file.read_bytes() == bytes1      # untouched, bit-identical
    assert [p.name for p in tmp_path.iterdir()] == ["calibrated_noc.json"]
    # force re-solves
    s3 = calibrate_once(synthetic_measure_fn(REF), REF, [2, 4, 8],
                        force=True, **kw)
    assert s3["fits_solved"] == 1


def test_calibrate_once_degenerate_persists_nothing(tmp_path):
    mf = faults.faulty_measure_fn(synthetic_measure_fn(REF),
                                  fail_at=range(10_000))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        s = calibrate_once(mf, REF, 8, backend="synthetic",
                           jax_version="testver", store=str(tmp_path),
                           config=FAST)
    assert s["degenerate"] and not s["persisted"] and not s["gate_ok"]
    assert not (tmp_path / "calibrated_noc.json").exists()
    assert any("degenerate" in str(w.message) for w in _cal_warnings(rec))


def test_cli_end_to_end(tmp_path):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (str(REPO_ROOT / "src"), env.get("PYTHONPATH")) if p)
    cmd = [sys.executable, "-m", "repro.calibrate", "--backend=synthetic",
           "--store", str(tmp_path), "--sizes=4", "--json"]
    r1 = subprocess.run(cmd, env=env, capture_output=True, text=True,
                        timeout=300)
    assert r1.returncode == 0, r1.stderr
    s1 = json.loads(r1.stdout)
    assert s1["fits_solved"] == 1 and s1["gate_ok"]
    store_file = tmp_path / "calibrated_noc.json"
    bytes1 = store_file.read_bytes()
    r2 = subprocess.run(cmd, env=env, capture_output=True, text=True,
                        timeout=300)
    s2 = json.loads(r2.stdout)
    assert s2["reused"] and s2["fits_solved"] == 0
    assert store_file.read_bytes() == bytes1
