"""mamba2-130m [ssm]: attention-free SSD (state-space duality); mixer-only
blocks (d_ff=0), tied embeddings.  The COMET attention-collective technique
is inapplicable (DESIGN.md §Arch-applicability); the SSD chunk dataflow is
modeled instead.  [arXiv:2405.21060]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m", family="ssm",
        n_layers=24, d_model=768, n_heads=1, n_kv_heads=1,
        attn_type="none", d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
        conv_kernel=4, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, vocab_size=512, ssm_state=16,
        ssm_headdim=16, name="mamba2-smoke")
