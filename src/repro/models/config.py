"""Model configuration covering all assigned architecture families."""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

__all__ = ["ModelConfig", "pad_to_multiple"]


def pad_to_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"            # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0                # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1000

    # --- attention ---
    attn_type: str = "gqa"           # gqa | mla | none (ssm) | parallel (hybrid)
    rope_theta: float = 10000.0
    qk_norm: bool = False
    window: Optional[int] = None     # sliding-window width (None = global)
    # MLA (DeepSeek)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- FFN / MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0      # DeepSeek: leading dense layers
    router_type: str = "softmax"     # softmax | sigmoid (DeepSeek noaux bias)
    capacity_factor: float = 1.25

    # --- SSM (Mamba-2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    conv_kernel: int = 4

    # --- enc-dec ---
    n_enc_layers: int = 0
    enc_ratio: int = 8               # encoder frames = seq // enc_ratio (stub frontend)

    # --- misc ---
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    vocab_pad: int = 128             # pad vocab to this multiple (sharding)

    # --- runtime knobs (not architecture) ---
    use_kernels: bool = False        # route hot ops through Pallas kernels
    remat: bool = True
    remat_policy: str = "full"       # full (nothing saveable) | dots | none
    softmax_strategy: str = "auto"   # dist | gather | auto (COMET-planned)
    seq_shard: bool = False          # sequence-parallel residual stream (hillclimb)
    tensor_parallel: bool = True     # False: replicate params (small models)
    banded_attention: bool = True    # O(S*2W) sliding-window path
    fsdp: bool = False               # ZeRO-3: shard params over data too
                                     # (required to fit 671B+Adam on a pod)
    scan_unroll: int = 1             # layer-scan unroll (9999 = full; used by
                                     # measurement dry-runs: XLA cost_analysis
                                     # does not scale while-loop bodies)

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return pad_to_multiple(self.vocab_size, self.vocab_pad)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.ssm_ngroups * self.ssm_state

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def has_attention(self) -> bool:
        return self.attn_type != "none"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    def n_params(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, L = self.d_model, self.n_layers
        hd = self.hd
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        if self.attn_type == "mla":
            att = (self.q_lora_rank * d + self.q_lora_rank * self.n_heads
                   * (128 + self.rope_head_dim)
                   + d * (self.kv_lora_rank + self.rope_head_dim)
                   + self.kv_lora_rank * self.n_heads * (128 + self.v_head_dim)
                   + self.n_heads * self.v_head_dim * d)
        elif self.attn_type == "none":
            att = 0
        else:
            att = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        ffn_dense = 3 * d * self.d_ff
        if self.is_moe:
            ffn = (self.n_experts + self.n_shared_experts) * 3 * d * self.moe_d_ff \
                + d * self.n_experts
            dense_part = self.first_dense_layers * ffn_dense
            moe_part = (L - self.first_dense_layers) * ffn
            ffn_total = dense_part + moe_part
        else:
            ffn_total = L * ffn_dense
        ssm = 0
        if self.has_ssm:
            di, cd = self.d_inner, self.conv_dim
            ssm = (d * (2 * di + 2 * self.ssm_ngroups * self.ssm_state
                        + self.ssm_nheads)
                   + cd * self.conv_kernel + di * d + 3 * self.ssm_nheads)
            ssm *= L
        att_total = L * att
        if self.is_encdec:
            att_total += self.n_enc_layers * att * 2  # enc self + dec cross
            ffn_total += self.n_enc_layers * ffn_dense
        return int(emb + att_total + ffn_total + ssm)

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only routed top-k + shared)."""
        if not self.is_moe:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        full = self.n_params()
        all_experts = (L - self.first_dense_layers) * self.n_experts * 3 * d * self.moe_d_ff
        active = (L - self.first_dense_layers) * (self.top_k + self.n_shared_experts) \
            * 3 * d * self.moe_d_ff
        return int(full - all_experts + active)
