"""Compute-collective overlap: cost-model semantics, grid/search axis,
and the collectives-level hideable/exposed decomposition.

The load-bearing invariant is **serial bit-identity**: ``overlap=0`` —
scalar or an array of zeros — must reproduce the pre-overlap engine's
numbers *bitwise* on every paper (workload, arch) pair, so turning the
axis on can never silently perturb published results.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core.batcheval import (Topology, enumerate_topologies,
                                  evaluate_specs_batch,
                                  evaluate_topology_grid)
from repro.core.collectives import (collective_latency_terms,
                                    collective_overlap_terms,
                                    collective_seconds,
                                    overlapped_collective_seconds)
from repro.core.hardware import cloud, edge, tpu_v5e
from repro.core.ir import MappingSpec, evaluate_mapping
from repro.core.search import OVERLAP_CANDIDATES, candidate_specs, search
from repro.core.workload import gemm_softmax

from benchmarks.search_throughput import _paper_pairs

PAIRS = _paper_pairs()
PAIR_IDS = [f"{n}_{a.name}_{i}" for i, (n, _co, a) in enumerate(PAIRS)]

TILES = [1, 2, 4]
SCHEDS = ["sequential", "pipelined"]


def _spec_lists():
    """A small dense spec set crossing tilings with both schedules."""
    ms, ks, ns, sc = [], [], [], []
    for m in TILES:
        for k in [1, 2]:
            for n in TILES:
                for s in SCHEDS:
                    ms.append(m)
                    ks.append(k)
                    ns.append(n)
                    sc.append(s)
    ones = [1] * len(ms)
    return ms, ks, ns, ones, ones, sc


@pytest.mark.parametrize("name,co,arch", PAIRS, ids=PAIR_IDS)
def test_overlap_zero_bitwise_identical(name, co, arch):
    """overlap as an array of zeros returns bitwise the same latency,
    energy, validity and headroom as the pre-overlap scalar path, on
    every topology of every paper pair."""
    cands = candidate_specs(co, arch)
    ms, ks, ns, spc, spo, sc = _spec_lists()
    for topo in enumerate_topologies(co, cands):
        base = evaluate_specs_batch(co, arch, topo, ms, ks, ns, spc, spo,
                                    sc, None)
        zeros = evaluate_specs_batch(co, arch, topo, ms, ks, ns, spc, spo,
                                     sc, [0.0] * len(ms))
        assert np.array_equal(base.latency, zeros.latency)
        assert np.array_equal(base.energy_pj, zeros.energy_pj)
        assert np.array_equal(base.valid, zeros.valid)
        assert np.array_equal(base.headroom, zeros.headroom)
        assert np.all(zeros.overlap == 0.0)


def test_overlap_grid_axis_and_plan_roundtrip():
    """The overlap axis multiplies the grid; a searched plan carries the
    winning overlap through spec_at and the search result."""
    co = gemm_softmax(512, 1024, 128)
    arch = cloud()
    cands = dict(candidate_specs(co, arch), overlap=list(OVERLAP_CANDIDATES))
    topo = next(iter(enumerate_topologies(co, cands)))
    br = evaluate_topology_grid(co, arch, topo, cands)
    br0 = evaluate_topology_grid(co, arch, topo, candidate_specs(co, arch))
    assert br.size == br0.size * len(OVERLAP_CANDIDATES)
    assert set(np.unique(br.overlap)) == set(OVERLAP_CANDIDATES)
    i = int(np.argmin(np.where(br.valid, br.latency, np.inf)))
    spec = br.spec_at(i)
    assert spec.overlap in OVERLAP_CANDIDATES


@pytest.mark.parametrize("arch", [edge(), cloud()],
                         ids=["edge", "cloud"])
def test_overlap_search_no_worse_than_serial(arch):
    """Searching the overlap axis can only improve the best latency, and
    the serial sub-grid result is recovered bitwise at overlap=[0.0]."""
    co = gemm_softmax(512, 4096, 128)
    serial = search(co, arch, mode="exhaustive")
    serial_explicit = search(co, arch, mode="exhaustive", overlap=[0.0])
    assert serial_explicit.latency == serial.latency  # bitwise
    full = search(co, arch, mode="exhaustive",
                  overlap=list(OVERLAP_CANDIDATES))
    assert full.latency <= serial.latency
    assert full.best.spec.overlap in OVERLAP_CANDIDATES


def _hbm_rich_cloud():
    """The cloud preset with the DRAM stream taken off the critical path.

    On the stock cloud balance every winning GEMM-Softmax mapping is
    DRAM-floor-bound, and Eq. 2 *already* hides the whole window —
    collectives included — under the memory stream (``os_stall`` absorbs
    any window shrinkage one-for-one).  Scaling the DRAM bandwidth ×64
    models an HBM-rich node where the on-chip window binds, which is the
    regime the overlap axis exists for."""
    base = cloud()
    return dataclasses.replace(
        base, name="cloud_hbm",
        dram=dataclasses.replace(base.dram, bandwidth=base.dram.bandwidth
                                 * 64))


def test_overlap_strictly_improves_distributed_mapping():
    """The acceptance showcase in miniature (GEMM-Softmax distSM, cloud).

    Stock cloud: the mapping is DRAM-floor-bound, so hiding the
    collective shrinks the *collective breakdown* strictly while total
    latency may only improve or stay put (Eq. 2's ``os_stall`` reabsorbs
    the freed window time).  HBM-rich cloud (window-bound): the same
    mapping gets strictly cheaper end to end, on both schedules."""
    co = gemm_softmax(512, 4096, 128)
    spec0 = MappingSpec(variant="fused_dist", m_tiles=8, k_tiles=2)
    spec1 = MappingSpec(variant="fused_dist", m_tiles=8, k_tiles=2,
                        overlap=1.0)

    arch = cloud()
    r0 = evaluate_mapping(co, arch, spec0)
    r1 = evaluate_mapping(co, arch, spec1)
    assert r1.latency <= r0.latency
    assert r1.cost.lat_breakdown["collective"] < \
        r0.cost.lat_breakdown["collective"]

    fat = _hbm_rich_cloud()
    for sched in SCHEDS:
        f0 = evaluate_mapping(co, fat, dataclasses.replace(
            spec0, schedule=sched))
        f1 = evaluate_mapping(co, fat, dataclasses.replace(
            spec1, schedule=sched))
        assert f1.latency < f0.latency * (1 - 1e-6)


def test_overlap_search_strictly_improves_sequential_issue():
    """Search-level strict improvement (GEMM-Softmax, cloud).

    With the pipelined schedule in the axis, the exhaustive winner
    already hides its collectives through Eq. 6 (conflict <= 0 at the
    winning specs), so the searched best is overlap-invariant — an
    honest model finding the explicit representation makes visible.
    Restricted to sequential issue (a runtime that cannot software-
    pipeline windows), searching the overlap axis strictly improves the
    best distSM latency on the window-bound cloud."""
    co = gemm_softmax(512, 4096, 128)
    fat = _hbm_rich_cloud()
    serial_cl = [MappingSpec(variant="fused_dist", m_tiles=m, k_tiles=k,
                             schedule="sequential")
                 for m in (1, 2, 4, 8, 16) for k in (1, 2, 4)]
    ov_cl = serial_cl + [dataclasses.replace(s, overlap=1.0)
                         for s in serial_cl]
    s = search(co, fat, candidate_list=serial_cl)
    f = search(co, fat, candidate_list=ov_cl)
    assert f.latency < s.latency * (1 - 1e-6)
    assert f.best.spec.overlap == 1.0
    # the full axis (pipelined included) can only match or improve
    full_serial = search(co, fat, mode="exhaustive",
                         variants=["fused_dist"])
    full_ov = search(co, fat, mode="exhaustive", variants=["fused_dist"],
                     overlap=list(OVERLAP_CANDIDATES))
    assert full_ov.latency <= full_serial.latency


@pytest.mark.parametrize("variant", ["fused_dist", "fused_std", "unfused"])
@pytest.mark.parametrize("sched", SCHEDS)
def test_overlap_monotone_nonincreasing(variant, sched):
    """Latency is monotone non-increasing along overlap in [0, 1], on
    both schedule branches, and the collective breakdown never goes
    negative (the exposed Eq. 3 term is not hideable)."""
    co = gemm_softmax(512, 4096, 128)
    arch = cloud()
    prev = math.inf
    for ov in (0.0, 0.25, 0.5, 0.75, 1.0):
        r = evaluate_mapping(co, arch, MappingSpec(
            variant=variant, m_tiles=8, k_tiles=2, schedule=sched,
            overlap=ov))
        assert r.latency <= prev * (1 + 1e-12)
        assert r.cost.lat_breakdown["collective"] >= -1e-12
        prev = r.latency


def test_batch_overlap_matches_scalar_walk():
    """Nonzero overlap on the vectorized path matches the per-spec tree
    walk to 1e-9 (same formulas, array- vs scalar-typed)."""
    co = gemm_softmax(512, 1024, 128)
    arch = cloud()
    cands = candidate_specs(co, arch)
    ms, ks, ns, spc, spo, sc = _spec_lists()
    ovs = [(0.5 if i % 2 else 1.0) for i in range(len(ms))]
    for topo in enumerate_topologies(co, cands):
        br = evaluate_specs_batch(co, arch, topo, ms, ks, ns, spc, spo,
                                  sc, ovs)
        for i in range(0, br.size, 7):
            spec = br.spec_at(i)
            assert spec.overlap == ovs[i]
            try:
                r = evaluate_mapping(co, arch, spec)
            except (ValueError, KeyError):
                assert not br.valid[i]
                continue
            assert br.latency[i] == pytest.approx(r.latency, rel=1e-9)
            assert br.energy_pj[i] == pytest.approx(r.energy_pj, rel=1e-9)


def test_overlap_validation():
    with pytest.raises(ValueError, match="overlap"):
        evaluate_specs_batch(gemm_softmax(64, 64, 64), edge(),
                             next(iter(enumerate_topologies(
                                 gemm_softmax(64, 64, 64),
                                 candidate_specs(gemm_softmax(64, 64, 64),
                                                 edge())))),
                             [1], [1], [1], [1], [1], ["sequential"], [1.5])
    with pytest.raises(ValueError):
        candidate_specs(gemm_softmax(64, 64, 64), edge(), overlap=[-0.1])


# ----------------------------------------- collectives-level decomposition

NOCS = [("edge", edge().cluster_noc), ("cloud", cloud().cluster_noc),
        ("tpu_v5e", tpu_v5e().cluster_noc)]
COLS = ["AllReduce", "AllGather", "ReduceScatter", "AllToAll"]


@pytest.mark.parametrize("nname,noc", NOCS, ids=[n for n, _ in NOCS])
@pytest.mark.parametrize("col", COLS)
def test_overlap_terms_partition_total(nname, noc, col):
    """hideable + exposed == the Eq. 4 total, exactly; hideable is the
    Eq. 1 mem_lat term."""
    dv, p = 1 << 20, noc.num_nodes
    if p <= 1:
        pytest.skip("single-node cluster")
    hideable, exposed = collective_overlap_terms(col, dv, p, noc)
    cc, mem_lat, total = collective_latency_terms(col, dv, p, noc)
    assert hideable == mem_lat
    assert hideable + exposed == total
    assert exposed >= 0.0


@pytest.mark.parametrize("nname,noc", NOCS, ids=[n for n, _ in NOCS])
@pytest.mark.parametrize("col", COLS)
def test_overlapped_seconds_floor_and_monotone(nname, noc, col):
    """The overlapped cost never drops below the exposed enqueue/router
    term (even at overlap=1 with unlimited compute), is monotone
    non-increasing in overlap, and reproduces Eq. 4 at overlap=0."""
    dv, p = 1 << 22, noc.num_nodes
    if p <= 1:
        pytest.skip("single-node cluster")
    hideable, exposed = collective_overlap_terms(col, dv, p, noc)
    serial = collective_seconds(col, dv, p, noc)
    assert overlapped_collective_seconds(col, dv, p, noc) == serial
    prev = math.inf
    for ov in (0.0, 0.3, 0.7, 1.0):
        t = overlapped_collective_seconds(col, dv, p, noc, overlap=ov,
                                          compute_seconds=math.inf)
        assert exposed - 1e-18 <= t <= prev
        prev = t
    floor = overlapped_collective_seconds(col, dv, p, noc, overlap=1.0,
                                          compute_seconds=math.inf)
    assert floor == pytest.approx(exposed, rel=1e-12)
    # a small compute window bounds what can hide
    small = hideable * 0.25
    t = overlapped_collective_seconds(col, dv, p, noc, overlap=1.0,
                                      compute_seconds=small)
    assert t == pytest.approx(serial - small, rel=1e-12)
