"""Structural FLOP *and collective* counting from the jaxpr (scan-aware).

XLA's ``cost_analysis()`` does not multiply while-loop bodies by their trip
counts, so scanned-layer models under-report FLOPs by ~n_layers (observed
useful_flop_ratio >> 1, see EXPERIMENTS §Roofline).  The jaxpr still knows
every ``scan`` length statically, so we count matmul FLOPs exactly by
walking it recursively with a trip-count multiplier — and, for the static
contract checker (:mod:`repro.analysis.contracts`), we count the explicit
collective primitives (``psum``/``pmax``/``pmin``/``all_gather``/
``psum_scatter``/``all_to_all``/``ppermute``) the same scan-aware way,
recording per-(type, participants) occurrence counts and data volumes.

Counted FLOPs: ``dot_general`` (2·M·N·K·batch) and ``conv_general_dilated``
(2 · output points · kernel spatial · in-channels-per-group).  Elementwise/
reduce FLOPs are a few percent of LM totals and are not counted
(documented).  Returned FLOPs are GLOBAL (whole-program,
pre-partitioning): divide by the device count for per-device numbers.

Trip-count multipliers
----------------------
``scan``       body × ``length`` — nested scans multiply (outer × inner),
               pinned by a regression test.
``while``      body × the static trip count when the loop is the bounded
               counter pattern (``i < literal`` cond, literal step/init);
               otherwise body × 1 with an explicit ``while-unbounded``
               finding in :attr:`TraceCounts.findings` (never silence).
``shard_map``  FLOPs × mesh device count (body runs on every device over
               1/N of the data; global FLOPs = body × N).  Collectives are
               **not** multiplied: N devices execute one *logical*
               collective (SPMD), and its cost is already a function of the
               participant count.
``pallas_call``  body × grid product — one kernel-body trace per grid cell.
``cond``       the maximum-FLOP branch; collectives take the per-type
               maximum across branches (conservative upper bound).
``pjit``/``remat``/``custom_vjp`` and other call-like primitives recurse
with unchanged multipliers.

Collective volume conventions match ``core/collectives.py``: ``dv_bytes``
is the *logical* tensor size the collective operates on (full tensor for
All-Reduce, gathered result for All-Gather, full input for
Reduce-Scatter), so ``collective_cost(type, dv_bytes, participants, noc)``
charges the traced op exactly as the cost model charges the planned one.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

import jax
import numpy as np

__all__ = ["count_flops", "structural_flops", "CollectiveRecord",
           "TraceCounts", "trace_counts", "count_jaxpr"]


# COMET collective type each jax collective primitive realizes.  pmax/pmin
# are max/min-AllReduces: same exchange schedule and wire volume as psum.
_PRIM_TO_TYPE = {
    "psum": "AllReduce",
    "pmax": "AllReduce",
    "pmin": "AllReduce",
    "all_gather": "AllGather",
    # jax.lax.psum_scatter binds a primitive named ``reduce_scatter``; the
    # ``psum_scatter`` alias is kept for jax versions that use the API name.
    # (Before PR 8 only the alias was listed, so every traced Reduce-Scatter
    # — e.g. the transpose of the gather-arm softmax All-Gather — was
    # silently dropped from the contract audit.)
    "reduce_scatter": "ReduceScatter",
    "psum_scatter": "ReduceScatter",
    "all_to_all": "AllToAll",
    "ppermute": "Permute",
    "pshuffle": "Permute",
}


@dataclass
class CollectiveRecord:
    """Aggregated trace of one (collective type, participant count) pair."""

    col_type: str          # COMET type: AllReduce/AllGather/ReduceScatter/...
    participants: int
    count: float = 0.0     # occurrences × trip-count multipliers
    dv_bytes: float = 0.0  # Σ logical data volume (cost-model DV convention)
    shard_bytes: float = 0.0  # Σ per-shard operand bytes (as traced)

    def merge(self, other: "CollectiveRecord") -> None:
        self.count += other.count
        self.dv_bytes += other.dv_bytes
        self.shard_bytes += other.shard_bytes

    def to_dict(self) -> Dict:
        return {"type": self.col_type, "participants": self.participants,
                "count": self.count, "dv_bytes": self.dv_bytes,
                "shard_bytes": self.shard_bytes}


@dataclass
class TraceCounts:
    """FLOPs + collectives counted from one jaxpr walk."""

    flops: float = 0.0
    collectives: Dict[Tuple[str, int], CollectiveRecord] = field(
        default_factory=dict)
    # Non-fatal analysis findings, e.g. a ``while`` whose trip count could
    # not be statically determined (body counted once — a lower bound).
    # Each finding is {"kind": ..., "detail": ...}.
    findings: list = field(default_factory=list)

    def add_finding(self, kind: str, detail: str) -> None:
        self.findings.append({"kind": kind, "detail": detail})

    def add_collective(self, col_type: str, participants: int, count: float,
                       dv_bytes: float, shard_bytes: float) -> None:
        key = (col_type, int(participants))
        rec = self.collectives.get(key)
        if rec is None:
            rec = self.collectives[key] = CollectiveRecord(
                col_type, int(participants))
        rec.count += count
        rec.dv_bytes += dv_bytes
        rec.shard_bytes += shard_bytes

    def merge(self, other: "TraceCounts") -> None:
        self.flops += other.flops
        self.findings.extend(other.findings)
        for key, rec in other.collectives.items():
            mine = self.collectives.get(key)
            if mine is None:
                self.collectives[key] = CollectiveRecord(
                    rec.col_type, rec.participants, rec.count,
                    rec.dv_bytes, rec.shard_bytes)
            else:
                mine.merge(rec)

    def merge_max(self, other: "TraceCounts") -> None:
        """Per-type conservative merge for ``cond`` branches: keep the
        heavier branch's record for each (type, participants) key."""
        self.flops = max(self.flops, other.flops)
        self.findings.extend(other.findings)
        for key, rec in other.collectives.items():
            mine = self.collectives.get(key)
            if mine is None or rec.dv_bytes > mine.dv_bytes:
                self.collectives[key] = CollectiveRecord(
                    rec.col_type, rec.participants, rec.count,
                    rec.dv_bytes, rec.shard_bytes)

    def total_collective_dv(self) -> float:
        return sum(r.dv_bytes for r in self.collectives.values())

    def by_type(self) -> Dict[str, CollectiveRecord]:
        """Per-type totals (participants field holds the max seen)."""
        out: Dict[str, CollectiveRecord] = {}
        for rec in self.collectives.values():
            t = out.get(rec.col_type)
            if t is None:
                out[rec.col_type] = CollectiveRecord(
                    rec.col_type, rec.participants, rec.count,
                    rec.dv_bytes, rec.shard_bytes)
            else:
                t.participants = max(t.participants, rec.participants)
                t.merge(rec)
        return out

    def to_dict(self) -> Dict:
        return {"flops": self.flops,
                "collectives": [r.to_dict() for _, r in
                                sorted(self.collectives.items())],
                "findings": list(self.findings)}


def _dot_flops(eqn) -> float:
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    batch = 1
    for d in lb:
        batch *= a.shape[d]
    contract = 1
    for d in lc:
        contract *= a.shape[d]
    m = 1
    for i, s in enumerate(a.shape):
        if i not in lc and i not in lb:
            m *= s
    n = 1
    for i, s in enumerate(b.shape):
        if i not in rc and i not in rb:
            n *= s
    return 2.0 * batch * m * n * contract


def _conv_flops(eqn) -> float:
    """conv_general_dilated as a dot per output point: every output element
    is a (kernel-spatial × in-channels-per-group) MAC reduction."""
    rhs = eqn.invars[1].aval
    out = eqn.outvars[0].aval
    dn = eqn.params["dimension_numbers"]
    rhs_spec = dn.rhs_spec  # (out_feature_dim, in_feature_dim, *spatial)
    in_ch_per_group = rhs.shape[rhs_spec[1]]
    k_spatial = 1
    for d in rhs_spec[2:]:
        k_spatial *= rhs.shape[d]
    out_pts = 1
    for s in out.shape:
        out_pts *= s
    return 2.0 * out_pts * k_spatial * in_ch_per_group


def _aval_bytes(aval) -> float:
    n = 1
    for s in aval.shape:
        n *= s
    return float(n) * np.dtype(aval.dtype).itemsize


def _axis_tuple(v) -> Tuple:
    return v if isinstance(v, (tuple, list)) else (v,)


def _participants(params, axis_env: Dict[str, int],
                  axis_keys=("axes", "axis_name")) -> int:
    """Participant count of a collective eqn: the replica-group length if
    ``axis_index_groups`` is set, else the product of the mapped axis sizes
    (``axis_size`` param when present — all_gather/psum_scatter carry it)."""
    groups = params.get("axis_index_groups")
    if groups:
        return len(groups[0])
    if "axis_size" in params:
        return int(params["axis_size"])
    p = 1
    for key in axis_keys:
        if key in params:
            for ax in _axis_tuple(params[key]):
                p *= int(axis_env.get(ax, 1))
            break
    return p


def _record_collective(eqn, prim: str, mult: float, axis_env: Dict[str, int],
                       out: TraceCounts) -> None:
    col_type = _PRIM_TO_TYPE[prim]
    P = _participants(eqn.params, axis_env)
    shard = sum(_aval_bytes(v.aval) for v in eqn.invars)
    if col_type == "AllGather":
        # DV convention: the gathered result (per-shard operand × P).
        dv = shard * P
    else:
        # AllReduce: per-shard partials span the full logical tensor.
        # ReduceScatter/AllToAll/Permute: DV is the full input.
        dv = shard
    out.add_collective(col_type, P, mult, dv * mult, shard * mult)


def _grid_product(params) -> float:
    gm = params.get("grid_mapping")
    grid = getattr(gm, "grid", None) if gm is not None else params.get("grid")
    if not grid:
        return 1.0
    n = 1.0
    for g in grid:
        try:
            n *= float(g)
        except TypeError:  # symbolic/dynamic grid dim: count once
            pass
    return n


def _literal_value(var):
    """Concrete python value of a jaxpr Literal, else None."""
    val = getattr(var, "val", None)
    if val is None:
        return None
    try:
        return float(np.asarray(val).reshape(()))
    except Exception:
        return None


def _while_trip_count(eqn):
    """Static trip count of a ``while`` eqn, or None if unbounded.

    Recognizes the counter pattern ``lax.while_loop`` lowers bounded loops
    to (and that ``fori_loop`` with traced-but-constant bounds produces):
    the cond jaxpr is a single ``i < bound`` comparison of a carry slot
    against a literal, and the body advances that slot by a literal step.
    The initial counter value must be a literal at the call site.  Anything
    else — data-dependent predicates, non-literal bounds — returns None and
    the caller emits a ``while-unbounded`` finding.
    """
    try:
        params = eqn.params
        cond = params["cond_jaxpr"].jaxpr
        body = params["body_jaxpr"].jaxpr
        cn = int(params.get("cond_nconsts", 0))
        bn = int(params.get("body_nconsts", 0))
        pred = cond.outvars[0]
        pred_eqn = None
        for e in cond.eqns:
            if pred in e.outvars:
                pred_eqn = e
        if pred_eqn is None or pred_eqn.primitive.name != "lt":
            return None
        ivar, bvar = pred_eqn.invars
        bound = _literal_value(bvar)
        carry = list(cond.invars[cn:])
        if bound is None or ivar not in carry:
            return None
        idx = carry.index(ivar)
        # the body must advance carry slot idx by a literal step
        out_i = body.outvars[idx]
        step_eqn = None
        for e in body.eqns:
            if out_i in e.outvars:
                step_eqn = e
        if step_eqn is None or step_eqn.primitive.name != "add":
            return None
        body_carry = list(body.invars[bn:])
        step = None
        for a, b in (step_eqn.invars, reversed(step_eqn.invars)):
            if a is body_carry[idx]:
                step = _literal_value(b)
                break
        if not step or step <= 0:
            return None
        init = _literal_value(eqn.invars[cn + bn + idx])
        if init is None:
            return None
        import math
        return max(0, int(math.ceil((bound - init) / step)))
    except Exception:
        return None


def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    try:
        return {str(k): int(v) for k, v in dict(mesh.shape).items()}
    except Exception:
        return {}


def _walk(jaxpr, flops_mult: float, coll_mult: float,
          axis_env: Dict[str, int], out: TraceCounts) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            out.flops += flops_mult * _dot_flops(eqn)
        elif prim == "conv_general_dilated":
            out.flops += flops_mult * _conv_flops(eqn)
        elif prim in _PRIM_TO_TYPE:
            _record_collective(eqn, prim, coll_mult, axis_env, out)
        elif prim == "scan":
            inner = eqn.params["jaxpr"].jaxpr
            length = eqn.params["length"]
            # nested scans multiply: an inner scan walked with mult×L_outer
            # passes mult×L_outer×L_inner down (regression-tested).
            _walk(inner, flops_mult * length, coll_mult * length,
                  axis_env, out)
        elif prim == "while":
            trip = _while_trip_count(eqn)
            if trip is None:
                # data-dependent trip count: body counted once (a lower
                # bound) and flagged so downstream consumers know the
                # totals under-count instead of silently trusting them.
                out.add_finding(
                    "while-unbounded",
                    "while primitive has no static trip count; body "
                    "counted once (flops/collectives are a lower bound)")
                trip = 1
            _walk(eqn.params["body_jaxpr"].jaxpr, flops_mult * trip,
                  coll_mult * trip, axis_env, out)
        elif prim == "shard_map":
            sub = eqn.params.get("jaxpr")
            if sub is not None:
                inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
                # shard_map body runs on EVERY device over 1/N of data: the
                # global flop count is body × num_devices (mesh size).  The
                # mesh axes also name the collective axes inside the body.
                mesh = eqn.params.get("mesh")
                sizes = _mesh_axis_sizes(mesh) if mesh is not None else {}
                n = 1
                for s in sizes.values():
                    n *= s
                env = dict(axis_env)
                env.update(sizes)
                _walk(inner, flops_mult * max(n, 1), coll_mult, env, out)
        elif prim == "cond":
            branches = eqn.params.get("branches", ())
            merged = None
            for b in branches:
                sub = TraceCounts()
                _walk(b.jaxpr, flops_mult, coll_mult, axis_env, sub)
                if merged is None:
                    merged = sub
                else:
                    merged.merge_max(sub)
            if merged is not None:
                out.merge(merged)
        elif prim == "pallas_call":
            sub = eqn.params.get("jaxpr")
            if sub is not None:
                inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
                # the kernel body executes once per grid cell
                g = _grid_product(eqn.params)
                _walk(inner, flops_mult * g, coll_mult * g, axis_env, out)
        else:
            # generic call-like primitives (pjit, remat2, custom_vjp, ...)
            sub = (eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                   or eqn.params.get("fun_jaxpr"))
            if sub is not None:
                inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
                _walk(inner, flops_mult, coll_mult, axis_env, out)


def count_jaxpr(closed_jaxpr) -> TraceCounts:
    """Walk a closed jaxpr, returning FLOPs + per-(type, P) collectives."""
    out = TraceCounts()
    _walk(closed_jaxpr.jaxpr, 1.0, 1.0, {}, out)
    return out


def count_flops(closed_jaxpr) -> float:
    return count_jaxpr(closed_jaxpr).flops


def structural_flops(fn, *abstract_args, **abstract_kwargs) -> float:
    """Global matmul FLOPs of ``fn`` traced on abstract inputs."""
    cj = jax.make_jaxpr(fn)(*abstract_args, **abstract_kwargs)
    return count_flops(cj)


def trace_counts(fn, *abstract_args, **abstract_kwargs) -> TraceCounts:
    """FLOPs + collectives of ``fn`` traced on abstract inputs."""
    cj = jax.make_jaxpr(fn)(*abstract_args, **abstract_kwargs)
    return count_jaxpr(cj)
