"""MappingPlan subsystem tests (core/plan.py + the autotune refactor).

Covers: JSON roundtrip, memory/disk hit vs miss accounting, engine-version
invalidation, corrupted-file tolerance, concurrent-writer atomicity, plan
bundles, the no-search warm-process property for every paper-table kernel
shape, autotune parity against the pre-refactor algorithm, and the
ServeEngine startup warmup.
"""
import json
import math
import threading

import pytest

from repro.core import plan as plan_mod
from repro.core.hardware import edge
from repro.core.ir import MappingSpec
from repro.core.plan import MappingPlan, PlanCache, get_plan_cache
from repro.core.workload import gemm_softmax

CO = lambda: gemm_softmax(256, 1024, 64)


def _mk(tmp_path, name="plans"):
    return PlanCache(str(tmp_path / name))


# ------------------------------------------------------------- roundtrip


def test_plan_json_roundtrip(tmp_path):
    cache = _mk(tmp_path)
    plan = cache.resolve(CO(), edge())
    blob = json.dumps(plan.to_json())
    assert MappingPlan.from_json(json.loads(blob)) == plan


def test_plan_roundtrip_candidates_mode(tmp_path):
    cache = _mk(tmp_path)
    cl = [MappingSpec(variant="fused_dist", m_tiles=m) for m in (1, 2, 4)]
    plan = cache.resolve(CO(), edge(), candidate_list=cl)
    assert plan.search_mode == "candidates"
    assert plan.best_index is not None
    rt = MappingPlan.from_json(json.loads(json.dumps(plan.to_json())))
    assert rt == plan and rt.spec == cl[plan.best_index]


# ----------------------------------------------------------- hit / miss


def test_memory_and_disk_hits(tmp_path):
    cache = _mk(tmp_path)
    co, arch = CO(), edge()
    p1 = cache.resolve(co, arch)
    assert cache.stats["misses"] == 1 and cache.stats["stores"] == 1
    p2 = cache.resolve(co, arch)
    assert p2 is p1 and cache.stats["hits_mem"] == 1
    # a fresh instance over the same directory = a second process
    other = PlanCache(str(tmp_path / "plans"))
    p3 = other.resolve(co, arch)
    assert p3 == p1
    assert other.stats["hits_disk"] == 1 and other.stats["misses"] == 0


def test_distinct_search_kwargs_are_distinct_plans(tmp_path):
    cache = _mk(tmp_path)
    co, arch = CO(), edge()
    lat = cache.resolve(co, arch, objective="latency")
    en = cache.resolve(co, arch, objective="energy")
    assert cache.stats["misses"] == 2
    assert en.energy_pj <= lat.energy_pj


def test_version_bump_invalidates(tmp_path, monkeypatch):
    cache = _mk(tmp_path)
    co, arch = CO(), edge()
    cache.resolve(co, arch)
    monkeypatch.setattr(plan_mod, "ENGINE_VERSION", plan_mod.ENGINE_VERSION + 1)
    fresh = PlanCache(str(tmp_path / "plans"))
    assert fresh.lookup(co, arch) is None          # old plan invisible
    p2 = fresh.resolve(co, arch)                   # re-solves + persists
    assert fresh.stats["misses"] == 1
    assert p2.engine_version == plan_mod.ENGINE_VERSION


# ------------------------------------------------------------ durability


def _rewrite_payload(root, fn):
    """Tamper with every stored payload through a direct connection (the
    moral equivalent of another process corrupting the store)."""
    import sqlite3

    db = sqlite3.connect(str(root / "plans.sqlite"))
    try:
        for rowid, payload in db.execute(
                "SELECT rowid, payload FROM plans").fetchall():
            db.execute("UPDATE plans SET payload = ? WHERE rowid = ?",
                       (fn(payload), rowid))
        db.commit()
    finally:
        db.close()


def test_corrupted_record_warns_quarantines_and_resolves(tmp_path):
    cache = _mk(tmp_path)
    co, arch = CO(), edge()
    p1 = cache.resolve(co, arch)
    cache.store.close()
    _rewrite_payload(tmp_path / "plans", lambda _p: "{ not json !")
    fresh = PlanCache(str(tmp_path / "plans"))
    with pytest.warns(RuntimeWarning, match="corrupted stored plan"):
        p2 = fresh.resolve(co, arch)
    assert p2 == p1 and fresh.stats["corrupt"] == 1
    # the corrupt row was quarantined and the re-solve re-persisted: a
    # third instance reads the valid plan silently
    third = PlanCache(str(tmp_path / "plans"))
    assert third.lookup(co, arch) == p1 and third.stats["corrupt"] == 0


def test_wrong_key_payload_treated_as_miss(tmp_path):
    cache = _mk(tmp_path)
    co, arch = CO(), edge()
    p1 = cache.resolve(co, arch)
    cache.store.close()

    def forge(payload):
        blob = json.loads(payload)
        blob["key"][0] = "0" * 16                   # forged arch signature
        return json.dumps(blob)

    _rewrite_payload(tmp_path / "plans", forge)
    fresh = PlanCache(str(tmp_path / "plans"))
    with pytest.warns(RuntimeWarning, match="corrupted stored plan"):
        assert fresh.resolve(co, arch) == p1


def test_unwritable_store_degrades_to_memory(tmp_path):
    blocker = tmp_path / "blocked"
    blocker.write_text("a file where the store dir should go")
    cache = PlanCache(str(blocker / "plans"))
    with pytest.warns(RuntimeWarning, match="memory-only"):
        plan = cache.resolve(CO(), edge())
    assert plan.latency_s > 0
    assert cache.resolve(CO(), edge()) is plan     # memory layer still works


def test_concurrent_writers_atomic(tmp_path):
    """Many writers racing on the same key (separate store connections,
    WAL mode): every resolve returns the same plan, the final database
    passes an integrity check, and there is no write litter."""
    import sqlite3

    co, arch = CO(), edge()
    results, errors, caches = [], [], []

    def worker():
        try:
            # separate instances: no shared in-memory layer, all hit disk
            c = PlanCache(str(tmp_path / "plans"))
            caches.append(c)
            results.append(c.resolve(co, arch))
        except BaseException as e:   # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert all(r == results[0] for r in results)
    for c in caches:
        c.store.close()
    fresh = PlanCache(str(tmp_path / "plans"))
    assert fresh.lookup(co, arch) == results[0]    # readable => not partial
    fresh.store.close()
    db = sqlite3.connect(str(tmp_path / "plans" / "plans.sqlite"))
    try:
        assert db.execute("PRAGMA integrity_check").fetchone()[0] == "ok"
    finally:
        db.close()
    assert not list((tmp_path / "plans").glob("*.tmp"))  # no write litter


# --------------------------------------------------------------- bundles


def test_bundle_export_import(tmp_path):
    src = _mk(tmp_path, "src")
    co, arch = CO(), edge()
    plan = src.resolve(co, arch)
    bundle = tmp_path / "bundle.json"
    assert src.export_bundle(bundle) == 1
    dst = _mk(tmp_path, "dst")
    assert dst.import_bundle(bundle) == 1
    assert dst.lookup(co, arch) == plan
    # and the import persisted: a later instance hits disk
    assert PlanCache(str(tmp_path / "dst")).lookup(co, arch) == plan


def test_bundle_version_mismatch_skipped(tmp_path, monkeypatch):
    src = _mk(tmp_path, "src")
    src.resolve(CO(), edge())
    bundle = tmp_path / "bundle.json"
    src.export_bundle(bundle)
    monkeypatch.setattr(plan_mod, "ENGINE_VERSION", plan_mod.ENGINE_VERSION + 1)
    dst = _mk(tmp_path, "dst")
    assert dst.import_bundle(bundle) == 0


def test_get_plan_cache_follows_env(tmp_path, monkeypatch):
    a, b = tmp_path / "a", tmp_path / "b"
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(a))
    ca = get_plan_cache()
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(b))
    cb = get_plan_cache()
    assert ca is not cb and ca.root == a and cb.root == b
    assert get_plan_cache() is cb


# ------------------------------------- warm process answers without search


def test_warm_disk_cache_answers_all_paper_kernel_shapes_without_search(
        tmp_path, monkeypatch):
    """Acceptance gate: after one process warms the disk store, a second
    process (fresh PlanCache instances, empty in-memory layer) answers
    every paper-table kernel shape without ever invoking search()."""
    from repro.kernels import autotune

    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans"))
    warm = {}
    for sq, skv, d in autotune.PAPER_KERNEL_SHAPES["attention_blocks"]:
        warm[("a", sq, skv, d)] = autotune.attention_blocks(sq, skv, d)
    for m, n, k in autotune.PAPER_KERNEL_SHAPES["gemm_epilogue_blocks"]:
        warm[("g", m, n, k)] = autotune.gemm_epilogue_blocks(m, n, k)
    for s, p, n in autotune.PAPER_KERNEL_SHAPES["ssd_chunk_len"]:
        warm[("s", s, p, n)] = autotune.ssd_chunk_len(s, p, n)

    # "second process": drop every in-memory cache layer, then forbid the
    # search engine outright
    with plan_mod._CACHES_LOCK:
        plan_mod._CACHES.clear()

    def boom(*a, **kw):                            # pragma: no cover
        raise AssertionError("search() ran despite a warm disk cache")

    monkeypatch.setattr(plan_mod, "search", boom)
    monkeypatch.setattr(plan_mod, "search_many", boom)

    for sq, skv, d in autotune.PAPER_KERNEL_SHAPES["attention_blocks"]:
        assert autotune.attention_blocks(sq, skv, d) == warm[("a", sq, skv, d)]
    for m, n, k in autotune.PAPER_KERNEL_SHAPES["gemm_epilogue_blocks"]:
        assert autotune.gemm_epilogue_blocks(m, n, k) == warm[("g", m, n, k)]
    for s, p, n in autotune.PAPER_KERNEL_SHAPES["ssd_chunk_len"]:
        assert autotune.ssd_chunk_len(s, p, n) == warm[("s", s, p, n)]


def test_resolve_counts_solves_once_across_calls(tmp_path, monkeypatch):
    calls = []
    real = plan_mod.search

    def counting(*a, **kw):
        calls.append(a)
        return real(*a, **kw)

    monkeypatch.setattr(plan_mod, "search", counting)
    cache = _mk(tmp_path)
    co, arch = CO(), edge()
    for _ in range(5):
        cache.resolve(co, arch)
    assert len(calls) == 1


# ------------------------------------------------------- autotune parity


def _best_candidate_ref(br):
    i = br.best_index("latency")
    if i is not None:
        return i
    return min(range(br.size), key=lambda j: float(br.latency[j]))


def _attention_blocks_ref(sq, skv, d):
    """The pre-refactor attention_blocks (PR 1-4 algorithm): direct
    evaluate_specs_batch over the schedule-duplicated candidate axes."""
    from repro.core.batcheval import Topology, evaluate_specs_batch
    from repro.core.workload import flash_attention
    from repro.kernels.autotune import (SCHEDULES, VMEM_BUDGET, _LANE,
                                        _kernel_arch)

    arch = _kernel_arch()
    cands = [128, 256, 512, 1024]
    pairs = []
    for bq in cands:
        if bq > max(sq, _LANE):
            continue
        for bk in cands:
            if bk > max(skv, _LANE):
                continue
            vmem = (bq * d * 2 + 2 * bk * d * 2 + bq * d * 4 + bq * bk * 4
                    + 2 * bq * _LANE * 4)
            if vmem * 2 > VMEM_BUDGET:
                continue
            pairs.append((bq, bk))
    if not pairs:
        return (_LANE, _LANE)
    M, N = max(sq, _LANE), max(skv, _LANE)
    co = flash_attention(M, d, N, d)
    dup = lambda axis: [v for _ in SCHEDULES for v in axis]
    br = evaluate_specs_batch(
        co, arch, Topology(variant="fa"),
        dup([math.ceil(M / bq) for bq, _ in pairs]),
        [1] * (len(SCHEDULES) * len(pairs)),
        dup([math.ceil(N / bk) for _, bk in pairs]),
        schedule=[s for s in SCHEDULES for _ in range(len(pairs))])
    return pairs[_best_candidate_ref(br) % len(pairs)]


def _gemm_epilogue_blocks_ref(m, n, k):
    from repro.core.batcheval import Topology, evaluate_specs_batch
    from repro.kernels.autotune import (SCHEDULES, VMEM_BUDGET, _LANE,
                                        _kernel_arch)

    arch = _kernel_arch()
    pairs = []
    for bm in (128, 256, 512):
        for bk in (128, 256, 512):
            if bk > max(k, _LANE):
                continue
            vmem = bm * n * 4 + bk * n * 2 + bm * bk * 2 + bm * n * 2
            if vmem * 2 > VMEM_BUDGET:
                continue
            pairs.append((bm, bk))
    if not pairs:
        return (_LANE, _LANE)
    M, K = max(m, _LANE), max(k, _LANE)
    co = gemm_softmax(M, n, K)
    dup = lambda axis: [v for _ in SCHEDULES for v in axis]
    br = evaluate_specs_batch(
        co, arch, Topology(variant="fused_dist"),
        dup([math.ceil(M / bm) for bm, _ in pairs]),
        dup([math.ceil(K / bk) for _, bk in pairs]),
        [1] * (len(SCHEDULES) * len(pairs)),
        schedule=[s for s in SCHEDULES for _ in range(len(pairs))])
    return pairs[_best_candidate_ref(br) % len(pairs)]


def _ssd_chunk_len_ref(s, p, n):
    from repro.core.ir import evaluate_mapping
    from repro.core.workload import ssd_chunk
    from repro.kernels.autotune import VMEM_BUDGET, _LANE, _kernel_arch

    arch = _kernel_arch()
    best = None
    for c in (128, 256, 512):
        if c > max(s, _LANE):
            continue
        vmem = (c * p * 2 * 2 + 2 * c * n * 2 + c * c * 4 + n * p * 4)
        if vmem * 2 > VMEM_BUDGET:
            continue
        co = ssd_chunk(S=s, H=1, P=p, Dst=n, C=c)
        r = evaluate_mapping(co, arch, MappingSpec(variant="fused_dist",
                                                   m_tiles=1))
        lat = math.ceil(max(s, 1) / c) * r.latency
        if best is None or lat < best[0]:
            best = (lat, c)
    return 128 if best is None else best[1]


@pytest.mark.parametrize("shape", [
    (1024, 1024, 64), (4096, 4096, 128), (1, 32768, 128),
    (32768, 32768, 128), (100, 100, 32), (192, 300, 64), (1, 1, 64)])
def test_attention_blocks_parity(shape, tmp_path, monkeypatch):
    from repro.kernels.autotune import attention_blocks
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans"))
    assert attention_blocks(*shape) == _attention_blocks_ref(*shape)


@pytest.mark.parametrize("shape", [
    (512, 4096, 128), (4096, 4096, 4096), (4096, 16384, 4096),
    (128, 256, 64), (200, 1000, 96)])
def test_gemm_epilogue_blocks_parity(shape, tmp_path, monkeypatch):
    from repro.kernels.autotune import gemm_epilogue_blocks
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans"))
    assert gemm_epilogue_blocks(*shape) == _gemm_epilogue_blocks_ref(*shape)


@pytest.mark.parametrize("shape", [
    (4096, 64, 128), (128, 32, 64), (1024, 128, 256)])
def test_ssd_chunk_len_parity(shape, tmp_path, monkeypatch):
    from repro.kernels.autotune import ssd_chunk_len
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans"))
    assert ssd_chunk_len(*shape) == _ssd_chunk_len_ref(*shape)


def test_autotune_has_no_lru_cache():
    """Acceptance criterion: kernels/autotune.py has no functools
    lru_cache left — result caching lives in the PlanCache."""
    import inspect

    from repro.kernels import autotune

    src = inspect.getsource(autotune)
    assert "lru_cache" not in src
    assert "get_plan_cache" in src


# ------------------------------------------------------ serve-engine warmup


def test_serve_engine_warmup_populates_cache(tmp_path, monkeypatch):
    import jax

    from repro.configs import get_smoke_config
    from repro.kernels.autotune import plan_jobs
    from repro.models import Model
    from repro.serve.engine import ServeEngine

    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans"))
    cfg = get_smoke_config("glm4-9b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_size=2, cache_len=48,
                      prompt_len=16)
    assert eng.stats["plan_warmup_solved"] > 0
    assert (tmp_path / "plans" / "plans.sqlite").exists()
    assert get_plan_cache().store_stats()["store"]["plans"] > 0
    # every decode/prefill shape is now answerable without solving
    cache = get_plan_cache()
    for co, arch, kw in plan_jobs(eng.plan_shapes()):
        assert cache.lookup(co, arch, **kw) is not None
    # a second engine over the same store warms from hits alone
    eng2 = ServeEngine(model, params, batch_size=2, cache_len=48,
                       prompt_len=16)
    assert eng2.stats["plan_warmup_solved"] == 0
    assert eng2.stats["plan_warmup_hits"] > 0
