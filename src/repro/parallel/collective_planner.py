"""COMET-driven collective planning (DESIGN.md §2, model-level use).

The paper's central case study — distSM vs SM for a softmax whose reduction
dimension is sharded — occurs in this framework wherever the vocabulary-
sharded logits feed the cross-entropy loss (every training cell) and in
TP/flash-decoding attention merges.  This module:

1. ``plan_softmax_strategy``: costs both mappings with the COMET collective
   model (Eq. 3/4) on the actual mesh/tensor shapes and returns the
   cheaper one — 'dist' (two All-Reduces over M×1 stats, operate in place)
   or 'gather' (All-Gather the sharded rows, compute locally).
2. ``sharded_softmax_xent``: shard_map implementation of BOTH strategies —
   the framework's explicit-collective realization of Fig. 4(c).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.collectives import collective_cost, noc_latency
from repro.core.hardware import tpu_v5e

F32 = jnp.float32

__all__ = ["softmax_collective_schedule", "plan_softmax_strategy",
           "sharded_softmax_xent", "DeclaredCollective",
           "train_collective_schedule", "price_collective_schedule"]


def softmax_collective_schedule(strategy: str, rows: int, cols: int,
                                participants: int, *,
                                dp_participants: int = 1):
    """The DECLARED collective schedule of :func:`sharded_softmax_xent` —
    the single source of truth that both the planner (which costs it) and
    the static contract checker (``repro.analysis.contracts``, which
    audits the traced jaxpr against it) consume.  If the implementation
    gains or loses a collective, this list must change with it or the
    contract check fails.

    Returns ``[(col_type, dv_bytes, participants, count), ...]`` with DV
    in the cost model's convention (full tensor for All-Reduce, gathered
    result for All-Gather).  Stats and logits are f32 on the wire:
    ``_local_logits`` upcasts before the gather, so the gather arm is
    charged at 4 B/elem regardless of the input dtype.

    distSM: three (rows,) f32 stat All-Reduces over the model axis — the
    pmax of the running max, the psum of the exp-sums, and the psum of
    the label logits.  SM/gather: one All-Gather of the (rows, cols/P)
    f32 logit shards.  Both arms add two scalar loss-normalization
    All-Reduces over the data axis when it exists.
    """
    calls = []
    if participants > 1:
        if strategy == "dist":
            calls.append(("AllReduce", rows * 4.0, participants, 3))
        else:
            calls.append(("AllGather", rows * cols * 4.0, participants, 1))
    if dp_participants > 1:
        calls.append(("AllReduce", 4.0, dp_participants, 2))
    return calls


@functools.lru_cache(maxsize=1024)
def plan_softmax_strategy(rows: int, cols: int, participants: int,
                          dtype_bytes: int = 2) -> str:
    """COMET Eq. 3/4 comparison of the two softmax collective mappings.

    rows=M (tokens), cols=N (sharded softmax dim, e.g. padded vocab),
    participants=#shards on the reduction axis.  Costs exactly the
    collectives :func:`softmax_collective_schedule` declares (the data-
    axis scalar psums are common to both arms and cancel).  dtype_bytes
    is kept for call compatibility; the wire dtype is f32 either way
    (see the schedule's docstring).
    """
    if participants <= 1:
        return "dist"
    arch = tpu_v5e()
    noc = arch.cluster_noc

    def lat(schedule) -> float:
        total = 0.0
        for col_type, dv, P, count in schedule:
            cc = collective_cost(col_type, dv, P, noc)
            total += count * (cc.volume_bytes / noc.channel_bandwidth
                              + noc_latency(cc, noc))
        return total

    dist = lat(softmax_collective_schedule("dist", rows, cols, participants))
    gather = lat(softmax_collective_schedule("gather", rows, cols,
                                             participants))
    return "dist" if dist <= gather else "gather"


def sharded_softmax_xent(h: jax.Array, unembed: jax.Array,
                         labels: jax.Array, mesh: Mesh, *,
                         real_vocab: int,
                         strategy: str = "auto") -> jax.Array:
    """Cross-entropy over vocab-sharded logits with explicit collectives.

    h: (B, S, D) sharded over dp; unembed: (D, Vp) sharded over 'model';
    labels: (B, S).  Returns the scalar mean NLL.  'dist' computes the
    global max/logsumexp via All-Reduces of per-shard statistics (the
    paper's distSM); 'gather' All-Gathers the logit shards and computes
    locally (the paper's SM).  'auto' asks the COMET planner.
    """
    B, S, D = h.shape
    Vp = unembed.shape[1]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    mdl = "model"
    P_model = mesh.shape[mdl]
    if strategy == "auto":
        local_rows = (B * S) // max(1, int(np.prod([mesh.shape[a] for a in dp])) if dp else 1)
        strategy = plan_softmax_strategy(local_rows, Vp, P_model)

    v_local = Vp // P_model

    def _local_logits(h_l, w_l):
        return (h_l.reshape(-1, D) @ w_l).astype(F32)        # (T_l, V_l)

    def _mask_pad(lg, v0):
        idx = v0 + jnp.arange(lg.shape[-1])
        return jnp.where(idx[None, :] >= real_vocab, -1e30, lg)

    def dist_fn(h_l, w_l, y_l):
        lg = _local_logits(h_l, w_l)
        v0 = jax.lax.axis_index(mdl) * v_local
        lg = _mask_pad(lg, v0)
        # stability max is gradient-free (pmax has no AD rule; the exact
        # gradient flows through the logsumexp below regardless of m)
        m = jax.lax.stop_gradient(
            jax.lax.pmax(jax.lax.stop_gradient(lg.max(-1)), mdl))  # CO_1^0
        e = jnp.exp(lg - m[:, None])
        s = jax.lax.psum(e.sum(-1), mdl)                     # CO_1^1: AR(add)
        y = y_l.reshape(-1)
        in_shard = (y >= v0) & (y < v0 + v_local)
        safe = jnp.clip(y - v0, 0, v_local - 1)
        ll_local = jnp.where(in_shard,
                             jnp.take_along_axis(lg, safe[:, None], 1)[:, 0],
                             0.0)
        ll = jax.lax.psum(ll_local, mdl)
        nll = (jnp.log(s) + m - ll).sum()
        total = jax.lax.psum(jnp.float32(y.shape[0]), dp) if dp else y.shape[0]
        return jax.lax.psum(nll, dp) / total if dp else nll / total

    def gather_fn(h_l, w_l, y_l):
        lg = _local_logits(h_l, w_l)
        lg_full = jax.lax.all_gather(lg, mdl, axis=1, tiled=True)  # CO: AG
        lg_full = _mask_pad(lg_full, 0)
        m = lg_full.max(-1)
        s = jnp.exp(lg_full - m[:, None]).sum(-1)
        y = y_l.reshape(-1)
        ll = jnp.take_along_axis(lg_full, y[:, None], 1)[:, 0]
        nll = (jnp.log(s) + m - ll).sum()
        total = jax.lax.psum(jnp.float32(y.shape[0]), dp) if dp else y.shape[0]
        return jax.lax.psum(nll, dp) / total if dp else nll / total

    fn = dist_fn if strategy == "dist" else gather_fn
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(dp_spec, None, None), P(None, mdl), P(dp_spec, None)),
        out_specs=P(),
        check_rep=False,
    )(h, unembed, labels)


# ---------------------------------------------------------------------------
# Declared train-step collective schedule (PR 8 tentpole).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DeclaredCollective:
    """One declared collective of the train step.

    ``dv_bytes`` is the per-occurrence logical data volume in the cost
    model's convention (full tensor for All-Reduce, gathered result for
    All-Gather, full input for Reduce-Scatter) — the same convention
    ``repro.analysis.jaxpr`` records, so declared and traced entries
    compare directly.  ``origin`` partitions the schedule into the two
    audit regimes:

    * ``"explicit"`` — emitted by our shard_map bodies (softmax-xent, MoE
      combine) and their AD transposes.  These appear as collective
      primitives in the traced jaxpr and the contract checker asserts
      exact (type, participants, count, DV) equality.
    * ``"gspmd"`` — left to XLA's sharding propagation (data-axis grad
      all-reduces, tensor-parallel activation reductions in attention and
      the dense FFN).  Invisible in the jaxpr by construction; they are
      priced by the cost model and reconciled against the compiled HLO
      (``repro.analysis.reconcile``), not jaxpr-audited.
    """

    label: str
    col_type: str
    dv_bytes: float
    participants: int
    count: float
    origin: str = "explicit"

    def to_dict(self) -> dict:
        return {"label": self.label, "type": self.col_type,
                "dv_bytes": self.dv_bytes, "participants": self.participants,
                "count": self.count, "origin": self.origin}


def _dp_axes_size(mesh: Mesh):
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n = 1
    for a in dp:
        n *= int(mesh.shape[a])
    return dp, n


def train_collective_schedule(cfg, mesh: Mesh, batch: int, seq: int, *,
                              microbatches: int = 1, params=None,
                              planner_loss: bool = True):
    """The DECLARED per-layer collective schedule of one planner-loss
    training step — the single source of truth that the cost model prices
    (:func:`price_collective_schedule`) and the static contract checker
    (``repro.analysis.contracts`` train arm) audits against the traced
    jaxpr and, through ``repro.analysis.reconcile``, against the compiled
    HLO.  If ``make_train_step``'s implementation gains or loses a
    collective, this declaration must change with it or the audit fails.

    The explicit entries encode the empirically pinned shard_map AD rules
    (regression-tested in ``tests/test_static_analysis.py``):

    * every differentiable forward ``psum`` appears twice in the traced
      grad jaxpr — the forward op plus its transpose, which is again a
      psum of the same shape (``pmax`` under ``stop_gradient`` has no
      transpose);
    * an ``all_gather`` transposes to one ``reduce_scatter`` of the full
      gathered cotangent;
    * every shard_map *input* that is replicated over a mesh-axis set A
      (its in_spec leaves A unmentioned) and lies on the differentiation
      path contributes one cotangent ``psum`` over A, sized as the local
      operand (sharded inputs instead get a trivial ``psum(axes=())``
      which the audit ignores as participants == 1);
    * ``jax.checkpoint``/remat does NOT change traced collective counts;
      ``lax.scan`` multiplies its body counts by the trip count.

    Returns a list of :class:`DeclaredCollective`.  ``params`` is the
    (abstract or real) parameter tree used to size the data-axis gradient
    all-reduces; when None it is built from ``cfg`` via
    ``Model.abstract_params()``.
    """
    if params is None:
        from repro.models.model import Model
        params = Model(cfg).abstract_params()

    dp, P_dp = _dp_axes_size(mesh)
    P_m = int(mesh.shape["model"]) if "model" in mesh.axis_names else 1
    dtype_b = np.dtype(cfg.dtype).itemsize
    D = cfg.d_model
    Vp = cfg.padded_vocab
    v_local = Vp // max(P_m, 1)
    m = int(microbatches)
    b = batch // m                       # per-microbatch global batch
    B_local = b // max(P_dp, 1)
    rows = B_local * seq                 # local token rows per microbatch
    sched = []

    # ---- softmax-xent (explicit; composes softmax_collective_schedule's
    #      forward declaration with its AD transposes) -------------------
    if planner_loss and not cfg.tie_embeddings and not cfg.is_encdec:
        strategy = cfg.softmax_strategy
        if strategy in ("auto", "gspmd"):
            strategy = plan_softmax_strategy(rows, Vp, P_m)
        if P_m > 1:
            if strategy == "dist":
                # fwd: pmax + 2 psums (softmax_collective_schedule);
                # bwd: the 2 psums transpose, pmax is stop_gradient'd.
                sched.append(DeclaredCollective(
                    "xent/stats", "AllReduce", rows * 4.0, P_m, 5 * m))
            else:
                sched.append(DeclaredCollective(
                    "xent/logit-gather", "AllGather",
                    rows * Vp * 4.0, P_m, 1 * m))
                sched.append(DeclaredCollective(
                    "xent/logit-gather-grad", "ReduceScatter",
                    rows * Vp * 4.0, P_m, 1 * m))
            # h enters the shard_map replicated over 'model' -> one
            # cotangent psum of the local (B_local, S, D) activation.
            sched.append(DeclaredCollective(
                "xent/hidden-cotangent", "AllReduce",
                B_local * seq * D * dtype_b, P_m, 1 * m))
        if P_dp > 1:
            # fwd: token-count + nll psums; bwd: nll transpose (the token
            # count is constant under AD, so no fourth op).
            sched.append(DeclaredCollective(
                "xent/loss-norm", "AllReduce", 4.0, P_dp, 3 * m))
            # unembed enters replicated over dp -> cotangent psum of the
            # local (D, Vp/P_m) shard.
            sched.append(DeclaredCollective(
                "xent/unembed-grad", "AllReduce",
                D * v_local * dtype_b, P_dp, 1 * m))

    # ---- MoE combine + expert/router grads (explicit) ------------------
    n_moe = (cfg.n_layers - cfg.first_dense_layers) if cfg.is_moe else 0
    if n_moe and P_m > 1:
        E = cfg.n_experts
        e_local = E // P_m
        f = cfg.moe_d_ff
        t_local = (b * seq) // max(P_dp, 1)
        # combine psum (fwd) + its transpose + the x cotangent psum
        # (x enters replicated over 'model'): the checked realization of
        # the "no token all-to-all" claim in models/moe.py.
        sched.append(DeclaredCollective(
            "moe/combine", "AllReduce",
            t_local * D * dtype_b, P_m, 3 * n_moe * m))
        if P_dp > 1:
            # wi/wg/wo enter sharded over 'model', replicated over dp ->
            # one cotangent psum each of the local (e_local, d, f) shard.
            sched.append(DeclaredCollective(
                "moe/expert-grad", "AllReduce",
                e_local * D * f * dtype_b, P_dp, 3 * n_moe * m))
        if P_dp * P_m > 1:
            # router enters fully replicated -> cotangent psum over ALL
            # mesh axes (f32 by spec).
            sched.append(DeclaredCollective(
                "moe/router-grad", "AllReduce",
                D * E * 4.0, P_dp * P_m, 1 * n_moe * m))
            if cfg.router_type == "sigmoid":
                sched.append(DeclaredCollective(
                    "moe/router-bias-grad", "AllReduce",
                    E * 4.0, P_dp * P_m, 1 * n_moe * m))

    # ---- GSPMD-owned collectives (priced + HLO-reconciled only) --------
    # Data-axis gradient all-reduces, sized from the real param tree.
    # Leaves whose gradients are already reduced by an explicit cotangent
    # psum above (unembed under the planner loss, the MoE expert stack)
    # are excluded — declaring them twice would double-charge.
    if P_dp > 1:
        explicit = []
        if planner_loss and not cfg.tie_embeddings and not cfg.is_encdec:
            explicit.append("unembed")
        if n_moe and P_m > 1:
            explicit.append("moe")
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        rs = bool(cfg.fsdp)
        for path, leaf in flat:
            keys = [str(getattr(k, "key", k)) for k in path]
            if any(k in explicit for k in keys):
                continue
            nbytes = float(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
            sched.append(DeclaredCollective(
                "grads/" + ".".join(keys),
                "ReduceScatter" if rs else "AllReduce",
                nbytes, P_dp, 1, origin="gspmd"))
            if rs:  # ZeRO-3: fwd + bwd param regathers
                sched.append(DeclaredCollective(
                    "params/" + ".".join(keys), "AllGather",
                    nbytes, P_dp, 2, origin="gspmd"))
    # Tensor-parallel activation reductions: one AR after the attention
    # out-projection and one after the dense-FFN down-projection, forward
    # and backward (Megatron f/g) — the MoE FFN's reduction is the
    # explicit combine psum above.
    if cfg.tensor_parallel and P_m > 1:
        act = B_local * seq * D * dtype_b
        sched.append(DeclaredCollective(
            "tp/attn-out", "AllReduce", act, P_m,
            2 * cfg.n_layers * m, origin="gspmd"))
        n_dense_ffn = cfg.n_layers - n_moe
        if n_dense_ffn:
            sched.append(DeclaredCollective(
                "tp/ffn-out", "AllReduce", act, P_m,
                2 * n_dense_ffn * m, origin="gspmd"))
    return sched


def price_collective_schedule(schedule, noc=None) -> float:
    """COMET Eq. 3/4 latency of a declared schedule (seconds)."""
    if noc is None:
        noc = tpu_v5e().cluster_noc
    total = 0.0
    for d in schedule:
        if d.participants <= 1:
            continue
        cc = collective_cost(d.col_type, d.dv_bytes, d.participants, noc)
        total += d.count * (cc.volume_bytes / noc.channel_bandwidth
                            + noc_latency(cc, noc))
    return total
