"""COMET-driven collective planning (DESIGN.md §2, model-level use).

The paper's central case study — distSM vs SM for a softmax whose reduction
dimension is sharded — occurs in this framework wherever the vocabulary-
sharded logits feed the cross-entropy loss (every training cell) and in
TP/flash-decoding attention merges.  This module:

1. ``plan_softmax_strategy``: costs both mappings with the COMET collective
   model (Eq. 3/4) on the actual mesh/tensor shapes and returns the
   cheaper one — 'dist' (two All-Reduces over M×1 stats, operate in place)
   or 'gather' (All-Gather the sharded rows, compute locally).
2. ``sharded_softmax_xent``: shard_map implementation of BOTH strategies —
   the framework's explicit-collective realization of Fig. 4(c).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.collectives import collective_cost, noc_latency
from repro.core.hardware import tpu_v5e

F32 = jnp.float32

__all__ = ["softmax_collective_schedule", "plan_softmax_strategy",
           "sharded_softmax_xent"]


def softmax_collective_schedule(strategy: str, rows: int, cols: int,
                                participants: int, *,
                                dp_participants: int = 1):
    """The DECLARED collective schedule of :func:`sharded_softmax_xent` —
    the single source of truth that both the planner (which costs it) and
    the static contract checker (``repro.analysis.contracts``, which
    audits the traced jaxpr against it) consume.  If the implementation
    gains or loses a collective, this list must change with it or the
    contract check fails.

    Returns ``[(col_type, dv_bytes, participants, count), ...]`` with DV
    in the cost model's convention (full tensor for All-Reduce, gathered
    result for All-Gather).  Stats and logits are f32 on the wire:
    ``_local_logits`` upcasts before the gather, so the gather arm is
    charged at 4 B/elem regardless of the input dtype.

    distSM: three (rows,) f32 stat All-Reduces over the model axis — the
    pmax of the running max, the psum of the exp-sums, and the psum of
    the label logits.  SM/gather: one All-Gather of the (rows, cols/P)
    f32 logit shards.  Both arms add two scalar loss-normalization
    All-Reduces over the data axis when it exists.
    """
    calls = []
    if participants > 1:
        if strategy == "dist":
            calls.append(("AllReduce", rows * 4.0, participants, 3))
        else:
            calls.append(("AllGather", rows * cols * 4.0, participants, 1))
    if dp_participants > 1:
        calls.append(("AllReduce", 4.0, dp_participants, 2))
    return calls


@functools.lru_cache(maxsize=1024)
def plan_softmax_strategy(rows: int, cols: int, participants: int,
                          dtype_bytes: int = 2) -> str:
    """COMET Eq. 3/4 comparison of the two softmax collective mappings.

    rows=M (tokens), cols=N (sharded softmax dim, e.g. padded vocab),
    participants=#shards on the reduction axis.  Costs exactly the
    collectives :func:`softmax_collective_schedule` declares (the data-
    axis scalar psums are common to both arms and cancel).  dtype_bytes
    is kept for call compatibility; the wire dtype is f32 either way
    (see the schedule's docstring).
    """
    if participants <= 1:
        return "dist"
    arch = tpu_v5e()
    noc = arch.cluster_noc

    def lat(schedule) -> float:
        total = 0.0
        for col_type, dv, P, count in schedule:
            cc = collective_cost(col_type, dv, P, noc)
            total += count * (cc.volume_bytes / noc.channel_bandwidth
                              + noc_latency(cc, noc))
        return total

    dist = lat(softmax_collective_schedule("dist", rows, cols, participants))
    gather = lat(softmax_collective_schedule("gather", rows, cols,
                                             participants))
    return "dist" if dist <= gather else "gather"


def sharded_softmax_xent(h: jax.Array, unembed: jax.Array,
                         labels: jax.Array, mesh: Mesh, *,
                         real_vocab: int,
                         strategy: str = "auto") -> jax.Array:
    """Cross-entropy over vocab-sharded logits with explicit collectives.

    h: (B, S, D) sharded over dp; unembed: (D, Vp) sharded over 'model';
    labels: (B, S).  Returns the scalar mean NLL.  'dist' computes the
    global max/logsumexp via All-Reduces of per-shard statistics (the
    paper's distSM); 'gather' All-Gathers the logit shards and computes
    locally (the paper's SM).  'auto' asks the COMET planner.
    """
    B, S, D = h.shape
    Vp = unembed.shape[1]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    mdl = "model"
    P_model = mesh.shape[mdl]
    if strategy == "auto":
        local_rows = (B * S) // max(1, int(np.prod([mesh.shape[a] for a in dp])) if dp else 1)
        strategy = plan_softmax_strategy(local_rows, Vp, P_model)

    v_local = Vp // P_model

    def _local_logits(h_l, w_l):
        return (h_l.reshape(-1, D) @ w_l).astype(F32)        # (T_l, V_l)

    def _mask_pad(lg, v0):
        idx = v0 + jnp.arange(lg.shape[-1])
        return jnp.where(idx[None, :] >= real_vocab, -1e30, lg)

    def dist_fn(h_l, w_l, y_l):
        lg = _local_logits(h_l, w_l)
        v0 = jax.lax.axis_index(mdl) * v_local
        lg = _mask_pad(lg, v0)
        # stability max is gradient-free (pmax has no AD rule; the exact
        # gradient flows through the logsumexp below regardless of m)
        m = jax.lax.stop_gradient(
            jax.lax.pmax(jax.lax.stop_gradient(lg.max(-1)), mdl))  # CO_1^0
        e = jnp.exp(lg - m[:, None])
        s = jax.lax.psum(e.sum(-1), mdl)                     # CO_1^1: AR(add)
        y = y_l.reshape(-1)
        in_shard = (y >= v0) & (y < v0 + v_local)
        safe = jnp.clip(y - v0, 0, v_local - 1)
        ll_local = jnp.where(in_shard,
                             jnp.take_along_axis(lg, safe[:, None], 1)[:, 0],
                             0.0)
        ll = jax.lax.psum(ll_local, mdl)
        nll = (jnp.log(s) + m - ll).sum()
        total = jax.lax.psum(jnp.float32(y.shape[0]), dp) if dp else y.shape[0]
        return jax.lax.psum(nll, dp) / total if dp else nll / total

    def gather_fn(h_l, w_l, y_l):
        lg = _local_logits(h_l, w_l)
        lg_full = jax.lax.all_gather(lg, mdl, axis=1, tiled=True)  # CO: AG
        lg_full = _mask_pad(lg_full, 0)
        m = lg_full.max(-1)
        s = jnp.exp(lg_full - m[:, None]).sum(-1)
        y = y_l.reshape(-1)
        ll = jnp.take_along_axis(lg_full, y[:, None], 1)[:, 0]
        nll = (jnp.log(s) + m - ll).sum()
        total = jax.lax.psum(jnp.float32(y.shape[0]), dp) if dp else y.shape[0]
        return jax.lax.psum(nll, dp) / total if dp else nll / total

    fn = dist_fn if strategy == "dist" else gather_fn
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(dp_spec, None, None), P(None, mdl), P(dp_spec, None)),
        out_specs=P(),
        check_rep=False,
    )(h, unembed, labels)
