# Pallas TPU kernels for the compound operations the paper optimizes:
# FlashAttention (FA dataflow), fused GEMM-Softmax, fused GEMM-LayerNorm/
# RMSNorm, and the Mamba-2 SSD chunk scan.  Block sizes are chosen by the
# COMET cost model (autotune.py); ref.py holds the pure-jnp oracles.
from . import autotune, ops, ref
from .allgather_gemm import allgather_gemm, streamed_gemm
from .flash_attention import flash_attention
from .gemm_layernorm import gemm_layernorm, gemm_rmsnorm
from .gemm_softmax import gemm_softmax
from .ssd import ssd_scan

__all__ = ["autotune", "ops", "ref", "allgather_gemm", "streamed_gemm",
           "flash_attention", "gemm_layernorm", "gemm_rmsnorm",
           "gemm_softmax", "ssd_scan"]
