"""Compute-collective overlap microbenchmark.

Measures the *achieved* hidden fraction when an all-gather and a
dependency-adjacent GEMM run concurrently, feeding the calibrated
``overlap`` factor (``calibrate/overlap.py``) and the
BENCH_search.json v9 gate:

    t_gather  -- jit(shard_map(all_gather)), dispatched and blocked alone
    t_gemm    -- an independent jit(dot), dispatched and blocked alone
    t_conc    -- both dispatched back-to-back (jax async dispatch lets
                 the runtime execute them concurrently), then one block

    hidden fraction = clamp((t_gather + t_gemm - t_conc)
                            / min(t_gather, t_gemm), 0, 1)

This is exactly the :class:`repro.calibrate.overlap.ConcurrentPoint`
shape, so the result plugs straight into ``fit_overlap``.  Each timing
is best-of-``iters`` after a warm-up call (best-of, not mean: dispatch
jitter only ever *adds* time, so the minimum is the cleanest estimate
of the schedulable cost).

Backend honesty: the CPU PJRT client *serializes* executions across
its virtual devices (measured directly: two independent matmuls on
different virtual devices take exactly the sum of their solo times),
so off-TPU the achievable hidden fraction is genuinely ~0 — the
virtual devices share the same cores, and there is no idle engine to
hide the collective on.  The BENCH_search.json v9 floor gate on the
measured fraction therefore applies only ``on_tpu``; off-TPU CI gates
the *model* instead, via the deterministic synthetic-recovery bound
(``fit_overlap`` on ``synthetic_concurrent_points``).  The Pallas
double-buffer comparison below has the same caveat: interpret mode
runs the DMAs eagerly, so its speedup is only a signal on a real TPU.

Also times the Pallas streamed GEMM (``kernels/allgather_gemm.py``)
with ``buffers=2`` (prefetch chunk i+1 under the chunk-i matmul)
against the ``buffers=1`` serial baseline.  In interpret mode the
async copies execute eagerly, so off-TPU the ratio is reported for
visibility but carries no performance signal.

Run directly (spawns 8 virtual CPU devices when no TPU is attached):

    PYTHONPATH=src python benchmarks/overlap_bench.py
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Callable, Dict, Optional

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _best_of(fn, iters: int, clock: Callable[[], float]) -> float:
    """Best-of-``iters`` wall seconds of ``fn()`` after one warm-up."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(iters):
        t0 = clock()
        jax.block_until_ready(fn())
        best = min(best, clock() - t0)
    return best


def measure_hidden_fraction(*, M: int = 256, K: int = 4096, N: int = 512,
                            iters: int = 20,
                            clock: Callable[[], float] = time.perf_counter,
                            ) -> Dict:
    """Measured hidden fraction of gather-under-GEMM on this backend."""
    devices = jax.devices()
    n_dev = len(devices)
    mesh = Mesh(np.array(devices), ("x",))
    rng = np.random.default_rng(0)
    if K % n_dev != 0:
        K = (K // n_dev + 1) * n_dev
    X = jax.device_put(
        jnp.asarray(rng.standard_normal((M, K)), jnp.float32),
        NamedSharding(mesh, P(None, "x")))
    A = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    W = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)

    gather = jax.jit(shard_map(
        lambda x: jax.lax.all_gather(x, "x", axis=1, tiled=True),
        mesh=mesh, in_specs=P(None, "x"), out_specs=P(None, None),
        check_rep=False))
    # the compute half lives on the default device, off the mesh, so the
    # runtime is free to execute it while the gather is in flight
    gemm = jax.jit(lambda a, w: jnp.dot(a, w))

    t_gather = _best_of(lambda: gather(X), iters, clock)
    t_gemm = _best_of(lambda: gemm(A, W), iters, clock)
    # jax dispatch is async: both programs are in flight before the
    # single block — the measured analogue of overlap=achievable
    t_conc = _best_of(lambda: (gather(X), gemm(A, W)), iters, clock)

    cap = min(t_gather, t_gemm)
    hidden = t_gather + t_gemm - t_conc
    frac = float(np.clip(hidden / cap, 0.0, 1.0)) if cap > 0 else 0.0
    return {"t_gather_s": t_gather, "t_gemm_s": t_gemm,
            "t_concurrent_s": t_conc, "hidden_fraction": frac,
            "n_devices": n_dev, "backend": jax.default_backend(),
            "shape": [M, K, N]}


def measure_double_buffer(*, M: int = 128, K: int = 1024, N: int = 256,
                          chunks: int = 8, iters: int = 5,
                          clock: Callable[[], float] = time.perf_counter,
                          ) -> Dict:
    """Pallas streamed GEMM: double- vs single-buffered chunk stream.
    Only a performance signal on a real TPU (interpret mode runs the
    DMAs eagerly); always a correctness check."""
    from repro.kernels import streamed_gemm

    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    out2 = streamed_gemm(x, w, chunks=chunks, buffers=2)
    out1 = streamed_gemm(x, w, chunks=chunks, buffers=1)
    err = float(jnp.abs(out2 - out1).max())
    t2 = _best_of(lambda: streamed_gemm(x, w, chunks=chunks, buffers=2),
                  iters, clock)
    t1 = _best_of(lambda: streamed_gemm(x, w, chunks=chunks, buffers=1),
                  iters, clock)
    on_tpu = jax.default_backend() == "tpu"
    return {"t_double_buffer_s": t2, "t_single_buffer_s": t1,
            "buffer_agreement_err": err, "on_tpu": on_tpu,
            "speedup": (t1 / t2) if t2 > 0 else 0.0}


def synthetic_recovery(true_overlap: float = 0.6) -> Dict:
    """Deterministic model-side check: ``fit_overlap`` must recover a
    known achievable overlap from a synthetic concurrent sweep — the
    off-TPU stand-in for the measured-fraction gate (see module
    docstring)."""
    from repro.calibrate.overlap import (fit_overlap,
                                         synthetic_concurrent_points)
    from repro.core.hardware import tpu_v5e

    noc = tpu_v5e().cluster_noc
    clean = fit_overlap(synthetic_concurrent_points(noc, true_overlap), noc)
    jit_f = fit_overlap(
        synthetic_concurrent_points(noc, true_overlap, jitter=0.05, seed=3),
        noc)
    return {"true_overlap": true_overlap,
            "clean_fitted": clean.overlap,
            "clean_err": abs(clean.overlap - true_overlap),
            "clean_pred_max_err": clean.max_abs_err,
            "jittered_fitted": jit_f.overlap,
            "jittered_err": abs(jit_f.overlap - true_overlap)}


def run_all(*, iters: int = 20,
            clock: Callable[[], float] = time.perf_counter) -> Dict:
    out = {"schema": "comet/overlap_bench/v1"}
    out["fused_gather_gemm"] = measure_hidden_fraction(iters=iters,
                                                       clock=clock)
    out["pallas_double_buffer"] = measure_double_buffer(clock=clock)
    out["synthetic_recovery"] = synthetic_recovery()
    f = out["fused_gather_gemm"]
    print(f"gather={f['t_gather_s'] * 1e6:.0f}us gemm={f['t_gemm_s'] * 1e6:.0f}us "
          f"concurrent={f['t_concurrent_s'] * 1e6:.0f}us "
          f"hidden_fraction={f['hidden_fraction']:.3f} "
          f"({f['n_devices']} {f['backend']} devices)")
    d = out["pallas_double_buffer"]
    print(f"pallas 2buf={d['t_double_buffer_s'] * 1e6:.0f}us "
          f"1buf={d['t_single_buffer_s'] * 1e6:.0f}us "
          f"speedup={d['speedup']:.2f} on_tpu={d['on_tpu']} "
          f"agreement_err={d['buffer_agreement_err']:.1e}")
    s = out["synthetic_recovery"]
    print(f"synthetic recovery: true={s['true_overlap']:.2f} "
          f"clean={s['clean_fitted']:.4f} jittered={s['jittered_fitted']:.4f}")
    return out


if __name__ == "__main__":
    res = run_all()
    if "--json" in sys.argv:
        print(json.dumps(res))
