from . import attention, config, encdec, layers, model, moe, param, ssm, transformer
from .config import ModelConfig
from .model import Model

__all__ = ["ModelConfig", "Model", "attention", "config", "encdec", "layers",
           "model", "moe", "param", "ssm", "transformer"]
