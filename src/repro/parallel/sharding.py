"""Logical-axis sharding rules → NamedShardings.

Every parameter carries logical axis names (models/param.py); this module
maps them onto the production mesh: tensor-parallel axes (vocab / heads /
ff / experts / inner) shard over ``model``; batch shards over
``(pod, data)``; anything non-divisible falls back to replication (e.g.
MQA's single KV head, Hymba's 25 heads — XLA handles uneven sharding for
activations, but parameter shards must divide evenly for checkpoint
round-trips, so we replicate instead).

ZeRO-1: optimizer moments additionally shard over the data axes on the
largest divisible dimension not already sharded (reduce-scatter/all-gather
pattern at the XLA level).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["LOGICAL_RULES", "spec_for", "param_shardings", "zero1_shardings",
           "batch_spec", "batch_sharding", "cache_shardings", "dp_size"]

# logical axis -> mesh axis (None = replicate)
LOGICAL_RULES: Dict[str, Optional[str]] = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",
    "inner": "model",
    "embed": None,       # residual stream replicated (seq-parallel is a knob)
    "layer": None,
    None: None,
}


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def spec_for(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
             mesh: Mesh) -> P:
    """PartitionSpec for one param: apply LOGICAL_RULES with divisibility
    fallback (replicate non-divisible dims)."""
    entries = []
    for ax, dim in zip(axes, shape):
        mesh_ax = LOGICAL_RULES.get(ax)
        if mesh_ax is not None and mesh_ax in mesh.axis_names \
                and dim % mesh.shape[mesh_ax] == 0:
            entries.append(mesh_ax)
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_shardings(axes_tree, abstract_tree, mesh: Mesh):
    """NamedSharding pytree for params."""
    return jax.tree.map(
        lambda ax, ab: NamedSharding(mesh, spec_for(ax, ab.shape, mesh)),
        axes_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def zero1_spec(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
               mesh: Mesh) -> P:
    """Param spec + data-axis sharding on the largest free divisible dim."""
    base = list(spec_for(axes, shape, mesh))
    base += [None] * (len(shape) - len(base))
    dp = dp_axes(mesh)
    if not dp:
        return P(*base)
    n = dp_size(mesh)
    # largest unsharded dim divisible by the full dp size
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if base[i] is None and shape[i] % n == 0 and shape[i] >= n:
            base[i] = dp if len(dp) > 1 else dp[0]
            break
    while base and base[-1] is None:
        base.pop()
    return P(*base)


def zero1_shardings(axes_tree, abstract_tree, mesh: Mesh):
    return jax.tree.map(
        lambda ax, ab: NamedSharding(mesh, zero1_spec(ax, ab.shape, mesh)),
        axes_tree, abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def batch_spec(mesh: Mesh, batch: int) -> P:
    dp = dp_axes(mesh)
    if not dp or batch % dp_size(mesh) != 0:
        # decode long_500k (batch=1): replicate
        usable = []
        n = 1
        for a in dp:
            if batch % (n * mesh.shape[a]) == 0:
                usable.append(a)
                n *= mesh.shape[a]
        dp = tuple(usable)
    if not dp:
        return P()
    return P(dp if len(dp) > 1 else dp[0])


def batch_sharding(mesh: Mesh, batch: int, ndim: int) -> NamedSharding:
    bs = batch_spec(mesh, batch)
    tail = (None,) * (ndim - 1)
    entries = tuple(bs) + tail if len(bs) else (None,) * ndim
    return NamedSharding(mesh, P(*entries[:ndim]))


def cache_shardings(cache_abstract, mesh: Mesh, batch: int):
    """KV/SSM cache shardings (path-aware).  Layout is (L, B, ...) for
    layer-stacked entries.  Batch shards over dp.  Attention caches:
    kv-heads over ``model`` when divisible, otherwise the **sequence** dim
    shards over ``model`` — GSPMD then realizes the paper's distSM mapping
    for the decode softmax (stats All-Reduces across the seq shards).
    The MLA latent cache always shards seq over model (its feature dim is
    the contraction rank)."""
    bs = batch_spec(mesh, batch)
    b_ax = bs[0] if len(bs) else None
    m = mesh.shape.get("model", 1) if "model" in mesh.axis_names else 1

    def one(path, ab):
        name = jax.tree_util.keystr(path)
        shp = ab.shape
        entries = [None] * len(shp)
        # batch dim: index 1 for stacked (L, B, ...) entries
        for i, d in enumerate(shp[:2]):
            if d == batch:
                entries[i] = b_ax
                break
        if m > 1 and len(shp) >= 3:
            if ("'k'" in name or "'v'" in name) and len(shp) == 5:
                L_, B_, S_, H_, hd_ = shp
                if H_ % m == 0:
                    entries[3] = "model"          # kv-heads TP
                elif S_ % m == 0:
                    entries[2] = "model"          # seq-sharded -> distSM
            elif "'ckv'" in name or "'kr'" in name:
                if shp[2] % m == 0:
                    entries[2] = "model"          # MLA latent: seq over model
            elif "'conv'" in name and shp[-1] % m == 0:
                entries[-1] = "model"             # conv channels TP
            elif "'state'" in name and shp[2] % m == 0:
                entries[2] = "model"              # ssm heads TP
        while entries and entries[-1] is None:
            entries.pop()
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map_with_path(one, cache_abstract)
