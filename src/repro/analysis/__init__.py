"""Static analysis subsystem: trace contracts + repo-invariant lint.

Two arms, both runnable as ``python -m repro.analysis`` (JSON out,
nonzero exit on failure — the CI ``static-analysis`` job gates on it):

- :mod:`repro.analysis.contracts` — cross-checks the Pallas kernels and
  the shard_map model paths against the COMET cost model: traced GEMM
  FLOPs and per-collective-type wire volumes must match what the winning
  MappingPlan / declared collective schedule predicts.
- :mod:`repro.analysis.lint` — AST lint for the invariants the review
  process keeps re-litigating (array-polymorphic Eq. 1-7 path purity,
  Pallas-kernel host hygiene, VMEM budgets, sqlite confinement).

The jaxpr/HLO walkers these build on live in :mod:`repro.analysis.jaxpr`
and :mod:`repro.analysis.hlo`; ``repro.launch.jaxpr_analysis`` /
``repro.launch.hlo_analysis`` remain as compat shims.
"""
from .hlo import (CollectiveStats, HW, parse_collectives, roofline_terms,
                  shape_bytes)
from .jaxpr import (CollectiveRecord, TraceCounts, count_flops, count_jaxpr,
                    structural_flops, trace_counts)
from .reconcile import (ReconcileReport, expected_wire_from_schedule,
                        expected_wire_from_trace, reconcile, reconcile_cell)

__all__ = [
    "CollectiveStats", "HW", "parse_collectives", "roofline_terms",
    "shape_bytes", "CollectiveRecord", "TraceCounts", "count_flops",
    "count_jaxpr", "structural_flops", "trace_counts",
    "ReconcileReport", "reconcile", "reconcile_cell",
    "expected_wire_from_trace", "expected_wire_from_schedule",
]
