"""Fault-tolerant checkpointing: atomic manifest + per-leaf npy shards,
keep-last-k retention, async writer thread, and elastic restore (reshard a
checkpoint onto a different mesh/device count).

Layout:
    <dir>/step_000123/
        manifest.json      # treedef, shapes, dtypes, step, extra metadata
        leaf_00000.npy ... # one file per pytree leaf (host-gathered)
    <dir>/LATEST           # atomic pointer file
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes  # noqa: F401 — registers bfloat16 etc. with numpy
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]

_MANIFEST = "manifest.json"
_LATEST = "LATEST"


def _flatten_with_names(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]
    return named, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree, *,
                    extra: Optional[Dict] = None, keep: int = 3) -> str:
    """Synchronous atomic save; returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    named, _ = _flatten_with_names(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (name, leaf) in enumerate(named):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({"name": name, "file": fname,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    latest_tmp = os.path.join(ckpt_dir, _LATEST + ".tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(latest_tmp, os.path.join(ckpt_dir, _LATEST))
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, _LATEST)
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name, _MANIFEST)):
        return None
    return int(name.split("_")[1])


def restore_checkpoint(ckpt_dir: str, tree_like, *, step: Optional[int] = None,
                       shardings=None) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``tree_like``.  ``shardings`` (a
    matching pytree of NamedShardings) enables **elastic restore**: the
    host arrays are placed onto whatever mesh the shardings reference —
    growing or shrinking the device count between runs."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)

    def _load(entry):
        arr = np.load(os.path.join(path, entry["file"]))
        want = np.dtype(entry["dtype"])     # ml_dtypes names resolve here
        if arr.dtype != want:
            arr = arr.view(want)            # bf16 round-trips as void16
        return arr

    leaves = [_load(e) for e in manifest["leaves"]]
    flat_like, treedef = jax.tree_util.tree_flatten(tree_like)
    assert len(flat_like) == len(leaves), (len(flat_like), len(leaves))
    if shardings is not None:
        flat_sh, _ = jax.tree_util.tree_flatten(shardings)
        leaves = [jax.device_put(a, s) for a, s in zip(leaves, flat_sh)]
    else:
        leaves = [jax.device_put(a) for a in leaves]
    return (jax.tree_util.tree_unflatten(treedef, leaves), step,
            manifest.get("extra", {}))


class AsyncCheckpointer:
    """Background writer thread: training never blocks on I/O.  ``save``
    snapshots to host memory synchronously (cheap) and enqueues the disk
    write; ``wait`` drains the queue (call before exit)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self.errors: List[str] = []

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree, extra = item
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree, extra=extra,
                                keep=self.keep)
            except Exception as e:  # noqa: BLE001 — surfaced via .errors
                self.errors.append(f"step {step}: {e}")

    def save(self, step: int, tree, extra: Optional[Dict] = None) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._q.put((step, host_tree, extra))

    def wait(self) -> None:
        self._q.put(None)
        self._worker.join()
