"""Production mesh construction.

Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod: 2×16×16 = 512 chips, axes (pod, data, model) — the 'pod' axis
carries data parallelism across pods (gradient all-reduce crosses the
pod-interconnect; int8 compression applies there).

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "make_auto_mesh",
           "POD_SHAPE"]

POD_SHAPE = (16, 16)


def make_auto_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> jax.sharding.Mesh:
    """jax.make_mesh with Auto axis types on jax versions that have them
    (jax.sharding.AxisType landed after 0.4.x; Auto is the old implicit
    behavior, so omitting it there is equivalent)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_host_mesh(model_parallel: Optional[int] = None) -> jax.sharding.Mesh:
    """Largest (data, model) mesh on the devices actually present (tests,
    examples, smoke runs)."""
    n = len(jax.devices())
    mp = model_parallel or 1
    while n % mp:
        mp -= 1
    return jax.make_mesh((n // mp, mp), ("data", "model"))
