"""Gradient compression for cross-pod reduction.

Two pieces:

* :func:`quantize_int8` / :func:`dequantize_int8` — per-tensor symmetric
  int8 quantization with error feedback (the residual is carried in the
  optimizer state and added back next step, preserving convergence).
* :func:`compressed_psum` — shard_map collective that all-reduces an
  int8-quantized payload (int32 accumulation, shared pmax scale): the
  transport pattern a real cross-pod int8 gradient all-reduce uses (4–8×
  volume reduction on the ICI/DCN hop).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

F32 = jnp.float32

__all__ = ["quantize_int8", "dequantize_int8", "compress_with_feedback",
           "compressed_psum"]


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.abs(x).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(F32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(F32) * scale


def compress_with_feedback(g: jax.Array, err: jax.Array
                           ) -> Tuple[jax.Array, jax.Array]:
    """Returns (compressed-then-decompressed gradient, new error feedback)."""
    x = g.astype(F32) + err
    q, s = quantize_int8(x)
    dq = dequantize_int8(q, s)
    return dq.astype(g.dtype), (x - dq)


def compressed_psum(x: jax.Array, mesh: Mesh, axis: str) -> jax.Array:
    """All-reduce ``x`` over ``axis`` with int8 payload (int32 accumulate,
    shared scale via pmax).  x must be replicated over the other axes."""

    def fn(x_l):
        scale = jax.lax.pmax(jnp.maximum(jnp.abs(x_l).max(), 1e-12), axis) / 127.0
        q = jnp.clip(jnp.round(x_l / scale), -127, 127).astype(jnp.int32)
        acc = jax.lax.psum(q, axis)
        return acc.astype(F32) * scale

    in_spec = P(*([axis] + [None] * (x.ndim - 1)))
    # shard over the reduced axis on dim 0 requires divisibility; fall back
    # to replicated input (each shard holds a full copy == grad replicas).
    return shard_map(fn, mesh=mesh, in_specs=P(), out_specs=P(),
                     check_rep=False)(x)
