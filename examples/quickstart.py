"""Quickstart: COMET mapping search for a GEMM-Softmax compound op.

    PYTHONPATH=src python examples/quickstart.py

Searches the 4-D mapping space (tiling x spatial x collectives x schedule)
on the paper's cloud accelerator, prints the best mapping tree with its
explicit collective nodes, and compares the four fusion variants.
"""
from repro.core import gemm_softmax
from repro.core.hardware import cloud
from repro.core.ir import MappingSpec, evaluate_mapping
from repro.core.mapping import tree_str
from repro.core.search import search


def main() -> None:
    co = gemm_softmax(M=512, N=4096, K=128)      # GEMM12 (Table II)
    arch = cloud()

    print("== fusion variants (fixed tiling) ==")
    for variant in ("unfused", "fused_epilogue", "fused_std", "fused_dist"):
        r = evaluate_mapping(co, arch, MappingSpec(variant=variant,
                                                   m_tiles=8, k_tiles=2))
        print(f"  {variant:15s} latency={r.latency*1e6:9.2f}us "
              f"energy={r.energy_pj/1e6:8.2f}uJ valid={r.valid}")

    print("\n== map-space search (budget 2000) ==")
    res = search(co, arch, budget=2000, seed=0)
    best = res.best
    print(f"best: {best.spec.variant} m_tiles={best.spec.m_tiles} "
          f"k_tiles={best.spec.k_tiles} sched={best.spec.schedule} "
          f"-> {best.latency*1e6:.2f}us ({res.valid}/{res.evaluated} valid)")
    print("\nmapping tree (T = tile nodes, CO = explicit collectives):")
    print(tree_str(best.root))


if __name__ == "__main__":
    main()
