# COMET core — the paper's primary contribution: explicit-collective
# mapping representation + compound-operation cost model + map-space search.
from . import (batcheval, collectives, cost, hardware, ir, mapping, plan,
               search, validate, workload, yamlio)
from .batcheval import (BatchResult, ParetoArchive, Topology,
                        evaluate_specs_batch, evaluate_topology_grid,
                        pareto_merge, pareto_merge3)
from .hardware import Arch, cloud, edge, tpu_v5e
from .ir import MappingResult, MappingSpec, build_tree, evaluate_mapping
from .plan import ENGINE_VERSION, MappingPlan, PlanCache, get_plan_cache
from .search import SearchResult, search as map_search, search_many
from .workload import (CompoundOp, attention, flash_attention, gemm,
                       gemm_layernorm, gemm_softmax, ssd_chunk)

__all__ = [
    "Arch", "cloud", "edge", "tpu_v5e",
    "MappingResult", "MappingSpec", "build_tree", "evaluate_mapping",
    "SearchResult", "map_search", "search_many",
    "BatchResult", "ParetoArchive", "Topology", "evaluate_specs_batch",
    "evaluate_topology_grid", "pareto_merge", "pareto_merge3",
    "ENGINE_VERSION", "MappingPlan", "PlanCache", "get_plan_cache",
    "CompoundOp", "attention", "flash_attention", "gemm",
    "gemm_layernorm", "gemm_softmax", "ssd_chunk",
]
