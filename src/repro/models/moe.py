"""Mixture-of-Experts FFN with expert parallelism.

Design (DESIGN.md §6): activations are replicated across the ``model`` mesh
axis between layers (standard TP), so every model shard already holds all
local-batch tokens.  Experts are sharded over ``model`` (EP); each shard
gathers the tokens routed to *its* experts (capacity-bounded, GShard-style
dropping), runs the expert FFNs, scatters gate-weighted outputs back, and
the cross-shard combine is a single psum — the same collective TP already
pays for the FFN, i.e. **no token all-to-all is required**.  The psum is an
explicit collective planned/costed by COMET (core integration); the
alternative all-to-all dispatch is evaluated as a mapping variant in the
benchmarks.

Routing: softmax top-k (Qwen3-style, renormalized) or sigmoid+bias
(DeepSeek-V3 aux-free) per ``cfg.router_type``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .param import ParamSpec

F32 = jnp.float32

__all__ = ["moe_specs", "moe_apply", "moe_local", "router_weights"]


def moe_specs(cfg: ModelConfig, L: int) -> Dict[str, ParamSpec]:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    s = {
        "router": ParamSpec((L, d, E), ("layer", "embed", None), dtype="float32"),
        "wi": ParamSpec((L, E, d, f), ("layer", "experts", "embed", None), dtype=cfg.dtype),
        "wg": ParamSpec((L, E, d, f), ("layer", "experts", "embed", None), dtype=cfg.dtype),
        "wo": ParamSpec((L, E, f, d), ("layer", "experts", None, "embed"), dtype=cfg.dtype),
    }
    if cfg.router_type == "sigmoid":
        s["router_bias"] = ParamSpec((L, E), ("layer", None), init="zeros",
                                     dtype="float32")
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        s["shared_wi"] = ParamSpec((L, d, fs), ("layer", "embed", "ff"), dtype=cfg.dtype)
        s["shared_wg"] = ParamSpec((L, d, fs), ("layer", "embed", "ff"), dtype=cfg.dtype)
        s["shared_wo"] = ParamSpec((L, fs, d), ("layer", "ff", "embed"), dtype=cfg.dtype)
    return s


def router_weights(cfg: ModelConfig, p: Dict, x2d: jax.Array
                   ) -> Tuple[jax.Array, jax.Array]:
    """(gates (T, k) f32, idx (T, k) int32)."""
    logits = (x2d.astype(F32) @ p["router"].astype(F32))
    if cfg.router_type == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + p["router_bias"].astype(F32)       # bias only for routing
        _, idx = jax.lax.top_k(sel, cfg.top_k)
        gates = jnp.take_along_axis(scores, idx, axis=-1)
        gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)
    else:
        _, idx = jax.lax.top_k(logits, cfg.top_k)
        sel_logits = jnp.take_along_axis(logits, idx, axis=-1)
        gates = jax.nn.softmax(sel_logits, axis=-1)
    return gates, idx


def moe_local(cfg: ModelConfig, x2d: jax.Array, gates: jax.Array,
              idx: jax.Array, wi: jax.Array, wg: jax.Array, wo: jax.Array,
              e_offset: int, e_local: int, capacity: int) -> jax.Array:
    """Local-expert contribution for tokens x2d (T, d).

    wi/wg: (e_local, d, f); wo: (e_local, f, d).  Tokens routed to experts
    in [e_offset, e_offset + e_local) are gathered into (e_local, C, d)
    buffers (capacity-dropped), processed, and scatter-added back.
    Pure local computation — caller psums across expert shards.
    """
    T, d = x2d.shape
    k = idx.shape[1]
    flat_e = idx.reshape(-1)                              # (T*k,)
    flat_g = gates.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)

    # position of each assignment within its expert (stable by token order)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(cfg.n_experts))
    pos_sorted = jnp.arange(T * k) - seg_start[sorted_e]
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)

    local = (flat_e >= e_offset) & (flat_e < e_offset + e_local) & (pos < capacity)
    slot = jnp.where(local, (flat_e - e_offset) * capacity + pos, e_local * capacity)

    # dispatch: (e_local*C + 1 overflow row, d)
    buf = jnp.zeros((e_local * capacity + 1, d), x2d.dtype)
    buf = buf.at[slot].add(jnp.where(local[:, None], x2d[flat_t], 0))
    xe = buf[:-1].reshape(e_local, capacity, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) \
        * jnp.einsum("ecd,edf->ecf", xe, wi)
    ye = jnp.einsum("ecf,efd->ecd", h, wo).reshape(e_local * capacity, d)
    ye = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)], 0)

    # combine: gate-weighted scatter-add back to tokens
    contrib = ye[slot] * jnp.where(local, flat_g, 0.0)[:, None].astype(ye.dtype)
    out = jnp.zeros_like(x2d).at[flat_t].add(contrib)
    return out


def _shared_ffn(p: Dict, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p["shared_wg"]) * (x @ p["shared_wi"])) @ p["shared_wo"]


def moe_apply(cfg: ModelConfig, p: Dict, x: jax.Array,
              mesh: Optional[jax.sharding.Mesh] = None) -> jax.Array:
    """MoE FFN.  x: (B, S, d).  With a mesh, experts are sharded over the
    'model' axis via shard_map; without (CPU smoke tests) all experts are
    local."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    x2d = x.reshape(B * S, d)
    T = B * S

    def _cap(t_loc: int) -> int:
        # expected load + slack; small token populations (decode steps,
        # smoke tests) are dropless — production sizes use the float factor
        return max(int(t_loc * k / E * cfg.capacity_factor),
                   min(t_loc, 32))

    if mesh is None or "model" not in mesh.axis_names:
        capacity = _cap(T)
        gates, idx = router_weights(cfg, p, x2d)
        out = moe_local(cfg, x2d, gates, idx, p["wi"], p["wg"], p["wo"],
                        0, E, capacity)
    else:
        import math as _math
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        ep = mesh.shape["model"]
        assert E % ep == 0, (E, ep)
        e_local = E // ep
        # usable dp axes: token count must divide evenly for shard_map
        dp_axes = []
        dp_n = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names and T % (dp_n * mesh.shape[a]) == 0:
                dp_axes.append(a)
                dp_n *= mesh.shape[a]
        dp_axes = tuple(dp_axes)
        t_local = T // dp_n
        capacity = _cap(t_local)

        def shard_fn(x_l, router, rbias, wi, wg, wo):
            pp = {"router": router}
            if rbias is not None:
                pp["router_bias"] = rbias
            gates, idx = router_weights(cfg, pp, x_l)
            ei = jax.lax.axis_index("model") * e_local
            y = moe_local(cfg, x_l, gates, idx, wi, wg, wo, ei, e_local,
                          capacity)
            return jax.lax.psum(y, "model")

        rbias = p.get("router_bias")
        out = shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(dp_axes if dp_axes else None, None),
                      P(None, None),
                      (P(None) if rbias is not None else P(None)),
                      P("model", None, None), P("model", None, None),
                      P("model", None, None)),
            out_specs=P(dp_axes if dp_axes else None, None),
            check_rep=False,
        )(x2d, p["router"], rbias if rbias is not None else
          jnp.zeros((E,), F32), p["wi"], p["wg"], p["wo"])

    if cfg.n_shared_experts:
        out = out + _shared_ffn(p, x2d)
    return out.reshape(B, S, d)
