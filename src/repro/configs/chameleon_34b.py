"""chameleon-34b [vlm]: early-fusion backbone — plain decoder over a VQ
token vocabulary (image frontend stubbed per brief); qk-norm as in the
paper.  [arXiv:2405.09818]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="vlm",
        n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22016, vocab_size=65536, qk_norm=True,
        norm_type="rmsnorm", rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, name="chameleon-smoke")
