"""Tests for the roofline measurement tooling: HLO collective parser,
jaxpr structural FLOP counter, roofline terms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (HW, count_flops, parse_collectives,
                            roofline_terms, shape_bytes, structural_flops)


# ------------------------------------------------------------- HLO parsing

HLO_SAMPLE = """
HloModule test

%region_0.10 (a: bf16[8,128]) -> bf16[8,128] {
  %ar1 = bf16[8,128]{1,0} all-reduce(%a), replica_groups=[16,16]<=[256], to_apply=%add
  ROOT %r = bf16[8,128]{1,0} add(%ar1, %ar1)
}

ENTRY %main (p0: bf16[64,128]) -> bf16[64,128] {
  %ag = bf16[64,128]{1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %w = bf16[8,128]{1,0} while(%init), condition=%cond.1, body=%region_0.10
  %rs = bf16[16,128]{1,0} reduce-scatter(%ag), replica_groups=[2,128]<=[256], dimensions={0}
  ROOT %out = bf16[64,128]{1,0} copy(%ag)
}
"""


def test_shape_bytes():
    assert shape_bytes("bf16[8,128]") == 8 * 128 * 2
    assert shape_bytes("f32[4]") == 16
    assert shape_bytes("(bf16[2,2], f32[3])") == 8 + 12
    assert shape_bytes("pred[10]") == 10


def test_parse_collectives_in_loop_separation():
    st = parse_collectives(HLO_SAMPLE)
    d = st.to_dict()
    # the all-reduce lives in the while body -> in-loop bucket
    assert d["all-reduce"]["wire_bytes"] == 0
    assert d["all-reduce"]["wire_bytes_in_loop"] == pytest.approx(
        8 * 128 * 2 * 2 * 15 / 16)
    # top-level all-gather: group of 4 -> (G-1)/G
    assert d["all-gather"]["wire_bytes"] == pytest.approx(
        64 * 128 * 2 * 3 / 4)
    assert d["reduce-scatter"]["count"] == 1
    # scaling in-loop by trip count
    scaled = st.wire_bytes_scaled(10)
    unscaled = st.total_wire_bytes
    assert scaled > unscaled


def test_roofline_terms_bottleneck():
    r = roofline_terms(HW["peak_flops_bf16"], 0.0, 0.0)
    assert r["bottleneck"] == "compute" and r["t_compute_s"] == 1.0
    r = roofline_terms(0.0, HW["hbm_bw"] * 2, 0.0)
    assert r["bottleneck"] == "memory" and r["t_memory_s"] == 2.0
    r = roofline_terms(1.0, 1.0, HW["link_bw"] * 3)
    assert r["bottleneck"] == "collective"


# --------------------------------------------------------- jaxpr counting

def test_structural_flops_matmul():
    f = lambda a, b: a @ b
    A = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    B = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    assert structural_flops(f, A, B) == 2 * 64 * 32 * 16


def test_structural_flops_scan_multiplier():
    W = jax.ShapeDtypeStruct((8, 16, 16), jnp.float32)
    X = jax.ShapeDtypeStruct((4, 16), jnp.float32)

    def f(x, ws):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    # 8 scan iterations x (2*4*16*16)
    assert structural_flops(f, X, W) == 8 * 2 * 4 * 16 * 16


def test_structural_flops_remat_and_grad():
    W = jax.ShapeDtypeStruct((16, 16), jnp.float32)

    def loss(w):
        f = jax.checkpoint(lambda x: (x @ w).sum())
        return f(jnp.ones((4, 16)))

    n = structural_flops(jax.grad(loss), W)
    # fwd + dW backward matmul (dx is not needed for a constant input)
    assert n == 2 * 2 * 4 * 16 * 16


def test_structural_flops_batched_einsum():
    f = lambda a, b: jnp.einsum("bhqd,bhkd->bhqk", a, b)
    A = jax.ShapeDtypeStruct((2, 3, 8, 4), jnp.float32)
    B = jax.ShapeDtypeStruct((2, 3, 5, 4), jnp.float32)
    assert structural_flops(f, A, B) == 2 * (2 * 3) * 8 * 5 * 4


def test_structural_flops_model_consistency():
    """glm4 smoke: train-step structural FLOPs ≈ 8·N·D (full remat:
    fwd + recompute + 2x bwd) within 35% (attention/vocab overheads)."""
    from repro.configs import get_smoke_config
    from repro.models import Model
    from repro.train.optimizer import OptConfig, init_opt_state
    from repro.train.train_step import TrainState, make_train_step
    cfg = get_smoke_config("glm4-9b")
    model = Model(cfg)
    p = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    st = jax.eval_shape(lambda: TrainState(
        model.init(jax.random.PRNGKey(0)),
        init_opt_state(model.init(jax.random.PRNGKey(0)))))
    B, S = 8, 64
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    step = make_train_step(model, OptConfig())
    sf = structural_flops(step, st, batch)
    n_embodied = model.n_params()
    expect = 8.0 * n_embodied * B * S
    assert 0.5 * expect < sf < 2.5 * expect
