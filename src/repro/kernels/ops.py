"""Public jit'd wrappers for the Pallas kernels.

These are the entry points the model zoo uses.  On non-TPU backends the
kernels run in interpret mode (Pallas executes the kernel body in Python on
CPU), so the same code path is exercised everywhere; ``use_kernels=False``
falls back to the pure-jnp references (the default for training on CPU —
fast, and the kernels' custom_vjp recompute backward is reference-based
anyway).
"""
from __future__ import annotations

from typing import Optional

import jax

from . import ref
from .flash_attention import flash_attention
from .gemm_layernorm import gemm_layernorm, gemm_rmsnorm
from .gemm_softmax import gemm_softmax
from .ssd import ssd_scan

__all__ = [
    "mha", "fused_gemm_softmax", "fused_gemm_layernorm", "fused_gemm_rmsnorm",
    "ssd", "flash_attention", "gemm_softmax", "gemm_layernorm",
    "gemm_rmsnorm", "ssd_scan",
]


def mha(q: jax.Array, k: jax.Array, v: jax.Array, *,
        causal: bool = True, scale: Optional[float] = None,
        window: Optional[int] = None, use_kernel: bool = False) -> jax.Array:
    """Multi-head attention (GQA) — Pallas FlashAttention or jnp reference."""
    if use_kernel:
        return flash_attention(q, k, v, causal, scale, window)
    return ref.attention_ref(q, k, v, causal=causal, scale=scale,
                             window=window)


def fused_gemm_softmax(a, b, *, use_kernel: bool = False):
    if use_kernel:
        return gemm_softmax(a, b)
    return ref.gemm_softmax_ref(a, b)


def fused_gemm_layernorm(a, b, gamma, beta, *, eps: float = 1e-6,
                         use_kernel: bool = False):
    if use_kernel:
        return gemm_layernorm(a, b, gamma, beta, eps=eps)
    return ref.gemm_layernorm_ref(a, b, gamma, beta, eps=eps)


def fused_gemm_rmsnorm(a, b, gamma, *, eps: float = 1e-6,
                       use_kernel: bool = False):
    if use_kernel:
        return gemm_rmsnorm(a, b, gamma, eps=eps)
    return ref.gemm_rmsnorm_ref(a, b, gamma, eps=eps)


def ssd(xdt, dA, B, C, *, chunk: Optional[int] = None,
        use_kernel: bool = False):
    """Mamba-2 SSD chunk scan."""
    if use_kernel:
        return ssd_scan(xdt, dA, B, C, chunk)
    if chunk:
        return ref.ssd_chunked_ref(xdt, dA, B, C, chunk=chunk)
    return ref.ssd_ref(xdt, dA, B, C)
