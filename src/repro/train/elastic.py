"""Elastic scaling + straggler mitigation.

* :func:`remesh` — reshard a live pytree (or a restored checkpoint) onto a
  new mesh: the recovery path after losing (or gaining) data-parallel
  replicas.  Combined with checkpoint.restore_checkpoint(shardings=...)
  this gives checkpoint-elastic restarts; combined with device_put it
  gives in-job resharding.
* :class:`StragglerMonitor` — per-step wall-time EMA; flags steps slower
  than ``threshold``× the EMA (the training driver can then skip the
  all-reduce for that replica / re-dispatch data, and the monitor records
  the event for the ops log).
* :func:`shrink_mesh` — drop failed hosts' devices and rebuild the largest
  rectangular (data, model) mesh that still fits.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, List, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["remesh", "shrink_mesh", "StragglerMonitor"]


def remesh(tree, shardings) -> Any:
    """device_put every leaf onto the sharding from the (matching) pytree —
    works across meshes of different sizes/shapes."""
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(jax.device_get(x)), s),
        tree, shardings)


def shrink_mesh(failed_devices: int, *, model_parallel: int,
                devices: Optional[Sequence] = None) -> Mesh:
    """Rebuild a (data, model) mesh after losing ``failed_devices``:
    model-parallel width is preserved (TP shards are not divisible);
    whole data-parallel replicas are dropped."""
    devs = list(devices if devices is not None else jax.devices())
    usable = len(devs) - failed_devices
    data = usable // model_parallel
    if data < 1:
        raise RuntimeError(
            f"cannot keep model_parallel={model_parallel} with "
            f"{usable} devices")
    keep = devs[: data * model_parallel]
    arr = np.array(keep).reshape(data, model_parallel)
    return Mesh(arr, ("data", "model"))


@dataclass
class StragglerMonitor:
    threshold: float = 2.0
    decay: float = 0.9
    ema: Optional[float] = None
    events: List[dict] = field(default_factory=list)
    _t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self, step: int) -> bool:
        """Returns True if this step was a straggler."""
        dt = time.monotonic() - (self._t0 or time.monotonic())
        is_straggler = self.ema is not None and dt > self.threshold * self.ema
        if is_straggler:
            self.events.append({"step": step, "duration_s": dt,
                                "ema_s": self.ema})
        # EMA excludes straggler steps (they would poison the baseline)
        if not is_straggler:
            self.ema = dt if self.ema is None else \
                self.decay * self.ema + (1 - self.decay) * dt
        return is_straggler
