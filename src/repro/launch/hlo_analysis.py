"""Compat shim: the HLO collective parser moved to
:mod:`repro.analysis.hlo` (async ``-start``/``-done`` aware, knows
``ragged-all-to-all``).  Import from ``repro.analysis`` in new code."""
import warnings

from repro.analysis.hlo import (CollectiveStats, HW,  # noqa: F401
                                parse_collectives, roofline_terms,
                                shape_bytes, shape_elements_bytes)

warnings.warn(
    "repro.launch.hlo_analysis is a deprecated compat shim; import from "
    "repro.analysis (or repro.analysis.hlo) instead",
    DeprecationWarning, stacklevel=2)

__all__ = ["CollectiveStats", "parse_collectives", "shape_bytes",
           "shape_elements_bytes", "HW", "roofline_terms"]
