"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Each function is the mathematically transparent version of the fused
kernel; tests sweep shapes/dtypes and assert allclose between the kernel
(interpret=True on CPU) and these references.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "attention_ref",
    "gemm_softmax_ref",
    "gemm_layernorm_ref",
    "gemm_rmsnorm_ref",
    "ssd_ref",
    "ssd_chunked_ref",
]


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  scale: Optional[float] = None,
                  window: Optional[int] = None) -> jax.Array:
    """Multi-head attention with GQA.

    q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D); Hq % Hkv == 0.
    ``window``: optional sliding-window width (causal only).
    Returns (B, Hq, Sq, D) in q.dtype; math in f32.
    """
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    if causal or window is not None:
        q_pos = jnp.arange(Sq)[:, None] + (Skv - Sq)   # align last tokens
        k_pos = jnp.arange(Skv)[None, :]
        mask = jnp.ones((Sq, Skv), dtype=bool)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)   # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def gemm_softmax_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """softmax(a @ b) over the last axis; math in f32."""
    c = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
    return jax.nn.softmax(c, axis=-1).astype(a.dtype)


def gemm_layernorm_ref(a: jax.Array, b: jax.Array, gamma: jax.Array,
                       beta: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    """LayerNorm(a @ b) * gamma + beta over the last axis; math in f32."""
    c = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
    mu = c.mean(axis=-1, keepdims=True)
    var = ((c - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (c - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(a.dtype)


def gemm_rmsnorm_ref(a: jax.Array, b: jax.Array, gamma: jax.Array, *,
                     eps: float = 1e-6) -> jax.Array:
    """RMSNorm(a @ b) * gamma over the last axis; math in f32."""
    c = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
    ms = (c ** 2).mean(axis=-1, keepdims=True)
    return (c * jax.lax.rsqrt(ms + eps) * gamma.astype(jnp.float32)).astype(a.dtype)


def ssd_ref(xdt: jax.Array, dA: jax.Array, B: jax.Array, C: jax.Array) -> jax.Array:
    """Naive SSD (Mamba-2 SSM) recurrence oracle.

    xdt: (BH, S, P)   — dt-weighted inputs (x * dt)
    dA:  (BH, S)      — per-step log-decay (A * dt, A < 0)
    B:   (BH, S, N)   — input projections
    C:   (BH, S, N)   — output projections
    returns y: (BH, S, P);  h_t = exp(dA_t) h_{t-1} + B_t xdt_t^T;
    y_t = C_t @ h_t.  Math in f32.
    """
    BH, S, P = xdt.shape
    N = B.shape[-1]
    xf, df = xdt.astype(jnp.float32), dA.astype(jnp.float32)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)

    def step(h, inp):
        x_t, da_t, b_t, c_t = inp
        h = jnp.exp(da_t)[:, None, None] * h + b_t[:, :, None] * x_t[:, None, :]
        y = jnp.einsum("bn,bnp->bp", c_t, h)
        return h, y

    h0 = jnp.zeros((BH, N, P), jnp.float32)
    xs = (jnp.swapaxes(xf, 0, 1), jnp.swapaxes(df, 0, 1),
          jnp.swapaxes(Bf, 0, 1), jnp.swapaxes(Cf, 0, 1))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.swapaxes(ys, 0, 1).astype(xdt.dtype)


def _segsum(dA: jax.Array) -> jax.Array:
    """L[i, j] = sum_{k=j+1..i} dA_k for i >= j else -inf (log decay matrix)."""
    S = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # sum_{j+1..i}
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    return jnp.where(i >= j, diff, -jnp.inf)


def ssd_chunked_ref(xdt: jax.Array, dA: jax.Array, B: jax.Array, C: jax.Array,
                    *, chunk: int = 64) -> jax.Array:
    """Chunked SSD (state-space duality) oracle — the blocked algorithm the
    Pallas kernel implements: intra-chunk 'attention-like' term + inter-chunk
    state carry.  Numerically equivalent to :func:`ssd_ref`."""
    BH, S, P = xdt.shape
    N = B.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    xf = xdt.astype(jnp.float32).reshape(BH, nc, chunk, P)
    df = dA.astype(jnp.float32).reshape(BH, nc, chunk)
    Bf = B.astype(jnp.float32).reshape(BH, nc, chunk, N)
    Cf = C.astype(jnp.float32).reshape(BH, nc, chunk, N)

    cs = jnp.cumsum(df, axis=-1)                       # (BH, nc, c)
    L = jnp.exp(_segsum(df))                           # (BH, nc, c, c)
    # intra-chunk
    CB = jnp.einsum("bzin,bzjn->bzij", Cf, Bf) * L
    y_intra = jnp.einsum("bzij,bzjp->bzip", CB, xf)
    # chunk-final states
    decay_in = jnp.exp(cs[..., -1:] - cs)              # (BH, nc, c)
    chunk_state = jnp.einsum("bzcn,bzc,bzcp->bznp", Bf, decay_in, xf)
    # carry states across chunks
    total = jnp.exp(cs[..., -1])                       # (BH, nc)

    def carry(h, inp):
        st, tt = inp
        out = h
        h = tt[:, None, None] * h + st
        return h, out

    h0 = jnp.zeros((BH, N, P), jnp.float32)
    _, prev = jax.lax.scan(
        carry, h0, (jnp.swapaxes(chunk_state, 0, 1), jnp.swapaxes(total, 0, 1)))
    prev = jnp.swapaxes(prev, 0, 1)                    # (BH, nc, N, P) state before chunk
    y_inter = jnp.einsum("bzcn,bznp,bzc->bzcp", Cf, prev, jnp.exp(cs))
    y = (y_intra + y_inter).reshape(BH, S, P)
    return y.astype(xdt.dtype)
