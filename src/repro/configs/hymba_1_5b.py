"""hymba-1.5b [hybrid]: parallel attention + SSM heads per layer; sliding
window (1024) on all layers — the 3 global-attention layers of the source
model are approximated by the window to keep the scanned stack homogeneous
(DESIGN.md §5); meta-tokens omitted.  [arXiv:2411.13676]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab_size=32001, window=1024,
        ssm_state=16, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1,
        conv_kernel=4, rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, window=32, ssm_state=8, ssm_headdim=16,
        name="hymba-smoke")
