"""Serving driver: batched prefill+decode with the ServeEngine.

Example (CPU, reduced config):
    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \
        --requests 12 --batch 4 --prompt-len 32 --max-new 16

Plan-cache wiring (the MappingPlan subsystem, ``repro.core.plan``):
``--plan-cache DIR`` points the engine's plan store somewhere explicit
(equivalent to ``REPRO_PLAN_CACHE=DIR``); ``--plan-bundle PATH`` imports
a bundle exported by ``benchmarks/paper_tables.export_plans`` before the
engine starts, so startup warmup is pure cache hits; ``--no-plan-warmup``
skips the startup warmup sweep entirely; ``--plan-gc`` runs the store's
garbage collection (age expiry + LRU eviction + vacuum) before startup —
the knob a fleet cron job would use.  The output JSON reports which
store backend actually served the run (``plan_store``): ``sqlite`` on a
healthy host, ``json`` or ``memory`` after degradations (see
``repro.core.planstore``).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs.registry import get_config, get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--mesh", choices=["none", "host"], default="none")
    ap.add_argument("--plan-cache", default=None, metavar="DIR",
                    help="mapping-plan store directory "
                         "(default: $REPRO_PLAN_CACHE or ~/.cache/repro-plans)")
    ap.add_argument("--plan-bundle", default=None, metavar="PATH",
                    help="import a plan bundle (paper_tables.export_plans) "
                         "into the store before starting the engine")
    ap.add_argument("--no-plan-warmup", action="store_true",
                    help="skip the startup plan-warmup sweep")
    ap.add_argument("--plan-gc", action="store_true",
                    help="garbage-collect the plan store (age expiry + "
                         "LRU eviction + vacuum) before starting")
    args = ap.parse_args()

    if args.plan_cache:
        os.environ["REPRO_PLAN_CACHE"] = args.plan_cache
    from repro.core.plan import get_plan_cache
    imported = 0
    gc_out = None
    if args.plan_gc:
        gc_out = get_plan_cache().gc()
    if args.plan_bundle:
        imported = get_plan_cache().import_bundle(args.plan_bundle)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_host_mesh() if args.mesh == "host" else None

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    eng = ServeEngine(model, params, batch_size=args.batch,
                      cache_len=args.cache_len, prompt_len=args.prompt_len,
                      mesh=mesh, plan_warmup=not args.no_plan_warmup)
    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.output) for r in done)
    store = get_plan_cache().store_stats()["store"]
    print(json.dumps({
        "requests": len(done),
        "completed": sum(r.done or len(r.output) > 0 for r in done),
        "tokens": n_tok,
        "wall_s": round(dt, 2),
        "tok_per_s": round(n_tok / dt, 1),
        "decode_steps": eng.stats["decode_steps"],
        "prefill_calls": eng.stats["prefill_calls"],
        "plan_bundle_imported": imported,
        "plan_warmup_solved": eng.stats.get("plan_warmup_solved", 0),
        "plan_warmup_hits": eng.stats.get("plan_warmup_hits", 0),
        "plan_store": store.get("backend"),
        "plan_store_plans": store.get("plans", 0),
        "plan_gc": gc_out,
    }))


if __name__ == "__main__":
    main()
