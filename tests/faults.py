"""Reusable fault-injection harness for the plan-store fault matrix.

Each helper injects one storage fault — at the layer where the real
fault would occur — and restores the world on exit.  The harness is
deliberately framework-free (plain context managers, no pytest
dependency) so the CI fault job, the tests and ad-hoc debugging can all
drive the same injections.

Injection points
----------------
* ``enospc_writes()``       every durable write fails: sqlite raises
                            "database or disk is full", ``os.replace``
                            raises ``ENOSPC`` (hits the JSON rung and
                            quarantine moves too)
* ``busy_storm(n)``         the next ``n`` sqlite write statements raise
                            SQLITE_BUSY ("database is locked") before
                            the store's retry loop sees a success
* ``readonly_open()``       opening the database read-write raises
                            "attempt to write a readonly database"
                            (container runs as root, so chmod cannot
                            produce this — it must be injected)
* ``no_sqlite()``           the sqlite3 module is "missing": the ladder
                            must start on the JSON rung
* ``corrupt_db(root)``      scribble over the database header — a torn
                            write that destroyed the file
* ``torn_file(path)``       truncate any file to a fraction of its size
                            (crash mid-write; also used for torn shm
                            segments)
* ``faulty_measure_fn(fn)`` wrap a ``repro.calibrate`` measure_fn so
                            chosen calls raise, return NaN, or return
                            absurdly-fast timings (the sweep's fault
                            matrix)
* ``spawn_resolver(root)``  a real subprocess that resolves the
                            canonical plan against ``root`` and prints
                            its JSON — for multi-process writer races
* ``spawn_killed_writer(root)``  a subprocess that opens the database,
                            starts an uncommitted write transaction and
                            SIGKILLs itself — the WAL must roll it back

All sqlite injections patch ``repro.core.planstore`` attributes, so they
only affect backends *opened inside* the context — construct the
``PlanCache``/``PlanStore`` under the ``with`` block.
"""
import contextlib
import errno
import os
import subprocess
import sys
from pathlib import Path

from repro.core import planstore

try:
    import sqlite3
except ImportError:                      # pragma: no cover
    sqlite3 = None

#: source root, for subprocess PYTHONPATH (…/src/repro/core/planstore.py)
SRC_DIR = Path(planstore.__file__).resolve().parents[2]

_MUTATING = ("INSERT", "UPDATE", "DELETE", "REPLACE")


def _is_mutation(sql: str) -> bool:
    return sql.lstrip().upper().startswith(_MUTATING)


class FlakyConn:
    """Proxy over a real sqlite connection that fails selected
    ``execute`` calls with a chosen exception, then behaves normally."""

    def __init__(self, conn, state):
        self._real = conn
        self._state = state              # {"left": n, "exc": factory}

    def execute(self, sql, *args):
        if self._state["left"] > 0 and _is_mutation(sql):
            self._state["left"] -= 1
            raise self._state["exc"]()
        return self._real.execute(sql, *args)

    def __getattr__(self, name):
        return getattr(self._real, name)


@contextlib.contextmanager
def enospc_writes():
    """Every durable write path reports a full disk: sqlite mutations
    raise "database or disk is full", ``os.replace`` raises ENOSPC."""
    real_write = planstore._SqliteBackend._write
    real_replace = os.replace

    def fail_sql(self, sql, params=()):
        if _is_mutation(sql):
            raise sqlite3.OperationalError("database or disk is full")
        return real_write(self, sql, params)

    def fail_replace(src, dst, *a, **kw):
        raise OSError(errno.ENOSPC, "No space left on device", str(dst))

    planstore._SqliteBackend._write = fail_sql
    os.replace = fail_replace
    try:
        yield
    finally:
        planstore._SqliteBackend._write = real_write
        os.replace = real_replace


@contextlib.contextmanager
def busy_storm(n):
    """The next ``n`` sqlite write statements (on connections opened
    inside the context) raise SQLITE_BUSY.  Yields the mutable state
    dict: ``state["left"]`` is the number of failures still pending, so
    a test can drain or extend the storm mid-flight."""
    state = {"left": n,
             "exc": lambda: sqlite3.OperationalError("database is locked")}
    real_open = planstore._SqliteBackend._open_rw

    def open_flaky(self):
        return FlakyConn(real_open(self), state)

    planstore._SqliteBackend._open_rw = open_flaky
    try:
        yield state
    finally:
        planstore._SqliteBackend._open_rw = real_open


@contextlib.contextmanager
def readonly_open():
    """Read-write opens of the database fail as read-only media would.
    Only affects stores opened inside the context; an existing database
    file is then served through the store's read-only fallback."""
    real_open = planstore._SqliteBackend._open_rw

    def fail_open(self):
        raise sqlite3.OperationalError(
            "attempt to write a readonly database")

    planstore._SqliteBackend._open_rw = fail_open
    try:
        yield
    finally:
        planstore._SqliteBackend._open_rw = real_open


@contextlib.contextmanager
def no_sqlite():
    """Pretend the sqlite3 module is unavailable (exotic Python builds):
    the ladder must start on the legacy JSON rung."""
    real = planstore._SQLITE_OK
    planstore._SQLITE_OK = False
    try:
        yield
    finally:
        planstore._SQLITE_OK = real


def corrupt_db(root) -> Path:
    """Destroy the database header in place (torn write over the file)
    and drop any sidecars, so the next open sees garbage."""
    db = Path(root) / planstore.DB_FILENAME
    data = db.read_bytes()
    db.write_bytes(b"\x00torn-write-garbage\x00" + data[24:])
    for suffix in ("-wal", "-shm"):
        try:
            os.unlink(str(db) + suffix)
        except OSError:
            pass
    return db


def torn_file(path, keep=0.5) -> int:
    """Truncate ``path`` to ``keep`` of its size — a crash mid-write.
    Returns the new size."""
    path = Path(path)
    size = path.stat().st_size
    new = int(size * keep)
    with open(path, "r+b") as f:
        f.truncate(new)
    return new


def faulty_measure_fn(inner, *, fail_at, mode="raise"):
    """Wrap a calibration ``measure_fn`` so calls ``fail_at`` (a 0-based
    call index, or a set of them) misbehave: ``mode='raise'`` raises a
    RuntimeError, ``'nan'`` returns NaN, ``'tiny'`` returns a
    near-zero timing (the non-monotone fault — a 16 MiB collective
    "finishing" in a nanosecond).  Everything else passes through to
    ``inner``; the calibration sweep must degrade, never crash."""
    fail = {fail_at} if isinstance(fail_at, int) else set(fail_at)
    calls = {"n": -1}

    def measure(col_type, dv_bytes, participants):
        calls["n"] += 1
        if calls["n"] in fail:
            if mode == "raise":
                raise RuntimeError(
                    f"injected measurement fault at call {calls['n']}")
            if mode == "nan":
                return float("nan")
            if mode == "tiny":
                return 1e-12
            raise ValueError(f"unknown fault mode {mode!r}")
        return inner(col_type, dv_bytes, participants)

    return measure


# ------------------------------------------------------------ subprocesses

#: resolves the canonical (gemm_softmax 256x1024x64, edge) plan against
#: the store root in argv[1] and prints the plan JSON — concurrent copies
#: of this script are the multi-process concurrent-writer fault
RESOLVER_SCRIPT = r"""
import json, sys
from repro.core.hardware import edge
from repro.core.plan import PlanCache
from repro.core.workload import gemm_softmax

cache = PlanCache(sys.argv[1])
plan = cache.resolve(gemm_softmax(256, 1024, 64), edge())
cache.store.close()
print(json.dumps(plan.to_json(), sort_keys=True))
"""

#: opens the store database directly, starts an uncommitted write
#: transaction holding the write lock, then SIGKILLs itself — WAL
#: recovery in the next reader must roll the transaction back
KILLED_WRITER_SCRIPT = r"""
import os, signal, sqlite3, sys

db = sqlite3.connect(os.path.join(sys.argv[1], "plans.sqlite"))
db.execute("PRAGMA journal_mode = WAL")
db.execute("BEGIN IMMEDIATE")
db.execute(
    "INSERT OR REPLACE INTO plans (arch_sig, op_sig, engine_version, "
    "kw_sig, payload, size_bytes, sweep_id, created_s, last_hit_s, hits) "
    "VALUES ('deadbeefdeadbeef', 'deadbeefdeadbeef', 999, "
    "'deadbeefdeadbeef', '{torn', 5, 'killed-writer', 0, 0, 0)")
sys.stdout.write("armed\n")
sys.stdout.flush()
os.kill(os.getpid(), signal.SIGKILL)
"""


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC_DIR) + os.pathsep + env.get("PYTHONPATH", "")
    return env


def spawn_resolver(root) -> subprocess.Popen:
    """Start (not wait for) a subprocess resolving the canonical plan
    against ``root``; its stdout is one JSON line."""
    return subprocess.Popen(
        [sys.executable, "-c", RESOLVER_SCRIPT, str(root)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        text=True, env=_env())


def spawn_killed_writer(root) -> subprocess.CompletedProcess:
    """Run a writer that SIGKILLs itself mid-transaction (waits for the
    kill; the schema must already exist in ``root``)."""
    return subprocess.run(
        [sys.executable, "-c", KILLED_WRITER_SCRIPT, str(root)],
        capture_output=True, text=True, env=_env(), timeout=120)
