"""CLI: ``python -m repro.calibrate [--backend=cpu|synthetic] ...``.

Runs one calibration pass (sweep -> fit -> gate -> persist) and prints a
summary.  ``--backend=cpu`` times real ``jax.lax`` collectives on the
forced 8-virtual-device CPU mesh; ``--backend=synthetic`` generates
timings from the reference preset's own NoC constants (optionally
jittered) so the whole loop runs without touching jax — the CI fit gate.

The persisted ``calibrated_noc.json`` lands in the plan-store root
(``$REPRO_PLAN_CACHE`` / ``~/.cache/repro-plans``, or ``--store``).
Re-running with matching provenance reuses it: ``fits_solved: 0``, file
untouched, bit-identical store.  Exit status: 0 on a passing gate,
1 when the fitted model misses its own sweep by more than
``--gate-median``, 2 when the sweep degrades to a degenerate fit.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# XLA only reads XLA_FLAGS at backend initialization — nothing has
# triggered that yet even though `-m` imported the package __init__ —
# so setting the forced device count here still takes effect.
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

from repro.core import hardware  # noqa: E402

from .driver import calibrate_once  # noqa: E402
from .harness import (SweepConfig, _replace_mesh, jax_measure_fn,  # noqa: E402
                      synthetic_measure_fn)

PRESETS = {"edge": hardware.edge, "cloud": hardware.cloud,
           "tpu_v5e": hardware.tpu_v5e, "tileflow_like": hardware.tileflow_like}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.calibrate",
        description="Measure collectives, fit NoCParams, persist with "
                    "provenance")
    ap.add_argument("--backend", choices=("cpu", "synthetic"),
                    default="cpu",
                    help="cpu: time real jax.lax collectives on the forced "
                         "8-virtual-device mesh; synthetic: analytic "
                         "generator from the reference preset (no jax)")
    ap.add_argument("--arch", choices=sorted(PRESETS), default="tpu_v5e",
                    help="preset whose cluster NoC seeds the fit's "
                         "reference (channel width, enqueue split)")
    ap.add_argument("--store", default=None,
                    help="store root (default: plan-store resolution)")
    ap.add_argument("--force", action="store_true",
                    help="re-sweep even when a matching calibration exists")
    ap.add_argument("--min-bytes", type=int, default=None)
    ap.add_argument("--max-bytes", type=int, default=None)
    ap.add_argument("--sizes", type=int, default=None,
                    help="log-spaced sizes per collective type")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--warmup", type=int, default=None)
    ap.add_argument("--jitter", type=float, default=0.0,
                    help="synthetic backend: multiplicative noise bound")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--gate-median", type=float, default=0.6,
                    help="max median |relative error| of the fitted model "
                         "on its own sweep")
    ap.add_argument("--json", action="store_true",
                    help="print the summary as one JSON document")
    args = ap.parse_args(argv)

    cfg_kwargs = {k: v for k, v in
                  (("min_bytes", args.min_bytes),
                   ("max_bytes", args.max_bytes),
                   ("n_sizes", args.sizes),
                   ("iters", args.iters),
                   ("warmup", args.warmup)) if v is not None}
    config = SweepConfig(**cfg_kwargs) if cfg_kwargs else None

    reference = PRESETS[args.arch]().cluster_noc
    if args.backend == "cpu":
        import jax
        n = len(jax.devices())
        reference = _replace_mesh(reference, (1, n))
        measure_fn = jax_measure_fn()
        participants = n
        jax_version = jax.__version__
    else:
        reference = _replace_mesh(reference, (1, 8))
        measure_fn = synthetic_measure_fn(reference, jitter=args.jitter,
                                          seed=args.seed)
        participants = [2, 4, 8]
        jax_version = "synthetic"

    summary = calibrate_once(
        measure_fn, reference, participants,
        backend=args.backend, jax_version=jax_version,
        store=args.store, force=args.force, config=config,
        gate_median=args.gate_median)

    if args.json:
        print(json.dumps(summary, indent=2, sort_keys=True))
    else:
        p = summary["params"]
        print(f"backend={summary['backend']} reused={summary['reused']} "
              f"fits_solved={summary['fits_solved']}")
        print(f"points={summary['n_points']} "
              f"dropped={summary.get('n_dropped', 0)} "
              f"degenerate={summary['degenerate']}")
        print(f"fitted: channel_bandwidth={p['channel_bandwidth']:.4g} B/s  "
              f"t_router={p['t_router']:.4g} s  t_enq={p['t_enq']:.4g} s")
        print(f"rel err: median={summary['median_rel_err']:.3f} "
              f"max={summary['max_rel_err']:.3f} "
              f"(gate median<={summary['gate_median']}) "
              f"-> {'OK' if summary['gate_ok'] else 'FAIL'}")
        print(f"store: {summary['path']}")

    if summary["degenerate"]:
        return 2
    return 0 if summary["gate_ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
