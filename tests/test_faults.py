"""Plan-store fault matrix (tests/faults.py harness).

The durability contract under injected faults — torn writes, ENOSPC,
read-only stores, corrupt databases, SQLITE_BUSY storms, killed writers,
multi-process races: ``PlanCache.resolve``, ``warmup``, the autotuner
and ``ServeEngine`` startup never crash, never serve a wrong plan (every
resolved plan is bit-identical to a clean-store run), and each distinct
degradation cause warns at most once per process.

Also pins the per-request runaway guards in ``ServeEngine.run``
(deadline / token-cap): one non-terminating request must not hold a
decode slot until the engine-global ``max_steps``.
"""
import json
import warnings

import pytest

import faults
from repro.core import plan as plan_mod
from repro.core import planstore
from repro.core.hardware import edge
from repro.core.plan import PlanCache
from repro.core.workload import gemm_softmax

CO = lambda: gemm_softmax(256, 1024, 64)

_CLEAN_PLAN_JSON = {}


@pytest.fixture(autouse=True)
def _fresh_warn_state():
    """Each test gets a clean warn-once registry (the production
    semantics are per-process; tests assert per-cause counts)."""
    planstore._reset_warned()
    yield
    planstore._reset_warned()


def _plan_warnings(rec):
    """The warnings our storage stack raised (JAX et al. are noisy)."""
    return [w for w in rec
            if "PlanStore" in str(w.message) or "PlanCache" in str(w.message)]


def _clean_plan_json(tmp_path):
    """The canonical plan solved once against a pristine store — the
    bit-identity reference every faulted resolve is compared against."""
    if "plan" not in _CLEAN_PLAN_JSON:
        cache = PlanCache(str(tmp_path / "clean-reference"))
        plan = cache.resolve(CO(), edge())
        cache.store.close()
        _CLEAN_PLAN_JSON["plan"] = json.dumps(plan.to_json(), sort_keys=True)
    return _CLEAN_PLAN_JSON["plan"]


def _as_json(plan):
    return json.dumps(plan.to_json(), sort_keys=True)


# --------------------------------------------------------------- ENOSPC


def test_enospc_resolves_bit_identical_with_one_warning(tmp_path):
    """Satellite: a full disk costs durability, never correctness — and
    warns exactly once, not once per write."""
    ref = _clean_plan_json(tmp_path)
    with faults.enospc_writes():
        cache = PlanCache(str(tmp_path / "plans"))
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            plans = [cache.resolve(CO(), edge()) for _ in range(3)]
            # distinct shapes -> distinct failing writes, still one warning
            cache.resolve(gemm_softmax(128, 512, 64), edge())
            cache.resolve(gemm_softmax(512, 512, 32), edge())
        assert all(_as_json(p) == ref for p in plans)
        assert len(_plan_warnings(rec)) == 1
        assert "memory" in str(_plan_warnings(rec)[0].message)
    # the one-shot flag outlives the fault: writes stay off, still silent
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert _as_json(cache.resolve(CO(), edge())) == ref
        cache.resolve(gemm_softmax(256, 256, 128), edge())
    assert not _plan_warnings(rec)
    assert cache.store.stats()["write_ok"] is False


def test_enospc_during_warmup_never_crashes(tmp_path):
    ref = _clean_plan_json(tmp_path)
    jobs = [(CO(), edge()), (gemm_softmax(128, 512, 64), edge())]
    with faults.enospc_writes():
        cache = PlanCache(str(tmp_path / "plans"))
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            stats = cache.warmup(jobs, executor="serial")
        assert stats["solved"] == 2
        assert len(_plan_warnings(rec)) <= 1
        assert _as_json(cache.lookup(CO(), edge())) == ref


def test_enospc_during_autotune_matches_clean_run(tmp_path, monkeypatch):
    from repro.kernels.autotune import attention_blocks

    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "clean"))
    with plan_mod._CACHES_LOCK:
        plan_mod._CACHES.clear()
    clean = attention_blocks(1024, 1024, 64)
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "faulted"))
    with plan_mod._CACHES_LOCK:
        plan_mod._CACHES.clear()
    with faults.enospc_writes():
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            faulted = attention_blocks(1024, 1024, 64)
    assert faulted == clean
    assert len(_plan_warnings(rec)) <= 1


# --------------------------------------------------------- SQLITE_BUSY


def test_busy_storm_below_retry_budget_is_absorbed_silently(tmp_path):
    ref = _clean_plan_json(tmp_path)
    with faults.busy_storm(planstore.BUSY_RETRIES - 2):
        cache = PlanCache(str(tmp_path / "plans"))
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            plan = cache.resolve(CO(), edge())
    assert _as_json(plan) == ref
    assert not _plan_warnings(rec)                 # retries absorbed it
    cache.store.close()
    fresh = PlanCache(str(tmp_path / "plans"))     # and the write landed
    assert _as_json(fresh.lookup(CO(), edge())) == ref


def test_busy_storm_exhausted_skips_write_keeps_rung(tmp_path):
    ref = _clean_plan_json(tmp_path)
    with faults.busy_storm(10 * planstore.BUSY_RETRIES) as storm:
        cache = PlanCache(str(tmp_path / "plans"))
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            plan = cache.resolve(CO(), edge())
        assert _as_json(plan) == ref
        pw = _plan_warnings(rec)
        assert len(pw) == 1 and "busy" in str(pw[0].message)
        storm["left"] = 0                          # the storm drains...
        cache.resolve(gemm_softmax(128, 512, 64), edge())
        cache.store.close()
    fresh = PlanCache(str(tmp_path / "plans"))
    # ...and later writes succeeded on the SAME rung (no demotion)
    assert fresh.lookup(gemm_softmax(128, 512, 64), edge()) is not None
    assert fresh.store.backend == "sqlite"


# ------------------------------------------------------- corrupt database


def test_corrupt_db_quarantined_and_resolves_bit_identical(tmp_path):
    ref = _clean_plan_json(tmp_path)
    root = tmp_path / "plans"
    cache = PlanCache(str(root))
    cache.resolve(CO(), edge())
    cache.store.close()
    faults.corrupt_db(root)
    fresh = PlanCache(str(root))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        plan = fresh.resolve(CO(), edge())
    assert _as_json(plan) == ref
    pw = _plan_warnings(rec)
    assert len(pw) == 1 and "quarantined" in str(pw[0].message)
    assert (root / planstore.CORRUPT_DIRNAME / planstore.DB_FILENAME).exists()
    fresh.store.close()
    # the recreated database is healthy and holds the re-solve
    third = PlanCache(str(root))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert _as_json(third.lookup(CO(), edge())) == ref
    assert not _plan_warnings(rec)


def test_torn_json_file_quarantined(tmp_path):
    """Satellite: a corrupt legacy JSON plan is moved to ``corrupt/``
    (not deleted, not re-parsed forever) and the plan re-solves."""
    ref = _clean_plan_json(tmp_path)
    root = tmp_path / "plans"
    with faults.no_sqlite():
        cache = PlanCache(str(root))
        cache.resolve(CO(), edge())
        victim = next(root.glob("*.json"))
        faults.torn_file(victim, keep=0.4)
        fresh = PlanCache(str(root))
        with pytest.warns(RuntimeWarning, match="corrupted stored plan"):
            plan = fresh.resolve(CO(), edge())
        assert _as_json(plan) == ref
        assert (root / planstore.CORRUPT_DIRNAME / victim.name).exists()
        # quarantine means the next cold process reads the re-solve silently
        third = PlanCache(str(root))
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            assert _as_json(third.lookup(CO(), edge())) == ref
        assert not _plan_warnings(rec)


# ----------------------------------------------------------- read-only


def test_readonly_store_serves_reads_with_one_warning(tmp_path):
    ref = _clean_plan_json(tmp_path)
    root = tmp_path / "plans"
    cache = PlanCache(str(root))
    cache.resolve(CO(), edge())
    cache.store.close()
    with faults.readonly_open():
        ro = PlanCache(str(root))
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            # stored plan is served (read path), new plan solves into
            # memory (write path silently off after the open warning)
            assert _as_json(ro.resolve(CO(), edge())) == ref
            novel = ro.resolve(gemm_softmax(128, 512, 64), edge())
            assert ro.resolve(gemm_softmax(128, 512, 64), edge()) is novel
        pw = _plan_warnings(rec)
        assert len(pw) == 1 and "read-only" in str(pw[0].message)
        assert ro.store.stats()["read_only"] is True


def test_no_sqlite_falls_back_to_json_then_migrates(tmp_path):
    """sqlite3 missing -> JSON rung; once sqlite is back, the legacy
    files auto-migrate into the database with zero lost plans."""
    ref = _clean_plan_json(tmp_path)
    root = tmp_path / "plans"
    with faults.no_sqlite():
        cache = PlanCache(str(root))
        assert cache.store.backend == "json"
        cache.resolve(CO(), edge())
        assert list(root.glob("*.json"))
    fresh = PlanCache(str(root))
    with pytest.warns(RuntimeWarning, match="migrated 1 legacy"):
        assert _as_json(fresh.lookup(CO(), edge())) == ref
    assert not list(root.glob("*.json"))           # moved aside, not lost
    assert list((root / planstore.MIGRATED_DIRNAME).glob("*.json"))
    assert fresh.store.stats()["by_sweep"].get("legacy-json") == 1


# ------------------------------------------------- killed / racing writers


def test_killed_writer_mid_transaction_rolls_back(tmp_path):
    """SIGKILL mid-write-transaction: WAL recovery discards the torn
    transaction; the store stays consistent and silent."""
    import sqlite3

    ref = _clean_plan_json(tmp_path)
    root = tmp_path / "plans"
    cache = PlanCache(str(root))
    cache.resolve(CO(), edge())
    cache.store.close()
    proc = faults.spawn_killed_writer(root)
    assert proc.returncode == -9 and "armed" in proc.stdout
    fresh = PlanCache(str(root))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert _as_json(fresh.lookup(CO(), edge())) == ref
    assert not _plan_warnings(rec)
    assert not [k for k in fresh.store.keys() if k[2] == 999]  # rolled back
    fresh.store.close()
    db = sqlite3.connect(str(root / planstore.DB_FILENAME))
    try:
        assert db.execute("PRAGMA integrity_check").fetchone()[0] == "ok"
    finally:
        db.close()


def test_concurrent_process_writers_bit_identical(tmp_path):
    """Three real processes race the same key through WAL: every writer
    prints the same plan, the survivor database is intact, no litter."""
    import sqlite3

    ref = _clean_plan_json(tmp_path)
    root = tmp_path / "plans"
    procs = [faults.spawn_resolver(root) for _ in range(3)]
    outs = [p.communicate(timeout=300) for p in procs]
    for p, (out, err) in zip(procs, outs):
        assert p.returncode == 0, err
        assert out.strip() == ref
    fresh = PlanCache(str(root))
    assert _as_json(fresh.lookup(CO(), edge())) == ref
    fresh.store.close()
    db = sqlite3.connect(str(root / planstore.DB_FILENAME))
    try:
        assert db.execute("PRAGMA integrity_check").fetchone()[0] == "ok"
    finally:
        db.close()
    assert not list(root.glob("*.tmp"))
    assert not list(root.glob("*-wal")) and not list(root.glob("*-shm"))


# ------------------------------------------------ ServeEngine under faults


@pytest.fixture(scope="module")
def smoke_engine_parts():
    import jax

    from repro.configs import get_smoke_config
    from repro.models import Model

    cfg = get_smoke_config("glm4-9b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_serve_engine_startup_under_enospc(tmp_path, monkeypatch,
                                           smoke_engine_parts):
    """ServeEngine startup (plan warmup included) on a host with a full
    disk: no crash, plans solved into memory, one warning."""
    from repro.serve.engine import ServeEngine

    model, params = smoke_engine_parts
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans"))
    with plan_mod._CACHES_LOCK:
        plan_mod._CACHES.clear()
    with faults.enospc_writes():
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            eng = ServeEngine(model, params, batch_size=2, cache_len=48,
                              prompt_len=16)
    assert eng.stats["plan_warmup_solved"] > 0
    assert len(_plan_warnings(rec)) == 1


# --------------------------------------------- per-request runaway guards


def test_runaway_request_times_out_others_finish(smoke_engine_parts):
    """Satellite: one non-terminating request among finishers — the
    deadline frees its slot; the finishers complete normally and the
    loop ends long before the engine-global max_steps."""
    import numpy as np

    from repro.serve.engine import Request, ServeEngine

    model, params = smoke_engine_parts
    eng = ServeEngine(model, params, batch_size=2, cache_len=64,
                      prompt_len=8, plan_warmup=False)
    prompt = np.arange(1, 9, dtype=np.int32)
    runaway = Request(rid=0, prompt=prompt, max_new_tokens=10**6,
                      deadline_s=0.0)
    finishers = [Request(rid=i, prompt=prompt, max_new_tokens=4)
                 for i in (1, 2, 3)]
    done = eng.run([runaway] + finishers, max_steps=64)
    assert runaway.done and runaway.timed_out
    assert len(runaway.output) < 10**6
    for r in finishers:
        assert r.done and not r.timed_out and len(r.output) == 4
    assert eng.stats["timeouts"] == 1
    assert eng.stats["decode_steps"] < 64          # terminated early
    assert done is not None


def test_max_new_cap_clamps_every_request(smoke_engine_parts):
    import numpy as np

    from repro.serve.engine import Request, ServeEngine

    model, params = smoke_engine_parts
    eng = ServeEngine(model, params, batch_size=2, cache_len=64,
                      prompt_len=8, plan_warmup=False, max_new_cap=2)
    prompt = np.arange(1, 9, dtype=np.int32)
    reqs = [Request(rid=i, prompt=prompt, max_new_tokens=50)
            for i in range(3)]
    eng.run(reqs, max_steps=32)
    assert all(r.done and len(r.output) == 2 and not r.timed_out
               for r in reqs)
    assert eng.stats["timeouts"] == 0


def test_default_deadline_applies_when_request_has_none(smoke_engine_parts):
    import numpy as np

    from repro.serve.engine import Request, ServeEngine

    model, params = smoke_engine_parts
    eng = ServeEngine(model, params, batch_size=2, cache_len=64,
                      prompt_len=8, plan_warmup=False,
                      default_deadline_s=0.0)
    prompt = np.arange(1, 9, dtype=np.int32)
    reqs = [Request(rid=i, prompt=prompt, max_new_tokens=50)
            for i in range(2)]
    eng.run(reqs, max_steps=32)
    assert all(r.done and r.timed_out for r in reqs)
    assert eng.stats["timeouts"] == 2
