"""Mamba-2 SSD (state-space duality) chunk-scan Pallas kernel.

The SSD chunked algorithm is itself a compound operation (three GEMMs +
decay-mask SIMD ops per chunk — see core/workload.py::ssd_chunk), so COMET
models its dataflow and picks the chunk length.  TPU adaptation: the chunk
is the VMEM-resident tile; intra-chunk terms use the MXU; the inter-chunk
state (N × P, f32) is carried in VMEM scratch across the sequential chunk
grid dimension.

y_t = C_t · h_t,   h_t = exp(dA_t) · h_{t-1} + B_t ⊗ xdt_t

Inputs (per flattened batch*heads row):
  xdt (BH, S, P)  dA (BH, S)  B (BH, S, N)  C (BH, S, N)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["ssd_scan_fwd", "ssd_scan"]


def _ssd_kernel(xdt_ref, da_ref, b_ref, c_ref, y_ref, h_scr, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = xdt_ref[0].astype(jnp.float32)                 # (c, P)
    da = da_ref[0].astype(jnp.float32)                 # (1, c) block
    bmat = b_ref[0].astype(jnp.float32)                # (c, N)
    cmat = c_ref[0].astype(jnp.float32)                # (c, N)

    cs = jnp.cumsum(da, axis=-1)                       # (1, c)
    csr = cs.reshape(chunk, 1)                         # (c, 1)
    total = cs[0, chunk - 1]

    # intra-chunk: (C B^T * L) @ X with L[i,j] = exp(cs_i - cs_j) for i>=j
    logl = csr - csr.reshape(1, chunk)                 # (c, c)
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmask = i_idx >= j_idx
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    cb = jnp.where(lmask, cb * jnp.exp(logl), 0.0)
    y_intra = jax.lax.dot_general(cb, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: C_t · h_prev decayed to position t
    h = h_scr[...]                                     # (N, P)
    y_inter = jax.lax.dot_general(cmat, h, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(csr)

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h' = exp(total) h + (B * exp(total - cs))^T @ X
    decay_in = jnp.exp(total - csr)                    # (c, 1)
    h_scr[...] = jnp.exp(total) * h + jax.lax.dot_general(
        bmat * decay_in, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def ssd_scan_fwd(xdt: jax.Array, dA: jax.Array, B: jax.Array, C: jax.Array,
                 *, chunk: Optional[int] = None,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Pallas forward. Shapes: xdt (BH,S,P), dA (BH,S), B/C (BH,S,N)."""
    from .autotune import ssd_chunk_len

    BH, S, P = xdt.shape
    N = B.shape[-1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    chunk = min(chunk or ssd_chunk_len(S, P, N), S)
    pad = (-S) % chunk
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    da3 = dA.reshape(BH, Sp // chunk, chunk)           # (BH, nc, c): chunk-blocked

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(BH, Sp // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sp, P), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xdt, da3, B, C)
    return out[:, :S] if pad else out


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def ssd_scan(xdt, dA, B, C, chunk=None, interpret=None):
    """SSD chunk scan with recompute-based backward (custom_vjp over the
    chunked jnp reference)."""
    return ssd_scan_fwd(xdt, dA, B, C, chunk=chunk, interpret=interpret)


def _ssd_fwd(xdt, dA, B, C, chunk, interpret):
    return ssd_scan_fwd(xdt, dA, B, C, chunk=chunk, interpret=interpret), \
        (xdt, dA, B, C)


def _ssd_bwd(chunk, interpret, res, g):
    from .ref import ssd_chunked_ref
    xdt, dA, B, C = res
    ck = chunk or 64
    # pad to chunk multiple for the reference
    S = xdt.shape[1]
    pad = (-S) % ck
    if pad:
        def f(x_, d_, b_, c_):
            xp = jnp.pad(x_, ((0, 0), (0, pad), (0, 0)))
            dp = jnp.pad(d_, ((0, 0), (0, pad)))
            bp = jnp.pad(b_, ((0, 0), (0, pad), (0, 0)))
            cp = jnp.pad(c_, ((0, 0), (0, pad), (0, 0)))
            return ssd_chunked_ref(xp, dp, bp, cp, chunk=ck)[:, :S]
    else:
        def f(x_, d_, b_, c_):
            return ssd_chunked_ref(x_, d_, b_, c_, chunk=ck)
    _, vjp = jax.vjp(f, xdt, dA, B, C)
    return vjp(g)


ssd_scan.defvjp(_ssd_fwd, _ssd_bwd)
