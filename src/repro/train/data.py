"""Deterministic, seekable synthetic token pipeline.

Batches are a pure function of (seed, step) — restart from a checkpoint at
step k reproduces byte-identical data without replaying the stream.  Per-
host sharded feeding slices the global batch by host id (multi-host
jax.make_array_from_process_local_data pattern); on one host it degrades to
the full batch.

The generator produces Zipf-ish token ids with short-range structure (so
the LM loss actually decreases) plus shifted labels; for enc-dec models it
also derives deterministic 'frame embeddings' (the stubbed modality
frontend).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np

__all__ = ["SyntheticLM", "host_slice"]


@dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    encdec: bool = False
    d_model: int = 0
    enc_ratio: int = 8

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        # Markov-ish stream: next token = (prev * a + noise) % V with
        # regime switches -> learnable bigram structure.
        base = rng.integers(0, V, size=(B, 1))
        mult = rng.integers(3, 11, size=(B, 1))
        noise = rng.integers(0, max(2, V // 64), size=(B, S + 1))
        toks = np.empty((B, S + 1), np.int64)
        toks[:, 0:1] = base
        for t in range(1, S + 1):
            toks[:, t] = (toks[:, t - 1] * mult[:, 0] + noise[:, t]) % V
        out = {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
        if self.encdec:
            Se = max(1, S // self.enc_ratio)
            emb = rng.standard_normal((B, Se, self.d_model)).astype(np.float32)
            out["src_embeds"] = emb.astype(np.dtype("bfloat16")
                                           if False else np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def host_slice(batch: Dict[str, np.ndarray], host_id: int,
               num_hosts: int) -> Dict[str, np.ndarray]:
    """Per-host shard of the global batch (batch dim 0)."""
    def sl(x):
        b = x.shape[0]
        assert b % num_hosts == 0, (b, num_hosts)
        k = b // num_hosts
        return x[host_id * k:(host_id + 1) * k]
    return {k: sl(v) for k, v in batch.items()}
