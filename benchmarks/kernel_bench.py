"""Kernel micro-benchmarks: wall-time of the Pallas kernels (interpret mode
on CPU — correctness-path timing, NOT TPU performance; TPU perf is the
dry-run/roofline's job) plus the COMET-predicted latency for the same tile
shapes on the tpu_v5e model, so the autotuner's choices are visible."""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.autotune import (attention_blocks, gemm_epilogue_blocks,
                                    ssd_chunk_len)


def _time(fn, *args, iters: int = 3) -> float:
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run_all() -> Dict:
    rng = np.random.default_rng(0)
    out = {}

    q = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    us = _time(lambda a, b, c: ops.flash_attention(a, b, c, True, None, None,
                                                   128, 128, True), q, k, v)
    bq, bk = attention_blocks(4096, 4096, 128)
    print(f"pallas_flash_attention,{us:.0f},autotuned_blocks=({bq}x{bk})@4k")
    out["fa_us"] = us

    a = jnp.asarray(rng.normal(size=(256, 128)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(128, 512)) / 10, jnp.float32)
    us = _time(lambda x, y: ops.gemm_softmax(x, y, block_m=128, block_k=64,
                                             interpret=True), a, b)
    bm, bkk = gemm_epilogue_blocks(4096, 4096, 4096)
    print(f"pallas_gemm_softmax,{us:.0f},autotuned_blocks=({bm}x{bkk})@4k3")
    out["gemm_sm_us"] = us

    g = jnp.asarray(rng.normal(size=(512,)), jnp.float32)
    be = jnp.asarray(rng.normal(size=(512,)), jnp.float32)
    us = _time(lambda x, y: ops.gemm_layernorm(x, y, g, be, block_m=128,
                                               block_k=64, interpret=True), a, b)
    print(f"pallas_gemm_layernorm,{us:.0f},fused_epilogue")
    out["gemm_ln_us"] = us

    BH, S, P, N = 4, 256, 32, 64
    xdt = jnp.asarray(rng.normal(size=(BH, S, P)), jnp.float32)
    dA = -jnp.abs(jnp.asarray(rng.normal(size=(BH, S)), jnp.float32)) * 0.1
    Bm = jnp.asarray(rng.normal(size=(BH, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(BH, S, N)), jnp.float32)
    us = _time(lambda *xs: ops.ssd_scan(*xs, 64, True), xdt, dA, Bm, Cm)
    ck = ssd_chunk_len(4096, 64, 128)
    print(f"pallas_ssd_scan,{us:.0f},autotuned_chunk={ck}@4k")
    out["ssd_us"] = us
    return out


if __name__ == "__main__":
    run_all()
