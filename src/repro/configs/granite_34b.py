"""granite-34b [dense]: 88-layer code model with MQA (kv=1).
[arXiv:2405.04324]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-34b", family="dense",
        n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
        d_ff=24576, vocab_size=49152, rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
        vocab_size=512, name="granite-smoke")
