"""qwen3-moe-30b-a3b [moe]: 128 experts top-8 (softmax router), GQA kv=4,
head_dim 128, qk-norm.  [hf:Qwen/Qwen3-30B-A3B]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
        head_dim=128, d_ff=0, vocab_size=151936, qk_norm=True,
        n_experts=128, top_k=8, moe_d_ff=768, router_type="softmax",
        rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        vocab_size=512, n_experts=8, top_k=2, moe_d_ff=32,
        name="qwen3-moe-smoke")
