"""minitron-4b [dense]: pruned Nemotron; very large vocab (256000) makes
the vocab-sharded logits/loss the dominant memory term.  [arXiv:2407.14679]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b", family="dense",
        n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=9216, vocab_size=256000, rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab_size=1024, name="minitron-smoke")
