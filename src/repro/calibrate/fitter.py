"""Least-squares ``NoCParams`` fitting from measured collective sweeps.

The model being inverted is exactly what the cost model charges per
collective (``collective_latency_terms``, Eqs. 1/3/4):

    t(type, DV, P) = t_router * hops(type, P)
                   + vol(type, DV, P) * (t_enq / W  +  1 / B)

where ``vol = DV * volume_factor(type, P)`` and ``hops`` come from the
same per-NoC factor tables the search engine reads (so the fit and the
predictions can never drift apart), ``W`` is the channel width and ``B``
the channel bandwidth.  Substituting x1 = t_router and
x2 = t_enq/W + 1/B makes the model **linear**:

    t_i = x1 * hops_i + x2 * vol_i

which a weighted linear least squares solves directly — weights are
1/t_i, so the fit minimizes *relative* residuals and the microsecond
latency floor counts as much as the multi-millisecond bandwidth regime
(an absolute fit would let the largest message drown the alpha term,
the standard alpha–beta fitting pitfall).

Identifiability
---------------
``t_enq`` and ``channel_bandwidth`` both multiply ``vol`` — a timing
sweep can only observe their combined per-byte cost x2, never the split
(this is inherent to alpha–beta models, not a weakness of the solver).
The fitter therefore apportions x2 using the *reference* NoC's
enqueue-vs-bandwidth ratio:

    frac  = (t_enq_ref / W) / (t_enq_ref / W + 1 / B_ref)
    t_enq = x2 * frac * W,     B = 1 / (x2 * (1 - frac))

so calibrating from a preset keeps the preset's split while matching
every measured latency exactly.  The ground-truth-recovery tests pass
the true params as the reference, which makes all three constants
recoverable; ``FitResult.identifiable`` documents the caveat in every
persisted artifact.

Degenerate sweeps (P <= 1 everywhere — e.g. a (1,1) mesh — or fewer
than two usable points) return the reference unchanged with
``degenerate=True`` rather than inventing constants from nothing.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.collectives import collective_cost, collective_seconds
from repro.core.hardware import NoCParams

from .harness import MeasuredPoint

__all__ = ["TypeFit", "FitResult", "fit_noc_params", "predicted_seconds",
           "relative_errors"]


@dataclass(frozen=True)
class TypeFit:
    """Per-collective-type alpha–beta diagnostic fit: t = alpha * hops +
    beta * vol (same regressors as the joint fit, restricted to one
    type's points)."""

    col_type: str
    alpha_s: float               # fitted per-hop latency for this type
    beta_s_per_byte: float       # fitted per-wire-byte cost for this type
    n_points: int
    max_rel_err: float
    median_rel_err: float

    def to_json(self) -> Dict:
        return {"col_type": self.col_type, "alpha_s": self.alpha_s,
                "beta_s_per_byte": self.beta_s_per_byte,
                "n_points": self.n_points,
                "max_rel_err": self.max_rel_err,
                "median_rel_err": self.median_rel_err}


@dataclass(frozen=True)
class FitResult:
    """Fitted NoCParams + per-point residuals of one calibration."""

    params: NoCParams            # reference with fitted timing constants
    reference: NoCParams
    per_type: Tuple[TypeFit, ...]
    residuals: Tuple[float, ...]   # signed rel err per point, point order
    points: Tuple[MeasuredPoint, ...]
    max_rel_err: float
    median_rel_err: float
    degenerate: bool = False
    #: False while t_enq / channel_bandwidth are split by the reference
    #: ratio rather than separately observed (see module docstring)
    identifiable: bool = False

    @property
    def n_points(self) -> int:
        return len(self.points)


def _wls(h: np.ndarray, v: np.ndarray, t: np.ndarray,
         w: np.ndarray) -> Tuple[float, float]:
    """Non-negative weighted least squares of t ~ x1*h + x2*v (2-column
    active set: solve unconstrained; if a coefficient goes negative, pin
    it to zero and re-solve the other)."""
    A = np.stack([h, v], axis=1) * w[:, None]
    b = t * w
    x, *_ = np.linalg.lstsq(A, b, rcond=None)
    x1, x2 = float(x[0]), float(x[1])

    def solve_one(col: np.ndarray) -> float:
        denom = float(np.dot(col * w, col * w))
        if denom <= 0.0:
            return 0.0
        return max(0.0, float(np.dot(col * w, b)) / denom)

    if x1 < 0.0 and x2 < 0.0:
        return 0.0, 0.0
    if x1 < 0.0:
        return 0.0, solve_one(v)
    if x2 < 0.0:
        return solve_one(h), 0.0
    return x1, x2


def _regressors(points: Sequence[MeasuredPoint], noc: NoCParams
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(hops, wire-volume bytes, measured seconds) arrays, one row per
    point, from the same factor tables the cost model reads."""
    h = np.empty(len(points))
    v = np.empty(len(points))
    t = np.empty(len(points))
    for i, p in enumerate(points):
        cc = collective_cost(p.col_type, float(p.data_volume_bytes),
                             p.participants, noc)
        h[i] = cc.hops
        v[i] = cc.volume_bytes
        t[i] = p.seconds
    return h, v, t


def predicted_seconds(points: Sequence[MeasuredPoint],
                      noc: NoCParams) -> np.ndarray:
    """Eq. 4 prediction for each measured point under ``noc``."""
    return np.array([collective_seconds(p.col_type,
                                        float(p.data_volume_bytes),
                                        p.participants, noc)
                     for p in points])


def relative_errors(points: Sequence[MeasuredPoint],
                    noc: NoCParams) -> np.ndarray:
    """Signed (pred - measured) / measured per point."""
    pred = predicted_seconds(points, noc)
    meas = np.array([p.seconds for p in points])
    return (pred - meas) / np.where(meas > 0, meas, 1.0)


def _split_beta(x2: float, reference: NoCParams) -> Tuple[float, float]:
    """Apportion the combined per-byte cost into (t_enq, bandwidth) by
    the reference ratio; a zero x2 keeps the reference constants."""
    if x2 <= 0.0:
        return reference.t_enq, reference.channel_bandwidth
    enq = reference.t_enq / reference.channel_width
    inv_b = 1.0 / reference.channel_bandwidth
    total = enq + inv_b
    frac = (enq / total) if total > 0 else 0.0
    if frac >= 1.0:                       # reference had infinite bandwidth
        return x2 * reference.channel_width, reference.channel_bandwidth
    t_enq = x2 * frac * reference.channel_width
    bandwidth = 1.0 / (x2 * (1.0 - frac))
    return t_enq, bandwidth


def _stats(res: np.ndarray) -> Tuple[float, float]:
    if res.size == 0:
        return 0.0, 0.0
    a = np.abs(res)
    return float(a.max()), float(np.median(a))


def fit_noc_params(points: Sequence[MeasuredPoint], reference: NoCParams,
                   ) -> FitResult:
    """Fit ``(channel_bandwidth, t_router, t_enq)`` to a measured sweep.

    ``reference`` supplies everything a timing sweep cannot observe: the
    mesh geometry the hop distances are computed on (it must match the
    topology the sweep ran over), the channel width, the hop energy, and
    the enqueue-vs-bandwidth split of the per-byte cost.  Points with
    ``participants <= 1`` contribute nothing (the model predicts exactly
    zero) and are excluded; if nothing usable remains the reference is
    returned unchanged with ``degenerate=True``.
    """
    pts = tuple(p for p in points
                if p.participants > 1 and p.seconds > 0.0
                and np.isfinite(p.seconds))
    if len(pts) < 2:
        return FitResult(params=reference, reference=reference,
                         per_type=(), residuals=(), points=tuple(points),
                         max_rel_err=0.0, median_rel_err=0.0,
                         degenerate=True)
    h, v, t = _regressors(pts, reference)
    usable = v > 0.0
    if usable.sum() < 2:
        return FitResult(params=reference, reference=reference,
                         per_type=(), residuals=(), points=tuple(points),
                         max_rel_err=0.0, median_rel_err=0.0,
                         degenerate=True)
    w = 1.0 / np.where(t > 0, t, 1.0)
    x1, x2 = _wls(h[usable], v[usable], t[usable], w[usable])
    t_enq, bandwidth = _split_beta(x2, reference)
    fitted = replace(reference, t_router=x1, t_enq=t_enq,
                     channel_bandwidth=bandwidth)

    res = relative_errors(pts, fitted)
    max_err, med_err = _stats(res)

    per_type: List[TypeFit] = []
    for col_type in sorted({p.col_type for p in pts}):
        idx = np.array([p.col_type == col_type for p in pts])
        sel = idx & usable
        if sel.sum() < 2:
            continue
        a_t, b_t = _wls(h[sel], v[sel], t[sel], w[sel])
        pred_t = a_t * h[sel] + b_t * v[sel]
        res_t = (pred_t - t[sel]) / t[sel]
        mx, md = _stats(res_t)
        per_type.append(TypeFit(col_type, a_t, b_t, int(sel.sum()), mx, md))

    return FitResult(params=fitted, reference=reference,
                     per_type=tuple(per_type),
                     residuals=tuple(float(r) for r in res),
                     points=pts, max_rel_err=max_err,
                     median_rel_err=med_err)
