"""Durable plan storage engine behind :class:`repro.core.plan.PlanCache`.

PR 5 gave every consumer of the search engine one shared ``PlanCache``;
this module gives that cache a **fleet-grade store**.  The flat
one-JSON-file-per-plan directory worked for a single host but had no
eviction, no GC, and no record of which sweep produced each plan — at
fleet scale (hundreds of serving hosts, thousands of (arch, op) cells,
DFModel-style datacenter provisioning sweeps) the store itself becomes
the reliability bottleneck.

The storage engine is a **degradation ladder** — every rung keeps
``resolve()`` correct, each failure just costs durability:

    SQLite (WAL)  ──open/corrupt failure──►  legacy JSON dir  ──►  memory-only
       │                                         │
       │ write failure (ENOSPC, read-only):      │ write failure:
       └── reads keep working, new plans         └── new plans stay
           stay in memory, ONE warning               in memory, ONE warning

* :class:`PlanStore` — the facade ``PlanCache`` talks to.  It owns the
  ladder: rung selection is lazy (a cache that never touches disk never
  warns), every demotion or write-disable warns **exactly once per
  cause**, and all faults degrade instead of raising to the caller.
* ``_SqliteBackend`` — the primary rung: one ``plans`` table in a WAL
  database (``plans.sqlite`` inside the store root), keyed by the exact
  ``PlanKey`` fingerprints, with

  - *busy handling*: ``PRAGMA busy_timeout`` plus a bounded exponential
    backoff retry loop around every statement, so SQLITE_BUSY storms
    from concurrent writers are absorbed silently;
  - *provenance columns*: ``engine_version`` (part of the key),
    ``sweep_id`` (which warmup sweep produced the plan;
    ``$REPRO_PLAN_SWEEP_ID`` or a per-warmup token), ``created_s`` /
    ``last_hit_s`` timestamps and a ``hits`` counter — so stale plans
    are *queryable* (:meth:`PlanStore.stats`) and *invalidatable*
    (:meth:`PlanStore.invalidate`, e.g. ``engine_version=4`` removes
    exactly the stale generation);
  - *size bounding*: LRU eviction (least-recently-hit first) whenever
    the store exceeds ``max_bytes`` / ``max_plans``, age expiry via
    ``max_age_s``, and ``PRAGMA incremental_vacuum`` so evictions
    actually return disk space;
  - *auto-migration*: on first writable open, any legacy per-plan
    ``*.json`` files in the root are imported into the table (zero lost
    plans) and moved to ``migrated-json/``; unparsable ones are
    quarantined to ``corrupt/`` instead of being re-parsed (and
    re-warned about) by every cold process forever;
  - *corruption recovery*: an unreadable database file is quarantined
    to ``corrupt/`` and recreated — one warning, no crash, plans
    re-solve.
* ``_JsonBackend`` — the legacy flat directory, kept as the fallback
  rung (and the wire format bundles still use): atomic ``os.replace``
  writes, corrupt files quarantined to ``corrupt/``.
* ``_NullBackend`` — memory-only: the store accepts writes and returns
  misses; the in-memory dict inside ``PlanCache`` is the actual cache.

Configuration (constructor kwargs override environment):

======================================  =======================================
``REPRO_PLAN_STORE``                    force a backend: ``sqlite`` | ``json``
                                        | ``memory``
``REPRO_PLAN_STORE_MAX_BYTES``          payload-byte bound before LRU eviction
``REPRO_PLAN_STORE_MAX_PLANS``          row-count bound before LRU eviction
``REPRO_PLAN_STORE_MAX_AGE_S``          age expiry applied by :meth:`gc`
``REPRO_PLAN_SWEEP_ID``                 provenance tag for new plans
======================================  =======================================

The fault matrix in ``tests/test_faults.py`` pins the contract: under
torn writes, ENOSPC, read-only stores, corrupt DB/JSON, SQLITE_BUSY
storms and killed writers, ``resolve()`` still returns plans
bit-identical to a clean-store run, with at most one warning per cause.
"""
from __future__ import annotations

import atexit
import json
import os
import re
import tempfile
import threading
import time
import warnings
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

try:                                    # stdlib, but allow exotic builds
    import sqlite3
    _SQLITE_OK = True
except ImportError:                     # pragma: no cover
    sqlite3 = None                      # type: ignore[assignment]
    _SQLITE_OK = False

__all__ = ["PlanStore", "StoreError", "PlanKey", "DB_FILENAME",
           "CORRUPT_DIRNAME", "MIGRATED_DIRNAME", "DEFAULT_MAX_BYTES",
           "DEFAULT_MAX_PLANS", "current_sweep_id"]

PlanKey = Tuple[str, str, int, str]     # (arch_sig, op_sig, version, kw_sig)

DB_FILENAME = "plans.sqlite"
CORRUPT_DIRNAME = "corrupt"
MIGRATED_DIRNAME = "migrated-json"

_ENV_BACKEND = "REPRO_PLAN_STORE"
_ENV_MAX_BYTES = "REPRO_PLAN_STORE_MAX_BYTES"
_ENV_MAX_PLANS = "REPRO_PLAN_STORE_MAX_PLANS"
_ENV_MAX_AGE = "REPRO_PLAN_STORE_MAX_AGE_S"
_ENV_SWEEP = "REPRO_PLAN_SWEEP_ID"

DEFAULT_MAX_BYTES = 512 * 1024 * 1024   # payload bytes before LRU eviction
DEFAULT_MAX_PLANS = 1_000_000

# SQLITE_BUSY handling: sqlite's own busy_timeout sleeps inside one
# statement; the retry loop re-issues the statement with bounded
# exponential backoff on top, so writer storms degrade to latency, never
# to an exception reaching resolve().
BUSY_TIMEOUT_MS = 250
BUSY_RETRIES = 6
BUSY_BACKOFF_S = 0.01
BUSY_BACKOFF_CAP_S = 0.32

_KEY_FILE_RE = re.compile(
    r"^([0-9a-f]{16})-([0-9a-f]{16})-v(\d+)-([0-9a-f]{16})\.json$")


def current_sweep_id(explicit: Optional[str] = None) -> Optional[str]:
    """Provenance tag for plans written now: the explicit id (a warmup
    sweep's token), else ``$REPRO_PLAN_SWEEP_ID``, else None (ad-hoc
    single resolves)."""
    return explicit or os.environ.get(_ENV_SWEEP) or None


def key_filename(key: PlanKey) -> str:
    arch_sig, op_sig, version, kw_sig = key
    return f"{arch_sig}-{op_sig}-v{version}-{kw_sig}.json"


def parse_key_filename(name: str) -> Optional[PlanKey]:
    m = _KEY_FILE_RE.match(name)
    if m is None:
        return None
    return (m.group(1), m.group(2), int(m.group(3)), m.group(4))


class StoreError(Exception):
    """A backend operation failed.  ``cause`` routes the facade's
    response: ``'store-dir'`` (root uncreatable — no rung that needs the
    directory can work), ``'open'`` (backend cannot open its store),
    ``'write'`` (unrecoverable write error: ENOSPC, read-only — reads
    keep working, writes stop), ``'busy'`` (retry budget exhausted —
    transient, this write is skipped but later ones may succeed)."""

    def __init__(self, cause: str, msg: str):
        super().__init__(msg)
        self.cause = cause


def _is_busy(e: Exception) -> bool:
    s = str(e).lower()
    return "locked" in s or "busy" in s


def _is_full_or_readonly(e: Exception) -> bool:
    if isinstance(e, OSError):
        return True
    s = str(e).lower()
    return ("full" in s or "readonly" in s or "read-only" in s
            or "unable to open" in s)


# ----------------------------------------------------------- null backend


class _NullBackend:
    """Memory-only rung: every read misses, every write is accepted and
    dropped — the in-memory dict inside ``PlanCache`` is the cache."""

    kind = "memory"

    def __init__(self):
        self.dropped = 0

    def get(self, key: PlanKey) -> Optional[str]:
        return None

    def put(self, key: PlanKey, payload: str,
            sweep_id: Optional[str] = None) -> bool:
        self.dropped += 1
        return False                    # nothing durable was written

    def discard(self, key: PlanKey) -> bool:
        return False

    def keys(self) -> List[PlanKey]:
        return []

    def invalidate(self, **kw) -> int:
        return 0

    def gc(self, **kw) -> Dict[str, int]:
        return {"expired": 0, "evicted": 0}

    def stats(self) -> Dict:
        return {"backend": self.kind, "plans": 0, "bytes": 0,
                "writes_dropped": self.dropped}

    def close(self) -> None:
        pass


# ----------------------------------------------------------- json backend


class _JsonBackend:
    """Legacy flat-directory store: one atomic-write JSON file per plan.
    Kept as the ladder's fallback rung; corrupt files are quarantined to
    ``corrupt/`` so cold processes stop re-parsing (and re-warning
    about) them forever."""

    kind = "json"

    def __init__(self, root: Path, now: Callable[[], float] = time.time):
        self.root = root
        self._now = now
        self._dir_ok: Optional[bool] = None

    def _path(self, key: PlanKey) -> Path:
        return self.root / key_filename(key)

    def _ensure_dir(self) -> None:
        if self._dir_ok:
            return
        try:
            self.root.mkdir(parents=True, exist_ok=True)
        except OSError as e:
            raise StoreError(
                "store-dir",
                f"cannot create store dir {self.root}: {e!r}") from e
        self._dir_ok = True

    def get(self, key: PlanKey) -> Optional[str]:
        try:
            return self._path(key).read_text()
        except OSError:
            return None

    def put(self, key: PlanKey, payload: str,
            sweep_id: Optional[str] = None) -> bool:
        self._ensure_dir()
        path = self._path(key)
        try:
            fd, tmp = tempfile.mkstemp(dir=str(self.root),
                                       prefix=path.stem + ".",
                                       suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    f.write(payload)
                os.replace(tmp, path)   # atomic: readers never see partials
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
        except OSError as e:
            raise StoreError("write",
                             f"could not persist plan to {path}: "
                             f"{e!r}") from e
        return True

    def discard(self, key: PlanKey) -> bool:
        """Quarantine one stored plan (corrupt payload): move the file to
        ``corrupt/`` so it is never re-parsed, fall back to unlinking."""
        path = self._path(key)
        qdir = self.root / CORRUPT_DIRNAME
        try:
            qdir.mkdir(parents=True, exist_ok=True)
            os.replace(path, qdir / path.name)
            return True
        except OSError:
            try:
                os.unlink(path)
                return True
            except OSError:
                return False

    def _entries(self) -> List[Tuple[PlanKey, Path, os.stat_result]]:
        out = []
        try:
            names = os.listdir(self.root)
        except OSError:
            return out
        for name in names:
            key = parse_key_filename(name)
            if key is None:
                continue
            path = self.root / name
            try:
                out.append((key, path, path.stat()))
            except OSError:
                continue
        return out

    def keys(self) -> List[PlanKey]:
        return [k for k, _p, _s in self._entries()]

    def invalidate(self, *, engine_version: Optional[int] = None,
                   sweep_id: Optional[str] = None,
                   older_than_s: Optional[float] = None) -> int:
        # sweep_id provenance only exists in the SQLite rung; filtering
        # on it here can only be a no-op.
        if sweep_id is not None:
            return 0
        n = 0
        cutoff = None if older_than_s is None else self._now() - older_than_s
        for key, path, st in self._entries():
            if engine_version is not None and key[2] != engine_version:
                continue
            if cutoff is not None and st.st_mtime > cutoff:
                continue
            if engine_version is None and cutoff is None:
                continue
            try:
                os.unlink(path)
                n += 1
            except OSError:
                pass
        return n

    def gc(self, *, max_bytes: Optional[int] = None,
           max_plans: Optional[int] = None,
           max_age_s: Optional[float] = None) -> Dict[str, int]:
        expired = 0
        if max_age_s is not None:
            expired = self.invalidate(older_than_s=max_age_s)
        entries = sorted(self._entries(), key=lambda e: e[2].st_mtime)
        total = sum(st.st_size for _k, _p, st in entries)
        count = len(entries)
        evicted = 0
        for _key, path, st in entries:     # oldest-mtime first (LRU proxy)
            over = ((max_bytes is not None and total > max_bytes)
                    or (max_plans is not None and count > max_plans))
            if not over:
                break
            try:
                os.unlink(path)
            except OSError:
                continue
            total -= st.st_size
            count -= 1
            evicted += 1
        return {"expired": expired, "evicted": evicted}

    def stats(self) -> Dict:
        entries = self._entries()
        return {"backend": self.kind, "plans": len(entries),
                "bytes": sum(st.st_size for _k, _p, st in entries)}

    def close(self) -> None:
        pass


# --------------------------------------------------------- sqlite backend


_SCHEMA = """
CREATE TABLE IF NOT EXISTS plans (
    arch_sig        TEXT    NOT NULL,
    op_sig          TEXT    NOT NULL,
    engine_version  INTEGER NOT NULL,
    kw_sig          TEXT    NOT NULL,
    payload         TEXT    NOT NULL,
    size_bytes      INTEGER NOT NULL,
    sweep_id        TEXT,
    created_s       REAL    NOT NULL,
    last_hit_s      REAL    NOT NULL,
    hits            INTEGER NOT NULL DEFAULT 0,
    PRIMARY KEY (arch_sig, op_sig, engine_version, kw_sig)
);
CREATE INDEX IF NOT EXISTS plans_lru ON plans (last_hit_s);
CREATE INDEX IF NOT EXISTS plans_version ON plans (engine_version);
"""

_KEY_WHERE = ("arch_sig = ? AND op_sig = ? AND engine_version = ? "
              "AND kw_sig = ?")


class _SqliteBackend:
    """WAL-mode SQLite store: the primary rung.  One writer at a time
    (WAL readers never block), busy-timeout + bounded-backoff retries,
    LRU/age eviction with incremental vacuum, provenance per row."""

    kind = "sqlite"

    def __init__(self, root: Path, *, max_bytes: int, max_plans: int,
                 max_age_s: Optional[float],
                 now: Callable[[], float] = time.time):
        if not _SQLITE_OK:
            raise StoreError("open", "sqlite3 module unavailable")
        self.root = root
        self.db_path = root / DB_FILENAME
        self.max_bytes = max_bytes
        self.max_plans = max_plans
        self.max_age_s = max_age_s
        self._now = now
        self._conn_obj: Optional["sqlite3.Connection"] = None
        self._lock = threading.RLock()
        self.write_ok = True            # flipped once on unrecoverable error
        self.read_only = False
        self.migrated = 0
        self.quarantined = 0
        self.evicted_total = 0

    # -------------------------------------------------------- connection

    def _legacy_files(self) -> List[Path]:
        try:
            return [self.root / n for n in os.listdir(self.root)
                    if parse_key_filename(n) is not None]
        except OSError:
            return []

    def _conn(self, create: bool) -> Optional["sqlite3.Connection"]:
        with self._lock:
            if self._conn_obj is not None:
                return self._conn_obj
            if not create and not self.db_path.exists() \
                    and not self._legacy_files():
                return None             # nothing to read, don't create
            try:
                self.root.mkdir(parents=True, exist_ok=True)
            except OSError as e:
                raise StoreError(
                    "store-dir",
                    f"cannot create store dir {self.root}: {e!r}") from e
            try:
                conn = self._open_rw()
            except sqlite3.DatabaseError as e:
                if isinstance(e, sqlite3.OperationalError) \
                        and _is_full_or_readonly(e) \
                        and self.db_path.exists():
                    conn = self._open_ro(e)
                else:
                    conn = self._recover_corrupt(e)
            self._conn_obj = conn
            # closing checkpoints the WAL and removes -wal/-shm: no
            # litter left by drivers that exit without an explicit close
            atexit.register(self.close)
            if not self.read_only:
                self._migrate_legacy()
            return conn

    def _open_rw(self) -> "sqlite3.Connection":
        conn = sqlite3.connect(str(self.db_path),
                               timeout=BUSY_TIMEOUT_MS / 1000.0,
                               check_same_thread=False)
        try:
            conn.execute(f"PRAGMA busy_timeout = {BUSY_TIMEOUT_MS}")
            conn.execute("PRAGMA journal_mode = WAL")
            conn.execute("PRAGMA synchronous = NORMAL")
            # must precede table creation to shape the file; a no-op on
            # an existing database (where it would need a full VACUUM)
            conn.execute("PRAGMA auto_vacuum = INCREMENTAL")
            conn.executescript(_SCHEMA)
            conn.commit()
        except BaseException:
            conn.close()
            raise
        return conn

    def _open_ro(self, cause: Exception) -> "sqlite3.Connection":
        """The directory or file rejects writes but a database exists:
        serve reads, keep new plans in memory (one warning)."""
        try:
            conn = sqlite3.connect(f"file:{self.db_path}?mode=ro", uri=True,
                                   timeout=BUSY_TIMEOUT_MS / 1000.0,
                                   check_same_thread=False)
            conn.execute("SELECT COUNT(*) FROM plans").fetchone()
        except sqlite3.DatabaseError:
            raise StoreError("open",
                             f"cannot open plan store {self.db_path}: "
                             f"{cause!r}") from cause
        self.read_only = True
        self.write_ok = False
        _warn_once(("read-only", str(self.root)),
                   f"PlanStore: {self.db_path} is read-only ({cause!r}); "
                   "serving stored plans, keeping new plans in memory only")
        return conn

    def _recover_corrupt(self, cause: Exception) -> "sqlite3.Connection":
        """Quarantine an unreadable database file and start fresh —
        plans re-solve; a corrupt store must never poison startup."""
        qdir = self.root / CORRUPT_DIRNAME
        try:
            qdir.mkdir(parents=True, exist_ok=True)
            os.replace(self.db_path, qdir / DB_FILENAME)
            for suffix in ("-wal", "-shm"):
                try:
                    os.unlink(str(self.db_path) + suffix)
                except OSError:
                    pass
        except OSError as e:
            raise StoreError("open",
                             f"corrupt plan store {self.db_path} "
                             f"({cause!r}) and quarantine failed "
                             f"({e!r})") from e
        self.quarantined += 1
        _warn_once(("corrupt-db", str(self.root)),
                   f"PlanStore: quarantined corrupt database "
                   f"{self.db_path} -> {qdir / DB_FILENAME} ({cause!r}); "
                   "starting a fresh store, plans will re-solve")
        try:
            return self._open_rw()
        except sqlite3.DatabaseError as e:
            raise StoreError("open",
                             f"cannot recreate plan store after "
                             f"quarantine: {e!r}") from e

    # ----------------------------------------------------- retry plumbing

    def _retry(self, fn):
        """Bounded exponential backoff around one statement batch.  Busy
        errors are retried; anything else propagates to the caller's
        classification."""
        delay = BUSY_BACKOFF_S
        for attempt in range(BUSY_RETRIES):
            try:
                return fn()
            except sqlite3.OperationalError as e:
                if not _is_busy(e) or attempt == BUSY_RETRIES - 1:
                    raise
                time.sleep(delay)
                delay = min(delay * 2, BUSY_BACKOFF_CAP_S)

    def _write(self, sql: str, params: Tuple = ()) -> int:
        """One committed write statement under the store lock, with busy
        retries.  Returns the affected rowcount."""
        conn = self._conn(create=True)

        def go():
            with self._lock:
                cur = conn.execute(sql, params)
                conn.commit()
                return cur.rowcount

        return self._retry(go)

    def _read(self, sql: str, params: Tuple = ()) -> List[Tuple]:
        conn = self._conn(create=False)
        if conn is None:
            return []

        def go():
            with self._lock:
                return conn.execute(sql, params).fetchall()

        return self._retry(go)

    # ---------------------------------------------------------- get / put

    def get(self, key: PlanKey) -> Optional[str]:
        try:
            rows = self._read(
                f"SELECT payload FROM plans WHERE {_KEY_WHERE}", key)
        except sqlite3.Error:
            return None                 # degraded read: treat as a miss
        if not rows:
            return None
        if not self.read_only and self.write_ok:
            try:                        # LRU bookkeeping is best-effort
                self._write(
                    f"UPDATE plans SET hits = hits + 1, last_hit_s = ? "
                    f"WHERE {_KEY_WHERE}", (self._now(),) + key)
            except (sqlite3.Error, OSError, StoreError):
                pass
        return rows[0][0]

    def put(self, key: PlanKey, payload: str,
            sweep_id: Optional[str] = None) -> bool:
        if not self.write_ok:
            return False                # degraded: warned once already
        now = self._now()
        try:
            self._write(
                "INSERT OR REPLACE INTO plans (arch_sig, op_sig, "
                "engine_version, kw_sig, payload, size_bytes, sweep_id, "
                "created_s, last_hit_s, hits) "
                "VALUES (?, ?, ?, ?, ?, ?, ?, ?, ?, 0)",
                key + (payload, len(payload.encode()),
                       current_sweep_id(sweep_id), now, now))
            self._enforce_bounds()
        except sqlite3.OperationalError as e:
            if _is_busy(e):
                raise StoreError("busy",
                                 f"plan store busy after {BUSY_RETRIES} "
                                 f"retries: {e!r}") from e
            raise StoreError("write", f"plan write failed: {e!r}") from e
        except (sqlite3.Error, OSError) as e:
            raise StoreError("write", f"plan write failed: {e!r}") from e
        return True

    def discard(self, key: PlanKey) -> bool:
        try:
            return self._write(
                f"DELETE FROM plans WHERE {_KEY_WHERE}", key) > 0
        except (sqlite3.Error, OSError, StoreError):
            return False

    def keys(self) -> List[PlanKey]:
        try:
            rows = self._read(
                "SELECT arch_sig, op_sig, engine_version, kw_sig "
                "FROM plans ORDER BY created_s")
        except sqlite3.Error:
            return []
        return [(r[0], r[1], int(r[2]), r[3]) for r in rows]

    # ------------------------------------------------- eviction / gc / gc

    def _enforce_bounds(self) -> int:
        """LRU-evict (least-recently-hit first) until the store fits the
        configured bounds; reclaim freed pages incrementally."""
        rows = self._read(
            "SELECT COUNT(*), COALESCE(SUM(size_bytes), 0) FROM plans")
        if not rows:
            return 0
        count, total = int(rows[0][0]), int(rows[0][1])
        if count <= self.max_plans and total <= self.max_bytes:
            return 0
        victims = []
        for rowid, size in self._read(
                "SELECT rowid, size_bytes FROM plans "
                "ORDER BY last_hit_s ASC, created_s ASC"):
            if count <= self.max_plans and total <= self.max_bytes:
                break
            victims.append(rowid)
            count -= 1
            total -= int(size)
        if victims:
            self._write(
                "DELETE FROM plans WHERE rowid IN (%s)"
                % ",".join("?" * len(victims)), tuple(victims))
            self._vacuum()
            self.evicted_total += len(victims)
        return len(victims)

    def _vacuum(self) -> None:
        try:
            self._write("PRAGMA incremental_vacuum")
        except (sqlite3.Error, OSError, StoreError):
            pass

    def invalidate(self, *, engine_version: Optional[int] = None,
                   sweep_id: Optional[str] = None,
                   older_than_s: Optional[float] = None) -> int:
        """Delete exactly the rows matching the provenance filters (ANDed
        together; at least one must be given)."""
        where, params = [], []
        if engine_version is not None:
            where.append("engine_version = ?")
            params.append(engine_version)
        if sweep_id is not None:
            where.append("sweep_id = ?")
            params.append(sweep_id)
        if older_than_s is not None:
            where.append("created_s < ?")
            params.append(self._now() - older_than_s)
        if not where:
            return 0
        try:
            n = self._write("DELETE FROM plans WHERE " + " AND ".join(where),
                            tuple(params))
        except (sqlite3.Error, OSError):
            return 0
        if n:
            self._vacuum()
        return max(n, 0)

    def gc(self, *, max_bytes: Optional[int] = None,
           max_plans: Optional[int] = None,
           max_age_s: Optional[float] = None) -> Dict[str, int]:
        """Expire by age, enforce (possibly tightened) size bounds, then
        vacuum and truncate the WAL."""
        expired = 0
        age = max_age_s if max_age_s is not None else self.max_age_s
        if age is not None:
            expired = self.invalidate(older_than_s=age)
        old_bounds = (self.max_bytes, self.max_plans)
        if max_bytes is not None:
            self.max_bytes = max_bytes
        if max_plans is not None:
            self.max_plans = max_plans
        try:
            evicted = self._enforce_bounds()
        finally:
            if max_bytes is not None or max_plans is not None:
                self.max_bytes, self.max_plans = old_bounds
        try:
            self._write("PRAGMA wal_checkpoint(TRUNCATE)")
        except (sqlite3.Error, OSError, StoreError):
            pass
        return {"expired": expired, "evicted": evicted}

    def stats(self) -> Dict:
        try:
            rows = self._read(
                "SELECT COUNT(*), COALESCE(SUM(size_bytes), 0), "
                "COALESCE(SUM(hits), 0) FROM plans")
            by_version = dict(self._read(
                "SELECT engine_version, COUNT(*) FROM plans "
                "GROUP BY engine_version"))
            by_sweep = dict(self._read(
                "SELECT COALESCE(sweep_id, 'adhoc'), COUNT(*) FROM plans "
                "GROUP BY sweep_id"))
        except sqlite3.Error:
            rows, by_version, by_sweep = [], {}, {}
        count, nbytes, hits = (int(rows[0][0]), int(rows[0][1]),
                               int(rows[0][2])) if rows else (0, 0, 0)
        try:
            db_bytes = self.db_path.stat().st_size
        except OSError:
            db_bytes = 0
        return {"backend": self.kind, "plans": count, "bytes": nbytes,
                "db_bytes": db_bytes, "hits": hits,
                "by_version": {int(k): int(v) for k, v in by_version.items()},
                "by_sweep": {str(k): int(v) for k, v in by_sweep.items()},
                "migrated": self.migrated, "quarantined": self.quarantined,
                "evicted_total": self.evicted_total,
                "read_only": self.read_only, "write_ok": self.write_ok,
                "max_bytes": self.max_bytes, "max_plans": self.max_plans}

    def close(self) -> None:
        with self._lock:
            if self._conn_obj is None:
                return
            try:
                if not self.read_only:
                    self._conn_obj.execute("PRAGMA wal_checkpoint(TRUNCATE)")
            except sqlite3.Error:
                pass
            try:
                self._conn_obj.close()   # drops -wal/-shm on last close
            except sqlite3.Error:
                pass
            self._conn_obj = None

    # ---------------------------------------------------------- migration

    def _migrate_legacy(self) -> int:
        """Import every legacy per-plan JSON file in the root into the
        table (first writable open only — files are then moved aside so
        no later open re-parses them).  Zero lost plans: readable files
        land in ``migrated-json/``, unreadable ones in ``corrupt/``."""
        files = self._legacy_files()
        if not files:
            return 0
        moved_dir = self.root / MIGRATED_DIRNAME
        qdir = self.root / CORRUPT_DIRNAME
        migrated = corrupt = 0
        for path in files:
            key = parse_key_filename(path.name)
            try:
                payload = path.read_text()
                d = json.loads(payload)
                if tuple(d["key"]) != key or "plan" not in d:
                    raise ValueError("key mismatch")
            except (OSError, ValueError, KeyError, TypeError):
                try:
                    qdir.mkdir(parents=True, exist_ok=True)
                    os.replace(path, qdir / path.name)
                except OSError:
                    pass
                corrupt += 1
                continue
            try:
                st_mtime = path.stat().st_mtime
            except OSError:
                st_mtime = self._now()
            try:
                self._write(
                    "INSERT OR IGNORE INTO plans (arch_sig, op_sig, "
                    "engine_version, kw_sig, payload, size_bytes, "
                    "sweep_id, created_s, last_hit_s, hits) "
                    "VALUES (?, ?, ?, ?, ?, ?, 'legacy-json', ?, ?, 0)",
                    key + (payload, len(payload.encode()),
                           st_mtime, st_mtime))
            except (sqlite3.Error, OSError, StoreError):
                continue                # file stays for the next attempt
            try:
                moved_dir.mkdir(parents=True, exist_ok=True)
                os.replace(path, moved_dir / path.name)
            except OSError:
                pass
            migrated += 1
        self.migrated += migrated
        if migrated or corrupt:
            _warn_once(("migrated", str(self.root)),
                       f"PlanStore: migrated {migrated} legacy JSON "
                       f"plan(s) from {self.root} into {DB_FILENAME}"
                       + (f"; quarantined {corrupt} corrupt file(s) to "
                          f"{CORRUPT_DIRNAME}/" if corrupt else ""))
        return migrated


# ------------------------------------------------------------ warn-once


_WARNED: set = set()
_WARNED_LOCK = threading.Lock()


def _warn_once(cause_key: Tuple, msg: str) -> None:
    """One warning per (cause, store) for the life of the process — a
    degraded store degrades once, not once per write."""
    with _WARNED_LOCK:
        if cause_key in _WARNED:
            return
        _WARNED.add(cause_key)
    warnings.warn(msg, RuntimeWarning, stacklevel=4)


def _reset_warned() -> None:
    """Test hook: forget which degradations have been warned about."""
    with _WARNED_LOCK:
        _WARNED.clear()


# --------------------------------------------------------------- facade


def _env_int(name: str, default: int) -> int:
    v = os.environ.get(name)
    return default if not v else int(v)


def _env_float(name: str) -> Optional[float]:
    v = os.environ.get(name)
    return None if not v else float(v)


class PlanStore:
    """The degradation-ladder facade ``PlanCache`` persists through.

    Rung selection is lazy: a cache that only ever hits its in-memory
    layer never touches disk and never warns.  All faults degrade —
    ``get`` returns a miss, ``put`` returns False — and each distinct
    cause warns exactly once per store root.
    """

    _LADDER = ("sqlite", "json", "memory")

    def __init__(self, root, *, backend: Optional[str] = None,
                 max_bytes: Optional[int] = None,
                 max_plans: Optional[int] = None,
                 max_age_s: Optional[float] = None,
                 now: Callable[[], float] = time.time):
        self.root = Path(root).expanduser()
        backend = backend or os.environ.get(_ENV_BACKEND) or None
        if backend is not None and backend not in self._LADDER:
            raise ValueError(f"unknown plan-store backend {backend!r}; "
                             f"expected one of {self._LADDER}")
        self._rungs = list(self._LADDER[self._LADDER.index(backend):]
                           if backend else self._LADDER)
        self._cfg = {
            "max_bytes": (max_bytes if max_bytes is not None
                          else _env_int(_ENV_MAX_BYTES, DEFAULT_MAX_BYTES)),
            "max_plans": (max_plans if max_plans is not None
                          else _env_int(_ENV_MAX_PLANS, DEFAULT_MAX_PLANS)),
            "max_age_s": (max_age_s if max_age_s is not None
                          else _env_float(_ENV_MAX_AGE)),
        }
        self._now = now
        self._impl = None
        self._lock = threading.Lock()
        self.demotions: List[str] = []

    # ------------------------------------------------------------ ladder

    def _make_impl(self):
        with self._lock:
            if self._impl is not None:
                return self._impl
            kind = self._rungs[0]
            if kind == "sqlite" and _SQLITE_OK:
                self._impl = _SqliteBackend(self.root, now=self._now,
                                            **self._cfg)
            elif kind == "json" or kind == "sqlite":
                self._impl = _JsonBackend(self.root, now=self._now)
            else:
                self._impl = _NullBackend()
            return self._impl

    def _demote(self, err: StoreError) -> None:
        """Drop to the next usable rung after an open-level failure.  A
        root directory that cannot exist fails every disk rung at once,
        so it jumps straight to memory with a single warning."""
        with self._lock:
            failed = self._rungs[0] if self._rungs else "memory"
            if err.cause == "store-dir" or failed == "json":
                self._rungs = ["memory"]
            else:
                self._rungs = self._rungs[1:] or ["memory"]
            nxt = self._rungs[0]
            self._impl = None
            self.demotions.append(f"{failed}->{nxt}: {err}")
        reason = ("running memory-only" if nxt == "memory"
                  else f"falling back to the {nxt} store")
        _warn_once((err.cause, failed, str(self.root)),
                   f"PlanStore: {failed} backend failed ({err}); {reason}")

    @property
    def backend(self) -> str:
        """The active rung's kind (instantiates the backend lazily)."""
        return self._make_impl().kind

    # -------------------------------------------------------- operations

    def get(self, key: PlanKey) -> Optional[str]:
        for _ in range(len(self._LADDER) + 1):
            impl = self._make_impl()
            try:
                return impl.get(key)
            except StoreError as e:
                self._demote(e)
        return None                      # pragma: no cover — ladder ends

    def put(self, key: PlanKey, payload: str,
            sweep_id: Optional[str] = None) -> bool:
        for _ in range(len(self._LADDER) + 1):
            impl = self._make_impl()
            if not getattr(impl, "write_ok", True):
                return False             # degraded: warned once already
            try:
                return impl.put(key, payload, sweep_id=sweep_id)
            except StoreError as e:
                if e.cause == "busy":
                    # transient: skip this write, keep the rung
                    _warn_once(("busy", str(self.root)),
                               f"PlanStore: {e}; plan kept in memory "
                               "(later writes will retry)")
                    return False
                if e.cause == "write" and impl.kind != "memory":
                    # reads still work; writes stop, exactly one warning
                    impl.write_ok = False
                    _warn_once(("write", impl.kind, str(self.root)),
                               f"PlanStore: unrecoverable {impl.kind} "
                               f"write error ({e}); keeping new plans "
                               "in memory only")
                    return False
                self._demote(e)
        return False                     # pragma: no cover — ladder ends

    def discard(self, key: PlanKey) -> bool:
        try:
            return self._make_impl().discard(key)
        except StoreError:
            return False

    def keys(self) -> List[PlanKey]:
        try:
            return self._make_impl().keys()
        except StoreError as e:
            self._demote(e)
            return []

    def invalidate(self, **kw) -> int:
        try:
            return self._make_impl().invalidate(**kw)
        except StoreError:
            return 0

    def gc(self, **kw) -> Dict[str, int]:
        try:
            return self._make_impl().gc(**kw)
        except StoreError as e:
            self._demote(e)
            return {"expired": 0, "evicted": 0}

    def stats(self) -> Dict:
        try:
            s = self._make_impl().stats()
        except StoreError:
            s = {"backend": "memory", "plans": 0, "bytes": 0}
        s["demotions"] = list(self.demotions)
        return s

    def close(self) -> None:
        with self._lock:
            if self._impl is not None:
                self._impl.close()
