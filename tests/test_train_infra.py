"""Training-substrate tests: loss decreases, checkpoint round-trip +
restart determinism, async writer, straggler monitor, grad compression
convergence, collective planner."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import Model
from repro.train.checkpoint import (AsyncCheckpointer, latest_step,
                                    restore_checkpoint, save_checkpoint)
from repro.train.data import SyntheticLM, host_slice
from repro.train.elastic import StragglerMonitor
from repro.train.optimizer import OptConfig, cosine_lr, init_opt_state
from repro.train.train_step import TrainState, make_train_step


def _train(arch="glm4-9b", steps=40, compression=False, seed=0):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt_cfg = OptConfig(lr=5e-3, total_steps=steps, warmup_steps=2,
                        grad_compression=compression)
    state = TrainState(params, init_opt_state(params,
                                              compression=compression))
    step = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0,))
    data = SyntheticLM(cfg.vocab_size, 64, 8, seed=seed)
    losses = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    return losses, state


def test_loss_decreases():
    losses, _ = _train(steps=40)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_grad_compression_converges_similarly():
    base, _ = _train(steps=25, compression=False)
    comp, _ = _train(steps=25, compression=True)
    # int8 + error feedback must track the uncompressed run closely
    assert abs(np.mean(comp[-5:]) - np.mean(base[-5:])) < 0.35


def test_checkpoint_roundtrip(tmp_path):
    _, state = _train(steps=3)
    d = str(tmp_path / "ck")
    save_checkpoint(d, 3, state, extra={"note": "t"})
    assert latest_step(d) == 3
    restored, step, extra = restore_checkpoint(d, state)
    assert step == 3 and extra["note"] == "t"
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k(tmp_path):
    _, state = _train(steps=2)
    d = str(tmp_path / "ck")
    for s in range(1, 6):
        save_checkpoint(d, s, state, keep=2)
    dirs = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert dirs == ["step_000000004", "step_000000005"]


def test_async_checkpointer(tmp_path):
    _, state = _train(steps=2)
    d = str(tmp_path / "ck")
    ck = AsyncCheckpointer(d, keep=2)
    ck.save(1, state)
    ck.save(2, state)
    ck.wait()
    assert not ck.errors
    assert latest_step(d) == 2


def test_restart_determinism(tmp_path):
    """Training 10 straight == training 5, checkpointing, restoring, +5."""
    cfg = get_smoke_config("phi4-mini-3.8b")
    model = Model(cfg)
    opt_cfg = OptConfig(lr=1e-3, total_steps=10, warmup_steps=1)
    data = SyntheticLM(cfg.vocab_size, 32, 4, seed=7)
    step = jax.jit(make_train_step(model, opt_cfg))

    def run(state, lo, hi):
        out = []
        for i in range(lo, hi):
            b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            state, m = step(state, b)
            out.append(float(m["loss"]))
        return state, out

    s0 = TrainState(model.init(jax.random.PRNGKey(1)),
                    init_opt_state(model.init(jax.random.PRNGKey(1))))
    _, straight = run(s0, 0, 10)

    s1 = TrainState(model.init(jax.random.PRNGKey(1)),
                    init_opt_state(model.init(jax.random.PRNGKey(1))))
    s1, first = run(s1, 0, 5)
    d = str(tmp_path / "ck")
    save_checkpoint(d, 5, s1)
    s2, _, _ = restore_checkpoint(d, s1)
    _, second = run(s2, 5, 10)
    np.testing.assert_allclose(straight, first + second, rtol=1e-5)


def test_microbatch_accumulation_equivalence():
    """µbatch-accumulated grads equal full-batch grads (same loss path)."""
    cfg = get_smoke_config("glm4-9b")
    model = Model(cfg)
    opt_cfg = OptConfig(lr=1e-3, total_steps=5)
    data = SyntheticLM(cfg.vocab_size, 32, 8, seed=3)
    b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    p = model.init(jax.random.PRNGKey(0))
    s1 = TrainState(p, init_opt_state(p))
    s2 = TrainState(p, init_opt_state(p))
    full = make_train_step(model, opt_cfg, microbatches=1)
    micro = make_train_step(model, opt_cfg, microbatches=4)
    _, m1 = full(s1, b)
    _, m2 = micro(s2, b)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-2
    assert abs(float(m1["grad_norm"]) - float(m2["grad_norm"])) < 0.2


def test_cosine_lr_schedule():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(cosine_lr(cfg, jnp.int32(0))) < 0.2
    assert float(cosine_lr(cfg, jnp.int32(10))) == pytest.approx(1.0, abs=0.1)
    assert float(cosine_lr(cfg, jnp.int32(99))) == pytest.approx(0.1, abs=0.05)


def test_straggler_monitor():
    import time
    mon = StragglerMonitor(threshold=3.0, decay=0.5)
    for _ in range(4):
        mon.start()
        time.sleep(0.01)
        assert not mon.stop(0)
    mon.start()
    time.sleep(0.12)
    assert mon.stop(5)            # 12x the EMA -> flagged
    assert len(mon.events) == 1


def test_host_slice():
    ds = SyntheticLM(100, 8, 8, seed=0)
    b = ds.batch(0)
    parts = [host_slice(b, h, 4) for h in range(4)]
    recon = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(recon, b["tokens"])


def test_planner_strategy_decision():
    from repro.parallel.collective_planner import plan_softmax_strategy
    # huge sharded vocab rows -> gathering the logits is absurd: dist wins
    assert plan_softmax_strategy(65536, 151552, 16) == "dist"
    # tiny rows, tiny cols: either is fine but must be deterministic
    s1 = plan_softmax_strategy(1, 128, 16)
    assert s1 == plan_softmax_strategy(1, 128, 16)
