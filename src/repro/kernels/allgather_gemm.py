"""Fused all-gather-then-GEMM Pallas kernel with double-buffered gather.

C = all_gather(X, axis=K) @ W.  The gathered operand never materializes
in VMEM as a whole: the kernel streams it chunk-by-chunk (one chunk per
source shard) through a two-slot VMEM buffer with explicit async DMA —
the copy of gather chunk *i+1* is in flight while the MXU contracts
chunk *i*, which is exactly the compute–collective overlap the cost
model's ``overlap`` factor charges (``core/cost.py``): the chunk
transfer time (Eq. 1 MemLat) hides under the dependency-adjacent GEMM,
and only the per-chunk enqueue/issue cost (Eq. 3) stays exposed.

Two layers:

* :func:`streamed_gemm` — the Pallas kernel proper.  X lives in
  HBM/ANY; each K chunk of X and W is DMA'd into a ``buffers``-slot VMEM
  scratch and accumulated into an f32 VMEM accumulator.  ``buffers=2``
  (default) prefetches chunk *i+1* during the chunk-*i* matmul;
  ``buffers=1`` serializes copy → compute per chunk — the unoverlapped
  baseline the microbenchmark (``benchmarks/overlap_bench.py``) compares
  against to measure the *achieved* hidden fraction on real hardware.
* :func:`allgather_gemm` — the shard_map entry point: gathers the
  K-sharded activation with ``jax.lax.all_gather`` and streams the
  result through the kernel.  On a multi-chip TPU mesh the gather chunks
  arrive per-shard over ICI (ring all-gather), so the chunked DMA stream
  models the per-step shard arrival; the remote-DMA ring fusion
  (``make_async_remote_copy``) is the real-mesh follow-up noted in
  ROADMAP.md.

Correctness oracle: :func:`allgather_gemm_reference`
(``shard_map(all_gather) + dot``), pinned by ``tests/test_kernels.py``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; support
# both, and older releases lack the has_side_effects knob (it only guards
# the DMA-only kernel against DCE; the output here data-depends on every
# copy, so omitting it is safe).
_CP = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
try:
    _COMPILER_PARAMS = _CP(has_side_effects=True)
except TypeError:  # pragma: no cover - version compat
    _COMPILER_PARAMS = _CP()

# Static VMEM budget envelope: the (M, K, N, chunks) configurations the
# tests and the overlap microbenchmark drive the kernel with.  The
# ``vmem-budget`` lint (analysis/lint.py) evaluates the scratch shapes
# below against these (both buffer counts) at the arch GB capacity, so
# growing a config here without headroom fails CI statically.
BUDGET_SHAPES = (
    (256, 4096, 512, 8),   # overlap_bench.measure_hidden_fraction scale
    (128, 1024, 256, 8),   # overlap_bench.measure_double_buffer
    (128, 512, 256, 4),    # test_kernels streamed-GEMM cases (largest)
)
# ... and TPUMemorySpace.ANY -> MemorySpace.ANY.
_ANY = getattr(pltpu, "ANY", None)
if _ANY is None:  # pragma: no cover - version compat
    _ANY = pltpu.MemorySpace.ANY

__all__ = ["streamed_gemm", "allgather_gemm", "allgather_gemm_reference"]


def _kernel(x_hbm, w_hbm, o_ref, x_buf, w_buf, acc, x_sem, w_sem, *,
            n_chunks: int, kc: int, nbuf: int):
    """Accumulate sum_c X[:, c*kc:(c+1)*kc] @ W[c*kc:(c+1)*kc, :] with the
    chunk DMA stream double-buffered against the MXU when ``nbuf == 2``."""

    def x_copy(slot, c):
        return pltpu.make_async_copy(
            x_hbm.at[:, pl.ds(c * kc, kc)], x_buf.at[slot], x_sem.at[slot])

    def w_copy(slot, c):
        return pltpu.make_async_copy(
            w_hbm.at[pl.ds(c * kc, kc), :], w_buf.at[slot], w_sem.at[slot])

    acc[...] = jnp.zeros_like(acc)

    if nbuf == 2:
        # warm-up: start the first gather chunk before entering the loop
        x_copy(0, 0).start()
        w_copy(0, 0).start()

        def body(c, carry):
            slot = jax.lax.rem(c, 2)
            nxt = 1 - slot

            # gather chunk c+1 overlaps the matmul on chunk c
            @pl.when(c + 1 < n_chunks)
            def _prefetch():
                x_copy(nxt, c + 1).start()
                w_copy(nxt, c + 1).start()

            x_copy(slot, c).wait()
            w_copy(slot, c).wait()
            acc[...] += jnp.dot(x_buf[slot], w_buf[slot],
                                preferred_element_type=jnp.float32)
            return carry

        jax.lax.fori_loop(0, n_chunks, body, None)
    else:
        # single-buffered baseline: copy chunk c, wait, compute — the
        # fully exposed (serial) charging of the same chunk stream
        def body(c, carry):
            x_copy(0, c).start()
            w_copy(0, c).start()
            x_copy(0, c).wait()
            w_copy(0, c).wait()
            acc[...] += jnp.dot(x_buf[0], w_buf[0],
                                preferred_element_type=jnp.float32)
            return carry

        jax.lax.fori_loop(0, n_chunks, body, None)

    o_ref[...] = acc[...].astype(o_ref.dtype)


def streamed_gemm(x: jax.Array, w: jax.Array, *, chunks: int,
                  buffers: int = 2,
                  interpret: Optional[bool] = None) -> jax.Array:
    """x @ w with the K contraction streamed in ``chunks`` DMA chunks
    (one per gather shard); ``buffers=2`` double-buffers the stream.

    Requires ``K % chunks == 0`` (the all-gather entry always satisfies
    this: K = participants x local shard).  Working set: ``buffers`` X
    and W chunk slots plus the (M, N) f32 accumulator must fit VMEM —
    callers pick chunk counts accordingly (``analysis/lint.py`` budgets
    the scratch shapes below statically).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    if K % chunks != 0:
        raise ValueError(f"chunks={chunks} must divide K={K}")
    if buffers not in (1, 2):
        raise ValueError(f"buffers must be 1 or 2, got {buffers}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    kc = K // chunks

    return pl.pallas_call(
        functools.partial(_kernel, n_chunks=chunks, kc=kc, nbuf=buffers),
        in_specs=[
            pl.BlockSpec(memory_space=_ANY),
            pl.BlockSpec(memory_space=_ANY),
        ],
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((buffers, M, kc), x.dtype),
            pltpu.VMEM((buffers, kc, N), w.dtype),
            pltpu.VMEM((M, N), jnp.float32),
            pltpu.SemaphoreType.DMA((buffers,)),
            pltpu.SemaphoreType.DMA((buffers,)),
        ],
        compiler_params=_COMPILER_PARAMS,
        interpret=interpret,
    )(x, w)


def allgather_gemm(x_shard: jax.Array, w: jax.Array, *, axis_name: str,
                   buffers: int = 2,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Fused all-gather-then-GEMM under ``shard_map``: gather the
    K-sharded activation ``x_shard`` (M, K/P) over ``axis_name`` and
    contract the gathered (M, K) against the replicated ``w`` (K, N),
    streaming one chunk per source shard through the double-buffered
    kernel.  Numerically matches :func:`allgather_gemm_reference` up to
    f32 accumulation order."""
    p = jax.lax.psum(1, axis_name)
    xg = jax.lax.all_gather(x_shard, axis_name, axis=1, tiled=True)
    return streamed_gemm(xg, w, chunks=p, buffers=buffers,
                         interpret=interpret)


def allgather_gemm_reference(x_shard: jax.Array, w: jax.Array, *,
                             axis_name: str) -> jax.Array:
    """Unfused oracle: materialize the all-gather, then one dot."""
    xg = jax.lax.all_gather(x_shard, axis_name, axis=1, tiled=True)
    return jnp.dot(xg, w, preferred_element_type=jnp.float32).astype(
        x_shard.dtype)
