from .registry import (ARCH_IDS, SHAPES, Shape, all_cells, cells_for,
                       get_config, get_smoke_config)

__all__ = ["ARCH_IDS", "SHAPES", "Shape", "all_cells", "cells_for",
           "get_config", "get_smoke_config"]
