from . import collective_planner, compression, sharding

__all__ = ["collective_planner", "compression", "sharding"]
