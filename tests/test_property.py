"""Hypothesis property-based tests on the system's invariants."""
import math

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dependency: property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import gemm_layernorm, gemm_softmax
from repro.core.collectives import collective_cost
from repro.core.hardware import cloud, edge
from repro.core.ir import MappingSpec, evaluate_mapping
from repro.core.mapping import Loop, TileNode, Tiling

DIM = st.sampled_from([1, 4, 64, 128, 256, 512, 1024])
TILES = st.sampled_from([1, 2, 4, 8, 16])
WL = st.sampled_from([gemm_softmax, gemm_layernorm])
VARIANT = st.sampled_from(["unfused", "fused_epilogue", "fused_std",
                           "fused_dist"])


@settings(max_examples=60, deadline=None)
@given(M=DIM, N=DIM, K=st.sampled_from([64, 128]), m_tiles=TILES,
       k_tiles=st.sampled_from([1, 2]), wl=WL, variant=VARIANT,
       gran=st.sampled_from(["tile", "stats"]),
       sched=st.sampled_from(["sequential", "pipelined"]))
def test_cost_model_invariants(M, N, K, m_tiles, k_tiles, wl, variant, gran,
                               sched):
    """Every evaluated mapping has nonnegative finite latency/energy; the
    breakdown sums to <= total latency (CS/OS are additive parts);
    energy breakdown sums to the total."""
    co = wl(M, N, K)
    arch = edge()
    r = evaluate_mapping(co, arch, MappingSpec(
        variant=variant, m_tiles=m_tiles, k_tiles=k_tiles,
        collective_gran=gran, schedule=sched))
    assert math.isfinite(r.latency) and r.latency > 0
    assert math.isfinite(r.energy_pj) and r.energy_pj > 0
    assert sum(r.cost.energy_breakdown.values()) == \
        __import__("pytest").approx(r.energy_pj, rel=1e-6)
    assert all(v >= 0 for v in r.cost.lat_breakdown.values())
    assert all(v >= 0 for v in r.cost.energy_breakdown.values())


@settings(max_examples=60, deadline=None)
@given(dv=st.floats(min_value=1.0, max_value=1e9),
       p=st.sampled_from([2, 3, 4, 5, 6, 8, 16, 64, 256]),
       col=st.sampled_from(["AllReduce", "AllGather", "ReduceScatter",
                            "Gather", "Broadcast", "AllToAll"]))
def test_collective_cost_properties(dv, p, col):
    """Volume scales linearly in DV; is monotone in participants; hops are
    positive."""
    noc = cloud().cluster_noc
    c1 = collective_cost(col, dv, p, noc)
    c2 = collective_cost(col, 2 * dv, p, noc)
    assert c2.volume_bytes == __import__("pytest").approx(
        2 * c1.volume_bytes, rel=1e-9)
    assert c1.hops >= 1
    assert c1.volume_bytes < dv * 2 + 1e-6  # never exceeds 2*DV (AR bound)


@settings(max_examples=60, deadline=None)
@given(dv=st.floats(min_value=0.0, max_value=1e9),
       ps=st.lists(st.integers(min_value=1, max_value=256), min_size=1,
                   max_size=16),
       col=st.sampled_from(["AllReduce", "AllGather", "ReduceScatter",
                            "Gather", "Broadcast", "AllToAll"]),
       arch_fn=st.sampled_from([edge, cloud]),
       noc_name=st.sampled_from(["cluster_noc", "core_noc"]))
def test_tabulated_collective_bitwise_parity(dv, ps, col, arch_fn, noc_name):
    """The tabulated array path is bit-identical (==, not approx) to the
    scalar-P formulas for arbitrary participant mixes on the preset NoCs,
    including non-pow2 P and the degenerate (1,1) core NoC of tpu_v5e."""
    import numpy as np
    from repro.core.hardware import tpu_v5e
    noc = getattr(arch_fn(), noc_name)
    for n in (noc, tpu_v5e().core_noc):
        P = np.asarray(ps)
        arr = collective_cost(col, dv, P, n)
        for j, p in enumerate(ps):
            sc = collective_cost(col, dv, p, n)
            assert arr.volume_bytes[j] == sc.volume_bytes
            if p > 1 and dv > 0:   # scalar short-circuits steps/hops to 0
                assert arr.hops[j] == sc.hops
                assert arr.steps[j] == sc.steps


@settings(max_examples=60, deadline=None)
@given(M=DIM, N=DIM, K=st.sampled_from([64, 128]), m_tiles=TILES,
       k_tiles=st.sampled_from([1, 2]), wl=WL,
       variant=st.sampled_from(["fused_std", "fused_dist"]),
       sched=st.sampled_from(["sequential", "pipelined"]),
       ov_lo=st.floats(min_value=0.0, max_value=1.0),
       ov_hi=st.floats(min_value=0.0, max_value=1.0))
def test_overlap_monotone_and_serial_identity(M, N, K, m_tiles, k_tiles, wl,
                                              variant, sched, ov_lo, ov_hi):
    """Latency is monotone non-increasing in the overlap factor on any
    mapping, and overlap=0.0 is *bitwise* the default-spec result (the
    serial-identity guarantee the 48-pair suite pins per pair)."""
    if ov_lo > ov_hi:
        ov_lo, ov_hi = ov_hi, ov_lo
    co = wl(M, N, K)
    arch = cloud()

    def run(ov):
        return evaluate_mapping(co, arch, MappingSpec(
            variant=variant, m_tiles=m_tiles, k_tiles=k_tiles,
            schedule=sched, overlap=ov))

    base = run(0.0)
    default = evaluate_mapping(co, arch, MappingSpec(
        variant=variant, m_tiles=m_tiles, k_tiles=k_tiles, schedule=sched))
    assert base.latency == default.latency          # bitwise
    assert base.energy_pj == default.energy_pj      # bitwise
    lo, hi = run(ov_lo), run(ov_hi)
    assert hi.latency <= lo.latency * (1 + 1e-12)
    assert hi.latency <= base.latency * (1 + 1e-12)
    assert hi.energy_pj == base.energy_pj  # overlap moves time, not joules


@settings(max_examples=60, deadline=None)
@given(dv=st.floats(min_value=1.0, max_value=1e9),
       p=st.sampled_from([2, 4, 8, 16, 256]),
       col=st.sampled_from(["AllReduce", "AllGather", "ReduceScatter",
                            "AllToAll"]),
       ov=st.floats(min_value=0.0, max_value=1.0),
       comp_ratio=st.floats(min_value=0.0, max_value=4.0))
def test_overlapped_collective_seconds_properties(dv, p, col, ov,
                                                  comp_ratio):
    """The overlapped cost stays within [exposed, serial], is exact at
    the endpoints, and the hidden share never exceeds either the
    hideable wire time or the adjacent compute window."""
    from repro.core.collectives import (collective_overlap_terms,
                                        collective_seconds,
                                        overlapped_collective_seconds)
    noc = cloud().cluster_noc
    hideable, exposed = collective_overlap_terms(col, dv, p, noc)
    serial = collective_seconds(col, dv, p, noc)
    comp = hideable * comp_ratio
    t = overlapped_collective_seconds(col, dv, p, noc, overlap=ov,
                                      compute_seconds=comp)
    assert exposed - 1e-15 <= t <= serial + 1e-15
    hidden = serial - t
    assert hidden <= ov * min(hideable, comp) + 1e-15
    assert overlapped_collective_seconds(col, dv, p, noc) == serial


@settings(max_examples=40, deadline=None)
@given(n=st.integers(min_value=1, max_value=50), seed=st.integers(0, 2**31),
       rounding=st.sampled_from([None, 1, 2]))
def test_pareto3_front_dominated_free(n, seed, rounding):
    """pareto_merge3 fronts stay mutually non-dominated (and complete
    w.r.t. an O(n^2) check) under random point clouds, with and without
    duplicated/tied coordinates."""
    import numpy as np
    from repro.core.batcheval import pareto_merge3
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 3))
    if rounding is not None:
        pts = np.round(pts, rounding)   # force ties and duplicates
    front = pareto_merge3([(p[0], p[1], p[2], i) for i, p in enumerate(pts)])
    assert front
    ids = {f[3] for f in front}
    for a in front:
        for b in front:
            if a is not b:
                assert not (a[0] <= b[0] and a[1] <= b[1] and a[2] >= b[2])
    # completeness: every excluded point is weakly dominated by the front
    for i, p in enumerate(pts):
        if i in ids:
            continue
        assert any(f[0] <= p[0] and f[1] <= p[1] and f[2] >= p[2]
                   for f in front), i


@settings(max_examples=50, deadline=None)
@given(size=st.integers(min_value=1, max_value=10_000),
       t_gb=TILES, t_ob=TILES, sp=st.sampled_from([1, 2, 4]))
def test_tiling_consistency(size, t_gb, t_ob, sp):
    """tile_below chains: leaf tile * all factors >= dim size, and
    tile_at(GB) == size (root granularity)."""
    tiling = Tiling({"X": size},
                    temporal={"GB": {"X": t_gb}, "OB": {"X": t_ob}},
                    spatial={"GB": {"X": sp}})
    leaf = tiling.leaf_tile("X")
    assert leaf * t_gb * t_ob * sp >= size
    assert tiling.tile_at("X", "GB") == size
    assert tiling.tile_below("X", "OB") == leaf


@settings(max_examples=40, deadline=None)
@given(factors=st.lists(st.sampled_from([1, 2, 4, 8]), min_size=1,
                        max_size=4),
       tensor_dims=st.sets(st.sampled_from(["M", "N", "K"]), min_size=1))
def test_fetch_reuse_bounds(factors, tensor_dims):
    """Fetches are between 1 and total iterations, and equal total
    iterations when the innermost loop touches the tensor."""
    dims = ["M", "N", "K", "L"]
    loops = [Loop(dims[i % 4], f) for i, f in enumerate(factors)]
    node = TileNode(level="GB", index=0, loops=loops)
    fetches = node.tensor_fetches(tuple(tensor_dims))
    total = 1
    for f in factors:
        total *= f
    assert 1 <= fetches <= total
    if loops[-1].dim in tensor_dims:
        assert fetches == total


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       step=st.integers(min_value=0, max_value=10_000))
def test_data_pipeline_deterministic(seed, step):
    """Seekable determinism: same (seed, step) -> identical batch."""
    import numpy as np
    from repro.train.data import SyntheticLM
    ds = SyntheticLM(vocab_size=997, seq_len=32, global_batch=4, seed=seed)
    b1 = ds.batch(step)
    b2 = SyntheticLM(vocab_size=997, seq_len=32, global_batch=4,
                     seed=seed).batch(step)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert np.array_equal(b1["labels"], b2["labels"])
    assert b1["tokens"].max() < 997
    # labels are next-token shifted
    assert np.array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


@settings(max_examples=30, deadline=None)
@given(shape=st.sampled_from([(8,), (4, 16), (3, 5, 7)]),
       scale=st.floats(min_value=1e-3, max_value=1e3))
def test_int8_compression_error_feedback(shape, scale):
    """Quantize-dequantize error is bounded by the step size, and error
    feedback makes the two-step accumulated error smaller than naive."""
    import numpy as np
    import jax.numpy as jnp
    from repro.parallel.compression import compress_with_feedback, quantize_int8
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)
    q, s = quantize_int8(g)
    err = float(jnp.abs(q.astype(jnp.float32) * s - g).max())
    assert err <= float(s) * 0.5 + 1e-6
    dq, e = compress_with_feedback(g, jnp.zeros_like(g))
    # feedback carries exactly the residual
    assert float(jnp.abs((dq + e) - g).max()) < 1e-5 * max(1.0, scale)
