"""Collective-operation hop/volume models (COMET §IV-B, Eq. 3/4).

The paper uses the recursive doubling/halving algorithms [30] to compute
both the total number of hops and the total data volume moved for each
collective type.  Participants are peer memory instances at one level of
the hierarchy (e.g. the GBs of all clusters), laid out row-major on the
level's NoC mesh; hop distances are Manhattan distances between exchange
partners.

Conventions
-----------
``data_volume`` (DV) passed in is the *logical tensor size in bytes* on
which the collective operates (the full tensor for All-Reduce / the
gathered result for All-Gather, matching the paper's Tensor annotation on
CO nodes).  Each model returns:

    CollectiveCost(volume_bytes, hops, steps)

where ``volume_bytes`` is the total bytes moved across the NoC per
participant (the busiest node's traffic, which Eq. 3 charges), and
``hops`` is the summed hop distance of its exchange schedule.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Tuple

import numpy as np

from .hardware import NoCParams
from .numerics import is_array

__all__ = [
    "CollectiveCost",
    "collective_cost",
    "noc_latency",
    "COLLECTIVE_TYPES",
]

COLLECTIVE_TYPES = (
    "AllReduce",
    "AllGather",
    "ReduceScatter",
    "Gather",
    "Broadcast",
    "AllToAll",
)


@dataclass(frozen=True)
class CollectiveCost:
    volume_bytes: float   # bytes through the busiest participant
    hops: int             # summed exchange-partner hop distance
    steps: int            # number of communication steps


def _step_distances(noc: NoCParams, participants: int) -> Tuple[int, ...]:
    """Manhattan distance of the partner at linear offset 2^i, for each
    recursive-doubling step i (log2 P steps).  Non-power-of-two participant
    counts are rounded up to the next power of two (standard dissemination
    fallback)."""
    if participants <= 1:
        return ()
    steps = max(1, math.ceil(math.log2(participants)))
    return tuple(
        noc.manhattan(0, min((1 << i), noc.num_nodes - 1) if noc.num_nodes > 1 else 0)
        or 1
        for i in range(steps)
    )


def collective_cost(
    col_type: str,
    data_volume: float,
    participants: int,
    noc: NoCParams,
) -> CollectiveCost:
    """Volume/hops for one collective over ``participants`` peers.

    Recursive halving (Reduce-Scatter): step i exchanges DV/2^(i+1);
    recursive doubling (All-Gather): step i exchanges DV*2^i/P.
    All-Reduce = RS + AG  => 2*DV*(P-1)/P volume.
    Gather/Broadcast: tree over log2 P steps, total (P-1)/P * DV through
    the root.  All-to-all: each node exchanges DV*(P-1)/P in P-1 direct
    transfers (paired exchange schedule).

    ``participants`` may be a NumPy int array (the batched engine folds
    the spatial-fanout axes into its grid, so CO nodes carry one
    participant count per grid point); the result is then a
    :class:`CollectiveCost` of arrays, computed per unique participant
    count through this same scalar-P code so both paths share one formula.
    """
    if is_array(participants):
        return _collective_cost_array(col_type, data_volume, participants,
                                      noc)
    P = int(participants)
    if P <= 1:
        return CollectiveCost(0.0, 0, 0)
    if is_array(data_volume):
        if np.all(data_volume <= 0):
            return CollectiveCost(0.0, 0, 0)
    elif data_volume <= 0:
        return CollectiveCost(0.0, 0, 0)
    if col_type not in COLLECTIVE_TYPES:
        raise ValueError(f"unknown collective type {col_type!r}")

    dists = _step_distances(noc, P)
    steps = len(dists)
    shard = data_volume / P

    if col_type == "ReduceScatter":
        # recursive halving: volumes DV/2, DV/4, ... DV/P
        vol = sum(data_volume / (1 << (i + 1)) for i in range(steps))
        hops = sum(dists)
    elif col_type == "AllGather":
        # recursive doubling: volumes DV/P, 2DV/P, ... DV/2
        vol = sum(shard * (1 << i) for i in range(steps))
        hops = sum(dists)
    elif col_type == "AllReduce":
        rs = collective_cost("ReduceScatter", data_volume, P, noc)
        ag = collective_cost("AllGather", data_volume, P, noc)
        return CollectiveCost(rs.volume_bytes + ag.volume_bytes,
                              rs.hops + ag.hops, rs.steps + ag.steps)
    elif col_type == "Gather":
        # binomial tree toward the root; root receives (P-1)/P * DV
        vol = data_volume * (P - 1) / P
        hops = sum(dists)
    elif col_type == "Broadcast":
        vol = data_volume * (P - 1) / P
        hops = sum(dists)
    elif col_type == "AllToAll":
        vol = data_volume * (P - 1) / P
        # P-1 paired exchanges; average Manhattan distance on the mesh
        avg = _mesh_avg_distance(noc)
        hops = int(round(avg * (P - 1)))
        steps = P - 1
    else:  # pragma: no cover
        raise AssertionError(col_type)

    if is_array(vol):
        # Batched path: grid points with dv <= 0 move nothing (the scalar
        # path short-circuits those to a zero CollectiveCost above).
        vol = np.where(np.asarray(data_volume) > 0, vol, 0.0)
        return CollectiveCost(vol, int(hops), steps)
    return CollectiveCost(float(vol), int(hops), steps)


def _collective_cost_array(col_type: str, data_volume, participants,
                           noc: NoCParams) -> CollectiveCost:
    """Batched participants: evaluate the scalar-P formulas once per unique
    participant count and mask-select the results.  Participant axes come
    from small spatial-fanout candidate sets (a handful of unique values),
    so this is a short loop over exact re-executions of the scalar path —
    results are bit-identical elementwise."""
    P = np.asarray(participants)
    dv = np.asarray(data_volume, dtype=np.float64)
    shape = np.broadcast_shapes(P.shape, dv.shape)
    vol = np.zeros(shape)
    hops = np.zeros(shape, dtype=np.int64)
    steps = np.zeros(shape, dtype=np.int64)
    for p in np.unique(P):
        p = int(p)
        if p <= 1:
            continue        # zero-cost, matching the scalar short-circuit
        cp = collective_cost(col_type, data_volume, p, noc)
        sel = P == p
        vol = np.where(sel, cp.volume_bytes, vol)
        hops = np.where(sel, cp.hops, hops)
        steps = np.where(sel, cp.steps, steps)
    vol = np.where(dv > 0, vol, 0.0)
    return CollectiveCost(vol, hops, steps)


def _mesh_avg_distance(noc: NoCParams) -> float:
    r, c = noc.mesh
    if r * c <= 1:
        return 1.0
    # mean Manhattan distance between distinct nodes of an r x c mesh
    total = 0
    for a in range(r * c):
        for b in range(r * c):
            if a != b:
                total += noc.manhattan(a, b)
    return total / (r * c * (r * c - 1))


def noc_latency(cost: CollectiveCost, noc: NoCParams) -> float:
    """Eq. 3: NoCLat = t_router * hops + t_enq * DV / W  (seconds)."""
    if is_array(cost.volume_bytes):
        lat = (noc.t_router * cost.hops
               + noc.t_enq * (cost.volume_bytes / noc.channel_width))
        return np.where(cost.volume_bytes > 0, lat, 0.0)
    if cost.volume_bytes <= 0:
        return 0.0
    return noc.t_router * cost.hops + noc.t_enq * (cost.volume_bytes / noc.channel_width)
