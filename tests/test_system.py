"""End-to-end behaviour tests: training driver (with checkpoint restart),
serving engine (continuous batching), and decode/prefill consistency for
the stateful families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import Model


def test_train_loop_end_to_end(tmp_path):
    from repro.launch.train import train_loop
    from repro.train.optimizer import OptConfig
    model = Model(get_smoke_config("phi4-mini-3.8b"))
    out = train_loop(model, steps=12, batch=4, seq=48,
                     opt_cfg=OptConfig(lr=2e-3, total_steps=12,
                                       warmup_steps=2),
                     ckpt_dir=str(tmp_path / "ck"), ckpt_every=5,
                     log_every=100)
    assert out["steps_done"] == 12
    assert np.isfinite(out["final_loss"])
    # restart continues from the checkpoint
    out2 = train_loop(model, steps=14, batch=4, seq=48,
                      ckpt_dir=str(tmp_path / "ck"), ckpt_every=5,
                      log_every=100)
    assert out2["steps_done"] == 2      # 12 -> 14 only


def test_serve_engine_continuous_batching():
    from repro.serve.engine import Request, ServeEngine
    cfg = get_smoke_config("glm4-9b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(
        0, cfg.vocab_size, size=12).astype(np.int32), max_new_tokens=5)
        for i in range(7)]                      # 7 requests through 3 slots
    eng = ServeEngine(model, params, batch_size=3, cache_len=48,
                      prompt_len=16, plan_warmup=False)
    done = eng.run(reqs)
    assert all(len(r.output) == 5 for r in done)
    assert eng.stats["tokens_out"] == 35
    # slots are reused, but refilled slots ARE re-prefilled (batched per
    # step): 7 requests through 3 slots in same-length waves = 3 prefills
    assert eng.stats["prefill_calls"] == 3


def test_serve_engine_refill_matches_serial_decoding():
    """Regression for the continuous-batching bug: slots refilled
    mid-decode used to inherit the previous occupant's KV cache and last
    token (never prefilled).  With the queue exceeding batch_size, every
    request's output must match decoding it alone through a 1-slot
    engine."""
    from repro.serve.engine import Request, ServeEngine
    cfg = get_smoke_config("glm4-9b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=12).astype(np.int32)
               for _ in range(7)]
    # staggered lengths so slots free at different steps (un-batched
    # refills as well as the same-step batched case)
    new_tokens = [5, 3, 4, 6, 2, 5, 3]
    reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=n)
            for i, (p, n) in enumerate(zip(prompts, new_tokens))]
    eng = ServeEngine(model, params, batch_size=3, cache_len=48,
                      prompt_len=16, plan_warmup=False)
    eng.run(reqs)

    one = ServeEngine(model, params, batch_size=1, cache_len=48,
                      prompt_len=16, plan_warmup=False)
    for i, (p, n) in enumerate(zip(prompts, new_tokens)):
        ref = Request(rid=100 + i, prompt=p.copy(), max_new_tokens=n)
        one.run([ref])
        assert reqs[i].output == ref.output, f"request {i} diverged"


def test_ssm_decode_equals_prefill_continuation():
    """Mamba-2: decoding one token after prefill == full-seq forward."""
    cfg = get_smoke_config("mamba2-130m")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    toks = jnp.asarray(np.arange(B * S).reshape(B, S) % cfg.vocab_size,
                       jnp.int32)
    full = model.logits(params, {"tokens": toks}).astype(jnp.float32)
    _, cache = model.prefill(params, {"tokens": toks[:, :S - 1]},
                             cache_len=S + 2)
    lg, _ = model.decode(params, cache, toks[:, S - 1:S])
    np.testing.assert_allclose(np.asarray(lg[:, 0].astype(jnp.float32)),
                               np.asarray(full[:, S - 1]), atol=3e-2,
                               rtol=3e-2)


def test_hybrid_window_ring_buffer():
    """Hymba: decode beyond the window uses the ring buffer correctly —
    prediction must match the teacher-forced forward at every step."""
    cfg = get_smoke_config("hymba-1.5b").with_(window=8)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 20
    toks = jnp.asarray(np.arange(B * S).reshape(B, S) % cfg.vocab_size,
                       jnp.int32)
    full = model.logits(params, {"tokens": toks}).astype(jnp.float32)
    _, cache = model.prefill(params, {"tokens": toks[:, :12]}, cache_len=24)
    for t in range(12, 16):
        lg, cache = model.decode(params, cache, toks[:, t:t + 1])
        np.testing.assert_allclose(
            np.asarray(lg[:, 0].astype(jnp.float32)),
            np.asarray(full[:, t]), atol=5e-2, rtol=5e-2)


def test_mla_absorbed_decode_matches_forward():
    """DeepSeek MLA: the absorbed (latent-space) decode must agree with the
    decompressed training attention.  f32 so the check is exact (the two
    paths contract in different orders; bf16 noise is checked loosely by
    the per-arch smoke test instead)."""
    cfg = get_smoke_config("deepseek-v3-671b").with_(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jnp.asarray(np.arange(B * S).reshape(B, S) % cfg.vocab_size,
                       jnp.int32)
    full = model.logits(params, {"tokens": toks}).astype(jnp.float32)
    _, cache = model.prefill(params, {"tokens": toks[:, :S - 1]},
                             cache_len=S + 2)
    lg, _ = model.decode(params, cache, toks[:, S - 1:S])
    np.testing.assert_allclose(np.asarray(lg[:, 0].astype(jnp.float32)),
                               np.asarray(full[:, S - 1]), atol=1e-4,
                               rtol=1e-4)
