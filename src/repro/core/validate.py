"""Memory-fit validation (COMET Fig. 3: 'Validation' stage).

Before a mapping instance is converted to the IR and costed, COMET checks
that all tensors staged at each memory level fit within that level's
capacity (×2 for double buffering, §IV-B).  Invalid mappings are rejected
by the mapping-instance generator / search.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from .hardware import Arch
from .mapping import CollectiveNode, Node, TileNode, Tiling
from .numerics import vmin
from .workload import TensorSpec

__all__ = ["validate_tree", "validate_and_headroom", "validity_mask",
           "validity_and_headroom", "validity_headroom_levels",
           "validate_headroom_levels", "capacity_headroom",
           "ValidationError", "residency_report"]


class ValidationError(Exception):
    pass


def _staged_tensors(node: TileNode) -> List[str]:
    """Tensors resident at this node: its own i/o plus everything its
    direct children exchange (fused intermediates live here)."""
    names = set(node.input_tensors) | set(node.output_tensors)
    for ch in node.children:
        if isinstance(ch, TileNode):
            names |= set(ch.input_tensors) | set(ch.output_tensors)
        elif isinstance(ch, CollectiveNode):
            names.add(ch.tensor)
    return sorted(names)


def residency_report(node: Node, arch: Arch, tiling: Tiling,
                     tensors: Dict[str, TensorSpec]) -> List[Tuple[str, str, float, float]]:
    """[(level, label, resident_bytes, capacity_bytes)] for every TileNode."""
    out: List[Tuple[str, str, float, float]] = []

    def rec(n: Node) -> None:
        if not isinstance(n, TileNode):
            return
        staged = _staged_tensors(n)
        dbl = 2.0 if arch.level(n.level).double_buffered else 1.0
        resident = n.extra_resident_bytes * 1.0
        for t in staged:
            if t in n.bypass_tensors:
                continue
            resident = resident + tiling.tensor_tile_bytes(
                tensors[t], n.level, below=True) * dbl
        if n.level == "OB":
            # split: inputs -> IB+WB, outputs -> OB
            cap = (arch.ib.size_bytes + arch.wb.size_bytes + arch.ob.size_bytes)
        else:
            cap = arch.level(n.level).size_bytes
        out.append((n.level, n.label or f"T[{n.level}]^{n.index}", resident, cap))
        for ch in n.children:
            rec(ch)

    rec(node)
    return out


def validate_tree(node: Node, arch: Arch, tiling: Tiling,
                  tensors: Dict[str, TensorSpec], *, raise_on_fail: bool = False) -> bool:
    """True iff every TileNode's staged tensors fit its level capacity."""
    tiling.validate()
    for level, label, resident, cap in residency_report(node, arch, tiling, tensors):
        if level == "DRAM":
            continue  # DRAM holds full tensors by construction
        if resident > cap:
            if raise_on_fail:
                raise ValidationError(
                    f"{label}: {resident/1024:.1f} KiB > capacity {cap/1024:.1f} KiB")
            return False
    return True


def validity_mask(node: Node, arch: Arch, tiling: Tiling,
                  tensors: Dict[str, TensorSpec]) -> np.ndarray:
    """Batched analogue of :func:`validate_tree` for array-valued tilings:
    elementwise True where the tiling is not over-factored AND every
    TileNode's staged tensors fit its level capacity (exactly the grid
    points for which the per-spec path would return True rather than
    raising or returning False)."""
    return validity_and_headroom(node, arch, tiling, tensors)[0]


def validity_and_headroom(node: Node, arch: Arch, tiling: Tiling,
                          tensors: Dict[str, TensorSpec]
                          ) -> Tuple[np.ndarray, np.ndarray]:
    """(validity mask, capacity headroom) from one residency walk.

    Headroom is the mapping's worst relative slack: ``min`` over all
    non-DRAM TileNodes of ``(capacity - resident) / capacity``.  1.0 means
    the buffers are untouched, 0.0 exactly full, negative over capacity
    (such points are also invalid).  It is the third objective channel of
    the provisioning-study Pareto fronts (``objective='pareto3'``)."""
    ok, hr, _levels = validity_headroom_levels(node, arch, tiling, tensors)
    return ok, hr


def validity_headroom_levels(node: Node, arch: Arch, tiling: Tiling,
                             tensors: Dict[str, TensorSpec]
                             ) -> Tuple[np.ndarray, np.ndarray,
                                        Dict[str, np.ndarray]]:
    """(validity mask, folded headroom, per-level headroom) from one
    residency walk.

    The per-level dict maps each non-DRAM memory level present in the
    tree (``'GB'`` — the per-cluster global buffer — and ``'OB'`` — the
    per-core IB+WB+OB budget) to the worst relative slack among that
    level's TileNodes only, so provisioning studies can size the cluster
    and core buffers independently instead of reading the folded
    worst-over-all-levels scalar.  The folded headroom equals the ``min``
    across the per-level values (bit-identical to the historical
    scalar)."""
    ok = np.asarray(tiling.overfactor_mask())
    levels: Dict[str, np.ndarray] = {}
    for level, _label, resident, cap in residency_report(node, arch, tiling,
                                                         tensors):
        if level == "DRAM":
            continue  # DRAM holds full tensors by construction
        ok = np.logical_and(ok, resident <= cap)
        frac = (cap - np.asarray(resident, dtype=np.float64)) / cap
        prev = levels.get(level)
        levels[level] = frac if prev is None else np.minimum(prev, frac)
    hr = np.asarray(1.0)
    for frac in levels.values():
        hr = np.minimum(hr, frac)
    return ok, hr, levels


def validate_and_headroom(node: Node, arch: Arch, tiling: Tiling,
                          tensors: Dict[str, TensorSpec]
                          ) -> Tuple[bool, float]:
    """Scalar-path fusion of :func:`validate_tree` and
    :func:`capacity_headroom`: one residency walk yields both the
    validity verdict and the headroom (the per-spec evaluation hot path
    must not pay the tensor-tile walk twice).  Raises like
    ``validate_tree`` for inconsistent tilings."""
    valid, hr, _levels = validate_headroom_levels(node, arch, tiling, tensors)
    return valid, hr


def validate_headroom_levels(node: Node, arch: Arch, tiling: Tiling,
                             tensors: Dict[str, TensorSpec]
                             ) -> Tuple[bool, float, Dict[str, float]]:
    """Scalar-path analogue of :func:`validity_headroom_levels`: one
    residency walk yields (valid, folded headroom, per-level headroom).
    The per-level dict holds each non-DRAM level's own worst slack (GB =
    cluster buffer, OB = per-core IB+WB+OB budget); the folded value is
    their ``min``.  Raises like ``validate_tree`` for inconsistent
    tilings."""
    tiling.validate()
    valid = True
    levels: Dict[str, float] = {}
    for level, _label, resident, cap in residency_report(node, arch, tiling,
                                                         tensors):
        if level == "DRAM":
            continue
        if resident > cap:
            valid = False
        frac = (cap - resident) / cap
        levels[level] = frac if level not in levels \
            else vmin(levels[level], frac)
    hr = 1.0
    for frac in levels.values():
        hr = vmin(hr, frac)
    return valid, hr, levels


def capacity_headroom(node: Node, arch: Arch, tiling: Tiling,
                      tensors: Dict[str, TensorSpec]) -> float:
    """Scalar-path capacity headroom: ``min`` over non-DRAM TileNodes of
    ``(capacity - resident) / capacity`` (see
    :func:`validity_and_headroom`); plain Python float for scalar
    tilings."""
    hr = 1.0
    for level, _label, resident, cap in residency_report(node, arch, tiling,
                                                         tensors):
        if level == "DRAM":
            continue
        hr = vmin(hr, (cap - resident) / cap)
    return hr
