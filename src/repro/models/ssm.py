"""Mamba-2 (SSD) mixer: in-proj -> causal conv1d -> SSD chunk scan ->
gated norm -> out-proj, with a constant-size recurrent state for decode.

The SSD scan itself is a compound operation (chunk GEMMs + decay SIMD ops);
train/prefill route through the chunked algorithm (Pallas kernel or the
chunked jnp reference, chunk length COMET-tuned), decode is the O(1)
recurrence h' = exp(dA) h + B ⊗ x·dt.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from .config import ModelConfig
from .param import ParamSpec

F32 = jnp.float32

__all__ = ["ssm_specs", "ssm_train", "ssm_prefill", "ssm_decode",
           "init_ssm_cache"]


def ssm_specs(cfg: ModelConfig, L: int) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    di, ng, ns, nh = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    cd = cfg.conv_dim
    proj_out = 2 * di + 2 * ng * ns + nh     # z, x, B, C, dt
    return {
        "in_proj": ParamSpec((L, d, proj_out), ("layer", "embed", "inner"), dtype=cfg.dtype),
        "conv_w": ParamSpec((L, cfg.conv_kernel, cd), ("layer", None, "inner"),
                            scale=1.0, dtype=cfg.dtype),
        "conv_b": ParamSpec((L, cd), ("layer", "inner"), init="zeros", dtype=cfg.dtype),
        "A_log": ParamSpec((L, nh), ("layer", None), init="zeros", dtype="float32"),
        "dt_bias": ParamSpec((L, nh), ("layer", None), init="zeros", dtype="float32"),
        "D": ParamSpec((L, nh), ("layer", None), init="ones", dtype="float32"),
        "norm_scale": ParamSpec((L, di), ("layer", "inner"), init="ones", dtype=cfg.dtype),
        "out_proj": ParamSpec((L, di, d), ("layer", "inner", "embed"), dtype=cfg.dtype),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    di, ng, ns, nh = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    z = proj[..., :di]
    xin = proj[..., di:2 * di]
    Bm = proj[..., 2 * di:2 * di + ng * ns]
    Cm = proj[..., 2 * di + ng * ns:2 * di + 2 * ng * ns]
    dt = proj[..., 2 * di + 2 * ng * ns:]
    return z, xin, Bm, Cm, dt


def _gated_norm(cfg: ModelConfig, scale: jax.Array, y: jax.Array,
                z: jax.Array) -> jax.Array:
    yf = (y * jax.nn.silu(z)).astype(F32)
    ms = (yf * yf).mean(-1, keepdims=True)
    return (yf * jax.lax.rsqrt(ms + cfg.norm_eps) * scale.astype(F32)).astype(y.dtype)


def _conv1d(cfg: ModelConfig, w: jax.Array, b: jax.Array, u: jax.Array,
            prev: Optional[jax.Array] = None) -> jax.Array:
    """Causal depthwise conv over (B, S, C).  w: (K, C)."""
    K = cfg.conv_kernel
    pad = prev if prev is not None else jnp.zeros(
        (u.shape[0], K - 1, u.shape[2]), u.dtype)
    up = jnp.concatenate([pad, u], axis=1)
    out = sum(up[:, i:i + u.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _ssd_inputs(cfg: ModelConfig, p, xbc_conv: jax.Array, dt_raw: jax.Array):
    """Split conv output & build (xdt, dA, B, C) for the SSD scan."""
    di, ng, ns, nh, hp = (cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state,
                          cfg.ssm_nheads, cfg.ssm_headdim)
    B_, S = dt_raw.shape[0], dt_raw.shape[1]
    xin = xbc_conv[..., :di]
    Bm = xbc_conv[..., di:di + ng * ns].reshape(B_, S, ng, ns)
    Cm = xbc_conv[..., di + ng * ns:].reshape(B_, S, ng, ns)
    dt = jax.nn.softplus(dt_raw.astype(F32) + p["dt_bias"].astype(F32))  # (B,S,nh)
    A = -jnp.exp(p["A_log"].astype(F32))                                  # (nh,)
    dA = dt * A                                                           # (B,S,nh)
    xh = xin.reshape(B_, S, nh, hp)
    xdt = xh.astype(F32) * dt[..., None]
    # broadcast groups to heads
    rep = nh // ng
    Bh = jnp.repeat(Bm, rep, axis=2)
    Ch = jnp.repeat(Cm, rep, axis=2)
    return xh, xdt, dA, Bh, Ch


def _ssd_scan(cfg: ModelConfig, xdt, dA, Bh, Ch) -> jax.Array:
    """(B,S,nh,hp) x (B,S,nh) x (B,S,nh,ns) -> y (B,S,nh,hp)."""
    B_, S, nh, hp = xdt.shape
    ns = Bh.shape[-1]
    # flatten batch x heads for the kernel layout
    xk = xdt.transpose(0, 2, 1, 3).reshape(B_ * nh, S, hp)
    dk = dA.transpose(0, 2, 1).reshape(B_ * nh, S)
    bk = Bh.transpose(0, 2, 1, 3).reshape(B_ * nh, S, ns)
    ck = Ch.transpose(0, 2, 1, 3).reshape(B_ * nh, S, ns)
    from ..kernels.autotune import ssd_chunk_len
    chunk = min(ssd_chunk_len(S, hp, ns), S)
    if S % chunk:
        chunk = max(1, math.gcd(S, chunk))
    y = kops.ssd(xk.astype(jnp.bfloat16), dk, bk.astype(jnp.bfloat16),
                 ck.astype(jnp.bfloat16), chunk=chunk,
                 use_kernel=cfg.use_kernels)
    return y.reshape(B_, nh, S, hp).transpose(0, 2, 1, 3)


def ssm_train(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    B_, S, _ = x.shape
    di, nh, hp = cfg.d_inner, cfg.ssm_nheads, cfg.ssm_headdim
    proj = x @ p["in_proj"]
    z, xin, Bm, Cm, dt_raw = _split_proj(cfg, proj)
    xbc = jnp.concatenate([xin, Bm, Cm], axis=-1)
    xbc = _conv1d(cfg, p["conv_w"], p["conv_b"], xbc)
    xh, xdt, dA, Bh, Ch = _ssd_inputs(cfg, p, xbc, dt_raw)
    y = _ssd_scan(cfg, xdt, dA, Bh, Ch)
    y = y + xh.astype(F32) * p["D"].astype(F32)[None, None, :, None]
    y = y.reshape(B_, S, di).astype(x.dtype)
    y = _gated_norm(cfg, p["norm_scale"], y, z)
    return y @ p["out_proj"]


def init_ssm_cache(cfg: ModelConfig, B: int, dtype) -> Dict:
    return {
        "conv": jnp.zeros((B, cfg.conv_kernel - 1, cfg.conv_dim), dtype),
        "state": jnp.zeros((B, cfg.ssm_nheads, cfg.ssm_state,
                            cfg.ssm_headdim), F32),
    }


def ssm_prefill(cfg: ModelConfig, p, x: jax.Array) -> Tuple[jax.Array, Dict]:
    """Full-seq forward + final recurrent state for decode continuation."""
    B_, S, _ = x.shape
    di, nh, hp, ns = cfg.d_inner, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    proj = x @ p["in_proj"]
    z, xin, Bm, Cm, dt_raw = _split_proj(cfg, proj)
    xbc_raw = jnp.concatenate([xin, Bm, Cm], axis=-1)
    xbc = _conv1d(cfg, p["conv_w"], p["conv_b"], xbc_raw)
    xh, xdt, dA, Bh, Ch = _ssd_inputs(cfg, p, xbc, dt_raw)
    y = _ssd_scan(cfg, xdt, dA, Bh, Ch)
    y = y + xh.astype(F32) * p["D"].astype(F32)[None, None, :, None]
    yo = _gated_norm(cfg, p["norm_scale"], y.reshape(B_, S, di).astype(x.dtype), z)
    out = yo @ p["out_proj"]
    # final state: h_S = sum_t exp(sum_{u>t} dA_u) B_t xdt_t^T  (per head)
    cs = jnp.cumsum(dA, axis=1)
    decay = jnp.exp(cs[:, -1:, :] - cs)                       # (B,S,nh)
    state = jnp.einsum("bshn,bsh,bshp->bhnp", Bh.astype(F32), decay,
                       xdt)                                   # (B,nh,ns,hp)
    conv_state = xbc_raw[:, -(cfg.conv_kernel - 1):, :]
    return out, {"conv": conv_state, "state": state}


def ssm_decode(cfg: ModelConfig, p, x: jax.Array, cache: Dict
               ) -> Tuple[jax.Array, Dict]:
    """One-token step.  x: (B, 1, d)."""
    B_, _, _ = x.shape
    di, nh, hp, ns = cfg.d_inner, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    proj = x @ p["in_proj"]
    z, xin, Bm, Cm, dt_raw = _split_proj(cfg, proj)
    xbc1 = jnp.concatenate([xin, Bm, Cm], axis=-1)            # (B,1,cd)
    conv_prev = cache["conv"]
    xbc = _conv1d(cfg, p["conv_w"], p["conv_b"], xbc1, prev=conv_prev)
    new_conv = jnp.concatenate([conv_prev, xbc1], axis=1)[:, 1:, :]
    xh, xdt, dA, Bh, Ch = _ssd_inputs(cfg, p, xbc, dt_raw)
    # recurrence: h' = exp(dA) h + B ⊗ xdt
    h = cache["state"]
    h = jnp.exp(dA[:, 0, :, None, None]) * h \
        + jnp.einsum("bhn,bhp->bhnp", Bh[:, 0].astype(F32), xdt[:, 0])
    y = jnp.einsum("bhn,bhnp->bhp", Ch[:, 0].astype(F32), h)  # (B,nh,hp)
    y = y + xh[:, 0].astype(F32) * p["D"].astype(F32)[None, :, None]
    y = y.reshape(B_, 1, di).astype(x.dtype)
    y = _gated_norm(cfg, p["norm_scale"], y, z)
    return y @ p["out_proj"], {"conv": new_conv, "state": h}
