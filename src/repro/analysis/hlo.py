"""Compiled-HLO collective analysis for the roofline (§Roofline).

``cost_analysis()`` provides FLOPs / bytes-accessed of the (per-device,
SPMD-partitioned) module; collective traffic is NOT included there, so we
parse the optimized HLO text and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / ragged-all-to-all /
collective-permute, weighting by the wire factor of each collective type
((G-1)/G patterns of ring/rec-dbl algorithms) using the replica-group size
parsed per op.

Async collectives
-----------------
Newer XLA emits collectives as ``-start``/``-done`` pairs.  Volumes are
counted at the ``-start`` op and the ``-done`` op is skipped — counting
both would double every async collective.  A ``-start`` result is a
*tuple* ``(operand, result[, context...])``: summing every element would
double-count again (operand ≈ result for in-place types), so we take the
**largest** tuple element — the gathered result for all-gather, the
operand/result for the equal-shape types — and for reduce-scatter (whose
largest element is the *input*) divide by the group size to recover the
scattered output the sync form reports.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List

__all__ = ["CollectiveStats", "parse_collectives", "shape_bytes",
           "shape_elements_bytes", "HW", "roofline_terms"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# ragged-all-to-all must precede all-to-all in the alternation: the regex
# engine takes the first matching branch, and at the op-name position
# "all-to-all" would never match the leading "ragged-" (so the op would be
# silently dropped, not mis-binned — pinned by a fixture test).
_OP_RE = re.compile(
    r"=\s*(\(?[\w\[\],\s{}:#]*?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|ragged-all-to-all|all-to-all"
    r"|collective-permute)"
    r"(-start|-done)?\(")
_GROUPS_LITERAL_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def shape_elements_bytes(shape_str: str) -> List[int]:
    """Byte size of each array in an HLO shape string, in order — one
    entry for 'bf16[16,4096]', several for a tuple '(bf16[4], f32[8,2])'."""
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        out.append(n * _DTYPE_BYTES[dt])
    return out


def shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'bf16[16,4096]' or a tuple
    '(bf16[4], f32[8,2])'."""
    return sum(shape_elements_bytes(shape_str))


@dataclass
class CollectiveStats:
    # per type: [count, raw output bytes, wire bytes (top-level),
    #            wire bytes inside while-loop bodies (counted ONCE by XLA —
    #            scale by the loop trip count, i.e. the layer count)]
    by_type: Dict[str, List[float]] = field(default_factory=dict)

    @property
    def total_wire_bytes(self) -> float:
        return sum(v[2] + v[3] for v in self.by_type.values())

    @property
    def total_raw_bytes(self) -> float:
        return sum(v[1] for v in self.by_type.values())

    def wire_bytes_scaled(self, loop_trip: int) -> float:
        """Per-device wire bytes with in-loop collectives × trip count."""
        return sum(v[2] + v[3] * loop_trip for v in self.by_type.values())

    def to_dict(self) -> Dict:
        return {k: {"count": v[0], "raw_bytes": v[1], "wire_bytes": v[2],
                    "wire_bytes_in_loop": v[3]}
                for k, v in self.by_type.items()}


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LITERAL_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


def _wire_factor(op: str, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (g - 1) / g
    if op in ("all-gather", "all-to-all", "ragged-all-to-all"):
        return (g - 1) / g
    if op == "reduce-scatter":
        return (g - 1)          # output is 1/g of the input
    if op == "collective-permute":
        return 1.0
    return 1.0


def _collective_bytes(op: str, shape_str: str, suffix: str, g: int) -> int:
    """Raw bytes of one collective result, async-aware (module docstring)."""
    elems = shape_elements_bytes(shape_str)
    if not elems:
        return 0
    if suffix != "-start" or len(elems) == 1:
        return sum(elems)
    b = max(elems)
    if op == "reduce-scatter" and g > 1:
        # the largest tuple element of reduce-scatter-start is the INPUT;
        # the sync form's result (what raw_bytes means) is input/g
        b = b // g
    return b


_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->")
_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Collective traffic, separating ops inside while-loop bodies (XLA
    counts/emits those once; callers scale by the loop trip count)."""
    lines = hlo_text.splitlines()
    body_names = set()
    for line in lines:
        if " while(" in line or "= while(" in line:
            m = _WHILE_BODY_RE.search(line)
            if m:
                body_names.add(m.group(1))

    stats = CollectiveStats()
    current = ""
    for line in lines:
        if not line.startswith(" "):
            h = _COMP_HEADER_RE.match(line.strip())
            if h:
                current = h.group(1)
            continue
        if "all-" not in line and "reduce-scatter" not in line \
                and "collective-permute" not in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, op, suffix = m.group(1), m.group(2), m.group(3) or ""
        if suffix == "-done":
            # volume was counted at the paired -start op
            continue
        g = _group_size(line)
        b = _collective_bytes(op, shape_str, suffix, g)
        wf = _wire_factor(op, g)
        ent = stats.by_type.setdefault(op, [0, 0.0, 0.0, 0.0])
        ent[0] += 1
        ent[1] += b
        if current in body_names:
            ent[3] += b * wf
        else:
            ent[2] += b * wf
    return stats


# ------------------------------------------------------------- roofline

# TPU v5e hardware constants (per chip)
HW = {
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # B/s
    "link_bw": 50e9,               # B/s per ICI link
}


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   wire_bytes_per_device: float,
                   link_bw: float = None,
                   overlap: float = 1.0) -> Dict[str, float]:
    """Three roofline terms in seconds (per-device quantities; the SPMD
    module is per-device, so chips cancel out of the brief's formulas).

    ``link_bw`` overrides the tabulated ICI link bandwidth — the dryrun
    passes the measured-and-fitted channel bandwidth from a
    ``repro.calibrate`` calibration here, so the collective term of the
    roofline is charged at the bandwidth the harness actually observed
    instead of the datasheet constant.

    ``overlap`` in [0, 1] is the achievable compute-collective overlap
    (the same factor ``core/cost.py`` charges): the ``*_serial_s`` /
    ``*_overlap_s`` pair reports the collective term fully exposed
    (overlap=0) vs. hidden up to ``overlap`` behind the on-chip bound.
    The legacy ``bottleneck`` / ``bound_s`` keys keep their original
    max-of-three semantics (everything perfectly concurrent).
    """
    t_compute = flops_per_device / HW["peak_flops_bf16"]
    t_memory = bytes_per_device / HW["hbm_bw"]
    t_collective = wire_bytes_per_device / (link_bw if link_bw
                                            else HW["link_bw"])
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_collective)), key=lambda kv: kv[1])[0]
    total = max(t_compute, t_memory, t_collective)
    on_chip = max(t_compute, t_memory)
    t_col_exposed = (1.0 - overlap) * t_collective
    dominant_ov = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_col_exposed)), key=lambda kv: kv[1])[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "bottleneck": dominant,
        "bound_s": total,
        "compute_fraction": t_compute / total if total > 0 else 0.0,
        # serial charging: every collective fully exposed behind the
        # on-chip bound (the pre-overlap cost model's assumption)
        "bound_serial_s": on_chip + t_collective,
        # overlap-adjusted: only the non-hidden share stays exposed
        "overlap": overlap,
        "t_collective_exposed_s": t_col_exposed,
        "bound_overlap_s": on_chip + t_col_exposed,
        "bottleneck_overlap": dominant_ov,
    }
