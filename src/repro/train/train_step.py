"""Train-step factory: loss + grad + AdamW update as one donated jit.

Supports microbatch gradient accumulation (lax.scan over µbatches — keeps
the collective/compute overlap window open for the XLA latency-hiding
scheduler) and the COMET-planned explicit-collective loss
(``cfg.softmax_strategy``: 'dist'/'gather'/'auto' via the planner;
'gspmd' leaves the choice to XLA).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..models.model import Model
from .optimizer import OptConfig, OptState, adamw_update

__all__ = ["TrainState", "make_train_step", "make_loss_fn"]


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def make_loss_fn(model: Model, mesh: Optional[Mesh],
                 use_planner_loss: bool = False):
    cfg = model.cfg

    def loss_fn(params, batch):
        if use_planner_loss and mesh is not None and not cfg.tie_embeddings \
                and not cfg.is_encdec:
            # explicit-collective loss: forward to hidden states, then the
            # COMET-planned sharded softmax-xent (dist vs gather).
            from ..models import transformer
            from ..models.layers import apply_norm, embed_apply
            from ..parallel.collective_planner import sharded_softmax_xent
            x = embed_apply(params, batch["tokens"]).astype(jnp.dtype(cfg.dtype))
            if cfg.first_dense_layers > 0:
                x = transformer._scan_stack(cfg.with_(n_experts=0), mesh,
                                            False, x, params["dense_layers"])
            x = transformer._scan_stack(cfg, mesh, cfg.is_moe, x,
                                        params["layers"])
            x = apply_norm(cfg, params["final_norm"], x)
            return sharded_softmax_xent(
                x, params["unembed"], batch["labels"], mesh,
                real_vocab=cfg.vocab_size, strategy=cfg.softmax_strategy
                if cfg.softmax_strategy != "gspmd" else "auto")
        return model.loss(params, batch, mesh)

    return loss_fn


def make_train_step(model: Model, opt_cfg: OptConfig,
                    mesh: Optional[Mesh] = None, *,
                    microbatches: int = 1,
                    use_planner_loss: bool = False):
    """Returns train_step(state, batch) -> (state, metrics)."""
    loss_fn = make_loss_fn(model, mesh, use_planner_loss)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if microbatches > 1:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_fn(carry, b):
                l, g = jax.value_and_grad(loss_fn)(state.params, b)
                return (carry[0] + l, jax.tree.map(jnp.add, carry[1], g)), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = jax.lax.scan(acc_fn, (jnp.float32(0), zero_g), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)

        new_params, new_opt, metrics = adamw_update(opt_cfg, state.params,
                                                    grads, state.opt)
        metrics = dict(metrics, loss=loss)
        return TrainState(new_params, new_opt), metrics

    return train_step
