"""Persist calibrated NoC params next to the plan store.

One JSON document, ``calibrated_noc.json``, lives in the plan-store
root (``$REPRO_PLAN_CACHE`` or ``~/.cache/repro-plans`` — the same
resolution ``repro.core.plan`` uses, so a serving fleet that shares a
plan store shares its calibration).  The file carries full provenance —
measurement backend, mesh shape, participant counts, jax version,
timestamp, per-point residuals — because a calibration is only valid
for the machine it was measured on:

* **roundtrip** is bit-identical: canonical JSON (sorted keys, fixed
  indent, repr-exact floats), so re-saving a loaded calibration writes
  the same bytes and CI can gate on file equality;
* **stale provenance** (different mesh shape, backend or jax version
  than the caller expects) is *refused* with one actionable warning —
  silently applying another machine's constants is exactly the failure
  mode the calibration loop exists to remove;
* **corruption** (torn write, truncation) quarantines the file to a
  ``corrupt/`` sibling (planstore convention) with one warning and
  falls back to preset params — ``load_calibration`` returns ``None``
  and ``apply_calibration`` leaves the arch untouched;
* a fit with **non-finite residuals or params is never persisted**:
  ``save_calibration`` refuses (one warning) instead of writing a file
  that would poison every later session.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.hardware import NoCParams

from .fitter import FitResult
from .harness import MeasuredPoint, _warn_once

__all__ = ["CALIBRATION_SCHEMA", "CALIB_FILENAME", "Calibration",
           "calibration_path", "save_calibration", "load_calibration",
           "calibration_from_fit"]

CALIBRATION_SCHEMA = "repro/calibrated-noc/v1"
CALIB_FILENAME = "calibrated_noc.json"
CORRUPT_DIRNAME = "corrupt"

#: provenance keys that must match for a persisted calibration to be
#: trusted by a loader that states its expectations
_STALE_KEYS = ("backend", "mesh", "jax_version")


@dataclass(frozen=True)
class Calibration:
    """A fitted NoCParams plus the provenance that scopes its validity."""

    params: NoCParams
    provenance: Dict             # backend, mesh, jax_version, timestamp_s…
    per_type: Tuple[Dict, ...]   # TypeFit.to_json() rows
    points: Tuple[MeasuredPoint, ...]
    residuals: Tuple[float, ...]
    max_rel_err: float
    median_rel_err: float
    identifiable: bool = False

    def to_json(self) -> Dict:
        return {
            "schema": CALIBRATION_SCHEMA,
            "provenance": dict(self.provenance),
            "params": _noc_to_json(self.params),
            "per_type": [dict(t) for t in self.per_type],
            "points": [p.to_json() for p in self.points],
            "residuals": list(self.residuals),
            "max_rel_err": self.max_rel_err,
            "median_rel_err": self.median_rel_err,
            "identifiable": self.identifiable,
        }

    @classmethod
    def from_json(cls, d: Dict) -> "Calibration":
        if d.get("schema") != CALIBRATION_SCHEMA:
            raise ValueError(f"unknown calibration schema {d.get('schema')!r}")
        return cls(
            params=_noc_from_json(d["params"]),
            provenance=dict(d["provenance"]),
            per_type=tuple(dict(t) for t in d["per_type"]),
            points=tuple(MeasuredPoint.from_json(p) for p in d["points"]),
            residuals=tuple(float(r) for r in d["residuals"]),
            max_rel_err=float(d["max_rel_err"]),
            median_rel_err=float(d["median_rel_err"]),
            identifiable=bool(d.get("identifiable", False)),
        )


def _noc_to_json(noc: NoCParams) -> Dict:
    return {"mesh": list(noc.mesh), "channel_width": noc.channel_width,
            "channel_bandwidth": noc.channel_bandwidth,
            "t_router": noc.t_router, "t_enq": noc.t_enq,
            "hop_energy_pj_per_byte": noc.hop_energy_pj_per_byte}


def _noc_from_json(d: Dict) -> NoCParams:
    return NoCParams(mesh=tuple(int(x) for x in d["mesh"]),
                     channel_width=int(d["channel_width"]),
                     channel_bandwidth=float(d["channel_bandwidth"]),
                     t_router=float(d["t_router"]),
                     t_enq=float(d["t_enq"]),
                     hop_energy_pj_per_byte=float(
                         d["hop_energy_pj_per_byte"]))


def calibration_from_fit(fit: FitResult, *, backend: str,
                         jax_version: str,
                         now: Callable[[], float] = time.time,
                         extra: Optional[Dict] = None) -> Calibration:
    """Wrap a ``FitResult`` with the provenance that scopes it."""
    prov = {
        "backend": backend,
        "mesh": list(fit.params.mesh),
        "participants": sorted({p.participants for p in fit.points}),
        "jax_version": jax_version,
        "timestamp_s": float(now()),
        "n_points": fit.n_points,
        "degenerate": fit.degenerate,
    }
    if extra:
        prov.update(extra)
    return Calibration(params=fit.params, provenance=prov,
                       per_type=tuple(t.to_json() for t in fit.per_type),
                       points=fit.points, residuals=fit.residuals,
                       max_rel_err=fit.max_rel_err,
                       median_rel_err=fit.median_rel_err,
                       identifiable=fit.identifiable)


# ----------------------------------------------------------------- paths


def calibration_path(root: Optional[str] = None) -> Path:
    """``calibrated_noc.json`` inside the plan-store root (the same
    ``$REPRO_PLAN_CACHE`` / ``~/.cache/repro-plans`` resolution the plan
    cache uses, re-read per call like ``plan.default_cache``)."""
    if root is None:
        from repro.core.plan import DEFAULT_CACHE_DIR, _ENV_VAR
        root = os.environ.get(_ENV_VAR) or DEFAULT_CACHE_DIR
    return Path(root).expanduser() / CALIB_FILENAME


# ------------------------------------------------------------ save / load


def _canonical_bytes(doc: Dict) -> bytes:
    """Sorted-key, fixed-indent JSON: float repr is exact (json uses
    ``repr``-shortest round-trip floats), so equal documents are equal
    bytes and the roundtrip is bit-identical."""
    return (json.dumps(doc, indent=2, sort_keys=True) + "\n").encode()


def _finite(x: float) -> bool:
    return x == x and abs(x) != float("inf")


def save_calibration(cal: Calibration,
                     path: Optional[Path] = None) -> Optional[Path]:
    """Atomically write ``cal`` to ``path`` (default: the store root).

    Refuses — one warning, returns ``None``, writes nothing — when any
    residual or fitted constant is non-finite: a NaN fit must never
    outlive the process that produced it.
    """
    path = Path(path) if path is not None else calibration_path()
    bad = [r for r in cal.residuals if not _finite(r)]
    p = cal.params
    if bad or not all(_finite(x) for x in
                      (p.channel_bandwidth, p.t_router, p.t_enq)):
        _warn_once(("calib-nan", str(path)),
                   f"refusing to persist calibration to {path}: "
                   f"{len(bad)} non-finite residuals / params — fix the "
                   f"measurement backend and re-run the sweep")
        return None
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(".json.tmp")
    tmp.write_bytes(_canonical_bytes(cal.to_json()))
    os.replace(tmp, path)
    return path


def _quarantine(path: Path) -> None:
    qdir = path.parent / CORRUPT_DIRNAME
    try:
        qdir.mkdir(parents=True, exist_ok=True)
        os.replace(path, qdir / path.name)
    except OSError:
        pass                              # read-only media: leave in place


def load_calibration(path: Optional[Path] = None, *,
                     expect: Optional[Dict] = None) -> Optional[Calibration]:
    """Load a persisted calibration, or ``None`` when unusable.

    * missing file — ``None``, silently (never calibrated is a normal
      state);
    * unparsable / schema-mismatched file — quarantined to ``corrupt/``
      beside the store (planstore convention), one warning, ``None``;
    * ``expect`` provenance mismatch (any of ``backend`` / ``mesh`` /
      ``jax_version`` present in ``expect`` and different in the file) —
      one warning naming the drift and the recalibrate command, ``None``.
    """
    path = Path(path) if path is not None else calibration_path()
    try:
        raw = path.read_bytes()
    except OSError:
        return None
    try:
        cal = Calibration.from_json(json.loads(raw))
    except (ValueError, KeyError, TypeError) as e:
        _quarantine(path)
        _warn_once(("calib-corrupt", str(path)),
                   f"corrupted calibration file quarantined to "
                   f"{path.parent / CORRUPT_DIRNAME}: {e!r}; falling back "
                   f"to preset NoC params")
        return None
    if expect:
        drift: List[str] = []
        for key in _STALE_KEYS:
            if key in expect:
                want, got = expect[key], cal.provenance.get(key)
                if key == "mesh":
                    want, got = list(want), list(got or [])
                if want != got:
                    drift.append(f"{key}: file has {got!r}, "
                                 f"this run is {want!r}")
        if drift:
            _warn_once(("calib-stale", str(path)),
                       f"stale calibration at {path} refused "
                       f"({'; '.join(drift)}) — re-run "
                       f"`python -m repro.calibrate` on this backend to "
                       f"recalibrate; using preset NoC params")
            return None
    return cal
