"""Abstract input specs (ShapeDtypeStruct + sharding) for every
(architecture × shape × mesh) dry-run cell — weak-type-correct, shardable,
zero allocation."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import Shape
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.parallel.sharding import (batch_sharding, cache_shardings, param_shardings, zero1_shardings)

__all__ = ["batch_specs", "state_specs", "cache_specs", "with_shardings"]


def with_shardings(abstract, shardings):
    """Attach shardings to ShapeDtypeStructs (lower() picks them up)."""
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract, shardings)


def batch_specs(cfg: ModelConfig, shape: Shape, mesh: Optional[Mesh],
                *, with_labels: bool = True) -> Dict[str, Any]:
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    out: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if with_labels:
        out["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.is_encdec and shape.kind != "decode":
        Se = max(1, shape.seq_len // cfg.enc_ratio)
        out["src_embeds"] = jax.ShapeDtypeStruct((B, Se, cfg.d_model),
                                                 jnp.float32)
    if mesh is not None:
        out = {k: jax.ShapeDtypeStruct(
            v.shape, v.dtype, sharding=batch_sharding(mesh, B, v.ndim))
            for k, v in out.items()}
    return out


def state_specs(model: Model, mesh: Optional[Mesh], *,
                with_opt: bool = True) -> Tuple[Any, Any]:
    """(abstract TrainState-or-params, matching shardings)."""
    ab = model.abstract_params()
    ax = model.param_axes()
    if mesh is None:
        return ab, None
    if not model.cfg.tensor_parallel:
        # replicate-everything TP-off mode (small models: pure DP + ZeRO)
        ax = jax.tree.map(lambda t: tuple(None for _ in t), ax,
                          is_leaf=lambda x: isinstance(x, tuple) and all(
                              isinstance(e, (str, type(None))) for e in x))
    # FSDP/ZeRO-3: params get the same extra data-axis sharding as the
    # optimizer moments (weights all-gathered per layer by GSPMD)
    psh = (zero1_shardings(ax, ab, mesh) if model.cfg.fsdp
           else param_shardings(ax, ab, mesh))
    if not with_opt:
        return with_shardings(ab, psh), psh
    from repro.train.optimizer import OptState
    from repro.train.train_step import TrainState
    f32 = lambda t: jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), t)
    zsh = zero1_shardings(ax, ab, mesh)
    scalar_sh = NamedSharding(mesh, P())
    opt_ab = OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      m=f32(ab), v=f32(ab), err=None)
    opt_sh = OptState(step=scalar_sh, m=zsh, v=zsh, err=None)
    state_ab = TrainState(params=ab, opt=opt_ab)
    state_sh = TrainState(params=psh, opt=opt_sh)
    return with_shardings(state_ab, state_sh), state_sh


def cache_specs(model: Model, shape: Shape, mesh: Optional[Mesh]):
    """(abstract cache, shardings) for decode cells."""
    B, S = shape.global_batch, shape.seq_len
    cache_ab = jax.eval_shape(lambda: model.init_cache(B, S))
    if mesh is None:
        return cache_ab, None
    csh = cache_shardings(cache_ab, mesh, B)
    return with_shardings(cache_ab, csh), csh
