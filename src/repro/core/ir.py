"""Mapping-instance → IR construction (COMET Fig. 3 'IR' stage).

Builds the hierarchical mapping trees of Fig. 4(c) for the paper's case
studies, parameterized by a :class:`MappingSpec`:

* GEMM-epilogue compound ops (GEMM-Softmax / GEMM-LayerNorm) with the four
  fusion variants of §V-D:  ``unfused``, ``fused_epilogue`` (Fused-distSM),
  ``fused_std`` (Fused-GEMM-SM: epilogue gathered to one cluster) and
  ``fused_dist`` (Fused-GEMM-distSM: fully fused + distributed epilogue
  with explicit All-Reduce collectives).
* Self-attention with the three variants of §V-D2: ``ua`` (unfused),
  ``pfa`` (score+softmax fused) and ``fa`` (FlashAttention, fully fused
  online-softmax).
* A generic unfused builder for arbitrary compound ops (used for SSD).

Collective granularity (DESIGN.md §8): the paper annotates the distSM
All-Reduce with tensor **C** (so the collective moves M×N tile volume);
``collective_gran='tile'`` reproduces that.  ``'stats'`` is our
beyond-paper optimization that reduces only the M×1 statistics vectors.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

from .cost import CostModel, NodeCost
from .hardware import Arch
from .mapping import CollectiveNode, ComputeNode, Loop, Node, TileNode, Tiling
from .numerics import ceil_div, is_array, vmax, vmin
from .validate import validate_headroom_levels
from .workload import CompoundOp, Operation

__all__ = ["MappingSpec", "build_tree", "evaluate_mapping", "MappingResult"]

VARIANTS_GEMM = ("unfused", "fused_epilogue", "fused_std", "fused_dist")
VARIANTS_ATTN = ("ua", "pfa", "fa")


@dataclass(frozen=True)
class MappingSpec:
    """A concrete mapping instance (tiling + order + spatial + collectives
    + schedule) — the output of the mapping-instance generator.

    ``sp_cluster``/``sp_core`` are the spatial unrolling *fanouts* (how
    many clusters / cores-per-cluster the builder's partition dim spreads
    over); 0 means "use the full architecture fanout" (the §V-C2 case
    study choice and the pre-existing default).  The builders accept NumPy
    int arrays here — the batched engine enumerates both axes inside its
    structure-of-arrays grid.
    """

    variant: str = "fused_dist"
    m_tiles: int = 1            # temporal M tiling at GB (DRAM->GB streaming)
    k_tiles: int = 1            # temporal K tiling at OB (accumulation)
    n_tiles: int = 1            # temporal N tiling at GB (KV streaming for FA)
    sp_cluster: int = 0         # spatial fanout across clusters (0 = arch max)
    sp_core: int = 0            # spatial fanout across cores (0 = arch max)
    loop_order_gb: Tuple[str, ...] = ("M", "N")
    schedule: str = "sequential"
    collective_gran: str = "tile"   # 'tile' (paper-faithful) | 'stats'
    collective_level: str = "GB"    # where CO nodes sit
    # Compute–collective overlap factor in [0, 1]: the fraction of each
    # window's hideable collective time (its Eq. 1 mem_lat; the Eq. 3
    # enqueue/router term stays exposed) hidden under sibling compute.
    # 0.0 (default) reproduces the pre-overlap serial charging exactly.
    overlap: float = 0.0


@dataclass
class MappingResult:
    cost: NodeCost
    root: TileNode
    tiling: Tiling
    spec: MappingSpec
    valid: bool
    # Worst relative buffer slack: min over non-DRAM tile nodes of
    # (capacity - resident)/capacity — the provisioning ("pareto3")
    # objective channel.  Negative iff some buffer overflows.
    headroom: float = 1.0
    # Per-level worst slack ({'GB': ..., 'OB': ...}): the un-folded view
    # of ``headroom`` (== min over the values), letting provisioning
    # studies size the cluster (GB) and core (IB+WB+OB) buffers
    # independently.
    headroom_levels: Dict[str, float] = field(default_factory=dict)

    @property
    def latency(self) -> float:
        return self.cost.latency

    @property
    def energy_pj(self) -> float:
        return self.cost.energy_pj


# ------------------------------------------------------------------ helpers


def _ceil_div(a: int, b: int) -> int:
    return vmax(1, ceil_div(a, b))


def _clamped_spatial(size: int, want: int) -> int:
    """Spatial fanout cannot exceed the dimension size."""
    return vmax(1, vmin(want, size))


def _sp_want(req, cap: int):
    """Resolve a MappingSpec spatial-fanout request against the arch limit:
    0 (or negative) means 'use the full fanout'; otherwise clamp to the
    number of physical instances.  Array-polymorphic for the batched grid."""
    if is_array(req):
        return np.where(req <= 0, cap, np.minimum(req, cap))
    if req <= 0:
        return cap
    return min(req, cap)


def _leaf_shape(tiling: Tiling, dims: Tuple[str, ...]) -> Dict[str, int]:
    return {d: tiling.leaf_tile(d) for d in dims}


def _gb_shape(tiling: Tiling, dims: Tuple[str, ...]) -> Dict[str, int]:
    return {d: tiling.tile_below(d, "GB") for d in dims}


def _simd_node(op: Operation, shape: Dict[str, int]) -> ComputeNode:
    return ComputeNode(op=op, tile_shape=dict(shape), unit="simd", label=op.name)


def _gemm_node(op: Operation, shape: Dict[str, int]) -> ComputeNode:
    return ComputeNode(op=op, tile_shape=dict(shape), unit="gemm", label=op.name)


# ================================================== GEMM-epilogue builders


def _build_gemm_epilogue(co: CompoundOp, arch: Arch, spec: MappingSpec) -> Tuple[TileNode, Tiling]:
    """GEMM-Softmax / GEMM-LayerNorm trees for all four fusion variants.

    Case-study mapping (§V-C2): N spatially across clusters and cores,
    M temporally tiled (FLAT row granularity).  The cluster/core fanouts
    come from ``spec.sp_cluster``/``spec.sp_core`` (0 = full fanout) and
    may be arrays on the batched path; edge tiles at non-divisible sizes
    use ceil-div residual shapes throughout.
    """
    M, N, K = (co.dim_sizes[d] for d in ("M", "N", "K"))
    want_cl = _sp_want(spec.sp_cluster, arch.num_clusters)
    want_co = _sp_want(spec.sp_core, arch.cores_per_cluster)
    n_cl = _clamped_spatial(N, want_cl)
    n_co = _clamped_spatial(_ceil_div(N, n_cl), want_co)
    m_tiles = vmin(spec.m_tiles, M)
    k_tiles = vmin(spec.k_tiles, K)

    tiling = Tiling(
        co.dim_sizes,
        temporal={"GB": {"M": m_tiles}, "OB": {"K": k_tiles}},
        spatial={"GB": {"N": n_cl}, "OB": {"N": n_co}},
    )
    gemm_op = co.gemm_ops()[0]
    simd_ops = co.simd_ops()
    inter = co.op("Op1_gemm").output          # "C"
    final = co.external_outputs[0]
    stats = [t for t, s in co.tensors.items() if s.dims == ("M",)]
    dtype_b = co.tensors[inter].dtype_bytes

    leaf = _leaf_shape(tiling, ("M", "N", "K"))
    m_tile = tiling.tile_below("M", "GB")
    n_leaf = leaf["N"]

    def ob(op_nodes: List[ComputeNode], inputs, outputs, loops=None,
           spatial=True, label="") -> TileNode:
        return TileNode(
            level="OB", index=0, loops=loops or [],
            spatial_loops=[Loop("N", n_co, True)] if spatial else [],
            input_tensors=tuple(inputs), output_tensors=tuple(outputs),
            children=list(op_nodes), schedule="sequential", label=label)

    def collective(tensor: str, reduce_op: str, label: str) -> CollectiveNode:
        if spec.collective_gran == "tile":
            dv = m_tile * N * dtype_b          # paper-faithful: tensor C tile
            tname = inter
        else:
            dv = m_tile * dtype_b              # stats-only (beyond-paper)
            tname = tensor
        return CollectiveNode(
            col_type="AllReduce", tensor=tname, reduce_op=reduce_op,
            src=("GB",), dest=("GB",), participants=n_cl,
            data_volume_bytes=dv, count=1, noc_level="GB", label=label)

    # ---- per-variant GB-level children ------------------------------------
    gemm_leaf = dict(leaf)
    gemm_ob = ob([_gemm_node(gemm_op, gemm_leaf)], gemm_op.inputs, (inter,),
                 loops=[Loop("K", k_tiles)], label="T_gemm")

    ext_in = co.external_inputs
    gemm_only_inputs = tuple(t for t in gemm_op.inputs if t in ext_in)
    epi_ext_inputs = tuple(t for t in ext_in if t not in gemm_op.inputs)

    if spec.variant == "fused_dist":
        # Fig. 4(c): everything fused at GB; distributed epilogue with
        # explicit All-Reduce collectives between SIMD stages.
        children: List[Node] = [gemm_ob]
        per_core = {"M": m_tile, "N": n_leaf}
        pending: List[ComputeNode] = []
        for op in simd_ops:
            shape = {d: per_core.get(d, tiling.tile_below(d, "OB")) for d in op.dims}
            pending.append(_simd_node(op, shape))
            if op.reduce_dims:                 # stats op => needs cross-cluster AR
                ins = tuple(t for t in op.inputs)
                outs = (op.output,)
                children.append(ob(pending, ins, outs, label=f"T_{op.name}"))
                pending = []
                children.append(collective(op.output,
                                           "max" if "max" in op.name else "add",
                                           f"CO_{op.name}"))
        if pending:
            last = simd_ops[-1]
            children.append(ob(pending, last.inputs, (final,), label="T_tail"))
        root_children: List[Node] = [TileNode(
            level="GB", index=0,
            loops=[Loop("M", m_tiles)],
            spatial_loops=[Loop("N", n_cl, True)],
            input_tensors=gemm_only_inputs + epi_ext_inputs,
            output_tensors=(final,),
            bypass_tensors=tuple(co.intermediates()),
            children=children, schedule=spec.schedule, label="T_fused_dist",
            overlap=spec.overlap)]

    elif spec.variant == "fused_std":
        # Fused-GEMM-SM: GEMM distributed; Gather C rows to one cluster;
        # epilogue on a single cluster/core (full-row tiles, no AR).
        gather = CollectiveNode(
            col_type="Gather", tensor=inter, reduce_op="none",
            src=("GB",), dest=("GB",), participants=n_cl,
            data_volume_bytes=m_tile * N * dtype_b, count=1,
            noc_level="GB", label="CO_gather")
        full_row = {"M": m_tile, "N": N}
        epi_nodes = [_simd_node(op, {d: full_row.get(d, 1) for d in op.dims})
                     for op in simd_ops]
        epi_ob = ob(epi_nodes, (inter,) + epi_ext_inputs, (final,),
                    spatial=False, label="T_epi_single")
        gb = TileNode(
            level="GB", index=0,
            loops=[Loop("M", m_tiles)],
            spatial_loops=[Loop("N", n_cl, True)],
            input_tensors=gemm_only_inputs + epi_ext_inputs,
            output_tensors=(final,),
            bypass_tensors=tuple(co.intermediates()),
            children=[gemm_ob, gather, epi_ob],
            schedule=spec.schedule, label="T_fused_std",
            extra_resident_bytes=m_tile * N * dtype_b * 2.0,
            overlap=spec.overlap)
        root_children = [gb]

    elif spec.variant == "fused_epilogue":
        # Fused-distSM: epilogue ops fused together but NOT with the GEMM;
        # C round-trips DRAM between the two subtrees.
        gb_gemm = TileNode(
            level="GB", index=0, loops=[Loop("M", m_tiles)],
            spatial_loops=[Loop("N", n_cl, True)],
            input_tensors=gemm_only_inputs, output_tensors=(inter,),
            children=[gemm_ob], schedule="sequential", label="T_gemm_gb")
        children = []
        per_core = {"M": m_tile, "N": n_leaf}
        pending = []
        for op in simd_ops:
            shape = {d: per_core.get(d, tiling.tile_below(d, "OB")) for d in op.dims}
            pending.append(_simd_node(op, shape))
            if op.reduce_dims:
                children.append(ob(pending, op.inputs, (op.output,),
                                   label=f"T_{op.name}"))
                pending = []
                children.append(collective(op.output,
                                           "max" if "max" in op.name else "add",
                                           f"CO_{op.name}"))
        if pending:
            children.append(ob(pending, simd_ops[-1].inputs, (final,),
                               label="T_tail"))
        epi_bypass = tuple(t for t in co.intermediates() if t != inter)
        gb_epi = TileNode(
            level="GB", index=1, loops=[Loop("M", m_tiles)],
            spatial_loops=[Loop("N", n_cl, True)],
            input_tensors=(inter,) + epi_ext_inputs, output_tensors=(final,),
            bypass_tensors=epi_bypass,
            children=children, schedule=spec.schedule, label="T_epi_gb",
            overlap=spec.overlap)
        root_children = [gb_gemm, gb_epi]

    elif spec.variant == "unfused":
        # Every elementary op round-trips DRAM.  SIMD ops partition M across
        # clusters/cores when possible; otherwise N with an explicit AR.
        root_children = []
        gb_gemm = TileNode(
            level="GB", index=0, loops=[Loop("M", m_tiles)],
            spatial_loops=[Loop("N", n_cl, True)],
            input_tensors=gemm_only_inputs, output_tensors=(inter,),
            children=[gemm_ob], schedule="sequential", label="T_gemm_gb")
        root_children.append(gb_gemm)
        m_cl = _clamped_spatial(M, want_cl)
        m_co = _clamped_spatial(_ceil_div(M, m_cl), want_co)
        m_leaf_u = _ceil_div(M, m_cl * m_co * m_tiles)
        for i, op in enumerate(simd_ops):
            shape = {d: (m_leaf_u if d == "M" else co.dim_sizes[d])
                     for d in op.dims}
            opin = tuple(op.inputs)
            ob_n = TileNode(
                level="OB", index=0, loops=[],
                spatial_loops=[Loop("M", m_co, True)],
                input_tensors=opin, output_tensors=(op.output,),
                children=[_simd_node(op, shape)], label=f"T_{op.name}_ob")
            gb_n = TileNode(
                level="GB", index=i + 1, loops=[Loop("M", m_tiles)],
                spatial_loops=[Loop("M", m_cl, True)],
                input_tensors=opin, output_tensors=(op.output,),
                children=[ob_n], schedule="sequential", label=f"T_{op.name}_gb")
            root_children.append(gb_n)
    else:
        raise ValueError(f"unknown variant {spec.variant}")

    root = TileNode(
        level="DRAM", index=0, loops=[], spatial_loops=[],
        input_tensors=(), output_tensors=(),
        children=root_children, schedule="sequential", label="T_root")
    return root, tiling


# ======================================================= attention builders


def _build_attention(co: CompoundOp, arch: Arch, spec: MappingSpec) -> Tuple[TileNode, Tiling]:
    """UA / PFA / FA trees (§V-D2).

    FA: query rows (M) spatially partitioned when M is large enough; KV
    streamed temporally in n_tiles blocks with online softmax (no
    collectives).  When M is small (decode), N is partitioned across
    clusters and a final merge All-Reduce on (O, stats) is required —
    flash-decoding style.
    """
    M, N, K = (co.dim_sizes[d] for d in ("M", "N", "K"))
    L = co.dim_sizes["L"]
    total_cores = arch.total_cores
    dtype_b = co.tensors["S"].dtype_bytes
    row_parallel = M >= total_cores        # enough query rows to go around

    want_cl = _sp_want(spec.sp_cluster, arch.num_clusters)
    want_co = _sp_want(spec.sp_core, arch.cores_per_cluster)
    if row_parallel:
        sp_dim = "M"
        sp_gb = _clamped_spatial(M, want_cl)
        sp_ob = _clamped_spatial(_ceil_div(M, sp_gb), want_co)
    else:
        sp_dim = "N"
        sp_gb = _clamped_spatial(N, want_cl)
        sp_ob = _clamped_spatial(_ceil_div(N, sp_gb), want_co)

    m_tiles = vmin(spec.m_tiles, M)
    # KV-block cap: number of N elements per core, ceil-div so residual
    # (edge) tiles at non-divisible sizes still count as a streamable block.
    n_cap = vmax(1, _ceil_div(N, sp_gb * sp_ob)) if sp_dim == "N" else N
    n_tiles = vmin(spec.n_tiles, n_cap)
    # KV streaming (the N temporal loop) lives at the GB node: blocks of
    # K^T/V are staged DRAM->GB per iteration (FLAT/FlashAttention style).
    gb_loops = ([Loop("M", m_tiles), Loop("N", n_tiles)]
                if spec.loop_order_gb[0] == "M"
                else [Loop("N", n_tiles), Loop("M", m_tiles)])
    tiling = Tiling(
        co.dim_sizes,
        temporal={"GB": {"M": m_tiles, "N": n_tiles}},
        spatial={"GB": {sp_dim: sp_gb}, "OB": {sp_dim: sp_ob}},
    )
    leaf = {d: tiling.leaf_tile(d) for d in ("M", "N", "K", "L")}
    score = co.op("Op1_score")
    ctx = co.op("Op8_context")
    simd_ops = [o for o in co.ops if o.kind == "simd"]

    def ob_node(children, inputs, outputs, loops=None, label="") -> TileNode:
        return TileNode(
            level="OB", index=0, loops=loops or [],
            spatial_loops=[Loop(sp_dim, sp_ob, True)],
            input_tensors=tuple(inputs), output_tensors=tuple(outputs),
            children=children, schedule="sequential", label=label)

    if spec.variant == "fa":
        # one fused GB subtree; KV streamed in n_tiles blocks
        body: List[Node] = []
        kv_leaf = dict(leaf)
        body.append(_gemm_node(score, kv_leaf))
        for op in simd_ops:
            shape = {d: leaf.get(d, 1) for d in op.dims}
            body.append(_simd_node(op, shape))
        body.append(_gemm_node(ctx, kv_leaf))
        inner = ob_node(body, ("Q", "Kt", "V"), (co.external_outputs[0],),
                        label="T_fa_ob")
        children: List[Node] = [inner]
        if not row_parallel:
            # flash-decoding final merge: AR of O tile + running stats,
            # once per M tile (i.e. per 1/n_tiles of the GB iterations).
            # participants == 1 grid points cost exactly zero (the
            # collective model short-circuits), so the node is added
            # unconditionally — sp_gb may be an array on the batched path.
            merge_dv = (leaf["M"] * L + 2 * leaf["M"]) * dtype_b
            children.append(CollectiveNode(
                col_type="AllReduce", tensor="O", reduce_op="add",
                src=("GB",), dest=("GB",), participants=sp_gb,
                data_volume_bytes=merge_dv, count=1, noc_level="GB",
                label="CO_fa_merge", exec_fraction=1.0 / n_tiles))
        gb = TileNode(
            level="GB", index=0, loops=list(gb_loops),
            spatial_loops=[Loop(sp_dim, sp_gb, True)],
            input_tensors=("Q", "Kt", "V"),
            output_tensors=(co.external_outputs[0],),
            bypass_tensors=tuple(co.intermediates()),
            children=children, schedule=spec.schedule, label="T_fa_gb",
            overlap=spec.overlap)
        root_children: List[Node] = [gb]

    elif spec.variant in ("pfa", "ua"):
        # score (+softmax if pfa) subtree, then context subtree.
        def gb_wrap(children, inputs, outputs, idx, bypass=(), label="",
                    loops=None, extra=0.0):
            return TileNode(
                level="GB", index=idx,
                loops=list(gb_loops) if loops is None else loops,
                spatial_loops=[Loop(sp_dim, sp_gb, True)],
                input_tensors=tuple(inputs), output_tensors=tuple(outputs),
                bypass_tensors=tuple(bypass),
                children=children, schedule=spec.schedule, label=label,
                extra_resident_bytes=extra, overlap=spec.overlap)

        score_ob = ob_node([_gemm_node(score, leaf)], ("Q", "Kt"), ("S",),
                           label="T_score_ob")
        # softmax sees full rows when rows are local (sp over M); when N is
        # partitioned (decode) pfa works on local slices + a stats AR while
        # ua computes full rows on a single cluster/core.
        softmax_n = (N if (not row_parallel and spec.variant == "ua")
                     or sp_dim == "M" else leaf["N"])
        softmax_shape = {"M": leaf["M"], "N": softmax_n}
        soft_nodes = [_simd_node(op, {d: softmax_shape.get(d, 1) for d in op.dims})
                      for op in simd_ops]
        ctx_ob = ob_node([_gemm_node(ctx, leaf)], ("P", "V"), ("O",),
                         label="T_ctx_ob")
        s_row_bytes = leaf["M"] * N * dtype_b  # full-row S resident at GB
        if spec.variant == "pfa":
            soft_ob = ob_node(soft_nodes, ("S",), ("P",), label="T_sm_ob")
            soft_ob.exec_fraction = 1.0 / n_tiles   # once per M tile
            children = [score_ob, soft_ob]
            if not row_parallel:
                # zero-cost when sp_gb == 1; see the fa merge note above
                children.insert(1, CollectiveNode(
                    col_type="AllReduce", tensor="S", reduce_op="max",
                    src=("GB",), dest=("GB",), participants=sp_gb,
                    data_volume_bytes=(leaf["M"] * 2) * dtype_b,
                    count=1, noc_level="GB", label="CO_pfa_stats",
                    exec_fraction=1.0 / n_tiles))
            gb1 = gb_wrap(children, ("Q", "Kt"), ("P",), 0,
                          bypass=("S", "mx", "D", "E", "sm"),
                          label="T_pfa_gb", extra=s_row_bytes)
            gb2 = gb_wrap([ctx_ob], ("P", "V"), ("O",), 1, label="T_ctx_gb")
            root_children = [gb1, gb2]
        else:  # ua: every op round-trips DRAM
            gb_score = gb_wrap([score_ob], ("Q", "Kt"), ("S",), 0,
                               label="T_score_gb")
            soft_ob = ob_node(soft_nodes, ("S",), ("P",), label="T_sm_ob")
            gb_soft = gb_wrap([soft_ob], ("S",), ("P",), 1,
                              bypass=("mx", "D", "E", "sm"),
                              loops=[Loop("M", m_tiles)],
                              label="T_sm_gb", extra=s_row_bytes)
            gb_ctx = gb_wrap([ctx_ob], ("P", "V"), ("O",), 2, label="T_ctx_gb")
            root_children = [gb_score, gb_soft, gb_ctx]
    else:
        raise ValueError(f"unknown attention variant {spec.variant}")

    root = TileNode(level="DRAM", index=0, children=root_children,
                    schedule="sequential", label="T_root")
    return root, tiling


# ====================================================== generic unfused


def _build_generic(co: CompoundOp, arch: Arch, spec: MappingSpec) -> Tuple[TileNode, Tiling]:
    """Generic unfused (or GB-fused) mapping for arbitrary compound ops:
    each op gets a GB subtree; the first non-reduced dim of each op is
    spatially partitioned; ``spec.variant == 'fused_dist'`` stages
    intermediates in GB instead of DRAM."""
    fused = spec.variant != "unfused"
    dims = co.dim_sizes
    # partition the largest dim common to most ops
    from collections import Counter
    cnt: Counter = Counter()
    for op in co.ops:
        for d in op.dims:
            if d not in op.reduce_dims:
                cnt[d] += 1
    part_dim = max(cnt, key=lambda d: (cnt[d], dims[d]))
    p_cl = _clamped_spatial(dims[part_dim],
                            _sp_want(spec.sp_cluster, arch.num_clusters))
    p_co = _clamped_spatial(_ceil_div(dims[part_dim], p_cl),
                            _sp_want(spec.sp_core, arch.cores_per_cluster))
    # ceil-div so the residual edge tile still counts as a temporal step
    m_tiles = vmin(spec.m_tiles, _ceil_div(dims[part_dim], p_cl * p_co))
    tiling = Tiling(dims,
                    temporal={"GB": {part_dim: m_tiles}},
                    spatial={"GB": {part_dim: p_cl}, "OB": {part_dim: p_co}})

    inter = set(co.intermediates())
    children: List[Node] = []
    for i, op in enumerate(co.ops):
        shape = {d: tiling.leaf_tile(d) for d in op.dims}
        node = (_gemm_node if op.kind == "gemm" else _simd_node)(op, shape)
        ob_n = TileNode(level="OB", index=0,
                        spatial_loops=[Loop(part_dim, p_co, True)],
                        input_tensors=tuple(op.inputs),
                        output_tensors=(op.output,),
                        children=[node], label=f"T_{op.name}_ob")
        byp = tuple(t for t in (op.inputs + (op.output,)) if t in inter) if fused else ()
        gb_n = TileNode(level="GB", index=i, loops=[Loop(part_dim, m_tiles)],
                        spatial_loops=[Loop(part_dim, p_cl, True)],
                        input_tensors=tuple(op.inputs),
                        output_tensors=(op.output,),
                        bypass_tensors=byp,
                        children=[ob_n], schedule="sequential",
                        label=f"T_{op.name}_gb")
        children.append(gb_n)
        # reduction over a spatially-partitioned dim needs an AR
        # (zero-cost at grid points where p_cl == 1)
        if any(d == part_dim for d in op.reduce_dims):
            out_b = co.tensors[op.output].size_bytes(dims)
            children.append(CollectiveNode(
                col_type="AllReduce", tensor=op.output, reduce_op="add",
                src=("GB",), dest=("GB",), participants=p_cl,
                data_volume_bytes=out_b / vmax(1, m_tiles), count=1,
                noc_level="GB", label=f"CO_{op.name}"))
    # the generic builder's collectives sit at the DRAM root, so the
    # overlap factor applies there (fused or not, the tree shape is the
    # same; fused only changes bypass staging)
    root = TileNode(level="DRAM", index=0, children=children,
                    schedule="sequential", label="T_root",
                    overlap=spec.overlap)
    return root, tiling


# ------------------------------------------------------------------ facade


def build_tree(co: CompoundOp, arch: Arch, spec: MappingSpec) -> Tuple[TileNode, Tiling]:
    if co.name in ("gemm", "gemm_softmax", "gemm_layernorm"):
        return _build_gemm_epilogue(co, arch, spec)
    if co.name in ("attention", "flash_attention"):
        return _build_attention(co, arch, spec)
    return _build_generic(co, arch, spec)


def evaluate_mapping(co: CompoundOp, arch: Arch, spec: MappingSpec) -> MappingResult:
    root, tiling = build_tree(co, arch, spec)
    valid, headroom, levels = validate_headroom_levels(root, arch, tiling,
                                                      co.tensors)
    cost = CostModel(arch, tiling, co.tensors).evaluate(root)
    return MappingResult(cost=cost, root=root, tiling=tiling, spec=spec,
                         valid=valid, headroom=headroom,
                         headroom_levels=levels)
