"""End-to-end driver: train a ~100M-parameter LM with the full stack —
synthetic pipeline, AdamW, checkpointing/restart, straggler monitor.

    PYTHONPATH=src python examples/train_lm.py --steps 300

(CPU-friendly: ~90M params; on real hardware swap --arch for any of the 10
assigned configs and --mesh production.)
"""
import argparse

from repro.launch.train import train_loop
from repro.models import Model, ModelConfig
from repro.train.optimizer import OptConfig


def lm100m() -> ModelConfig:
    return ModelConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=512,
        n_heads=8, n_kv_heads=4, d_ff=2048, vocab_size=32000)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/lm100m_ckpt")
    args = ap.parse_args()

    model = Model(lm100m())
    print(f"params: {model.n_params()/1e6:.1f}M")
    out = train_loop(
        model, steps=args.steps, batch=args.batch, seq=args.seq,
        opt_cfg=OptConfig(lr=3e-4, total_steps=args.steps,
                          warmup_steps=max(1, args.steps // 20)),
        ckpt_dir=args.ckpt_dir, ckpt_every=max(10, args.steps // 5),
        log_every=10)
    print(f"final loss {out['final_loss']:.4f} in {out['wall_s']:.0f}s "
          f"({out['steps_done']} steps)")


if __name__ == "__main__":
    main()
