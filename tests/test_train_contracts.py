"""Train-step collective contracts + jaxpr/HLO reconciliation (PR 8).

Three layers under test:

* the jaxpr walker's `while` trip-count detection and the
  `reduce_scatter` primitive mapping (`analysis/jaxpr.py`);
* the declared train schedule audited against the traced train step —
  including the injected-drift regression that proves a mis-declared
  psum is caught, in-process and through the CLI exit code
  (`analysis/contracts.py` + `parallel/collective_planner.py`);
* the jaxpr-vs-HLO reconciler and the checked-in golden fixture of a
  real compiled 2x2-mesh train step (`analysis/reconcile.py`).

Multi-device pieces run in subprocesses (XLA_FLAGS must be set before
jax initializes); everything else is pure and single-device.
"""
import gzip
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXDIR = os.path.join(REPO, "tests", "fixtures")


def _run_sub(script: str, timeout: int = 900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    try:
        return subprocess.run([sys.executable, "-c", script], env=env,
                              capture_output=True, text=True,
                              timeout=timeout)
    except (OSError, PermissionError) as e:
        pytest.skip(f"sandbox cannot spawn the subprocess: {e!r}")


# ------------------------------------------------- walker: while + RS


def test_while_static_trip_count_multiplies():
    """A counted while (fori_loop lowers to one) multiplies the body's
    FLOPs by the statically derived trip count — no finding."""
    from repro.analysis import trace_counts

    def f(x):
        return jax.lax.fori_loop(
            0, 7, lambda i, c: c @ c, x)

    tc = trace_counts(f, jax.ShapeDtypeStruct((8, 8), jnp.float32))
    assert tc.flops == pytest.approx(7 * 2 * 8 * 8 * 8)
    assert tc.findings == []


def test_while_unbounded_is_a_finding_not_a_silent_lower_bound():
    """A data-dependent while cannot be statically counted: the body is
    counted ONCE and an explicit `while-unbounded` finding marks the
    totals as a lower bound."""
    from repro.analysis import trace_counts

    def f(x):
        def cond(c):
            return jnp.sum(c) < 100.0     # data-dependent bound

        return jax.lax.while_loop(cond, lambda c: c @ c, x)

    tc = trace_counts(f, jax.ShapeDtypeStruct((8, 8), jnp.float32))
    assert tc.flops == pytest.approx(2 * 8 * 8 * 8)   # body once
    assert len(tc.findings) == 1
    assert tc.findings[0]["kind"] == "while-unbounded"
    assert "lower bound" in tc.findings[0]["detail"]


def test_while_literal_bound_nonunit_step():
    """Trip count = ceil((bound - init) / step) for literal-stepped
    counters, not just fori_loop's +1."""
    from repro.analysis import trace_counts

    def f(x):
        def body(carry):
            i, c = carry
            return i + 2, c @ c

        _, out = jax.lax.while_loop(lambda carry: carry[0] < 9,
                                    body, (0, x))
        return out

    tc = trace_counts(f, jax.ShapeDtypeStruct((4, 4), jnp.float32))
    # i = 0,2,4,6,8 -> 5 iterations
    assert tc.flops == pytest.approx(5 * 2 * 4 * 4 * 4)
    assert tc.findings == []


def test_psum_scatter_binds_reduce_scatter_primitive():
    """jax.lax.psum_scatter binds a primitive named `reduce_scatter`
    (NOT `psum_scatter`); the walker's table must key on the bound
    name or every Reduce-Scatter is silently dropped.  Regression for
    the bug the gather-arm train contract exposed."""
    from repro.analysis.jaxpr import _PRIM_TO_TYPE
    assert _PRIM_TO_TYPE.get("reduce_scatter") == "ReduceScatter"

    script = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=4'\n"
        "import jax, jax.numpy as jnp, numpy as np\n"
        "from jax.sharding import Mesh, PartitionSpec as P\n"
        "from jax.experimental.shard_map import shard_map\n"
        "from repro.analysis import trace_counts\n"
        "mesh = Mesh(np.array(jax.devices()).reshape(4), ('model',))\n"
        "def body(x):\n"
        "    return jax.lax.psum_scatter(x, 'model', tiled=True)\n"
        "f = shard_map(body, mesh=mesh, in_specs=P(), out_specs=P('model'),\n"
        "              check_rep=False)\n"
        "tc = trace_counts(f, jax.ShapeDtypeStruct((8, 4), jnp.float32))\n"
        "rec = tc.collectives.get(('ReduceScatter', 4))\n"
        "assert rec is not None, tc.to_dict()\n"
        "assert rec.count == 1.0, rec\n"
        "assert rec.dv_bytes == 8 * 4 * 4.0, rec\n"
        "print('RS_TRACED_OK')\n")
    r = _run_sub(script)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "RS_TRACED_OK" in r.stdout


# ------------------------------------------------- declared schedule


class _FakeMesh:
    """Just enough Mesh surface for train_collective_schedule."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


def test_train_schedule_declares_and_prices():
    """The declared schedule is serializable, covers both origins, and
    prices to a finite positive latency on the cluster NoC."""
    from repro.configs.registry import get_smoke_config
    from repro.parallel.collective_planner import (
        price_collective_schedule, train_collective_schedule)

    cfg = get_smoke_config("glm4-9b")
    mesh = _FakeMesh(data=2, model=4)
    sched = train_collective_schedule(cfg, mesh, 8, 16)
    assert sched
    origins = {d.origin for d in sched}
    assert origins == {"explicit", "gspmd"}
    labels = [d.label for d in sched]
    assert "xent/stats" in labels            # softmax schedule composed in
    assert any(lbl.startswith("grads/") for lbl in labels)
    for d in sched:
        rt = d.to_dict()
        assert set(rt) == {"label", "type", "dv_bytes", "participants",
                           "count", "origin"}
    t = price_collective_schedule(sched)
    assert 0.0 < t < float("inf")


def test_train_schedule_moe_has_no_all_to_all():
    """The MoE combine is declared as psums — a token all-to-all in the
    declaration would contradict models/moe.py's contract."""
    from repro.configs.registry import get_smoke_config
    from repro.parallel.collective_planner import train_collective_schedule

    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    sched = train_collective_schedule(cfg, _FakeMesh(data=2, model=4), 8, 16)
    assert all(d.col_type != "AllToAll" for d in sched)
    assert any(d.label == "moe/combine" for d in sched)
    assert any(d.label == "moe/router-grad" for d in sched)


def test_train_schedule_microbatch_scaling():
    """Microbatching splits activations into m smaller chunks: for
    activation-sized entries, count x m with DV / m (total wire
    invariant); for weight-gradient entries the psum repeats per
    microbatch on the SAME-sized tensor (count x m, DV unchanged —
    total wire grows), exactly what the traced jaxpr does."""
    from repro.configs.registry import get_smoke_config
    from repro.parallel.collective_planner import train_collective_schedule

    # pin the strategy: "auto" legitimately flips dist->gather when the
    # microbatch rows shrink, which would change the label set
    cfg = get_smoke_config("glm4-9b").with_(softmax_strategy="dist")
    mesh = _FakeMesh(data=2, model=4)
    s1 = {d.label: d for d in train_collective_schedule(
        cfg, mesh, 8, 16, microbatches=1) if d.origin == "explicit"}
    s2 = {d.label: d for d in train_collective_schedule(
        cfg, mesh, 8, 16, microbatches=2) if d.origin == "explicit"}
    assert set(s1) == set(s2)
    for label in ("xent/stats", "xent/hidden-cotangent"):  # activations
        assert s2[label].count == 2 * s1[label].count, label
        assert s2[label].dv_bytes == pytest.approx(
            s1[label].dv_bytes / 2), label
    w = "xent/unembed-grad"                                # weight grad
    assert s2[w].count == 2 * s1[w].count
    assert s2[w].dv_bytes == pytest.approx(s1[w].dv_bytes)


def test_train_contracts_pass_and_drift_is_caught():
    """The tentpole assertion, on a real 8-virtual-device mesh: the
    traced train step (dense + MoE) matches the declared schedule
    exactly, and a deliberately mis-declared psum (one count off) is
    flagged with the declared labels in the failure report."""
    script = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=8'\n"
        "from repro.analysis.contracts import train_contract_checks\n"
        "from repro.parallel.collective_planner import "
        "train_collective_schedule\n"
        "checks = train_contract_checks()\n"
        "assert checks, 'no train checks ran'\n"
        "bad = [c.describe() for c in checks if not c.ok]\n"
        "assert not bad, '\\n'.join(bad)\n"
        "names = [c.name for c in checks]\n"
        "assert any('moe-no-all-to-all' in n for n in names), names\n"
        "assert any('statically-bounded' in n for n in names), names\n"
        "assert any('qwen3-moe-30b-a3b' in n for n in names), names\n"
        "# inject drift: drop one xent/stats occurrence from the declaration\n"
        "def drifted(cfg, mesh, batch, seq, **kw):\n"
        "    out = []\n"
        "    for d in train_collective_schedule(cfg, mesh, batch, seq, **kw):\n"
        "        if d.label == 'xent/stats':\n"
        "            d = type(d)(d.label, d.col_type, d.dv_bytes,\n"
        "                        d.participants, d.count - 1, d.origin)\n"
        "        out.append(d)\n"
        "    return out\n"
        "checks = train_contract_checks(schedule_fn=drifted)\n"
        "fails = [c for c in checks if not c.ok]\n"
        "assert fails, 'mis-declared psum not caught'\n"
        "# the dropped count fails exactly; the bucket's wire may follow\n"
        "cnt = [c for c in fails if c.kind == 'collective_count']\n"
        "assert cnt, fails\n"
        "assert {c.kind for c in fails} <= "
        "{'collective_count', 'collective_wire_bytes'}, fails\n"
        "msg = cnt[0].describe()\n"
        "assert 'MISMATCH' in msg\n"
        "assert 'xent/stats' in cnt[0].detail['declared_labels']\n"
        "assert 'train_collective_schedule' in cnt[0].detail['note']\n"
        "print('TRAIN_CONTRACTS_OK', len(checks))\n")
    r = _run_sub(script)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "TRAIN_CONTRACTS_OK" in r.stdout


def test_cli_train_arm_exits_nonzero_on_drift():
    """`python -m repro.analysis --contracts=train` is the CI gate: it
    must exit 0 on the honest declaration and nonzero when the declared
    schedule drifts from the implementation."""
    script = (
        "import sys\n"
        "import repro.parallel.collective_planner as cp\n"
        "real = cp.train_collective_schedule\n"
        "def drifted(cfg, mesh, batch, seq, **kw):\n"
        "    out = []\n"
        "    for d in real(cfg, mesh, batch, seq, **kw):\n"
        "        if d.label == 'xent/stats':\n"
        "            d = type(d)(d.label, d.col_type, d.dv_bytes,\n"
        "                        d.participants, d.count - 1, d.origin)\n"
        "        out.append(d)\n"
        "    return out\n"
        "cp.train_collective_schedule = drifted\n"
        "from repro.analysis.__main__ import main\n"
        "rc = main(['--contracts=train', '--json', 'drift.json'])\n"
        "assert rc != 0, 'CLI returned 0 on a drifted schedule'\n"
        "import json\n"
        "rep = json.load(open('drift.json'))\n"
        "assert not rep['ok'] and not rep['contracts']['ok']\n"
        "assert rep['contracts']['arms'] == ['train']\n"
        "import os; os.unlink('drift.json')\n"
        "print('CLI_DRIFT_NONZERO_OK')\n")
    r = _run_sub(script)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "CLI_DRIFT_NONZERO_OK" in r.stdout
    # the human-readable mismatch report went to stderr
    assert "MISMATCH" in r.stderr


# --------------------------------------------------------- reconciler


def _stats(hlo: str):
    from repro.analysis import parse_collectives
    return parse_collectives(hlo)


AR_HLO = """
HloModule m
ENTRY %main (p0: f32[256]) -> f32[256] {
  %ar = f32[256] all-reduce(%p0), replica_groups={{0,1}}, to_apply=%add
  ROOT %out = f32[256] copy(%ar)
}
"""


def test_reconcile_match_uses_hlo_number():
    from repro.analysis import reconcile
    stats = _stats(AR_HLO)
    hlo_wire = stats.by_type["all-reduce"][2]
    rep = reconcile({"all-reduce": hlo_wire * 1.1}, stats, tol=0.25)
    assert rep.clean
    t = rep.per_type["all-reduce"]
    assert t.status == "match"
    assert t.reconciled_wire == pytest.approx(hlo_wire)
    assert rep.total_reconciled_wire == pytest.approx(hlo_wire)


def test_reconcile_mismatch_charges_larger_side():
    from repro.analysis import reconcile
    stats = _stats(AR_HLO)
    hlo_wire = stats.by_type["all-reduce"][2]
    rep = reconcile({"all-reduce": hlo_wire * 3.0}, stats, tol=0.25)
    assert not rep.clean
    t = rep.per_type["all-reduce"]
    assert t.status == "mismatch"
    assert t.reconciled_wire == pytest.approx(hlo_wire * 3.0)
    assert rep.findings[0]["kind"] == "reconcile-mismatch"
    assert "larger side" in rep.findings[0]["detail"]


def test_reconcile_hlo_only_and_expected_only():
    from repro.analysis import reconcile
    stats = _stats(AR_HLO)
    rep = reconcile({"all-gather": 512.0}, stats)
    assert {t.status for t in rep.per_type.values()} == \
        {"hlo-only", "expected-only"}
    kinds = {f["kind"] for f in rep.findings}
    assert kinds == {"reconcile-hlo-only", "reconcile-expected-only"}
    # never undercharge: both sides' volumes survive into the total
    assert rep.total_reconciled_wire == pytest.approx(
        512.0 + stats.by_type["all-reduce"][2])


def test_reconcile_zero_vs_zero_is_silent_match():
    """P=1 declarations produce 0 expected wire; an absent HLO op is 0
    too — that carries no signal and must not produce a finding."""
    from repro.analysis import reconcile
    from repro.analysis.hlo import CollectiveStats
    rep = reconcile({"collective-permute": 0.0}, CollectiveStats())
    assert rep.clean
    assert rep.per_type["collective-permute"].status == "match"


def test_reconcile_loop_trip_scales_while_body_collectives():
    from repro.analysis import reconcile
    hlo = """
HloModule m
%body (a: f32[64]) -> f32[64] {
  %ar = f32[64] all-reduce(%a), replica_groups={{0,1}}, to_apply=%add
  ROOT %r = f32[64] add(%ar, %ar)
}
ENTRY %main (p0: f32[64]) -> f32[64] {
  ROOT %w = f32[64] while(%p0), condition=%cond, body=%body
}
"""
    stats = _stats(hlo)
    per_trip = stats.by_type["all-reduce"][3]
    assert per_trip > 0.0
    rep = reconcile({"all-reduce": per_trip * 4}, stats, loop_trip=4)
    assert rep.clean
    assert rep.per_type["all-reduce"].hlo_wire == pytest.approx(per_trip * 4)


def test_reconcile_cell_adds_gspmd_schedule_to_trace():
    """Expected = jaxpr trace (explicit) + declared gspmd entries; the
    explicit entries must NOT be double-charged from the schedule."""
    from repro.analysis import reconcile_cell
    from repro.analysis.hlo import _wire_factor
    from repro.analysis.jaxpr import TraceCounts
    from repro.parallel.collective_planner import DeclaredCollective

    trace = TraceCounts()
    trace.add_collective("AllReduce", 2, 1.0, 1000.0, 1000.0)
    sched = [
        DeclaredCollective("grads/w", "AllReduce", 500.0, 2, 1,
                           origin="gspmd"),
        # explicit entries are already in the trace -> must be ignored
        DeclaredCollective("xent/stats", "AllReduce", 999.0, 2, 1,
                           origin="explicit"),
    ]
    from repro.analysis.hlo import CollectiveStats
    stats = CollectiveStats()
    stats.by_type["all-reduce"] = [1, 1500.0,
                                   _wire_factor("all-reduce", 2) * 1500.0,
                                   0.0]
    rep = reconcile_cell(trace, stats, schedule=sched)
    assert rep.clean, rep.findings
    t = rep.per_type["all-reduce"]
    assert t.expected_wire == pytest.approx(
        _wire_factor("all-reduce", 2) * 1500.0)
    assert t.status == "match"


# ----------------------------------------------------- golden fixture


def _load_fixture():
    with gzip.open(os.path.join(FIXDIR, "train_step_2x2.hlo.txt.gz"),
                   "rt") as fh:
        hlo = fh.read()
    with open(os.path.join(FIXDIR, "train_step_2x2.json")) as fh:
        side = json.load(fh)
    return hlo, side


def test_golden_fixture_reconciles():
    """The checked-in compiled HLO of a REAL 2x2-mesh glm4-9b train step
    must reconcile against its recorded jaxpr trace + declared schedule:
    the dominant all-reduce volume agrees within tolerance and nothing
    the declaration promises goes missing.  Pins the whole
    walker -> schedule -> HLO-parse -> reconciler chain without
    compiling anything in CI."""
    from repro.analysis import parse_collectives, reconcile_cell
    from repro.analysis.jaxpr import TraceCounts
    from repro.parallel.collective_planner import DeclaredCollective

    hlo, side = _load_fixture()
    stats = parse_collectives(hlo)
    assert stats.by_type.get("all-reduce", [0])[0] > 0, \
        "fixture HLO parse found no all-reduces"

    trace = TraceCounts(flops=side["jaxpr_trace"]["flops"])
    for c in side["jaxpr_trace"]["collectives"]:
        trace.add_collective(c["type"], c["participants"], c["count"],
                             c["dv_bytes"], c["shard_bytes"])
    sched = [DeclaredCollective(d["label"], d["type"], d["dv_bytes"],
                                d["participants"], d["count"], d["origin"])
             for d in side["schedule"]]

    rep = reconcile_cell(trace, stats, schedule=sched,
                         loop_trip=side["n_layers"])
    ar = rep.per_type["all-reduce"]
    assert ar.status == "match", rep.to_dict()
    assert ar.rel_err <= rep.tolerance
    # disagreements may only be GSPMD extras the declaration cannot see,
    # never a mismatch on something both sides claim to know
    kinds = {f["kind"] for f in rep.findings}
    assert "reconcile-mismatch" not in kinds, rep.describe_findings()
    assert "reconcile-expected-only" not in kinds, rep.describe_findings()
    assert rep.total_reconciled_wire >= rep.total_hlo_wire


def test_golden_fixture_trace_matches_declaration():
    """The sidecar's recorded jaxpr buckets equal the declared explicit
    schedule aggregated the same way — the train contract, replayed from
    the frozen artifact (catches schedule edits that forget the
    fixture)."""
    from repro.configs.registry import get_smoke_config
    from repro.parallel.collective_planner import train_collective_schedule

    _, side = _load_fixture()
    cfg = get_smoke_config(side["arch"])
    if side.get("softmax_strategy"):
        cfg = cfg.with_(softmax_strategy=side["softmax_strategy"])
    mesh = _FakeMesh(**side["mesh"])
    sched = train_collective_schedule(cfg, mesh, side["batch"], side["seq"])

    declared = {}
    for d in sched:
        if d.origin != "explicit" or d.participants <= 1:
            continue
        agg = declared.setdefault((d.col_type, d.participants),
                                  {"count": 0.0, "dv": 0.0})
        agg["count"] += d.count
        agg["dv"] += d.dv_bytes * d.count
    traced = {(c["type"], c["participants"]): c
              for c in side["jaxpr_trace"]["collectives"]
              if c["participants"] > 1}
    assert set(declared) == set(traced)
    for key, agg in declared.items():
        assert traced[key]["count"] == pytest.approx(agg["count"]), key
        assert traced[key]["dv_bytes"] == pytest.approx(agg["dv"]), key
