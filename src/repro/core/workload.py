"""Workload description for compound operations (COMET §II, §IV).

A *compound operation* is a DAG of elementary operations (GEMM and
non-GEMM/SIMD ops) connected through named tensors.  Each elementary op
declares its iteration space as a set of named dimensions; tensors declare
which dimensions they span.  This is the direct analogue of the paper's
YAML workload description.

Builders are provided for the paper's three case-study compound ops:
GEMM-Softmax, GEMM-LayerNorm and self-attention (plus the FlashAttention
decomposition of Fig. 2(a)), and for the SSD (Mamba-2) chunk dataflow used
by the TPU integration.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = [
    "TensorSpec",
    "Operation",
    "CompoundOp",
    "gemm",
    "gemm_softmax",
    "gemm_layernorm",
    "attention",
    "flash_attention",
    "ssd_chunk",
]


@dataclass(frozen=True)
class TensorSpec:
    """A named tensor spanning a subset of the compound op's dimensions."""

    name: str
    dims: Tuple[str, ...]
    dtype_bytes: int = 2  # bf16 by default

    def size_elems(self, dim_sizes: Dict[str, int]) -> int:
        n = 1
        for d in self.dims:
            n *= dim_sizes[d]
        return n

    def size_bytes(self, dim_sizes: Dict[str, int]) -> int:
        return self.size_elems(dim_sizes) * self.dtype_bytes


@dataclass(frozen=True)
class Operation:
    """One elementary operation inside a compound op.

    kind:          'gemm' (runs on the systolic/MXU unit) or 'simd'
                   (runs on the vector/SIMD unit).
    dims:          iteration-space dimensions of this op.
    reduce_dims:   subset of ``dims`` reduced away in the output.
    inputs/output: tensor names.
    flops_per_point: arithmetic ops per iteration-space point (e.g. a GEMM
                   point is one MAC = 2 flops; exp ~ 1 'op' on the SIMD
                   unit; fused multiply-adds in normalization count each).
    """

    name: str
    kind: str  # 'gemm' | 'simd'
    dims: Tuple[str, ...]
    inputs: Tuple[str, ...]
    output: str
    reduce_dims: Tuple[str, ...] = ()
    flops_per_point: float = 2.0

    def __post_init__(self) -> None:
        if self.kind not in ("gemm", "simd"):
            raise ValueError(f"unknown op kind {self.kind!r}")
        for d in self.reduce_dims:
            if d not in self.dims:
                raise ValueError(f"reduce dim {d} not in dims of {self.name}")


@dataclass
class CompoundOp:
    """A compound operation: dims, tensors and a topologically-ordered op list."""

    name: str
    dim_sizes: Dict[str, int]
    tensors: Dict[str, TensorSpec]
    ops: List[Operation] = field(default_factory=list)
    # Tensors that live in DRAM at the boundary of the compound op.
    external_inputs: Tuple[str, ...] = ()
    external_outputs: Tuple[str, ...] = ()

    # ------------------------------------------------------------------ DAG
    def producer(self, tensor: str) -> Optional[Operation]:
        for op in self.ops:
            if op.output == tensor:
                return op
        return None

    def consumers(self, tensor: str) -> List[Operation]:
        return [op for op in self.ops if tensor in op.inputs]

    def intermediates(self) -> List[str]:
        ext = set(self.external_inputs) | set(self.external_outputs)
        return [t for t in self.tensors if t not in ext]

    # ----------------------------------------------------------------- util
    def op(self, name: str) -> Operation:
        for o in self.ops:
            if o.name == name:
                return o
        raise KeyError(name)

    def validate(self) -> None:
        for op in self.ops:
            for t in op.inputs + (op.output,):
                if t not in self.tensors:
                    raise ValueError(f"{op.name}: unknown tensor {t}")
            for d in op.dims:
                if d not in self.dim_sizes:
                    raise ValueError(f"{op.name}: unknown dim {d}")
        # topological order: every input is external or already produced
        produced = set(self.external_inputs)
        for op in self.ops:
            for t in op.inputs:
                if t not in produced:
                    raise ValueError(
                        f"{op.name}: input {t} not produced before use"
                    )
            produced.add(op.output)

    def total_flops(self) -> float:
        total = 0.0
        for op in self.ops:
            pts = 1
            for d in op.dims:
                pts *= self.dim_sizes[d]
            total += pts * op.flops_per_point
        return total

    def gemm_ops(self) -> List[Operation]:
        return [o for o in self.ops if o.kind == "gemm"]

    def simd_ops(self) -> List[Operation]:
        return [o for o in self.ops if o.kind == "simd"]


# ===================================================================== builders


def gemm(M: int, N: int, K: int, *, name: str = "gemm", dtype_bytes: int = 2) -> CompoundOp:
    """Plain C[M,N] = A[M,K] @ B[K,N] (single-operator baseline)."""
    t = {
        "A": TensorSpec("A", ("M", "K"), dtype_bytes),
        "B": TensorSpec("B", ("K", "N"), dtype_bytes),
        "C": TensorSpec("C", ("M", "N"), dtype_bytes),
    }
    ops = [
        Operation("Op1_gemm", "gemm", ("M", "N", "K"), ("A", "B"), "C",
                  reduce_dims=("K",), flops_per_point=2.0),
    ]
    co = CompoundOp(name, {"M": M, "N": N, "K": K}, t, ops,
                    external_inputs=("A", "B"), external_outputs=("C",))
    co.validate()
    return co


def gemm_softmax(M: int, N: int, K: int, *, dtype_bytes: int = 2) -> CompoundOp:
    """GEMM followed by row-softmax over N, decomposed as Fig. 4(a).

    Op1: C = A@B          (gemm)
    Op3: m = rowmax(C)    (simd, reduce N)
    Op4: D = C - m        (simd)
    Op5: E = exp(D)       (simd)
    Op6: s = rowsum(E)    (simd, reduce N)
    Op7: P = E / s        (simd)
    """
    t = {
        "A": TensorSpec("A", ("M", "K"), dtype_bytes),
        "B": TensorSpec("B", ("K", "N"), dtype_bytes),
        "C": TensorSpec("C", ("M", "N"), dtype_bytes),
        "mx": TensorSpec("mx", ("M",), dtype_bytes),
        "D": TensorSpec("D", ("M", "N"), dtype_bytes),
        "E": TensorSpec("E", ("M", "N"), dtype_bytes),
        "sm": TensorSpec("sm", ("M",), dtype_bytes),
        "P": TensorSpec("P", ("M", "N"), dtype_bytes),
    }
    ops = [
        Operation("Op1_gemm", "gemm", ("M", "N", "K"), ("A", "B"), "C",
                  reduce_dims=("K",), flops_per_point=2.0),
        Operation("Op3_rowmax", "simd", ("M", "N"), ("C",), "mx",
                  reduce_dims=("N",), flops_per_point=1.0),
        Operation("Op4_sub", "simd", ("M", "N"), ("C", "mx"), "D",
                  flops_per_point=1.0),
        Operation("Op5_exp", "simd", ("M", "N"), ("D",), "E",
                  flops_per_point=1.0),
        Operation("Op6_rowsum", "simd", ("M", "N"), ("E",), "sm",
                  reduce_dims=("N",), flops_per_point=1.0),
        Operation("Op7_div", "simd", ("M", "N"), ("E", "sm"), "P",
                  flops_per_point=1.0),
    ]
    co = CompoundOp("gemm_softmax", {"M": M, "N": N, "K": K}, t, ops,
                    external_inputs=("A", "B"), external_outputs=("P",))
    co.validate()
    return co


def gemm_layernorm(M: int, N: int, K: int, *, dtype_bytes: int = 2) -> CompoundOp:
    """GEMM followed by LayerNorm over N.

    LayerNorm decomposes into more elementary ops than Softmax (the paper
    notes this is why its fusion win is larger):
    Op1: C = A@B            (gemm)
    Op2: mu = rowmean(C)    (simd, reduce N)
    Op3: D  = C - mu        (simd)
    Op4: sq = D*D           (simd)
    Op5: var= rowmean(sq)   (simd, reduce N)
    Op6: r  = rsqrt(var+e)  (simd, on M-vector)
    Op7: Nm = D * r         (simd)
    Op8: Y  = Nm*gamma+beta (simd, affine)
    """
    t = {
        "A": TensorSpec("A", ("M", "K"), dtype_bytes),
        "B": TensorSpec("B", ("K", "N"), dtype_bytes),
        "C": TensorSpec("C", ("M", "N"), dtype_bytes),
        "mu": TensorSpec("mu", ("M",), dtype_bytes),
        "D": TensorSpec("D", ("M", "N"), dtype_bytes),
        "sq": TensorSpec("sq", ("M", "N"), dtype_bytes),
        "var": TensorSpec("var", ("M",), dtype_bytes),
        "r": TensorSpec("r", ("M",), dtype_bytes),
        "Nm": TensorSpec("Nm", ("M", "N"), dtype_bytes),
        "gamma": TensorSpec("gamma", ("N",), dtype_bytes),
        "beta": TensorSpec("beta", ("N",), dtype_bytes),
        "Y": TensorSpec("Y", ("M", "N"), dtype_bytes),
    }
    ops = [
        Operation("Op1_gemm", "gemm", ("M", "N", "K"), ("A", "B"), "C",
                  reduce_dims=("K",), flops_per_point=2.0),
        Operation("Op2_mean", "simd", ("M", "N"), ("C",), "mu",
                  reduce_dims=("N",), flops_per_point=1.0),
        Operation("Op3_sub", "simd", ("M", "N"), ("C", "mu"), "D",
                  flops_per_point=1.0),
        Operation("Op4_sq", "simd", ("M", "N"), ("D",), "sq",
                  flops_per_point=1.0),
        Operation("Op5_var", "simd", ("M", "N"), ("sq",), "var",
                  reduce_dims=("N",), flops_per_point=1.0),
        Operation("Op6_rsqrt", "simd", ("M",), ("var",), "r",
                  flops_per_point=4.0),
        Operation("Op7_norm", "simd", ("M", "N"), ("D", "r"), "Nm",
                  flops_per_point=1.0),
        Operation("Op8_affine", "simd", ("M", "N"), ("Nm", "gamma", "beta"), "Y",
                  flops_per_point=2.0),
    ]
    co = CompoundOp("gemm_layernorm", {"M": M, "N": N, "K": K}, t, ops,
                    external_inputs=("A", "B", "gamma", "beta"),
                    external_outputs=("Y",))
    co.validate()
    return co


def attention(M: int, K: int, N: int, L: int, *, dtype_bytes: int = 2) -> CompoundOp:
    """Self-attention: S = Q@K^T, P = softmax_N(S), O = P@V.

    Q: (M, K)  Kt: (K, N)  V: (N, L)  O: (M, L)  — the paper's Table III/IV
    shape convention.
    """
    t = {
        "Q": TensorSpec("Q", ("M", "K"), dtype_bytes),
        "Kt": TensorSpec("Kt", ("K", "N"), dtype_bytes),
        "V": TensorSpec("V", ("N", "L"), dtype_bytes),
        "S": TensorSpec("S", ("M", "N"), dtype_bytes),
        "mx": TensorSpec("mx", ("M",), dtype_bytes),
        "D": TensorSpec("D", ("M", "N"), dtype_bytes),
        "E": TensorSpec("E", ("M", "N"), dtype_bytes),
        "sm": TensorSpec("sm", ("M",), dtype_bytes),
        "P": TensorSpec("P", ("M", "N"), dtype_bytes),
        "O": TensorSpec("O", ("M", "L"), dtype_bytes),
    }
    ops = [
        Operation("Op1_score", "gemm", ("M", "N", "K"), ("Q", "Kt"), "S",
                  reduce_dims=("K",), flops_per_point=2.0),
        Operation("Op3_rowmax", "simd", ("M", "N"), ("S",), "mx",
                  reduce_dims=("N",), flops_per_point=1.0),
        Operation("Op4_sub", "simd", ("M", "N"), ("S", "mx"), "D",
                  flops_per_point=1.0),
        Operation("Op5_exp", "simd", ("M", "N"), ("D",), "E",
                  flops_per_point=1.0),
        Operation("Op6_rowsum", "simd", ("M", "N"), ("E",), "sm",
                  reduce_dims=("N",), flops_per_point=1.0),
        Operation("Op7_div", "simd", ("M", "N"), ("E", "sm"), "P",
                  flops_per_point=1.0),
        Operation("Op8_context", "gemm", ("M", "L", "N"), ("P", "V"), "O",
                  reduce_dims=("N",), flops_per_point=2.0),
    ]
    co = CompoundOp("attention", {"M": M, "N": N, "K": K, "L": L}, t, ops,
                    external_inputs=("Q", "Kt", "V"), external_outputs=("O",))
    co.validate()
    return co


def flash_attention(M: int, K: int, N: int, L: int, *, dtype_bytes: int = 2) -> CompoundOp:
    """FlashAttention decomposition (Fig. 2(a)): online softmax adds extra
    non-GEMM work (running max merge, rescale of the accumulator) relative
    to plain attention — the paper observes this increases SIMD latency
    while eliminating off-chip traffic for S/P.
    """
    base = attention(M, K, N, L, dtype_bytes=dtype_bytes)
    t = dict(base.tensors)
    t.update({
        "m_run": TensorSpec("m_run", ("M",), dtype_bytes),
        "alpha": TensorSpec("alpha", ("M",), dtype_bytes),
        "Oacc": TensorSpec("Oacc", ("M", "L"), dtype_bytes),
    })
    ops = list(base.ops)
    # Extra online-softmax ops (block-merge arithmetic), all SIMD:
    ops.insert(2, Operation("Op3b_maxmerge", "simd", ("M",), ("mx",), "m_run",
                            flops_per_point=2.0))
    ops.insert(6, Operation("Op6b_scale", "simd", ("M",), ("sm",), "alpha",
                            flops_per_point=3.0))
    ops.append(Operation("Op9_rescale", "simd", ("M", "L"), ("O", "alpha"),
                         "Oacc", flops_per_point=2.0))
    co = CompoundOp("flash_attention", dict(base.dim_sizes), t, ops,
                    external_inputs=("Q", "Kt", "V"),
                    external_outputs=("Oacc",))
    co.validate()
    return co


def ssd_chunk(S: int, H: int, P: int, Dst: int, C: int, *, dtype_bytes: int = 2) -> CompoundOp:
    """One SSD (Mamba-2) chunk step as a compound op (TPU integration):

    per chunk of length C with H heads, head dim P, state Dst:
      Op1: G  = Bc^T @ Xc        (gemm,  K=C contraction  -> state update)
      Op2: Sdec = decay(G)       (simd,  cumulative decay weights)
      Op3: Yl = (Cc @ state)     (gemm,  inter-chunk output)
      Op4: A  = Cc @ Bc^T        (gemm,  intra-chunk attention-like)
      Op5: Am = A * Lmask        (simd,  causal decay mask)
      Op6: Yd = Am @ Xc          (gemm,  intra-chunk output)
      Op7: Y  = Yl + Yd          (simd)
    Dimensions: Sq=C (chunk len), Dst (state), P (head dim); H folded into
    the M dimension.
    """
    t = {
        "Xc": TensorSpec("Xc", ("Cq", "Pd"), dtype_bytes),
        "Bc": TensorSpec("Bc", ("Cq", "Ds"), dtype_bytes),
        "Cc": TensorSpec("Cc", ("Cq", "Ds"), dtype_bytes),
        "G": TensorSpec("G", ("Ds", "Pd"), dtype_bytes),
        "St": TensorSpec("St", ("Ds", "Pd"), dtype_bytes),
        "Yl": TensorSpec("Yl", ("Cq", "Pd"), dtype_bytes),
        "A": TensorSpec("A", ("Cq", "Cq2"), dtype_bytes),
        "Am": TensorSpec("Am", ("Cq", "Cq2"), dtype_bytes),
        "Lmask": TensorSpec("Lmask", ("Cq", "Cq2"), dtype_bytes),
        "Yd": TensorSpec("Yd", ("Cq", "Pd"), dtype_bytes),
        "Y": TensorSpec("Y", ("Cq", "Pd"), dtype_bytes),
    }
    ops = [
        Operation("Op1_state", "gemm", ("Ds", "Pd", "Cq"), ("Bc", "Xc"), "G",
                  reduce_dims=("Cq",), flops_per_point=2.0),
        Operation("Op2_decay", "simd", ("Ds", "Pd"), ("G",), "St",
                  flops_per_point=2.0),
        Operation("Op3_inter", "gemm", ("Cq", "Pd", "Ds"), ("Cc", "St"), "Yl",
                  reduce_dims=("Ds",), flops_per_point=2.0),
        Operation("Op4_intra", "gemm", ("Cq", "Cq2", "Ds"), ("Cc", "Bc"), "A",
                  reduce_dims=("Ds",), flops_per_point=2.0),
        Operation("Op5_mask", "simd", ("Cq", "Cq2"), ("A", "Lmask"), "Am",
                  flops_per_point=1.0),
        Operation("Op6_out", "gemm", ("Cq", "Pd", "Cq2"), ("Am", "Xc"), "Yd",
                  reduce_dims=("Cq2",), flops_per_point=2.0),
        Operation("Op7_add", "simd", ("Cq", "Pd"), ("Yl", "Yd"), "Y",
                  flops_per_point=1.0),
    ]
    dims = {"Cq": C, "Cq2": C, "Ds": Dst, "Pd": P * H, "Sq": S}
    co = CompoundOp("ssd_chunk", dims, t, ops,
                    external_inputs=("Xc", "Bc", "Cc", "Lmask"),
                    external_outputs=("Y",))
    co.validate()
    return co
