"""Vectorized batch map-space evaluation engine (DFModel-style factoring).

The mapping space of Fig. 1 factors into

* a **topology** — the discrete shape of the mapping tree: fusion variant
  x schedule x collective granularity x GB loop order.  A compound op has
  only a handful of topologies, and the tree structure (nodes, labels,
  tensors, collectives) is fully determined by the topology; and
* **numeric tiling parameters** — the m/k/n temporal tile counts, which
  only change Loop factors, tile sizes and collective data volumes.

Exploiting that, one topology's entire numeric grid is evaluated in a
single structure-of-arrays pass: ``build_tree`` is called once with NumPy
int arrays for the tiling parameters, and the unchanged Eq. 1-7 formulas
in :mod:`.cost`, :mod:`.collectives` and :mod:`.validate` broadcast
through the tree.  Results are bit-identical to the per-spec path (same
code, same formulas) at a fraction of the per-mapping Python overhead.

Two LRU caches sit on top:

* a **grid cache** keyed on (compound-op signature, arch name, topology,
  candidate axes) holding whole :class:`BatchResult` arrays, and
* a **spec cache** keyed on (compound-op signature, arch name, spec)
  holding lightweight (latency, energy, valid) triples for the randomized
  fallback path.

Both are shared across searches (see :func:`repro.core.search.search` and
``search_many``).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cost import CostModel
from .hardware import Arch
from .ir import MappingSpec, build_tree
from .validate import validity_mask
from .workload import CompoundOp

__all__ = [
    "Topology",
    "BatchResult",
    "co_signature",
    "numeric_axes",
    "enumerate_topologies",
    "evaluate_specs_batch",
    "evaluate_topology_grid",
    "evaluate_cached",
    "cache_info",
    "cache_clear",
]

GEMM_EPILOGUE_COS = ("gemm", "gemm_softmax", "gemm_layernorm")
ATTENTION_COS = ("attention", "flash_attention")

OBJECTIVES = ("latency", "energy", "edp")


@dataclass(frozen=True)
class Topology:
    """The discrete (non-numeric) part of a MappingSpec."""

    variant: str
    schedule: str = "sequential"
    collective_gran: str = "tile"
    loop_order_gb: Tuple[str, ...] = ("M", "N")

    def spec(self, m_tiles: int = 1, k_tiles: int = 1,
             n_tiles: int = 1) -> MappingSpec:
        return MappingSpec(
            variant=self.variant, m_tiles=m_tiles, k_tiles=k_tiles,
            n_tiles=n_tiles, schedule=self.schedule,
            collective_gran=self.collective_gran,
            loop_order_gb=self.loop_order_gb)


@dataclass
class BatchResult:
    """Structure-of-arrays result of one topology's numeric grid."""

    topo: Topology
    m_tiles: np.ndarray
    k_tiles: np.ndarray
    n_tiles: np.ndarray
    latency: np.ndarray
    energy_pj: np.ndarray
    valid: np.ndarray

    @property
    def size(self) -> int:
        return int(self.latency.shape[0])

    def scores(self, objective: str = "latency") -> np.ndarray:
        """Objective value per grid point; +inf where invalid."""
        if objective == "latency":
            s = self.latency
        elif objective == "energy":
            s = self.energy_pj
        elif objective == "edp":
            s = self.latency * self.energy_pj
        else:
            raise ValueError(f"unknown objective {objective!r}")
        return np.where(self.valid, s, np.inf)

    def best_index(self, objective: str = "latency") -> Optional[int]:
        if self.size == 0 or not bool(self.valid.any()):
            return None
        return int(np.argmin(self.scores(objective)))

    def spec_at(self, i: int) -> MappingSpec:
        return self.topo.spec(int(self.m_tiles[i]), int(self.k_tiles[i]),
                              int(self.n_tiles[i]))


# ------------------------------------------------------------- signatures


def co_signature(co: CompoundOp) -> Tuple:
    """Hashable identity of a compound op for cache keying: name, dims and
    tensor layouts (ops are derived from the builder, so name+dims+tensors
    pin the workload)."""
    return (
        co.name,
        tuple(sorted(co.dim_sizes.items())),
        tuple(sorted((t.name, t.dims, t.dtype_bytes)
                     for t in co.tensors.values())),
    )


def numeric_axes(co: CompoundOp) -> Tuple[str, ...]:
    """Which numeric MappingSpec axes actually reach the tree builder for
    this compound op (the rest are degenerate and pinned to 1)."""
    if co.name in GEMM_EPILOGUE_COS:
        return ("m_tiles", "k_tiles")
    if co.name in ATTENTION_COS:
        return ("m_tiles", "n_tiles")
    return ("m_tiles",)


def topology_fields(co: CompoundOp) -> Tuple[str, ...]:
    """Which discrete MappingSpec fields alter the tree for this compound
    op.  GEMM-epilogue trees ignore the GB loop order; attention trees
    ignore the collective granularity; the generic builder only branches
    on fused-vs-unfused."""
    if co.name in GEMM_EPILOGUE_COS:
        return ("variant", "schedule", "collective_gran")
    if co.name in ATTENTION_COS:
        return ("variant", "schedule", "loop_order_gb")
    return ("variant",)


def enumerate_topologies(co: CompoundOp,
                         cands: Dict[str, List]) -> List[Topology]:
    """All distinct topologies for ``co`` given the candidate sets from
    :func:`repro.core.search.candidate_specs`.  Fields that do not alter
    the tree are pinned to their first candidate, so the enumeration has
    no duplicate-cost topologies."""
    fields = topology_fields(co)

    def opts(name: str) -> List:
        return cands[name] if name in fields else cands[name][:1]

    out = []
    for variant in opts("variant"):
        for schedule in opts("schedule"):
            for gran in opts("collective_gran"):
                for lo in opts("loop_order_gb"):
                    out.append(Topology(variant=variant, schedule=schedule,
                                        collective_gran=gran,
                                        loop_order_gb=tuple(lo)))
    return out


# ------------------------------------------------------------- evaluation


def evaluate_specs_batch(co: CompoundOp, arch: Arch, topo: Topology,
                         m_tiles: Sequence[int], k_tiles: Sequence[int],
                         n_tiles: Sequence[int]) -> BatchResult:
    """Evaluate parallel arrays of (m, k, n) tile counts for one topology
    in a single vectorized pass."""
    m = np.asarray(m_tiles, dtype=np.int64)
    k = np.asarray(k_tiles, dtype=np.int64)
    n = np.asarray(n_tiles, dtype=np.int64)
    m, k, n = np.broadcast_arrays(m, k, n)
    shape = m.shape
    spec = MappingSpec(
        variant=topo.variant, m_tiles=m, k_tiles=k, n_tiles=n,
        schedule=topo.schedule, collective_gran=topo.collective_gran,
        loop_order_gb=topo.loop_order_gb)
    try:
        root, tiling = build_tree(co, arch, spec)
    except (ValueError, KeyError):
        # Whole topology rejected (e.g. unknown variant for this builder):
        # mirror the scalar path, which skips these specs.
        zeros = np.zeros(shape)
        return BatchResult(topo, m, k, n, zeros, zeros,
                           np.zeros(shape, dtype=bool))
    valid = np.broadcast_to(
        validity_mask(root, arch, tiling, co.tensors), shape).copy()
    cost = CostModel(arch, tiling, co.tensors,
                     track_breakdown=False).evaluate(root)
    latency = np.ascontiguousarray(
        np.broadcast_to(np.asarray(cost.latency, dtype=np.float64), shape))
    energy = np.ascontiguousarray(
        np.broadcast_to(np.asarray(cost.energy_pj, dtype=np.float64), shape))
    return BatchResult(topo, m, k, n, latency, energy, valid)


def _grid_arrays(co: CompoundOp, cands: Dict[str, List]
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    axes = numeric_axes(co)
    per_axis = [np.asarray(cands[ax], dtype=np.int64) if ax in axes
                else np.asarray([1], dtype=np.int64)
                for ax in ("m_tiles", "k_tiles", "n_tiles")]
    mg = np.meshgrid(*per_axis, indexing="ij")
    return tuple(g.reshape(-1) for g in mg)


def grid_size(co: CompoundOp, cands: Dict[str, List]) -> int:
    """Number of grid points per topology for this compound op."""
    n = 1
    for ax in numeric_axes(co):
        n *= len(cands[ax])
    return n


# ------------------------------------------------------------------ caches


class _LRU:
    """Tiny thread-safe LRU (search_many fans searches out over threads
    that share these caches)."""

    def __init__(self, maxsize: int):
        self.maxsize = maxsize
        self.data: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self._lock = threading.Lock()

    def get(self, key):
        with self._lock:
            if key in self.data:
                self.data.move_to_end(key)
                self.hits += 1
                return self.data[key]
            self.misses += 1
            return None

    def put(self, key, value) -> None:
        with self._lock:
            self.data[key] = value
            self.data.move_to_end(key)
            while len(self.data) > self.maxsize:
                self.data.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self.data.clear()
            self.hits = 0
            self.misses = 0


_GRID_CACHE = _LRU(maxsize=1024)
_SPEC_CACHE = _LRU(maxsize=65536)


def cache_info() -> Dict[str, Dict[str, int]]:
    return {
        "grid": {"hits": _GRID_CACHE.hits, "misses": _GRID_CACHE.misses,
                 "size": len(_GRID_CACHE.data)},
        "spec": {"hits": _SPEC_CACHE.hits, "misses": _SPEC_CACHE.misses,
                 "size": len(_SPEC_CACHE.data)},
    }


def cache_clear() -> None:
    _GRID_CACHE.clear()
    _SPEC_CACHE.clear()


def evaluate_topology_grid(co: CompoundOp, arch: Arch, topo: Topology,
                           cands: Dict[str, List]) -> BatchResult:
    """Whole-grid evaluation of one topology, LRU-cached on the compound
    op signature, arch name, topology and candidate axes."""
    key = (co_signature(co), arch.name, topo,
           tuple(cands["m_tiles"]), tuple(cands["k_tiles"]),
           tuple(cands["n_tiles"]))
    hit = _GRID_CACHE.get(key)
    if hit is not None:
        return hit
    m, k, n = _grid_arrays(co, cands)
    br = evaluate_specs_batch(co, arch, topo, m, k, n)
    _GRID_CACHE.put(key, br)
    return br


def evaluate_cached(co: CompoundOp, arch: Arch, spec: MappingSpec
                    ) -> Optional[Tuple[float, float, bool]]:
    """Lightweight cached per-spec evaluation: (latency, energy_pj, valid),
    or None when the spec is rejected outright (the scalar path raises).
    Shared by the randomized search fallback across searches."""
    key = (co_signature(co), arch.name, spec)
    hit = _SPEC_CACHE.get(key)
    if hit is not None:
        return hit if hit != () else None
    from .ir import evaluate_mapping
    try:
        r = evaluate_mapping(co, arch, spec)
        val = (r.latency, r.energy_pj, r.valid)
    except (ValueError, KeyError):
        val = ()
    _SPEC_CACHE.put(key, val)
    return val if val != () else None
