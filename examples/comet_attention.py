"""Case study: optimize self-attention dataflows with COMET (paper §V-D2)
and show the TPU integration — the same cost model picks the Pallas
FlashAttention kernel's block sizes and the vocab-softmax collective
strategy used by the training framework.

    PYTHONPATH=src python examples/comet_attention.py
"""
import time

from repro.core import attention, flash_attention
from repro.core.hardware import cloud, edge, tpu_v5e
from repro.core.plan import get_plan_cache
from repro.core.search import search
from repro.kernels.autotune import attention_blocks, gemm_epilogue_blocks
from repro.parallel.collective_planner import plan_softmax_strategy


def main() -> None:
    print("== UA / PFA / FA across paper shapes (Table III/IV) ==")
    for arch in (edge(), cloud()):
        for (M, K, N, L) in ((1024, 256, 1024, 256), (1, 128, 8192, 128)):
            ua = search(attention(M, K, N, L), arch, budget=300, seed=0,
                        variants=["ua"]).latency
            pfa = search(attention(M, K, N, L), arch, budget=300, seed=0,
                         variants=["pfa"]).latency
            fa = search(flash_attention(M, K, N, L), arch, budget=300,
                        seed=0, variants=["fa"]).latency
            print(f"  {arch.name:5s} M={M:5d} N={N:5d}: "
                  f"UA {ua*1e6:8.1f}us | PFA {pfa*1e6:8.1f}us | "
                  f"FA {fa*1e6:8.1f}us  (FA speedup {ua/fa:4.2f}x)")

    print("\n== TPU integration: COMET-tuned Pallas block sizes ==")
    print("   (each selection resolves through the PlanCache: first call")
    print("   solves and persists a plan, later calls/processes look up)")
    for (sq, skv, d) in ((4096, 4096, 128), (1, 32768, 128),
                         (32768, 32768, 64)):
        t0 = time.perf_counter()
        bq, bk = attention_blocks(sq, skv, d)
        cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        attention_blocks(sq, skv, d)
        warm = time.perf_counter() - t0
        print(f"  flash_attention S={sq:6d}/{skv:6d} d={d:4d} "
              f"-> block_q={bq}, block_k={bk}  "
              f"(cold {cold * 1e3:5.1f}ms, warm {warm * 1e6:5.1f}us)")
    bm, bk = gemm_epilogue_blocks(4096, 8192, 4096)
    print(f"  gemm_softmax 4096x8192x4096 -> block_m={bm}, block_k={bk}")
    stats = get_plan_cache().stats
    print(f"  plan cache: {stats['misses']} solved, "
          f"{stats['hits_mem'] + stats['hits_disk']} hits "
          f"(store: {get_plan_cache().root})")

    print("\n== collective planner: vocab-sharded softmax strategy ==")
    for rows, cols in ((65536, 151552), (128, 129280), (1, 4096)):
        s = plan_softmax_strategy(rows, cols, participants=16)
        print(f"  rows={rows:6d} vocab={cols:6d} x16 shards -> {s}")


if __name__ == "__main__":
    main()
