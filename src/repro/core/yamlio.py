"""YAML front-end (COMET §V-A: 'The simulator accepts YAML-formatted
specifications of the workload, mapping, architecture description and
mapping constraints').

Schema
------
workload:
  kind: gemm_softmax | gemm_layernorm | attention | flash_attention | gemm
  dims: {M: 512, N: 1024, K: 128, L: 256}   # L only for attention
architecture: edge | cloud | tpu_v5e        # or an inline dict of overrides
mapping:                                     # optional -> search if absent
  variant: fused_dist
  m_tiles: 8
  k_tiles: 2
  n_tiles: 1
  sp_cluster: 0                              # spatial fanout, 0 = arch max
  sp_core: 0
  schedule: sequential
  collective_gran: tile
constraints:
  budget: 2000
  seed: 0
  objective: latency                # latency | energy | edp | pareto | pareto3
  variants: [fused_dist, fused_std]
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import yaml

from . import hardware, workload
from .hardware import Arch
from .ir import MappingSpec, evaluate_mapping
from .search import search
from .workload import CompoundOp

__all__ = ["load_spec", "run_spec", "parse_workload", "parse_arch",
           "parse_mapping", "spec_to_dict"]


def parse_workload(w: Dict[str, Any]) -> CompoundOp:
    kind = w["kind"]
    d = w["dims"]
    if kind == "gemm":
        return workload.gemm(d["M"], d["N"], d["K"])
    if kind == "gemm_softmax":
        return workload.gemm_softmax(d["M"], d["N"], d["K"])
    if kind == "gemm_layernorm":
        return workload.gemm_layernorm(d["M"], d["N"], d["K"])
    if kind == "attention":
        return workload.attention(d["M"], d["K"], d["N"], d["L"])
    if kind == "flash_attention":
        return workload.flash_attention(d["M"], d["K"], d["N"], d["L"])
    if kind == "ssd_chunk":
        return workload.ssd_chunk(d["S"], d["H"], d["P"], d["Dst"], d["C"])
    raise ValueError(f"unknown workload kind {kind!r}")


def parse_arch(a: Any) -> Arch:
    if isinstance(a, str):
        return hardware.PRESETS[a]()
    if isinstance(a, dict):
        base = hardware.PRESETS[a.get("base", "cloud")]()
        # shallow overrides of scalar fields, e.g. {"base": "cloud"}
        return base
    raise ValueError("architecture must be a preset name or dict")


def parse_mapping(m: Dict[str, Any]) -> MappingSpec:
    fields = {f.name for f in dataclasses.fields(MappingSpec)}
    kw = {k: (tuple(v) if isinstance(v, list) else v)
          for k, v in m.items() if k in fields}
    return MappingSpec(**kw)


def spec_to_dict(spec: MappingSpec) -> Dict[str, Any]:
    d = dataclasses.asdict(spec)
    d["loop_order_gb"] = list(d["loop_order_gb"])
    return d


def load_spec(path_or_str: str) -> Dict[str, Any]:
    try:
        with open(path_or_str) as f:
            return yaml.safe_load(f)
    except (OSError, FileNotFoundError):
        return yaml.safe_load(path_or_str)


def run_spec(doc: Dict[str, Any]):
    """Run a parsed YAML document: returns MappingResult (explicit mapping)
    or SearchResult (mapping omitted -> search)."""
    co = parse_workload(doc["workload"])
    arch = parse_arch(doc.get("architecture", "cloud"))
    if "mapping" in doc and doc["mapping"]:
        return evaluate_mapping(co, arch, parse_mapping(doc["mapping"]))
    cons = doc.get("constraints", {}) or {}
    return search(
        co, arch,
        budget=int(cons.get("budget", 2000)),
        seed=int(cons.get("seed", 0)),
        objective=cons.get("objective", "latency"),
        variants=cons.get("variants"),
        allow_stats_gran=bool(cons.get("allow_stats_gran", False)),
    )
