# COMET core — the paper's primary contribution: explicit-collective
# mapping representation + compound-operation cost model + map-space search.
from . import collectives, cost, hardware, ir, mapping, search, validate, workload, yamlio
from .hardware import Arch, cloud, edge, tpu_v5e
from .ir import MappingResult, MappingSpec, build_tree, evaluate_mapping
from .search import SearchResult, search as map_search
from .workload import (CompoundOp, attention, flash_attention, gemm,
                       gemm_layernorm, gemm_softmax, ssd_chunk)

__all__ = [
    "Arch", "cloud", "edge", "tpu_v5e",
    "MappingResult", "MappingSpec", "build_tree", "evaluate_mapping",
    "SearchResult", "map_search",
    "CompoundOp", "attention", "flash_attention", "gemm",
    "gemm_layernorm", "gemm_softmax", "ssd_chunk",
]
