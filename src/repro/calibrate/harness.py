"""PARAM/nccl-tests-style collective microbenchmark harness.

The COMET collective model (Eqs. 3–4, ``core/collectives.py`` +
``core/cost.py``) is purely analytical: every per-NoC volume/hop/step
factor comes from the paper's HiSIM/Orion constants.  This harness is
the *measured* side of the calibration loop — it times real ``jax.lax``
collectives (the four COMET collective types that appear in compound-op
dataflows) over a log-spaced message-size sweep, nccl-tests style:

    for each collective type:
        for each log-spaced data volume DV:
            warmup, then best-of-``iters`` timed executions

The backend is pluggable.  :func:`run_sweep` drives any
``measure_fn(col_type, dv_bytes, participants) -> seconds`` — one timed
execution per call — so tests and benchmarks swap the real mesh for
:func:`synthetic_measure_fn` (an analytic generator from known
``NoCParams``, optionally jittered) and the whole fit path is
deterministic in CI.  :func:`jax_measure_fn` is the real backend: it
shards a buffer over every available device with ``shard_map`` and times
``psum`` / ``all_gather`` / ``psum_scatter`` / ``all_to_all``.  Timing
uses an injectable ``clock=`` (the ``planstore.py`` ``now=`` pattern),
so even the real backend can be driven with a fake clock.

Data-volume convention matches ``core/collectives.py``: ``dv_bytes`` is
the *logical tensor size* the collective operates on (the full tensor
for All-Reduce / Reduce-Scatter / All-to-All, the gathered result for
All-Gather), so measured points feed the fitter and
``collective_latency_terms`` without unit conversion.

Fault behavior (pinned by ``tests/test_calibrate.py``): a ``measure_fn``
that raises, returns non-finite/non-positive values, or produces wildly
non-monotone timings mid-sweep degrades the sweep to the surviving
points — one ``RuntimeWarning`` per cause (planstore-style), never a
crash, and the dropped points are tallied in ``SweepResult.dropped`` so
persistence can refuse to write a fit built from nothing.
"""
from __future__ import annotations

import threading
import time
import warnings
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.collectives import collective_seconds
from repro.core.hardware import NoCParams

__all__ = [
    "CALIBRATED_TYPES",
    "MeasuredPoint",
    "SweepConfig",
    "SweepResult",
    "run_sweep",
    "log_sizes",
    "jax_measure_fn",
    "synthetic_measure_fn",
]

#: the four COMET collective types a real backend can execute directly
#: (Gather/Broadcast have no first-class jax.lax collective; their
#: dissemination-tree factors share the AllGather exchange schedule).
CALIBRATED_TYPES = ("AllReduce", "AllGather", "ReduceScatter", "AllToAll")

#: a point whose timing falls below this fraction of the running maximum
#: of *smaller* messages of the same type is non-monotone noise (a
#: 4 MiB collective cannot be 4x faster than a 4 KiB one) and is dropped
NONMONOTONE_FRACTION = 0.25


@dataclass(frozen=True)
class MeasuredPoint:
    """One timed collective execution (best-of-iters)."""

    col_type: str
    data_volume_bytes: int      # logical tensor size (COMET DV convention)
    participants: int
    seconds: float

    def to_json(self) -> Dict:
        return {"col_type": self.col_type,
                "data_volume_bytes": self.data_volume_bytes,
                "participants": self.participants,
                "seconds": self.seconds}

    @classmethod
    def from_json(cls, d: Dict) -> "MeasuredPoint":
        return cls(str(d["col_type"]), int(d["data_volume_bytes"]),
                   int(d["participants"]), float(d["seconds"]))


@dataclass(frozen=True)
class SweepConfig:
    """Sweep shape: which collectives, which sizes, how many repeats."""

    col_types: Tuple[str, ...] = CALIBRATED_TYPES
    min_bytes: int = 1 << 12            # 4 KiB
    max_bytes: int = 1 << 24            # 16 MiB
    n_sizes: int = 8                    # log-spaced points per type
    warmup: int = 1                     # untimed executions per point
    iters: int = 5                      # timed executions; best is kept


@dataclass
class SweepResult:
    """Surviving measurements plus the fault tally of one sweep."""

    points: List[MeasuredPoint] = field(default_factory=list)
    dropped: Dict[str, int] = field(default_factory=dict)
    participants: Tuple[int, ...] = ()
    config: Optional[SweepConfig] = None

    @property
    def n_dropped(self) -> int:
        return sum(self.dropped.values())


# ------------------------------------------------------------- warn-once

_WARNED: set = set()
_WARNED_LOCK = threading.Lock()


def _warn_once(cause_key: Tuple, msg: str) -> None:
    """One warning per cause for the life of the process (planstore
    style): a flaky backend degrades once, not once per point."""
    with _WARNED_LOCK:
        if cause_key in _WARNED:
            return
        _WARNED.add(cause_key)
    warnings.warn(msg, RuntimeWarning, stacklevel=3)


def _reset_warned() -> None:
    """Test hook: forget which sweep degradations have been warned."""
    with _WARNED_LOCK:
        _WARNED.clear()


# ------------------------------------------------------------ size sweep


def log_sizes(min_bytes: int, max_bytes: int, n: int, *,
              multiple: int = 4) -> List[int]:
    """``n`` log-spaced byte sizes in [min_bytes, max_bytes], each
    rounded to a positive multiple of ``multiple`` (element size x
    participants, so per-device shards divide evenly), deduplicated and
    ascending."""
    if n <= 0:
        return []
    if n == 1:
        targets = [float(max_bytes)]
    else:
        ratio = (max_bytes / min_bytes) ** (1.0 / (n - 1))
        targets = [min_bytes * ratio ** i for i in range(n)]
    out: List[int] = []
    for t in targets:
        size = max(1, round(t / multiple)) * multiple
        if not out or size > out[-1]:
            out.append(size)
    return out


def run_sweep(
    measure_fn: Callable[[str, int, int], float],
    participants,
    *,
    config: Optional[SweepConfig] = None,
) -> SweepResult:
    """Drive ``measure_fn`` over the (type x size x participants) grid.

    ``participants`` is one int (the real backend: every device) or a
    sequence (synthetic backends can sweep several group sizes, which
    sharpens the fit's separation of the per-hop and per-byte terms).

    Each grid point is measured ``config.warmup + config.iters`` times;
    the best (minimum) timed iteration survives — the nccl-tests
    convention, which rejects one-sided scheduler noise.  Faults degrade
    per the module docstring; the returned ``SweepResult.dropped`` maps
    cause (``error`` / ``not-finite`` / ``non-monotone``) to the number
    of grid points lost to it.
    """
    cfg = config or SweepConfig()
    ps: Tuple[int, ...] = (tuple(participants)
                           if isinstance(participants, (list, tuple))
                           else (int(participants),))
    result = SweepResult(participants=ps, config=cfg)

    def drop(cause: str, detail: str) -> None:
        result.dropped[cause] = result.dropped.get(cause, 0) + 1
        _warn_once(("sweep", cause),
                   f"calibration sweep: dropping point(s) [{cause}] — "
                   f"{detail}; continuing with a partial sweep")

    for col_type in cfg.col_types:
        for P in ps:
            # shards must divide: DV multiple of elem_size * P * P (the
            # all-to-all split needs P^2 alignment of the flat buffer)
            sizes = log_sizes(cfg.min_bytes, cfg.max_bytes, cfg.n_sizes,
                              multiple=4 * max(1, P) * max(1, P))
            running_max = 0.0
            for dv in sizes:
                best = None
                try:
                    for _ in range(cfg.warmup):
                        measure_fn(col_type, dv, P)
                    for _ in range(cfg.iters):
                        t = float(measure_fn(col_type, dv, P))
                        if best is None or t < best:
                            best = t
                except Exception as e:  # noqa: BLE001 — degrade, never crash
                    drop("error", f"{col_type}@{dv}B/P={P} raised {e!r}")
                    continue
                if best is None or not (best > 0.0) or best != best \
                        or best == float("inf"):
                    drop("not-finite",
                         f"{col_type}@{dv}B/P={P} returned {best!r}")
                    continue
                if running_max > 0.0 and best < NONMONOTONE_FRACTION * running_max:
                    drop("non-monotone",
                         f"{col_type}@{dv}B/P={P}: {best:.3e}s after "
                         f"{running_max:.3e}s at a smaller size")
                    continue
                running_max = max(running_max, best)
                result.points.append(
                    MeasuredPoint(col_type, dv, P, best))
    return result


# ------------------------------------------------------------- backends


def jax_measure_fn(mesh=None, *, clock: Callable[[], float] = time.perf_counter,
                   dtype=None) -> Callable[[str, int, int], float]:
    """Real backend: time one execution of the requested collective over
    a 1-D device mesh with ``shard_map``.

    ``mesh`` defaults to all of ``jax.devices()`` on one axis — under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (which
    ``python -m repro.calibrate`` sets before importing jax) that is the
    forced 8-virtual-device CPU backend.  ``participants`` must equal
    the mesh size: a real collective cannot run over a subgroup the mesh
    does not express.  Jitted executables are cached per (type, shape),
    so the warmup iteration absorbs compilation and the timed iterations
    measure execution only.  ``clock`` is injectable (planstore ``now=``
    pattern) for deterministic tests.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P

    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), ("cal",))
    axis = mesh.axis_names[0]
    n_devices = int(np.prod(mesh.devices.shape))
    dtype = dtype or jnp.float32
    elem = jnp.dtype(dtype).itemsize

    # COMET DV (logical tensor bytes) -> global flat element count.  The
    # global array is sharded over the axis; AllGather's DV is the
    # *gathered* result, everyone else's the full input tensor.
    def bodies():
        return {
            "AllReduce": (lambda x: jax.lax.psum(x, axis), P(axis), P()),
            "AllGather": (lambda x: jax.lax.all_gather(x, axis, tiled=True),
                          P(axis), P()),
            "ReduceScatter": (lambda x: jax.lax.psum_scatter(
                x, axis, tiled=True), P(axis), P(axis)),
            "AllToAll": (lambda x: jax.lax.all_to_all(
                x, axis, 0, 0, tiled=True), P(axis), P(axis)),
        }

    compiled: Dict[Tuple[str, int], Callable] = {}

    def measure(col_type: str, dv_bytes: int, participants: int) -> float:
        if participants != n_devices:
            raise ValueError(
                f"jax backend measures over all {n_devices} mesh devices; "
                f"got participants={participants}")
        if col_type not in CALIBRATED_TYPES:
            raise ValueError(f"jax backend cannot execute {col_type!r}")
        elems = max(1, dv_bytes // elem)
        # every per-device shard must hold a whole number of elements,
        # and AllReduce shards the *replicated-sum* input per device
        elems = max(1, elems // (n_devices * n_devices)) \
            * n_devices * n_devices
        if col_type == "AllReduce":
            # DV is the full tensor each device contributes: global
            # input is P stacked shards of DV bytes
            global_elems = elems * n_devices
        elif col_type == "AllGather":
            global_elems = elems          # gathered result == DV
        else:
            # ReduceScatter / AllToAll: each device holds DV bytes
            global_elems = elems * n_devices
        key = (col_type, global_elems)
        fn = compiled.get(key)
        if fn is None:
            body, ins, outs = bodies()[col_type]
            fn = jax.jit(shard_map(body, mesh=mesh, in_specs=ins,
                                   out_specs=outs, check_rep=False))
            compiled[key] = fn
        x = jnp.zeros((global_elems,), dtype)
        jax.block_until_ready(x)
        t0 = clock()
        jax.block_until_ready(fn(x))
        return clock() - t0

    return measure


def synthetic_measure_fn(params: NoCParams, *, jitter: float = 0.0,
                         seed: int = 0) -> Callable[[str, int, int], float]:
    """Analytic backend: generate timings from known ``NoCParams``
    through the exact Eq. 1/3/4 prediction (``collective_seconds``) the
    fitter inverts, optionally with bounded multiplicative jitter
    (uniform in ``[1-jitter, 1+jitter]``, seeded, deterministic).

    This is the ground-truth generator of the recovery tests: a
    noise-free sweep must let the fitter recover ``params`` to float
    precision, and a jittered one must stay within the documented
    tolerance.
    """
    import random

    rng = random.Random(seed)

    def measure(col_type: str, dv_bytes: int, participants: int) -> float:
        t = collective_seconds(col_type, float(dv_bytes), int(participants),
                               params)
        if jitter > 0.0:
            t *= 1.0 + rng.uniform(-jitter, jitter)
        return t

    return measure


def _replace_mesh(params: NoCParams, mesh: Tuple[int, int]) -> NoCParams:
    """Reference NoC re-meshed to the measured topology (hop distances
    must be computed on the mesh the sweep actually ran on)."""
    return replace(params, mesh=mesh)
