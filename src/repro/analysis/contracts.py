"""Static trace contracts: kernels and sharded paths vs. the cost model.

The COMET cost model (Eqs. 1-7 + the tabulated collective tables) is only
useful if it tells the truth about what the Pallas kernels and the
shard_map model paths actually execute.  This module cross-checks them
*structurally* — no compilation, no execution:

1. **Kernel contracts** — for every paper kernel shape, resolve the
   winning :class:`~repro.core.plan.MappingPlan` through the
   :class:`~repro.core.plan.PlanCache` (exactly as the kernels themselves
   do), trace the kernel with the plan's block sizes via
   ``jax.make_jaxpr``, and assert the traced ``dot_general`` FLOPs equal
   the compound op's GEMM FLOPs — and that a single-core kernel traces
   **zero** collectives.

2. **Sharded contracts** — trace ``parallel.collective_planner.
   sharded_softmax_xent`` on a CPU mesh and assert its collective
   schedule (type, participant count, occurrence count, wire volume)
   matches :func:`~repro.parallel.collective_planner.
   softmax_collective_schedule` — the declaration the planner costs.
   Wire volumes on both sides go through ``core.collectives.
   collective_cost`` on the cluster NoC, so the check is "the cost model
   charges the traced program exactly what it charged the plan".

A mismatch report carries the op/kernel name, the plan fingerprints
(op_sig/arch_sig/best_index), and predicted vs. traced numbers — enough
to see *which* plan lied and by how much.

Tolerances: FLOP contracts are exact for the paper shapes (blocks divide
the aligned dims); the default ``tol`` absorbs block-padding slack for
off-grid shapes.  Collective counts are compared exactly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .jaxpr import TraceCounts, trace_counts

__all__ = ["ContractCheck", "ContractReport", "gemm_flops",
           "kernel_contract_checks", "sharded_contract_checks",
           "train_contract_checks", "train_trace",
           "TRAIN_CONTRACT_CONFIGS", "ARMS",
           "run_contracts", "KERNEL_TRACERS"]

DEFAULT_TOL = 0.02


@dataclass
class ContractCheck:
    """One predicted-vs-traced assertion."""

    name: str           # e.g. "gemm_softmax[4096,16384,4096]"
    kind: str           # "gemm_flops" | "collective_count" | ...
    predicted: float
    traced: float
    tolerance: float
    ok: bool
    detail: Dict = field(default_factory=dict)

    @property
    def rel_err(self) -> float:
        base = max(abs(self.predicted), abs(self.traced))
        return abs(self.predicted - self.traced) / base if base else 0.0

    def to_dict(self) -> Dict:
        return {"name": self.name, "kind": self.kind,
                "predicted": self.predicted, "traced": self.traced,
                "rel_err": self.rel_err, "tolerance": self.tolerance,
                "ok": self.ok, "detail": self.detail}

    def describe(self) -> str:
        status = "ok" if self.ok else "MISMATCH"
        line = (f"[{status}] {self.name} {self.kind}: "
                f"predicted={self.predicted:.6g} traced={self.traced:.6g} "
                f"(rel_err={self.rel_err:.2e}, tol={self.tolerance:g})")
        fp = self.detail.get("plan")
        if fp:
            line += (f"\n         plan op_sig={fp.get('op_sig', '?')[:12]} "
                     f"arch_sig={fp.get('arch_sig', '?')[:12]} "
                     f"best_index={fp.get('best_index')}")
        extra = self.detail.get("note")
        if extra and not self.ok:
            line += f"\n         {extra}"
        return line


@dataclass
class ContractReport:
    checks: List[ContractCheck] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return all(c.ok for c in self.checks)

    @property
    def failures(self) -> List[ContractCheck]:
        return [c for c in self.checks if not c.ok]

    def to_dict(self) -> Dict:
        return {"checked": len(self.checks),
                "passed": sum(1 for c in self.checks if c.ok),
                "failed": len(self.failures),
                "ok": self.ok,
                "checks": [c.to_dict() for c in self.checks]}

    def describe_failures(self) -> str:
        return "\n".join(c.describe() for c in self.failures)


def _mk_check(name: str, kind: str, predicted: float, traced: float,
              tol: float, detail: Dict) -> ContractCheck:
    base = max(abs(predicted), abs(traced))
    err = abs(predicted - traced) / base if base else 0.0
    return ContractCheck(name, kind, float(predicted), float(traced),
                         tol, err <= tol, detail)


def gemm_flops(co) -> float:
    """GEMM (MXU) FLOPs of a compound op — the number the traced
    ``dot_general`` count must reproduce."""
    total = 0.0
    for op in co.gemm_ops():
        pts = 1
        for d in op.dims:
            pts *= co.dim_sizes[d]
        total += pts * op.flops_per_point
    return total


def _plan_fp(plan) -> Dict:
    return {"op_sig": plan.op_sig, "arch_sig": plan.arch_sig,
            "best_index": plan.best_index,
            "engine_version": plan.engine_version}


# ------------------------------------------------------------- kernel arm


def _bf16(shape):
    import jax
    import jax.numpy as jnp
    return jax.ShapeDtypeStruct(shape, jnp.bfloat16)


def _trace_gemm_softmax(co, blocks):
    from repro.kernels.gemm_softmax import gemm_softmax as kernel
    bm, bk = blocks
    M, K = co.dim_sizes["M"], co.dim_sizes["K"]
    N = co.dim_sizes["N"]

    def fn(a, b):
        return kernel(a, b, block_m=bm, block_k=bk)

    return trace_counts(fn, _bf16((M, K)), _bf16((K, N)))


def _trace_gemm_layernorm(co, blocks):
    from repro.kernels.gemm_layernorm import gemm_layernorm as kernel
    bm, bk = blocks
    M, K = co.dim_sizes["M"], co.dim_sizes["K"]
    N = co.dim_sizes["N"]

    def fn(a, b, g, beta):
        return kernel(a, b, g, beta, block_m=bm, block_k=bk)

    return trace_counts(fn, _bf16((M, K)), _bf16((K, N)),
                        _bf16((N,)), _bf16((N,)))


def _trace_flash_attention(co, blocks):
    from repro.kernels.flash_attention import flash_attention_fwd as kernel
    bq, bk = blocks
    # co dims (workload.flash_attention(M, K, N, L)): M=Sq, K=L=head dim,
    # N=Skv.  causal=False: the compound op models the full score matrix;
    # the causal path skips blocks via cond, which the walker upper-bounds.
    M, N, D = co.dim_sizes["M"], co.dim_sizes["N"], co.dim_sizes["K"]

    def fn(q, k, v):
        return kernel(q, k, v, causal=False, block_q=bq, block_k=bk)

    return trace_counts(fn, _bf16((1, 1, M, D)), _bf16((1, 1, N, D)),
                        _bf16((1, 1, N, D)))


def _trace_ssd(co, chunk):
    from repro.kernels.ssd import ssd_scan_fwd as kernel
    # ssd_chunk dims: Cq=chunk, Ds=state, Pd=head dim x heads, Sq=sequence
    S, P, N = co.dim_sizes["Sq"], co.dim_sizes["Pd"], co.dim_sizes["Ds"]

    def fn(xdt, dA, B, C):
        return kernel(xdt, dA, B, C, chunk=chunk)

    return trace_counts(fn, _bf16((1, S, P)), _bf16((1, S)),
                        _bf16((1, S, N)), _bf16((1, S, N)))


# family -> tracer(co, blocks) -> TraceCounts.  Tests substitute a broken
# tracer here (via the ``tracers`` argument) to prove mismatches are caught.
KERNEL_TRACERS: Dict[str, Callable] = {
    "gemm_softmax": _trace_gemm_softmax,
    "gemm_layernorm": _trace_gemm_layernorm,
    "flash_attention": _trace_flash_attention,
    "ssd": _trace_ssd,
}


def kernel_contract_checks(
        shapes: Optional[Dict[str, Sequence[Tuple[int, ...]]]] = None,
        tol: float = DEFAULT_TOL,
        tracers: Optional[Dict[str, Callable]] = None,
) -> List[ContractCheck]:
    """Contract checks for every kernel shape in ``shapes`` (default: the
    paper shapes).  Each check resolves the kernel's MappingPlan exactly
    as the kernel would, traces the kernel at the plan's block sizes, and
    compares GEMM FLOPs (plus a zero-collective assertion — these are
    single-core kernels)."""
    from repro.core.plan import get_plan_cache
    from repro.kernels.autotune import (PAPER_KERNEL_SHAPES, _pair_of,
                                        attention_plan_job,
                                        gemm_epilogue_plan_job,
                                        ssd_plan_jobs)
    shapes = shapes if shapes is not None else PAPER_KERNEL_SHAPES
    use = dict(KERNEL_TRACERS)
    if tracers:
        use.update(tracers)
    cache = get_plan_cache()
    checks: List[ContractCheck] = []

    def add(family: str, shape, co, plan, blocks, trace: TraceCounts,
            predicted_flops: float, note: str = "") -> None:
        name = f"{family}[{','.join(str(s) for s in shape)}]"
        detail = {"family": family, "shape": list(shape),
                  "blocks": list(blocks) if isinstance(blocks, tuple)
                  else blocks,
                  "plan": _plan_fp(plan)}
        if note:
            detail["note"] = note
        checks.append(_mk_check(name, "gemm_flops", predicted_flops,
                                trace.flops, tol, detail))
        # single-core kernels must trace zero collectives
        checks.append(_mk_check(name, "collective_volume", 0.0,
                                trace.total_collective_dv(), 0.0, detail))

    for m, n, k in shapes.get("gemm_epilogue_blocks", ()):
        job = gemm_epilogue_plan_job(m, n, k)
        if job is None:
            continue
        co, arch, kw, pairs = job
        plan = cache.resolve(co, arch, **kw)
        blocks = _pair_of(plan, pairs)
        for family in ("gemm_softmax", "gemm_layernorm"):
            trace = use[family](co, blocks)
            add(family, (m, n, k), co, plan, blocks, trace, gemm_flops(co),
                note="both epilogue kernels share the gemm_softmax plan "
                     "(identical GEMM, different VPU epilogue)")

    for sq, skv, d in shapes.get("attention_blocks", ()):
        job = attention_plan_job(sq, skv, d)
        if job is None:
            continue
        co, arch, kw, pairs = job
        plan = cache.resolve(co, arch, **kw)
        blocks = _pair_of(plan, pairs)
        trace = use["flash_attention"](co, blocks)
        add("flash_attention", (sq, skv, d), co, plan, blocks, trace,
            gemm_flops(co),
            note="traced at the plan's aligned dims (M=max(sq,128)); "
                 "causal=False matches the non-causal compound op")

    for s, p, n in shapes.get("ssd_chunk_len", ()):
        jobs = ssd_plan_jobs(s, p, n)
        if not jobs:
            continue
        from repro.kernels.autotune import ssd_chunk_len
        c_win = ssd_chunk_len(s, p, n)
        for co, arch, kw, c in jobs:
            if c != c_win:
                continue
            plan = cache.resolve(co, arch, **kw)
            trace = use["ssd"](co, c_win)
            nchunks = -(-s // c_win)
            add("ssd", (s, p, n), co, plan, c_win, trace,
                gemm_flops(co) * nchunks,
                note=f"per-chunk compound op x {nchunks} chunks")
    return checks


# ------------------------------------------------------------ sharded arm


def _default_mesh():
    import jax
    import numpy as np
    from jax.sharding import Mesh
    devs = jax.devices()
    n = len(devs)
    if n >= 4 and n % 2 == 0:
        data, model = 2, n // 2
    else:
        data, model = 1, n
    arr = np.array(devs[: data * model]).reshape(data, model)
    return Mesh(arr, ("data", "model"))


def sharded_contract_checks(mesh=None, *, batch: int = 8, seq: int = 16,
                            d_model: int = 64, vocab_p: int = 512,
                            strategies: Sequence[str] = ("dist", "gather"),
                            tol: float = DEFAULT_TOL,
                            ) -> List[ContractCheck]:
    """Trace ``sharded_softmax_xent`` on a CPU mesh and check its
    collectives against the declared schedule the planner costs.

    Traced entries with participants <= 1 are ignored (the cost model
    charges zero for single-participant collectives), so this degrades
    gracefully on a 1-device mesh — run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` for a real
    check (the ``python -m repro.analysis`` CLI sets 8 by default).
    """
    import jax
    import jax.numpy as jnp
    from repro.core.collectives import collective_cost
    from repro.core.hardware import tpu_v5e
    from repro.parallel.collective_planner import (
        sharded_softmax_xent, softmax_collective_schedule)

    if mesh is None:
        mesh = _default_mesh()
    # sharded_softmax_xent reduces over every data-parallel axis present
    # (pod AND data on the multi-pod production mesh)
    dp = 1
    for ax in ("pod", "data"):
        dp *= int(mesh.shape.get(ax, 1))
    P_model = int(mesh.shape.get("model", 1))
    noc = tpu_v5e().cluster_noc
    rows_local = (batch * seq) // dp
    v_local = vocab_p // P_model
    real_vocab = vocab_p - max(1, v_local // 4)

    h = jax.ShapeDtypeStruct((batch, seq, d_model), jnp.float32)
    w = jax.ShapeDtypeStruct((d_model, vocab_p), jnp.float32)
    y = jax.ShapeDtypeStruct((batch, seq), jnp.int32)

    def wire(col_type: str, dv: float, P: int) -> float:
        return collective_cost(col_type, dv, P, noc).volume_bytes

    checks: List[ContractCheck] = []
    for strategy in strategies:
        def fn(h_, w_, y_, _strategy=strategy):
            return sharded_softmax_xent(h_, w_, y_, mesh,
                                        real_vocab=real_vocab,
                                        strategy=_strategy)

        trace = trace_counts(fn, h, w, y)
        declared = softmax_collective_schedule(
            strategy, rows_local, vocab_p, P_model, dp_participants=dp)
        name = f"sharded_softmax_xent[{strategy},mesh={dp}x{P_model}]"
        detail_base = {"strategy": strategy, "mesh": [dp, P_model],
                       "rows_local": rows_local, "vocab_p": vocab_p,
                       "declared": [list(c) for c in declared]}

        # GEMM FLOPs: the vocab-sharded logits GEMM, globally
        predicted_flops = 2.0 * batch * seq * d_model * vocab_p
        checks.append(_mk_check(name, "gemm_flops", predicted_flops,
                                trace.flops, tol, dict(detail_base)))

        traced = {k: r for k, r in trace.collectives.items()
                  if k[1] > 1}
        # The tracer buckets by (type, participants), so distinct declared
        # entries that share a key — e.g. model-axis stat All-Reduces and
        # data-parallel scalar All-Reduces on a mesh where both axes have
        # the same size — must be aggregated before comparison (wire is
        # linear in DV, so summing per-entry wires matches the traced
        # wire of the summed DV).
        declared_by_key: dict = {}
        for col_type, dv, P, count in declared:
            agg = declared_by_key.setdefault(
                (col_type, P), {"count": 0.0, "wire": 0.0})
            agg["count"] += count
            agg["wire"] += wire(col_type, dv * count, P)
        for (col_type, P), agg in declared_by_key.items():
            rec = traced.pop((col_type, P), None)
            detail = dict(detail_base)
            detail["participants"] = P
            detail["collective"] = col_type
            t_count = rec.count if rec else 0.0
            t_dv = rec.dv_bytes if rec else 0.0
            checks.append(_mk_check(f"{name}/{col_type}@P{P}",
                                    "collective_count", agg["count"],
                                    t_count, 0.0, detail))
            checks.append(_mk_check(f"{name}/{col_type}@P{P}",
                                    "collective_wire_bytes", agg["wire"],
                                    wire(col_type, t_dv, P), tol, detail))
        if traced:
            # collectives the implementation executes but the planner
            # never charges — exactly the drift this checker exists for
            detail = dict(detail_base)
            detail["undeclared"] = [r.to_dict() for r in traced.values()]
            detail["note"] = ("traced collectives missing from "
                              "softmax_collective_schedule")
            extra_dv = sum(r.dv_bytes for r in traced.values())
            checks.append(_mk_check(f"{name}/undeclared",
                                    "collective_volume", 0.0, extra_dv,
                                    0.0, detail))
    return checks


# -------------------------------------------------------------- train arm


# Representative train configs audited by the train arm: one dense, one
# MoE (the two loss/combine regimes the declared schedule distinguishes).
TRAIN_CONTRACT_CONFIGS: Tuple[str, ...] = ("glm4-9b", "qwen3-moe-30b-a3b")


def train_trace(arch_id: str, mesh=None, *, batch: int = 8, seq: int = 16,
                microbatches: int = 1, softmax_strategy: Optional[str] = None):
    """(cfg, TraceCounts) of one abstract ``make_train_step`` trace.

    Pure tracing — no compilation, no execution: the state/batch are
    ``ShapeDtypeStruct`` specs from ``launch.specs``, so this runs in
    milliseconds even for configs whose real parameters would not fit.
    """
    import jax
    from repro.configs.registry import Shape, get_smoke_config
    from repro.launch.specs import batch_specs, state_specs
    from repro.models.model import Model
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import make_train_step

    from .jaxpr import count_jaxpr

    if mesh is None:
        mesh = _default_mesh()
    cfg = get_smoke_config(arch_id)
    if softmax_strategy:
        cfg = cfg.with_(softmax_strategy=softmax_strategy)
    model = Model(cfg)
    step = make_train_step(model, OptConfig(), mesh,
                           microbatches=microbatches, use_planner_loss=True)
    state_ab, _ = state_specs(model, mesh)
    batch_ab = batch_specs(cfg, Shape("contract", seq, batch, "train"), mesh)
    return cfg, count_jaxpr(jax.make_jaxpr(step)(state_ab, batch_ab))


def train_contract_checks(mesh=None, *,
                          configs: Sequence[str] = TRAIN_CONTRACT_CONFIGS,
                          batch: int = 8, seq: int = 16,
                          microbatches: int = 1,
                          tol: float = DEFAULT_TOL,
                          schedule_fn=None) -> List[ContractCheck]:
    """Audit the full train-step collective schedule against the declared
    :func:`~repro.parallel.collective_planner.train_collective_schedule`.

    For each config the train step is traced abstractly on the CPU mesh
    and every traced (type, participants) bucket with participants > 1 is
    compared against the aggregated ``origin == "explicit"`` declaration:
    occurrence counts exactly, wire bytes through ``collective_cost`` (the
    cost model's own charge).  Traced-but-undeclared and declared-but-
    untraced both fail.  The MoE "no token all-to-all" docstring claim is
    a named invariant check.  ``schedule_fn`` substitutes a (deliberately
    wrong) declaration in tests to prove drift is caught.
    """
    from repro.core.collectives import collective_cost
    from repro.core.hardware import tpu_v5e
    from repro.parallel.collective_planner import train_collective_schedule

    if mesh is None:
        mesh = _default_mesh()
    if schedule_fn is None:
        schedule_fn = train_collective_schedule
    noc = tpu_v5e().cluster_noc

    def wire(col_type: str, dv: float, P: int) -> float:
        return collective_cost(col_type, dv, P, noc).volume_bytes

    mesh_desc = "x".join(str(int(mesh.shape[a])) for a in mesh.axis_names)
    checks: List[ContractCheck] = []
    for arch_id in configs:
        cfg, trace = train_trace(arch_id, mesh, batch=batch, seq=seq,
                                 microbatches=microbatches)
        sched = schedule_fn(cfg, mesh, batch, seq, microbatches=microbatches)
        explicit = [d for d in sched
                    if d.origin == "explicit" and d.participants > 1]
        name = f"train[{arch_id},mesh={mesh_desc},mb={microbatches}]"
        detail_base = {"arch": arch_id, "mesh": mesh_desc,
                       "batch": batch, "seq": seq,
                       "microbatches": microbatches,
                       "schedule": [d.to_dict() for d in sched]}

        traced = {k: r for k, r in trace.collectives.items() if k[1] > 1}
        declared_by_key: dict = {}
        for d in explicit:
            agg = declared_by_key.setdefault(
                (d.col_type, d.participants),
                {"count": 0.0, "wire": 0.0, "labels": []})
            agg["count"] += d.count
            agg["wire"] += wire(d.col_type, d.dv_bytes * d.count,
                                d.participants)
            agg["labels"].append(d.label)
        for (col_type, P), agg in sorted(declared_by_key.items()):
            rec = traced.pop((col_type, P), None)
            detail = dict(detail_base)
            detail["participants"] = P
            detail["collective"] = col_type
            detail["declared_labels"] = agg["labels"]
            detail["note"] = (
                f"declared by train_collective_schedule entries "
                f"{agg['labels']} (parallel/collective_planner.py); a count "
                f"mismatch means the implementation gained/lost a "
                f"collective or an AD-transpose rule changed — update the "
                f"declaration with the implementation")
            t_count = rec.count if rec else 0.0
            t_dv = rec.dv_bytes if rec else 0.0
            checks.append(_mk_check(f"{name}/{col_type}@P{P}",
                                    "collective_count", agg["count"],
                                    t_count, 0.0, detail))
            checks.append(_mk_check(f"{name}/{col_type}@P{P}",
                                    "collective_wire_bytes", agg["wire"],
                                    wire(col_type, t_dv, P), tol, detail))
        if traced:
            detail = dict(detail_base)
            detail["undeclared"] = [r.to_dict() for r in traced.values()]
            detail["note"] = (
                "traced collectives missing from train_collective_schedule "
                "— the train step executes collectives the cost model "
                "never charges; declare them (with origin='explicit') in "
                "parallel/collective_planner.py")
            extra_dv = sum(r.dv_bytes for r in traced.values())
            checks.append(_mk_check(f"{name}/undeclared",
                                    "collective_volume", 0.0, extra_dv,
                                    0.0, detail))
        if cfg.is_moe:
            # models/moe.py promises the EP combine is a psum — "no token
            # all-to-all is required".  Checked, not just documented.
            a2a = sum(r.count for r in trace.collectives.values()
                      if r.col_type == "AllToAll")
            detail = dict(detail_base)
            detail["note"] = ("models/moe.py claims the expert combine "
                              "needs no token all-to-all; the traced train "
                              "step must contain zero AllToAll ops")
            checks.append(_mk_check(f"{name}/moe-no-all-to-all",
                                    "collective_count", 0.0, a2a, 0.0,
                                    detail))
        # A train step must be statically countable: any while-unbounded
        # finding means the totals above are lower bounds, not contracts.
        detail = dict(detail_base)
        detail["findings"] = list(trace.findings)
        checks.append(_mk_check(f"{name}/statically-bounded",
                                "analysis_findings", 0.0,
                                float(len(trace.findings)), 0.0, detail))
    return checks


# ------------------------------------------------------------------ entry


ARMS = ("kernel", "sharded", "train")


def run_contracts(shapes=None, *, sharded: bool = True,
                  arms: Optional[Sequence[str]] = None,
                  tol: float = DEFAULT_TOL) -> ContractReport:
    """Selected contract arms as one report (the CLI and CI entry point).

    ``arms`` selects from ``("kernel", "sharded", "train")``; when None,
    the legacy ``sharded`` flag picks kernel(+sharded) for backward
    compatibility with pre-train-arm callers.
    """
    if arms is None:
        arms = ("kernel", "sharded") if sharded else ("kernel",)
    unknown = set(arms) - set(ARMS)
    if unknown:
        raise ValueError(f"unknown contract arms {sorted(unknown)}; "
                         f"pick from {ARMS}")
    report = ContractReport()
    if "kernel" in arms:
        report.checks.extend(kernel_contract_checks(shapes, tol=tol))
    if "sharded" in arms:
        report.checks.extend(sharded_contract_checks(tol=tol))
    if "train" in arms:
        report.checks.extend(train_contract_checks(tol=tol))
    return report
