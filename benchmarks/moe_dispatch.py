"""MoE expert-parallel dispatch strategies, costed with COMET's collective
model (the AllToAll entry of Fig. 1(b)) — now per hardware preset and
with the compute-collective ``overlap`` axis applied.

Two EP designs for (tokens T over dp axis, E experts over the P-way model
axis, top-k routing), per layer:

* **replicated-EP** (what the framework ships, models/moe.py): activations
  are already replicated over `model`; each shard gathers its experts'
  tokens locally and the combine is one AllReduce of the (T_local, d)
  output over `model`.  Collective volume per layer: AR(T_l·d).
* **a2a-EP** (classic GShard/DeepSpeed): tokens sequence-sharded over
  `model`; dispatch AllToAll (T_l/P·k copies out), expert compute,
  combine AllToAll back.  Volume: 2·A2A(T_l·k/P·d) — but the residual
  stream must also be resharded (AG per layer) unless the whole block is
  sequence-parallel.

Both strategies are charged twice: **serial** (``overlap=0``, the
pre-overlap model, every collective fully exposed) and
**overlap-adjusted** (``overlapped_collective_seconds`` with the expert
GEMM as the adjacent compute window — a2a-EP's dispatch/combine can hide
under expert compute; replicated-EP's single AllReduce has the same
window).  The crossover can *move* under overlap — a2a-EP's volume
advantage only matters for the exposed share — which is exactly the kind
of mapping decision COMET's explicit representation makes costable
before committing an implementation.

All collective charging goes through the shared ``collective_seconds`` /
``overlapped_collective_seconds`` entry points (``core/collectives.py``)
— no hand-rolled latency math (the pre-refactor ``_lat`` helper is
pinned bit-identical to ``collective_seconds`` in
``tests/test_collective_table.py``).

Usage::

    PYTHONPATH=src python benchmarks/moe_dispatch.py [--preset tpu_v5e]
        [--overlap 1.0] [--calibrated [STORE]]
"""
from __future__ import annotations

import argparse
from typing import Dict, Optional

from repro.core.collectives import (collective_seconds,
                                    overlapped_collective_seconds)
from repro.core.hardware import PRESETS

# (config name, d_model, top_k, moe_d_ff, T_local at train_4k dp scale)
CASES = [
    ("deepseek-v3-671b", 7168, 8, 2048, 65536),
    ("qwen3-moe-30b-a3b", 2048, 8, 768, 65536),
]


def _expert_gemm_seconds(arch, d: int, k: int, d_ff: int, t_l: int) -> float:
    """Per-layer expert compute across the cluster: every routed copy of
    every token runs the gated FFN (wi, wg, wo — 3 GEMMs, 2·d·d_ff MACs
    each); the cluster's peak absorbs the P-way expert parallelism."""
    flops = t_l * k * 3 * 2.0 * d * d_ff
    return flops / arch.peak_flops_total()


def _strategy_seconds(noc, P: int, d: int, k: int, t_l: int, *,
                      overlap: float, compute_s: float) -> Dict[str, float]:
    """Per-layer collective seconds of both EP designs at ``overlap``."""
    rep = overlapped_collective_seconds(
        "AllReduce", t_l * d * 2, P, noc,
        overlap=overlap, compute_seconds=compute_s)
    a2a = (2 * overlapped_collective_seconds(
        "AllToAll", (t_l // P) * k * d * 2, P, noc,
        overlap=overlap, compute_seconds=compute_s)
        + overlapped_collective_seconds(
            "AllGather", t_l * d * 2, P, noc,
            overlap=overlap, compute_seconds=compute_s))
    return {"replicated": rep, "a2a": a2a}


def run_all(presets=None, *, overlap: float = 1.0,
            calibrated: Optional[str] = None) -> Dict:
    """Cost both EP strategies per preset, serial and overlap-adjusted.

    ``overlap`` is the achievable overlap factor used for the adjusted
    numbers (1.0 = everything hideable hides — the optimistic bound; a
    calibrated value from ``repro.calibrate.overlap`` is the honest
    choice).  ``calibrated`` forwards to the preset constructors, so the
    collective model runs on measured-and-fitted NoC constants.
    """
    out = {}
    for preset in (presets or sorted(PRESETS)):
        arch = PRESETS[preset](calibrated=calibrated)
        noc = arch.cluster_noc
        P = noc.num_nodes
        if P <= 1:
            print(f"moe_dispatch[{preset}]: single-node cluster, "
                  f"no EP collectives to cost")
            continue
        out[preset] = {}
        for name, d, k, d_ff, t_l in CASES:
            comp = _expert_gemm_seconds(arch, d, k, d_ff, t_l)
            serial = _strategy_seconds(noc, P, d, k, t_l,
                                       overlap=0.0, compute_s=comp)
            adj = _strategy_seconds(noc, P, d, k, t_l,
                                    overlap=overlap, compute_s=comp)
            best_serial = min(serial, key=serial.get)
            best_adj = min(adj, key=adj.get)
            print(f"moe_dispatch_{preset}_{name},"
                  f"{serial['replicated'] * 1e6:.0f},"
                  f"P={P};replicated={serial['replicated'] * 1e3:.2f}ms;"
                  f"a2a={serial['a2a'] * 1e3:.2f}ms;best={best_serial};"
                  f"ov{overlap:g}:replicated={adj['replicated'] * 1e3:.2f}ms;"
                  f"a2a={adj['a2a'] * 1e3:.2f}ms;best={best_adj}")
            out[preset][name] = {
                "participants": P,
                "expert_gemm_ms": comp * 1e3,
                "serial": {s: t * 1e3 for s, t in serial.items()},
                "overlap_adjusted": {s: t * 1e3 for s, t in adj.items()},
                "overlap": overlap,
                "best_serial": best_serial,
                "best_overlap_adjusted": best_adj,
            }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default=None,
                    help="cost one preset (default: all)")
    ap.add_argument("--overlap", type=float, default=1.0,
                    help="achievable overlap factor for the adjusted "
                         "numbers (default 1.0, the optimistic bound)")
    ap.add_argument("--calibrated", nargs="?", const=True, default=None,
                    metavar="STORE",
                    help="use calibrated NoC constants from STORE "
                         "(default store root when given bare)")
    args = ap.parse_args()
    if not 0.0 <= args.overlap <= 1.0:
        ap.error("--overlap must lie in [0, 1]")
    run_all([args.preset] if args.preset else None,
            overlap=args.overlap, calibrated=args.calibrated)


if __name__ == "__main__":
    main()
