"""Achievable compute–collective overlap fitting from concurrent sweeps.

The cost model's ``overlap`` factor (``core/cost.py``, the Eq. 5–7
extension) charges each window

    hidden = overlap * min(hideable, compute)

where ``hideable`` is the collective's Eq. 1 wire time (its Eq. 3
enqueue/router term stays exposed).  This module inverts that model
against *measured* concurrent runs: each :class:`ConcurrentPoint`
records the serial compute time, the serial collective time, and the
wall time when both are launched together.  The measured hidden time

    hidden_meas = t_compute + t_collective - t_concurrent

divided by the model's hiding capacity ``min(hideable, t_compute)``
yields a per-point achievable-overlap estimate; :func:`fit_overlap`
aggregates per collective type by the median (robust to a straggler
iteration) and clamps to [0, 1].  The result is the ``overlap`` value a
calibrated search should use instead of the optimistic 1.0 — the same
role ``fit_noc_params`` plays for the serial timing constants.

``hideable`` is computed from the *same* ``collective_overlap_terms``
decomposition the cost model charges, so the fit and the predictions
cannot drift apart (mirroring ``fitter.py``'s use of
``collective_cost``).

Degenerate sweeps (no point with positive compute, collective, and
concurrent time, or ``participants <= 1`` everywhere) return
``overlap=0.0`` with ``degenerate=True`` — never invent hiding the
hardware did not demonstrate.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.collectives import (collective_overlap_terms,
                                    collective_seconds)
from repro.core.hardware import NoCParams

from .harness import CALIBRATED_TYPES, log_sizes

__all__ = ["ConcurrentPoint", "OverlapFit", "fit_overlap",
           "measured_hidden_fraction", "predicted_concurrent_seconds",
           "synthetic_concurrent_points"]


@dataclass(frozen=True)
class ConcurrentPoint:
    """One measured concurrent compute+collective run.

    ``compute_seconds`` and ``collective_seconds`` are the *serial*
    times of each half run alone; ``concurrent_seconds`` is the wall
    time with both in flight.  A perfectly overlapping device gives
    ``concurrent = max(compute, collective)``; a fully serializing one
    gives the sum.
    """

    col_type: str
    data_volume_bytes: int
    participants: int
    compute_seconds: float
    collective_seconds: float
    concurrent_seconds: float

    def to_json(self) -> Dict:
        return {"col_type": self.col_type,
                "data_volume_bytes": int(self.data_volume_bytes),
                "participants": int(self.participants),
                "compute_seconds": self.compute_seconds,
                "collective_seconds": self.collective_seconds,
                "concurrent_seconds": self.concurrent_seconds}

    @classmethod
    def from_json(cls, d: Dict) -> "ConcurrentPoint":
        return cls(col_type=d["col_type"],
                   data_volume_bytes=int(d["data_volume_bytes"]),
                   participants=int(d["participants"]),
                   compute_seconds=float(d["compute_seconds"]),
                   collective_seconds=float(d["collective_seconds"]),
                   concurrent_seconds=float(d["concurrent_seconds"]))


@dataclass(frozen=True)
class OverlapFit:
    """Fitted achievable overlap, overall and per collective type."""

    overlap: float                       # pooled median, in [0, 1]
    per_type: Dict[str, float]           # col_type -> achievable overlap
    n_points: int
    max_abs_err: float                   # |pred - meas|/meas on t_conc
    median_abs_err: float
    points: Tuple[ConcurrentPoint, ...]
    degenerate: bool = False

    def overlap_for(self, col_type: str) -> float:
        return self.per_type.get(col_type, self.overlap)

    def to_json(self) -> Dict:
        return {"overlap": self.overlap,
                "per_type": dict(self.per_type),
                "n_points": self.n_points,
                "max_abs_err": self.max_abs_err,
                "median_abs_err": self.median_abs_err,
                "degenerate": self.degenerate}


def _usable(p: ConcurrentPoint) -> bool:
    vals = (p.compute_seconds, p.collective_seconds, p.concurrent_seconds)
    return (p.participants > 1 and all(np.isfinite(v) and v > 0.0
                                       for v in vals))


def measured_hidden_fraction(p: ConcurrentPoint, noc: NoCParams) -> float:
    """Per-point achievable-overlap estimate: measured hidden time over
    the model's hiding capacity ``min(hideable, compute)``, clamped to
    [0, 1]."""
    hideable, _exposed = collective_overlap_terms(
        p.col_type, float(p.data_volume_bytes), p.participants, noc)
    cap = min(hideable, p.compute_seconds)
    if cap <= 0.0:
        return 0.0
    hidden = p.compute_seconds + p.collective_seconds - p.concurrent_seconds
    return float(np.clip(hidden / cap, 0.0, 1.0))


def predicted_concurrent_seconds(p: ConcurrentPoint, noc: NoCParams,
                                 overlap: float) -> float:
    """Model prediction for the concurrent wall time: serial sum minus
    the hidden share — the same charging ``core/cost.py`` applies inside
    a window, using the *measured* serial halves as the window terms."""
    hideable, _exposed = collective_overlap_terms(
        p.col_type, float(p.data_volume_bytes), p.participants, noc)
    hidden = overlap * min(hideable, p.compute_seconds)
    return p.compute_seconds + p.collective_seconds - hidden


def fit_overlap(points: Sequence[ConcurrentPoint],
                noc: NoCParams) -> OverlapFit:
    """Fit the achievable ``overlap`` factor to a concurrent sweep.

    ``noc`` must be the (calibrated) NoC the serial collective model was
    validated against — the hideable/exposed split is taken from it.
    """
    pts = tuple(p for p in points if _usable(p))
    if not pts:
        return OverlapFit(overlap=0.0, per_type={}, n_points=0,
                          max_abs_err=0.0, median_abs_err=0.0,
                          points=tuple(points), degenerate=True)

    fracs = np.array([measured_hidden_fraction(p, noc) for p in pts])
    per_type: Dict[str, float] = {}
    for col_type in sorted({p.col_type for p in pts}):
        sel = np.array([p.col_type == col_type for p in pts])
        per_type[col_type] = float(np.median(fracs[sel]))
    overall = float(np.median(fracs))

    errs = np.array([
        abs(predicted_concurrent_seconds(p, noc, per_type[p.col_type])
            - p.concurrent_seconds) / p.concurrent_seconds
        for p in pts])
    return OverlapFit(overlap=overall, per_type=per_type,
                      n_points=len(pts), max_abs_err=float(errs.max()),
                      median_abs_err=float(np.median(errs)), points=pts)


def synthetic_concurrent_points(
        noc: NoCParams, true_overlap: float, *,
        participants: int = 8,
        n_sizes: int = 6,
        compute_ratios: Sequence[float] = (0.5, 1.0, 2.0),
        col_types: Sequence[str] = CALIBRATED_TYPES,
        jitter: float = 0.0,
        seed: int = 0) -> Tuple[ConcurrentPoint, ...]:
    """Generate a concurrent sweep from known ground truth — the overlap
    analogue of ``synthetic_measure_fn``: serial halves follow Eq. 4
    under ``noc``, the concurrent time hides exactly ``true_overlap`` of
    the capacity, and ``jitter`` multiplies every timing by a seeded
    lognormal factor.  ``fit_overlap`` on the clean output must recover
    ``true_overlap`` (the recovery gate in ``tests/test_calibrate.py``).

    ``compute_ratios`` sets compute time as multiples of each point's
    serial collective time, spanning collective-bound (<1) and
    compute-bound (>1) windows so the min() in the capacity is exercised
    from both sides.
    """
    rng = np.random.default_rng(seed)
    pts = []
    for col_type in col_types:
        for dv in log_sizes(1 << 12, 1 << 24, n_sizes):
            t_col = collective_seconds(col_type, float(dv), participants,
                                       noc)
            hideable, _ = collective_overlap_terms(col_type, float(dv),
                                                   participants, noc)
            for ratio in compute_ratios:
                t_comp = ratio * t_col
                hidden = true_overlap * min(hideable, t_comp)
                t_conc = t_comp + t_col - hidden
                if jitter > 0.0:
                    t_comp *= float(rng.lognormal(0.0, jitter))
                    t_col *= float(rng.lognormal(0.0, jitter))
                    t_conc *= float(rng.lognormal(0.0, jitter))
                pts.append(ConcurrentPoint(
                    col_type=col_type, data_volume_bytes=int(dv),
                    participants=participants, compute_seconds=t_comp,
                    collective_seconds=t_col, concurrent_seconds=t_conc))
    return tuple(pts)
