"""phi4-mini-3.8b [dense]: RoPE + SwiGLU + GQA.  [arXiv:2412.08905]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b", family="dense",
        n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=8192, vocab_size=200064, rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, name="phi4-smoke")
