"""Unified decoder-LM engine: every assigned non-enc-dec architecture
(dense GQA/MQA, MLA+MoE, softmax-MoE, Mamba-2 SSD, Hymba hybrid) is a
configuration of this module.  Layers are scanned (stacked params) so the
HLO is O(1) in depth; a separate small scan handles DeepSeek's leading
dense layers.

Paths: ``forward`` (training, full-seq causal), ``prefill`` (builds the
cache), ``decode`` (one token, fixed shapes).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ModelConfig
from .layers import (apply_norm, embed_apply, embed_specs, mlp_apply, mlp_specs, norm_specs, unembed_apply)
from .param import ParamSpec

__all__ = ["decoder_specs", "forward", "prefill", "decode", "init_cache",
           "dp_axes", "constrain"]


def dp_axes(mesh: Optional[Mesh]) -> Tuple[str, ...]:
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def constrain(x: jax.Array, mesh: Optional[Mesh], spec: P) -> jax.Array:
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _residual_spec(cfg: ModelConfig, mesh: Optional[Mesh]) -> P:
    """Residual-stream sharding: batch over dp; optionally the sequence dim
    over 'model' (sequence parallelism — converts per-layer TP all-reduces
    into reduce-scatter/all-gather pairs, halving collective bytes)."""
    dp = dp_axes(mesh)
    seq = "model" if (cfg.seq_shard and mesh is not None
                      and "model" in mesh.axis_names) else None
    return P(dp if dp else None, seq, None)


def _remat(cfg: ModelConfig, body):
    if not cfg.remat or cfg.remat_policy == "none":
        return body
    pol = (jax.checkpoint_policies.nothing_saveable
           if cfg.remat_policy == "full"
           else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(body, policy=pol)


def _unroll(cfg: ModelConfig, length: int) -> int:
    return max(1, min(cfg.scan_unroll, length))


# ------------------------------------------------------------------ specs


def _mixer_specs(cfg: ModelConfig, L: int) -> Dict[str, Any]:
    s: Dict[str, Any] = {}
    if cfg.has_attention:
        if cfg.attn_type == "mla":
            s["attn"] = attn.mla_specs(cfg, L)
        else:
            s["attn"] = attn.gqa_specs(cfg, L)
    if cfg.has_ssm:
        s["ssm"] = ssm_mod.ssm_specs(cfg, L)
    if cfg.family == "hybrid":
        s["alpha_attn"] = ParamSpec((L, cfg.d_model), ("layer", "embed"),
                                    init="ones", dtype=cfg.dtype)
        s["alpha_ssm"] = ParamSpec((L, cfg.d_model), ("layer", "embed"),
                                   init="ones", dtype=cfg.dtype)
    return s


def _layer_specs(cfg: ModelConfig, L: int, use_moe: bool) -> Dict[str, Any]:
    s: Dict[str, Any] = {"norm1": norm_specs(cfg, L)}
    s.update(_mixer_specs(cfg, L))
    if cfg.is_moe and use_moe:
        s["norm2"] = norm_specs(cfg, L)
        s["moe"] = moe_mod.moe_specs(cfg, L)
    elif cfg.d_ff > 0:
        s["norm2"] = norm_specs(cfg, L)
        s["mlp"] = mlp_specs(cfg, L)
    return s


def decoder_specs(cfg: ModelConfig) -> Dict[str, Any]:
    n_moe = cfg.n_layers - cfg.first_dense_layers
    s: Dict[str, Any] = dict(embed_specs(cfg))
    if cfg.first_dense_layers > 0:
        dense_cfg = cfg.with_(n_experts=0)
        s["dense_layers"] = _layer_specs(dense_cfg, cfg.first_dense_layers,
                                         use_moe=False)
        s["layers"] = _layer_specs(cfg, n_moe, use_moe=True)
    else:
        s["layers"] = _layer_specs(cfg, cfg.n_layers, use_moe=cfg.is_moe)
    s["final_norm"] = norm_specs(cfg)
    return s


# ------------------------------------------------------------------ layer


def _layer_train(cfg: ModelConfig, mesh: Optional[Mesh], use_moe: bool,
                 x: jax.Array, pl: Dict) -> jax.Array:
    dp = dp_axes(mesh)
    h = apply_norm(cfg, pl["norm1"], x)
    if cfg.family == "hybrid":
        a = attn.attn_train(cfg, pl["attn"], h)
        s = ssm_mod.ssm_train(cfg, pl["ssm"], h)
        mix = 0.5 * (a * pl["alpha_attn"] + s * pl["alpha_ssm"])
    elif cfg.has_ssm:
        mix = ssm_mod.ssm_train(cfg, pl["ssm"], h)
    else:
        mix = attn.attn_train(cfg, pl["attn"], h)
    x = x + mix
    x = constrain(x, mesh, _residual_spec(cfg, mesh))
    if use_moe and cfg.is_moe:
        x = x + moe_mod.moe_apply(cfg, pl["moe"], apply_norm(cfg, pl["norm2"], x),
                                  mesh=mesh)
    elif cfg.d_ff > 0:
        x = x + mlp_apply(cfg, pl["mlp"], apply_norm(cfg, pl["norm2"], x))
    return constrain(x, mesh, _residual_spec(cfg, mesh))


def _scan_stack(cfg: ModelConfig, mesh, use_moe, x, stacked):
    fn = functools.partial(_layer_train, cfg, mesh, use_moe)

    def body(carry, pl):
        return fn(carry, pl), None

    body = _remat(cfg, body)
    L = jax.tree.leaves(stacked)[0].shape[0]
    x, _ = jax.lax.scan(body, x, stacked, unroll=_unroll(cfg, L))
    return x


# ---------------------------------------------------------------- forward


def forward(cfg: ModelConfig, params: Dict, tokens: jax.Array,
            mesh: Optional[Mesh] = None) -> jax.Array:
    """Training forward: tokens (B, S) -> logits (B, S, Vp)."""
    dp = dp_axes(mesh)
    x = embed_apply(params, tokens).astype(jnp.dtype(cfg.dtype))
    x = constrain(x, mesh, P(dp if dp else None, None, None))
    if cfg.first_dense_layers > 0:
        dense_cfg = cfg.with_(n_experts=0)
        x = _scan_stack(dense_cfg, mesh, False, x, params["dense_layers"])
    x = _scan_stack(cfg, mesh, cfg.is_moe, x, params["layers"])
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed_apply(cfg, params, x)
    return constrain(logits, mesh, P(dp if dp else None, None, "model"))


# ------------------------------------------------------------------ cache


def _layer_cache(cfg: ModelConfig, B: int, cache_len: int, dtype) -> Dict:
    c: Dict[str, Any] = {}
    if cfg.has_attention:
        c["attn"] = attn.init_attn_cache(cfg, B, cache_len, dtype)
    if cfg.has_ssm:
        c["ssm"] = ssm_mod.init_ssm_cache(cfg, B, dtype)
    return c


def _stack_cache(cache: Dict, L: int) -> Dict:
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (L,) + a.shape).copy()
                        if L else a, cache)


def init_cache(cfg: ModelConfig, B: int, cache_len: int) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    # per-row positions from the start (see decode): the cache keeps one
    # shape whether or not a serving engine ever staggers its slots
    out: Dict[str, Any] = {"pos": jnp.zeros((B,), jnp.int32)}
    n_moe = cfg.n_layers - cfg.first_dense_layers
    if cfg.first_dense_layers > 0:
        out["dense"] = _stack_cache(
            _layer_cache(cfg.with_(n_experts=0), B, cache_len, dt),
            cfg.first_dense_layers)
        out["layers"] = _stack_cache(_layer_cache(cfg, B, cache_len, dt), n_moe)
    else:
        out["layers"] = _stack_cache(_layer_cache(cfg, B, cache_len, dt),
                                     cfg.n_layers)
    return out


# ---------------------------------------------------------------- prefill


def _layer_prefill(cfg, mesh, use_moe, x, pl):
    dp = dp_axes(mesh)
    h = apply_norm(cfg, pl["norm1"], x)
    new_c: Dict[str, Any] = {}
    if cfg.family == "hybrid":
        a, ca = attn.attn_prefill(cfg, pl["attn"], h)
        s, cs = ssm_mod.ssm_prefill(cfg, pl["ssm"], h)
        mix = 0.5 * (a * pl["alpha_attn"] + s * pl["alpha_ssm"])
        new_c = {"attn": ca, "ssm": cs}
    elif cfg.has_ssm:
        mix, cs = ssm_mod.ssm_prefill(cfg, pl["ssm"], h)
        new_c = {"ssm": cs}
    else:
        mix, ca = attn.attn_prefill(cfg, pl["attn"], h)
        new_c = {"attn": ca}
    x = x + mix
    x = constrain(x, mesh, _residual_spec(cfg, mesh))
    if use_moe and cfg.is_moe:
        x = x + moe_mod.moe_apply(cfg, pl["moe"], apply_norm(cfg, pl["norm2"], x),
                                  mesh=mesh)
    elif cfg.d_ff > 0:
        x = x + mlp_apply(cfg, pl["mlp"], apply_norm(cfg, pl["norm2"], x))
    return constrain(x, mesh, _residual_spec(cfg, mesh)), new_c


def _scan_prefill(cfg, mesh, use_moe, x, stacked):
    fn = functools.partial(_layer_prefill, cfg, mesh, use_moe)

    def body(carry, pl):
        x2, c = fn(carry, pl)
        return x2, c

    body = _remat(cfg, body)
    L = jax.tree.leaves(stacked)[0].shape[0]
    return jax.lax.scan(body, x, stacked, unroll=_unroll(cfg, L))


def prefill(cfg: ModelConfig, params: Dict, tokens: jax.Array,
            cache_len: int, mesh: Optional[Mesh] = None
            ) -> Tuple[jax.Array, Dict]:
    """Process the prompt; returns (logits for last position, cache).

    The cache is padded/relaid to ``cache_len`` slots.
    """
    B, S = tokens.shape
    dp = dp_axes(mesh)
    x = embed_apply(params, tokens).astype(jnp.dtype(cfg.dtype))
    x = constrain(x, mesh, P(dp if dp else None, None, None))
    cache: Dict[str, Any] = {"pos": jnp.full((B,), S, jnp.int32)}
    if cfg.first_dense_layers > 0:
        x, cd = _scan_prefill(cfg.with_(n_experts=0), mesh, False, x,
                              params["dense_layers"])
        cache["dense"] = _pad_cache(cfg.with_(n_experts=0), cd, S, cache_len)
        x, cl = _scan_prefill(cfg, mesh, True, x, params["layers"])
        cache["layers"] = _pad_cache(cfg, cl, S, cache_len)
    else:
        x, cl = _scan_prefill(cfg, mesh, cfg.is_moe, x, params["layers"])
        cache["layers"] = _pad_cache(cfg, cl, S, cache_len)
    x = apply_norm(cfg, params["final_norm"], x[:, -1:, :])
    logits = unembed_apply(cfg, params, x)
    return logits, cache


def _pad_cache(cfg: ModelConfig, c: Dict, S: int, cache_len: int) -> Dict:
    """Grow prefill caches (seq dim S or ring W) to the serving cache_len."""
    def grow(path_a):
        def g(a):
            return a
        return g

    def pad_leaf(a, target_len, axis):
        pad = target_len - a.shape[axis]
        if pad <= 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, pad)
        if a.dtype == jnp.int32:
            return jnp.pad(a, widths, constant_values=-1)
        return jnp.pad(a, widths)

    out = dict(c)
    if "attn" in c:
        ac = dict(c["attn"])
        if cfg.attn_type == "mla":
            ac["ckv"] = pad_leaf(ac["ckv"], cache_len, 2)
            ac["kr"] = pad_leaf(ac["kr"], cache_len, 2)
        else:
            W = min(cfg.window, cache_len) if cfg.window else cache_len
            ac["k"] = pad_leaf(ac["k"], W, 2)
            ac["v"] = pad_leaf(ac["v"], W, 2)
            ac["kpos"] = pad_leaf(ac["kpos"], W, 2)    # (L, B, W) per-row
        out["attn"] = ac
    return out


# ----------------------------------------------------------------- decode


def _layer_decode(cfg, mesh, use_moe, x, pl, cl, pos):
    h = apply_norm(cfg, pl["norm1"], x)
    new_c: Dict[str, Any] = {}
    if cfg.family == "hybrid":
        a, ca = attn.attn_decode(cfg, pl["attn"], h, cl["attn"], pos)
        s, cs = ssm_mod.ssm_decode(cfg, pl["ssm"], h, cl["ssm"])
        mix = 0.5 * (a * pl["alpha_attn"] + s * pl["alpha_ssm"])
        new_c = {"attn": ca, "ssm": cs}
    elif cfg.has_ssm:
        mix, cs = ssm_mod.ssm_decode(cfg, pl["ssm"], h, cl["ssm"])
        new_c = {"ssm": cs}
    else:
        mix, ca = attn.attn_decode(cfg, pl["attn"], h, cl["attn"], pos)
        new_c = {"attn": ca}
    x = x + mix
    if use_moe and cfg.is_moe:
        x = x + moe_mod.moe_apply(cfg, pl["moe"], apply_norm(cfg, pl["norm2"], x),
                                  mesh=mesh)
    elif cfg.d_ff > 0:
        x = x + mlp_apply(cfg, pl["mlp"], apply_norm(cfg, pl["norm2"], x))
    return x, new_c


def decode(cfg: ModelConfig, params: Dict, cache: Dict, tokens: jax.Array,
           mesh: Optional[Mesh] = None) -> Tuple[jax.Array, Dict]:
    """One decode step.  tokens: (B, 1) -> (logits (B, 1, Vp), new cache).

    ``cache['pos']`` may be a scalar (every row at the same depth — the
    plain prefill-then-decode flow) or a per-row (B,) vector (continuous
    batching: a serving engine re-prefilled some slots mid-decode).  It
    is normalized to (B,) here so attention layers always see per-row
    positions."""
    dp = dp_axes(mesh)
    pos = jnp.broadcast_to(jnp.asarray(cache["pos"], jnp.int32),
                           (tokens.shape[0],))
    x = embed_apply(params, tokens).astype(jnp.dtype(cfg.dtype))
    x = constrain(x, mesh, P(dp if dp else None, None, None))
    new_cache: Dict[str, Any] = {"pos": pos + 1}

    def make_body(c, use_moe):
        def body(carry, xs):
            pl, cl = xs
            x2, nc = _layer_decode(c, mesh, use_moe, carry, pl, cl, pos)
            return x2, nc
        return body

    if cfg.first_dense_layers > 0:
        dense_cfg = cfg.with_(n_experts=0)
        x, nd = jax.lax.scan(make_body(dense_cfg, False), x,
                             (params["dense_layers"], cache["dense"]),
                             unroll=_unroll(cfg, cfg.first_dense_layers))
        new_cache["dense"] = nd
        x, nl = jax.lax.scan(make_body(cfg, True), x,
                             (params["layers"], cache["layers"]),
                             unroll=_unroll(cfg, cfg.n_layers
                                            - cfg.first_dense_layers))
        new_cache["layers"] = nl
    else:
        x, nl = jax.lax.scan(make_body(cfg, cfg.is_moe), x,
                             (params["layers"], cache["layers"]),
                             unroll=_unroll(cfg, cfg.n_layers))
        new_cache["layers"] = nl
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed_apply(cfg, params, x)
    logits = constrain(logits, mesh, P(dp if dp else None, None, "model"))
    return logits, new_cache
