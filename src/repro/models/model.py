"""Model facade: one object tying config -> specs -> init/abstract params ->
train/prefill/decode callables, uniform across all families."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
from jax.sharding import Mesh

from . import encdec, transformer
from .config import ModelConfig
from .layers import cross_entropy_loss
from .param import abstract_tree, axes_tree, count_params, init_tree

__all__ = ["Model"]


@dataclass
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------- params
    @property
    def specs(self):
        if self.cfg.is_encdec:
            return encdec.encdec_specs(self.cfg)
        return transformer.decoder_specs(self.cfg)

    def init(self, key: jax.Array):
        return init_tree(self.specs, key)

    def abstract_params(self):
        return abstract_tree(self.specs)

    def param_axes(self):
        return axes_tree(self.specs)

    def n_params(self) -> int:
        return count_params(self.specs)

    # ------------------------------------------------------------ forward
    def logits(self, params, batch: Dict[str, jax.Array],
               mesh: Optional[Mesh] = None) -> jax.Array:
        if self.cfg.is_encdec:
            return encdec.encdec_forward(self.cfg, params, batch["src_embeds"],
                                         batch["tokens"], mesh)
        return transformer.forward(self.cfg, params, batch["tokens"], mesh)

    def loss(self, params, batch: Dict[str, jax.Array],
             mesh: Optional[Mesh] = None) -> jax.Array:
        logits = self.logits(params, batch, mesh)
        return cross_entropy_loss(logits, batch["labels"], self.cfg.vocab_size)

    # ------------------------------------------------------------ serving
    def init_cache(self, batch: int, cache_len: int):
        if self.cfg.is_encdec:
            return encdec.encdec_init_cache(self.cfg, batch, cache_len)
        return transformer.init_cache(self.cfg, batch, cache_len)

    def prefill(self, params, batch: Dict[str, jax.Array], cache_len: int,
                mesh: Optional[Mesh] = None):
        if self.cfg.is_encdec:
            return encdec.encdec_prefill(self.cfg, params, batch["src_embeds"],
                                         batch["tokens"], cache_len, mesh)
        return transformer.prefill(self.cfg, params, batch["tokens"],
                                   cache_len, mesh)

    def decode(self, params, cache, tokens: jax.Array,
               mesh: Optional[Mesh] = None):
        if self.cfg.is_encdec:
            return encdec.encdec_decode(self.cfg, params, cache, tokens, mesh)
        return transformer.decode(self.cfg, params, cache, tokens, mesh)
