# Benchmark harness — one section per paper table/figure plus the roofline
# from the dry-run artifacts.  Prints ``name,us_per_call,derived`` CSV.
import sys


def main() -> None:
    from benchmarks import (costmodel_compare, kernel_bench, moe_dispatch,
                            paper_tables, roofline)

    print("# ================ paper tables (Figs 7-12) ================")
    paper_tables.run_all()
    print("# ================ cost-model compare (Fig 6) ===============")
    costmodel_compare.run_all()
    print("# ================ Pallas kernels ===========================")
    kernel_bench.run_all()
    print("# ================ MoE dispatch (COMET AllToAll model) ======")
    moe_dispatch.run_all()
    print("# ================ roofline (dry-run artifacts) =============")
    roofline.run_all()


if __name__ == '__main__':
    main()
