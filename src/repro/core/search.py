"""Map-space search (COMET §V-A).

Iterative randomized search over the 4-D design space of Fig. 1 —
tiling factors × loop order/spatial unrolling × collective strategy ×
scheduling — with constraint pruning (memory-fit validation) and a small
mutation-based hill-climb.  The paper uses up to 10,000 iterations; so do
we (``budget``).  Deterministic under ``seed``.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .hardware import Arch
from .ir import MappingResult, MappingSpec, evaluate_mapping
from .workload import CompoundOp

__all__ = ["SearchResult", "search", "candidate_specs", "pow2_tilings"]


@dataclass
class SearchResult:
    best: MappingResult
    evaluated: int
    valid: int
    history: List[Tuple[int, float]] = field(default_factory=list)  # (iter, best latency)

    @property
    def latency(self) -> float:
        return self.best.latency

    @property
    def energy_pj(self) -> float:
        return self.best.energy_pj


def pow2_tilings(size: int, cap: int = 4096) -> List[int]:
    """Candidate temporal tile counts for a dimension: powers of two up to
    min(size, cap), always including 1 and the full size when small."""
    out = [1]
    t = 2
    while t <= min(size, cap):
        out.append(t)
        t *= 2
    if size <= cap and size not in out:
        out.append(size)
    return out


def candidate_specs(co: CompoundOp, arch: Arch, *,
                    variants: Optional[Sequence[str]] = None,
                    allow_stats_gran: bool = False) -> Dict[str, List]:
    """The discrete choice sets for each MappingSpec field."""
    M = co.dim_sizes.get("M", 1)
    K = co.dim_sizes.get("K", 1)
    N = co.dim_sizes.get("N", 1)
    if variants is None:
        if co.name in ("attention", "flash_attention"):
            variants = ["ua", "pfa", "fa"]
        elif co.name in ("gemm_softmax", "gemm_layernorm"):
            variants = ["unfused", "fused_epilogue", "fused_std", "fused_dist"]
        else:
            variants = ["unfused", "fused_dist"]
    grans = ["tile", "stats"] if allow_stats_gran else ["tile"]
    return {
        "variant": list(variants),
        "m_tiles": pow2_tilings(M),
        "k_tiles": pow2_tilings(K, cap=64),
        "n_tiles": pow2_tilings(N, cap=256),
        "schedule": ["sequential", "pipelined"],
        "collective_gran": grans,
        "loop_order_gb": [("M", "N"), ("N", "M")],
    }


def _sample(rng: random.Random, cands: Dict[str, List]) -> MappingSpec:
    return MappingSpec(
        variant=rng.choice(cands["variant"]),
        m_tiles=rng.choice(cands["m_tiles"]),
        k_tiles=rng.choice(cands["k_tiles"]),
        n_tiles=rng.choice(cands["n_tiles"]),
        schedule=rng.choice(cands["schedule"]),
        collective_gran=rng.choice(cands["collective_gran"]),
        loop_order_gb=rng.choice(cands["loop_order_gb"]),
    )


def _mutate(rng: random.Random, spec: MappingSpec, cands: Dict[str, List]) -> MappingSpec:
    fieldname = rng.choice(list(cands.keys()))
    return replace(spec, **{fieldname: rng.choice(cands[fieldname])})


def search(co: CompoundOp, arch: Arch, *,
           budget: int = 2000,
           seed: int = 0,
           objective: str = "latency",
           variants: Optional[Sequence[str]] = None,
           allow_stats_gran: bool = False,
           hillclimb_frac: float = 0.5) -> SearchResult:
    """Randomized search + hill-climb.  ``objective`` is 'latency',
    'energy' or 'edp' (energy-delay product)."""
    rng = random.Random(seed)
    cands = candidate_specs(co, arch, variants=variants,
                            allow_stats_gran=allow_stats_gran)

    def score(r: MappingResult) -> float:
        if not r.valid:
            return math.inf
        if objective == "latency":
            return r.latency
        if objective == "energy":
            return r.energy_pj
        return r.latency * r.energy_pj

    best: Optional[MappingResult] = None
    best_score = math.inf
    evaluated = valid = 0
    history: List[Tuple[int, float]] = []
    seen = set()

    explore = max(1, int(budget * (1.0 - hillclimb_frac)))
    for i in range(budget):
        if best is None or i < explore:
            spec = _sample(rng, cands)
        else:
            spec = _mutate(rng, best.spec, cands)
        key = (spec.variant, spec.m_tiles, spec.k_tiles, spec.n_tiles,
               spec.schedule, spec.collective_gran, spec.loop_order_gb)
        if key in seen:
            continue
        seen.add(key)
        try:
            r = evaluate_mapping(co, arch, spec)
        except (ValueError, KeyError):
            continue
        evaluated += 1
        if r.valid:
            valid += 1
        s = score(r)
        if s < best_score:
            best, best_score = r, s
            history.append((i, r.latency))

    if best is None:
        raise RuntimeError(f"no valid mapping found for {co.name} on {arch.name}")
    return SearchResult(best=best, evaluated=evaluated, valid=valid,
                        history=history)
