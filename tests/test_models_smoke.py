"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs; plus prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, cells_for, get_config, get_smoke_config
from repro.models import Model

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    b = {"tokens": jnp.asarray(np.arange(B * S).reshape(B, S) % cfg.vocab_size,
                               jnp.int32),
         "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.is_encdec:
        b["src_embeds"] = jnp.ones((B, max(1, S // cfg.enc_ratio),
                                    cfg.d_model), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    logits = model.logits(params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # one real train step
    from repro.train.optimizer import OptConfig, init_opt_state
    from repro.train.train_step import TrainState, make_train_step
    step = make_train_step(model, OptConfig(lr=1e-3, total_steps=10))
    state = TrainState(params, init_opt_state(params))
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_matches_forward(arch):
    """Greedy decode after prefill must equal the teacher-forced forward
    logits at the same positions (causal consistency)."""
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(KEY)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    full = model.logits(params, batch).astype(jnp.float32)

    pre_batch = {k: (v[:, :S - 2] if k != "src_embeds" else v)
                 for k, v in batch.items() if k != "labels"}
    logits_p, cache = model.prefill(params, pre_batch, cache_len=S + 4)
    np.testing.assert_allclose(np.asarray(logits_p[:, -1].astype(jnp.float32)),
                               np.asarray(full[:, S - 3]), atol=6e-2,
                               rtol=6e-2)
    # decode the next token with the true continuation
    lg, cache = model.decode(params, cache, batch["tokens"][:, S - 2:S - 1])
    np.testing.assert_allclose(np.asarray(lg[:, 0].astype(jnp.float32)),
                               np.asarray(full[:, S - 2]), atol=6e-2,
                               rtol=6e-2)


def test_full_configs_match_assignment():
    """The full configs carry the exact dims from the assignment table."""
    spec = {
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    }
    for arch, (L, d, H, kv, ff, V) in spec.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
                c.d_ff, c.vocab_size) == (L, d, H, kv, ff, V), arch
    ds = get_config("deepseek-v3-671b")
    assert (ds.n_layers, ds.d_model, ds.n_heads, ds.n_experts, ds.top_k,
            ds.moe_d_ff, ds.vocab_size) == (61, 7168, 128, 256, 8, 2048, 129280)
    qw = get_config("qwen3-moe-30b-a3b")
    assert (qw.n_layers, qw.d_model, qw.n_experts, qw.top_k, qw.moe_d_ff,
            qw.vocab_size) == (48, 2048, 128, 8, 768, 151936)
    mb = get_config("mamba2-130m")
    assert (mb.n_layers, mb.d_model, mb.ssm_state, mb.vocab_size) == \
        (24, 768, 128, 50280)


def test_cells_follow_brief():
    """long_500k only for sub-quadratic archs; all archs have 3 base cells."""
    for a in ARCH_IDS:
        cells = cells_for(a)
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(cells)
        if a in ("mamba2-130m", "hymba-1.5b"):
            assert "long_500k" in cells
        else:
            assert "long_500k" not in cells
    total = sum(len(cells_for(a)) for a in ARCH_IDS)
    assert total == 32   # 40-cell table minus 8 noted long_500k skips


def test_moe_routing_conservation():
    """Top-k gates are normalized and dispatch preserves token mass for
    tokens under capacity."""
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    model = Model(cfg)
    params = model.init(KEY)
    from repro.models.moe import moe_apply, router_weights
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model),
                          jnp.bfloat16)
    layer0 = jax.tree.map(lambda a: a[0], params["layers"]["moe"])
    gates, idx = router_weights(cfg, layer0, x.reshape(8, cfg.d_model))
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-3)
    assert int(idx.max()) < cfg.n_experts
    out = moe_apply(cfg, layer0, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ["glm4-9b", "hymba-1.5b", "mamba2-130m"])
def test_pallas_kernel_path_in_model(arch):
    """use_kernels=True routes attention/SSD through the Pallas kernels
    (interpret mode on CPU) and must match the reference path closely."""
    cfg = get_smoke_config(arch).with_(dtype="float32", window=None)
    model_ref = Model(cfg)
    model_k = Model(cfg.with_(use_kernels=True))
    params = model_ref.init(KEY)
    B, S = 1, 128   # S >= kernel block size
    toks = jnp.asarray(np.arange(B * S).reshape(B, S) % cfg.vocab_size,
                       jnp.int32)
    ref = model_ref.logits(params, {"tokens": toks}).astype(jnp.float32)
    out = model_k.logits(params, {"tokens": toks}).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-3, rtol=5e-3)
