"""Compat shim: the jaxpr walker moved to :mod:`repro.analysis.jaxpr`
(it now counts collectives for the static contract checker as well as
FLOPs).  Import from ``repro.analysis`` in new code."""
from repro.analysis.jaxpr import (CollectiveRecord, TraceCounts,  # noqa: F401
                                  count_flops, count_jaxpr,
                                  structural_flops, trace_counts)

__all__ = ["count_flops", "structural_flops", "count_jaxpr",
           "trace_counts", "TraceCounts", "CollectiveRecord"]
