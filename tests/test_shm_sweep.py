"""Tests for the shared-memory process-pool sweep engine: BatchResult
(de)serialization through ``multiprocessing.shared_memory`` segments,
executor parity (serial/thread/process must be bit-identical), segment
lifecycle (no leaks after clean sweeps or worker crashes) and the warning
fallbacks."""
import os
import pickle
import secrets

import numpy as np
import pytest

from repro.core import batcheval
from repro.core import search as search_mod
from repro.core.batcheval import (Topology, batch_from_shm, batch_to_shm,
                                  enumerate_topologies,
                                  evaluate_specs_batch,
                                  evaluate_topology_grid, shm_unlink)
from repro.core.hardware import cloud, edge
from repro.core.ir import MappingSpec
from repro.core.search import (candidate_specs, cleanup_shm_segments,
                               parallel_map, search_many)
from repro.core.workload import attention, gemm_softmax

shm_required = pytest.mark.skipif(not search_mod._shm_usable(),
                                  reason="no working shared memory")

SHM_DIR = "/dev/shm"


def _segments():
    return set(os.listdir(SHM_DIR)) if os.path.isdir(SHM_DIR) else set()


def _small_jobs():
    """A mixed sweep over small spaces: scalar + front objectives plus a
    forced-randomized job (exercises the pickle wire next to the shm
    wire)."""
    jobs = [(gemm_softmax(256, 1024, 64), edge(), {"variants": [v]})
            for v in ("unfused", "fused_epilogue", "fused_std", "fused_dist")]
    jobs += [
        (gemm_softmax(256, 1024, 64), cloud(), {"objective": "pareto"}),
        (attention(256, 128, 256, 128), edge(), {"objective": "pareto3"}),
        (gemm_softmax(256, 1024, 64), edge(),
         {"mode": "randomized", "budget": 50, "seed": 3}),
        (gemm_softmax(384, 768, 96), edge(), {"divisor_tilings": True}),
    ]
    return jobs


# ------------------------------------------------------ shm serialization

@shm_required
def test_batch_shm_roundtrip_all_fields():
    """Every BatchResult channel — axes, str-dtype schedule, results,
    headroom + per-level headroom, breakdowns — survives the segment
    roundtrip bit-exactly, and unlinking is idempotent."""
    co, arch = gemm_softmax(512, 1024, 128), edge()
    br = evaluate_specs_batch(
        co, arch, Topology(variant="fused_dist"),
        [8, 4, 8], [2, 2, 1], [1, 1, 1],
        sp_cluster=[4, 2, 1], sp_core=[4, 1, 2],
        schedule=["sequential", "pipelined", "pipelined"],
        track_breakdown=True)
    ref = batch_to_shm(br, prefix="comettest")
    assert ref.shm_name.startswith("comettest")
    br2, shm = batch_from_shm(ref)
    try:
        assert br2.topo == br.topo
        for f in ("m_tiles", "k_tiles", "n_tiles", "sp_cluster", "sp_core",
                  "latency", "energy_pj", "valid", "headroom"):
            got, want = getattr(br2, f), getattr(br, f)
            assert got.dtype == want.dtype and np.array_equal(got, want), f
        assert np.array_equal(br2.schedule, br.schedule)
        assert sorted(br2.headroom_levels) == sorted(br.headroom_levels)
        for lvl, a in br.headroom_levels.items():
            assert np.array_equal(br2.headroom_levels[lvl], a)
        for d2, d in ((br2.lat_breakdown, br.lat_breakdown),
                      (br2.energy_breakdown, br.energy_breakdown)):
            assert sorted(d2) == sorted(d)
            for k in d:
                assert np.array_equal(np.asarray(d2[k]), np.asarray(d[k])), k
    finally:
        del br2
        shm.close()
        shm.unlink()
    assert not shm_unlink(ref.shm_name)       # already gone; no raise


@shm_required
def test_shm_ref_is_small_and_picklable():
    """The wire object is the ref, not the arrays: pickling it must cost
    bytes, not megabytes, while the segment holds the actual grid."""
    co, arch = gemm_softmax(512, 1024, 128), edge()
    cands = candidate_specs(co, arch)
    topo = enumerate_topologies(co, cands)[0]
    br = evaluate_topology_grid(co, arch, topo, cands)
    ref = batch_to_shm(br, prefix="comettest")
    try:
        wire = pickle.dumps(ref)
        array_bytes = sum(a.nbytes for a in
                          (br.m_tiles, br.latency, br.energy_pj))
        assert len(wire) < 4096 < array_bytes
        ref2 = pickle.loads(wire)
        br2, shm = batch_from_shm(ref2)
        assert np.array_equal(br2.latency, br.latency)
        del br2
        shm.close()
    finally:
        shm_unlink(ref.shm_name)


@shm_required
def test_shm_names_fit_posix_limits():
    """macOS caps shm names at 31 chars *including* the leading slash
    (PSHMNAMLEN); the default prefix and the sweep-scoped prefix format
    must both stay under it."""
    co, arch = gemm_softmax(256, 1024, 64), edge()
    br = evaluate_specs_batch(co, arch, Topology(variant="fused_dist"),
                              [1], [1], [1])
    ref = batch_to_shm(br)                       # default prefix
    try:
        assert 1 + len(ref.shm_name) <= 31
    finally:
        shm_unlink(ref.shm_name)
    # sweep prefix: "cm" + hex pid + "x" + 4 hex; batch_to_shm appends
    # "_" + 8 hex.  Even at pid_max (2^22) the name fits.
    worst = f"cm{4194304:x}x{'f' * 4}_{'f' * 8}"
    assert 1 + len(worst) <= 31


# ------------------------------------------------------- executor parity

@shm_required
def test_thread_process_serial_bitwise_parity():
    """The tentpole contract: identical jobs produce bit-identical
    results — specs, latency/energy floats, evaluated counts and whole
    Pareto fronts — no matter which executor ran them."""
    jobs = _small_jobs()
    runs = {}
    for ex in ("serial", "thread", "process"):
        batcheval.cache_clear()
        runs[ex] = search_many(jobs, executor=ex)
    for rs, rt, rp in zip(runs["serial"], runs["thread"], runs["process"]):
        assert rs.latency == rt.latency == rp.latency
        assert rs.energy_pj == rt.energy_pj == rp.energy_pj
        assert rs.best.spec == rt.best.spec == rp.best.spec
        assert rs.evaluated == rt.evaluated == rp.evaluated
        assert rs.valid == rt.valid == rp.valid
        assert rs.mode == rt.mode == rp.mode
        assert (rs.front is None) == (rp.front is None)
        if rs.front is not None:
            assert len(rs.front) == len(rp.front)
            for ps, pp in zip(rs.front, rp.front):
                assert ps[:-1] == pp[:-1]          # objective floats
                assert ps[-1] == pp[-1]            # the MappingSpec


@shm_required
def test_process_sweep_leaves_no_segments():
    before = _segments()
    res = search_many(_small_jobs(), executor="process")
    assert len(res) == len(_small_jobs())
    leaked = {n for n in _segments() - before if n.startswith("cm")}
    assert not leaked


# ------------------------------------------------------ segment lifecycle

@shm_required
def test_cleanup_shm_segments_reclaims_prefixed():
    """cleanup_shm_segments unlinks exactly the prefixed segments and
    reports them; foreign segments survive."""
    from multiprocessing import shared_memory

    prefix = f"comettest{secrets.token_hex(4)}"
    mine = [shared_memory.SharedMemory(name=f"{prefix}_{i}", create=True,
                                       size=64) for i in range(3)]
    other = shared_memory.SharedMemory(name=f"other{secrets.token_hex(4)}",
                                       create=True, size=64)
    for s in mine:
        s.close()
    try:
        removed = cleanup_shm_segments(prefix)
        assert sorted(removed) == sorted(f"{prefix}_{i}" for i in range(3))
        assert cleanup_shm_segments(prefix) == []       # idempotent
        assert other.name.lstrip("/") in _segments()
    finally:
        other.close()
        other.unlink()


@shm_required
def test_worker_crash_reclaims_orphans_and_finishes_serially(monkeypatch):
    """A worker that dies after creating a segment but before returning
    its ref must not leak: the sweep warns, finishes the jobs serially,
    and the prefix sweep reclaims the orphan."""
    from concurrent.futures.process import BrokenProcessPool
    from multiprocessing import shared_memory

    monkeypatch.setattr(secrets, "token_hex", lambda n: "fixedtok")
    prefix = f"cm{os.getpid():x}xfixedtok"
    orphan_name = f"{prefix}_orphan"

    class _CrashingPool:
        def __init__(self, max_workers=None):
            pass

        def __enter__(self):
            return self

        def __exit__(self, *exc):
            return False

        def submit(self, fn, payload):
            # simulate the worker writing a grid segment, then dying
            # (submit runs once per chunk; the orphan only needs creating
            # once)
            try:
                seg = shared_memory.SharedMemory(name=orphan_name,
                                                 create=True, size=256)
                seg.close()
            except FileExistsError:
                pass

            class _F:
                @staticmethod
                def result():
                    raise BrokenProcessPool("worker died")

                @staticmethod
                def cancel():
                    return True

            return _F()

    monkeypatch.setattr(search_mod, "ProcessPoolExecutor", _CrashingPool)
    jobs = _small_jobs()[:3]
    with pytest.warns(RuntimeWarning, match="worker pool broke"):
        broken = search_many(jobs, executor="process")
    assert orphan_name not in _segments()               # orphan reclaimed
    ref = search_many(jobs, executor="serial")
    assert [r.latency for r in broken] == [r.latency for r in ref]
    assert [r.best.spec for r in broken] == [r.best.spec for r in ref]


@shm_required
def test_worker_killed_mid_shm_write_torn_segment_reclaimed(monkeypatch):
    """A worker SIGKILLed *mid-``batch_to_shm``* leaves a torn segment —
    created and half-filled with garbage, its ref never delivered.  The
    sweep must warn, finish the jobs serially with bit-identical
    results, and the prefix sweep must reclaim the torn segment (its
    contents are never parsed, so torn bytes cannot poison anything)."""
    from concurrent.futures.process import BrokenProcessPool
    from multiprocessing import shared_memory

    monkeypatch.setattr(secrets, "token_hex", lambda n: "tornsg")
    prefix = f"cm{os.getpid():x}xtornsg"
    torn_name = f"{prefix}_torn0001"

    class _KilledMidWritePool:
        def __init__(self, max_workers=None):
            pass

        def __enter__(self):
            return self

        def __exit__(self, *exc):
            return False

        def submit(self, fn, payload):
            # the worker got as far as creating the segment and writing
            # part of the grid before the OOM-killer got it
            try:
                seg = shared_memory.SharedMemory(name=torn_name,
                                                 create=True, size=1024)
                seg.buf[:512] = bytes(range(256)) * 2
                seg.close()
            except FileExistsError:
                pass

            class _F:
                @staticmethod
                def result():
                    raise BrokenProcessPool("worker killed mid-write")

                @staticmethod
                def cancel():
                    return True

            return _F()

    monkeypatch.setattr(search_mod, "ProcessPoolExecutor",
                        _KilledMidWritePool)
    jobs = _small_jobs()[:4]
    with pytest.warns(RuntimeWarning, match="worker pool broke"):
        broken = search_many(jobs, executor="process")
    assert torn_name not in _segments()            # torn segment reclaimed
    ref = search_many(jobs, executor="serial")
    assert [r.latency for r in broken] == [r.latency for r in ref]
    assert [r.energy_pj for r in broken] == [r.energy_pj for r in ref]
    assert [r.best.spec for r in broken] == [r.best.spec for r in ref]


@shm_required
def test_cleanup_races_concurrent_healthy_sweep():
    """``cleanup_shm_segments`` for a dead sweep's prefix, looping
    concurrently with a live process sweep under its own prefix: the
    janitor reclaims exactly the stale segments, never touches the live
    sweep's, and the sweep's results stay bit-identical to serial."""
    import threading
    import time
    from multiprocessing import shared_memory

    stale_prefix = f"cmstale{secrets.token_hex(2)}"
    stale = [shared_memory.SharedMemory(name=f"{stale_prefix}_{i}",
                                        create=True, size=64)
             for i in range(4)]
    for s in stale:
        s.close()
    reclaimed, stop = [], threading.Event()

    def janitor():
        while not stop.is_set():
            reclaimed.extend(cleanup_shm_segments(stale_prefix))
            time.sleep(0.002)

    t = threading.Thread(target=janitor)
    t.start()
    jobs = _small_jobs()
    try:
        before = _segments()
        out = search_many(jobs, executor="process")
    finally:
        stop.set()
        t.join()
    assert sorted(reclaimed) == sorted(f"{stale_prefix}_{i}"
                                       for i in range(4))
    assert not [n for n in _segments() if n.startswith(stale_prefix)]
    # the healthy sweep leaked nothing and lost nothing to the janitor
    assert not {n for n in _segments() - before if n.startswith("cm")}
    ref = search_many(jobs, executor="serial")
    assert [r.latency for r in out] == [r.latency for r in ref]
    assert [r.best.spec for r in out] == [r.best.spec for r in ref]


# ---------------------------------------------------- warning fallbacks

def test_pool_unavailable_falls_back_to_threads_with_warning(monkeypatch):
    class _NoPool:
        def __init__(self, max_workers=None):
            raise OSError("no process pools here")

    monkeypatch.setattr(search_mod, "ProcessPoolExecutor", _NoPool)
    jobs = _small_jobs()[:3]
    with pytest.warns(RuntimeWarning, match="process pool unavailable"):
        out = search_many(jobs, executor="process")
    ref = search_many(jobs, executor="serial")
    assert [r.latency for r in out] == [r.latency for r in ref]


def test_parallel_map_pool_creation_failure_warns_and_runs_serial(monkeypatch):
    class _NoPool:
        def __init__(self, max_workers=None):
            raise OSError("no threads either")

    monkeypatch.setattr(search_mod, "ThreadPoolExecutor", _NoPool)
    with pytest.warns(RuntimeWarning, match="running serially"):
        out = parallel_map(lambda x: x * x, [1, 2, 3], executor="thread")
    assert out == [1, 4, 9]


def test_auto_executor_thresholds(monkeypatch):
    """'auto' stays on threads below PROCESS_MIN_JOBS and switches to the
    process pool at the threshold (when shared memory works)."""
    calls = []

    def _spy(jobs, *, max_workers, chunksize, chunking="size"):
        calls.append(len(jobs))
        return [search_mod._run_search_job(j) for j in jobs]

    monkeypatch.setattr(search_mod, "_search_many_process", _spy)
    co, arch = gemm_softmax(256, 1024, 64), edge()
    small = [(co, arch, {"variants": ["unfused"]})] * 2
    search_many(small)                       # auto, below threshold
    assert calls == []
    if not search_mod._shm_usable():
        pytest.skip("no working shared memory")
    big = [(co, arch, {"variants": ["unfused"]})] * search_mod.PROCESS_MIN_JOBS
    search_many(big)                         # auto, at threshold
    assert calls == [search_mod.PROCESS_MIN_JOBS]


@shm_required
def test_unknown_kwargs_rejected_identically_across_executors():
    """A typoed search kwarg must raise on the process path exactly as
    it does serially — the shm shortcut may not silently ignore it and
    return wrong-axes optima."""
    co, arch = gemm_softmax(256, 1024, 64), edge()
    jobs = [(co, arch, {"fanout": "pow2"})] * 3      # typo of 'fanouts'
    with pytest.raises(TypeError):
        search_many(jobs, executor="serial")
    with pytest.raises(TypeError):
        search_many(jobs, executor="process")


def test_make_chunks_size_aware_longest_first():
    """Size-aware chunk assignment deals jobs longest-first round-robin:
    the largest job opens the first chunk, every index appears exactly
    once, and 'contiguous' reproduces plain slicing."""
    from repro.core.search import _make_chunks, _norm_job

    arch = edge()
    small = gemm_softmax(256, 1024, 64)
    # candidate_list sizes are the (exact) size estimate, so the ranking
    # is fully deterministic
    def job(n_specs):
        return _norm_job((small, arch, {"candidate_list": [
            MappingSpec(variant="fused_dist", m_tiles=1 + i)
            for i in range(n_specs)]}))

    jobs = [job(2), job(5), job(1), job(9), job(3), job(4), job(7)]
    chunks = _make_chunks(jobs, 2, "size")
    flat = sorted(i for c in chunks for i, _j in c)
    assert flat == list(range(len(jobs)))          # a partition
    assert chunks[0][0][0] == 3                    # 9-spec job leads chunk 0
    # round-robin: second-largest (index 6, 7 specs) opens chunk 1
    assert chunks[1][0][0] == 6
    contig = _make_chunks(jobs, 2, "contiguous")
    assert [[i for i, _j in c] for c in contig] == [[0, 1], [2, 3], [4, 5], [6]]
    with pytest.raises(ValueError, match="chunking"):
        _make_chunks(jobs, 2, "random")


@shm_required
def test_size_aware_chunking_bit_identical_results():
    """chunking='size' must return the same ordered, bit-identical
    results as chunking='contiguous' and the serial path."""
    co, arch = gemm_softmax(256, 1024, 64), edge()
    variants = ["unfused", "fused_epilogue", "fused_std", "fused_dist"] * 2
    jobs = [(co, arch, {"variants": [v]}) for v in variants]
    serial = search_many(jobs, executor="serial")
    for mode in ("size", "contiguous"):
        out = search_many(jobs, executor="process", chunksize=3,
                          chunking=mode)
        assert [r.best.spec.variant for r in out] == variants
        assert all(a.latency == b.latency and a.best.spec == b.best.spec
                   and a.evaluated == b.evaluated
                   for a, b in zip(out, serial))


@shm_required
def test_chunked_scheduling_preserves_order():
    """Chunked job scheduling returns results in job order even when
    chunk sizes do not divide the job count."""
    co, arch = gemm_softmax(256, 1024, 64), edge()
    variants = ["unfused", "fused_epilogue", "fused_std", "fused_dist"] * 3
    jobs = [(co, arch, {"variants": [v]}) for v in variants]
    out = search_many(jobs, executor="process", chunksize=5)
    assert [r.best.spec.variant for r in out] == variants
    # chunksize=1 forces more chunks than the bounded submission window
    # holds, exercising the refill path
    out1 = search_many(jobs, executor="process", chunksize=1, max_workers=2)
    assert [r.best.spec.variant for r in out1] == variants
