"""glm4-9b [dense]: RoPE, GQA kv=2.  [hf:THUDM/glm-4-9b]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
        d_ff=13696, vocab_size=151552, rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, name="glm4-smoke")
