"""Measured-collective calibration loop (PARAM/nccl-tests style).

Sweep real (or synthetic) ``jax.lax`` collectives over log-spaced
message sizes, least-squares-fit ``NoCParams`` timing constants to the
measurements, persist the result with provenance next to the plan
store, and feed it back into every ``Arch`` preset via ``calibrated=``.

    harness  -- run_sweep / jax_measure_fn / synthetic_measure_fn
    fitter   -- fit_noc_params: weighted NNLS on the Eq. 1/3/4 model
    overlap  -- fit_overlap: achievable compute-collective overlap from
                concurrent compute+collective sweeps
    persist  -- calibrated_noc.json: save / load / staleness / quarantine
    driver   -- calibrate_once: reuse-or-sweep -> fit -> gate -> persist
    __main__ -- ``python -m repro.calibrate`` CLI

See ARCHITECTURE.md "Calibration loop" for the full picture.
"""
from .driver import calibrate_once
from .fitter import FitResult, TypeFit, fit_noc_params, predicted_seconds, \
    relative_errors
from .harness import (CALIBRATED_TYPES, MeasuredPoint, SweepConfig,
                      SweepResult, jax_measure_fn, log_sizes, run_sweep,
                      synthetic_measure_fn)
from .overlap import (ConcurrentPoint, OverlapFit, fit_overlap,
                      measured_hidden_fraction, predicted_concurrent_seconds,
                      synthetic_concurrent_points)
from .persist import (CALIB_FILENAME, CALIBRATION_SCHEMA, Calibration,
                      calibration_from_fit, calibration_path,
                      load_calibration, save_calibration)

__all__ = [
    "CALIBRATED_TYPES", "MeasuredPoint", "SweepConfig", "SweepResult",
    "run_sweep", "log_sizes", "jax_measure_fn", "synthetic_measure_fn",
    "FitResult", "TypeFit", "fit_noc_params", "predicted_seconds",
    "relative_errors",
    "ConcurrentPoint", "OverlapFit", "fit_overlap",
    "measured_hidden_fraction", "predicted_concurrent_seconds",
    "synthetic_concurrent_points",
    "CALIBRATION_SCHEMA", "CALIB_FILENAME", "Calibration",
    "calibration_path", "save_calibration", "load_calibration",
    "calibration_from_fit",
    "calibrate_once",
]
