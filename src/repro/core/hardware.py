"""Hardware architecture description (COMET §II Fig. 2(b), §V Table V).

An :class:`Arch` describes a spatial accelerator:

    DRAM -> per-cluster Global Buffer (GB) -> per-core IB/WB/OB ->
    GEMM unit (grid of systolic arrays) + SIMD unit

Clusters are connected by a cluster-level NoC mesh; cores within a cluster
by a core-level NoC mesh.  The same dataclass family also hosts the TPU-v5e
adaptation used by the framework integration (HBM->VMEM->MXU/VPU; the ICI
torus plays the role of the cluster NoC).

Energy constants: the paper derives DRAM energy from DRAMPower (DDR4),
SRAM energies from CACTI-7 and compute energies from synthesized
DesignWare IP.  Those toolchains are not available offline, so we use
published-ballpark constants (documented inline); see DESIGN.md §8 —
*ratios*, not absolute joules, are the validation target.
"""
from __future__ import annotations

from dataclasses import dataclass, fields, replace
from typing import Optional, Tuple

__all__ = [
    "MemLevel",
    "NoCParams",
    "GemmUnit",
    "SimdUnit",
    "Arch",
    "apply_calibration",
    "edge",
    "cloud",
    "tpu_v5e",
    "tileflow_like",
    "PRESETS",
]

GIGA = 1e9


@dataclass(frozen=True)
class MemLevel:
    """One memory level. Bandwidth in bytes/s, energy in pJ/byte."""

    name: str
    size_bytes: int
    bandwidth: float
    read_energy_pj_per_byte: float
    write_energy_pj_per_byte: float
    double_buffered: bool = True

    def access_energy(self, read_bytes: float, write_bytes: float) -> float:
        """Energy in pJ."""
        return (read_bytes * self.read_energy_pj_per_byte
                + write_bytes * self.write_energy_pj_per_byte)


@dataclass(frozen=True)
class NoCParams:
    """Network-on-chip parameters for Eq. 3 (HiSIM/Orion model).

    t_router/t_enq in seconds; channel_width in links (bytes moved per
    enqueue slot); channel_bandwidth in bytes/s (effective BW cap used for
    the MemLat term of collective ops, Eq. 1/4); hop energy in pJ/byte/hop.
    """

    mesh: Tuple[int, int]
    channel_width: int
    channel_bandwidth: float
    t_router: float
    t_enq: float
    hop_energy_pj_per_byte: float = 0.1

    @property
    def num_nodes(self) -> int:
        return self.mesh[0] * self.mesh[1]

    def manhattan(self, a: int, b: int) -> int:
        """Manhattan hop distance between linear node ids on the mesh."""
        r, c = self.mesh
        ax, ay = divmod(a, c)
        bx, by = divmod(b, c)
        return abs(ax - bx) + abs(ay - by)


@dataclass(frozen=True)
class GemmUnit:
    """Grid of systolic arrays (SCALE-Sim-style analytical timing)."""

    array_rows: int = 32
    array_cols: int = 32
    grid: Tuple[int, int] = (8, 8)
    freq_hz: float = 1.0 * GIGA
    mac_energy_pj: float = 0.5  # bf16 MAC, 32nm-ballpark

    @property
    def num_arrays(self) -> int:
        return self.grid[0] * self.grid[1]

    @property
    def peak_macs_per_sec(self) -> float:
        return self.num_arrays * self.array_rows * self.array_cols * self.freq_hz

    @property
    def peak_flops(self) -> float:
        return 2.0 * self.peak_macs_per_sec


@dataclass(frozen=True)
class SimdUnit:
    lanes: int = 256
    freq_hz: float = 1.0 * GIGA
    op_energy_pj: float = 0.3

    @property
    def peak_ops_per_sec(self) -> float:
        return self.lanes * self.freq_hz


@dataclass(frozen=True)
class Arch:
    """Full accelerator description."""

    name: str
    dram: MemLevel
    gb: MemLevel          # per-cluster global buffer
    ib: MemLevel          # per-core input buffer
    wb: MemLevel          # per-core weight buffer
    ob: MemLevel          # per-core output buffer
    cluster_noc: NoCParams
    core_noc: NoCParams
    gemm_unit: GemmUnit
    simd_unit: SimdUnit

    @property
    def num_clusters(self) -> int:
        return self.cluster_noc.num_nodes

    @property
    def cores_per_cluster(self) -> int:
        return self.core_noc.num_nodes

    @property
    def total_cores(self) -> int:
        return self.num_clusters * self.cores_per_cluster

    def level(self, name: str) -> MemLevel:
        m = {lvl.name: lvl for lvl in (self.dram, self.gb, self.ib, self.wb, self.ob)}
        return m[name]

    # Order of levels root->leaf used by the mapping tree.
    LEVELS: Tuple[str, ...] = ("DRAM", "GB", "OB")

    def parent_of(self, level: str) -> Optional[str]:
        order = list(self.LEVELS)
        i = order.index(level)
        return order[i - 1] if i > 0 else None

    def signature(self) -> Tuple:
        """Hashable identity covering *every* architecture parameter.

        Evaluation caches must key on this, never on ``name`` alone: two
        Arch instances sharing a name but differing in bandwidth/capacity
        are different machines and must not reuse each other's results.
        Enumerated via ``dataclasses.fields`` so fields added later are
        covered automatically; all members are frozen dataclasses / tuples,
        so the tuple is hashable and equality tracks parameter equality.

        Memoized on the (frozen) instance: this sits on the hot search
        path as the cache-key prefix of every grid/spec lookup, so the
        field tuple is built once per Arch object.  ``dataclasses.replace``
        constructs a fresh instance, so derived Archs never inherit a
        stale signature.
        """
        sig = self.__dict__.get("_signature_memo")
        if sig is None:
            sig = tuple(getattr(self, f.name) for f in fields(self))
            object.__setattr__(self, "_signature_memo", sig)
        return sig

    def spatial_fanout(self, level: str) -> int:
        """Number of peer instances of ``level`` under one parent instance."""
        if level == "DRAM":
            return 1
        if level == "GB":
            return self.num_clusters
        return self.cores_per_cluster  # IB/WB/OB are per-core

    def peak_flops_total(self) -> float:
        return self.gemm_unit.peak_flops * self.total_cores


# ----------------------------------------------------------- calibration


def _coerce_calibrated_noc(calibrated) -> Optional[NoCParams]:
    """Resolve a ``calibrated=`` argument to the NoCParams carrying the
    measured timing constants.

    Accepts a :class:`NoCParams`, a ``repro.calibrate`` ``Calibration``
    (anything with a ``params`` NoCParams attribute), or a path to a
    persisted ``calibrated_noc.json``.  Returns ``None`` when the path
    holds no usable calibration (missing / stale / corrupt — the loader
    already warned), so callers degrade to the preset constants.
    """
    if calibrated is None:
        return None
    if isinstance(calibrated, NoCParams):
        return calibrated
    params = getattr(calibrated, "params", None)
    if isinstance(params, NoCParams):
        return params
    # a str/Path: load the persisted file (lazy import — repro.calibrate
    # imports this module)
    from repro.calibrate.persist import load_calibration
    cal = load_calibration(calibrated)
    return cal.params if cal is not None else None


def apply_calibration(arch: Arch, calibrated, *,
                      core_noc: bool = False) -> Arch:
    """Return ``arch`` with its cluster NoC's *timing* constants replaced
    by measured-and-fitted values (``repro.calibrate``).

    Only the three fitted constants transfer — ``channel_bandwidth``,
    ``t_router`` (per hop) and ``t_enq`` (per enqueue slot) — because
    they are mesh-shape-independent; the preset's mesh geometry, channel
    width and hop energy are kept.  ``core_noc=True`` additionally
    applies the same constants to the core-level NoC.  The replaced
    NoCParams flows through ``Arch.signature()``, so every downstream
    cache (factor tables, search grids, plan fingerprints) sees the
    calibrated machine as distinct from the preset.

    A ``calibrated`` that resolves to nothing (e.g. a missing or stale
    ``calibrated_noc.json``) returns ``arch`` unchanged.
    """
    noc = _coerce_calibrated_noc(calibrated)
    if noc is None:
        return arch
    def patch(base: NoCParams) -> NoCParams:
        return replace(base, channel_bandwidth=noc.channel_bandwidth,
                       t_router=noc.t_router, t_enq=noc.t_enq)
    out = replace(arch, cluster_noc=patch(arch.cluster_noc))
    if core_noc:
        out = replace(out, core_noc=patch(arch.core_noc))
    return out


# ---------------------------------------------------------------- presets


def _mk_mem(name: str, size: int, bw_gbs: float, re: float, we: float) -> MemLevel:
    return MemLevel(name, size, bw_gbs * GIGA, re, we)


def edge(calibrated=None) -> Arch:
    """Table V 'Edge' column.

    DRAM 1 GB @ 25 GB/s; 2x2 clusters of 2x2 cores; GB 2 MB @ 2 TB/s;
    IB/WB 32 KB, OB 128 KB @ 4 TB/s; channel width 256 links, channel BW
    64 GB/s, t_router 5 ns, t_enq 2 ns.
    Energy: DDR4 ~150 pJ/B (DRAMPower ballpark), MB-scale SRAM ~6 pJ/B,
    KB-scale SRAM ~1 pJ/B.

    ``calibrated`` (a NoCParams / Calibration / ``calibrated_noc.json``
    path) replaces the cluster NoC timing constants with measured ones
    via :func:`apply_calibration`.
    """
    arch = Arch(
        name="edge",
        dram=_mk_mem("DRAM", 1 << 30, 25, 150.0, 150.0),
        gb=_mk_mem("GB", 2 << 20, 2000, 6.0, 6.0),
        ib=_mk_mem("IB", 32 << 10, 4000, 1.0, 1.0),
        wb=_mk_mem("WB", 32 << 10, 4000, 1.0, 1.0),
        ob=_mk_mem("OB", 128 << 10, 4000, 1.0, 1.0),
        cluster_noc=NoCParams((2, 2), 256, 64 * GIGA, 5e-9, 2e-9, 0.10),
        core_noc=NoCParams((2, 2), 256, 64 * GIGA, 5e-9, 2e-9, 0.05),
        gemm_unit=GemmUnit(32, 32, (8, 8), 1.0 * GIGA, 0.5),
        simd_unit=SimdUnit(256, 1.0 * GIGA, 0.3),
    )
    return apply_calibration(arch, calibrated)


def cloud(calibrated=None) -> Arch:
    """Table V 'Cloud' column."""
    arch = Arch(
        name="cloud",
        dram=_mk_mem("DRAM", 4 << 30, 50, 150.0, 150.0),
        gb=_mk_mem("GB", 8 << 20, 4000, 8.0, 8.0),
        ib=_mk_mem("IB", 32 << 10, 4000, 1.0, 1.0),
        wb=_mk_mem("WB", 32 << 10, 4000, 1.0, 1.0),
        ob=_mk_mem("OB", 128 << 10, 4000, 1.0, 1.0),
        cluster_noc=NoCParams((4, 4), 2048, 512 * GIGA, 5e-9, 2e-9, 0.10),
        core_noc=NoCParams((4, 4), 2048, 512 * GIGA, 5e-9, 2e-9, 0.05),
        gemm_unit=GemmUnit(32, 32, (8, 8), 1.0 * GIGA, 0.5),
        simd_unit=SimdUnit(256, 1.0 * GIGA, 0.3),
    )
    return apply_calibration(arch, calibrated)


def tpu_v5e(mesh: Tuple[int, int] = (16, 16), calibrated=None) -> Arch:
    """TPU-v5e adaptation (DESIGN.md §3).

    DRAM -> HBM (16 GB, 819 GB/s); GB -> VMEM (128 MB, ~8 TB/s on-chip);
    IB/WB/OB -> Pallas BlockSpec VMEM tiles (modelled as fast small
    buffers feeding the MXU/VPU); GEMM unit -> 4 MXUs of 128x128 (peak
    197 bf16 TFLOP/s => 1.5 GHz effective); SIMD -> VPU ~4 Tops/s.
    Cluster NoC -> ICI torus @ 50 GB/s/link (mesh = the jax device mesh);
    core NoC degenerates (1 core per chip).
    """
    peak = 197e12
    freq = peak / (4 * 128 * 128 * 2)
    arch = Arch(
        name="tpu_v5e",
        dram=_mk_mem("DRAM", 16 << 30, 819, 3.9, 3.9),   # HBM2e ~3.9 pJ/B
        gb=_mk_mem("GB", 128 << 20, 8000, 1.2, 1.2),      # VMEM
        ib=_mk_mem("IB", 512 << 10, 16000, 0.3, 0.3),
        wb=_mk_mem("WB", 512 << 10, 16000, 0.3, 0.3),
        ob=_mk_mem("OB", 1 << 20, 16000, 0.3, 0.3),
        cluster_noc=NoCParams(mesh, 4096, 50 * GIGA, 1e-7, 5e-9, 0.05),
        core_noc=NoCParams((1, 1), 4096, 8000 * GIGA, 1e-9, 1e-9, 0.01),
        gemm_unit=GemmUnit(128, 128, (2, 2), freq, 0.15),
        simd_unit=SimdUnit(4096, 0.94 * GIGA, 0.1),
    )
    return apply_calibration(arch, calibrated)


def tileflow_like(calibrated=None) -> Arch:
    """The 3-level architecture used for the Fig. 6 cost-model comparison:
    DRAM, one on-chip buffer, one MAC array (single cluster/core)."""
    arch = Arch(
        name="tileflow_like",
        dram=_mk_mem("DRAM", 4 << 30, 50, 150.0, 150.0),
        gb=_mk_mem("GB", 4 << 20, 2000, 6.0, 6.0),
        # Fig 6 arch has a single on-chip buffer level: the core buffers
        # are sized so GB is the binding constraint.
        ib=_mk_mem("IB", 2 << 20, 4000, 1.0, 1.0),
        wb=_mk_mem("WB", 2 << 20, 4000, 1.0, 1.0),
        ob=_mk_mem("OB", 2 << 20, 4000, 1.0, 1.0),
        cluster_noc=NoCParams((1, 1), 256, 64 * GIGA, 5e-9, 2e-9, 0.1),
        core_noc=NoCParams((1, 1), 256, 64 * GIGA, 5e-9, 2e-9, 0.05),
        gemm_unit=GemmUnit(32, 32, (1, 1), 1.0 * GIGA, 0.5),
        simd_unit=SimdUnit(256, 1.0 * GIGA, 0.3),
    )
    return apply_calibration(arch, calibrated)


PRESETS = {
    "edge": edge,
    "cloud": cloud,
    "tpu_v5e": tpu_v5e,
    "tileflow_like": tileflow_like,
}
