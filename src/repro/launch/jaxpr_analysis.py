"""Compat shim: the jaxpr walker moved to :mod:`repro.analysis.jaxpr`
(it now counts collectives for the static contract checker as well as
FLOPs).  Import from ``repro.analysis`` in new code."""
import warnings

from repro.analysis.jaxpr import (CollectiveRecord, TraceCounts,  # noqa: F401
                                  count_flops, count_jaxpr,
                                  structural_flops, trace_counts)

warnings.warn(
    "repro.launch.jaxpr_analysis is a deprecated compat shim; import from "
    "repro.analysis (or repro.analysis.jaxpr) instead",
    DeprecationWarning, stacklevel=2)

__all__ = ["count_flops", "structural_flops", "count_jaxpr",
           "trace_counts", "TraceCounts", "CollectiveRecord"]
