"""Repo-invariant AST lint (no dependency beyond the stdlib ``ast``).

Encodes the invariants this codebase keeps re-breaking in review, as
mechanical checks:

``poly-no-math``
    No ``math.*`` calls in the scalar/array-polymorphic Eq. 1-7 path
    (``core/cost.py``, ``core/collectives.py``, ``core/validate.py``,
    ``core/numerics.py`` and their array callers): ``math.ceil`` on a
    NumPy array raises (or silently scalarizes) and breaks the batched
    engine's SoA pass.  Scalar-only helpers (e.g. the factor-table
    builders in ``collectives.py``) are allowlisted by function name.

``poly-array-branch``
    No array-truthiness branches in the same files: ``if dv <= 0:`` on an
    array raises "truth value is ambiguous".  Lines audited to be
    scalar-only carry a ``# scalar-ok`` pragma; comparisons against
    strings/None, ``is``/``in`` tests, and guards on ``.size``/``.ndim``/
    ``len()``/``isinstance()``/``is_array()`` are recognized as scalar.
    Builtin ``max``/``min`` over 2+ positional args are flagged too
    (use ``numerics.vmax``/``vmin``).

``kernel-no-host``
    No float64 references, host NumPy (``np.*``), ``.item()``/
    ``.tolist()``/``device_get`` round-trips inside Pallas kernel bodies
    (functions passed to ``pl.pallas_call``): each is either a tracing
    error or a silent performance cliff on TPU.

``core-no-sqlite``
    No raw ``sqlite3`` access in ``core/`` outside ``planstore.py``'s
    retry/degradation wrapper.

``vmem-budget``
    Static VMEM working-set estimation: block shapes and scratch shapes
    are extracted from each kernel's ``pallas_call`` declaration by AST
    and evaluated against every VMEM-feasible candidate the autotuner can
    emit for the paper shapes; (working set x 2 for double buffering)
    must fit the arch's GB (VMEM) capacity.  An un-evaluatable
    declaration is itself a finding — the extraction must not silently
    rot.

Adding a rule: write a ``check_<name>(ctx) -> Iterable[LintFinding]``
function, register it in ``RULES``, and document it here and in
ARCHITECTURE.md ("Static contracts").
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

__all__ = ["LintFinding", "lint_repo", "lint_source", "RULES",
           "vmem_findings"]

_PKG_ROOT = Path(__file__).resolve().parents[1]   # src/repro

PRAGMA = "scalar-ok"

# Files on the scalar/array-polymorphic Eq. 1-7 path.
POLY_FILES = (
    "core/cost.py",
    "core/collectives.py",
    "core/validate.py",
    "core/numerics.py",
    "core/batcheval.py",
    "core/mapping.py",
)

# Scalar-only helpers inside poly files where math.* is legitimate.
MATH_ALLOWED_FUNCS: Dict[str, Set[str]] = {
    "core/collectives.py": {"_step_distances", "_scalar_factors",
                            "_factor_table", "_mesh_avg_distance",
                            "overlapped_collective_seconds"},
}

# Functions that are documented scalar-only paths (validated entry points,
# table builders): array-truthiness rules do not apply inside them.
SCALAR_ONLY_FUNCS: Dict[str, Set[str]] = {
    "core/collectives.py": {"_step_distances", "_scalar_factors",
                            "_factor_table", "_mesh_avg_distance",
                            "overlapped_collective_seconds"},
    "core/validate.py": {"validate_headroom_levels", "validate_tree"},
}

KERNEL_DIR = "kernels"
KERNEL_EXEMPT = {"kernels/autotune.py"}  # host-side planner, no kernel body

CORE_SQLITE_OWNER = "core/planstore.py"


@dataclass(frozen=True)
class LintFinding:
    rule: str
    path: str        # package-relative, e.g. "core/cost.py"
    line: int
    col: int
    message: str

    def describe(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"

    def to_dict(self) -> Dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message}


@dataclass
class _Ctx:
    path: str                  # package-relative posix path
    tree: ast.AST
    lines: List[str]

    def pragma(self, node: ast.AST) -> bool:
        ln = getattr(node, "lineno", 0)
        if 1 <= ln <= len(self.lines):
            return PRAGMA in self.lines[ln - 1]
        return False


def _enclosing_funcs(tree: ast.AST) -> Dict[ast.AST, str]:
    """Map every node to the name of its innermost enclosing function."""
    owner: Dict[ast.AST, str] = {}

    def walk(node: ast.AST, fn: str) -> None:
        for child in ast.iter_child_nodes(node):
            name = fn
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                name = child.name
            owner[child] = name
            walk(child, name)

    owner[tree] = ""
    walk(tree, "")
    return owner


# --------------------------------------------------------- rule: poly math


def check_poly_math(ctx: _Ctx) -> Iterable[LintFinding]:
    if ctx.path not in POLY_FILES:
        return []
    allowed = MATH_ALLOWED_FUNCS.get(ctx.path, set())
    owner = _enclosing_funcs(ctx.tree)
    out = []
    for node in ast.walk(ctx.tree):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "math"):
            if owner.get(node, "") in allowed or ctx.pragma(node):
                continue
            out.append(LintFinding(
                "poly-no-math", ctx.path, node.lineno, node.col_offset,
                f"math.{node.attr} in the scalar/array-polymorphic path "
                f"(use numerics.* / numpy ufuncs, or allowlist the "
                f"scalar-only helper)"))
    return out


# ------------------------------------------------- rule: poly array branch


_SCALAR_ATTRS = {"size", "ndim", "shape"}
_SCALAR_CALLS = {"len", "int", "float", "bool", "isinstance", "is_array",
                 "hasattr", "getattr", "callable"}


def _is_scalar_expr(node: ast.expr) -> bool:
    """Conservatively true when an expression is guaranteed non-array.

    Numeric constants are deliberately NOT scalar evidence: ``dv <= 0``
    with an array ``dv`` is the canonical array-truthiness bug, so a
    numeric literal on one side says nothing about the other side.
    """
    if isinstance(node, ast.Constant):
        return isinstance(node.value, (str, bytes, bool)) or node.value is None
    if isinstance(node, ast.UnaryOp):
        return _is_scalar_expr(node.operand)
    if isinstance(node, ast.Attribute):
        return node.attr in _SCALAR_ATTRS
    if isinstance(node, ast.Call):
        fn = node.func
        if isinstance(fn, ast.Name) and fn.id in _SCALAR_CALLS:
            return True
        if isinstance(fn, ast.Attribute) and fn.attr in ("all", "any"):
            return True   # np.all(...) / arr.all() reduce to a scalar bool
    if isinstance(node, ast.BinOp):
        return _is_scalar_expr(node.left) and _is_scalar_expr(node.right)
    return False


def _compare_is_scalar(node: ast.Compare) -> bool:
    if all(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
           for op in node.ops):
        return True
    operands = [node.left, *node.comparators]
    if any(isinstance(o, ast.Constant) and isinstance(o.value, (str, bytes))
           for o in operands):
        return True   # string equality (schedule names etc.)
    if any(isinstance(o, ast.Tuple) and not o.elts for o in operands):
        return True   # sentinel compare against the empty tuple
    return any(_is_scalar_expr(o) for o in operands)


def _condition_findings(ctx: _Ctx, cond: ast.expr, owner: Dict[ast.AST, str],
                        scalar_funcs: Set[str]) -> Iterable[LintFinding]:
    stack = [cond]
    while stack:
        node = stack.pop()
        if isinstance(node, ast.BoolOp):
            stack.extend(node.values)
            continue
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.Not):
            stack.append(node.operand)
            continue
        if isinstance(node, ast.Compare):
            if _compare_is_scalar(node):
                continue
            if owner.get(node, "") in scalar_funcs or ctx.pragma(node):
                continue
            yield LintFinding(
                "poly-array-branch", ctx.path, node.lineno, node.col_offset,
                "comparison used as a branch condition in the "
                "array-polymorphic path — ambiguous for arrays (use "
                "numerics.vwhere / np.where, or mark the audited scalar "
                "site with '# scalar-ok')")


def check_poly_branches(ctx: _Ctx) -> Iterable[LintFinding]:
    if ctx.path not in POLY_FILES:
        return []
    scalar_funcs = SCALAR_ONLY_FUNCS.get(ctx.path, set())
    owner = _enclosing_funcs(ctx.tree)
    out: List[LintFinding] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.If, ast.While)):
            out.extend(_condition_findings(ctx, node.test, owner,
                                           scalar_funcs))
        elif isinstance(node, ast.IfExp):
            out.extend(_condition_findings(ctx, node.test, owner,
                                           scalar_funcs))
        elif isinstance(node, ast.Call):
            fn = node.func
            if (isinstance(fn, ast.Name) and fn.id in ("max", "min")
                    and len(node.args) >= 2
                    and not any(isinstance(a, ast.Starred)
                                for a in node.args)):
                if owner.get(node, "") in scalar_funcs or ctx.pragma(node):
                    continue
                out.append(LintFinding(
                    "poly-array-branch", ctx.path, node.lineno,
                    node.col_offset,
                    f"builtin {fn.id}() over multiple args in the "
                    f"array-polymorphic path (use numerics.vmax/vmin, or "
                    f"'# scalar-ok')"))
    return out


# ----------------------------------------------------- rule: kernel bodies


def _kernel_function_names(tree: ast.AST) -> Set[str]:
    """Names of functions handed to pl.pallas_call (directly or through
    functools.partial), plus the ``*_kernel`` naming convention."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node.name.endswith("_kernel") or node.name == "_kernel":
                names.add(node.name)
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Attribute) and fn.attr == "pallas_call":
                for arg in node.args[:1]:
                    if isinstance(arg, ast.Name):
                        names.add(arg.id)
                    elif isinstance(arg, ast.Call):
                        for sub in arg.args[:1]:
                            if isinstance(sub, ast.Name):
                                names.add(sub.id)
    return names


def check_kernel_host(ctx: _Ctx) -> Iterable[LintFinding]:
    if not ctx.path.startswith(KERNEL_DIR + "/") or ctx.path in KERNEL_EXEMPT:
        return []
    kernel_names = _kernel_function_names(ctx.tree)
    out: List[LintFinding] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if node.name not in kernel_names:
            continue
        for sub in ast.walk(node):
            if isinstance(sub, ast.Attribute):
                if sub.attr == "float64" or sub.attr == "f64":
                    out.append(LintFinding(
                        "kernel-no-host", ctx.path, sub.lineno,
                        sub.col_offset,
                        f"float64 reference inside kernel body "
                        f"'{node.name}' (TPU kernels are f32/bf16)"))
                elif (isinstance(sub.value, ast.Name)
                        and sub.value.id in ("np", "numpy")):
                    out.append(LintFinding(
                        "kernel-no-host", ctx.path, sub.lineno,
                        sub.col_offset,
                        f"host numpy ({sub.value.id}.{sub.attr}) inside "
                        f"kernel body '{node.name}' (use jnp/jax.lax)"))
                elif sub.attr in ("item", "tolist", "device_get"):
                    out.append(LintFinding(
                        "kernel-no-host", ctx.path, sub.lineno,
                        sub.col_offset,
                        f".{sub.attr} host round-trip inside kernel body "
                        f"'{node.name}'"))
            elif (isinstance(sub, ast.Constant) and sub.value == "float64"):
                out.append(LintFinding(
                    "kernel-no-host", ctx.path, sub.lineno, sub.col_offset,
                    f"'float64' dtype string inside kernel body "
                    f"'{node.name}'"))
    return out


# ------------------------------------------------------ rule: core sqlite


def check_core_sqlite(ctx: _Ctx) -> Iterable[LintFinding]:
    if not ctx.path.startswith("core/") or ctx.path == CORE_SQLITE_OWNER:
        return []
    out = []
    for node in ast.walk(ctx.tree):
        bad = None
        if isinstance(node, ast.Import):
            if any(a.name.split(".")[0] == "sqlite3" for a in node.names):
                bad = "import sqlite3"
        elif isinstance(node, ast.ImportFrom):
            if (node.module or "").split(".")[0] == "sqlite3":
                bad = "from sqlite3 import"
        if bad:
            out.append(LintFinding(
                "core-no-sqlite", ctx.path, node.lineno, node.col_offset,
                f"{bad} outside planstore.py — all SQLite access goes "
                f"through core/planstore.py's retry/degradation wrapper"))
    return out


# ------------------------------------------------------- rule: vmem budget


class _ShapeEval(ast.NodeVisitor):
    """Safe arithmetic evaluator for block-shape expressions."""

    def __init__(self, env: Dict[str, int]):
        self.env = env

    def eval(self, node: ast.expr) -> int:
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            return node.value
        if isinstance(node, ast.Name):
            if node.id in self.env:
                return int(self.env[node.id])
            raise KeyError(node.id)
        if isinstance(node, ast.BinOp):
            left, right = self.eval(node.left), self.eval(node.right)
            if isinstance(node.op, ast.Add):
                return left + right
            if isinstance(node.op, ast.Sub):
                return left - right
            if isinstance(node.op, ast.Mult):
                return left * right
            if isinstance(node.op, ast.FloorDiv):
                return left // right
            if isinstance(node.op, ast.Mod):
                return left % right
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            return -self.eval(node.operand)
        raise ValueError(ast.dump(node))


_DTYPE_ATTR_BYTES = {"float32": 4, "f32": 4, "bfloat16": 2, "bf16": 2,
                     "float16": 2, "int32": 4, "uint32": 4, "int8": 1}


def _pallas_decl(tree: ast.AST) -> Optional[Dict]:
    """Extract (in_specs shapes, out_specs shape, scratch (shape, bytes))
    expression lists from the first pallas_call in a module."""
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "pallas_call"):
            continue
        decl = {"in": [], "out": [], "scratch": [], "line": node.lineno}

        def block_shape(call: ast.expr):
            if (isinstance(call, ast.Call) and call.args
                    and isinstance(call.args[0], ast.Tuple)):
                return call.args[0].elts
            return None

        for kw in node.keywords:
            if kw.arg == "in_specs" and isinstance(kw.value, (ast.List,
                                                              ast.Tuple)):
                for el in kw.value.elts:
                    shp = block_shape(el)
                    if shp is not None:
                        decl["in"].append(shp)
            elif kw.arg == "out_specs":
                els = (kw.value.elts
                       if isinstance(kw.value, (ast.List, ast.Tuple))
                       else [kw.value])
                for el in els:
                    shp = block_shape(el)
                    if shp is not None:
                        decl["out"].append(shp)
            elif kw.arg == "scratch_shapes" and isinstance(
                    kw.value, (ast.List, ast.Tuple)):
                for el in kw.value.elts:
                    if isinstance(el, ast.Call) and el.args:
                        shp = (el.args[0].elts
                               if isinstance(el.args[0], ast.Tuple) else None)
                        nbytes = 4
                        if len(el.args) > 1 and isinstance(el.args[1],
                                                           ast.Attribute):
                            nbytes = _DTYPE_ATTR_BYTES.get(
                                el.args[1].attr, 4)
                        if shp is not None:
                            decl["scratch"].append((shp, nbytes))
        return decl
    return None


def _kernel_vmem_cases() -> Dict[str, Tuple[List[Dict[str, int]], str]]:
    """Per kernel file: the candidate-variable environments the autotuner
    can emit for the paper shapes (the feasible sets its VMEM filters
    produce), plus a label for reports."""
    from repro.kernels.allgather_gemm import BUDGET_SHAPES
    from repro.kernels.autotune import (PAPER_KERNEL_SHAPES,
                                        _attention_pairs, _gemm_pairs,
                                        _ssd_chunk_cands)
    gemm_envs, attn_envs, ssd_envs = [], [], []
    for m, n, k in PAPER_KERNEL_SHAPES["gemm_epilogue_blocks"]:
        for bm, bk in _gemm_pairs(m, n, k):
            gemm_envs.append({"block_m": bm, "block_k": bk, "N": n})
    for sq, skv, d in PAPER_KERNEL_SHAPES["attention_blocks"]:
        for bq, bk in _attention_pairs(sq, skv, d):
            attn_envs.append({"block_q": bq, "block_k": bk, "D": d})
    for s, p, n in PAPER_KERNEL_SHAPES["ssd_chunk_len"]:
        for c in _ssd_chunk_cands(s, p, n):
            ssd_envs.append({"chunk": c, "P": p, "N": n})
    # the streamed all-gather-GEMM declares its double buffers explicitly
    # (a ``buffers`` axis on the scratch shapes), so the envs cross both
    # buffer counts; the rule's global x2 stays as conservative headroom
    agg_envs = [{"buffers": b, "M": m, "kc": k // c, "N": n}
                for m, k, n, c in BUDGET_SHAPES for b in (1, 2)]
    return {
        "kernels/gemm_softmax.py": (gemm_envs, "gemm paper shapes"),
        "kernels/gemm_layernorm.py": (gemm_envs, "gemm paper shapes"),
        "kernels/flash_attention.py": (attn_envs, "attention paper shapes"),
        "kernels/ssd.py": (ssd_envs, "ssd paper shapes"),
        "kernels/allgather_gemm.py": (agg_envs,
                                      "all-gather-GEMM stream shapes"),
    }


def vmem_findings(root: Optional[Path] = None) -> List[LintFinding]:
    """Static VMEM working-set check of every kernel's pallas_call
    declaration against the arch GB capacity, across all autotuner-
    feasible candidate blocks for the paper shapes."""
    from repro.core.hardware import tpu_v5e
    root = root or _PKG_ROOT
    capacity = tpu_v5e().gb.size_bytes
    block_bytes = 2  # kernels take/emit bf16 blocks; scratch dtype is read
    out: List[LintFinding] = []
    for rel, (envs, label) in _kernel_vmem_cases().items():
        path = root / rel
        if not path.exists():
            continue
        tree = ast.parse(path.read_text())
        decl = _pallas_decl(tree)
        if decl is None:
            out.append(LintFinding("vmem-budget", rel, 1, 0,
                                   "no pallas_call declaration found "
                                   "(extraction rot — update the lint)"))
            continue
        worst = (0, None)
        for env in envs:
            ev = _ShapeEval(env)
            try:
                total = 0
                for shp in decl["in"] + decl["out"]:
                    n = 1
                    for e in shp:
                        n *= ev.eval(e)
                    total += n * block_bytes
                for shp, nbytes in decl["scratch"]:
                    n = 1
                    for e in shp:
                        n *= ev.eval(e)
                    total += n * nbytes
            except (KeyError, ValueError) as exc:
                out.append(LintFinding(
                    "vmem-budget", rel, decl["line"], 0,
                    f"could not statically evaluate a block shape with "
                    f"candidate env {env} ({exc!r}) — update "
                    f"_kernel_vmem_cases"))
                break
            if total > worst[0]:
                worst = (total, env)
        else:
            working = worst[0] * 2  # double buffering
            if working > capacity:
                out.append(LintFinding(
                    "vmem-budget", rel, decl["line"], 0,
                    f"declared working set {worst[0]} B x2 (double "
                    f"buffer) exceeds GB capacity {capacity} B for "
                    f"candidate {worst[1]} ({label})"))
    return out


# ------------------------------------------------------------------ driver


RULES = {
    "poly-no-math": check_poly_math,
    "poly-array-branch": check_poly_branches,
    "kernel-no-host": check_kernel_host,
    "core-no-sqlite": check_core_sqlite,
}


def lint_source(source: str, path: str) -> List[LintFinding]:
    """Lint one in-memory module under a package-relative ``path`` (the
    path selects which rules apply) — the unit-test entry point."""
    ctx = _Ctx(path=path, tree=ast.parse(source),
               lines=source.splitlines())
    out: List[LintFinding] = []
    for check in RULES.values():
        out.extend(check(ctx))
    return out


def lint_repo(root: Optional[Path] = None,
              with_vmem: bool = True) -> List[LintFinding]:
    """Run every rule over the package tree (``src/repro``)."""
    root = root or _PKG_ROOT
    out: List[LintFinding] = []
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        try:
            source = path.read_text()
            out.extend(lint_source(source, rel))
        except SyntaxError as exc:
            out.append(LintFinding("parse-error", rel,
                                   exc.lineno or 1, 0, str(exc)))
    if with_vmem:
        out.extend(vmem_findings(root))
    return sorted(out, key=lambda f: (f.path, f.line, f.rule))
