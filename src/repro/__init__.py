"""COMET-JAX: compound-operation dataflow modeling with explicit
collectives (Negi et al., CS.AR 2025), reproduced and extended into a
multi-pod JAX training/inference framework.

Subpackages: core (the paper), kernels (Pallas TPU), models (10 assigned
architectures), configs, parallel, train, serve, launch.
"""
__version__ = "1.0.0"
