"""Attention: GQA/MQA (optionally sliding-window, qk-norm), MLA (DeepSeek),
and cross-attention — with train (full-seq), prefill (cache-building) and
decode (cached, fixed-shape) paths.

Training/prefill uses a *blocked* online-softmax implementation (pure jnp
``lax.scan`` over KV blocks — the FlashAttention dataflow the paper costs,
expressed at the XLA level) so the S×S score matrix is never materialized;
``use_kernels=True`` routes through the Pallas kernel instead.  Decode uses
dense einsums over the cache (the flash-decoding merge across shards is
handled by the collective planner at the sharding level).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from .config import ModelConfig
from .layers import apply_norm, apply_rope, rope_cos_sin
from .param import ParamSpec

F32 = jnp.float32
NEG = -1e30

__all__ = [
    "gqa_specs", "mla_specs", "cross_specs",
    "attn_train", "attn_prefill", "attn_decode",
    "cross_train", "cross_decode", "make_cross_cache",
    "init_attn_cache", "blocked_attention",
]


# ============================================================ blocked attn


def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, window: Optional[int],
                      scale: float, block_k: int = 512,
                      q_offset: int = 0) -> jax.Array:
    """Online-softmax attention, scanning KV blocks.

    q: (B, Hq, Sq, Dq); k: (B, Hkv, Skv, Dq); v: (B, Hkv, Skv, Dv).
    ``q_offset``: absolute position of q[0] minus absolute position of k[0]
    (for prefill Sq == Skv -> offset 0; decode handled elsewhere).
    Returns (B, Hq, Sq, Dv) in q.dtype.
    """
    B, Hq, Sq, Dq = q.shape
    Hkv, Skv, Dv = k.shape[1], k.shape[2], v.shape[-1]
    group = Hq // Hkv
    bk = min(block_k, Skv)
    pad = (-Skv) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nblk = (Skv + pad) // bk
    kb = jnp.moveaxis(k.reshape(B, Hkv, nblk, bk, Dq), 2, 0)   # (nblk,B,Hkv,bk,Dq)
    vb = jnp.moveaxis(v.reshape(B, Hkv, nblk, bk, Dv), 2, 0)
    qf = q.astype(F32)
    q_pos = jnp.arange(Sq) + q_offset                          # (Sq,)

    # grouped-query layout: (B, Hkv, group, Sq, D) — no KV repeat, so TP
    # sharding of kv-heads/seq never forces a reshard of the cache.
    qg = qf.reshape(B, Hkv, group, Sq, Dq)

    def step(carry, inp):
        m, l, acc = carry
        kblk, vblk, bi = inp
        kf = kblk.astype(F32)
        vf = vblk.astype(F32)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf) * scale
        k_pos = bi * bk + jnp.arange(bk)                       # (bk,)
        mask = k_pos[None, :] < Skv                            # padding
        if causal:
            mask = mask & (q_pos[:, None] >= k_pos[None, :])
        if window is not None:
            mask = mask & ((q_pos[:, None] - k_pos[None, :]) < window)
        s = jnp.where(mask[None, None, None], s, NEG)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, group, Sq), NEG, F32)
    l0 = jnp.zeros((B, Hkv, group, Sq), F32)
    a0 = jnp.zeros((B, Hkv, group, Sq, Dv), F32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kb, vb, jnp.arange(nblk)))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).reshape(B, Hq, Sq, Dv)
    return out.astype(q.dtype)


def banded_window_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                            window: int, scale: float) -> jax.Array:
    """Causal sliding-window self-attention in O(S·2W) instead of O(S²):
    queries are processed in blocks of W; each block attends only its
    [iW−W, iW+W) key band (beyond-paper optimization; see EXPERIMENTS §Perf
    hymba hillclimb).  Requires Sq == Skv (training/prefill self-attn)."""
    B, Hq, S, Dq = q.shape
    Hkv, Dv = k.shape[1], v.shape[-1]
    group = Hq // Hkv
    W = window
    pad = (-S) % W
    Sp = S + pad
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (W, pad), (0, 0)))   # front band pad
    vp = jnp.pad(v, ((0, 0), (0, 0), (W, pad), (0, 0)))
    nb = Sp // W
    qf = qp.astype(F32).reshape(B, Hkv, group, Sp, Dq)
    rel = W + jnp.arange(W)[:, None] - jnp.arange(2 * W)[None, :]  # q-k dist
    band_ok = (rel >= 0) & (rel < W)

    def step(_, i):
        qi = jax.lax.dynamic_slice_in_dim(qf, i * W, W, axis=3)      # (B,Hkv,g,W,D)
        ki = jax.lax.dynamic_slice_in_dim(kp, i * W, 2 * W, axis=2)  # (B,Hkv,2W,D)
        vi = jax.lax.dynamic_slice_in_dim(vp, i * W, 2 * W, axis=2)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qi, ki.astype(F32)) * scale
        k_pos = i * W - W + jnp.arange(2 * W)                         # original idx
        q_pos = i * W + jnp.arange(W)
        mask = band_ok & (k_pos[None, :] >= 0) & (k_pos[None, :] < S) \
            & (q_pos[:, None] < S)
        s = jnp.where(mask[None, None, None], s, NEG)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vi.astype(F32))
        return None, o

    _, outs = jax.lax.scan(step, None, jnp.arange(nb))
    # outs: (nb, B, Hkv, g, W, Dv) -> (B, Hq, Sp, Dv)
    out = jnp.moveaxis(outs, 0, 3).reshape(B, Hkv, group, Sp, Dv)
    out = out.reshape(B, Hq, Sp, Dv)
    return out[:, :, :S].astype(q.dtype)


def _attend(cfg: ModelConfig, q, k, v, *, causal, window, scale, q_offset=0):
    """Dispatch: banded-window / Pallas kernel / blocked scan / reference."""
    Dq, Dv = q.shape[-1], v.shape[-1]
    Sq, Skv = q.shape[2], k.shape[2]
    if (window is not None and causal and Sq == Skv and q_offset == 0
            and Skv >= 2 * window and cfg.banded_attention):
        return banded_window_attention(q, k, v, window=window, scale=scale)
    if cfg.use_kernels and Dq == Dv:
        return kops.mha(q, k, v, causal=causal, scale=scale, window=window,
                        use_kernel=True)
    if k.shape[2] > 1024:
        return blocked_attention(q, k, v, causal=causal, window=window,
                                 scale=scale, q_offset=q_offset)
    from ..kernels.ref import attention_ref
    if Dq == Dv and q_offset == 0:
        return attention_ref(q, k, v, causal=causal, scale=scale,
                             window=window)
    return blocked_attention(q, k, v, causal=causal, window=window,
                             scale=scale, q_offset=q_offset)


# ================================================================= specs


def gqa_specs(cfg: ModelConfig, L: int) -> Dict[str, ParamSpec]:
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s = {
        "wq": ParamSpec((L, d, H * hd), ("layer", "embed", "heads"), dtype=cfg.dtype),
        "wk": ParamSpec((L, d, Hkv * hd), ("layer", "embed", "kv_heads"), dtype=cfg.dtype),
        "wv": ParamSpec((L, d, Hkv * hd), ("layer", "embed", "kv_heads"), dtype=cfg.dtype),
        "wo": ParamSpec((L, H * hd, d), ("layer", "heads", "embed"), dtype=cfg.dtype),
    }
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((L, hd), ("layer", None), init="ones", dtype=cfg.dtype)
        s["k_norm"] = ParamSpec((L, hd), ("layer", None), init="ones", dtype=cfg.dtype)
    return s


def mla_specs(cfg: ModelConfig, L: int) -> Dict[str, ParamSpec]:
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.hd, cfg.rope_head_dim, cfg.v_head_dim
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    return {
        "wdq": ParamSpec((L, d, qr), ("layer", "embed", None), dtype=cfg.dtype),
        "q_norm": ParamSpec((L, qr), ("layer", None), init="ones", dtype=cfg.dtype),
        "wuq": ParamSpec((L, qr, H * (dn + dr)), ("layer", None, "heads"), dtype=cfg.dtype),
        "wdkv": ParamSpec((L, d, kvr + dr), ("layer", "embed", None), dtype=cfg.dtype),
        "kv_norm": ParamSpec((L, kvr), ("layer", None), init="ones", dtype=cfg.dtype),
        "wuk": ParamSpec((L, kvr, H * dn), ("layer", None, "heads"), dtype=cfg.dtype),
        "wuv": ParamSpec((L, kvr, H * dv), ("layer", None, "heads"), dtype=cfg.dtype),
        "wo": ParamSpec((L, H * dv, d), ("layer", "heads", "embed"), dtype=cfg.dtype),
    }


def cross_specs(cfg: ModelConfig, L: int) -> Dict[str, ParamSpec]:
    return gqa_specs(cfg, L)


# =============================================================== GQA paths


def _qkv(cfg: ModelConfig, p, x, positions):
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, Hkv, hd)
    v = (x @ p["wv"]).reshape(B, S, Hkv, hd)
    if cfg.qk_norm:
        qn = {"scale": p["q_norm"]}
        kn = {"scale": p["k_norm"]}
        if cfg.norm_type == "layernorm":
            qn["bias"] = jnp.zeros_like(p["q_norm"])
            kn["bias"] = jnp.zeros_like(p["k_norm"])
        q = apply_norm(cfg, qn, q)
        k = apply_norm(cfg, kn, k)
    cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def attn_train(cfg: ModelConfig, p, x, *, causal: bool = True) -> jax.Array:
    if cfg.attn_type == "mla":
        return _mla_train(cfg, p, x)
    B, S, d = x.shape
    q, k, v = _qkv(cfg, p, x, jnp.arange(S))
    scale = 1.0 / math.sqrt(cfg.hd)
    o = _attend(cfg, q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), causal=causal, window=cfg.window,
                scale=scale)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.hd)
    return o @ p["wo"]


def init_attn_cache(cfg: ModelConfig, B: int, cache_len: int, dtype) -> Dict:
    """Fixed-shape cache.  Windowed layers use a ring buffer of width
    min(window, cache_len); global layers use the full length.  ``kpos``
    is per-row (B, W): decode positions are per-slot so a serving engine
    can re-prefill one slot while the others keep decoding."""
    if cfg.attn_type == "mla":
        return {
            "ckv": jnp.zeros((B, cache_len, cfg.kv_lora_rank), dtype),
            "kr": jnp.zeros((B, cache_len, cfg.rope_head_dim), dtype),
        }
    W = min(cfg.window, cache_len) if cfg.window else cache_len
    return {
        "k": jnp.zeros((B, W, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((B, W, cfg.n_kv_heads, cfg.hd), dtype),
        "kpos": jnp.full((B, W), -1, jnp.int32),
    }


def attn_prefill(cfg: ModelConfig, p, x) -> Tuple[jax.Array, Dict]:
    """Full-sequence forward that also returns the populated cache."""
    B, S, d = x.shape
    if cfg.attn_type == "mla":
        o, ckv, kr = _mla_train(cfg, p, x, return_cache=True)
        return o, {"ckv": ckv, "kr": kr}
    q, k, v = _qkv(cfg, p, x, jnp.arange(S))
    scale = 1.0 / math.sqrt(cfg.hd)
    o = _attend(cfg, q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), causal=True, window=cfg.window,
                scale=scale)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.hd)
    if cfg.window and cfg.window < S:
        W = cfg.window
        # last W positions land at ring slots (pos % W)
        pos = jnp.arange(S - W, S)
        slots = pos % W
        k_ring = jnp.zeros((B, W) + k.shape[2:], k.dtype).at[:, slots].set(k[:, S - W:])
        v_ring = jnp.zeros((B, W) + v.shape[2:], v.dtype).at[:, slots].set(v[:, S - W:])
        kpos = jnp.full((W,), -1, jnp.int32).at[slots].set(pos)
        cache = {"k": k_ring, "v": v_ring,
                 "kpos": jnp.broadcast_to(kpos, (B, W))}
    else:
        cache = {"k": k, "v": v,
                 "kpos": jnp.broadcast_to(
                     jnp.arange(k.shape[1], dtype=jnp.int32),
                     (B, k.shape[1]))}
    return o @ p["wo"], cache


def attn_decode(cfg: ModelConfig, p, x, cache: Dict, pos: jax.Array
                ) -> Tuple[jax.Array, Dict]:
    """One-token decode.  x: (B, 1, d); pos: scalar int32 or per-row
    (B,) int32 (per-slot positions — continuous-batching engines
    re-prefill individual slots, so rows may sit at different depths)."""
    if cfg.attn_type == "mla":
        return _mla_decode(cfg, p, x, cache, pos)
    B = x.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    q, k1, v1 = _qkv(cfg, p, x, pos[:, None])      # per-row RoPE positions
    W = cache["k"].shape[1]
    slot = pos % W
    rows = jnp.arange(B)
    k = cache["k"].at[rows, slot].set(k1[:, 0])
    v = cache["v"].at[rows, slot].set(v1[:, 0])
    kpos = cache["kpos"].at[rows, slot].set(pos)               # (B, W)
    scale = 1.0 / math.sqrt(hd)
    group = H // Hkv
    qg = q.astype(F32).reshape(B, Hkv, group, hd)              # grouped layout
    kf = k.astype(F32)
    vf = v.astype(F32)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, kf) * scale
    valid = (kpos >= 0) & (kpos <= pos[:, None])
    if cfg.window:
        valid = valid & (kpos > (pos - cfg.window)[:, None])
    s = jnp.where(valid[:, None, None, :], s, NEG)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", pr, vf).astype(x.dtype)
    o = o.reshape(B, 1, H * hd)
    return o @ p["wo"], {"k": k, "v": v, "kpos": kpos}


# =============================================================== MLA paths


def _mla_q(cfg, p, x, positions):
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.hd, cfg.rope_head_dim
    cq = x @ p["wdq"]
    cq = apply_norm(cfg.with_(norm_type="rmsnorm"), {"scale": p["q_norm"]}, cq)
    q = (cq @ p["wuq"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    cos, sin = rope_cos_sin(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _mla_kv_compress(cfg, p, x, positions):
    kvr, dr = cfg.kv_lora_rank, cfg.rope_head_dim
    ckv_full = x @ p["wdkv"]                                   # (B,S,kvr+dr)
    ckv, kr = ckv_full[..., :kvr], ckv_full[..., kvr:]
    ckv = apply_norm(cfg.with_(norm_type="rmsnorm"), {"scale": p["kv_norm"]}, ckv)
    cos, sin = rope_cos_sin(positions, dr, cfg.rope_theta)
    kr = apply_rope(kr[:, :, None, :], cos, sin)[:, :, 0, :]   # shared across heads
    return ckv, kr


def _mla_train(cfg, p, x, *, return_cache: bool = False):
    B, S, _ = x.shape
    H, dn, dr, dv = cfg.n_heads, cfg.hd, cfg.rope_head_dim, cfg.v_head_dim
    positions = jnp.arange(S)
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    ckv, kr = _mla_kv_compress(cfg, p, x, positions)
    k_nope = (ckv @ p["wuk"]).reshape(B, S, H, dn)
    v = (ckv @ p["wuv"]).reshape(B, S, H, dv)
    q = jnp.concatenate([q_nope, q_rope], -1)                  # (B,S,H,dn+dr)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kr[:, :, None, :],
                                                  (B, S, H, dr))], -1)
    scale = 1.0 / math.sqrt(dn + dr)
    o = _attend(cfg, q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), causal=True, window=None, scale=scale)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, H * dv)
    out = o @ p["wo"]
    if return_cache:
        return out, ckv, kr
    return out


def _mla_decode(cfg, p, x, cache, pos):
    """Absorbed MLA decode: attention runs in the latent (kv_lora) space —
    the compressed cache is never decompressed (DeepSeek inference opt.).
    ``pos`` may be scalar or per-row (B,) (per-slot decode depths)."""
    B = x.shape[0]
    H, dn, dr, dv = cfg.n_heads, cfg.hd, cfg.rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    posv = pos[:, None]                                        # (B,1)
    q_nope, q_rope = _mla_q(cfg, p, x, posv)                   # (B,1,H,*)
    ckv1, kr1 = _mla_kv_compress(cfg, p, x, posv)              # (B,1,kvr),(B,1,dr)
    rows = jnp.arange(B)
    ckv = cache["ckv"].at[rows, pos].set(ckv1[:, 0])
    kr = cache["kr"].at[rows, pos].set(kr1[:, 0])
    S = ckv.shape[1]
    wuk = p["wuk"].reshape(kvr, H, dn)
    # absorb: q_lat[b,h,:] = W_uk[:,h,:] @ q_nope[b,h,:]
    q_lat = jnp.einsum("bhd,khd->bhk", q_nope[:, 0].astype(F32),
                       wuk.astype(F32))                        # (B,H,kvr)
    s = jnp.einsum("bhk,bsk->bhs", q_lat, ckv.astype(F32))
    s = s + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(F32),
                       kr.astype(F32))
    s = s * (1.0 / math.sqrt(dn + dr))
    mask = jnp.arange(S)[None, :] <= pos[:, None]              # (B, S)
    s = jnp.where(mask[:, None, :], s, NEG)
    pr = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhs,bsk->bhk", pr, ckv.astype(F32))  # (B,H,kvr)
    wuv = p["wuv"].reshape(kvr, H, dv)
    o = jnp.einsum("bhk,khd->bhd", ctx_lat, wuv.astype(F32)).astype(x.dtype)
    o = o.reshape(B, 1, H * dv)
    return o @ p["wo"], {"ckv": ckv, "kr": kr}


# ============================================================ cross-attn


def cross_train(cfg: ModelConfig, p, x, enc: jax.Array) -> jax.Array:
    """Decoder cross-attention over encoder output ``enc`` (B, Se, d)."""
    B, S, d = x.shape
    Se = enc.shape[1]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (enc @ p["wk"]).reshape(B, Se, Hkv, hd)
    v = (enc @ p["wv"]).reshape(B, Se, Hkv, hd)
    scale = 1.0 / math.sqrt(hd)
    o = _attend(cfg, q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), causal=False, window=None, scale=scale)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    return o @ p["wo"]


def make_cross_cache(cfg: ModelConfig, p, enc: jax.Array) -> Dict:
    B, Se, _ = enc.shape
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    return {"k": (enc @ p["wk"]).reshape(B, Se, Hkv, hd),
            "v": (enc @ p["wv"]).reshape(B, Se, Hkv, hd)}


def cross_decode(cfg: ModelConfig, p, x, cross_cache: Dict) -> jax.Array:
    B = x.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    group = H // Hkv
    q = (x @ p["wq"]).reshape(B, H, hd)
    k, v = cross_cache["k"], cross_cache["v"]
    kf = jnp.repeat(k.astype(F32), group, axis=2) if group > 1 else k.astype(F32)
    vf = jnp.repeat(v.astype(F32), group, axis=2) if group > 1 else v.astype(F32)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(F32), kf) / math.sqrt(hd)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhs,bshd->bhd", pr, vf).astype(x.dtype).reshape(B, 1, H * hd)
    return o @ p["wo"]
