from . import checkpoint, data, elastic, optimizer, train_step

__all__ = ["checkpoint", "data", "elastic", "optimizer", "train_step"]
