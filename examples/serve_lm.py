"""Serve a small model with batched requests through the continuous-batching
engine (prefill + jitted decode steps, slot reuse).

    PYTHONPATH=src python examples/serve_lm.py --requests 16
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import Model
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 24).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    eng = ServeEngine(model, params, batch_size=args.batch, cache_len=96,
                      prompt_len=32)
    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    n = sum(len(r.output) for r in done)
    print(f"{len(done)} requests, {n} tokens in {dt:.1f}s "
          f"({n/dt:.1f} tok/s, {eng.stats['decode_steps']} decode steps, "
          f"{eng.stats['prefill_calls']} prefill)")
    print("sample output:", done[0].output)


if __name__ == "__main__":
    main()
