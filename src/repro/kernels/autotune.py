"""COMET-driven Pallas block-size selection (DESIGN.md §2, kernel-level use).

This is the paper's mapping-space exploration applied to TPU tiles: for each
kernel we build the corresponding compound-op workload, instantiate the
TPU-v5e hardware model, and evaluate candidate tile shapes with the COMET
cost model (memory-fit validation + Eq. 1–7 latency).  Results are cached
per shape.  All functions degrade to safe hardware-aligned defaults if the
search finds nothing valid.

VMEM budget accounting mirrors the kernels' actual scratch/BlockSpec usage.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

from repro.core import hardware, workload
from repro.core.cost import systolic_gemm_cycles
from repro.core.hardware import tpu_v5e

__all__ = ["attention_blocks", "gemm_epilogue_blocks", "ssd_chunk_len",
           "VMEM_BUDGET"]

# usable VMEM per core for kernel working sets (half of 128 MB, leaving room
# for Pallas double buffering which the cost model assumes)
VMEM_BUDGET = 64 * 1024 * 1024
_LANE = 128  # MXU/VPU lane alignment


def _align(x: int, a: int = _LANE) -> int:
    return max(a, (x // a) * a)


@functools.lru_cache(maxsize=256)
def attention_blocks(sq: int, skv: int, d: int) -> Tuple[int, int]:
    """(block_q, block_k) for the FlashAttention kernel via COMET search.

    Working set per (bq, bk): q(bq,d) + k/v(bk,d)*2 + acc(bq,d) f32 +
    s(bq,bk) f32 (+ double buffering handled by budget halving).
    """
    arch = tpu_v5e()
    best = None
    cands = [128, 256, 512, 1024]
    for bq in cands:
        if bq > max(sq, _LANE):
            continue
        for bk in cands:
            if bk > max(skv, _LANE):
                continue
            vmem = (bq * d * 2 + 2 * bk * d * 2 + bq * d * 4 + bq * bk * 4
                    + 2 * bq * _LANE * 4)
            if vmem * 2 > VMEM_BUDGET:
                continue
            # COMET leaf costs: two MXU GEMM tiles + VPU online-softmax ops
            u = arch.gemm_unit
            g1 = systolic_gemm_cycles(bq, bk, d, u.array_rows, u.array_cols,
                                      u.num_arrays) / u.freq_hz
            g2 = systolic_gemm_cycles(bq, d, bk, u.array_rows, u.array_cols,
                                      u.num_arrays) / u.freq_hz
            simd = (5 * bq * bk + 6 * bq) / arch.simd_unit.peak_ops_per_sec
            mem = (bq * d * 2 + 2 * bk * d * 2) / arch.gb.bandwidth
            n_blocks = math.ceil(max(sq, 1) / bq) * math.ceil(max(skv, 1) / bk)
            lat = n_blocks * max(g1 + g2 + simd, mem)
            if best is None or lat < best[0]:
                best = (lat, bq, bk)
    if best is None:
        return (_LANE, _LANE)
    return best[1], best[2]


@functools.lru_cache(maxsize=256)
def gemm_epilogue_blocks(m: int, n: int, k: int) -> Tuple[int, int]:
    """(block_m, block_k) for the fused GEMM-SM / GEMM-LN kernels.

    Constraint: acc (block_m, N) f32 + B slice (block_k, N) must fit VMEM.
    """
    arch = tpu_v5e()
    best = None
    for bm in (128, 256, 512):
        for bk in (128, 256, 512):
            if bk > max(k, _LANE):
                continue
            vmem = bm * n * 4 + bk * n * 2 + bm * bk * 2 + bm * n * 2
            if vmem * 2 > VMEM_BUDGET:
                continue
            u = arch.gemm_unit
            g = systolic_gemm_cycles(bm, n, bk, u.array_rows, u.array_cols,
                                     u.num_arrays) / u.freq_hz
            mem = (bm * bk * 2 + bk * n * 2) / arch.dram.bandwidth
            n_iters = math.ceil(max(m, 1) / bm) * math.ceil(max(k, 1) / bk)
            epi = (4 * bm * n) / arch.simd_unit.peak_ops_per_sec \
                * math.ceil(max(m, 1) / bm)
            lat = n_iters * max(g, mem) + epi
            if best is None or lat < best[0]:
                best = (lat, bm, bk)
    if best is None:
        return (_LANE, _LANE)
    return best[1], best[2]


@functools.lru_cache(maxsize=256)
def ssd_chunk_len(s: int, p: int, n: int) -> int:
    """Chunk length for the SSD kernel via the COMET ssd_chunk compound op.

    Larger chunks amortize the state GEMMs but grow the (c, c) intra-chunk
    matrix quadratically; COMET's cost model finds the knee.
    """
    arch = tpu_v5e()
    best = None
    u = arch.gemm_unit
    for c in (128, 256, 512):
        if c > max(s, _LANE):
            continue
        vmem = (c * p * 2 * 2 + 2 * c * n * 2 + c * c * 4 + n * p * 4)
        if vmem * 2 > VMEM_BUDGET:
            continue
        # per-chunk: 3 GEMM tiles + decay SIMD; n_chunks = s/c
        g = (systolic_gemm_cycles(c, c, n, u.array_rows, u.array_cols, u.num_arrays)
             + systolic_gemm_cycles(c, p, c, u.array_rows, u.array_cols, u.num_arrays)
             + systolic_gemm_cycles(n, p, c, u.array_rows, u.array_cols, u.num_arrays)
             ) / u.freq_hz
        simd = (3 * c * c + 2 * c * p) / arch.simd_unit.peak_ops_per_sec
        mem = (c * p * 2 * 2 + 2 * c * n * 2) / arch.gb.bandwidth
        lat = math.ceil(max(s, 1) / c) * max(g + simd, mem)
        if best is None or lat < best[0]:
            best = (lat, c)
    return 128 if best is None else best[1]
