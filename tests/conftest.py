"""Shared test fixtures.

The plan store (repro.core.plan) defaults to ``~/.cache/repro-plans``;
tests must never leak files there, so the whole session is pointed at a
throwaway directory unless the environment already pins one (the CI
workflow sets ``REPRO_PLAN_CACHE`` explicitly and asserts nothing lands
outside it).
"""
import os
import tempfile

import pytest


@pytest.fixture(scope="session", autouse=True)
def _plan_cache_tmpdir():
    if os.environ.get("REPRO_PLAN_CACHE"):
        yield
        return
    with tempfile.TemporaryDirectory(prefix="repro-plans-test-") as d:
        os.environ["REPRO_PLAN_CACHE"] = d
        try:
            yield
        finally:
            os.environ.pop("REPRO_PLAN_CACHE", None)
