"""Multi-device tests: run in a subprocess with 8 virtual CPU devices
(XLA_FLAGS must be set before jax initializes, hence the subprocess).

Triage note: the suite failed at seed because the kernel/model stack it
exercises could not import against newer pltpu APIs; the PR 1 compat shim
fixed that and the suite passes under the sandbox now.  Environments that
cannot run it at all (no jax, or subprocess spawning disabled) skip with
an explicit reason instead of erroring; genuine assertion failures inside
the subprocess still fail the test.  CI runs this under the non-blocking
``slow-suite`` job so regressions stay visible without gating merges.
"""
import os
import subprocess
import sys
import textwrap

import pytest

pytest.importorskip("jax", reason="distributed suite needs jax")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_auto_mesh
    mesh = make_auto_mesh((2, 4), ("data", "model"))
    assert len(jax.devices()) == 8

    # ---- 1. sharded train step on the mesh, GSPMD loss
    from repro.configs import get_smoke_config
    from repro.models import Model
    from repro.parallel.sharding import (batch_sharding, param_shardings,
                                         zero1_shardings)
    from repro.train.optimizer import OptConfig, init_opt_state
    from repro.train.train_step import TrainState, make_train_step
    from repro.train.data import SyntheticLM

    cfg = get_smoke_config("qwen3-moe-30b-a3b").with_(d_model=64, n_experts=8)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ax, ab = model.param_axes(), model.abstract_params()
    psh = param_shardings(ax, ab, mesh)
    zsh = zero1_shardings(ax, ab, mesh)
    params = jax.device_put(params, psh)
    opt = init_opt_state(params)
    opt = opt._replace(m=jax.device_put(opt.m, zsh),
                       v=jax.device_put(opt.v, zsh))
    state = TrainState(params, opt)
    data = SyntheticLM(cfg.vocab_size, 32, 8, seed=0)
    step = jax.jit(make_train_step(model, OptConfig(lr=1e-3, total_steps=5),
                                   mesh), donate_argnums=(0,))
    losses = []
    for i in range(5):
        b = {k: jax.device_put(jnp.asarray(v),
                               batch_sharding(mesh, 8, v.ndim))
             for k, v in data.batch(i).items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses)), losses
    print("MESH_TRAIN_OK", losses[0], losses[-1])

    # ---- 2. planner loss: dist == gather == unsharded reference
    from repro.parallel.collective_planner import sharded_softmax_xent
    from repro.models.layers import cross_entropy_loss
    B, S, D, V = 4, 8, 32, 64
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    W = jnp.asarray(rng.normal(size=(D, V)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 50, size=(B, S)), jnp.int32)
    ref = cross_entropy_loss((h @ W)[None][0], y, 50)
    ld = jax.jit(lambda *a: sharded_softmax_xent(*a, mesh, real_vocab=50,
                                                 strategy="dist"))(h, W, y)
    lg = jax.jit(lambda *a: sharded_softmax_xent(*a, mesh, real_vocab=50,
                                                 strategy="gather"))(h, W, y)
    np.testing.assert_allclose(float(ld), float(ref), rtol=1e-5)
    np.testing.assert_allclose(float(lg), float(ref), rtol=1e-5)
    print("PLANNER_LOSS_OK", float(ld), float(lg), float(ref))

    # ---- 3. MoE shard_map == no-mesh reference
    from repro.models.moe import moe_apply
    x = jnp.asarray(rng.normal(size=(8, 16, cfg.d_model)) * 0.1, jnp.float32)
    lp = jax.tree.map(lambda a: a[0], jax.device_get(state.params["layers"]["moe"]))
    lp = jax.tree.map(jnp.asarray, lp)
    y_ref = moe_apply(cfg, lp, x.astype(jnp.bfloat16))
    xs = jax.device_put(x.astype(jnp.bfloat16),
                        NamedSharding(mesh, P("data", None, None)))
    lps = {k: jax.device_put(v, NamedSharding(mesh, P("model") if k in
           ("wi", "wg", "wo") else P())) for k, v in lp.items()}
    y_mesh = jax.jit(lambda p, xx: moe_apply(cfg, p, xx, mesh=mesh))(lps, xs)
    err = float(jnp.abs(y_mesh.astype(jnp.float32)
                        - y_ref.astype(jnp.float32)).max())
    assert err < 0.1, err   # capacity drop differences only
    print("MOE_SHARD_OK", err)

    # ---- 4. elastic remesh: 2x4 -> 1x4 (lost a data replica)
    from repro.train.elastic import remesh, shrink_mesh
    small = shrink_mesh(failed_devices=4, model_parallel=4)
    psh_small = param_shardings(ax, ab, small)
    p_small = remesh(jax.device_get(state.params), psh_small)
    n_before = sum(x.size for x in jax.tree.leaves(state.params))
    n_after = sum(x.size for x in jax.tree.leaves(p_small))
    assert n_before == n_after
    print("ELASTIC_OK", small.devices.shape)

    # ---- 5. compressed psum over pod axis
    from repro.parallel.compression import compressed_psum
    g = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    out = compressed_psum(g, mesh, "data")
    # int8 quantization error <= absmax/127 per replica
    tol = 2.5 * float(jnp.abs(g).max()) / 127.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(g) * 2, atol=tol)
    print("COMPRESSED_PSUM_OK")

    # ---- 6. checkpoint saved on mesh restores onto the smaller mesh
    from repro.train.checkpoint import save_checkpoint, restore_checkpoint
    import tempfile
    d = tempfile.mkdtemp()
    save_checkpoint(d, 1, state.params)
    restored, _, _ = restore_checkpoint(d, state.params, shardings=psh_small)
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("ELASTIC_RESTORE_OK")
    print("ALL_DISTRIBUTED_OK")
""")


@pytest.mark.slow
def test_distributed_suite():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    try:
        r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                           capture_output=True, text=True, timeout=1200)
    except (OSError, PermissionError) as e:
        pytest.skip(f"sandbox cannot spawn the 8-device subprocess: {e!r}")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "ALL_DISTRIBUTED_OK" in r.stdout
