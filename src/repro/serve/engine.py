"""Batched serving engine: prefill + greedy decode with fixed-shape jitted
steps and slot-based continuous batching (finished sequences are replaced
from the request queue without recompiling — the decode step shape never
changes)."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..models.model import Model

__all__ = ["Request", "ServeEngine"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Fixed batch of decode slots; requests stream through them."""

    def __init__(self, model: Model, params, *, batch_size: int,
                 cache_len: int, prompt_len: int,
                 mesh: Optional[Mesh] = None):
        self.model = model
        self.params = params
        self.mesh = mesh
        self.B = batch_size
        self.cache_len = cache_len
        self.prompt_len = prompt_len
        cfg = model.cfg

        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len, mesh))
        self._decode = jax.jit(
            lambda p, c, t: model.decode(p, c, t, mesh),
            donate_argnums=(1,))
        self.stats: Dict[str, float] = {"prefill_calls": 0, "decode_steps": 0,
                                        "tokens_out": 0}

    # ------------------------------------------------------------- serving
    def _pad_prompts(self, reqs: Sequence[Request]) -> np.ndarray:
        toks = np.zeros((self.B, self.prompt_len), np.int32)
        for i, r in enumerate(reqs):
            t = r.prompt[-self.prompt_len:]
            toks[i, -len(t):] = t          # right-aligned
        return toks

    def run(self, requests: List[Request], *, max_steps: int = 10_000
            ) -> List[Request]:
        """Process all requests with continuous slot reuse."""
        queue = list(requests)
        active: List[Optional[Request]] = [None] * self.B

        def refill() -> bool:
            changed = False
            for i in range(self.B):
                if active[i] is None and queue:
                    active[i] = queue.pop(0)
                    changed = True
            return changed

        refill()
        batch = {"tokens": jnp.asarray(self._pad_prompts(
            [r for r in active if r] + []))}
        if self.model.cfg.is_encdec:
            Se = max(1, self.prompt_len // self.model.cfg.enc_ratio)
            batch["src_embeds"] = jnp.zeros((self.B, Se, self.model.cfg.d_model),
                                            jnp.float32)
        logits, cache = self._prefill(self.params, batch)
        self.stats["prefill_calls"] += 1
        last = jnp.argmax(logits[:, -1, :self.model.cfg.vocab_size], -1)

        for step in range(max_steps):
            if all(r is None or r.done for r in active) and not queue:
                break
            tok = last[:, None].astype(jnp.int32)
            logits, cache = self._decode(self.params, cache, tok)
            self.stats["decode_steps"] += 1
            last = jnp.argmax(logits[:, -1, :self.model.cfg.vocab_size], -1)
            host = np.asarray(last)
            for i, r in enumerate(active):
                if r is None or r.done:
                    continue
                r.output.append(int(host[i]))
                self.stats["tokens_out"] += 1
                if len(r.output) >= r.max_new_tokens or \
                        (r.eos_id is not None and host[i] == r.eos_id):
                    r.done = True
                    active[i] = None       # slot freed (continuous batching)
            refill()
        done = [r for r in requests]
        return done
