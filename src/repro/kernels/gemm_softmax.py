"""Fused GEMM→Softmax Pallas kernel (the paper's GEMM-SM compound op,
Fused-GEMM-distSM dataflow adapted to one TPU core).

C = softmax(A @ B, axis=-1).  The K contraction streams through VMEM in
block_k tiles accumulating into a VMEM f32 scratch (the OB-level K loop of
the COMET mapping); the softmax epilogue runs on the VPU at the final K
step while the full N row is still VMEM-resident — the intermediate C
tensor never touches HBM, which is precisely the fusion the paper costs.

Requires block_m * N * 4B to fit VMEM (validated by autotune).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["gemm_softmax"]


def _kernel(a_ref, b_ref, o_ref, acc):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    acc[...] += jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _epilogue():
        c = acc[...]
        m = jnp.max(c, axis=1, keepdims=True)          # Op3 rowmax
        e = jnp.exp(c - m)                             # Op4/Op5 sub+exp
        s = jnp.sum(e, axis=1, keepdims=True)          # Op6 rowsum
        o_ref[...] = (e / s).astype(o_ref.dtype)       # Op7 div


def gemm_softmax(a: jax.Array, b: jax.Array, *,
                 block_m: Optional[int] = None,
                 block_k: Optional[int] = None,
                 interpret: Optional[bool] = None) -> jax.Array:
    """softmax(a @ b, axis=-1); a: (M, K), b: (K, N)."""
    from .autotune import gemm_epilogue_blocks

    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bm_d, bk_d = gemm_epilogue_blocks(M, N, K)
    block_m = min(block_m or bm_d, M)
    block_k = min(block_k or bk_d, K)

    pm = (-M) % block_m
    pk = (-K) % block_k
    ap = jnp.pad(a, ((0, pm), (0, pk))) if (pm or pk) else a
    bp = jnp.pad(b, ((0, pk), (0, 0))) if pk else b
    Mp, Kp = M + pm, K + pk

    out = pl.pallas_call(
        _kernel,
        grid=(Mp // block_m, Kp // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ki: (mi, ki)),
            pl.BlockSpec((block_k, N), lambda mi, ki: (ki, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, N), lambda mi, ki: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(ap, bp)
    return out[:M] if pm else out
