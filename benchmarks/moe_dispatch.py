"""MoE expert-parallel dispatch strategies, costed with COMET's collective
model (the AllToAll entry of Fig. 1(b)).

Two EP designs for (tokens T over dp axis, E experts over the 16-way model
axis, top-k routing), per layer:

* **replicated-EP** (what the framework ships, models/moe.py): activations
  are already replicated over `model`; each shard gathers its experts'
  tokens locally and the combine is one AllReduce of the (T_local, d)
  output over `model`.  Collective volume per layer: AR(T_l·d).
* **a2a-EP** (classic GShard/DeepSpeed): tokens sequence-sharded over
  `model`; dispatch AllToAll (T_l/16·k copies out), expert compute,
  combine AllToAll back.  Volume: 2·A2A(T_l·k/16·d) — but the residual
  stream must also be resharded (AG per layer) unless the whole block is
  sequence-parallel.

The crossover depends on top-k and d — exactly the kind of mapping
decision COMET's explicit representation makes costable before committing
an implementation.  Printed per assigned MoE arch at train_4k scale.
"""
from __future__ import annotations

from typing import Dict

from repro.core.collectives import collective_cost, noc_latency
from repro.core.hardware import tpu_v5e


def _lat(col: str, dv: float, P: int, noc) -> float:
    cc = collective_cost(col, dv, P, noc)
    return cc.volume_bytes / noc.channel_bandwidth + noc_latency(cc, noc)


def run_all() -> Dict:
    arch = tpu_v5e()
    noc = arch.cluster_noc
    P = 16                                  # model axis
    out = {}
    cases = [
        ("deepseek-v3-671b", 7168, 8, 65536),   # d, top_k, T_local(dp=16)
        ("qwen3-moe-30b-a3b", 2048, 8, 65536),
    ]
    for name, d, k, t_l in cases:
        rep = _lat("AllReduce", t_l * d * 2, P, noc)
        a2a = (2 * _lat("AllToAll", (t_l // P) * k * d * 2, P, noc)
               + _lat("AllGather", t_l * d * 2, P, noc))
        best = "replicated-EP" if rep <= a2a else "a2a-EP"
        print(f"moe_dispatch_{name},{rep*1e6:.0f},"
              f"replicated_AR={rep*1e3:.2f}ms;a2a={a2a*1e3:.2f}ms;"
              f"per_layer_best={best}")
        out[name] = {"replicated_ms": rep * 1e3, "a2a_ms": a2a * 1e3,
                     "best": best}
    return out


if __name__ == "__main__":
    run_all()
