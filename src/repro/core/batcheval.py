"""Vectorized batch map-space evaluation engine (DFModel-style factoring).

The mapping space of Fig. 1 factors into

* a **topology** — the discrete shape of the mapping tree: fusion variant
  x collective granularity x GB loop order.  A compound op has only a
  handful of topologies, and the tree structure (nodes, labels, tensors,
  collectives) is fully determined by the topology; and
* **grid axes** — the m/k/n temporal tile counts, the ``sp_cluster``/
  ``sp_core`` spatial unrolling fanouts and the ``schedule`` choice.
  Tile counts and fanouts only change Loop factors, tile sizes, collective
  participants and data volumes; the schedule enters Eqs. 5-7 as a
  mask-select (True = pipelined) rather than a separate tree build, which
  halves the topology count per space.

Exploiting that, one topology's entire grid is evaluated in a single
structure-of-arrays pass: ``build_tree`` is called once with NumPy int
arrays for the tiling/fanout parameters (plus the schedule mask), and the
unchanged Eq. 1-7 formulas in :mod:`.cost`, :mod:`.collectives` and
:mod:`.validate` broadcast through the tree.  Results are bit-identical to
the per-spec path (same code, same formulas) at a fraction of the
per-mapping Python overhead.  ``track_breakdown=True`` additionally
carries the per-key latency/energy breakdown dicts through the same SoA
pass (used by the benchmark breakdown figures — no scalar tree walk).

:meth:`BatchResult.pareto_front` extracts the latency/energy Pareto front
of a grid as a vectorized skyline (argsort + running min), and
``objective='pareto'`` in :func:`repro.core.search.search` merges the
per-topology fronts into a global front.  Every batch also carries a
**capacity-headroom** channel (worst relative buffer slack, see
:func:`repro.core.validate.validity_and_headroom`);
:meth:`BatchResult.pareto_front3` filters the 3-D
latency/energy/headroom front (minimize the first two, maximize the
third) for provisioning studies (``objective='pareto3'``), and
:class:`ParetoArchive` is the bounded online non-dominated archive the
randomized search fallback uses for both front objectives.

Two LRU caches sit on top:

* a **grid cache** keyed on (compound-op signature, ``Arch.signature()``,
  topology, candidate axes) holding whole :class:`BatchResult` arrays, and
* a **spec cache** keyed on (compound-op signature, ``Arch.signature()``,
  spec) holding lightweight (latency, energy, valid, headroom) tuples for
  the randomized fallback path.

Cache keys use the *full architecture parameter signature*
(:meth:`repro.core.hardware.Arch.signature`), never ``arch.name`` alone:
two Arch instances sharing a name but differing in bandwidth/capacity
must not reuse each other's results.  Both caches are shared across
searches (see :func:`repro.core.search.search` and ``search_many``).

**Executor contract.**  The caches and every evaluation entry point here
are executor-agnostic: ``search_many`` may run them in the calling
thread (``'serial'``), in a thread pool sharing this module's caches
(``'thread'``), or in process-pool workers that each hold their own
module-level cache instance (``'process'``) — the numbers are
bit-identical either way because the same code evaluates the same grids.
For the process path, :func:`batch_to_shm` serializes a
:class:`BatchResult`'s arrays into one ``multiprocessing.shared_memory``
segment and returns a tiny picklable :class:`ShmBatchRef`;
:func:`batch_from_shm` reattaches the arrays zero-copy in the parent.
Segments are created by workers and unlinked by the consumer, with
``repro.core.search.cleanup_shm_segments`` as the crash backstop — see
the lifecycle notes on :class:`ShmBatchRef`.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cost import ENERGY_KEYS, LAT_KEYS, CostModel
from .hardware import Arch
from .ir import MappingSpec, build_tree
from .mapping import SCHEDULES
from .validate import validity_headroom_levels
from .workload import CompoundOp

__all__ = [
    "Topology",
    "BatchResult",
    "ParetoArchive",
    "ShmBatchRef",
    "batch_to_shm",
    "batch_from_shm",
    "shm_unlink",
    "co_signature",
    "numeric_axes",
    "enumerate_topologies",
    "evaluate_specs_batch",
    "evaluate_topology_grid",
    "evaluate_cached",
    "pareto_merge",
    "pareto_merge3",
    "cache_info",
    "cache_clear",
]

GEMM_EPILOGUE_COS = ("gemm", "gemm_softmax", "gemm_layernorm")
ATTENTION_COS = ("attention", "flash_attention")

OBJECTIVES = ("latency", "energy", "edp", "pareto", "pareto3")


@dataclass(frozen=True)
class Topology:
    """The discrete (non-numeric) part of a MappingSpec.

    ``schedule`` is retained for API compatibility (explicit
    ``evaluate_specs_batch`` callers may pin it) but is no longer a
    topology axis: grids enumerate it via the schedule mask instead.
    """

    variant: str
    schedule: str = "sequential"
    collective_gran: str = "tile"
    loop_order_gb: Tuple[str, ...] = ("M", "N")

    def spec(self, m_tiles: int = 1, k_tiles: int = 1, n_tiles: int = 1,
             sp_cluster: int = 0, sp_core: int = 0,
             schedule: Optional[str] = None,
             overlap: float = 0.0) -> MappingSpec:
        return MappingSpec(
            variant=self.variant, m_tiles=m_tiles, k_tiles=k_tiles,
            n_tiles=n_tiles, sp_cluster=sp_cluster, sp_core=sp_core,
            schedule=self.schedule if schedule is None else schedule,
            collective_gran=self.collective_gran,
            loop_order_gb=self.loop_order_gb, overlap=overlap)


@dataclass
class BatchResult:
    """Structure-of-arrays result of one topology's grid."""

    topo: Topology
    m_tiles: np.ndarray
    k_tiles: np.ndarray
    n_tiles: np.ndarray
    sp_cluster: np.ndarray
    sp_core: np.ndarray
    schedule: np.ndarray            # per-point schedule names (str array)
    overlap: np.ndarray             # per-point compute–collective overlap
    latency: np.ndarray
    energy_pj: np.ndarray
    valid: np.ndarray
    # Worst relative buffer slack per grid point (the 'pareto3' channel);
    # negative where some buffer overflows.
    headroom: Optional[np.ndarray] = None
    # Per-level slack arrays ({'GB': ..., 'OB': ...}, same shape):
    # ``headroom`` folded per memory level instead of across all levels,
    # so provisioning studies can size the cluster (GB) and core (OB =
    # IB+WB+OB) buffers independently.  None for rejected topologies.
    headroom_levels: Optional[Dict[str, np.ndarray]] = None
    # Per-key breakdown arrays (same shape), present only when the batch
    # was evaluated with track_breakdown=True.
    lat_breakdown: Optional[Dict[str, np.ndarray]] = None
    energy_breakdown: Optional[Dict[str, np.ndarray]] = None

    @property
    def size(self) -> int:
        return int(self.latency.shape[0])

    def scores(self, objective: str = "latency") -> np.ndarray:
        """Objective value per grid point; +inf where invalid."""
        if objective == "latency":
            s = self.latency
        elif objective == "energy":
            s = self.energy_pj
        elif objective == "edp":
            s = self.latency * self.energy_pj
        else:
            raise ValueError(f"unknown scalar objective {objective!r}")
        return np.where(self.valid, s, np.inf)

    def best_index(self, objective: str = "latency") -> Optional[int]:
        if self.size == 0 or not bool(self.valid.any()):
            return None
        return int(np.argmin(self.scores(objective)))

    def pareto_front(self) -> np.ndarray:
        """Indices of the non-dominated (latency, energy) points among the
        valid grid entries, in ascending-latency order.

        Vectorized 2-D skyline: lexsort by (latency, energy), then a point
        survives iff its energy is strictly below the running minimum of
        all points with better-or-equal latency (weakly dominated points
        and duplicates are dropped).
        """
        idx = np.flatnonzero(self.valid)
        if idx.size == 0:
            return idx
        lat = self.latency[idx]
        en = self.energy_pj[idx]
        order = np.lexsort((en, lat))
        en_s = en[order]
        cummin = np.minimum.accumulate(en_s)
        keep = np.ones(order.size, dtype=bool)
        keep[1:] = en_s[1:] < cummin[:-1]
        return idx[order[keep]]

    def pareto_front3(self) -> np.ndarray:
        """Indices of the non-dominated (latency, energy, headroom) points
        among the valid grid entries — latency/energy minimized, headroom
        maximized — in ascending-latency order.  Weakly dominated points
        and duplicates are dropped, matching :meth:`pareto_front`."""
        if self.headroom is None:
            raise ValueError("batch evaluated without a headroom channel")
        idx = np.flatnonzero(self.valid)
        if idx.size == 0:
            return idx
        keep = _pareto3_sorted_indices(self.latency[idx], self.energy_pj[idx],
                                       -self.headroom[idx])
        return idx[keep]

    def spec_at(self, i: int) -> MappingSpec:
        return self.topo.spec(
            int(self.m_tiles[i]), int(self.k_tiles[i]), int(self.n_tiles[i]),
            sp_cluster=int(self.sp_cluster[i]), sp_core=int(self.sp_core[i]),
            schedule=str(self.schedule[i]), overlap=float(self.overlap[i]))

    def _breakdown_at(self, bd: Dict[str, np.ndarray], i: int) -> Dict[str, float]:
        return {k: float(np.broadcast_to(np.asarray(v, dtype=np.float64),
                                         self.latency.shape)[i])
                for k, v in bd.items()}

    def lat_breakdown_at(self, i: int) -> Dict[str, float]:
        if self.lat_breakdown is None:
            raise ValueError("batch evaluated without track_breakdown")
        return self._breakdown_at(self.lat_breakdown, i)

    def energy_breakdown_at(self, i: int) -> Dict[str, float]:
        if self.energy_breakdown is None:
            raise ValueError("batch evaluated without track_breakdown")
        return self._breakdown_at(self.energy_breakdown, i)


def pareto_merge(points: Sequence[Tuple]) -> List[Tuple]:
    """Skyline of ``(latency, energy, *payload)`` tuples: the merged
    latency/energy Pareto front across several :class:`BatchResult` fronts
    (ascending latency, strictly descending energy)."""
    best_en = np.inf
    out: List[Tuple] = []
    for p in sorted(points, key=lambda p: (p[0], p[1])):
        if p[1] < best_en:  # scalar-ok: merged points are float tuples
            out.append(p)
            best_en = p[1]
    return out


def _pareto3_sorted_indices(a: np.ndarray, b: np.ndarray,
                            c: np.ndarray) -> np.ndarray:
    """Indices of the non-dominated points of the all-minimized (a, b, c)
    triples, in lexicographic (a, b, c) order.

    Lexsort makes every earlier point <= the current one in ``a``, so a
    point is dominated iff some kept point has b <= and c <= (weak
    dominance — duplicates are dropped too, as in the 2-D skyline); the
    membership test against the kept staircase is a vectorized NumPy
    reduction per point.  Kept points are final: a later point can never
    dominate an earlier one under the lex order.
    """
    order = np.lexsort((c, b, a))
    n = order.size
    kb = np.empty(n)
    kc = np.empty(n)
    m = 0
    kept: List[int] = []
    for j in order:
        if m and bool(np.any((kb[:m] <= b[j]) & (kc[:m] <= c[j]))):
            continue
        kb[m] = b[j]
        kc[m] = c[j]
        m += 1
        kept.append(int(j))
    return np.asarray(kept, dtype=np.int64)


def pareto_merge3(points: Sequence[Tuple]) -> List[Tuple]:
    """Non-dominated subset of ``(latency, energy, headroom, *payload)``
    tuples — latency/energy minimized, headroom maximized — in
    ascending-latency order: the merged 3-D front across several
    :class:`BatchResult` fronts."""
    if not points:
        return []
    a = np.asarray([p[0] for p in points], dtype=np.float64)
    b = np.asarray([p[1] for p in points], dtype=np.float64)
    c = np.asarray([-p[2] for p in points], dtype=np.float64)
    return [points[j] for j in _pareto3_sorted_indices(a, b, c)]


def _crowding_distances(keys: np.ndarray) -> np.ndarray:
    """NSGA-II crowding distance per point of an (n, dims) objective
    matrix (all objectives minimized): for each objective, the span-
    normalized gap between a point's two neighbours in that objective's
    ordering, summed over objectives.  Per-objective extreme points get
    +inf so boundary points are never pruned; a degenerate objective
    (zero span) contributes nothing."""
    n, dims = keys.shape
    dist = np.zeros(n)
    for j in range(dims):
        order = np.argsort(keys[:, j], kind="stable")
        col = keys[order, j]
        span = float(col[-1] - col[0])
        dist[order[0]] = np.inf
        dist[order[-1]] = np.inf
        if span > 0.0 and n > 2:  # scalar-ok: span is float(), n is int
            dist[order[1:-1]] += (col[2:] - col[:-2]) / span
    return dist


class ParetoArchive:
    """Bounded online non-dominated archive (ROADMAP: the randomized
    multi-objective fallback must not hold every valid sample once budgets
    grow past ~10k).

    Points are ``(latency, energy, *payload)`` for ``dims=2`` or
    ``(latency, energy, headroom, *payload)`` for ``dims=3``
    (latency/energy minimized, headroom maximized).  ``add`` rejects
    points weakly dominated by the archive and evicts points the newcomer
    dominates, so the archive is mutually non-dominated at all times.
    When it outgrows ``maxlen`` it is thinned by **crowding-distance
    pruning** (NSGA-II style): the per-objective extreme points always
    survive and the most-crowded interior points — the ones whose
    neighbours along every objective sit closest — are dropped first, so
    a dense cluster loses points before a sparse stretch of the front
    does.  (The previous every-other-point decimation kept clusters dense
    and halved sparse regions instead.)  Thinning bounds memory at the
    cost of front *fidelity*: once points have been evicted, a later
    sample that only an evicted point dominated can be re-admitted, so
    the final front is an approximation of the true front over all
    evaluated samples — though always mutually non-dominated.
    """

    def __init__(self, dims: int = 2, maxlen: int = 512):
        if dims not in (2, 3):
            raise ValueError(f"dims must be 2 or 3, got {dims}")
        if maxlen < 2:  # scalar-ok: constructor int arg
            raise ValueError(f"maxlen must be >= 2, got {maxlen}")
        self.dims = dims
        self.maxlen = maxlen
        self._points: List[Tuple] = []

    def __len__(self) -> int:
        return len(self._points)

    def _key(self, p: Tuple) -> Tuple[float, ...]:
        # all-minimized objective vector
        if self.dims == 2:  # scalar-ok: dims validated to 2 or 3
            return (p[0], p[1])
        return (p[0], p[1], -p[2])

    def add(self, point: Tuple) -> bool:
        """Insert ``point``; True iff it joined the archive (i.e. it is
        not weakly dominated by a current member)."""
        k = self._key(point)
        keep: List[Tuple] = []
        for q in self._points:
            qk = self._key(q)
            if all(a <= b for a, b in zip(qk, k)):
                return False                    # dominated (or duplicate)
            if not all(a <= b for a, b in zip(k, qk)):
                keep.append(q)                  # q survives the newcomer
        keep.append(point)
        self._points = keep
        if len(self._points) > self.maxlen:
            self._thin()
        return True

    def _thin(self) -> None:
        """Crowding-distance pruning down to ``maxlen // 2`` points (the
        same amortization ratio as the old decimation, so ``add`` still
        thins at most once per ~maxlen/2 insertions).  Points are removed
        one at a time — always a currently lowest-crowding interior point
        — and distances are recomputed after each removal, so pruning one
        of two tight neighbours immediately un-crowds the other."""
        pts = sorted(self._points, key=self._key)
        target = max(2, self.maxlen // 2)  # scalar-ok: ints
        keys = np.asarray([self._key(p) for p in pts], dtype=np.float64)
        alive = list(range(len(pts)))
        while len(alive) > target:
            d = _crowding_distances(keys[alive])
            if np.isfinite(d).any():
                alive.pop(int(np.argmin(d)))
            else:
                # every survivor is extreme in some objective — drop from
                # the middle rather than eat into a front endpoint
                alive.pop(len(alive) // 2)
        self._points = [pts[i] for i in alive]

    def front(self) -> List[Tuple]:
        """The archived non-dominated points in ascending-latency order."""
        return sorted(self._points, key=self._key)


# ------------------------------------------------- shared-memory transport

# BatchResult array fields shipped through a segment, in declaration order.
# Dict-valued channels (headroom_levels / breakdowns) are flattened to
# dotted keys ("hl.GB", "lb.gemm", "eb.dram", ...).
_SHM_FIELDS = ("m_tiles", "k_tiles", "n_tiles", "sp_cluster", "sp_core",
               "schedule", "overlap", "latency", "energy_pj", "valid",
               "headroom")
_SHM_ALIGN = 64      # cache-line alignment for each array's offset


@dataclass(frozen=True)
class ShmBatchRef:
    """Picklable reference to a :class:`BatchResult` serialized into one
    ``multiprocessing.shared_memory`` segment.

    The ref itself is tiny (segment name, topology, and per-array
    (key, offset, dtype, shape) descriptors): it crosses the process
    boundary through the ordinary pickle channel while the grid arrays
    stay in the segment, so the parent reattaches them **zero-copy** with
    :func:`batch_from_shm` instead of unpickling megabytes per result.

    Lifecycle contract: the creating process (a pool worker) writes the
    arrays, closes its mapping and returns the ref; the consuming process
    (the sweep parent) attaches, reduces, then **unlinks** the segment.
    Create-in-worker / unlink-in-parent is tracker-clean on every
    multiprocessing start method: pool workers inherit the parent's
    resource-tracker fd (``multiprocessing.spawn`` passes ``tracker_fd``
    in the preparation data, fork inherits it outright), so register and
    unregister land in the same tracker and no "leaked shared_memory"
    warning fires at pool shutdown.  A segment whose ref is lost (worker
    crash mid-job) is reclaimed by the sweep driver's prefix sweep — see
    ``repro.core.search.cleanup_shm_segments``.
    """

    shm_name: str
    nbytes: int
    topo: Topology
    # (key, byte offset, numpy dtype str, shape) per serialized array
    arrays: Tuple[Tuple[str, int, str, Tuple[int, ...]], ...]


def _shm_group(arrs: Dict[str, np.ndarray], tag: str
               ) -> Optional[Dict[str, np.ndarray]]:
    d = {k.split(".", 1)[1]: v for k, v in arrs.items()
         if k.startswith(tag + ".")}
    return d or None


def batch_to_shm(br: BatchResult, *, prefix: str = "cmbatch") -> ShmBatchRef:
    """Serialize ``br``'s arrays into a fresh shared-memory segment named
    ``{prefix}_{random}`` and return the picklable :class:`ShmBatchRef`.
    The caller's process keeps no mapping open; the segment lives until
    the consumer unlinks it (or a prefix sweep reclaims it).

    Keep ``prefix`` short: POSIX shm names are capped at 31 chars
    **including** the leading slash on macOS (PSHMNAMLEN), and this
    function appends ``_`` + 8 hex chars — so prefixes up to ~21 chars
    are portable.  Name collisions (8 hex chars of randomness) are
    retried with a fresh suffix."""
    import secrets
    from multiprocessing import shared_memory

    items: List[Tuple[str, np.ndarray]] = []
    for f in _SHM_FIELDS:
        a = getattr(br, f)
        if a is not None:
            items.append((f, np.ascontiguousarray(a)))
    for tag, d in (("hl", br.headroom_levels), ("lb", br.lat_breakdown),
                   ("eb", br.energy_breakdown)):
        if d:
            for k in sorted(d):
                items.append((f"{tag}.{k}", np.ascontiguousarray(d[k])))
    metas: List[Tuple[str, int, str, Tuple[int, ...]]] = []
    off = 0
    for key, a in items:
        off = -(-off // _SHM_ALIGN) * _SHM_ALIGN
        metas.append((key, off, a.dtype.str, tuple(a.shape)))
        off += a.nbytes
    total = max(off, 1)  # scalar-ok: byte offsets are ints
    for _attempt in range(8):
        try:
            shm = shared_memory.SharedMemory(
                name=f"{prefix}_{secrets.token_hex(4)}", create=True,
                size=total)
            break
        except FileExistsError:
            continue
    else:
        raise FileExistsError(
            f"could not allocate a fresh shm name under prefix {prefix!r}")
    try:
        for (_key_m, o, _dt, shape), (_key, a) in zip(metas, items):
            dst = np.ndarray(shape, dtype=a.dtype, buffer=shm.buf, offset=o)
            dst[...] = a
            del dst             # release the buffer export before close()
        ref = ShmBatchRef(shm.name, total, br.topo, tuple(metas))
    except BaseException:
        shm.close()
        shm.unlink()
        raise
    shm.close()
    return ref


def batch_from_shm(ref: ShmBatchRef):
    """Reattach a :class:`BatchResult` from ``ref``'s segment.

    Returns ``(batch, shm)``: the batch's arrays are zero-copy views over
    the segment, so ``shm`` (the ``SharedMemory`` handle) must stay alive
    while the batch is in use, and the caller is responsible for
    ``shm.unlink()`` exactly once when done (drop the batch's arrays
    before ``shm.close()``, or skip close and let refcounting reclaim the
    mapping)."""
    from multiprocessing import shared_memory

    shm = shared_memory.SharedMemory(name=ref.shm_name)
    arrs = {key: np.ndarray(shape, dtype=np.dtype(dt), buffer=shm.buf,
                            offset=off)
            for key, off, dt, shape in ref.arrays}
    br = BatchResult(
        ref.topo, arrs["m_tiles"], arrs["k_tiles"], arrs["n_tiles"],
        arrs["sp_cluster"], arrs["sp_core"], arrs["schedule"],
        arrs["overlap"], arrs["latency"], arrs["energy_pj"], arrs["valid"],
        headroom=arrs.get("headroom"),
        headroom_levels=_shm_group(arrs, "hl"),
        lat_breakdown=_shm_group(arrs, "lb"),
        energy_breakdown=_shm_group(arrs, "eb"))
    return br, shm


def shm_unlink(name: str) -> bool:
    """Unlink segment ``name`` if it still exists; True iff it did.
    Tolerates already-unlinked segments (idempotent cleanup)."""
    from multiprocessing import shared_memory

    try:
        shm = shared_memory.SharedMemory(name=name)
    except FileNotFoundError:
        return False
    shm.unlink()
    shm.close()
    return True


# ------------------------------------------------------------- signatures


def co_signature(co: CompoundOp) -> Tuple:
    """Hashable identity of a compound op for cache keying: name, dims and
    tensor layouts (ops are derived from the builder, so name+dims+tensors
    pin the workload)."""
    return (
        co.name,
        tuple(sorted(co.dim_sizes.items())),
        tuple(sorted((t.name, t.dims, t.dtype_bytes)
                     for t in co.tensors.values())),
    )


NUMERIC_AXES = ("m_tiles", "k_tiles", "n_tiles", "sp_cluster", "sp_core")


def numeric_axes(co: CompoundOp) -> Tuple[str, ...]:
    """Which numeric MappingSpec axes actually reach the tree builder for
    this compound op (the rest are degenerate and pinned).  The spatial
    fanout axes apply to every builder family."""
    if co.name in GEMM_EPILOGUE_COS:
        return ("m_tiles", "k_tiles", "sp_cluster", "sp_core")
    if co.name in ATTENTION_COS:
        return ("m_tiles", "n_tiles", "sp_cluster", "sp_core")
    return ("m_tiles", "sp_cluster", "sp_core")


def topology_fields(co: CompoundOp) -> Tuple[str, ...]:
    """Which discrete MappingSpec fields alter the tree for this compound
    op.  GEMM-epilogue trees ignore the GB loop order; attention trees
    ignore the collective granularity; the generic builder only branches
    on fused-vs-unfused.  ``schedule`` is never a topology field: the
    batched engine folds it into the grid as an Eq. 5-7 mask-select."""
    if co.name in GEMM_EPILOGUE_COS:
        return ("variant", "collective_gran")
    if co.name in ATTENTION_COS:
        return ("variant", "loop_order_gb")
    return ("variant",)


def enumerate_topologies(co: CompoundOp,
                         cands: Dict[str, List]) -> List[Topology]:
    """All distinct topologies for ``co`` given the candidate sets from
    :func:`repro.core.search.candidate_specs`.  Fields that do not alter
    the tree are pinned to their first candidate, so the enumeration has
    no duplicate-cost topologies."""
    fields = topology_fields(co)

    def opts(name: str) -> List:
        return cands[name] if name in fields else cands[name][:1]

    out = []
    for variant in opts("variant"):
        for schedule in opts("schedule"):
            for gran in opts("collective_gran"):
                for lo in opts("loop_order_gb"):
                    out.append(Topology(variant=variant, schedule=schedule,
                                        collective_gran=gran,
                                        loop_order_gb=tuple(lo)))
    return out


# ------------------------------------------------------------- evaluation


def evaluate_specs_batch(co: CompoundOp, arch: Arch, topo: Topology,
                         m_tiles: Sequence[int], k_tiles: Sequence[int],
                         n_tiles: Sequence[int],
                         sp_cluster: Optional[Sequence[int]] = None,
                         sp_core: Optional[Sequence[int]] = None,
                         schedule: Optional[Sequence[str]] = None,
                         overlap: Optional[Sequence[float]] = None, *,
                         track_breakdown: bool = False) -> BatchResult:
    """Evaluate parallel arrays of (m, k, n[, sp_cluster, sp_core,
    schedule, overlap]) grid points for one topology in a single
    vectorized pass.

    ``sp_cluster``/``sp_core`` default to 0 (= full architecture fanout);
    ``schedule`` is a parallel array of schedule *names* defaulting to the
    topology's pinned schedule; ``overlap`` is a parallel array of
    compute–collective overlap factors in [0, 1] defaulting to the scalar
    0.0 (the pre-overlap serial charging, bit-identical by construction).
    With ``track_breakdown=True`` the result carries per-key
    latency/energy breakdown arrays.
    """
    m = np.asarray(m_tiles, dtype=np.int64)
    k = np.asarray(k_tiles, dtype=np.int64)
    n = np.asarray(n_tiles, dtype=np.int64)
    spc = (np.asarray(sp_cluster, dtype=np.int64)
           if sp_cluster is not None else np.asarray(0, dtype=np.int64))
    spo = (np.asarray(sp_core, dtype=np.int64)
           if sp_core is not None else np.asarray(0, dtype=np.int64))
    ov = (np.asarray(overlap, dtype=np.float64)
          if overlap is not None else np.asarray(0.0))
    if overlap is not None and ov.size:
        if float(ov.min()) < 0.0 or float(ov.max()) > 1.0:
            # mirror the scalar range contract of MappingSpec.overlap
            raise ValueError("overlap must lie in [0, 1]")
    if schedule is not None:
        sched_names = np.asarray(schedule)
        bad = set(np.unique(sched_names).tolist()) - set(SCHEDULES)
        if bad:
            # mirror the scalar path, which rejects unknown schedule names
            # at TileNode construction
            raise ValueError(f"bad schedule {sorted(bad)}")
        sched_mask = sched_names != "sequential"
        m, k, n, spc, spo, sched_mask, ov = np.broadcast_arrays(
            m, k, n, spc, spo, sched_mask, ov)
        sched_names = np.broadcast_to(sched_names, m.shape)
        spec_schedule = sched_mask
    else:
        m, k, n, spc, spo, ov = np.broadcast_arrays(m, k, n, spc, spo, ov)
        sched_names = np.broadcast_to(np.asarray(topo.schedule), m.shape)
        spec_schedule = topo.schedule
    shape = m.shape
    # ``overlap=None`` keeps the scalar 0.0 in the spec so the cost model
    # takes its pre-overlap short-circuit; the BatchResult still records
    # the per-point zeros for spec reconstruction.
    spec_overlap = ov if overlap is not None else 0.0
    ov_names = np.broadcast_to(np.asarray(ov, dtype=np.float64), shape)
    spec = MappingSpec(
        variant=topo.variant, m_tiles=m, k_tiles=k, n_tiles=n,
        sp_cluster=spc, sp_core=spo, schedule=spec_schedule,
        collective_gran=topo.collective_gran,
        loop_order_gb=topo.loop_order_gb, overlap=spec_overlap)
    try:
        root, tiling = build_tree(co, arch, spec)
    except (ValueError, KeyError):
        # Whole topology rejected (e.g. unknown variant for this builder):
        # mirror the scalar path, which skips these specs.  Every field
        # and breakdown key gets its OWN zeros array — a single shared
        # buffer would alias them, so an in-place edit of one breakdown
        # entry would silently corrupt every other key plus the
        # latency/energy fields.
        return BatchResult(
            topo, m, k, n, spc, spo, sched_names, ov_names,
            np.zeros(shape), np.zeros(shape), np.zeros(shape, dtype=bool),
            headroom=np.zeros(shape),
            lat_breakdown={k_: np.zeros(shape) for k_ in LAT_KEYS}
            if track_breakdown else None,
            energy_breakdown={k_: np.zeros(shape) for k_ in ENERGY_KEYS}
            if track_breakdown else None)
    ok, hr, levels = validity_headroom_levels(root, arch, tiling, co.tensors)
    valid = np.broadcast_to(ok, shape).copy()
    headroom = np.ascontiguousarray(
        np.broadcast_to(np.asarray(hr, dtype=np.float64), shape))
    # Read-only broadcast views, not copies: the levels unfold the
    # already-materialized folded channel, so charging two extra
    # full-grid arrays per evaluation would be pure waste (batch_to_shm
    # makes them contiguous if and when a grid is serialized).
    headroom_levels = {
        lvl: np.broadcast_to(np.asarray(v, dtype=np.float64), shape)
        for lvl, v in levels.items()}
    cost = CostModel(arch, tiling, co.tensors,
                     track_breakdown=track_breakdown).evaluate(root)
    latency = np.ascontiguousarray(
        np.broadcast_to(np.asarray(cost.latency, dtype=np.float64), shape))
    energy = np.ascontiguousarray(
        np.broadcast_to(np.asarray(cost.energy_pj, dtype=np.float64), shape))
    lat_bd = dict(cost.lat_breakdown) if track_breakdown else None
    en_bd = dict(cost.energy_breakdown) if track_breakdown else None
    return BatchResult(topo, m, k, n, spc, spo, sched_names, ov_names,
                       latency, energy, valid, headroom=headroom,
                       headroom_levels=headroom_levels,
                       lat_breakdown=lat_bd, energy_breakdown=en_bd)


def _grid_arrays(co: CompoundOp, cands: Dict[str, List]) -> Tuple[np.ndarray, ...]:
    """Flattened meshgrid over the numeric axes + the schedule and overlap
    axes: (m, k, n, sp_cluster, sp_core, schedule-names, overlap) parallel
    arrays."""
    axes = numeric_axes(co)
    per_axis = [np.asarray(cands[ax], dtype=np.int64) if ax in axes
                else np.asarray([0 if ax.startswith("sp_") else 1],
                                dtype=np.int64)
                for ax in NUMERIC_AXES]
    per_axis.append(np.asarray(cands["schedule"]))
    per_axis.append(np.asarray(cands.get("overlap", [0.0]),
                               dtype=np.float64))
    mg = np.meshgrid(*per_axis, indexing="ij")
    return tuple(g.reshape(-1) for g in mg)


def grid_size(co: CompoundOp, cands: Dict[str, List]) -> int:
    """Number of grid points per topology for this compound op (numeric
    axes x the schedule x overlap axes).  Missing axes count as pinned
    (PR 1-shaped candidate dicts without sp_*/schedule/overlap keys remain
    accepted)."""
    n = len(cands.get("schedule", ("sequential",)))
    n *= len(cands.get("overlap", (0.0,)))
    for ax in numeric_axes(co):
        n *= len(cands.get(ax, (0,)))
    return n


# ------------------------------------------------------------------ caches


class _LRU:
    """Tiny thread-safe LRU (search_many fans searches out over threads
    that share these caches)."""

    def __init__(self, maxsize: int):
        self.maxsize = maxsize
        self.data: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self._lock = threading.Lock()

    def get(self, key):
        with self._lock:
            if key in self.data:
                self.data.move_to_end(key)
                self.hits += 1
                return self.data[key]
            self.misses += 1
            return None

    def put(self, key, value) -> None:
        with self._lock:
            self.data[key] = value
            self.data.move_to_end(key)
            while len(self.data) > self.maxsize:
                self.data.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self.data.clear()
            self.hits = 0
            self.misses = 0


_GRID_CACHE = _LRU(maxsize=1024)
_SPEC_CACHE = _LRU(maxsize=65536)


def cache_info() -> Dict[str, Dict[str, int]]:
    return {
        "grid": {"hits": _GRID_CACHE.hits, "misses": _GRID_CACHE.misses,
                 "size": len(_GRID_CACHE.data)},
        "spec": {"hits": _SPEC_CACHE.hits, "misses": _SPEC_CACHE.misses,
                 "size": len(_SPEC_CACHE.data)},
    }


def cache_clear() -> None:
    _GRID_CACHE.clear()
    _SPEC_CACHE.clear()


def evaluate_topology_grid(co: CompoundOp, arch: Arch, topo: Topology,
                           cands: Dict[str, List]) -> BatchResult:
    """Whole-grid evaluation of one topology, LRU-cached on the compound
    op signature, the full arch parameter signature, the topology and the
    candidate axes (tiling, spatial fanouts and schedules).  Candidate
    dicts without the sp_*/schedule axes (the PR 1 shape) pin them to the
    auto fanout / the topology's schedule."""
    full = dict(cands)
    full.setdefault("sp_cluster", [0])
    full.setdefault("sp_core", [0])
    full.setdefault("schedule", [topo.schedule])
    full.setdefault("overlap", [0.0])
    key = (co_signature(co), arch.signature(), topo,
           tuple(full["m_tiles"]), tuple(full["k_tiles"]),
           tuple(full["n_tiles"]),
           tuple(full["sp_cluster"]), tuple(full["sp_core"]),
           tuple(full["schedule"]), tuple(full["overlap"]))
    hit = _GRID_CACHE.get(key)
    if hit is not None:
        return hit
    m, k, n, spc, spo, sched, ov = _grid_arrays(co, full)
    # a pure-serial grid ([0.0] overlap axis) passes overlap=None so the
    # cost model takes the bit-identical pre-overlap path
    ov_arg = None if tuple(full["overlap"]) == (0.0,) else ov  # scalar-ok: host-side axis tuple
    br = evaluate_specs_batch(co, arch, topo, m, k, n, spc, spo, sched,
                              ov_arg)
    _GRID_CACHE.put(key, br)
    return br


def evaluate_cached(co: CompoundOp, arch: Arch, spec: MappingSpec
                    ) -> Optional[Tuple[float, float, bool, float]]:
    """Lightweight cached per-spec evaluation: (latency, energy_pj, valid,
    headroom), or None when the spec is rejected outright (the scalar path
    raises).  Shared by the randomized search fallback across searches."""
    key = (co_signature(co), arch.signature(), spec)
    hit = _SPEC_CACHE.get(key)
    if hit is not None:
        return hit if hit != () else None
    from .ir import evaluate_mapping
    try:
        r = evaluate_mapping(co, arch, spec)
        val = (r.latency, r.energy_pj, r.valid, r.headroom)
    except (ValueError, KeyError):
        val = ()
    _SPEC_CACHE.put(key, val)
    return val if val != () else None
