"""Tests for the vectorized batch map-space evaluation engine
(core/batcheval.py), the exhaustive search mode, the shared evaluation
caches and the parallel sweep driver."""
import dataclasses
import math
import random
import warnings

import numpy as np
import pytest

from repro.core import batcheval
from repro.core.batcheval import (Topology, co_signature,
                                  enumerate_topologies, evaluate_cached,
                                  evaluate_specs_batch,
                                  evaluate_topology_grid, pareto_merge)
from repro.core.hardware import cloud, edge
from repro.core.ir import MappingSpec, evaluate_mapping
from repro.core.search import (candidate_specs, parallel_map, search,
                               search_many, _sample)
from repro.core.workload import (attention, flash_attention, gemm_layernorm,
                                 gemm_softmax, ssd_chunk)

WORKLOADS = [
    ("gemm_softmax", gemm_softmax(512, 1024, 128)),
    ("gemm_layernorm", gemm_layernorm(512, 4096, 128)),
    ("attention_prefill", attention(1024, 256, 1024, 256)),
    ("attention_decode", attention(1, 128, 1024, 128)),
    ("flash_attention", flash_attention(2048, 256, 2048, 256)),
]
# Prime / non-divisible sizes: spatial fanouts never divide the dims, so
# every edge tile is a ceil-div residual (regression cover for the
# non-divisible fanout accounting fix).
PRIME_WORKLOADS = [
    ("gemm_softmax_prime", gemm_softmax(509, 769, 127)),
    ("attention_decode_prime", attention(1, 64, 769, 128)),
    ("attention_prefill_prime", attention(769, 127, 769, 127)),
]
ARCHS = [edge(), cloud()]


# -------------------------------------------------- vectorized equivalence

@pytest.mark.parametrize("wl_name,co", WORKLOADS,
                         ids=[n for n, _ in WORKLOADS])
@pytest.mark.parametrize("arch", ARCHS, ids=[a.name for a in ARCHS])
def test_batch_matches_tree_path(wl_name, co, arch):
    """Every grid point of every topology matches the per-spec
    build_tree -> validate_tree -> CostModel path to 1e-9 relative
    tolerance (they execute the same formulas, so in practice they are
    bit-identical), including validity."""
    cands = candidate_specs(co, arch)
    rng = random.Random(0)
    for topo in enumerate_topologies(co, cands):
        br = evaluate_topology_grid(co, arch, topo, cands)
        # sample a handful of points per topology to keep runtime down
        idxs = {rng.randrange(br.size) for _ in range(8)} | {0, br.size - 1}
        for i in idxs:
            spec = br.spec_at(i)
            try:
                r = evaluate_mapping(co, arch, spec)
            except (ValueError, KeyError):
                assert not br.valid[i]
                continue
            assert bool(br.valid[i]) == r.valid
            assert br.latency[i] == pytest.approx(r.latency, rel=1e-9)
            assert br.energy_pj[i] == pytest.approx(r.energy_pj, rel=1e-9)
            assert br.headroom[i] == pytest.approx(r.headroom, rel=1e-9)


@pytest.mark.parametrize("wl_name,co", PRIME_WORKLOADS,
                         ids=[n for n, _ in PRIME_WORKLOADS])
@pytest.mark.parametrize("arch", ARCHS, ids=[a.name for a in ARCHS])
def test_batch_matches_tree_path_prime_sizes(wl_name, co, arch):
    """Parity at prime dimension sizes: no spatial fanout divides the
    dims, so every tile is a ceil-div residual (edge) tile — the batched
    path must still match the per-spec tree path everywhere."""
    cands = candidate_specs(co, arch)
    rng = random.Random(1)
    for topo in enumerate_topologies(co, cands):
        br = evaluate_topology_grid(co, arch, topo, cands)
        idxs = {rng.randrange(br.size) for _ in range(8)} | {0, br.size - 1}
        for i in idxs:
            spec = br.spec_at(i)
            try:
                r = evaluate_mapping(co, arch, spec)
            except (ValueError, KeyError):
                assert not br.valid[i]
                continue
            assert bool(br.valid[i]) == r.valid
            assert br.latency[i] == pytest.approx(r.latency, rel=1e-9)
            assert br.energy_pj[i] == pytest.approx(r.energy_pj, rel=1e-9)


def test_spatial_and_schedule_axes_in_grid():
    """The SoA grid enumerates sp_cluster/sp_core and the schedule; the
    topology count no longer doubles on the schedule axis."""
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    cands = candidate_specs(co, arch)
    topos = enumerate_topologies(co, cands)
    # schedule folded into the grid: topologies = variants only (x gran)
    assert len(topos) == len(cands["variant"])
    assert all(t.schedule == "sequential" for t in topos)
    expect = (len(cands["m_tiles"]) * len(cands["k_tiles"])
              * len(cands["sp_cluster"]) * len(cands["sp_core"])
              * len(cands["schedule"]))
    assert batcheval.grid_size(co, cands) == expect
    br = evaluate_topology_grid(co, arch, topos[0], cands)
    assert br.size == expect
    assert set(np.unique(br.sp_cluster)) == set(cands["sp_cluster"])
    assert set(np.unique(br.sp_core)) == set(cands["sp_core"])
    assert set(np.unique(br.schedule)) == set(cands["schedule"])
    # candidate specs per space grew >= 4x over the m/k-only grid of PR 1
    legacy = len(cands["m_tiles"]) * len(cands["k_tiles"])
    assert expect >= 4 * legacy
    # and the spatial axes actually change results somewhere on the grid
    v = br.valid
    full = br.latency[v & (br.sp_cluster == max(cands["sp_cluster"]))]
    one = br.latency[v & (br.sp_cluster == 1)]
    assert full.size and one.size and not np.isclose(full.min(), one.min())


def test_grid_accepts_pr1_shaped_candidate_dicts():
    """Candidate dicts without the sp_*/schedule axes (the PR 1 API
    shape) pin the missing axes instead of raising KeyError."""
    co = gemm_softmax(256, 1024, 64)
    arch = edge()
    cands = {"m_tiles": [1, 2, 4], "k_tiles": [1, 2], "n_tiles": [1]}
    assert batcheval.grid_size(co, cands) == 6
    topo = Topology(variant="fused_dist")
    br = evaluate_topology_grid(co, arch, topo, cands)
    assert br.size == 6
    assert set(np.unique(br.sp_cluster)) == {0}          # auto fanout
    assert set(np.unique(br.schedule)) == {"sequential"}
    # rejected topology keeps the requested breakdown dicts (zeros)
    bad = evaluate_specs_batch(co, arch, Topology(variant="fa"),
                               [1], [1], [1], track_breakdown=True)
    assert not bad.valid.any()
    assert bad.lat_breakdown is not None
    assert bad.lat_breakdown_at(0)["gemm"] == 0.0
    # unknown schedule names are rejected up front, like the scalar path
    with pytest.raises(ValueError, match="bad schedule"):
        evaluate_specs_batch(co, arch, topo, [1], [1], [1],
                             schedule=["sequentail"])


def test_rejected_topology_arrays_are_independent():
    """Regression (satellite): the rejected-topology path used to alias
    ONE zeros buffer across latency, energy and every breakdown key —
    mutating any of them corrupted all of them."""
    co = gemm_softmax(256, 1024, 64)
    arch = edge()
    bad = evaluate_specs_batch(co, arch, Topology(variant="fa"),
                               [1, 2], [1, 1], [1, 1], track_breakdown=True)
    assert not bad.valid.any()
    bufs = [bad.latency, bad.energy_pj, bad.headroom,
            *bad.lat_breakdown.values(), *bad.energy_breakdown.values()]
    for i, a in enumerate(bufs):
        for b in bufs[i + 1:]:
            assert a is not b and not np.shares_memory(a, b)
    bad.lat_breakdown["gemm"][0] = 123.0
    assert bad.lat_breakdown["simd"][0] == 0.0
    assert bad.latency[0] == 0.0
    assert bad.energy_pj[0] == 0.0


def test_spec_spatial_fanouts_reach_scalar_builder():
    """sp_cluster/sp_core are honoured by the per-spec tree path too."""
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    base = MappingSpec(variant="fused_dist", m_tiles=8, k_tiles=2)
    narrow = dataclasses.replace(base, sp_cluster=1, sp_core=1)
    r_full = evaluate_mapping(co, arch, base)
    r_one = evaluate_mapping(co, arch, narrow)
    assert r_full.latency != r_one.latency
    # sp 0 (auto) == full arch fanout explicitly requested
    explicit = dataclasses.replace(base, sp_cluster=arch.num_clusters,
                                   sp_core=arch.cores_per_cluster)
    assert evaluate_mapping(co, arch, explicit).latency == r_full.latency


def test_batch_specs_parallel_arrays():
    """evaluate_specs_batch accepts explicit (m, k, n) candidate pairs
    (the autotune use case), not just meshgrids."""
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    topo = Topology(variant="fused_dist", schedule="sequential")
    m = [1, 2, 8, 64]
    k = [1, 4, 2, 8]
    br = evaluate_specs_batch(co, arch, topo, m, k, [1, 1, 1, 1])
    assert br.size == 4
    for i in range(4):
        r = evaluate_mapping(co, arch, br.spec_at(i))
        assert br.latency[i] == pytest.approx(r.latency, rel=1e-9)


# ------------------------------------------- exhaustive vs randomized

@pytest.mark.parametrize("wl_name,co", WORKLOADS,
                         ids=[n for n, _ in WORKLOADS])
@pytest.mark.parametrize("arch", ARCHS, ids=[a.name for a in ARCHS])
def test_exhaustive_no_worse_than_randomized(wl_name, co, arch):
    ex = search(co, arch, mode="exhaustive")
    assert ex.mode == "exhaustive"
    assert ex.best.valid
    for seed in (0, 1, 7):
        rd = search(co, arch, mode="randomized", budget=500, seed=seed)
        assert ex.latency <= rd.latency * (1 + 1e-12), \
            f"exhaustive worse than randomized seed={seed}"


def test_search_auto_picks_exhaustive_and_is_deterministic():
    co = gemm_softmax(512, 2048, 128)
    arch = cloud()
    r1 = search(co, arch)
    r2 = search(co, arch)
    assert r1.mode == "exhaustive" == r2.mode
    assert r1.latency == r2.latency
    assert r1.evaluated == r2.evaluated
    # full space covered: evaluated == topologies x grid
    cands = candidate_specs(co, arch)
    expect = (len(enumerate_topologies(co, cands))
              * batcheval.grid_size(co, cands))
    assert r1.evaluated == expect


def test_search_objectives():
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    lat = search(co, arch, objective="latency")
    en = search(co, arch, objective="energy")
    edp = search(co, arch, objective="edp")
    assert lat.latency <= en.latency * (1 + 1e-12)
    assert en.energy_pj <= lat.energy_pj * (1 + 1e-12)
    assert (edp.latency * edp.energy_pj
            <= lat.latency * lat.energy_pj * (1 + 1e-12))


def test_pareto_front_matches_bruteforce():
    """Vectorized skyline == O(n^2) dominance check on a real grid."""
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    cands = candidate_specs(co, arch)
    topo = enumerate_topologies(co, cands)[0]
    br = evaluate_topology_grid(co, arch, topo, cands)
    front = br.pareto_front()
    assert front.size > 0
    lat, en, valid = br.latency, br.energy_pj, br.valid
    fset = set(front.tolist())
    kept = [(lat[i], en[i]) for i in front]
    # ascending latency, strictly descending energy
    assert all(a[0] <= b[0] and a[1] > b[1] for a, b in zip(kept, kept[1:]))
    for i in front:
        assert valid[i]
        dominated = ((lat <= lat[i]) & (en <= en[i]) & valid
                     & ((lat < lat[i]) | (en < en[i])))
        assert not dominated.any(), f"front point {i} is dominated"
    # every non-front valid point is dominated by (or duplicates) the front
    for j in np.flatnonzero(valid):
        if j in fset:
            continue
        dom = ((lat <= lat[j]) & (en <= en[j]) & valid
               & (np.arange(br.size) != j))
        assert dom.any(), f"non-front point {j} is non-dominated"


def test_pareto_merge_skyline():
    pts = [(2.0, 5.0, "a"), (1.0, 9.0, "b"), (3.0, 1.0, "c"),
           (2.5, 5.0, "d"), (1.0, 9.0, "e"), (2.0, 4.0, "f")]
    out = pareto_merge(pts)
    assert [p[2] for p in out] == ["b", "f", "c"]


def test_search_pareto_objective():
    """objective='pareto': front endpoints match the scalar optima and
    SearchResult.best is the front's minimum-latency mapping."""
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    lat = search(co, arch, objective="latency")
    en = search(co, arch, objective="energy")
    pf = search(co, arch, objective="pareto")
    assert pf.mode == "exhaustive" and pf.front
    assert pf.front[0][0] == pytest.approx(lat.latency, rel=1e-12)
    assert pf.front[-1][1] == pytest.approx(en.energy_pj, rel=1e-12)
    assert pf.latency == pytest.approx(pf.front[0][0], rel=1e-12)
    assert pf.best.valid
    # scalar objectives keep front=None; randomized mode fills it too
    assert lat.front is None
    rd = search(co, arch, mode="randomized", budget=300, seed=0,
                objective="pareto")
    assert rd.front and all(a[0] < b[0] and a[1] > b[1]
                            for a, b in zip(rd.front, rd.front[1:]))


def test_batched_breakdown_matches_scalar_walk():
    """track_breakdown=True carries per-key latency/energy breakdowns
    through the SoA pass, matching the scalar tree walk per grid point."""
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    topo = Topology(variant="fused_dist")
    br = evaluate_specs_batch(
        co, arch, topo, [8, 4, 8], [2, 2, 1], [1, 1, 1],
        sp_cluster=[4, 2, 1], sp_core=[4, 1, 2],
        schedule=["sequential", "pipelined", "pipelined"],
        track_breakdown=True)
    assert br.lat_breakdown is not None
    for i in range(br.size):
        r = evaluate_mapping(co, arch, br.spec_at(i))
        bd = br.lat_breakdown_at(i)
        eb = br.energy_breakdown_at(i)
        for k, v in r.cost.lat_breakdown.items():
            assert bd[k] == pytest.approx(v, rel=1e-9, abs=1e-18)
        for k, v in r.cost.energy_breakdown.items():
            assert eb[k] == pytest.approx(v, rel=1e-9, abs=1e-12)
    # default path stays lean
    lean = evaluate_specs_batch(co, arch, topo, [8], [2], [1])
    assert lean.lat_breakdown is None
    with pytest.raises(ValueError):
        lean.lat_breakdown_at(0)


def test_exhaustive_falls_back_when_space_too_large():
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    r = search(co, arch, exhaustive_limit=10, budget=200, seed=0)
    assert r.mode == "randomized"


def test_generic_workload_exhaustive():
    co = ssd_chunk(S=2048, H=1, P=64, Dst=128, C=256)
    from repro.core.hardware import tpu_v5e
    arch = tpu_v5e((1, 1))
    r = search(co, arch)
    assert r.mode == "exhaustive"
    assert r.best.valid and r.latency > 0


# ----------------------------------------------------------------- caches

def test_grid_cache_hits():
    batcheval.cache_clear()
    co = gemm_softmax(256, 1024, 64)
    arch = edge()
    cands = candidate_specs(co, arch)
    topo = enumerate_topologies(co, cands)[0]
    br1 = evaluate_topology_grid(co, arch, topo, cands)
    info1 = batcheval.cache_info()["grid"]
    br2 = evaluate_topology_grid(co, arch, topo, cands)
    info2 = batcheval.cache_info()["grid"]
    assert info2["hits"] == info1["hits"] + 1
    assert br2 is br1          # same cached object
    # a different arch is a different cache line
    evaluate_topology_grid(co, cloud(), topo, cands)
    assert batcheval.cache_info()["grid"]["misses"] == info2["misses"] + 1


def test_spec_cache_hits_and_rejections():
    batcheval.cache_clear()
    co = gemm_softmax(256, 1024, 64)
    arch = edge()
    spec = MappingSpec(variant="fused_dist", m_tiles=8, k_tiles=2)
    r1 = evaluate_cached(co, arch, spec)
    h0 = batcheval.cache_info()["spec"]["hits"]
    r2 = evaluate_cached(co, arch, spec)
    assert batcheval.cache_info()["spec"]["hits"] == h0 + 1
    assert r1 == r2
    ref = evaluate_mapping(co, arch, spec)
    assert r1 == (ref.latency, ref.energy_pj, ref.valid, ref.headroom)
    # rejected specs (scalar path raises) cache as None both times
    bad = MappingSpec(variant="fa")    # wrong builder family
    assert evaluate_cached(co, arch, bad) is None
    assert evaluate_cached(co, arch, bad) is None


def test_arch_signature_busts_caches():
    """Regression: two Arch instances sharing a name but differing in a
    parameter (here GB bandwidth) must not reuse each other's cached
    results — keys use Arch.signature(), not arch.name."""
    batcheval.cache_clear()
    co = gemm_softmax(256, 1024, 64)
    a1 = edge()
    a2 = dataclasses.replace(
        a1, gb=dataclasses.replace(a1.gb, bandwidth=a1.gb.bandwidth / 4))
    assert a1.name == a2.name
    assert a1.signature() != a2.signature()

    cands = candidate_specs(co, a1)
    topo = enumerate_topologies(co, cands)[0]
    br1 = evaluate_topology_grid(co, a1, topo, cands)
    g = batcheval.cache_info()["grid"]
    br2 = evaluate_topology_grid(co, a2, topo, cands)
    g2 = batcheval.cache_info()["grid"]
    assert g2["misses"] == g["misses"] + 1   # miss, not a stale hit
    assert br2 is not br1
    assert float(br1.scores().min()) != float(br2.scores().min())

    spec = MappingSpec(variant="fused_dist", m_tiles=8, k_tiles=2)
    r1 = evaluate_cached(co, a1, spec)
    s = batcheval.cache_info()["spec"]
    r2 = evaluate_cached(co, a2, spec)
    s2 = batcheval.cache_info()["spec"]
    assert s2["misses"] == s["misses"] + 1
    assert r1 != r2


def test_arch_signature_memoized():
    """Regression (satellite): Arch.signature() is on the hot cache-key
    path — it must build the field tuple once per instance, and derived
    instances (dataclasses.replace) must not inherit a stale memo."""
    a = edge()
    s1 = a.signature()
    assert a.signature() is s1              # memoized object, not a rebuild
    b = dataclasses.replace(
        a, gb=dataclasses.replace(a.gb, bandwidth=a.gb.bandwidth * 2))
    assert b.signature() != s1              # fresh instance, fresh memo
    assert edge().signature() == s1         # equal params -> equal tuple
    # the memo attribute never leaks into dataclass equality
    assert a == edge()


def test_co_signature_distinguishes_shapes():
    assert co_signature(gemm_softmax(256, 1024, 64)) != \
        co_signature(gemm_softmax(256, 1024, 128))
    assert co_signature(gemm_softmax(256, 1024, 64)) == \
        co_signature(gemm_softmax(256, 1024, 64))


# ----------------------------------------------------------- sweep driver

def test_search_many_matches_serial_order():
    jobs = [(gemm_softmax(256, 1024, 128), edge(), {"variants": [v]})
            for v in ("unfused", "fused_epilogue", "fused_std", "fused_dist")]
    par = search_many(jobs)
    ser = search_many(jobs, executor="serial")
    assert [r.latency for r in par] == [r.latency for r in ser]
    assert [r.best.spec.variant for r in par] == \
        ["unfused", "fused_epilogue", "fused_std", "fused_dist"]


def _square(x):
    return x * x


def _boom(x):
    if x == 2:
        raise ValueError("boom")
    return x


def test_parallel_map_propagates_fn_exceptions():
    """Ordinary exceptions raised by fn are NOT swallowed by the broken-
    pool fallback — they propagate to the caller."""
    with pytest.raises(ValueError, match="boom"):
        parallel_map(_boom, [1, 2, 3], executor="thread")
    with pytest.raises(ValueError, match="boom"):
        parallel_map(_boom, [1, 2, 3], executor="serial")


def test_parallel_map_broken_pool_falls_back_serial(monkeypatch):
    """A pool that breaks mid-sweep (worker killed -> BrokenProcessPool
    out of pool.map) degrades to serial execution of the remaining items
    with a RuntimeWarning, instead of losing the whole sweep."""
    from concurrent.futures.process import BrokenProcessPool

    from repro.core import search as search_mod

    class _BreaksAfterOne:
        def __init__(self, max_workers=None):
            pass

        def __enter__(self):
            return self

        def __exit__(self, *exc):
            return False

        def map(self, fn, items, chunksize=1):
            def gen():
                it = list(items)
                yield fn(it[0])
                raise BrokenProcessPool("worker died")
            return gen()

    monkeypatch.setattr(search_mod, "ProcessPoolExecutor", _BreaksAfterOne)
    with pytest.warns(RuntimeWarning, match="worker pool broke"):
        out = parallel_map(_square, [1, 2, 3, 4], executor="process")
    assert out == [1, 4, 9, 16]


# -------------------------------------------------- autotune integration

def test_autotune_uses_shared_engine():
    """Block selection routes through the shared search engine via the
    PlanCache (no local mini cost models, no per-process lru_cache) and
    still respects the kernel VMEM constraints."""
    import inspect

    from repro.kernels import autotune

    src = inspect.getsource(autotune)
    assert "get_plan_cache" in src             # PlanCache-resolved
    assert "candidate_list" in src             # shared candidates-mode search
    assert "lru_cache" not in src              # result caching = PlanCache
    assert "systolic_gemm_cycles" not in src   # the old mini-model hook
    bq, bk = autotune.attention_blocks(1024, 1024, 64)
    assert bq % 128 == 0 and bk % 128 == 0
    bm, bk2 = autotune.gemm_epilogue_blocks(512, 4096, 128)
    assert (bm * 4096 * 4 + bk2 * 4096 * 2 + bm * bk2 * 2
            + bm * 4096 * 2) * 2 <= autotune.VMEM_BUDGET
