"""Structural FLOP counting from the jaxpr (scan-aware).

XLA's ``cost_analysis()`` does not multiply while-loop bodies by their trip
counts, so scanned-layer models under-report FLOPs by ~n_layers (observed
useful_flop_ratio >> 1, see EXPERIMENTS §Roofline).  The jaxpr still knows
every ``scan`` length statically, so we count matmul FLOPs exactly by
walking it recursively with a trip-count multiplier.

Counted: dot_general (2·M·N·K·batch), conv as dots.  Elementwise/reduce
FLOPs are a few percent of LM totals and are not counted (documented).
Returned FLOPs are GLOBAL (whole-program, pre-partitioning): divide by the
device count for per-device numbers.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import numpy as np

__all__ = ["count_flops", "structural_flops"]


def _dot_flops(eqn) -> float:
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    batch = 1
    for d in lb:
        batch *= a.shape[d]
    contract = 1
    for d in lc:
        contract *= a.shape[d]
    m = 1
    for i, s in enumerate(a.shape):
        if i not in lc and i not in lb:
            m *= s
    n = 1
    for i, s in enumerate(b.shape):
        if i not in rc and i not in rb:
            n *= s
    return 2.0 * batch * m * n * contract


def _walk(jaxpr, mult: float) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            total += mult * _dot_flops(eqn)
        elif prim == "scan":
            inner = eqn.params["jaxpr"].jaxpr
            length = eqn.params["length"]
            total += _walk(inner, mult * length)
        elif prim == "while":
            # conservative: body counted once (no static trip count);
            # our models use scan, so this path is rare.
            total += _walk(eqn.params["body_jaxpr"].jaxpr, mult)
        elif prim == "shard_map":
            sub = eqn.params.get("jaxpr")
            if sub is not None:
                inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
                # shard_map body runs on EVERY device over 1/N of data: the
                # global flop count is body × num_devices (mesh size)
                mesh = eqn.params.get("mesh")
                n = mesh.devices.size if mesh is not None else 1
                total += _walk(inner, mult * n)
        elif prim == "cond":
            branches = eqn.params.get("branches", ())
            if branches:
                total += max(_walk(b.jaxpr, mult) for b in branches)
        else:
            # generic call-like primitives (pjit, remat2, custom_vjp, ...)
            sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if sub is not None:
                inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
                total += _walk(inner, mult)
    return total


def count_flops(closed_jaxpr) -> float:
    return _walk(closed_jaxpr.jaxpr, 1.0)


def structural_flops(fn, *abstract_args, **abstract_kwargs) -> float:
    """Global matmul FLOPs of ``fn`` traced on abstract inputs."""
    cj = jax.make_jaxpr(fn)(*abstract_args, **abstract_kwargs)
    return count_flops(cj)
