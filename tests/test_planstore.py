"""Storage-engine tests for repro.core.planstore: provenance columns,
LRU/size/age eviction, generation-exact invalidation, legacy-JSON
auto-migration, backend forcing, and the stats surface.

Complementary to tests/test_faults.py (the fault matrix) — this file
pins the *mechanics* of the store on the happy path, with an injectable
clock so eviction order and age expiry are deterministic.
"""
import json

import pytest

from repro.core import planstore
from repro.core.planstore import (CORRUPT_DIRNAME, DB_FILENAME,
                                  MIGRATED_DIRNAME, PlanStore, key_filename,
                                  parse_key_filename)


def K(i, ver=5):
    """A synthetic, filename-legal PlanKey."""
    return (f"{i:016x}", f"{i:016x}", ver, f"{i:016x}")


def payload_for(key, pad=0):
    return json.dumps({"key": list(key), "plan": {"v": key[0]},
                       "pad": "x" * pad})


class Clock:
    def __init__(self, t=1000.0):
        self.t = t

    def __call__(self):
        return self.t


@pytest.fixture()
def clock():
    return Clock()


def _store(tmp_path, clock, **kw):
    return PlanStore(tmp_path / "plans", now=clock, **kw)


# ------------------------------------------------------------- filenames


def test_key_filename_roundtrip():
    key = K(7)
    assert parse_key_filename(key_filename(key)) == key
    assert parse_key_filename("notaplan.json") is None
    assert parse_key_filename(key_filename(key) + ".tmp") is None


# -------------------------------------------------------- put/get/stats


def test_roundtrip_provenance_and_hit_counting(tmp_path, clock):
    store = _store(tmp_path, clock)
    key = K(1)
    assert store.get(key) is None                  # miss, nothing created
    assert not (tmp_path / "plans" / DB_FILENAME).exists()
    assert store.put(key, payload_for(key), sweep_id="sweep-a")
    clock.t += 5
    assert store.get(key) == payload_for(key)
    clock.t += 5
    assert store.get(key) == payload_for(key)
    s = store.stats()
    assert s["backend"] == "sqlite" and s["plans"] == 1
    assert s["hits"] == 2
    assert s["by_sweep"] == {"sweep-a": 1}
    assert s["by_version"] == {5: 1}
    store.close()


def test_sweep_id_env_default(tmp_path, clock, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_SWEEP_ID", "fleet-sweep-7")
    store = _store(tmp_path, clock)
    store.put(K(1), payload_for(K(1)))             # no explicit sweep_id
    store.put(K(2), payload_for(K(2)), sweep_id="explicit")
    s = store.stats()
    assert s["by_sweep"] == {"fleet-sweep-7": 1, "explicit": 1}
    store.close()


# --------------------------------------------------------------- eviction


def test_lru_eviction_max_plans_keeps_recently_hit(tmp_path, clock):
    store = _store(tmp_path, clock, max_plans=4)
    for i in range(4):
        clock.t += 1
        store.put(K(i), payload_for(K(i)))
    clock.t += 1
    assert store.get(K(0)) is not None             # refresh the oldest
    clock.t += 1
    store.put(K(9), payload_for(K(9)))             # overflow by one
    keys = set(store.keys())
    assert len(keys) == 4
    assert K(0) in keys and K(9) in keys           # recently hit + newest
    assert K(1) not in keys                        # least-recently-hit died
    assert store.stats()["evicted_total"] == 1
    store.close()


def test_max_bytes_bound_enforced_across_sweep(tmp_path, clock):
    """Acceptance: a sweep writing far past max_bytes leaves the store
    at or under the bound the whole way, and vacuum returns the pages
    (db file does not monotonically grow)."""
    pad = 2000
    size_one = len(payload_for(K(0), pad=pad).encode())
    store = _store(tmp_path, clock, max_bytes=5 * size_one + 10)
    for i in range(25):
        clock.t += 1
        store.put(K(i), payload_for(K(i), pad=pad))
        assert store.stats()["bytes"] <= 5 * size_one + 10
    s = store.stats()
    assert s["plans"] <= 5 and s["evicted_total"] >= 20
    assert s["db_bytes"] < 25 * size_one           # vacuum reclaimed pages
    store.close()


def test_age_gc_expires_old_plans(tmp_path, clock):
    store = _store(tmp_path, clock)
    store.put(K(1), payload_for(K(1)))
    clock.t += 100
    store.put(K(2), payload_for(K(2)))
    clock.t += 10                                  # K(1) age 110, K(2) age 10
    out = store.gc(max_age_s=50)
    assert out["expired"] == 1
    assert store.keys() == [K(2)]
    store.close()


def test_gc_with_tightened_bounds_does_not_stick(tmp_path, clock):
    store = _store(tmp_path, clock, max_plans=100)
    for i in range(6):
        clock.t += 1
        store.put(K(i), payload_for(K(i)))
    out = store.gc(max_plans=3)                    # one-off tightening
    assert out["evicted"] == 3 and len(store.keys()) == 3
    for i in range(10, 14):
        clock.t += 1
        store.put(K(i), payload_for(K(i)))         # permanent bound still 100
    assert len(store.keys()) == 7
    store.close()


# ------------------------------------------------------------ invalidate


def test_invalidate_removes_exactly_the_stale_generation(tmp_path, clock):
    store = _store(tmp_path, clock)
    for i in range(3):
        store.put(K(i, ver=4), payload_for(K(i, ver=4)))
    for i in range(2):
        store.put(K(i, ver=5), payload_for(K(i, ver=5)))
    assert store.invalidate(engine_version=4) == 3
    s = store.stats()
    assert s["by_version"] == {5: 2}
    assert all(k[2] == 5 for k in store.keys())
    assert store.invalidate(engine_version=4) == 0  # idempotent
    store.close()


def test_invalidate_by_sweep_and_age_are_anded(tmp_path, clock):
    store = _store(tmp_path, clock)
    store.put(K(1), payload_for(K(1)), sweep_id="old-sweep")
    clock.t += 100
    store.put(K(2), payload_for(K(2)), sweep_id="old-sweep")
    store.put(K(3), payload_for(K(3)), sweep_id="new-sweep")
    # sweep AND age: only the old-sweep row older than 50s dies
    assert store.invalidate(sweep_id="old-sweep", older_than_s=50) == 1
    assert set(store.keys()) == {K(2), K(3)}
    assert store.invalidate() == 0                 # no filters -> no-op
    store.close()


# ------------------------------------------------------------- migration


def test_legacy_json_auto_migration_zero_lost(tmp_path, clock):
    """Acceptance: pointing the SQLite store at a legacy flat-JSON dir
    migrates every valid plan (zero lost), quarantines unparsable files,
    and moves originals aside so no later open re-parses them."""
    root = tmp_path / "plans"
    root.mkdir()
    keys = [K(i) for i in range(3)]
    for key in keys:
        (root / key_filename(key)).write_text(payload_for(key))
    (root / key_filename(K(9))).write_text("{ torn json")
    store = _store(tmp_path, clock)
    with pytest.warns(RuntimeWarning, match="migrated 3 legacy"):
        got = {k: store.get(k) for k in keys}
    assert got == {k: payload_for(k) for k in keys}
    s = store.stats()
    assert s["migrated"] == 3 and s["plans"] == 3
    assert s["by_sweep"] == {"legacy-json": 3}
    assert not list(root.glob("*.json"))           # moved, not deleted
    assert len(list((root / MIGRATED_DIRNAME).glob("*.json"))) == 3
    assert len(list((root / CORRUPT_DIRNAME).glob("*.json"))) == 1
    store.close()
    # second open: nothing left to migrate, no warning
    planstore._reset_warned()
    import warnings as _w
    with _w.catch_warnings(record=True) as rec:
        _w.simplefilter("always")
        again = _store(tmp_path, clock)
        assert again.get(keys[0]) == payload_for(keys[0])
    assert not [w for w in rec if "migrated" in str(w.message)]
    again.close()


# -------------------------------------------------------- backend forcing


def test_forced_json_backend(tmp_path, clock, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_STORE", "json")
    store = _store(tmp_path, clock)
    assert store.backend == "json"
    key = K(1)
    store.put(key, payload_for(key))
    assert (tmp_path / "plans" / key_filename(key)).exists()
    assert not (tmp_path / "plans" / DB_FILENAME).exists()
    assert store.get(key) == payload_for(key)
    assert store.keys() == [key]


def test_forced_memory_backend_accepts_and_drops(tmp_path, clock):
    store = _store(tmp_path, clock, backend="memory")
    assert store.backend == "memory"
    assert store.put(K(1), payload_for(K(1))) is False
    assert store.get(K(1)) is None
    assert not (tmp_path / "plans").exists()       # never touches disk
    assert store.stats()["writes_dropped"] == 1


def test_unknown_backend_rejected(tmp_path, clock):
    with pytest.raises(ValueError, match="unknown plan-store backend"):
        _store(tmp_path, clock, backend="carrier-pigeon")


def test_env_bounds_respected(tmp_path, clock, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_STORE_MAX_PLANS", "2")
    store = _store(tmp_path, clock)
    for i in range(5):
        clock.t += 1
        store.put(K(i), payload_for(K(i)))
    assert len(store.keys()) == 2
    store.close()


# ----------------------------------------------------- json backend parity


def test_json_backend_gc_and_invalidate(tmp_path, clock, monkeypatch):
    import os
    import time

    monkeypatch.setenv("REPRO_PLAN_STORE", "json")
    store = _store(tmp_path, clock)
    now = time.time()
    for i, age in enumerate((500, 300, 10)):
        key = K(i)
        store.put(key, payload_for(key))
        p = tmp_path / "plans" / key_filename(key)
        os.utime(p, (now - age, now - age))
    clock.t = now
    assert store.invalidate(older_than_s=400) == 1          # the 500s one
    out = store.gc(max_plans=1)
    assert out["evicted"] == 1                              # the 300s one
    assert store.keys() == [K(2)]
    st = store.stats()
    assert st["backend"] == "json" and st["plans"] == 1


def test_json_backend_version_invalidate(tmp_path, clock, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_STORE", "json")
    store = _store(tmp_path, clock)
    store.put(K(1, ver=4), payload_for(K(1, ver=4)))
    store.put(K(1, ver=5), payload_for(K(1, ver=5)))
    assert store.invalidate(engine_version=4) == 1
    assert store.keys() == [K(1, ver=5)]
