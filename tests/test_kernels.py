"""Per-kernel allclose tests vs the pure-jnp oracles: shape/dtype sweeps in
interpret mode (Pallas kernel body executed on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 3e-2}


# ------------------------------------------------------------ flash attn

@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D", [
    (1, 4, 4, 128, 128, 64),      # MHA square
    (2, 8, 2, 128, 256, 64),      # GQA, kv longer
    (1, 4, 1, 64, 192, 32),       # MQA, ragged seq (padding path)
    (1, 2, 2, 100, 100, 128),     # non-multiple of block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, Hq, Hkv, Sq, Skv, D, dtype, causal):
    q = _arr((B, Hq, Sq, D), dtype)
    k = _arr((B, Hkv, Skv, D), dtype)
    v = _arr((B, Hkv, Skv, D), dtype)
    out = ops.flash_attention(q, k, v, causal, None, None, 128, 128, True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_attention_window():
    q = _arr((1, 4, 256, 64))
    k = _arr((1, 4, 256, 64))
    v = _arr((1, 4, 256, 64))
    out = ops.flash_attention(q, k, v, True, None, 64, 128, 128, True)
    want = ref.attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_flash_attention_grads_match_ref():
    q = _arr((1, 2, 128, 32))
    k = _arr((1, 2, 128, 32))
    v = _arr((1, 2, 128, 32))
    f_kernel = lambda *xs: ops.flash_attention(*xs, True, None, None, 128,
                                               128, True).sum()
    f_ref = lambda *xs: ref.attention_ref(*xs, causal=True).sum()
    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# --------------------------------------------------------- gemm epilogues

@pytest.mark.parametrize("M,K,N,bm,bk", [
    (128, 64, 256, 128, 64),
    (200, 96, 256, 128, 32),      # padding path
    (64, 128, 512, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_softmax_sweep(M, K, N, bm, bk, dtype):
    a = _arr((M, K), dtype)
    b = _arr((K, N), dtype, scale=0.1)
    out = ops.gemm_softmax(a, b, block_m=bm, block_k=bk, interpret=True)
    want = ref.gemm_softmax_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype])


@pytest.mark.parametrize("M,K,N", [(128, 64, 256), (96, 100, 128)])
def test_gemm_layernorm_and_rmsnorm(M, K, N):
    a = _arr((M, K))
    b = _arr((K, N), scale=0.2)
    g = _arr((N,))
    be = _arr((N,))
    out = ops.gemm_layernorm(a, b, g, be, block_m=64, block_k=32,
                             interpret=True)
    want = ref.gemm_layernorm_ref(a, b, g, be)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4)
    out = ops.gemm_rmsnorm(a, b, g, block_m=64, block_k=32, interpret=True)
    want = ref.gemm_rmsnorm_ref(a, b, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4)


# ------------------------------------------------------------------- SSD

@pytest.mark.parametrize("BH,S,P,N,chunk", [
    (2, 128, 16, 32, 64),
    (4, 256, 32, 64, 128),
    (1, 200, 16, 32, 64),         # padding path
])
def test_ssd_kernel_sweep(BH, S, P, N, chunk):
    xdt = _arr((BH, S, P))
    dA = -jnp.abs(_arr((BH, S))) * 0.1
    B = _arr((BH, S, N))
    C = _arr((BH, S, N))
    out = ops.ssd_scan(xdt, dA, B, C, chunk, True)
    want = ref.ssd_ref(xdt, dA, B, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


def test_ssd_chunked_ref_equals_naive():
    xdt = _arr((2, 256, 16))
    dA = -jnp.abs(_arr((2, 256))) * 0.2
    B = _arr((2, 256, 32))
    C = _arr((2, 256, 32))
    np.testing.assert_allclose(
        np.asarray(ref.ssd_chunked_ref(xdt, dA, B, C, chunk=32)),
        np.asarray(ref.ssd_ref(xdt, dA, B, C)), atol=2e-3, rtol=2e-3)


# -------------------------------------------------------------- autotune

def test_autotune_blocks_fit_vmem():
    from repro.kernels.autotune import (VMEM_BUDGET, attention_blocks,
                                        gemm_epilogue_blocks, ssd_chunk_len)
    for sq, skv, d in [(1024, 1024, 64), (32768, 32768, 128), (1, 32768, 128)]:
        bq, bk = attention_blocks(sq, skv, d)
        ws = (bq * d * 2 + 2 * bk * d * 2 + bq * d * 4 + bq * bk * 4
              + 2 * bq * 128 * 4)
        assert ws * 2 <= VMEM_BUDGET
    # single-pass fused epilogue targets N <= 16384 (the paper's largest);
    # larger N needs the two-pass/distSM mapping, not this kernel.
    for m, n, k in [(512, 4096, 128), (4096, 16384, 4096)]:
        bm, bk = gemm_epilogue_blocks(m, n, k)
        assert (bm * n * 4 + bk * n * 2 + bm * bk * 2 + bm * n * 2) * 2 \
            <= VMEM_BUDGET
    assert ssd_chunk_len(4096, 64, 128) in (128, 256, 512)


# ------------------------------------------------- fused all-gather GEMM

@pytest.mark.parametrize("M,K,N,chunks", [
    (64, 256, 128, 8),
    (128, 512, 256, 4),
    (8, 128, 128, 2),             # tiny M (gather-dominated shape)
])
@pytest.mark.parametrize("buffers", [1, 2])
def test_streamed_gemm_matches_dot(M, K, N, chunks, buffers):
    from repro.kernels import streamed_gemm
    x = _arr((M, K))
    w = _arr((K, N), scale=0.2)
    out = streamed_gemm(x, w, chunks=chunks, buffers=buffers, interpret=True)
    want = jnp.dot(x, w, preferred_element_type=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-4,
                               rtol=2e-5)


def test_streamed_gemm_validates_chunking():
    from repro.kernels import streamed_gemm
    x, w = _arr((16, 100)), _arr((100, 128))
    with pytest.raises(ValueError, match="must divide"):
        streamed_gemm(x, w, chunks=3, interpret=True)
    with pytest.raises(ValueError, match="buffers"):
        streamed_gemm(_arr((16, 128)), _arr((128, 128)), chunks=2, buffers=3,
                      interpret=True)


@pytest.mark.slow
def test_allgather_gemm_matches_reference_on_mesh():
    """Fused double-buffered all-gather-GEMM == shard_map(all_gather)+dot
    on an 8-virtual-device mesh (subprocess: XLA_FLAGS must predate jax)."""
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.kernels.allgather_gemm import (allgather_gemm,
                                                  allgather_gemm_reference)
        mesh = Mesh(np.array(jax.devices()), ("x",))
        rng = np.random.default_rng(1)
        X = jnp.asarray(rng.standard_normal((64, 512)), jnp.float32)
        W = jnp.asarray(rng.standard_normal((512, 128)), jnp.float32)
        for nbuf in (1, 2):
            fused = shard_map(
                lambda x, w: allgather_gemm(x, w, axis_name="x",
                                            buffers=nbuf),
                mesh=mesh, in_specs=(P(None, "x"), P()),
                out_specs=P(None, None), check_rep=False)
            ref = shard_map(
                lambda x, w: allgather_gemm_reference(x, w, axis_name="x"),
                mesh=mesh, in_specs=(P(None, "x"), P()),
                out_specs=P(None, None), check_rep=False)
            err = float(jnp.abs(fused(X, W) - ref(X, W)).max())
            assert err < 1e-3, (nbuf, err)
            print("AG_GEMM_OK", nbuf, err)
        print("ALL_AG_GEMM_OK")
    """)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env.pop("XLA_FLAGS", None)
    try:
        r = subprocess.run([sys.executable, "-c", script], env=env,
                           capture_output=True, text=True, timeout=600)
    except (OSError, PermissionError) as e:
        pytest.skip(f"sandbox cannot spawn the 8-device subprocess: {e!r}")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "ALL_AG_GEMM_OK" in r.stdout
