"""Regenerate the golden train-step fixture.

Compiles the glm4-9b smoke train step (planner loss, ``dist`` softmax,
B=8 S=16) on a 2x2 ``(data, model)`` mesh of virtual CPU devices and
writes, next to this script:

* ``train_step_2x2.hlo.txt.gz`` — the optimized-HLO text of the REAL
  compiled step (gzipped; ~650 KB raw);
* ``train_step_2x2.json`` — the sidecar: the jaxpr walker's trace, the
  declared collective schedule, and the shape/mesh provenance.

``tests/test_train_contracts.py`` replays the fixture through
``parse_collectives`` -> ``reconcile_cell`` so CI pins the whole
walker -> schedule -> HLO-parse -> reconciler chain without compiling
anything.  Re-run this script (and commit both outputs) whenever the
model code, the declared schedule, or the smoke config changes what the
train step emits:

    PYTHONPATH=src python tests/fixtures/regen_train_step_2x2.py

The script prints the reconciliation report; regenerated fixtures must
still show ``all-reduce: match`` and no ``reconcile-mismatch`` /
``reconcile-expected-only`` findings, or the tests that consume them
will (correctly) fail.
"""
import gzip
import json
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import jax                                                    # noqa: E402
import numpy as np                                            # noqa: E402
from jax.sharding import Mesh                                 # noqa: E402

from repro.analysis.hlo import parse_collectives              # noqa: E402
from repro.analysis.jaxpr import count_jaxpr                  # noqa: E402
from repro.analysis.reconcile import reconcile_cell           # noqa: E402
from repro.configs.registry import Shape, get_smoke_config    # noqa: E402
from repro.launch.specs import batch_specs, state_specs       # noqa: E402
from repro.models.model import Model                          # noqa: E402
from repro.parallel.collective_planner import (               # noqa: E402
    train_collective_schedule)
from repro.train.optimizer import OptConfig                   # noqa: E402
from repro.train.train_step import make_train_step            # noqa: E402

HERE = os.path.dirname(os.path.abspath(__file__))
B, S = 8, 16


def main() -> None:
    cfg = get_smoke_config("glm4-9b").with_(softmax_strategy="dist")
    model = Model(cfg)
    mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
    step = make_train_step(model, OptConfig(), mesh, use_planner_loss=True)
    state_ab, _ = state_specs(model, mesh)
    batch_ab = batch_specs(cfg, Shape("fixture", S, B, "train"), mesh)
    with mesh:
        compiled = jax.jit(step, donate_argnums=(0,)) \
            .lower(state_ab, batch_ab).compile()
    hlo = compiled.as_text()
    tc = count_jaxpr(jax.make_jaxpr(step)(state_ab, batch_ab))
    sched = train_collective_schedule(cfg, mesh, B, S)

    with gzip.open(os.path.join(HERE, "train_step_2x2.hlo.txt.gz"),
                   "wt") as fh:
        fh.write(hlo)
    side = {
        "arch": "glm4-9b", "smoke": True, "softmax_strategy": "dist",
        "mesh": {"data": 2, "model": 2}, "batch": B, "seq": S,
        "n_layers": cfg.n_layers,
        "jaxpr_trace": tc.to_dict(),
        "schedule": [d.to_dict() for d in sched],
    }
    with open(os.path.join(HERE, "train_step_2x2.json"), "w") as fh:
        json.dump(side, fh, indent=1)
        fh.write("\n")

    rep = reconcile_cell(tc, parse_collectives(hlo), schedule=sched,
                         loop_trip=cfg.n_layers)
    print(f"wrote fixture ({len(hlo)} HLO chars); reconciliation:")
    print(json.dumps(rep.to_dict(), indent=1))


if __name__ == "__main__":
    main()
