"""One calibration pass: reuse-or-sweep, fit, gate, persist, summarize.

``calibrate_once`` is the single orchestration point behind both the
``python -m repro.calibrate`` CLI and the benchmark gates — it owns the
reuse semantics the acceptance criteria pin down:

* a persisted ``calibrated_noc.json`` whose provenance (backend, mesh,
  jax version) matches the requested run is **reused verbatim** — the
  summary reports ``reused: true`` and ``fits_solved: 0``, no sweep
  runs, and the file is not rewritten (so re-running is bit-identical);
* otherwise the sweep runs, the fit solves once (``fits_solved: 1``),
  and the result is persisted only when it is non-degenerate and finite
  (``save_calibration`` refuses NaN) — a degenerate fit warns and
  leaves any existing file alone;
* the **error gate** compares the fitted model's predictions against
  the very sweep it was fitted on: ``median |rel err| <= gate_median``.
  A calibration that cannot reproduce its own measurements is worse
  than the preset it would replace.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

from repro.core.hardware import NoCParams

from .fitter import fit_noc_params
from .harness import SweepConfig, _warn_once, run_sweep
from .persist import (calibration_from_fit, calibration_path,
                      load_calibration, save_calibration)

__all__ = ["calibrate_once"]


def _params_json(p: NoCParams) -> Dict:
    return {"mesh": list(p.mesh), "channel_bandwidth": p.channel_bandwidth,
            "t_router": p.t_router, "t_enq": p.t_enq}


def calibrate_once(
    measure_fn: Callable[[str, int, int], float],
    reference: NoCParams,
    participants,
    *,
    backend: str,
    jax_version: str,
    store: Optional[str] = None,
    force: bool = False,
    config: Optional[SweepConfig] = None,
    gate_median: float = 0.6,
    now: Callable[[], float] = time.time,
) -> Dict:
    """Run (or reuse) one calibration; return a flat summary dict.

    ``reference`` must carry the mesh the sweep actually runs over —
    hop distances are computed on it (``_replace_mesh`` in the harness
    re-meshes a preset NoC).  ``gate_median`` bounds the median
    |relative error| of the fitted model on its own sweep.
    """
    path = calibration_path(store)
    expect = {"backend": backend, "mesh": list(reference.mesh),
              "jax_version": jax_version}

    if not force:
        cached = load_calibration(path, expect=expect)
        if cached is not None:
            return {
                "reused": True,
                "fits_solved": 0,
                "path": str(path),
                "backend": backend,
                "n_points": len(cached.points),
                "n_dropped": 0,
                "degenerate": bool(cached.provenance.get("degenerate",
                                                         False)),
                "max_rel_err": cached.max_rel_err,
                "median_rel_err": cached.median_rel_err,
                "gate_median": gate_median,
                "gate_ok": cached.median_rel_err <= gate_median,
                "persisted": True,
                "params": _params_json(cached.params),
            }

    sweep = run_sweep(measure_fn, participants, config=config)
    fit = fit_noc_params(sweep.points, reference)

    persisted_path = None
    if fit.degenerate:
        _warn_once(("calib-degenerate", backend),
                   f"calibration sweep on backend {backend!r} left "
                   f"{len(sweep.points)} usable point(s) "
                   f"(dropped: {dict(sweep.dropped)}) — fit is degenerate, "
                   f"keeping preset NoC params and persisting nothing")
    else:
        cal = calibration_from_fit(
            fit, backend=backend, jax_version=jax_version, now=now,
            extra={"dropped": dict(sweep.dropped),
                   "sweep": {"min_bytes": (config or SweepConfig()).min_bytes,
                             "max_bytes": (config or SweepConfig()).max_bytes,
                             "n_sizes": (config or SweepConfig()).n_sizes,
                             "iters": (config or SweepConfig()).iters}})
        persisted_path = save_calibration(cal, path)

    return {
        "reused": False,
        "fits_solved": 1,
        "path": str(persisted_path) if persisted_path else None,
        "backend": backend,
        "n_points": fit.n_points,
        "n_dropped": sweep.n_dropped,
        "dropped": dict(sweep.dropped),
        "degenerate": fit.degenerate,
        "max_rel_err": fit.max_rel_err,
        "median_rel_err": fit.median_rel_err,
        "gate_median": gate_median,
        "gate_ok": (not fit.degenerate
                    and fit.median_rel_err <= gate_median),
        "persisted": persisted_path is not None,
        "params": _params_json(fit.params),
    }
