from . import engine
from .engine import Request, ServeEngine

__all__ = ["engine", "Request", "ServeEngine"]
