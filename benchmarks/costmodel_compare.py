"""Fig 6 reproduction: COMET vs steady-state cost models.

(a,b) single-op GEMM vs a Timeloop-style steady-state model (perfect
pipelining, no ramp-up/ramp-down CS, no OS): energy should correlate ~1
(same access counts); COMET latency should be systematically >= steady-state
with high rank correlation.

(c,d) compound GEMM-GEMM vs a TileFlow-style model (no intermediate-reuse
credit, no inter-op dependency stalls): COMET energy lower (reuse captured),
COMET latency higher (dependency CS).
"""
from __future__ import annotations

import math
import random
from typing import Dict, List, Tuple

from repro.core.cost import CostModel, NodeCost
from repro.core.hardware import tileflow_like
from repro.core.ir import MappingSpec, build_tree, evaluate_mapping
from repro.core.mapping import CollectiveNode, ComputeNode, TileNode
from repro.core.search import candidate_specs, parallel_map, _sample
from repro.core.validate import validate_tree
from repro.core.workload import CompoundOp, Operation, TensorSpec, gemm


def steady_state_latency(root, arch, tiling, tensors) -> float:
    """Timeloop-style: per node latency = max(window, transfer); no CS/OS."""
    cm = CostModel(arch, tiling, tensors)

    def walk(node) -> Tuple[float, float]:
        """returns (latency, mem_lat)"""
        if isinstance(node, ComputeNode):
            c = cm.compute_cost(node)
            return c.latency, 0.0
        if isinstance(node, CollectiveNode):
            c = cm.collective_cost_node(node)
            return c.latency, c.mem_lat
        assert isinstance(node, TileNode)
        fracs = [getattr(ch, "exec_fraction", 1.0) for ch in node.children]
        subs = [walk(ch) for ch in node.children]
        mw = sum(l * f for (l, _), f in zip(subs, fracs))
        # recompute boundary transfer exactly as CostModel does
        full = cm.tile_cost(node)
        mem_time = full.mem_lat
        n = node.iterations
        return max(n * mw, mem_time), mem_time

    return walk(root)[0]


def _pearson(xs: List[float], ys: List[float]) -> float:
    """Pearson r; 0.0 (not NaN, not a division blow-up) for series that
    carry no correlation signal — fewer than two points, or either side
    constant (zero variance).  Pinned by tests/test_calibrate.py."""
    n = len(xs)
    if n < 2:
        return 0.0
    mx, my = sum(xs) / n, sum(ys) / n
    vx = math.sqrt(sum((x - mx) ** 2 for x in xs))
    vy = math.sqrt(sum((y - my) ** 2 for y in ys))
    if vx == 0.0 or vy == 0.0:
        return 0.0
    cov = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
    return cov / (vx * vy)


def gemm_gemm(M: int, N: int, K: int, N2: int) -> CompoundOp:
    """C = A@B ; E = C@D — the compound op of Fig 6(c,d)."""
    t = {
        "A": TensorSpec("A", ("M", "K")), "B": TensorSpec("B", ("K", "N")),
        "C": TensorSpec("C", ("M", "N")), "D": TensorSpec("D", ("N", "N2")),
        "E": TensorSpec("E", ("M", "N2")),
    }
    ops = [
        Operation("Op1_gemm", "gemm", ("M", "N", "K"), ("A", "B"), "C",
                  reduce_dims=("K",)),
        Operation("Op2_gemm", "gemm", ("M", "N2", "N"), ("C", "D"), "E",
                  reduce_dims=("N",)),
    ]
    co = CompoundOp("gemm_gemm", {"M": M, "N": N, "K": K, "N2": N2}, t, ops,
                    external_inputs=("A", "B", "D"), external_outputs=("E",))
    co.validate()
    return co


def _unique_specs(cands, keyfn, n_draws: int):
    """Deterministically sample distinct specs from the candidate space."""
    rng = random.Random(0)
    seen = set()
    specs = []
    for _ in range(n_draws):
        spec = _sample(rng, cands)
        key = keyfn(spec)
        if key in seen:
            continue
        seen.add(key)
        specs.append(spec)
    return specs


def _compare_one(args):
    """(comet latency, steady latency, comet energy) for one spec, or None
    when the mapping is rejected."""
    co, arch, spec = args
    try:
        root, tiling = build_tree(co, arch, spec)
        if not validate_tree(root, arch, tiling, co.tensors):
            return None
        r = CostModel(arch, tiling, co.tensors).evaluate(root)
        s = steady_state_latency(root, arch, tiling, co.tensors)
    except (ValueError, KeyError):
        return None
    return (r.latency, s, r.energy_pj)


def single_op_compare(n_mappings: int = 1152) -> Dict:
    """Fig 6(a,b): sweep mappings of one GEMM; compare latency models.
    The per-mapping model comparisons fan out over the parallel sweep
    driver."""
    arch = tileflow_like()
    co = gemm(256, 1024, 256)
    cands = candidate_specs(co, arch, variants=["unfused"])
    specs = _unique_specs(
        cands, lambda s: (s.m_tiles, s.k_tiles, s.n_tiles, s.schedule), 20000)
    # scalar tree evaluations are GIL-bound -> process pool
    rows = parallel_map(_compare_one, [(co, arch, s) for s in specs],
                        executor="process")
    rows = [r for r in rows if r is not None][:n_mappings]
    comet_l = [r[0] for r in rows]
    steady_l = [r[1] for r in rows]
    corr = _pearson(comet_l, steady_l)
    ratio = sum(c / max(s, 1e-12) for c, s in zip(comet_l, steady_l)) / len(comet_l)
    print(f"fig6ab_gemm_latency,{len(comet_l)},corr={corr:.3f};"
          f"comet_over_steady={ratio:.3f}(>=1 expected: staging stalls)")
    return {"corr": corr, "mean_ratio": ratio, "n": len(comet_l)}


def compound_compare() -> Dict:
    """Fig 6(c,d): GEMM-GEMM fused — TileFlow-style model misses
    intermediate reuse (higher energy) and dependency stalls (lower lat)."""
    arch = tileflow_like()
    co = gemm_gemm(256, 512, 256, 512)
    cands = candidate_specs(co, arch, variants=["fused_dist"])
    specs = _unique_specs(
        cands, lambda s: (s.m_tiles, s.k_tiles, s.n_tiles), 5000)
    results = parallel_map(_compare_one, [(co, arch, s) for s in specs],
                           executor="process")
    # TileFlow-style energy: charge DRAM for the intermediate C as if it
    # round-tripped (no reuse credit)
    c_bytes = co.tensors["C"].size_bytes(co.dim_sizes)
    tf_extra = 2 * c_bytes * arch.dram.read_energy_pj_per_byte
    rows = [(lat, s_lat, en, en + tf_extra)
            for r in results if r is not None
            for (lat, s_lat, en) in [r]][:200]
    lat_corr = _pearson([x[0] for x in rows], [x[1] for x in rows])
    en_corr = _pearson([x[2] for x in rows], [x[3] for x in rows])
    lat_ratio = sum(x[0] / max(x[1], 1e-12) for x in rows) / len(rows)
    en_ratio = sum(x[2] / x[3] for x in rows) / len(rows)
    print(f"fig6cd_compound,{len(rows)},lat_corr={lat_corr:.3f};"
          f"comet_lat_over_tf={lat_ratio:.3f}(>1: dependency stalls);"
          f"energy_corr={en_corr:.3f};comet_energy_over_tf={en_ratio:.3f}(<1: reuse)")
    return {"lat_corr": lat_corr, "lat_ratio": lat_ratio,
            "energy_corr": en_corr, "energy_ratio": en_ratio}


def collective_compare(jitter: float = 0.03, seed: int = 7) -> Dict:
    """Predicted-vs-measured collectives: sweep the synthetic backend
    with bounded jitter (a stand-in for a real mesh, same ``measure_fn``
    contract), fit ``NoCParams`` with ``repro.calibrate``, and compare
    the fitted model's Eq. 4 predictions against the measurements it was
    trained on.  Correlation should be ~1 and the median relative error
    within the jitter bound — the in-process half of the calibration
    gate (the real-CPU half runs via the ``python -m repro.calibrate``
    subprocess in search_throughput's calibration_gates)."""
    from dataclasses import replace as _replace

    from repro.calibrate import (fit_noc_params, predicted_seconds,
                                 relative_errors, run_sweep,
                                 synthetic_measure_fn)
    from repro.core.hardware import tpu_v5e

    ref = _replace(tpu_v5e().cluster_noc, mesh=(1, 8))
    sweep = run_sweep(synthetic_measure_fn(ref, jitter=jitter, seed=seed),
                      [2, 4, 8])
    fit = fit_noc_params(sweep.points, ref)
    pred = list(predicted_seconds(fit.points, fit.params))
    meas = [p.seconds for p in fit.points]
    corr = _pearson(pred, meas)
    res = sorted(abs(r) for r in relative_errors(fit.points, fit.params))
    med = res[len(res) // 2] if res else 0.0
    print(f"collective_pred_vs_meas,{len(meas)},corr={corr:.4f};"
          f"median_rel_err={med:.4f}(jitter={jitter});"
          f"max_rel_err={fit.max_rel_err:.4f}")
    return {"n": len(meas), "corr": float(corr),
            "median_rel_err": float(med),
            "max_rel_err": float(fit.max_rel_err), "jitter": jitter,
            "degenerate": fit.degenerate}


def run_all() -> Dict:
    print("# --- Fig 6(a,b): single-op vs Timeloop-style ---")
    a = single_op_compare()
    print("# --- Fig 6(c,d): compound vs TileFlow-style ---")
    b = compound_compare()
    print("# --- predicted-vs-measured collectives (repro.calibrate) ---")
    c = collective_compare()
    return {"single": a, "compound": b, "collective": c}


if __name__ == "__main__":
    run_all()
