"""COMET cost model (§IV-B, Eqs. 1–7).

Latency
-------
* Eq. 1  MemLat = DV / BW
* Eq. 2  Lat(T_n) = N·MW + CS + OS   (N temporal iterations; MW = memory
  window = per-iteration child latency; CS = compulsory stall — initial
  fill + final drain; OS = optional stall — transfer time in excess of the
  window, assuming double-buffered overlap)
* Eq. 3  NoCLat = t_router·hops + t_enq·DV/W
* Eq. 4  Lat(CO) = MemLat + NoCLat
* Eq. 5–7 scheduling: sequential = Σ children; pipelined/parallel =
  max(children) + conflictStall where conflictTime =
  Σ MemLat(children) − max(Lat(children)).

Semantics of the tree (see mapping.py):
* A :class:`TileNode` at level L represents **one instance** of that level;
  its ``spatial_loops`` give the number of peer instances (fanout).
  Latency is per-instance (instances run in parallel); energy and
  parent-boundary traffic aggregate across instances.
* ``loops`` at L iterate the parent-streamed tiles resident at L;
  children execute once per iteration (their costs scale by N).
* Tensors whose dims are **not** spatially partitioned at L are multicast:
  parent-side traffic is charged once, instance-side writes per instance.

Energy: access-count model (FLAT-style) + compute energy + Orion-style NoC
hop energy for collectives.

Compute timing: SCALE-Sim weight-stationary analytical model (GEMM tiles on
the per-core systolic grid); lanes × frequency for SIMD.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

from .collectives import collective_latency_terms
from .hardware import Arch
from .mapping import CollectiveNode, ComputeNode, Node, TileNode, Tiling
from .numerics import ceil_div, is_array, reduce_max, vmax, vmin, vwhere
from .workload import TensorSpec

__all__ = ["NodeCost", "CostModel", "systolic_gemm_cycles"]


LAT_KEYS = ("gemm", "simd", "collective", "cs", "os")
ENERGY_KEYS = ("DRAM", "GB", "corebuf", "noc", "gemm", "simd")


def _zeros(keys) -> Dict[str, float]:
    return {k: 0.0 for k in keys}


@dataclass
class NodeCost:
    latency: float = 0.0                       # seconds (per top-level execution)
    mem_lat: float = 0.0                       # boundary transfer time at this node
    energy_pj: float = 0.0
    lat_breakdown: Dict[str, float] = field(default_factory=lambda: _zeros(LAT_KEYS))
    energy_breakdown: Dict[str, float] = field(default_factory=lambda: _zeros(ENERGY_KEYS))

    def add_energy(self, key: str, pj: float) -> None:
        if self.energy_breakdown:
            self.energy_breakdown[key] += pj
        self.energy_pj = self.energy_pj + pj

    def scaled(self, lat_scale: float, energy_scale: float) -> "NodeCost":
        out = NodeCost(
            latency=self.latency * lat_scale,
            mem_lat=self.mem_lat * lat_scale,
            energy_pj=self.energy_pj * energy_scale,
            lat_breakdown={k: v * lat_scale for k, v in self.lat_breakdown.items()},
            energy_breakdown={k: v * energy_scale
                              for k, v in self.energy_breakdown.items()},
        )
        return out

    def accumulate(self, other: "NodeCost") -> None:
        if self.lat_breakdown:
            for k, v in other.lat_breakdown.items():
                self.lat_breakdown[k] += v
            for k, v in other.energy_breakdown.items():
                self.energy_breakdown[k] += v
        self.energy_pj = self.energy_pj + other.energy_pj


def _energy_key(level_name: str) -> str:
    if level_name == "DRAM":
        return "DRAM"
    if level_name == "GB":
        return "GB"
    return "corebuf"


# ------------------------------------------------------------ compute time


def systolic_gemm_cycles(m: int, n: int, k: int, rows: int, cols: int,
                         num_arrays: int) -> int:
    """Weight-stationary SCALE-Sim analytical timing for an (m,k)x(k,n) tile
    on ``num_arrays`` arrays of ``rows x cols`` PEs: the weight matrix folds
    into ceil(k/rows)*ceil(n/cols) array loads; each fold streams m rows:
    cycles = rows (fill) + m + cols - 1 (drain)."""
    folds = ceil_div(k, rows) * ceil_div(n, cols)
    per_fold = rows + m + cols - 1
    return ceil_div(folds, num_arrays) * per_fold


class CostModel:
    """Evaluates a mapping tree bottom-up (§IV-B).

    ``track_breakdown=False`` skips the per-key latency/energy breakdown
    dicts (total latency / energy / mem_lat are unaffected) — used by the
    batched engine where only the totals feed the argmin.
    """

    def __init__(self, arch: Arch, tiling: Tiling,
                 tensors: Dict[str, TensorSpec], *,
                 track_breakdown: bool = True):
        self.arch = arch
        self.tiling = tiling
        self.tensors = tensors
        self.track_breakdown = track_breakdown

    def _cost(self) -> NodeCost:
        if self.track_breakdown:
            return NodeCost()
        return NodeCost(lat_breakdown={}, energy_breakdown={})

    # ------------------------------------------------------------- leaves
    def compute_cost(self, node: ComputeNode) -> NodeCost:
        c = self._cost()
        if node.unit == "gemm":
            u = self.arch.gemm_unit
            red = node.op.reduce_dims
            out_dims = [d for d in node.op.dims if d not in red]
            m = node.tile_shape.get(out_dims[0], 1) if out_dims else 1
            n = node.tile_shape.get(out_dims[1], 1) if len(out_dims) > 1 else 1
            k = node.tile_shape.get(red[0], 1) if red else 1
            cyc = systolic_gemm_cycles(m, n, k, u.array_rows, u.array_cols,
                                       u.num_arrays)
            c.latency = cyc / u.freq_hz
            if self.track_breakdown:
                c.lat_breakdown["gemm"] = c.latency
            c.add_energy("gemm", m * n * k * u.mac_energy_pj)
        else:
            s = self.arch.simd_unit
            ops = node.points * node.op.flops_per_point
            c.latency = ops / s.peak_ops_per_sec
            if self.track_breakdown:
                c.lat_breakdown["simd"] = c.latency
            c.add_energy("simd", ops * s.op_energy_pj)
        return c

    # -------------------------------------------------------- collectives
    def collective_cost_node(self, node: CollectiveNode) -> NodeCost:
        c = self._cost()
        noc = (self.arch.cluster_noc if node.noc_level == "GB"
               else self.arch.core_noc)
        # Eq. 1 (capped by NoC BW) + Eq. 4 via the shared helper the
        # calibration fitter inverts (bit-identical to inlining it here).
        cc, mem_lat, lat_once = collective_latency_terms(
            node.col_type, node.data_volume_bytes, node.participants, noc)
        c.latency = lat_once * node.count
        c.mem_lat = mem_lat * node.count
        if self.track_breakdown:
            c.lat_breakdown["collective"] = c.latency
        c.add_energy("noc", cc.volume_bytes * cc.hops
                     * noc.hop_energy_pj_per_byte * node.count)
        if node.src:
            lvl = self.arch.level(node.src[0])
            c.add_energy(_energy_key(lvl.name),
                         lvl.access_energy(cc.volume_bytes, cc.volume_bytes)
                         * node.count)
        return c

    # --------------------------------------------------------- tile nodes
    def tile_cost(self, node: TileNode) -> NodeCost:
        child_costs = [self.evaluate(ch) for ch in node.children]
        fracs = [getattr(ch, "exec_fraction", 1.0) for ch in node.children]
        n_iter = node.iterations
        fanout = node.spatial_fanout

        c = self._cost()
        # Children execute exec_fraction * n_iter times, in every instance.
        for cc, fr in zip(child_costs, fracs):
            c.accumulate(cc.scaled(lat_scale=n_iter * fr,
                                   energy_scale=n_iter * fr * fanout))

        # Eq. 5: per-iteration memory window from children (amortized by
        # each child's execution fraction).  ``node.schedule`` is either a
        # name (scalar path) or a boolean mask array (batched path, True =
        # pipelined) — the mask folds the schedule axis into one SoA pass.
        sched = node.schedule
        sched_is_mask = is_array(sched)
        # Overlap extension to Eqs. 5–7: ``node.overlap`` in [0, 1] hides
        # that fraction of the window's *hideable* collective time (the
        # Eq. 1 mem_lat of CollectiveNode children; the Eq. 3 enqueue /
        # router term stays exposed) under sibling compute.  The hidden
        # time is capped by the compute time available to hide under, so
        # the window never drops below compute + exposed collective cost.
        # ``overlap`` may be an array (a grid axis, like the schedule
        # mask).  The guard keeps overlap == 0.0 bit-identical to the
        # pre-overlap serial charging: the code path is literally the old
        # one when overlap is the scalar 0.0, and ``x - 0.0 * y`` for the
        # array path.
        ov = node.overlap
        ov_on = is_array(ov) or ov != 0.0  # scalar-ok: scalar 0.0 short-circuit
        if ov_on:
            col_hideable = sum(
                cc.mem_lat * fr
                for cc, ch, fr in zip(child_costs, node.children, fracs)
                if isinstance(ch, CollectiveNode))
            comp_lat = sum(
                cc.latency * fr
                for cc, ch, fr in zip(child_costs, node.children, fracs)
                if not isinstance(ch, CollectiveNode))
            hidden = ov * vmin(col_hideable, comp_lat)
        else:
            hidden = 0.0
        if not child_costs:
            mw = 0.0
        elif len(child_costs) == 1:
            # single child: pipelined degenerates to sequential (stall <= 0)
            mw = child_costs[0].latency * fracs[0]
        elif not sched_is_mask and sched == "sequential":
            mw = sum(cc.latency * fr for cc, fr in zip(child_costs, fracs))
            if ov_on:
                mw = mw - hidden
                if self.track_breakdown:
                    c.lat_breakdown["collective"] -= hidden * n_iter
        else:
            mx = reduce_max(cc.latency * fr for cc, fr in zip(child_costs, fracs))
            conflict = (sum(cc.mem_lat * fr for cc, fr in zip(child_costs, fracs))
                        - mx)                                       # Eq. 7
            if ov_on:
                # hidden collective traffic no longer contends for the
                # pipeline window (Eq. 7's conflict time shrinks)
                conflict = conflict - hidden
            stall = vmax(0.0, conflict)                             # Eq. 6
            pipe = mx + stall
            if sched_is_mask:
                seq = sum(cc.latency * fr for cc, fr in zip(child_costs, fracs))
                if ov_on:
                    seq = seq - hidden
                mw = vwhere(sched, pipe, seq)
                stall = vwhere(sched, stall, 0.0)
                if self.track_breakdown and ov_on:
                    c.lat_breakdown["collective"] -= \
                        vwhere(sched, 0.0, hidden) * n_iter
            else:
                mw = pipe
            if self.track_breakdown:
                c.lat_breakdown["os"] += stall * n_iter

        # ---- boundary traffic parent(level) -> level (Eq. 1)
        parent_level = self.arch.parent_of(node.level)
        total_in = total_out = 0.0
        iter_in = iter_out = 0.0
        if parent_level is not None:
            lvl = self.arch.level(node.level)
            parent = self.arch.level(parent_level)
            eff_bw = min(lvl.bandwidth, parent.bandwidth)  # scalar-ok: arch params
            sp_factors = {lp.dim: lp.factor for lp in node.spatial_loops}

            def _traffic(t: str) -> Tuple[float, float]:
                """(parent-side bytes, instance-side bytes x fanout)."""
                spec = self.tensors[t]
                nest = node.tensor_nests.get(t)
                fetches = node.tensor_fetches(spec.dims, nest)
                tile_b = self.tiling.tensor_tile_bytes(spec, node.level, below=True)
                part = 1
                for d, f in sp_factors.items():
                    if d in spec.dims:
                        part *= f
                # parent side: partitioned slices are distinct (charge all);
                # non-partitioned dims are multicast (charge once).
                return fetches * tile_b * part, fetches * tile_b * fanout

            fill_b = drain_b = 0.0
            write_child = read_child = 0.0
            for t in node.input_tensors:
                if t in node.bypass_tensors:
                    continue
                pb, cb = _traffic(t)
                total_in += pb
                write_child += cb
                fill_b += pb / vmax(1, node.tensor_fetches(
                    self.tensors[t].dims, node.tensor_nests.get(t)))
            for t in node.output_tensors:
                if t in node.bypass_tensors:
                    continue
                pb, cb = _traffic(t)
                total_out += pb
                read_child += cb
                drain_b += pb / vmax(1, node.tensor_fetches(
                    self.tensors[t].dims, node.tensor_nests.get(t)))

            mem_time = (total_in + total_out) / eff_bw
            cs = (fill_b + drain_b) / eff_bw                       # ramp-up/down
            c.add_energy(_energy_key(parent.name),
                         parent.access_energy(total_in, total_out))
            c.add_energy(_energy_key(lvl.name),
                         lvl.access_energy(read_child, write_child))
        else:
            mem_time = 0.0
            cs = 0.0

        # Eq. 2
        window_total = n_iter * mw
        os_stall = vmax(0.0, mem_time - window_total)
        c.latency = window_total + cs + os_stall
        c.mem_lat = mem_time
        if self.track_breakdown:
            c.lat_breakdown["cs"] += cs
            c.lat_breakdown["os"] += os_stall
        return c

    # ------------------------------------------------------------ dispatch
    def evaluate(self, node: Node) -> NodeCost:
        if isinstance(node, ComputeNode):
            return self.compute_cost(node)
        if isinstance(node, CollectiveNode):
            return self.collective_cost_node(node)
        if isinstance(node, TileNode):
            return self.tile_cost(node)
        raise TypeError(type(node))
