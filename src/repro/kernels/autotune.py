"""COMET-driven Pallas block-size selection (DESIGN.md §2, kernel-level use).

This is the paper's mapping-space exploration applied to TPU tiles: for
each kernel we build the corresponding compound-op workload, instantiate a
single-core TPU-v5e hardware model, and rank candidate tile shapes with
the **shared batched evaluation engine** (core/batcheval.py) — the same
memory-fit validation + Eq. 1–7 latency model the map-space search uses,
so Pallas block selection and the analytical model cannot drift apart.
Candidate blocks map onto MappingSpec tile counts (block -> ceil(dim /
block) temporal tiles) and the whole candidate set is evaluated in one
vectorized pass.

VMEM working-set constraints mirror the kernels' actual scratch/BlockSpec
usage (those are layout facts about the kernels, not a cost model) and
pre-filter the candidate set.  Results are cached per shape.  All
functions degrade to safe hardware-aligned defaults if no candidate
survives.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

from repro.core.batcheval import Topology, evaluate_specs_batch
from repro.core.hardware import Arch, tpu_v5e
from repro.core.ir import MappingSpec, evaluate_mapping
from repro.core.workload import flash_attention, gemm_softmax, ssd_chunk

__all__ = ["attention_blocks", "gemm_epilogue_blocks", "ssd_chunk_len",
           "VMEM_BUDGET"]

# usable VMEM per core for kernel working sets (half of 128 MB, leaving room
# for Pallas double buffering which the cost model assumes)
VMEM_BUDGET = 64 * 1024 * 1024
_LANE = 128  # MXU/VPU lane alignment


def _align(x: int, a: int = _LANE) -> int:
    return max(a, (x // a) * a)


@functools.lru_cache(maxsize=4)
def _kernel_arch() -> Arch:
    """Single-chip view of the TPU for per-core block selection (the ICI
    mesh is irrelevant to one kernel invocation)."""
    return tpu_v5e(mesh=(1, 1))


def _best_candidate(br) -> int:
    """Lowest-latency candidate among memory-fit-valid mappings; when the
    arch model rejects every candidate (the kernel VMEM pre-filter is the
    binding constraint then), fall back to raw latency order."""
    i = br.best_index("latency")
    if i is not None:
        return i
    return min(range(br.size), key=lambda j: float(br.latency[j]))


SCHEDULES = ("sequential", "pipelined")


def _with_schedules(axis):
    """Duplicate a candidate axis across the schedule grid axis: the
    batched engine evaluates both Eq. 5-7 schedules per block candidate in
    the same SoA pass (Pallas pipelines its grid, so the pipelined window
    is usually the realistic one, but the cost model decides)."""
    return [v for _ in SCHEDULES for v in axis]


def _schedule_axis(n: int):
    return [s for s in SCHEDULES for _ in range(n)]


@functools.lru_cache(maxsize=256)
def attention_blocks(sq: int, skv: int, d: int) -> Tuple[int, int]:
    """(block_q, block_k) for the FlashAttention kernel via the batched
    COMET evaluator on the flash-attention compound op.

    Working set per (bq, bk): q(bq,d) + k/v(bk,d)*2 + acc(bq,d) f32 +
    s(bq,bk) f32 (+ double buffering handled by budget halving).
    """
    arch = _kernel_arch()
    cands = [128, 256, 512, 1024]
    pairs = []
    for bq in cands:
        if bq > max(sq, _LANE):
            continue
        for bk in cands:
            if bk > max(skv, _LANE):
                continue
            vmem = (bq * d * 2 + 2 * bk * d * 2 + bq * d * 4 + bq * bk * 4
                    + 2 * bq * _LANE * 4)
            if vmem * 2 > VMEM_BUDGET:
                continue
            pairs.append((bq, bk))
    if not pairs:
        return (_LANE, _LANE)
    M, N = max(sq, _LANE), max(skv, _LANE)
    co = flash_attention(M, d, N, d)
    topo = Topology(variant="fa")
    br = evaluate_specs_batch(
        co, arch, topo,
        _with_schedules([math.ceil(M / bq) for bq, _ in pairs]),
        [1] * (len(SCHEDULES) * len(pairs)),
        _with_schedules([math.ceil(N / bk) for _, bk in pairs]),
        schedule=_schedule_axis(len(pairs)))
    return pairs[_best_candidate(br) % len(pairs)]


@functools.lru_cache(maxsize=256)
def gemm_epilogue_blocks(m: int, n: int, k: int) -> Tuple[int, int]:
    """(block_m, block_k) for the fused GEMM-SM / GEMM-LN kernels via the
    batched COMET evaluator on the gemm_softmax compound op.

    Constraint: acc (block_m, N) f32 + B slice (block_k, N) must fit VMEM.
    """
    arch = _kernel_arch()
    pairs = []
    for bm in (128, 256, 512):
        for bk in (128, 256, 512):
            if bk > max(k, _LANE):
                continue
            vmem = bm * n * 4 + bk * n * 2 + bm * bk * 2 + bm * n * 2
            if vmem * 2 > VMEM_BUDGET:
                continue
            pairs.append((bm, bk))
    if not pairs:
        return (_LANE, _LANE)
    M, K = max(m, _LANE), max(k, _LANE)
    co = gemm_softmax(M, n, K)
    topo = Topology(variant="fused_dist")
    br = evaluate_specs_batch(
        co, arch, topo,
        _with_schedules([math.ceil(M / bm) for bm, _ in pairs]),
        _with_schedules([math.ceil(K / bk) for _, bk in pairs]),
        [1] * (len(SCHEDULES) * len(pairs)),
        schedule=_schedule_axis(len(pairs)))
    return pairs[_best_candidate(br) % len(pairs)]


@functools.lru_cache(maxsize=256)
def ssd_chunk_len(s: int, p: int, n: int) -> int:
    """Chunk length for the SSD kernel via the COMET ssd_chunk compound op.

    Larger chunks amortize the state GEMMs but grow the (c, c) intra-chunk
    matrix quadratically; the shared cost model finds the knee.  The chunk
    length changes the compound op's dimensions themselves, so this sweeps
    per-chunk workloads (scalar evaluations through the same model) rather
    than a tiling grid.
    """
    arch = _kernel_arch()
    best = None
    for c in (128, 256, 512):
        if c > max(s, _LANE):
            continue
        vmem = (c * p * 2 * 2 + 2 * c * n * 2 + c * c * 4 + n * p * 4)
        if vmem * 2 > VMEM_BUDGET:
            continue
        co = ssd_chunk(S=s, H=1, P=p, Dst=n, C=c)
        r = evaluate_mapping(co, arch, MappingSpec(variant="fused_dist",
                                                   m_tiles=1))
        lat = math.ceil(max(s, 1) / c) * r.latency
        if best is None or lat < best[0]:
            best = (lat, c)
    return 128 if best is None else best[1]
