"""jaxpr-vs-HLO collective reconciliation (PR 8 tentpole, part 3).

Two independent static views of a compiled cell's collective traffic
exist in this repo:

* the **jaxpr walker** (:mod:`repro.analysis.jaxpr`) sees the explicit
  collectives our shard_map bodies emit (plus their AD transposes), in
  the cost model's DV convention — exact counts, but blind to everything
  GSPMD inserts during SPMD partitioning;
* the **HLO text parse** (:mod:`repro.analysis.hlo`) sees every
  collective XLA actually emitted — complete, but a lossy text heuristic
  (async pairs, while-body scaling, tuple shapes).

Neither alone is trustworthy enough to feed the roofline: the jaxpr side
under-counts (GSPMD invisible), the HLO side mis-counts when the parse
heuristics slip or XLA rewrites a collective (all-reduce ->
reduce-scatter + all-gather reassociation).  This module compares the two
per HLO op type — with the declared ``origin == "gspmd"`` schedule
entries from :func:`~repro.parallel.collective_planner.
train_collective_schedule` filling in what the jaxpr cannot see — and
produces **reconciled** per-type wire volumes plus explicit findings for
every disagreement.  The reconciled total never undercharges: on a
mismatch it takes the larger side.

Both sides are normalized to *per-participant wire bytes* using the same
ring/recursive-doubling factors :func:`repro.analysis.hlo._wire_factor`
applies to the HLO parse, so a match means "the cost model and the
compiled program agree on what each chip puts on the wire".
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional

from .hlo import CollectiveStats, _wire_factor
from .jaxpr import TraceCounts

__all__ = ["TypeReconciliation", "ReconcileReport", "reconcile",
           "expected_wire_from_trace", "expected_wire_from_schedule",
           "reconcile_cell", "HLO_OP_FOR_TYPE"]

# COMET collective type -> optimized-HLO op name.
HLO_OP_FOR_TYPE = {
    "AllReduce": "all-reduce",
    "AllGather": "all-gather",
    "ReduceScatter": "reduce-scatter",
    "AllToAll": "all-to-all",
    "Permute": "collective-permute",
}
# ragged-all-to-all is the same logical type on the HLO side
_TYPE_FOR_HLO_OP = {v: k for k, v in HLO_OP_FOR_TYPE.items()}
_TYPE_FOR_HLO_OP["ragged-all-to-all"] = "AllToAll"

DEFAULT_TOL = 0.25  # GSPMD layouts/paddings legitimately move volumes a bit


def _wire_of(col_type: str, dv_bytes: float, participants: int) -> float:
    """Per-participant wire bytes of one collective in the DV convention
    of ``repro.analysis.jaxpr`` / ``DeclaredCollective``.

    The HLO parse applies ``_wire_factor`` to the *result* bytes; our DV
    is the result for All-Reduce/All-Gather/All-to-All/Permute but the
    full *input* for Reduce-Scatter (whose result is input/P), so the
    Reduce-Scatter factor (P-1) collapses to (P-1)/P x DV.
    """
    P = int(participants)
    if P <= 1:
        return 0.0
    op = HLO_OP_FOR_TYPE.get(col_type)
    if op is None:
        return 0.0
    if col_type == "ReduceScatter":
        return _wire_factor(op, P) * (dv_bytes / P)
    return _wire_factor(op, P) * dv_bytes


def expected_wire_from_trace(trace: TraceCounts) -> Dict[str, float]:
    """Per-HLO-op expected wire bytes from a jaxpr walk (explicit ops)."""
    out: Dict[str, float] = {}
    for (col_type, P), rec in trace.collectives.items():
        op = HLO_OP_FOR_TYPE.get(col_type)
        if op is None or P <= 1:
            continue
        out[op] = out.get(op, 0.0) + _wire_of(col_type, rec.dv_bytes, P)
    return out


def expected_wire_from_schedule(schedule: Iterable,
                                origins: Iterable[str] = ("gspmd",),
                                ) -> Dict[str, float]:
    """Per-HLO-op expected wire bytes from ``DeclaredCollective`` entries.

    Defaults to the ``gspmd`` origin only: explicit entries are already
    present in the jaxpr trace, and adding both would double-charge.
    """
    origins = set(origins)
    out: Dict[str, float] = {}
    for d in schedule:
        if d.origin not in origins or d.participants <= 1:
            continue
        op = HLO_OP_FOR_TYPE.get(d.col_type)
        if op is None:
            continue
        out[op] = out.get(op, 0.0) + d.count * _wire_of(
            d.col_type, d.dv_bytes, d.participants)
    return out


@dataclass
class TypeReconciliation:
    """Expected-vs-HLO verdict for one collective op type."""

    hlo_op: str
    expected_wire: float
    hlo_wire: float
    status: str            # match | mismatch | hlo-only | expected-only
    reconciled_wire: float

    @property
    def rel_err(self) -> float:
        base = max(abs(self.expected_wire), abs(self.hlo_wire))
        return abs(self.expected_wire - self.hlo_wire) / base if base else 0.0

    def to_dict(self) -> Dict:
        return {"hlo_op": self.hlo_op, "expected_wire": self.expected_wire,
                "hlo_wire": self.hlo_wire, "status": self.status,
                "rel_err": self.rel_err,
                "reconciled_wire": self.reconciled_wire}


@dataclass
class ReconcileReport:
    per_type: Dict[str, TypeReconciliation] = field(default_factory=dict)
    findings: List[Dict] = field(default_factory=list)
    tolerance: float = DEFAULT_TOL

    @property
    def clean(self) -> bool:
        return not self.findings

    @property
    def total_reconciled_wire(self) -> float:
        return sum(t.reconciled_wire for t in self.per_type.values())

    @property
    def total_hlo_wire(self) -> float:
        return sum(t.hlo_wire for t in self.per_type.values())

    @property
    def total_expected_wire(self) -> float:
        return sum(t.expected_wire for t in self.per_type.values())

    def to_dict(self) -> Dict:
        return {"clean": self.clean, "tolerance": self.tolerance,
                "total_reconciled_wire": self.total_reconciled_wire,
                "total_hlo_wire": self.total_hlo_wire,
                "total_expected_wire": self.total_expected_wire,
                "per_type": {k: t.to_dict()
                             for k, t in sorted(self.per_type.items())},
                "findings": list(self.findings)}

    def describe_findings(self) -> str:
        return "\n".join(f"[{f['kind']}] {f['detail']}"
                         for f in self.findings)


def reconcile(expected: Dict[str, float], stats: CollectiveStats, *,
              loop_trip: int = 1,
              tol: float = DEFAULT_TOL) -> ReconcileReport:
    """Compare expected per-op wire bytes against an HLO parse.

    ``loop_trip`` scales collectives XLA emitted inside while-loop bodies
    (scanned layers compile to one body executed ``n_layers`` times).
    Per op type the verdict is one of:

    * ``match`` — within ``tol``; the roofline uses the HLO number.
    * ``mismatch`` — both sides present but disagree; the roofline uses
      the LARGER side (never undercharge) and a finding names the gap.
    * ``hlo-only`` — XLA emitted collectives nothing declared (GSPMD
      resharding, all-reduce reassociation); charged as parsed, flagged.
    * ``expected-only`` — declared/traced ops absent from the HLO (XLA
      eliminated a redundant transpose psum, or the parse missed an op);
      charged as expected, flagged.
    """
    hlo_wire: Dict[str, float] = {}
    for op, v in stats.by_type.items():
        hlo_wire[op] = hlo_wire.get(op, 0.0) + v[2] + v[3] * loop_trip
    # fold ragged-all-to-all into all-to-all for the comparison
    if "ragged-all-to-all" in hlo_wire:
        hlo_wire["all-to-all"] = (hlo_wire.get("all-to-all", 0.0)
                                  + hlo_wire.pop("ragged-all-to-all"))

    report = ReconcileReport(tolerance=tol)
    for op in sorted(set(expected) | set(hlo_wire)):
        e = float(expected.get(op, 0.0))
        h = float(hlo_wire.get(op, 0.0))
        if e == 0.0 and h == 0.0:
            # zero-wire entries (single-participant groups) carry no signal
            report.per_type[op] = TypeReconciliation(op, 0.0, 0.0,
                                                     "match", 0.0)
            continue
        if e > 0.0 and h > 0.0:
            base = max(e, h)
            if abs(e - h) / base <= tol:
                status, rec_wire = "match", h
            else:
                status, rec_wire = "mismatch", max(e, h)
                report.findings.append({
                    "kind": "reconcile-mismatch",
                    "hlo_op": op,
                    "detail": (f"{op}: declared/traced wire {e:.4g} B vs "
                               f"HLO {h:.4g} B (rel_err "
                               f"{abs(e - h) / base:.2f} > tol {tol:g}); "
                               f"roofline charges the larger side")})
        elif h > 0.0:
            status, rec_wire = "hlo-only", h
            report.findings.append({
                "kind": "reconcile-hlo-only",
                "hlo_op": op,
                "detail": (f"{op}: HLO executes {h:.4g} wire bytes with no "
                           f"declared or traced counterpart (GSPMD-inserted "
                           f"resharding or collective rewrite)")})
        else:
            status, rec_wire = "expected-only", e
            report.findings.append({
                "kind": "reconcile-expected-only",
                "hlo_op": op,
                "detail": (f"{op}: {e:.4g} declared/traced wire bytes never "
                           f"appear in the compiled HLO (XLA eliminated the "
                           f"op, or the text parse missed it)")})
        report.per_type[op] = TypeReconciliation(op, e, h, status, rec_wire)
    return report


def reconcile_cell(trace: Optional[TraceCounts], stats: CollectiveStats, *,
                   schedule: Optional[Iterable] = None, loop_trip: int = 1,
                   tol: float = DEFAULT_TOL) -> ReconcileReport:
    """One-call reconciliation for a dry-run cell: expected = the jaxpr
    walk's explicit collectives + the declared GSPMD-origin schedule
    entries (if a schedule is provided), compared against the HLO parse."""
    expected: Dict[str, float] = {}
    if trace is not None:
        expected = expected_wire_from_trace(trace)
    if schedule is not None:
        for op, w in expected_wire_from_schedule(schedule).items():
            expected[op] = expected.get(op, 0.0) + w
    return reconcile(expected, stats, loop_trip=loop_trip, tol=tol)
