"""deepseek-v3-671b [moe]: MLA (q-LoRA 1536 / kv-LoRA 512 / rope 64),
1 shared + 256 routed experts top-8 (sigmoid aux-free router), 3 leading
dense layers (dense d_ff 18432; per-expert d_ff 2048 per the brief).
MTP head omitted (DESIGN.md §5).  [arXiv:2412.19437]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        head_dim=128, d_ff=18432, vocab_size=129280,
        attn_type="mla", q_lora_rank=1536, kv_lora_rank=512,
        rope_head_dim=64, v_head_dim=128,
        n_experts=256, n_shared_experts=1, top_k=8, moe_d_ff=2048,
        first_dense_layers=3, router_type="sigmoid",
        rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, q_lora_rank=32, kv_lora_rank=16,
        rope_head_dim=8, v_head_dim=16, n_experts=8, top_k=2,
        moe_d_ff=32, first_dense_layers=1, name="deepseek-smoke")
