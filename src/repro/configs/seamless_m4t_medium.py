"""seamless-m4t-medium [audio]: enc-dec backbone (12 enc + 12 dec layers,
LayerNorm); speech frontend stubbed — input_specs() provides precomputed
frame embeddings at seq/enc_ratio.  [arXiv:2308.11596]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="audio",
        n_layers=12, n_enc_layers=12, d_model=1024, n_heads=16,
        n_kv_heads=16, d_ff=4096, vocab_size=256206,
        norm_type="layernorm", enc_ratio=8, rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=1024, name="seamless-smoke")
