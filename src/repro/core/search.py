"""Map-space search (COMET §V-A).

The 4-D design space of Fig. 1 — tiling factors x loop order/spatial
unrolling x collective strategy x scheduling — factors into a handful of
discrete *topologies* and a dense grid per topology (see
:mod:`.batcheval`): temporal tiling counts, the ``sp_cluster``/``sp_core``
spatial unrolling fanouts and the schedule mask are all grid axes.  For
the paper's compound ops the whole enumerable space is a few thousand
points, so ``search()`` is **exhaustive by default**: every topology's
grid is evaluated in one vectorized pass and the global optimum is
returned.  When the grid exceeds ``exhaustive_limit`` (custom candidate
sets, huge dims) it falls back to the paper's randomized + hill-climb
sampling (budget up to 10,000 iterations, deterministic under ``seed``),
now served through a shared LRU evaluation cache.

The spatial-fanout axes are **divisor-complete** by default: the
``sp_cluster``/``sp_core`` candidate sets take every divisor of the
physical instance counts *and* every divisor of the partitioned workload
dims that fits them (so a 3-way unrolling of N=768 on a 4-cluster mesh is
enumerated, not just powers of two), on top of the power-of-two ladder.
``candidate_specs(..., fanouts='pow2')`` recovers the old sets and
``divisor_tilings=True`` extends the m/k/n temporal axes the same way.

``objective='pareto'`` returns the latency/energy Pareto front instead of
a single scalar winner: ``SearchResult.front`` holds the non-dominated
(latency, energy_pj, spec) points in ascending-latency order and
``SearchResult.best`` is the front's minimum-latency mapping.
``objective='pareto3'`` adds the capacity-headroom channel for
provisioning studies: front points are (latency, energy_pj, headroom,
spec), latency/energy minimized and headroom maximized.

``search(..., candidate_list=[MappingSpec, ...])`` is **candidates
mode**: an explicit (possibly correlated) spec list is evaluated through
the batched engine instead of the enumerated axes — the entry point the
kernel autotuner and the :mod:`repro.core.plan` layer use for
VMEM-prefiltered tile-pair sweeps.

``search_many()`` fans independent (workload, arch, kwargs) search cells
out over a ``concurrent.futures`` pool — the sweep driver used by the
benchmark harnesses.  Process-pool chunk assignment is **size-aware** by
default (``chunking='size'``): jobs are ordered by estimated space size
and dealt longest-first round-robin across chunks, so a ~117k-point
exhaustive job starts immediately instead of serializing behind tiny
cells; ``chunking='contiguous'`` restores plain slicing.  Chunking only
moves jobs between workers — results always come back in job order and
stay bit-identical.

**Executor contract** (``search_many``/``parallel_map``): results are
always returned in job order and are bit-identical across executors —
the same grids are evaluated by the same code regardless of where they
run, so ``executor='serial' | 'thread' | 'process'`` may be swapped
freely for scale without perturbing any reported optimum.

* ``'serial'`` — everything in the calling thread; the baseline the
  other executors must reproduce exactly.
* ``'thread'`` — a ``ThreadPoolExecutor`` sharing the in-process LRU
  grid/spec caches; cheap to start but GIL-bound on the Python parts of
  tree construction.
* ``'process'`` — a ``ProcessPoolExecutor`` fed **chunks** of jobs (so
  per-worker caches amortize across a chunk and pool workers persist
  across chunks).  Exhaustive-mode jobs return their per-topology
  :class:`~repro.core.batcheval.BatchResult` grids through
  ``multiprocessing.shared_memory`` segments — the parent reattaches the
  arrays zero-copy (:func:`repro.core.batcheval.batch_from_shm`) and
  runs the same reduction as the serial path; only tiny
  :class:`~repro.core.batcheval.ShmBatchRef` descriptors cross the
  pickle channel.  Randomized-mode jobs (space above the exhaustive
  limit) return their small ``SearchResult`` via pickle as before.
  Segment lifecycle: workers create, the parent unlinks after reduction;
  a sweep-scoped name prefix lets :func:`cleanup_shm_segments` reclaim
  segments orphaned by a worker crash, and the reclamation runs on every
  sweep exit (success, error or ``BrokenProcessPool``).
* ``'auto'`` — ``'process'`` for sweeps of at least
  ``PROCESS_MIN_JOBS`` jobs when shared memory works on the platform,
  else ``'thread'``.

Degradations warn instead of failing: an unavailable process pool falls
back to threads, and a pool that *breaks* mid-sweep (OOM-killed worker)
finishes the remaining jobs serially — both emit a ``RuntimeWarning``.
"""
from __future__ import annotations

import inspect
import math
import os
import random
import secrets
import warnings
from concurrent.futures import (BrokenExecutor, ProcessPoolExecutor,
                                ThreadPoolExecutor)
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from .batcheval import (OBJECTIVES, BatchResult, ParetoArchive, Topology,
                        batch_from_shm, batch_to_shm, enumerate_topologies,
                        evaluate_cached, evaluate_specs_batch,
                        evaluate_topology_grid, grid_size, pareto_merge,
                        pareto_merge3, shm_unlink)
from .hardware import Arch
from .ir import MappingResult, MappingSpec, evaluate_mapping
from .workload import CompoundOp

__all__ = ["SearchResult", "search", "search_many", "parallel_map",
           "candidate_specs", "pow2_tilings", "divisors",
           "fanout_candidates", "cleanup_shm_segments",
           "EXHAUSTIVE_LIMIT", "PROCESS_MIN_JOBS", "OVERLAP_CANDIDATES"]

# Exhaustive enumeration cap: above this many grid points per search the
# randomized fallback kicks in.  The paper-space grids are ~1e3-3e4
# points; re-budgeted (PR 4) so the divisor-tiling paper-table spaces —
# the largest is the non-pow2 provisioning GEMM on cloud at ~117k points
# — stay exhaustive.
EXHAUSTIVE_LIMIT = 131072

# Default compute–collective overlap candidate axis for overlap-searched
# runs (``search(..., overlap=OVERLAP_CANDIDATES)``).  0.0 keeps the
# serial point in the space (so the overlap-searched best can never lose
# to the serial best); 1.0 is the full double-buffered hiding the fused
# all-gather-GEMM kernel demonstrates; 0.5 is a conservative midpoint for
# schedules whose compute windows only partially cover the collective.
# A calibrated achievable overlap (``repro.calibrate.overlap``) replaces
# the upper rungs when available.
OVERLAP_CANDIDATES = (0.0, 0.5, 1.0)

# search_many(executor='auto') switches from threads to the process pool
# at this many jobs: below it, pool fork/spawn overhead dominates the
# sweep; above it, bypassing the GIL wins.
PROCESS_MIN_JOBS = 8

# Randomized fallback: how many resamples one iteration spends to dodge
# an already-seen spec before conceding the iteration, and the bound on
# the online Pareto archive (ROADMAP: don't hold every valid sample).
DUPLICATE_RETRIES = 16
ARCHIVE_MAXLEN = 512


@dataclass
class SearchResult:
    best: MappingResult
    evaluated: int
    valid: int
    # (iteration, best objective score so far): latency/energy/edp score
    # for scalar objectives, latency (the hill-climb steer) for the front
    # objectives — NOT unconditionally latency.
    history: List[Tuple[int, float]] = field(default_factory=list)
    mode: str = "randomized"    # 'exhaustive' | 'randomized' | 'candidates'
    # objective='pareto': non-dominated (latency, energy_pj, spec) points,
    # ascending latency; objective='pareto3': (latency, energy_pj,
    # headroom, spec).  None for scalar objectives.
    front: Optional[List[Tuple]] = None
    # mode='candidates': index of the winning spec in the caller's
    # ``candidate_list`` (None for the enumerated modes).
    best_index: Optional[int] = None

    @property
    def latency(self) -> float:
        return self.best.latency

    @property
    def energy_pj(self) -> float:
        return self.best.energy_pj


def pow2_tilings(size: int, cap: int = 4096) -> List[int]:
    """Candidate temporal tile counts for a dimension: powers of two up to
    min(size, cap), always including 1 and the full size when small."""
    out = [1]
    t = 2
    while t <= min(size, cap):
        out.append(t)
        t *= 2
    if size <= cap and size not in out:
        out.append(size)
    return out


def divisors(n: int, cap: int = 4096) -> List[int]:
    """All divisors of ``n`` up to ``cap``, ascending (always >= [1])."""
    n = int(n)
    if n <= 1:
        return [1]
    out = set()
    for d in range(1, math.isqrt(n) + 1):
        if n % d == 0:
            if d <= cap:
                out.add(d)
            q = n // d
            if q <= cap:
                out.add(q)
    return sorted(out)


def _with_divisors(base: List[int], size: int, cap: int) -> List[int]:
    """Union of a pow2 ladder with the divisors of ``size`` up to ``cap``."""
    return sorted(set(base) | set(divisors(size, cap=cap)))


def fanout_candidates(instances: int, dim_sizes: Sequence[int] = ()
                      ) -> List[int]:
    """Divisor-complete spatial-fanout candidates for a level with
    ``instances`` physical peers: the power-of-two ladder (so the set is
    always a superset of the old candidates), every divisor of the
    instance count, and every divisor of the partitioned workload dims
    that fits the level — e.g. N=768 on a 4-cluster mesh adds the 3-way
    unrolling that pow2 sets never consider."""
    out = set(pow2_tilings(instances)) | set(divisors(instances))
    for size in dim_sizes:
        out |= set(divisors(int(size), cap=instances))
    return sorted(out)


def _partition_dim_sizes(co: CompoundOp) -> List[int]:
    """The dim sizes the tree builders spatially partition: M/N for the
    GEMM-epilogue and attention families, every dim for the generic
    builder (it picks the most-shared dim at build time)."""
    sizes = [v for d, v in co.dim_sizes.items() if d in ("M", "N")]
    return sizes or list(co.dim_sizes.values())


def candidate_specs(co: CompoundOp, arch: Arch, *,
                    variants: Optional[Sequence[str]] = None,
                    allow_stats_gran: bool = False,
                    fanouts: str = "divisors",
                    divisor_tilings: bool = False,
                    overlap: Optional[Sequence[float]] = None
                    ) -> Dict[str, List]:
    """The discrete choice sets for each MappingSpec field.

    ``fanouts='divisors'`` (default) makes the sp_cluster/sp_core axes
    divisor-complete (:func:`fanout_candidates`); ``'pow2'`` restores the
    power-of-two-only sets.  ``divisor_tilings=True`` additionally unions
    the m/k/n temporal tile counts with the divisors of their dims (same
    caps), for workloads whose dims have non-pow2 factors.

    ``overlap`` is the compute–collective overlap candidate axis (values
    in [0, 1]); ``None`` (default) pins it to ``[0.0]`` — the pre-overlap
    serial charging, so existing searches stay bit-identical.  Pass
    :data:`OVERLAP_CANDIDATES` (or a calibrated achievable overlap from
    ``repro.calibrate.overlap``) to let the search hide collective time
    under dependency-adjacent compute.
    """
    M = co.dim_sizes.get("M", 1)
    K = co.dim_sizes.get("K", 1)
    N = co.dim_sizes.get("N", 1)
    if variants is None:
        if co.name in ("attention", "flash_attention"):
            variants = ["ua", "pfa", "fa"]
        elif co.name in ("gemm_softmax", "gemm_layernorm"):
            variants = ["unfused", "fused_epilogue", "fused_std", "fused_dist"]
        else:
            variants = ["unfused", "fused_dist"]
    grans = ["tile", "stats"] if allow_stats_gran else ["tile"]
    m_tiles = pow2_tilings(M)
    k_tiles = pow2_tilings(K, cap=64)
    n_tiles = pow2_tilings(N, cap=256)
    if divisor_tilings:
        m_tiles = _with_divisors(m_tiles, M, 4096)
        k_tiles = _with_divisors(k_tiles, K, 64)
        n_tiles = _with_divisors(n_tiles, N, 256)
    if fanouts == "pow2":
        sp_cluster = pow2_tilings(arch.num_clusters)
        sp_core = pow2_tilings(arch.cores_per_cluster)
    elif fanouts == "divisors":
        # Spatial unrolling fanouts (Fig. 1 axis 2): divisor-complete
        # candidate sets — free grid axes of the batched engine, costed
        # through the tabulated per-P collective factors.
        part = _partition_dim_sizes(co)
        sp_cluster = fanout_candidates(arch.num_clusters, part)
        sp_core = fanout_candidates(arch.cores_per_cluster, part)
    else:
        raise ValueError(f"unknown fanouts mode {fanouts!r}")
    if overlap is None:
        overlaps = [0.0]
    else:
        overlaps = [float(o) for o in overlap]
        if not overlaps or any(o < 0.0 or o > 1.0 for o in overlaps):
            raise ValueError("overlap candidates must lie in [0, 1]")
    return {
        "variant": list(variants),
        "m_tiles": m_tiles,
        "k_tiles": k_tiles,
        "n_tiles": n_tiles,
        "sp_cluster": sp_cluster,
        "sp_core": sp_core,
        "schedule": ["sequential", "pipelined"],
        "overlap": overlaps,
        "collective_gran": grans,
        "loop_order_gb": [("M", "N"), ("N", "M")],
    }


def _sample(rng: random.Random, cands: Dict[str, List]) -> MappingSpec:
    return MappingSpec(
        variant=rng.choice(cands["variant"]),
        m_tiles=rng.choice(cands["m_tiles"]),
        k_tiles=rng.choice(cands["k_tiles"]),
        n_tiles=rng.choice(cands["n_tiles"]),
        sp_cluster=rng.choice(cands["sp_cluster"]),
        sp_core=rng.choice(cands["sp_core"]),
        schedule=rng.choice(cands["schedule"]),
        overlap=rng.choice(cands.get("overlap", [0.0])),
        collective_gran=rng.choice(cands["collective_gran"]),
        loop_order_gb=rng.choice(cands["loop_order_gb"]),
    )


def _mutate(rng: random.Random, spec: MappingSpec, cands: Dict[str, List]) -> MappingSpec:
    fieldname = rng.choice(list(cands.keys()))
    return replace(spec, **{fieldname: rng.choice(cands[fieldname])})


def _score_of(latency: float, energy_pj: float, valid: bool,
              objective: str) -> float:
    if not valid:
        return math.inf
    if objective == "latency":
        return latency
    if objective == "energy":
        return energy_pj
    return latency * energy_pj


# ------------------------------------------------------------------ search


def search(co: CompoundOp, arch: Arch, *,
           budget: int = 2000,
           seed: int = 0,
           objective: str = "latency",
           variants: Optional[Sequence[str]] = None,
           allow_stats_gran: bool = False,
           fanouts: str = "divisors",
           divisor_tilings: bool = False,
           overlap: Optional[Sequence[float]] = None,
           hillclimb_frac: float = 0.5,
           mode: str = "auto",
           exhaustive_limit: int = EXHAUSTIVE_LIMIT,
           candidate_list: Optional[Sequence[MappingSpec]] = None
           ) -> SearchResult:
    """Map-space search.  ``objective`` is 'latency', 'energy', 'edp'
    (energy-delay product), 'pareto' (latency/energy front) or 'pareto3'
    (latency/energy/capacity-headroom front; see ``SearchResult.front``).

    ``fanouts``/``divisor_tilings`` select the candidate axes (see
    :func:`candidate_specs`): divisor-complete spatial fanouts by default,
    ``fanouts='pow2'`` for the legacy power-of-two-only sets.

    ``mode``: 'exhaustive' evaluates the whole enumerable space through
    the batched engine; 'randomized' is the paper's sampling + hill-climb;
    'auto' (default) picks exhaustive whenever the space fits within
    ``exhaustive_limit`` points — which is both faster and provably
    no-worse than any sampled subset of the same space.

    ``candidate_list`` switches to **candidates mode**: instead of
    enumerating the generic axes, the explicit list of
    :class:`~repro.core.ir.MappingSpec` candidates is evaluated through
    the batched engine (grouped by topology, original order preserved)
    and the best one wins.  This is the kernel-autotuning entry point:
    correlated candidate sets (e.g. VMEM-prefiltered (block_q, block_k)
    pairs) cannot be expressed as a product grid.  Selection: lowest
    objective score among the memory-fit-valid candidates; when the arch
    model rejects every candidate (a kernel pre-filter is the binding
    constraint then), lowest raw latency.  ``SearchResult.best_index``
    reports the winner's position in the list.  Scalar objectives only.
    """
    mode, cands, objective = _plan_search(co, arch, {
        "objective": objective, "variants": variants,
        "allow_stats_gran": allow_stats_gran, "fanouts": fanouts,
        "divisor_tilings": divisor_tilings, "overlap": overlap,
        "mode": mode, "exhaustive_limit": exhaustive_limit,
        "candidate_list": candidate_list})
    if mode == "candidates":
        return _search_candidates(co, arch, list(candidate_list), objective)
    if mode == "exhaustive":
        return _search_exhaustive(co, arch, cands, objective)
    if mode == "randomized":
        return _search_randomized(co, arch, cands, budget=budget, seed=seed,
                                  objective=objective,
                                  hillclimb_frac=hillclimb_frac)
    raise ValueError(f"unknown search mode {mode!r}")


def _plan_search(co: CompoundOp, arch: Arch, kw: Dict
                 ) -> Tuple[str, Dict[str, List], str]:
    """Resolve a search job's (mode, candidate axes, objective) exactly
    as :func:`search` would — same kwarg defaults (read from search()'s
    own signature, so they cannot drift), same auto rule — without
    running it.  Shared by ``search()`` and the process-pool sweep
    workers so both sides of the wire agree on the search plan."""
    def opt(name: str):
        return kw.get(name, _SEARCH_DEFAULTS[name])

    objective = opt("objective")
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}")
    if opt("candidate_list") is not None:
        if objective in ("pareto", "pareto3"):
            raise ValueError(
                "candidate_list mode supports scalar objectives only")
        return "candidates", {}, objective
    cands = candidate_specs(
        co, arch, variants=opt("variants"),
        allow_stats_gran=opt("allow_stats_gran"),
        fanouts=opt("fanouts"),
        divisor_tilings=opt("divisor_tilings"),
        overlap=opt("overlap"))
    mode = opt("mode")
    if mode == "auto":
        topos = enumerate_topologies(co, cands)
        total = len(topos) * grid_size(co, cands)
        mode = ("exhaustive" if total <= opt("exhaustive_limit")
                else "randomized")
    return mode, cands, objective


def _search_exhaustive(co: CompoundOp, arch: Arch, cands: Dict[str, List],
                       objective: str) -> SearchResult:
    grids = (evaluate_topology_grid(co, arch, topo, cands)
             for topo in enumerate_topologies(co, cands))
    return _reduce_grids(co, arch, grids, objective)


def _search_candidates(co: CompoundOp, arch: Arch,
                       specs: List[MappingSpec],
                       objective: str) -> SearchResult:
    """Candidates mode: evaluate an explicit spec list through the batched
    engine.  Specs are grouped by topology (variant/collective granularity
    /GB loop order) so each group is one SoA pass with the schedule as a
    parallel axis; scores land back at the specs' original positions, so
    selection order (ties included) matches evaluating the list in order.
    """
    import numpy as np

    if not specs:
        raise ValueError("candidate_list is empty")
    n = len(specs)
    lat = np.empty(n)
    en = np.empty(n)
    valid = np.zeros(n, dtype=bool)
    groups: Dict[Tuple, List[int]] = {}
    for i, s in enumerate(specs):
        groups.setdefault(
            (s.variant, s.collective_gran, tuple(s.loop_order_gb)),
            []).append(i)
    for (variant, gran, lo), idxs in groups.items():
        topo = Topology(variant=variant, collective_gran=gran,
                        loop_order_gb=lo)
        ovs = [specs[i].overlap for i in idxs]
        br = evaluate_specs_batch(
            co, arch, topo,
            [specs[i].m_tiles for i in idxs],
            [specs[i].k_tiles for i in idxs],
            [specs[i].n_tiles for i in idxs],
            [specs[i].sp_cluster for i in idxs],
            [specs[i].sp_core for i in idxs],
            [specs[i].schedule for i in idxs],
            # all-serial candidate lists keep the bit-identical
            # pre-overlap path
            ovs if any(o != 0.0 for o in ovs) else None)
        lat[idxs] = br.latency
        en[idxs] = br.energy_pj
        valid[idxs] = br.valid
    if valid.any():
        scores = np.where(
            valid,
            lat if objective == "latency"
            else en if objective == "energy" else lat * en,
            np.inf)
        i = int(np.argmin(scores))
        score = float(scores[i])
    else:
        # every candidate rejected by the arch model: the caller's own
        # pre-filters (e.g. kernel VMEM constraints) are the binding
        # constraint, so fall back to raw latency order
        i = int(np.argmin(lat))
        score = float(lat[i])
    best = evaluate_mapping(co, arch, specs[i])
    return SearchResult(best=best, evaluated=n, valid=int(valid.sum()),
                        history=[(n, score)], mode="candidates",
                        best_index=i)


def _reduce_grids(co: CompoundOp, arch: Arch, grids: Iterable[BatchResult],
                  objective: str) -> SearchResult:
    """Fold per-topology grids into a SearchResult.  This is the single
    reduction used by the serial/thread paths (grids evaluated in
    process) AND the process-pool parent (grids reattached from shared
    memory), which is what makes executor choice bit-invisible."""
    pareto = objective in ("pareto", "pareto3")
    best_spec: Optional[MappingSpec] = None
    best_score = math.inf
    best_latency = math.inf
    evaluated = valid = 0
    history: List[Tuple[int, float]] = []
    front_pts: List[Tuple] = []
    for br in grids:
        evaluated += br.size
        valid += int(br.valid.sum())
        if objective == "pareto3":
            front_pts.extend(
                (float(br.latency[i]), float(br.energy_pj[i]),
                 float(br.headroom[i]), br.spec_at(i))
                for i in br.pareto_front3())
            continue
        if objective == "pareto":
            # per-topology vectorized skyline; merged globally below
            front_pts.extend(
                (float(br.latency[i]), float(br.energy_pj[i]), br.spec_at(i))
                for i in br.pareto_front())
            continue
        i = br.best_index(objective)
        if i is None:
            continue
        s = float(br.scores(objective)[i])
        if s < best_score:
            best_score = s
            best_spec = br.spec_at(i)
            best_latency = float(br.latency[i])
            history.append((evaluated, s))
    front: Optional[List[Tuple]] = None
    if pareto:
        front = (pareto_merge3(front_pts) if objective == "pareto3"
                 else pareto_merge(front_pts))
        if front:
            best_latency = front[0][0]
            best_spec = front[0][-1]
            history.append((evaluated, best_latency))
    if best_spec is None:
        raise RuntimeError(f"no valid mapping found for {co.name} on {arch.name}")
    best = evaluate_mapping(co, arch, best_spec)
    return SearchResult(best=best, evaluated=evaluated, valid=valid,
                        history=history, mode="exhaustive", front=front)


def _search_randomized(co: CompoundOp, arch: Arch, cands: Dict[str, List], *,
                       budget: int, seed: int, objective: str,
                       hillclimb_frac: float) -> SearchResult:
    pareto = objective in ("pareto", "pareto3")
    # Front modes keep a bounded online non-dominated archive instead of
    # every valid sample (ROADMAP); latency steers the hill-climb.
    scalar_objective = "latency" if pareto else objective
    rng = random.Random(seed)
    best_spec: Optional[MappingSpec] = None
    best_score = math.inf
    evaluated = valid = 0
    history: List[Tuple[int, float]] = []
    archive = (ParetoArchive(dims=3 if objective == "pareto3" else 2,
                             maxlen=ARCHIVE_MAXLEN) if pareto else None)
    seen = set()

    explore = max(1, int(budget * (1.0 - hillclimb_frac)))
    for i in range(budget):
        # An already-seen spec would burn the iteration without learning
        # anything — resample (bounded) until an unseen one turns up.
        spec = None
        for _ in range(DUPLICATE_RETRIES):
            cand = (_sample(rng, cands) if best_spec is None or i < explore
                    else _mutate(rng, best_spec, cands))
            if cand not in seen:
                spec = cand
                break
        if spec is None:
            continue
        seen.add(spec)
        r = evaluate_cached(co, arch, spec)
        if r is None:
            continue
        latency, energy_pj, is_valid, headroom = r
        evaluated += 1
        if is_valid:
            valid += 1
            if objective == "pareto3":
                archive.add((latency, energy_pj, headroom, spec))
            elif objective == "pareto":
                archive.add((latency, energy_pj, spec))
        s = _score_of(latency, energy_pj, is_valid, scalar_objective)
        if s < best_score:
            best_spec, best_score = spec, s
            # convergence curve logs the objective score (== latency for
            # the latency-steered front modes), not latency regardless
            history.append((i, s))

    if best_spec is None:
        raise RuntimeError(f"no valid mapping found for {co.name} on {arch.name}")
    best = evaluate_mapping(co, arch, best_spec)
    return SearchResult(best=best, evaluated=evaluated, valid=valid,
                        history=history, mode="randomized",
                        front=archive.front() if pareto else None)


# ------------------------------------------------------------ sweep driver


def _norm_job(job) -> Tuple[CompoundOp, Arch, Dict]:
    if isinstance(job, dict):
        kw = dict(job)
        return kw.pop("co"), kw.pop("arch"), kw
    if len(job) == 2:
        co, arch = job
        return co, arch, {}
    co, arch, kw = job
    return co, arch, dict(kw)


def _run_search_job(job) -> SearchResult:
    co, arch, kw = _norm_job(job)
    return search(co, arch, **kw)


def parallel_map(fn: Callable, items: Sequence, *,
                 max_workers: Optional[int] = None,
                 executor: str = "auto") -> List:
    """Order-preserving parallel map over independent work items.

    ``executor``: 'thread' (default under 'auto' — shares the in-process
    evaluation caches and NumPy releases the GIL in the hot loops),
    'process' (bypasses the GIL; items/results must pickle), or 'serial'.
    Falls back to serial execution when a pool cannot be created (e.g.
    sandboxed environments without working multiprocessing primitives),
    and — for the items not yet completed — when the pool *breaks*
    mid-sweep (a worker killed by the OOM killer or a signal raises
    ``BrokenProcessPool`` out of ``pool.map``); a RuntimeWarning is
    emitted so the degradation is visible.  Ordinary exceptions raised by
    ``fn`` itself always propagate.
    """
    items = list(items)
    if executor == "serial" or len(items) <= 1:
        return [fn(it) for it in items]
    pool_cls = ProcessPoolExecutor if executor == "process" else ThreadPoolExecutor
    try:
        pool = pool_cls(max_workers=max_workers)
    except (OSError, PermissionError, ImportError) as e:
        # Pool creation failed (e.g. sandbox without multiprocessing
        # primitives) — errors raised by fn itself still propagate below.
        warnings.warn(
            f"parallel_map: could not create a {executor!r} pool ({e!r}); "
            "running serially", RuntimeWarning, stacklevel=2)
        return [fn(it) for it in items]
    results: List = []
    try:
        with pool:
            if executor == "process":
                # Amortize per-item pickling for short tasks.
                chunk = max(1, len(items)
                            // (32 * (max_workers or os.cpu_count() or 4)))
                it = pool.map(fn, items, chunksize=chunk)
            else:
                it = pool.map(fn, items)
            for r in it:
                results.append(r)
    except BrokenExecutor as e:
        # A worker died mid-sweep (e.g. OOM-killed): salvage the completed
        # prefix and finish the remaining items serially instead of losing
        # the whole sweep.
        warnings.warn(
            f"parallel_map: worker pool broke after {len(results)}/"
            f"{len(items)} items ({e!r}); finishing remaining items "
            "serially", RuntimeWarning, stacklevel=2)
        results.extend(fn(it) for it in items[len(results):])
    return results


def _shm_usable() -> bool:
    """One-shot probe: can this platform create (and unlink) a
    ``multiprocessing.shared_memory`` segment with POSIX persist-until-
    unlink semantics?  Memoized — sandboxes without /dev/shm or the
    _posixshmem module probe once, not per sweep.  Non-POSIX platforms
    are excluded outright: Windows named shared memory is freed when the
    last handle closes, so the create-in-worker / close / attach-in-
    parent lifecycle would lose the segment before the parent attaches
    (jobs then take the pickle wire instead)."""
    global _SHM_USABLE
    if _SHM_USABLE is None:
        if os.name != "posix":
            _SHM_USABLE = False
            return _SHM_USABLE
        try:
            from multiprocessing import shared_memory
            seg = shared_memory.SharedMemory(create=True, size=1)
            seg.close()
            seg.unlink()
            _SHM_USABLE = True
        except Exception:
            _SHM_USABLE = False
    return _SHM_USABLE


_SHM_USABLE: Optional[bool] = None


def cleanup_shm_segments(prefix: str) -> List[str]:
    """Best-effort reclamation of shared-memory segments whose names
    start with ``prefix`` (a sweep-scoped token): unlinks and returns the
    names found.  This is the crash backstop of the process-pool sweep —
    a worker that dies between creating a segment and returning its
    :class:`~repro.core.batcheval.ShmBatchRef` orphans the segment, and
    the parent cannot learn its name through the broken pool.  POSIX
    ``/dev/shm`` scan; a no-op on platforms without it."""
    removed: List[str] = []
    base = "/dev/shm"
    if not os.path.isdir(base):
        return removed
    try:
        names = os.listdir(base)
    except OSError:
        return removed
    for fn in names:
        if fn.startswith(prefix) and shm_unlink(fn):
            removed.append(fn)
    return removed


# Keyword arguments search() accepts and their default values — derived
# from the signature so the process-path kwarg validation and
# _plan_search's defaults can never drift from search() itself.
_SEARCH_DEFAULTS = {
    name: p.default for name, p in inspect.signature(search).parameters.items()
    if name not in ("co", "arch")}
_SEARCH_KWARGS = frozenset(_SEARCH_DEFAULTS)


def _run_search_chunk(payload: Tuple) -> List[Tuple]:
    """Process-pool worker: run a chunk of search jobs, one wire tuple
    per job.  Exhaustive-mode jobs evaluate their per-topology grids
    (through the worker's persistent LRU grid cache — chunking exists so
    repeated (co, arch) cells amortize it) and ship them as
    ``('grids', objective, [ShmBatchRef, ...])``; randomized-mode jobs
    (or all jobs when shared memory is unusable) run to completion and
    ship ``('result', SearchResult)`` through pickle."""
    prefix, use_shm, chunk = payload
    out: List[Tuple] = []
    for job in chunk:
        co, arch, kw = _norm_job(job)
        # The shm shortcut reads kwargs with .get() defaults, so an
        # unknown (typoed) kwarg must NOT be silently ignored here while
        # serial/thread raise TypeError from search(**kw): fall through
        # to search() so every executor rejects the job identically.
        if use_shm and set(kw) <= _SEARCH_KWARGS:
            mode, cands, objective = _plan_search(co, arch, kw)
            if mode == "exhaustive":
                refs = []
                try:
                    for topo in enumerate_topologies(co, cands):
                        br = evaluate_topology_grid(co, arch, topo, cands)
                        refs.append(batch_to_shm(br, prefix=prefix))
                except BaseException:
                    # the job dies with its segments, not with a leak
                    for ref in refs:
                        shm_unlink(ref.shm_name)
                    raise
                out.append(("grids", objective, refs))
                continue
            if mode == "randomized":
                # reuse the resolved plan instead of paying
                # candidate/topology enumeration again inside search()
                out.append(("result", _search_randomized(
                    co, arch, cands,
                    budget=kw.get("budget", _SEARCH_DEFAULTS["budget"]),
                    seed=kw.get("seed", _SEARCH_DEFAULTS["seed"]),
                    objective=objective,
                    hillclimb_frac=kw.get(
                        "hillclimb_frac",
                        _SEARCH_DEFAULTS["hillclimb_frac"]))))
                continue
            # an explicitly-passed unknown mode falls through: search()
            # raises the same ValueError the serial path would
        out.append(("result", search(co, arch, **kw)))
    return out


def _attach_refs(refs: Sequence, brs: List[BatchResult],
                 shms: List) -> None:
    """Attach every ref, appending in lockstep (in its own frame so no
    stray local keeps a view alive past the caller's cleanup)."""
    for ref in refs:
        br, shm = batch_from_shm(ref)
        brs.append(br)
        shms.append(shm)


def _finish_wire(co: CompoundOp, arch: Arch, wire: Tuple) -> SearchResult:
    """Parent-side completion of one worker wire tuple.  For ``'grids'``
    wires: reattach each BatchResult zero-copy, run the shared
    :func:`_reduce_grids` reduction (identical to the serial path), then
    unlink the segments — on success or failure."""
    if wire[0] == "result":
        return wire[1]
    _kind, objective, refs = wire
    shms: List = []
    brs: List[BatchResult] = []
    try:
        _attach_refs(refs, brs, shms)
        return _reduce_grids(co, arch, brs, objective)
    finally:
        brs.clear()                  # drop the views before close()
        for shm in shms:
            try:
                shm.close()
            except BufferError:      # a view outlived the reduction
                pass
            try:
                shm.unlink()
            except FileNotFoundError:
                pass
        for ref in refs[len(shms):]:     # attach failed partway
            shm_unlink(ref.shm_name)


def _job_size_estimate(co: CompoundOp, arch: Arch, kw: Dict) -> int:
    """Rough per-job cost proxy for size-aware chunk assignment: grid
    points x topologies for exhaustive-bound jobs, the sampling budget
    for randomized ones, the list length for candidates mode.  Only
    relative order matters; any failure degrades to 1 (the job still
    runs, it just gets no scheduling priority)."""
    try:
        cl = kw.get("candidate_list")
        if cl is not None:
            return len(cl)
        if not set(kw) <= _SEARCH_KWARGS:
            return 1
        mode, cands, _obj = _plan_search(co, arch, kw)
        if mode == "randomized":
            return int(kw.get("budget", _SEARCH_DEFAULTS["budget"]))
        return len(enumerate_topologies(co, cands)) * grid_size(co, cands)
    except Exception:
        return 1


def _make_chunks(jobs: List[Tuple[CompoundOp, Arch, Dict]], chunksize: int,
                 chunking: str) -> List[List[Tuple[int, Tuple]]]:
    """Split ``jobs`` into chunks of ``(original_index, job)`` pairs.

    ``chunking='size'`` (default) orders jobs by estimated space size and
    assigns them longest-first round-robin across the chunks, so a single
    ~117k-point exhaustive job starts immediately instead of serializing
    behind a chunk of tiny ones (ROADMAP: job costs vary by ~100x when
    randomized cells sit next to tiny exhaustive cells).
    ``chunking='contiguous'`` keeps the pre-PR-5 contiguous slices.
    Either way results are reassembled in job order and each job's
    evaluation is untouched, so the executor bit-identity contract holds.
    """
    n_chunks = max(1, math.ceil(len(jobs) / chunksize))
    indexed = list(enumerate(jobs))
    if chunking == "contiguous":
        return [indexed[i:i + chunksize]
                for i in range(0, len(indexed), chunksize)]
    if chunking != "size":
        raise ValueError(f"unknown chunking mode {chunking!r}")
    sizes = [_job_size_estimate(co, arch, kw) for co, arch, kw in jobs]
    order = sorted(range(len(jobs)), key=lambda i: (-sizes[i], i))
    chunks = [[indexed[i] for i in order[c::n_chunks]]
              for c in range(n_chunks)]
    return [c for c in chunks if c]


def _search_many_process(jobs: List[Tuple[CompoundOp, Arch, Dict]], *,
                         max_workers: Optional[int],
                         chunksize: Optional[int],
                         chunking: str = "size") -> List[SearchResult]:
    """The process-pool sweep path: chunked job scheduling over a
    ``ProcessPoolExecutor`` with shared-memory grid transport.  Falls
    back — warning, never failing — to threads when the pool cannot be
    created and to serial execution of the remaining jobs when the pool
    breaks mid-sweep; every exit path reclaims the sweep's segments."""
    use_shm = _shm_usable()
    # Short sweep-scoped prefix: batch_to_shm appends '_' + 8 hex chars
    # and macOS caps shm names at 31 chars including the leading slash.
    prefix = f"cm{os.getpid():x}x{secrets.token_hex(2)}"
    workers = max_workers or os.cpu_count() or 2
    if chunksize is None:
        # ~4 chunks per worker: coarse enough to amortize per-chunk
        # dispatch and per-worker cache warmup, fine enough to balance.
        chunksize = max(1, math.ceil(len(jobs) / (workers * 4)))
    chunks = _make_chunks(jobs, chunksize, chunking)
    try:
        pool = ProcessPoolExecutor(max_workers=max_workers)
    except (OSError, PermissionError, ImportError) as e:
        warnings.warn(
            f"search_many: process pool unavailable ({e!r}); falling back "
            "to threads", RuntimeWarning, stacklevel=3)
        return parallel_map(_run_search_job, jobs, max_workers=max_workers,
                            executor="thread")
    results: List[Optional[SearchResult]] = [None] * len(jobs)
    done = 0
    broken: Optional[BaseException] = None
    try:
        with pool:
            # Bounded submission window (~2 chunks in flight per worker,
            # refilled as results drain): results are consumed strictly
            # in order, so submitting everything upfront would let
            # completed-but-unconsumed grids pile up in /dev/shm behind
            # one slow early chunk — worst case the whole sweep's grid
            # bytes against a RAM-capped tmpfs.
            window = max(2 * workers, 1)
            pending: List[Tuple[List, object]] = []
            submitted = 0

            def refill() -> None:
                nonlocal submitted
                while submitted < len(chunks) and len(pending) < window:
                    c = chunks[submitted]
                    pending.append(
                        (c, pool.submit(_run_search_chunk,
                                        (prefix, use_shm,
                                         [job for _i, job in c]))))
                    submitted += 1

            refill()
            while pending:
                chunk, fut = pending.pop(0)
                try:
                    wires = fut.result()
                except BrokenExecutor as e:
                    broken = e
                    for _c, f in pending:
                        f.cancel()
                    break
                refill()        # keep workers busy during the reduction
                for (idx, (co, arch, _kw)), wire in zip(chunk, wires):
                    results[idx] = _finish_wire(co, arch, wire)
                    done += 1
        if broken is not None:
            warnings.warn(
                f"search_many: worker pool broke after {done}/"
                f"{len(jobs)} jobs ({broken!r}); finishing remaining jobs "
                "serially", RuntimeWarning, stacklevel=3)
            for i, job in enumerate(jobs):
                if results[i] is None:
                    results[i] = _run_search_job(job)
    finally:
        # Reclaims segments orphaned by a crashed worker (their refs
        # never arrived) or dropped mid-delivery; finds nothing on the
        # clean path, where _finish_wire unlinked each segment already.
        cleanup_shm_segments(prefix)
    return results


def search_many(jobs: Sequence, *,
                max_workers: Optional[int] = None,
                executor: str = "auto",
                chunksize: Optional[int] = None,
                chunking: str = "size") -> List[SearchResult]:
    """Parallel sweep driver: run many independent searches concurrently.

    Each job is ``(co, arch)``, ``(co, arch, kwargs)`` or a dict with
    ``co``/``arch`` keys plus search kwargs.  Results come back in job
    order and are bit-identical across executors (see the module
    docstring for the full executor contract).

    ``executor='process'`` runs jobs in chunks (``chunksize`` jobs per
    task, default ~4 chunks per worker) on a process pool, shipping
    exhaustive-mode grids back through shared memory; ``'thread'`` and
    ``'serial'`` behave as before; ``'auto'`` picks ``'process'`` for
    sweeps of at least ``PROCESS_MIN_JOBS`` jobs when the platform
    supports shared memory, else ``'thread'``.  Used by
    ``benchmarks/paper_tables.py`` and friends to fan out
    (workload, arch, variant) cells.

    ``chunking`` selects how jobs map to process-pool chunks:
    ``'size'`` (default) estimates each job's space size and assigns
    longest-first round-robin so one huge exhaustive job cannot
    serialize behind a chunk of tiny ones; ``'contiguous'`` slices jobs
    in order.  Chunk assignment never changes any result — only
    scheduling (results are reassembled in job order either way).
    """
    if chunking not in ("size", "contiguous"):
        raise ValueError(f"unknown chunking mode {chunking!r}")
    jobs = [_norm_job(j) for j in jobs]
    if executor == "auto":
        executor = ("process"
                    if len(jobs) >= PROCESS_MIN_JOBS and _shm_usable()
                    else "thread")
    if executor == "process" and len(jobs) > 1:
        return _search_many_process(jobs, max_workers=max_workers,
                                    chunksize=chunksize, chunking=chunking)
    return parallel_map(_run_search_job, jobs, max_workers=max_workers,
                        executor=executor)
