"""Scalar/array-polymorphic arithmetic helpers.

The cost model (Eqs. 1-7), the tree builders and the validator are written
once and evaluated through two paths:

* the classic per-spec path, where every tiling parameter is a Python int
  and results are Python floats;
* the batched path (core/batcheval.py), where the numeric tiling
  parameters are NumPy int arrays spanning a whole grid of mapping
  instances and every intermediate quantity becomes a structure-of-arrays.

These helpers dispatch between the two so both paths execute the *same*
formulas: ``ceil_div`` uses exact integer ceil-division (identical for
ints and int arrays), and ``vmax``/``vmin`` fall back to builtin
``max``/``min`` for scalars so the per-spec path keeps producing plain
Python numbers.
"""
from __future__ import annotations

import numpy as np

__all__ = ["ceil_div", "vmax", "vmin", "vwhere", "is_array", "reduce_max"]


def is_array(x) -> bool:
    return isinstance(x, np.ndarray)


def ceil_div(a, b):
    """Exact ceil(a / b) for non-negative ints or int arrays."""
    return -(-a // b)


def vmax(a, b):
    """Elementwise max that preserves Python scalars on the scalar path."""
    if is_array(a) or is_array(b):
        return np.maximum(a, b)
    return a if a >= b else b  # scalar-ok: the scalar fallback itself


def vmin(a, b):
    """Elementwise min that preserves Python scalars on the scalar path."""
    if is_array(a) or is_array(b):
        return np.minimum(a, b)
    return a if a <= b else b  # scalar-ok: the scalar fallback itself


def vwhere(mask, a, b):
    """Elementwise mask-select that preserves Python scalars on the scalar
    path (used by the Eq. 5-7 schedule select in the batched engine)."""
    if is_array(mask) or is_array(a) or is_array(b):
        return np.where(mask, a, b)
    return a if mask else b


def reduce_max(values):
    """max() over a non-empty sequence of scalars and/or arrays."""
    it = iter(values)
    out = next(it)
    for v in it:
        out = vmax(out, v)
    return out
