# Launchers: mesh construction, multi-pod dry-run, training and serving
# drivers.  NOTE: dryrun must be run as a module entry point so its
# XLA_FLAGS line executes before jax initializes.
