"""AdamW with global-norm clipping, cosine LR schedule, optional int8
gradient compression with error feedback, and ZeRO-1 state sharding
(opt moments sharded over the data axes — see parallel/sharding.py).

Implemented from scratch (no optax dependency); fp32 moments over bf16
params (mixed-precision master-less AdamW: the update is computed in f32
and cast back).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..parallel.compression import compress_with_feedback

F32 = jnp.float32

__all__ = ["OptConfig", "OptState", "init_opt_state", "adamw_update",
           "cosine_lr", "global_norm"]


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    grad_compression: bool = False      # int8 + error feedback


class OptState(NamedTuple):
    step: jax.Array          # () int32
    m: Any                   # pytree f32, like params
    v: Any                   # pytree f32, like params
    err: Any                 # error-feedback pytree (or empty tuple)


def init_opt_state(params, *, compression: bool = False) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, F32)
    m = jax.tree.map(zeros, params)
    v = jax.tree.map(zeros, params)
    err = jax.tree.map(zeros, params) if compression else None
    return OptState(step=jnp.zeros((), jnp.int32), m=m, v=v, err=err)


def cosine_lr(cfg: OptConfig, step: jax.Array) -> jax.Array:
    s = step.astype(F32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(1, cfg.warmup_steps))
    t = jnp.clip((s - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(F32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: OptConfig, params, grads, state: OptState
                 ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """One AdamW step; returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(F32) * scale, grads)

    if cfg.grad_compression and state.err is not None:
        pairs = jax.tree.map(compress_with_feedback, grads, state.err)
        grads = jax.tree.map(lambda pr: pr[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda pr: pr[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
    else:
        new_err = state.err

    step = state.step + 1
    lr = cosine_lr(cfg, state.step)
    b1, b2 = cfg.betas
    bc1 = 1.0 - b1 ** step.astype(F32)
    bc2 = 1.0 - b2 ** step.astype(F32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.v, grads)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_m, new_v, new_err), metrics
