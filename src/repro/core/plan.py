"""MappingPlan subsystem: durable search results shared across the stack.

The same Eq. 1-7 cost model that drives offline map-space search also
picks Pallas tile shapes at trace time and sizes serving-engine kernels —
but a search result that lives in a per-process ``lru_cache`` is re-solved
by every process that needs it.  This module promotes a solved mapping to
a first-class, **JSON-serializable artifact** (the DFModel treatment of
mappings as persisted design points) so the loop closes:

    benchmarks/paper_tables sweep ──┐
    kernels/autotune trace-time ────┼──>  PlanCache  <── serve/launch warmup
    ServeEngine startup warmup ─────┘      │     │
                                      in-memory  ~/.cache/repro-plans/*.json
                                        dict      (or $REPRO_PLAN_CACHE)

* :class:`MappingPlan` — frozen record of one solved search: the compound
  op signature, the architecture fingerprint, the winning
  :class:`~repro.core.ir.MappingSpec`, the predicted latency / energy /
  capacity headroom, and the engine version that produced it.
* :class:`PlanCache` — two-level cache: an in-memory dict in front of an
  atomic-write JSON store (one file per plan).  Keys are
  ``(arch_sig, op_sig, engine_version, search-kw fingerprint)``: the full
  :meth:`~repro.core.hardware.Arch.signature` and compound-op signature
  (never names alone), the :data:`ENGINE_VERSION` (bump it when the cost
  model or search semantics change and every stored plan self-invalidates)
  and a fingerprint of the search kwargs (two searches over the same
  workload with different objectives or candidate lists are different
  plans).
* :meth:`PlanCache.resolve` — hit or solve-and-persist through the shared
  :func:`repro.core.search.search` engine; :meth:`PlanCache.warmup` fans
  all anticipated shapes through :func:`repro.core.search.search_many`
  (``executor='auto'``) in one sweep.
* :meth:`PlanCache.export_bundle` / :meth:`PlanCache.import_bundle` —
  single-file plan bundles: a benchmark host exports its sweep, a serving
  host imports it and never solves at startup.

Durability contract (see :mod:`repro.core.planstore` for the storage
engine): the disk layer is a degradation ladder — a SQLite WAL store
with LRU/age eviction, provenance and busy-retry, falling back to the
legacy atomic-write JSON directory (auto-migrated into SQLite on first
open) and finally to memory-only.  Concurrent writers race benignly
(last writer wins, both wrote the same solution); readers never observe
partial plans.  A corrupted or stale-version record is treated as a
miss — warn, quarantine, re-solve, overwrite.  Any unrecoverable store
fault demotes the cache down the ladder with **one** warning per cause
instead of failing (or spamming) the caller.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import secrets
import threading
import warnings
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from . import planstore
from .batcheval import co_signature
from .hardware import Arch
from .ir import MappingSpec
from .planstore import PlanStore
from .search import SearchResult, search, search_many
from .workload import CompoundOp

__all__ = ["ENGINE_VERSION", "MappingPlan", "PlanCache", "get_plan_cache",
           "arch_fingerprint", "op_fingerprint", "kw_fingerprint",
           "DEFAULT_CACHE_DIR"]

# Version of the (cost model + search) engine whose predictions a stored
# plan embodies.  Bump on any change that can alter a chosen mapping or
# its predicted numbers: every persisted plan whose version mismatches is
# ignored and re-solved.
# v6: MappingSpec/plans carry the compute–collective ``overlap`` axis.
ENGINE_VERSION = 6

DEFAULT_CACHE_DIR = "~/.cache/repro-plans"
_ENV_VAR = "REPRO_PLAN_CACHE"


# ------------------------------------------------------------ fingerprints


def _hex(obj) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


def arch_fingerprint(arch: Arch) -> str:
    """Stable hex fingerprint of the *full* architecture parameter
    signature (:meth:`Arch.signature`), never the name alone.  Memoized
    on the (frozen) instance — this sits on the per-call key path of
    every plan lookup."""
    fp = arch.__dict__.get("_plan_fp_memo")
    if fp is None:
        fp = _hex(arch.signature())
        object.__setattr__(arch, "_plan_fp_memo", fp)
    return fp


def op_fingerprint(co: CompoundOp) -> str:
    """Stable hex fingerprint of the compound-op signature (name, dims,
    tensor layouts).  Memoized on the instance: a CompoundOp is built
    once and treated as immutable by the whole engine."""
    fp = getattr(co, "_plan_fp_memo", None)
    if fp is None:
        fp = _hex(co_signature(co))
        co._plan_fp_memo = fp
    return fp


# Sequence-value fingerprints (candidate_list is a sequence of
# MappingSpecs whose repr costs tens of microseconds) memoized by object
# identity — the strong reference in the table keeps the id from being
# recycled.  Only **tuples** are memoized: a caller-supplied list can be
# mutated in place after its first lookup, which would silently serve a
# stale plan, so lists are re-fingerprinted every time (the autotuner
# passes tuples, so its hot path still hits the memo).
_SEQ_FP_MEMO: Dict[int, Tuple[object, str]] = {}


def _seq_fp(v) -> str:
    if not isinstance(v, tuple):
        return _hex(tuple(v))
    hit = _SEQ_FP_MEMO.get(id(v))
    if hit is not None and hit[0] is v:
        return hit[1]
    fp = _hex(v)
    if len(_SEQ_FP_MEMO) > 4096:
        _SEQ_FP_MEMO.clear()
    _SEQ_FP_MEMO[id(v)] = (v, fp)
    return fp


def kw_fingerprint(search_kw: Dict) -> str:
    """Stable hex fingerprint of a search-kwargs dict.  MappingSpec lists
    (``candidate_list``) repr deterministically; kwargs are sorted by
    name so argument order never splits the key space."""
    items = []
    for k in sorted(search_kw):
        v = search_kw[k]
        if isinstance(v, (list, tuple)):
            v = ("seq", _seq_fp(v))
        items.append((k, v))
    return _hex(tuple(items))


# ------------------------------------------------------------------- plan


def _spec_to_json(spec: MappingSpec) -> Dict:
    d = dataclasses.asdict(spec)
    d["loop_order_gb"] = list(d["loop_order_gb"])
    return d


def _spec_from_json(d: Dict) -> MappingSpec:
    kw = dict(d)
    kw["loop_order_gb"] = tuple(kw["loop_order_gb"])
    return MappingSpec(**kw)


@dataclass(frozen=True)
class MappingPlan:
    """One solved mapping, frozen and JSON-roundtrippable.

    ``op_name``/``op_dims`` are the human-readable identity;
    ``op_sig``/``arch_sig`` are the exact cache-key fingerprints (the
    full signatures hashed), so a plan can be matched back to its
    workload/arch without re-deriving anything.
    """

    op_name: str
    op_dims: Tuple[Tuple[str, int], ...]
    op_sig: str                      # op_fingerprint(co)
    arch_name: str
    arch_sig: str                    # arch_fingerprint(arch)
    spec: MappingSpec
    latency_s: float
    energy_pj: float
    headroom: float
    headroom_levels: Tuple[Tuple[str, float], ...]
    engine_version: int
    search_mode: str                 # 'exhaustive'|'randomized'|'candidates'
    evaluated: int
    # mode='candidates': winner's index in the caller's candidate_list
    best_index: Optional[int] = None

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d["op_dims"] = [list(p) for p in self.op_dims]
        d["headroom_levels"] = [list(p) for p in self.headroom_levels]
        d["spec"] = _spec_to_json(self.spec)
        return d

    @classmethod
    def from_json(cls, d: Dict) -> "MappingPlan":
        kw = dict(d)
        kw["op_dims"] = tuple((str(k), int(v)) for k, v in d["op_dims"])
        kw["headroom_levels"] = tuple(
            (str(k), float(v)) for k, v in d["headroom_levels"])
        kw["spec"] = _spec_from_json(d["spec"])
        return cls(**kw)

    @classmethod
    def from_search(cls, co: CompoundOp, arch: Arch,
                    result: SearchResult) -> "MappingPlan":
        best = result.best
        return cls(
            op_name=co.name,
            op_dims=tuple(sorted(co.dim_sizes.items())),
            op_sig=op_fingerprint(co),
            arch_name=arch.name,
            arch_sig=arch_fingerprint(arch),
            spec=best.spec,
            latency_s=float(best.latency),
            energy_pj=float(best.energy_pj),
            headroom=float(best.headroom),
            headroom_levels=tuple(sorted(
                (k, float(v)) for k, v in best.headroom_levels.items())),
            engine_version=ENGINE_VERSION,
            search_mode=result.mode,
            evaluated=result.evaluated,
            best_index=result.best_index)


# ------------------------------------------------------------------ cache


PlanKey = Tuple[str, str, int, str]     # (arch_sig, op_sig, version, kw_sig)


class PlanCache:
    """Two-level plan cache: in-memory dict over a durable
    :class:`~repro.core.planstore.PlanStore` (``$REPRO_PLAN_CACHE`` or
    ``~/.cache/repro-plans``; SQLite WAL with JSON-dir and memory-only
    fallbacks).

    Thread-safe; process-safe through the store's write atomicity
    (concurrent resolvers of the same key each solve once and the last
    writer wins — both wrote the same plan).  ``stats`` counts
    memory/disk hits, misses (solves), stores and corrupt records
    tolerated; :meth:`store_stats` adds the store's own provenance view
    (row counts, bytes, per-version/per-sweep breakdowns).
    """

    def __init__(self, root: Optional[str] = None, *,
                 store: Optional[PlanStore] = None,
                 backend: Optional[str] = None,
                 max_bytes: Optional[int] = None,
                 max_plans: Optional[int] = None,
                 max_age_s: Optional[float] = None):
        if root is None:
            root = os.environ.get(_ENV_VAR) or DEFAULT_CACHE_DIR
        self.root = Path(root).expanduser()
        self.store = store if store is not None else PlanStore(
            self.root, backend=backend, max_bytes=max_bytes,
            max_plans=max_plans, max_age_s=max_age_s)
        self._mem: Dict[PlanKey, MappingPlan] = {}
        self._lock = threading.Lock()
        self.stats = {"hits_mem": 0, "hits_disk": 0, "misses": 0,
                      "stores": 0, "corrupt": 0}

    # ------------------------------------------------------------- keying

    def key(self, co: CompoundOp, arch: Arch, search_kw: Dict) -> PlanKey:
        return (arch_fingerprint(arch), op_fingerprint(co), ENGINE_VERSION,
                kw_fingerprint(search_kw))

    # --------------------------------------------------------------- disk

    def _load_disk(self, key: PlanKey) -> Optional[MappingPlan]:
        raw = self.store.get(key)
        if raw is None:
            return None
        try:
            d = json.loads(raw)
            plan = MappingPlan.from_json(d["plan"])
            if tuple(d["key"]) != key:          # hash collision / tamper
                raise ValueError("key mismatch")
            if plan.engine_version != ENGINE_VERSION:
                raise ValueError("engine version mismatch")
            return plan
        except (ValueError, KeyError, TypeError) as e:
            self.stats["corrupt"] += 1
            quarantined = self.store.discard(key)
            warnings.warn(
                f"PlanCache: ignoring corrupted stored plan {key} ({e!r}); "
                + ("quarantined; " if quarantined else "") + "re-solving",
                RuntimeWarning, stacklevel=3)
            return None

    def _store_disk(self, key: PlanKey, plan: MappingPlan,
                    sweep_id: Optional[str] = None) -> None:
        payload = json.dumps({"key": list(key), "plan": plan.to_json()},
                             indent=1)
        if self.store.put(key, payload, sweep_id=sweep_id):
            self.stats["stores"] += 1

    # -------------------------------------------------- store maintenance

    def gc(self, **kw) -> Dict[str, int]:
        """Run the store's garbage collection: age expiry plus LRU
        eviction down to the (optionally overridden) size bounds, then
        vacuum.  Returns ``{'expired': n, 'evicted': n}``."""
        return self.store.gc(**kw)

    def invalidate(self, *, engine_version: Optional[int] = None,
                   sweep_id: Optional[str] = None,
                   older_than_s: Optional[float] = None) -> int:
        """Delete exactly the stored plans matching the provenance
        filters (e.g. ``engine_version=4`` removes a stale generation
        after a cost-model bump) and drop matching in-memory entries.
        Returns the number of store rows removed."""
        n = self.store.invalidate(engine_version=engine_version,
                                  sweep_id=sweep_id,
                                  older_than_s=older_than_s)
        with self._lock:
            if engine_version is not None and sweep_id is None \
                    and older_than_s is None:
                drop = [k for k in self._mem if k[2] == engine_version]
            else:
                # memory entries carry no sweep/created provenance: be
                # conservative and drop everything (they re-load cheaply)
                drop = list(self._mem)
            for k in drop:
                del self._mem[k]
        return n

    def store_stats(self) -> Dict:
        """Cache counters plus the store's provenance/size view."""
        with self._lock:
            out = dict(self.stats, mem_plans=len(self._mem))
        out["store"] = self.store.stats()
        return out

    # ------------------------------------------------------------- lookup

    def lookup(self, co: CompoundOp, arch: Arch,
               **search_kw) -> Optional[MappingPlan]:
        """Memory-then-disk lookup; never solves."""
        key = self.key(co, arch, search_kw)
        with self._lock:
            plan = self._mem.get(key)
            if plan is not None:
                self.stats["hits_mem"] += 1
                return plan
        plan = self._load_disk(key)
        if plan is not None:
            with self._lock:
                self._mem[key] = plan
                self.stats["hits_disk"] += 1
        return plan

    def resolve(self, co: CompoundOp, arch: Arch,
                **search_kw) -> MappingPlan:
        """Return the cached plan for ``(co, arch, search_kw)`` or solve
        it through the shared :func:`repro.core.search.search` engine and
        persist the result."""
        plan = self.lookup(co, arch, **search_kw)
        if plan is not None:
            return plan
        result = search(co, arch, **search_kw)
        return self._admit(co, arch, search_kw, result)

    def _admit(self, co: CompoundOp, arch: Arch, search_kw: Dict,
               result: SearchResult,
               sweep_id: Optional[str] = None) -> MappingPlan:
        key = self.key(co, arch, search_kw)
        plan = MappingPlan.from_search(co, arch, result)
        with self._lock:
            self._mem[key] = plan
            self.stats["misses"] += 1
        self._store_disk(key, plan, sweep_id=sweep_id)
        return plan

    # ------------------------------------------------------------- warmup

    def warmup(self, jobs: Sequence, *,
               executor: str = "auto",
               max_workers: Optional[int] = None,
               sweep_id: Optional[str] = None) -> Dict[str, int]:
        """Pre-solve many plans in one sweep.  Each job is ``(co, arch)``,
        ``(co, arch, kwargs)`` or a ``co``/``arch`` dict (the
        :func:`repro.core.search.search_many` job forms).  Jobs already
        planned are skipped; the misses fan out through ``search_many``
        (size-aware process-pool chunking under ``executor='auto'``) and
        every result is persisted with ``sweep_id`` provenance (an
        auto-generated token when not given, so the whole warmup is
        queryable/invalidatable as one generation).  Returns counts."""
        norm: List[Tuple[CompoundOp, Arch, Dict]] = []
        for job in jobs:
            if isinstance(job, dict):
                kw = dict(job)
                norm.append((kw.pop("co"), kw.pop("arch"), kw))
            elif len(job) == 2:
                norm.append((job[0], job[1], {}))
            else:
                norm.append((job[0], job[1], dict(job[2])))
        misses, seen = [], set()
        for co, arch, kw in norm:
            key = self.key(co, arch, kw)
            # dedupe by plan key: a repeated (co, arch, kwargs) cell in
            # one sweep would otherwise be solved once per copy
            if key in seen or self.lookup(co, arch, **kw) is not None:
                continue
            seen.add(key)
            misses.append((co, arch, kw))
        if misses:
            sid = planstore.current_sweep_id(sweep_id) \
                or f"warmup-{secrets.token_hex(6)}"
            results = search_many(misses, executor=executor,
                                  max_workers=max_workers)
            for (co, arch, kw), result in zip(misses, results):
                self._admit(co, arch, kw, result, sweep_id=sid)
        return {"requested": len(norm), "hits": len(norm) - len(misses),
                "solved": len(misses)}

    # ------------------------------------------------------------ bundles

    def export_bundle(self, path) -> int:
        """Write every plan this cache can see — the in-memory layer
        *plus* everything in the durable store (current engine version
        only) — to a single JSON bundle file, for shipping a benchmark
        host's sweep to a serving fleet.  Returns the number of plans
        exported."""
        import tempfile

        with self._lock:
            plans = {k: p.to_json() for k, p in self._mem.items()}
        for key in self.store.keys():
            if key in plans or key[2] != ENGINE_VERSION:
                continue
            raw = self.store.get(key)
            if raw is None:
                continue
            try:
                d = json.loads(raw)
                if tuple(d["key"]) != key:
                    continue
                plans[key] = d["plan"]
            except (ValueError, KeyError, TypeError):
                continue                # corrupt rows never ship
        entries = [{"key": list(k), "plan": p} for k, p in plans.items()]
        bundle = {"schema": "repro/plan-bundle/v1",
                  "engine_version": ENGINE_VERSION,
                  "plans": entries}
        path = Path(path)
        fd, tmp = tempfile.mkstemp(dir=str(path.parent or Path(".")),
                                   prefix=path.name + ".", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(bundle, f, indent=1)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return len(entries)

    def import_bundle(self, path) -> int:
        """Load a plan bundle into this cache (memory + disk store).
        Entries whose engine version mismatches are skipped.  Returns the
        number of plans imported."""
        with open(path) as f:
            bundle = json.load(f)
        if bundle.get("schema") != "repro/plan-bundle/v1":
            raise ValueError(f"not a plan bundle: {path}")
        n = 0
        for entry in bundle["plans"]:
            try:
                plan = MappingPlan.from_json(entry["plan"])
                key = tuple(entry["key"])
            except (KeyError, TypeError, ValueError) as e:
                self.stats["corrupt"] += 1
                warnings.warn(
                    f"PlanCache: skipping malformed bundle entry ({e!r})",
                    RuntimeWarning, stacklevel=2)
                continue
            if plan.engine_version != ENGINE_VERSION or len(key) != 4:
                continue
            with self._lock:
                self._mem[key] = plan
            self._store_disk(key, plan, sweep_id="bundle-import")
            n += 1
        return n


# ------------------------------------------------------------- singleton

_CACHES: Dict[str, PlanCache] = {}
_CACHES_LOCK = threading.Lock()


def get_plan_cache() -> PlanCache:
    """The process-wide :class:`PlanCache` for the current store
    directory.  ``$REPRO_PLAN_CACHE`` is re-read on every call, so
    pointing it somewhere else (tests, CI sandboxes) takes effect
    immediately — each distinct directory gets its own instance with its
    own in-memory layer."""
    root = os.environ.get(_ENV_VAR) or DEFAULT_CACHE_DIR
    root = str(Path(root).expanduser())
    with _CACHES_LOCK:
        cache = _CACHES.get(root)
        if cache is None:
            cache = PlanCache(root)
            _CACHES[root] = cache
        return cache
