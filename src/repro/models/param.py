"""Parameter specs: one source of truth for shapes, dtypes, logical axes
and initializers.  Used to (a) init real params, (b) build abstract
ShapeDtypeStructs for the dry-run, and (c) derive NamedShardings from the
logical-axis rules in parallel/sharding.py.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ParamSpec", "init_tree", "abstract_tree", "axes_tree", "count_params"]


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]       # logical axis names, len == ndim
    init: str = "normal"                  # normal | zeros | ones | small_normal
    scale: float = 1.0                    # stddev multiplier for normal init
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_one(spec: ParamSpec, key: jax.Array) -> jax.Array:
    dt = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = spec.scale / math.sqrt(max(1, fan_in))
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dt)


def init_tree(specs, key: jax.Array):
    """Initialize a pytree of arrays from a pytree of ParamSpecs."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [_init_one(s, k) for s, k in zip(leaves, keys)])


def abstract_tree(specs):
    """ShapeDtypeStruct pytree (no allocation) from a ParamSpec pytree."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def axes_tree(specs):
    """Logical-axes pytree mirroring the params pytree."""
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return int(sum(int(np.prod(s.shape)) for s in leaves))
