"""Shared model layers: norms, RoPE, MLP, embeddings, loss."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .param import ParamSpec

__all__ = [
    "norm_specs", "apply_norm", "rope_cos_sin", "apply_rope",
    "mlp_specs", "mlp_apply", "embed_specs", "embed_apply", "unembed_apply",
    "cross_entropy_loss",
]

F32 = jnp.float32


# ------------------------------------------------------------------- norms


def norm_specs(cfg: ModelConfig, stacked: Optional[int] = None,
               dim: Optional[int] = None) -> Dict[str, ParamSpec]:
    d = dim or cfg.d_model
    shape = (stacked, d) if stacked else (d,)
    axes = ("layer", "embed") if stacked else ("embed",)
    out = {"scale": ParamSpec(shape, axes, init="ones", dtype=cfg.dtype)}
    if cfg.norm_type == "layernorm":
        out["bias"] = ParamSpec(shape, axes, init="zeros", dtype=cfg.dtype)
    return out


def apply_norm(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    xf = x.astype(F32)
    if cfg.norm_type == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(F32) + p["bias"].astype(F32)
    else:
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(F32)
    return y.astype(x.dtype)


# -------------------------------------------------------------------- RoPE


def rope_cos_sin(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions: (...,) int -> cos/sin (..., dim//2) f32."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=F32) / half))
    ang = positions.astype(F32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, D); cos/sin: (S, D//2) (broadcast over batch/heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]   # (S, 1, D/2) -> broadcast over heads
    s = sin[..., None, :]
    xf1, xf2 = x1.astype(F32), x2.astype(F32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s],
                           axis=-1).astype(x.dtype)


# --------------------------------------------------------------------- MLP


def mlp_specs(cfg: ModelConfig, stacked: Optional[int] = None,
              d_ff: Optional[int] = None) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    L = (stacked,) if stacked else ()
    la = ("layer",) if stacked else ()
    return {
        "wi": ParamSpec(L + (d, f), la + ("embed", "ff"), dtype=cfg.dtype),
        "wg": ParamSpec(L + (d, f), la + ("embed", "ff"), dtype=cfg.dtype),
        "wo": ParamSpec(L + (f, d), la + ("ff", "embed"), dtype=cfg.dtype),
    }


def mlp_apply(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    """SwiGLU MLP: silu(x@wg) * (x@wi) @ wo."""
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    return h @ p["wo"]


# -------------------------------------------------------------- embeddings


def embed_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    V, d = cfg.padded_vocab, cfg.d_model
    out = {"embedding": ParamSpec((V, d), ("vocab", "embed"), scale=1.0,
                                  dtype=cfg.dtype)}
    if not cfg.tie_embeddings:
        out["unembed"] = ParamSpec((d, V), ("embed", "vocab"), dtype=cfg.dtype)
    return out


def embed_apply(p: Dict[str, jax.Array], tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0)


def unembed_apply(cfg: ModelConfig, p: Dict[str, jax.Array], h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return h @ p["embedding"].T
    return h @ p["unembed"]


# -------------------------------------------------------------------- loss


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       real_vocab: int) -> jax.Array:
    """Mean token NLL.  logits (B, S, Vp) — padded vocab entries are masked;
    labels (B, S) int32 in [0, real_vocab)."""
    lf = logits.astype(F32)
    Vp = lf.shape[-1]
    if Vp > real_vocab:
        pad_mask = jnp.arange(Vp) >= real_vocab
        lf = jnp.where(pad_mask, -1e30, lf)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)
