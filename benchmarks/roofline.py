"""Roofline table from the dry-run artifacts (§Roofline).

Reads artifacts/dryrun/*.json and prints, per (arch × shape × mesh):
the three roofline terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs
(useful-compute ratio) and the per-device memory analysis.  ``--markdown``
emits the EXPERIMENTS.md table.

The collective term uses the RECONCILED jaxpr/HLO wire volume when the
artifact carries a ``reconcile`` section (written by ``launch/dryrun.py``
since the train-step contract PR): the jaxpr walker's explicit
collectives plus the declared GSPMD schedule, cross-checked against the
HLO text parse, charging the larger side on disagreement.  The ``recon``
column counts the reconciliation findings for the cell (0 = the two
static views agree everywhere within tolerance).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(tag_filter: str = "") -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if (r.get("tag") or "") != tag_filter:
            continue
        recs.append(r)
    return recs


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def row(r: Dict) -> Dict:
    rf = r["roofline"]
    ca = r.get("cost_analysis", {})
    ma = r.get("memory_analysis", {})
    rc = r.get("reconcile", {})
    per_dev_bytes = (ma.get("argument_size_in_bytes", 0)
                     + ma.get("temp_size_in_bytes", 0))
    return {
        "cell": f"{r['arch']}×{r['shape']}×{r['mesh']}",
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "t_compute": rf["t_compute_s"], "t_memory": rf["t_memory_s"],
        "t_collective": rf["t_collective_s"],
        "bottleneck": rf["bottleneck"],
        "useful": r.get("useful_flop_ratio", 0.0),
        "hlo_flops": ca.get("flops", 0.0),
        "mem_per_dev": per_dev_bytes,
        "compile_s": r.get("lower_compile_s", 0.0),
        "wire_reconciled": rc.get("total_reconciled_wire",
                                  r.get("collective_wire_per_device", 0.0)),
        "wire_hlo": rc.get("total_hlo_wire",
                           r.get("collective_wire_hlo_per_device", 0.0)),
        "recon_findings": len(rc.get("findings", [])),
        "recon_clean": bool(rc.get("clean", True)),
    }


def print_table(recs: List[Dict], markdown: bool = False) -> None:
    rows = [row(r) for r in recs]
    rows.sort(key=lambda x: (x["arch"], x["shape"], x["mesh"]))
    if markdown:
        print("| arch | shape | mesh | t_compute | t_memory | t_collective |"
              " bottleneck | useful FLOP ratio | bytes/device |"
              " wire/device (reconciled) | recon |")
        print("|---|---|---|---|---|---|---|---|---|---|---|")
        for x in rows:
            recon = ("clean" if x["recon_clean"]
                     else f"{x['recon_findings']} findings")
            print(f"| {x['arch']} | {x['shape']} | {x['mesh']} "
                  f"| {x['t_compute']:.3e} | {x['t_memory']:.3e} "
                  f"| {x['t_collective']:.3e} | **{x['bottleneck']}** "
                  f"| {x['useful']:.2f} | {fmt_bytes(x['mem_per_dev'])} "
                  f"| {fmt_bytes(x['wire_reconciled'])} | {recon} |")
    else:
        for x in rows:
            recon = ("clean" if x["recon_clean"]
                     else f"{x['recon_findings']}findings")
            print(f"roofline_{x['cell']},{x['t_compute']*1e6:.1f},"
                  f"mem={x['t_memory']*1e6:.1f}us;"
                  f"coll={x['t_collective']*1e6:.1f}us;"
                  f"bott={x['bottleneck']};useful={x['useful']:.2f};"
                  f"bytes/dev={fmt_bytes(x['mem_per_dev'])};"
                  f"wire/dev={fmt_bytes(x['wire_reconciled'])};"
                  f"recon={recon}")


def run_all() -> Dict:
    recs = load()
    print(f"# --- roofline from {len(recs)} dry-run artifacts ---")
    print_table(recs)
    from collections import Counter
    bt = Counter(r["roofline"]["bottleneck"] for r in recs)
    print(f"roofline_summary,{len(recs)},bottlenecks={dict(bt)}")
    return {"n": len(recs), "bottlenecks": dict(bt)}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    print_table(load(args.tag), markdown=args.markdown)
