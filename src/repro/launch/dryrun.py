import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the very first two lines: jax locks the device count on first
# init.  512 virtual host devices realize the 2x16x16 production mesh.

# Multi-pod dry-run (deliverable e).
#
# For every (architecture × input-shape × mesh) cell:
#     jax.jit(step).lower(*abstract_inputs).compile()
# must succeed on the single-pod 16×16 mesh AND the 2×16×16 multi-pod mesh.
# We record memory_analysis(), cost_analysis() and the parsed collective
# traffic into artifacts/dryrun/<arch>__<shape>__<mesh>.json for §Dry-run /
# §Roofline.
#
# Usage:
#     python -m repro.launch.dryrun --arch glm4-9b --shape train_4k --mesh single
#     python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def _mesh(kind: str):
    from repro.launch.mesh import make_production_mesh
    return make_production_mesh(multi_pod=(kind == "multi"))


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             *, opt_flags: Optional[Dict[str, Any]] = None,
             tag: str = "", calibrated: Optional[str] = None,
             overlap: float = 1.0) -> Dict[str, Any]:
    """Lower + compile one cell; returns the artifact dict.

    ``calibrated`` points at a ``repro.calibrate`` store (``True`` for
    the default plan-store root): when a valid calibration loads, the
    roofline's collective term is charged at the measured-and-fitted
    channel bandwidth instead of the datasheet link constant, and the
    artifact records which calibration was applied.

    ``overlap`` is the achievable compute-collective overlap factor for
    the overlap-adjusted roofline bound (the serial bound is always
    reported alongside; see ``roofline_terms``)."""
    import jax.numpy as jnp
    from repro.analysis import (parse_collectives, reconcile_cell,
                                roofline_terms, trace_counts)
    from repro.configs.registry import SHAPES, get_config
    from repro.launch import specs as S
    from repro.models.model import Model
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import make_train_step

    t0 = time.time()
    mesh = _mesh(mesh_kind)
    cfg = get_config(arch)
    STEP_FLAGS = ("planner_loss", "microbatches")
    for k, v in (opt_flags or {}).items():
        if k not in STEP_FLAGS:
            cfg = cfg.with_(**{k: v})
    shape = SHAPES[shape_name]
    model = Model(cfg)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
        "kind": shape.kind, "devices": mesh.devices.size,
        "params": model.n_params(), "active_params": cfg.n_active_params(),
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
    }

    with mesh:
        if shape.kind == "train":
            state_ab, state_sh = S.state_specs(model, mesh)
            batch_ab = S.batch_specs(cfg, shape, mesh)
            step = make_train_step(
                model, OptConfig(), mesh,
                microbatches=int((opt_flags or {}).get("microbatches", 1)),
                use_planner_loss=(opt_flags or {}).get("planner_loss", False))
            fn = jax.jit(step, donate_argnums=(0,))
            lowered = fn.lower(state_ab, batch_ab)
        elif shape.kind == "prefill":
            params_ab, _ = S.state_specs(model, mesh, with_opt=False)
            batch_ab = S.batch_specs(cfg, shape, mesh, with_labels=False)
            fn = jax.jit(lambda p, b: model.prefill(p, b, shape.seq_len, mesh))
            lowered = fn.lower(params_ab, batch_ab)
        else:  # decode
            params_ab, _ = S.state_specs(model, mesh, with_opt=False)
            cache_ab, _ = S.cache_specs(model, shape, mesh)
            tok = jax.ShapeDtypeStruct(
                (shape.global_batch, 1), jnp.int32,
                sharding=S.batch_sharding(mesh, shape.global_batch, 2))
            fn = jax.jit(lambda p, c, t: model.decode(p, c, t, mesh),
                         donate_argnums=(1,))
            lowered = fn.lower(params_ab, cache_ab, tok)

        compiled = lowered.compile()

    # --- structural FLOPs + explicit collectives from one jaxpr walk
    # (scan-aware; repro.analysis.jaxpr — the launch/*_analysis shims are
    # deprecated)
    jaxpr_trace = None
    try:
        if shape.kind == "train":
            jaxpr_trace = trace_counts(step, state_ab, batch_ab)
        elif shape.kind == "prefill":
            jaxpr_trace = trace_counts(lambda p, b: model.prefill(
                p, b, shape.seq_len, mesh), params_ab, batch_ab)
        else:
            jaxpr_trace = trace_counts(
                lambda p, c, t: model.decode(p, c, t, mesh),
                params_ab, cache_ab, tok)
        sf = jaxpr_trace.flops
        rec["structural_flops_global"] = sf
        rec["structural_flops_per_device"] = sf / mesh.devices.size
        if jaxpr_trace.findings:
            rec["jaxpr_findings"] = list(jaxpr_trace.findings)
    except Exception as e:  # noqa: BLE001
        rec["structural_flops_error"] = repr(e)

    rec["lower_compile_s"] = round(time.time() - t0, 1)
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax returns [per-device dict]
        ca = ca[0] if ca else {}
    rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                            if isinstance(v, (int, float))
                            and k in ("flops", "bytes accessed",
                                      "optimal_seconds", "utilization")}
    ma = compiled.memory_analysis()
    if ma is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                rec.setdefault("memory_analysis", {})[attr] = int(v)
    hlo = compiled.as_text()
    stats = parse_collectives(hlo)
    rec["collectives"] = stats.to_dict()
    rec["hlo_bytes"] = len(hlo)
    flops_raw = rec["cost_analysis"].get("flops", 0.0)
    bytes_acc = rec["cost_analysis"].get("bytes accessed", 0.0)
    # corrected per-device quantities (see EXPERIMENTS §Roofline "sources"):
    #  - compute: structural jaxpr FLOPs / devices (exact for scans)
    #  - memory: single-pass HBM traffic estimate from memory_analysis
    #    (args+outputs+temps each touched once)
    #  - collective: HLO wire bytes with in-loop ops × layer trip count
    flops_pd = rec.get("structural_flops_per_device", flops_raw)
    mem_traffic = 0.0
    if "memory_analysis" in rec:
        ma_ = rec["memory_analysis"]
        mem_traffic = (ma_.get("argument_size_in_bytes", 0)
                       + ma_.get("output_size_in_bytes", 0)
                       + ma_.get("temp_size_in_bytes", 0))
    wire_pd_hlo = stats.wire_bytes_scaled(cfg.n_layers)
    # --- jaxpr-vs-HLO reconciliation (repro.analysis.reconcile): compare
    # the walker's explicit collectives + the declared GSPMD schedule
    # against the HLO text parse; the roofline charges the RECONCILED
    # volumes (never undercharging), with disagreements surfaced as
    # findings in the artifact.
    schedule = None
    if shape.kind == "train":
        try:
            from repro.parallel.collective_planner import (
                train_collective_schedule)
            schedule = train_collective_schedule(
                cfg, mesh, shape.global_batch, shape.seq_len,
                microbatches=int((opt_flags or {}).get("microbatches", 1)),
                planner_loss=bool(
                    (opt_flags or {}).get("planner_loss", False)))
        except Exception as e:  # noqa: BLE001 — declaration gap, not fatal
            rec["schedule_error"] = repr(e)
    recon = reconcile_cell(jaxpr_trace, stats, schedule=schedule,
                           loop_trip=cfg.n_layers)
    rec["reconcile"] = recon.to_dict()
    wire_pd = recon.total_reconciled_wire
    rec["mem_traffic_per_device"] = mem_traffic
    rec["collective_wire_per_device"] = wire_pd
    rec["collective_wire_hlo_per_device"] = wire_pd_hlo
    link_bw = None
    if calibrated:
        from repro.calibrate import calibration_path, load_calibration
        cal_path = (calibration_path() if calibrated is True
                    else calibration_path(calibrated))
        cal = load_calibration(cal_path)
        if cal is not None:
            link_bw = cal.params.channel_bandwidth
            rec["calibration"] = {
                "path": str(cal_path),
                "backend": cal.provenance.get("backend"),
                "channel_bandwidth": link_bw,
                "median_rel_err": cal.median_rel_err,
            }
        else:
            rec["calibration"] = {"path": str(cal_path), "loaded": False}
    rec["roofline"] = roofline_terms(flops_pd, mem_traffic, wire_pd,
                                     link_bw=link_bw, overlap=overlap)
    rec["roofline_raw_hlo"] = roofline_terms(flops_raw, bytes_acc,
                                             stats.total_wire_bytes)
    # MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE); decode: D = batch
    # tokens per step
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        factor = 6.0
    elif shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        factor = 2.0
    else:
        tokens = shape.global_batch
        factor = 2.0
    model_flops_global = factor * cfg.n_active_params() * tokens
    rec["model_flops_global"] = model_flops_global
    rec["model_flops_per_device"] = model_flops_global / mesh.devices.size
    if rec.get("structural_flops_per_device"):
        rec["useful_flop_ratio"] = (rec["model_flops_per_device"]
                                    / rec["structural_flops_per_device"])

    # --- static contract verdict (repro.analysis.contracts): trace the
    # sharded softmax path on THIS cell's mesh and audit its collective
    # schedule against the planner's declaration — the roofline numbers
    # above are only trustworthy if the cost model and the traced program
    # agree on what goes over the wire.
    from repro.analysis.contracts import sharded_contract_checks
    try:
        dp = 1
        for ax in ("pod", "data"):
            if ax in mesh.axis_names:
                dp *= int(mesh.shape[ax])
        p_model = int(mesh.shape.get("model", 1))
        cchecks = sharded_contract_checks(
            mesh, batch=2 * dp, seq=16, d_model=64, vocab_p=128 * p_model)
        rec["contracts"] = {
            "checked": len(cchecks),
            "failures": [c.to_dict() for c in cchecks if not c.ok],
            "ok": all(c.ok for c in cchecks),
        }
    except Exception as e:  # noqa: BLE001 — verdict must not sink the cell
        rec["contracts"] = {"error": repr(e), "ok": False}
    return rec


def artifact_path(arch: str, shape: str, mesh_kind: str, tag: str = "") -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    return os.path.join(ARTIFACT_DIR,
                        f"{arch}__{shape}__{mesh_kind}{suffix}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--calibrated", nargs="?", const=True, default=None,
                    metavar="STORE",
                    help="charge the roofline's collective term at the "
                         "calibrated channel bandwidth from STORE (default: "
                         "the plan-store root) instead of the datasheet "
                         "link constant")
    ap.add_argument("--overlap", type=float, default=1.0,
                    help="achievable compute-collective overlap for the "
                         "overlap-adjusted roofline bound (default 1.0; "
                         "the serial bound is always reported too)")
    ap.add_argument("--opt", default="",
                    help="comma k=v model-config overrides (hillclimb)")
    args = ap.parse_args()
    if not 0.0 <= args.overlap <= 1.0:
        ap.error("--overlap must lie in [0, 1]")

    opt_flags: Dict[str, Any] = {}
    for kv in filter(None, args.opt.split(",")):
        k, v = kv.split("=")
        if v.lower() in ("true", "false"):
            opt_flags[k] = v.lower() == "true"
        elif v.lstrip("-").isdigit():
            opt_flags[k] = int(v)
        else:
            try:
                opt_flags[k] = float(v)
            except ValueError:
                opt_flags[k] = v

    from repro.configs.registry import all_cells
    cells = all_cells() if args.all else [(args.arch, args.shape)]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch, shape in cells:
        for mk in meshes:
            path = artifact_path(arch, shape, mk, args.tag)
            if args.skip_existing and os.path.exists(path):
                print(f"skip {path}")
                continue
            try:
                rec = run_cell(arch, shape, mk, opt_flags=opt_flags,
                               tag=args.tag, calibrated=args.calibrated,
                               overlap=args.overlap)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                r = rec["roofline"]
                c = rec.get("contracts", {})
                verdict = ("contracts=ok" if c.get("ok")
                           else f"contracts=FAIL({len(c.get('failures', []))}"
                                f"{' ' + c['error'] if 'error' in c else ''})")
                nrf = len(rec.get("reconcile", {}).get("findings", []))
                verdict += (" recon=clean" if nrf == 0
                            else f" recon={nrf} findings")
                print(f"OK  {arch:22s} {shape:12s} {mk:6s} "
                      f"compile={rec['lower_compile_s']:7.1f}s "
                      f"bottleneck={r['bottleneck']:10s} "
                      f"t=({r['t_compute_s']:.3e},{r['t_memory_s']:.3e},"
                      f"{r['t_collective_s']:.3e})s "
                      f"serial={r['bound_serial_s']:.3e}s "
                      f"ov{r['overlap']:g}={r['bound_overlap_s']:.3e}s"
                      f"({r['bottleneck_overlap']}) {verdict}", flush=True)
            except Exception as e:  # noqa: BLE001 — sweep must continue
                failures.append((arch, shape, mk, repr(e)))
                print(f"FAIL {arch} {shape} {mk}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall dry-run cells passed")


if __name__ == "__main__":
    main()
