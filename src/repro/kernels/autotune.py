"""COMET-driven Pallas block-size selection (DESIGN.md §2, kernel-level use).

This is the paper's mapping-space exploration applied to TPU tiles: for
each kernel we build the corresponding compound-op workload, instantiate a
single-core TPU-v5e hardware model, and rank candidate tile shapes with
the **shared search engine** — ``search(candidate_list=...)`` routes the
whole candidate set through the batched evaluator (core/batcheval.py),
the same memory-fit validation + Eq. 1–7 latency model the map-space
search uses, so Pallas block selection and the analytical model cannot
drift apart.  Candidate blocks map onto MappingSpec tile counts
(block -> ceil(dim / block) temporal tiles) and both Eq. 5-7 schedules
are evaluated per block candidate in the same SoA pass.

Every entry point resolves through the :class:`repro.core.plan.PlanCache`
(the ``MappingPlan`` subsystem): the first call per (shape, arch, engine
version) solves and persists a plan to the durable store
(:mod:`repro.core.planstore` — SQLite WAL with LRU/age eviction and
per-plan provenance, degrading to a JSON dir or memory-only under store
faults), so every later call — in this process or any other pointed at
the same ``$REPRO_PLAN_CACHE`` — is a dictionary/row lookup with **no
search at all**.  Store faults never reach the autotuner: a degraded
store costs durability, never a wrong (or missing) block shape.  Serving
engines pre-populate the cache at startup (``ServeEngine`` warmup) and
benchmark hosts can ship their sweeps as plan bundles
(``benchmarks/paper_tables.export_plans``).

VMEM working-set constraints mirror the kernels' actual scratch/BlockSpec
usage (those are layout facts about the kernels, not a cost model) and
pre-filter the candidate set.  All functions degrade to safe
hardware-aligned defaults if no candidate survives.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.hardware import Arch, tpu_v5e
from repro.core.ir import MappingSpec
from repro.core.plan import get_plan_cache
from repro.core.workload import (CompoundOp, flash_attention, gemm_softmax,
                                 ssd_chunk)

__all__ = ["attention_blocks", "gemm_epilogue_blocks", "ssd_chunk_len",
           "VMEM_BUDGET", "PAPER_KERNEL_SHAPES", "plan_jobs",
           "attention_plan_job", "gemm_epilogue_plan_job", "ssd_plan_jobs"]

# usable VMEM per core for kernel working sets (half of 128 MB, leaving room
# for Pallas double buffering which the cost model assumes)
VMEM_BUDGET = 64 * 1024 * 1024
_LANE = 128  # MXU/VPU lane alignment

SCHEDULES = ("sequential", "pipelined")

# Compute–collective overlap axis for kernel candidates.  On the
# single-chip kernel arch collectives cost zero, so the axis is inert
# today (ties break to overlap=0.0, the serial point) — it exists so
# multi-chip kernel archs rank double-buffered fused kernels (e.g.
# all-gather-GEMM) against their serial splits through the same plans.
OVERLAPS = (0.0, 1.0)

# The kernel shapes exercised by the paper-table benchmarks and the kernel
# test sweeps — the set a warm plan store must answer without solving
# (benchmarks/search_throughput.py gates this; tests/test_plan.py verifies
# the no-search property with a fresh cache instance).
PAPER_KERNEL_SHAPES: Dict[str, List[Tuple[int, ...]]] = {
    "attention_blocks": [(1024, 1024, 64), (4096, 4096, 128),
                         (1, 32768, 128), (32768, 32768, 128)],
    "gemm_epilogue_blocks": [(512, 4096, 128), (4096, 4096, 4096),
                             (4096, 16384, 4096)],
    "ssd_chunk_len": [(4096, 64, 128)],
}

_KERNEL_ARCH: Optional[Arch] = None

# Per-shape memo of *job descriptions* (compound op + candidate list) —
# the question, never the answer: every call still resolves its blocks
# through the PlanCache, this only avoids rebuilding identical candidate
# sets (and lets the plan layer's fingerprint memos hit by identity).
_JOB_MEMO: Dict[Tuple, object] = {}
_JOB_MEMO_MAX = 1024


def _memo_job(key: Tuple, build):
    hit = _JOB_MEMO.get(key)
    if hit is None and key not in _JOB_MEMO:
        if len(_JOB_MEMO) >= _JOB_MEMO_MAX:
            _JOB_MEMO.clear()
        hit = _JOB_MEMO[key] = build()
    return hit


def _align(x: int, a: int = _LANE) -> int:
    return max(a, (x // a) * a)


def _kernel_arch() -> Arch:
    """Single-chip view of the TPU for per-core block selection (the ICI
    mesh is irrelevant to one kernel invocation).  Memoized by hand — this
    module keeps no functools result caches; block-selection results live
    in the PlanCache alone."""
    global _KERNEL_ARCH
    if _KERNEL_ARCH is None:
        _KERNEL_ARCH = tpu_v5e(mesh=(1, 1))
    return _KERNEL_ARCH


def _candidate_specs(variant: str, tiles: Sequence[Dict[str, int]]
                     ) -> Tuple[MappingSpec, ...]:
    """Candidate MappingSpecs in (schedule, overlap)-major order (all
    sequential/overlap=0 first — the pre-plan-refactor axis layout, kept
    so selection ties break identically), pairs minor.  A tuple: immutable
    sequences are what the plan layer's fingerprint memo may cache by
    identity."""
    return tuple(MappingSpec(variant=variant, schedule=s, overlap=ov, **t)
                 for s in SCHEDULES for ov in OVERLAPS for t in tiles)


def _pair_of(plan, pairs: Sequence[Tuple[int, int]]) -> Tuple[int, int]:
    """Winning (block, block) pair of a candidates-mode plan: the stored
    ``best_index`` walks the (schedule, overlap)-major candidate list with
    the pairs minor, so modulo the pair count recovers the pair regardless
    of which schedule/overlap rung won."""
    return pairs[plan.best_index % len(pairs)]


# ------------------------------------------------------------ attention


def _attention_pairs(sq: int, skv: int, d: int) -> List[Tuple[int, int]]:
    """VMEM-feasible (block_q, block_k) pairs.  Working set per (bq, bk):
    q(bq,d) + k/v(bk,d)*2 + acc(bq,d) f32 + s(bq,bk) f32 (+ double
    buffering handled by budget halving)."""
    cands = (128, 256, 512, 1024)
    pairs = []
    for bq in cands:
        if bq > max(sq, _LANE):
            continue
        for bk in cands:
            if bk > max(skv, _LANE):
                continue
            vmem = (bq * d * 2 + 2 * bk * d * 2 + bq * d * 4 + bq * bk * 4
                    + 2 * bq * _LANE * 4)
            if vmem * 2 > VMEM_BUDGET:
                continue
            pairs.append((bq, bk))
    return pairs


def attention_plan_job(sq: int, skv: int, d: int
                       ) -> Optional[Tuple[CompoundOp, Arch, Dict,
                                           List[Tuple[int, int]]]]:
    """The plan job behind :func:`attention_blocks`: ``(co, arch,
    search_kw, pairs)``, or None when no pair survives the VMEM filter.
    The job triple is what warmup paths feed to ``PlanCache.warmup`` so
    their cache keys match the trace-time lookups exactly."""
    def build():
        pairs = _attention_pairs(sq, skv, d)
        if not pairs:
            return None
        M, N = max(sq, _LANE), max(skv, _LANE)
        co = flash_attention(M, d, N, d)
        tiles = [{"m_tiles": math.ceil(M / bq), "n_tiles": math.ceil(N / bk)}
                 for bq, bk in pairs]
        kw = {"candidate_list": _candidate_specs("fa", tiles)}
        return co, _kernel_arch(), kw, pairs

    return _memo_job(("attn", sq, skv, d), build)


def attention_blocks(sq: int, skv: int, d: int) -> Tuple[int, int]:
    """(block_q, block_k) for the FlashAttention kernel via a PlanCache-
    resolved candidates-mode search on the flash-attention compound op."""
    job = attention_plan_job(sq, skv, d)
    if job is None:
        return (_LANE, _LANE)
    co, arch, kw, pairs = job
    plan = get_plan_cache().resolve(co, arch, **kw)
    return _pair_of(plan, pairs)


# -------------------------------------------------------- gemm epilogues


def _gemm_pairs(m: int, n: int, k: int) -> List[Tuple[int, int]]:
    """VMEM-feasible (block_m, block_k) pairs.  Constraint: acc
    (block_m, N) f32 + B slice (block_k, N) must fit VMEM."""
    pairs = []
    for bm in (128, 256, 512):
        for bk in (128, 256, 512):
            if bk > max(k, _LANE):
                continue
            vmem = bm * n * 4 + bk * n * 2 + bm * bk * 2 + bm * n * 2
            if vmem * 2 > VMEM_BUDGET:
                continue
            pairs.append((bm, bk))
    return pairs


def gemm_epilogue_plan_job(m: int, n: int, k: int
                           ) -> Optional[Tuple[CompoundOp, Arch, Dict,
                                               List[Tuple[int, int]]]]:
    """The plan job behind :func:`gemm_epilogue_blocks` (see
    :func:`attention_plan_job`)."""
    def build():
        pairs = _gemm_pairs(m, n, k)
        if not pairs:
            return None
        M, K = max(m, _LANE), max(k, _LANE)
        co = gemm_softmax(M, n, K)
        tiles = [{"m_tiles": math.ceil(M / bm), "k_tiles": math.ceil(K / bk)}
                 for bm, bk in pairs]
        kw = {"candidate_list": _candidate_specs("fused_dist", tiles)}
        return co, _kernel_arch(), kw, pairs

    return _memo_job(("gemm", m, n, k), build)


def gemm_epilogue_blocks(m: int, n: int, k: int) -> Tuple[int, int]:
    """(block_m, block_k) for the fused GEMM-SM / GEMM-LN kernels via a
    PlanCache-resolved candidates-mode search on gemm_softmax."""
    job = gemm_epilogue_plan_job(m, n, k)
    if job is None:
        return (_LANE, _LANE)
    co, arch, kw, pairs = job
    plan = get_plan_cache().resolve(co, arch, **kw)
    return _pair_of(plan, pairs)


# ------------------------------------------------------------------ ssd


def _ssd_chunk_cands(s: int, p: int, n: int) -> List[int]:
    out = []
    for c in (128, 256, 512):
        if c > max(s, _LANE):
            continue
        vmem = (c * p * 2 * 2 + 2 * c * n * 2 + c * c * 4 + n * p * 4)
        if vmem * 2 > VMEM_BUDGET:
            continue
        out.append(c)
    return out


def ssd_plan_jobs(s: int, p: int, n: int
                  ) -> List[Tuple[CompoundOp, Arch, Dict, int]]:
    """One plan job per candidate chunk length (the chunk length changes
    the compound op's dimensions themselves, so this is a sweep of
    per-chunk workloads rather than a tiling grid)."""
    def build():
        return [(ssd_chunk(S=s, H=1, P=p, Dst=n, C=c), _kernel_arch(),
                 {"candidate_list": (MappingSpec(variant="fused_dist",
                                                 m_tiles=1),)}, c)
                for c in _ssd_chunk_cands(s, p, n)]

    return _memo_job(("ssd", s, p, n), build)


def ssd_chunk_len(s: int, p: int, n: int) -> int:
    """Chunk length for the SSD kernel via the COMET ssd_chunk compound op.

    Larger chunks amortize the state GEMMs but grow the (c, c) intra-chunk
    matrix quadratically; the shared cost model finds the knee.  The
    candidate chunk workloads fan through ``PlanCache.warmup`` as one
    batched sweep (no hand-rolled scalar loop); per-chunk plans persist,
    so warm processes answer from the store."""
    jobs = ssd_plan_jobs(s, p, n)
    if not jobs:
        return 128
    cache = get_plan_cache()
    cache.warmup([(co, arch, kw) for co, arch, kw, _c in jobs])
    best = None
    for co, arch, kw, c in jobs:
        plan = cache.resolve(co, arch, **kw)
        lat = math.ceil(max(s, 1) / c) * plan.latency_s
        if best is None or lat < best[0]:
            best = (lat, c)
    return best[1]


# --------------------------------------------------------------- warmup


def plan_jobs(shapes: Optional[Dict[str, Sequence[Tuple[int, ...]]]] = None
              ) -> List[Tuple[CompoundOp, Arch, Dict]]:
    """All plan jobs for a kernel-shape table (default:
    :data:`PAPER_KERNEL_SHAPES`) — feed to ``PlanCache.warmup`` to
    pre-solve every block selection those shapes will ever ask for."""
    shapes = shapes if shapes is not None else PAPER_KERNEL_SHAPES
    jobs: List[Tuple[CompoundOp, Arch, Dict]] = []
    for sq, skv, d in shapes.get("attention_blocks", ()):
        job = attention_plan_job(sq, skv, d)
        if job is not None:
            jobs.append(job[:3])
    for m, n, k in shapes.get("gemm_epilogue_blocks", ()):
        job = gemm_epilogue_plan_job(m, n, k)
        if job is not None:
            jobs.append(job[:3])
    for s, p, n in shapes.get("ssd_chunk_len", ()):
        jobs.extend(job[:3] for job in ssd_plan_jobs(s, p, n))
    return jobs
