"""Mapping IR (COMET §IV-A).

A *mapping* is a hierarchical tree (Fig. 4(c)) of

* :class:`TileNode` ``T_i^j`` — data residing at one memory level, with a
  **unique temporal loop nest per tensor** (the paper's key representational
  extension over Timeloop/TileFlow's one-nest-per-level), plus spatial
  unrolling factors;
* :class:`ComputeNode` — a leaf executing one elementary operation tile on
  the GEMM (systolic/MXU) or SIMD (VPU) unit;
* :class:`CollectiveNode` ``CO_i^j`` — an explicit peer-to-peer collective
  among the memory instances at one level, annotated with
  ColOpType / Tensor / ReduceOp / Src / Dest exactly as in §IV-A.

The :class:`Tiling` helper owns the per-dimension factorization across
levels (temporal and spatial) so that tile sizes at any level and loop
iteration counts are consistent by construction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from .numerics import ceil_div, is_array, vmax
from .workload import Operation, TensorSpec

__all__ = [
    "Loop",
    "Tiling",
    "TileNode",
    "ComputeNode",
    "CollectiveNode",
    "Node",
    "SCHEDULES",
]

SCHEDULES = ("sequential", "pipelined", "parallel")

# Canonical level order root -> leaf (matches Arch.LEVELS).
LEVEL_ORDER = ("DRAM", "GB", "OB")


@dataclass(frozen=True)
class Loop:
    """One loop: iterate ``factor`` times over tiles of dimension ``dim``."""

    dim: str
    factor: int
    spatial: bool = False

    def __post_init__(self) -> None:
        # Batched evaluation passes an array of factors; bounds are then
        # enforced by the grid construction, not per-Loop.
        if not is_array(self.factor) and self.factor < 1:  # scalar-ok
            raise ValueError(f"loop factor must be >=1, got {self.factor}")


class Tiling:
    """Per-dimension factorization across memory levels.

    ``temporal[level][dim] = factor`` and ``spatial[level][dim] = factor``.
    The leaf tile of dim ``d`` is ``ceil(size / prod(all factors of d))``.
    Factors need not divide exactly; ceil-division semantics are used and
    edge tiles are charged as full tiles (consistent with Timeloop).
    """

    def __init__(self, dim_sizes: Dict[str, int],
                 temporal: Dict[str, Dict[str, int]],
                 spatial: Dict[str, Dict[str, int]]):
        self.dim_sizes = dict(dim_sizes)
        self.temporal = {lvl: dict(temporal.get(lvl, {})) for lvl in LEVEL_ORDER}
        self.spatial = {lvl: dict(spatial.get(lvl, {})) for lvl in LEVEL_ORDER}
        # Factors are fixed after construction, so tile queries are
        # memoized — one tree evaluation asks for the same (dim, level)
        # tiles many times (and, on the batched path, each query is an
        # array op worth amortizing).
        self._memo: Dict[Tuple, object] = {}

    # ------------------------------------------------------------------
    def factors_of(self, dim: str) -> int:
        key = ("f", dim)
        out = self._memo.get(key)
        if out is None:
            p = 1
            for lvl in LEVEL_ORDER:
                p *= self.temporal[lvl].get(dim, 1)
                p *= self.spatial[lvl].get(dim, 1)
            out = self._memo[key] = p
        return out

    def leaf_tile(self, dim: str) -> int:
        return vmax(1, ceil_div(self.dim_sizes[dim], self.factors_of(dim)))

    def tile_at(self, dim: str, level: str) -> int:
        """Tile size of ``dim`` *resident at* ``level`` (i.e. after applying
        all factors at levels strictly above ``level``)."""
        key = ("at", dim, level)
        out = self._memo.get(key)
        if out is None:
            p = 1
            for lvl in LEVEL_ORDER:
                if lvl == level:  # scalar-ok: level names are strings
                    break
                p *= self.temporal[lvl].get(dim, 1)
                p *= self.spatial[lvl].get(dim, 1)
            out = self._memo[key] = vmax(1, ceil_div(self.dim_sizes[dim], p))
        return out

    def tile_below(self, dim: str, level: str) -> int:
        """Tile size of ``dim`` handed to the *children* of ``level`` (after
        this level's temporal+spatial factors as well)."""
        key = ("below", dim, level)
        out = self._memo.get(key)
        if out is None:
            p = 1
            for lvl in LEVEL_ORDER:
                p *= self.temporal[lvl].get(dim, 1)
                p *= self.spatial[lvl].get(dim, 1)
                if lvl == level:  # scalar-ok: level names are strings
                    break
            out = self._memo[key] = vmax(1, ceil_div(self.dim_sizes[dim], p))
        return out

    def tensor_tile_bytes(self, t: TensorSpec, level: str, *, below: bool) -> int:
        key = ("tb", t.name, t.dims, t.dtype_bytes, level, below)
        out = self._memo.get(key)
        if out is None:
            n = t.dtype_bytes
            for d in t.dims:
                n *= self.tile_below(d, level) if below else self.tile_at(d, level)
            out = self._memo[key] = n
        return out

    def validate(self) -> None:
        for d, size in self.dim_sizes.items():
            f = self.factors_of(d)
            if is_array(f):
                raise TypeError("use overfactor_mask() for batched tilings")
            if f > size:  # scalar-ok: is_array(f) raised above
                raise ValueError(
                    f"dim {d}: product of factors {f} exceeds size {size}")

    def overfactor_mask(self):
        """Batched analogue of :meth:`validate`: elementwise True where the
        per-dimension factor products are within the dimension sizes (i.e.
        where the scalar path would *not* raise)."""
        ok = True
        for d, size in self.dim_sizes.items():
            ok = np.logical_and(ok, self.factors_of(d) <= size)
        return ok


# ------------------------------------------------------------------- nodes


@dataclass
class ComputeNode:
    """Leaf: one elementary op tile on a compute unit."""

    op: Operation
    tile_shape: Dict[str, int]          # dim -> leaf tile size
    unit: str                           # 'gemm' | 'simd'
    label: str = ""
    # Fraction of the parent's temporal iterations on which this child
    # executes (e.g. 1/n_tiles for a per-M-tile op under an (M,N) nest).
    exec_fraction: float = 1.0

    @property
    def points(self) -> int:
        p = 1
        for d in self.op.dims:
            p *= self.tile_shape.get(d, 1)
        return p


@dataclass
class CollectiveNode:
    """Explicit collective among peer memories at one level (CO_i^j)."""

    col_type: str                       # AllReduce | AllGather | ...
    tensor: str
    reduce_op: str                      # 'add' | 'max' | 'none'
    src: Tuple[str, ...]                # e.g. ("GB",) — peers at GB level
    dest: Tuple[str, ...]
    participants: int
    data_volume_bytes: float            # logical tensor bytes per occurrence
    count: float = 1                    # occurrences per parent iteration
    noc_level: str = "GB"               # which NoC: 'GB' -> cluster, 'OB' -> core
    label: str = ""
    exec_fraction: float = 1.0


@dataclass
class TileNode:
    """T_i^j: data staged at ``level``; per-tensor temporal loop nests.

    ``tensor_nests[t]`` is the ordered (outer->inner) list of temporal
    loops for tensor ``t`` at this node.  ``loops`` is the node's overall
    temporal loop order; per-tensor nests are its projections but may be
    reordered per tensor (the unique-nest-per-tensor feature).
    ``spatial_loops`` unroll across the child instances (clusters for a
    DRAM node, cores for a GB node).
    """

    level: str
    index: int
    loops: List[Loop] = field(default_factory=list)              # temporal, outer->inner
    spatial_loops: List[Loop] = field(default_factory=list)
    tensor_nests: Dict[str, List[Loop]] = field(default_factory=dict)
    input_tensors: Tuple[str, ...] = ()
    output_tensors: Tuple[str, ...] = ()
    bypass_tensors: Tuple[str, ...] = ()   # tensors NOT staged here (fusion bypass)
    children: List["Node"] = field(default_factory=list)
    schedule: str = "sequential"
    label: str = ""
    # Extra bytes resident at this level beyond the staged tiles (e.g. a
    # gathered full-row tensor in the standard-SM mapping) — validation only.
    extra_resident_bytes: float = 0.0
    exec_fraction: float = 1.0
    # Compute–collective overlap factor in [0, 1] for this node's window:
    # the fraction of its collective children's hideable time (Eq. 1
    # mem_lat) hidden under sibling compute.  May be an array on the
    # batched path (an overlap grid axis, like the ``schedule`` mask).
    overlap: float = 0.0

    def __post_init__(self) -> None:
        # Batched evaluation passes a boolean mask array (True = pipelined)
        # spanning a grid of schedule choices; names are validated only on
        # the scalar path.
        if not is_array(self.schedule) and self.schedule not in SCHEDULES:
            raise ValueError(f"bad schedule {self.schedule}")

    @property
    def iterations(self) -> int:
        n = 1
        for lp in self.loops:
            n *= lp.factor
        return n

    @property
    def spatial_fanout(self) -> int:
        n = 1
        for lp in self.spatial_loops:
            n *= lp.factor
        return n

    def tensor_fetches(self, tensor_dims: Tuple[str, ...],
                       nest: Optional[List[Loop]] = None) -> int:
        """Number of times the tensor's tile must be (re)fetched across this
        node's temporal iterations, with classic stationary reuse: loops
        *below* (inside) the innermost relevant loop give free reuse.
        """
        loops = nest if nest is not None else self.loops
        relevant = [i for i, lp in enumerate(loops) if lp.dim in tensor_dims]
        if not relevant:
            return 1
        last = relevant[-1]
        n = 1
        for lp in loops[: last + 1]:
            n *= lp.factor
        return n


Node = Union[TileNode, ComputeNode, CollectiveNode]


def walk(node: Node):
    """Depth-first iterator over a mapping tree."""
    yield node
    if isinstance(node, TileNode):
        for c in node.children:
            yield from walk(c)


def tree_str(node: Node, depth: int = 0) -> str:
    pad = "  " * depth
    if isinstance(node, TileNode):
        sp = ",".join(f"{l.dim}:{l.factor}" for l in node.spatial_loops)
        tp = ",".join(f"{l.dim}:{l.factor}" for l in node.loops)
        s = (f"{pad}T[{node.level}]^{node.index} {node.label} "
             f"Tp({tp}) Sp({sp}) sched={node.schedule}\n")
        for c in node.children:
            s += tree_str(c, depth + 1)
        return s
    if isinstance(node, CollectiveNode):
        return (f"{pad}CO[{node.noc_level}] {node.col_type}({node.tensor},"
                f" {node.reduce_op}) P={node.participants}"
                f" DV={node.data_volume_bytes:.0f}B x{node.count}\n")
    return (f"{pad}C[{node.unit}] {node.op.name} tile="
            f"{dict(node.tile_shape)}\n")
