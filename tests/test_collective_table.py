"""Tests for the tabulated per-P collective cost factors, the divisor-
complete candidate sets, the capacity-headroom channel and the 3-D Pareto
machinery (plus their satellite bugfixes)."""
import dataclasses

import numpy as np
import pytest

from repro.core import batcheval, collectives
from repro.core.batcheval import (ParetoArchive, Topology,
                                  evaluate_specs_batch, pareto_merge3)
from repro.core.collectives import (COLLECTIVE_TYPES, collective_cost,
                                    noc_latency)
from repro.core.hardware import NoCParams, cloud, edge, tpu_v5e
from repro.core.ir import MappingSpec, evaluate_mapping
from repro.core.search import (_search_randomized, candidate_specs, divisors,
                               fanout_candidates, pow2_tilings, search)
from repro.core.validate import capacity_headroom, validity_and_headroom
from repro.core.workload import gemm_softmax

PRESETS = [edge(), cloud(), tpu_v5e()]
GIGA = 1e9


# ---------------------------------------------------- tabulated collectives

@pytest.mark.parametrize("arch", PRESETS, ids=[a.name for a in PRESETS])
@pytest.mark.parametrize("col", COLLECTIVE_TYPES)
def test_table_bitwise_matches_scalar(arch, col):
    """Array-participant costs gathered from the factor table are
    bit-identical to the scalar-P calls for EVERY participant count of
    every preset NoC — including non-pow2 P (3/5/6, ...) and the (1,1)
    degenerate core NoC of tpu_v5e."""
    for noc in (arch.cluster_noc, arch.core_noc):
        Ps = np.arange(0, noc.num_nodes + 1)
        dv = 8191.375  # non-trivial mantissa
        arr = collective_cost(col, dv, Ps, noc)
        assert arr.volume_bytes.shape == Ps.shape
        for j, p in enumerate(Ps):
            sc = collective_cost(col, dv, int(p), noc)
            assert arr.volume_bytes[j] == sc.volume_bytes, (noc.mesh, p)
            assert arr.hops[j] == sc.hops
            assert arr.steps[j] == sc.steps


def test_table_bitwise_matches_scalar_array_dv():
    """Parity also holds when the data volume is itself an array (the
    batched engine passes per-grid-point volumes)."""
    noc = edge().cluster_noc
    P = np.array([1, 2, 3, 4, 4, 3])
    dv = np.array([0.0, 1e3, 1e3, 512.5, 0.0, 77.25])
    for col in COLLECTIVE_TYPES:
        arr = collective_cost(col, dv, P, noc)
        for j in range(P.size):
            sc = collective_cost(col, float(dv[j]), int(P[j]), noc)
            assert arr.volume_bytes[j] == sc.volume_bytes, (col, j)
            if P[j] > 1 and dv[j] > 0:  # scalar short-circuits to 0 steps
                assert arr.hops[j] == sc.hops
                assert arr.steps[j] == sc.steps


@pytest.mark.parametrize("p", [2, 3, 4, 5, 6, 7, 8, 13, 16])
def test_non_pow2_volumes_not_rounded_up(p):
    """Dissemination schedule: busiest-node volume is exactly (P-1)/P*DV
    for every P — the old next-pow2 fallback overcharged non-pow2 P (e.g.
    All-Gather at P=3 moved the full DV instead of 2/3)."""
    noc = tpu_v5e().cluster_noc
    dv = 3072.0
    for col in ("AllGather", "ReduceScatter", "Gather", "Broadcast",
                "AllToAll"):
        c = collective_cost(col, dv, p, noc)
        assert c.volume_bytes == pytest.approx(dv * (p - 1) / p, rel=1e-12)
    ar = collective_cost("AllReduce", dv, p, noc)
    rs = collective_cost("ReduceScatter", dv, p, noc)
    ag = collective_cost("AllGather", dv, p, noc)
    assert ar.volume_bytes == rs.volume_bytes + ag.volume_bytes
    assert ar.hops == rs.hops + ag.hops
    assert ar.steps == rs.steps + ag.steps


def test_collective_zero_and_degenerate_cases():
    noc = NoCParams((1, 1), 256, 64 * GIGA, 5e-9, 2e-9)  # degenerate mesh
    assert collective_cost("AllReduce", 1024.0, 1, noc).volume_bytes == 0.0
    assert collective_cost("AllReduce", 0.0, 4, noc).volume_bytes == 0.0
    c = collective_cost("AllGather", 1024.0, 4, noc)
    assert c.volume_bytes > 0 and c.hops >= 1  # distances floor at 1
    with pytest.raises(ValueError, match="unknown collective"):
        collective_cost("AllSwizzle", 1.0, 4, noc)
    with pytest.raises(ValueError, match="unknown collective"):
        collective_cost("AllSwizzle", 1.0, np.array([2, 4]), noc)
    # negative/zero entries in a participant array cost nothing
    arr = collective_cost("AllReduce", 1e3, np.array([-2, 0, 1, 2]), noc)
    assert list(arr.volume_bytes[:3]) == [0.0, 0.0, 0.0]
    assert arr.volume_bytes[3] > 0


def test_moe_dispatch_lat_refactor_bit_identical():
    """The MoE dispatch benchmark's pre-refactor ``_lat`` helper
    (``cc.volume_bytes / noc.channel_bandwidth + noc_latency(cc, noc)``)
    must be *bitwise* what the shared ``collective_seconds`` entry point
    charges, on every preset NoC, for the exact (type, volume) mix the
    benchmark costs — so moving benchmarks/moe_dispatch.py onto the
    shared helper changed no published number."""
    from repro.core.collectives import collective_seconds

    from benchmarks.moe_dispatch import CASES

    for arch in PRESETS:
        noc = arch.cluster_noc
        P = noc.num_nodes
        if P <= 1:
            continue
        for _name, d, k, _d_ff, t_l in CASES:
            mix = [("AllReduce", t_l * d * 2),
                   ("AllToAll", (t_l // P) * k * d * 2),
                   ("AllGather", t_l * d * 2)]
            for col, dv in mix:
                cc = collective_cost(col, dv, P, noc)
                legacy = cc.volume_bytes / noc.channel_bandwidth \
                    + noc_latency(cc, noc)
                assert collective_seconds(col, dv, P, noc) == legacy


def test_mesh_scan_runs_once_per_noc(monkeypatch):
    """Regression (satellite): repeated collective_cost calls must not
    rescan the mesh — _mesh_avg_distance's O(nodes^2) manhattan sweep is
    cached per NoCParams inside the factor table build."""
    noc = NoCParams((7, 3), 256, 64 * GIGA, 5e-9, 2e-9)  # unique => cold
    calls = {"n": 0}
    orig = NoCParams.manhattan

    def counting(self, a, b):
        calls["n"] += 1
        return orig(self, a, b)

    monkeypatch.setattr(NoCParams, "manhattan", counting)
    collectives.collective_cache_clear()
    collective_cost("AllToAll", 1e6, 6, noc)
    warm = calls["n"]
    assert warm >= 21 * 20  # the one-off O(nodes^2) scan did happen
    # same NoC, different P / type / volume: the table answers, no rescan
    collective_cost("AllToAll", 1e6, 6, noc)
    collective_cost("AllToAll", 2e6, 13, noc)
    collective_cost("AllToAll", 5.0, np.arange(1, 22), noc)
    assert calls["n"] == warm
    # an equal-parameter NoCParams instance shares the cache line
    clone = NoCParams((7, 3), 256, 64 * GIGA, 5e-9, 2e-9)
    collective_cost("AllToAll", 1e6, 9, clone)
    assert calls["n"] == warm


def test_factor_tables_are_read_only():
    noc = edge().cluster_noc
    collective_cost("AllReduce", 1.0, 4, noc)
    tbl = collectives._FACTOR_TABLES[(noc, "AllReduce")]
    with pytest.raises(ValueError):
        tbl.volume_factor[2] = 99.0
    # noc_latency semantics unchanged by the table path
    c = collective_cost("AllReduce", 4096.0, 4, noc)
    assert noc_latency(c, noc) == pytest.approx(
        noc.t_router * c.hops + noc.t_enq * c.volume_bytes / noc.channel_width)


# ------------------------------------------------ divisor-complete fanouts

def test_divisors_helper():
    assert divisors(1) == [1]
    assert divisors(16) == [1, 2, 4, 8, 16]
    assert divisors(768, cap=4) == [1, 2, 3, 4]
    assert divisors(360, cap=20) == [1, 2, 3, 4, 5, 6, 8, 9, 10, 12, 15, 18,
                                     20]
    assert divisors(97) == [1, 97]  # prime


def test_fanout_candidates_superset_of_pow2():
    for n in (1, 4, 6, 16, 256):
        fc = fanout_candidates(n, (768, 97))
        assert set(pow2_tilings(n)) <= set(fc)
        assert all(1 <= d <= max(n, 1) for d in fc)
    # N=768 on a 4-cluster mesh: the 3-way unrolling appears
    assert 3 in fanout_candidates(4, (768,))


def test_candidate_specs_divisor_axes():
    co = gemm_softmax(384, 768, 96)
    arch = edge()
    div = candidate_specs(co, arch)
    p2 = candidate_specs(co, arch, fanouts="pow2")
    assert set(p2["sp_cluster"]) <= set(div["sp_cluster"])
    assert 3 in div["sp_cluster"] and 3 not in p2["sp_cluster"]
    with pytest.raises(ValueError, match="unknown fanouts"):
        candidate_specs(co, arch, fanouts="all")
    dt = candidate_specs(co, arch, divisor_tilings=True)
    assert 3 in dt["m_tiles"] and 3 in dt["k_tiles"]
    assert set(div["m_tiles"]) <= set(dt["m_tiles"])


def test_divisor_search_no_worse_than_pow2():
    """Superset candidate sets can only improve the exhaustive optimum —
    the BENCH_search divisor-vs-pow2 gate, spot-checked here on a non-pow2 dim
    where the divisor axes genuinely add fanouts."""
    co = gemm_softmax(384, 768, 96)
    arch = edge()
    rd = search(co, arch)                    # divisors (default)
    rp = search(co, arch, fanouts="pow2")
    assert rd.mode == rp.mode == "exhaustive"
    assert rd.latency <= rp.latency * (1 + 1e-12)
    # the divisor grid actually contains the 3-way point, evaluated valid
    cands = candidate_specs(co, arch)
    topo = batcheval.enumerate_topologies(co, cands)[0]
    br = batcheval.evaluate_topology_grid(co, arch, topo, cands)
    assert (br.sp_cluster == 3).any()


def test_nonpow2_fanout_matches_scalar_tree():
    """Grid points at sp_cluster=3 agree with the per-spec tree path
    (collective participants = 3 go through the tabulated factors)."""
    co = gemm_softmax(384, 768, 96)
    arch = edge()
    spec = MappingSpec(variant="fused_dist", m_tiles=4, k_tiles=2,
                       sp_cluster=3, sp_core=2)
    r = evaluate_mapping(co, arch, spec)
    br = evaluate_specs_batch(co, arch, Topology(variant="fused_dist"),
                              [4], [2], [1], sp_cluster=[3], sp_core=[2])
    assert br.latency[0] == pytest.approx(r.latency, rel=1e-12)
    assert br.energy_pj[0] == pytest.approx(r.energy_pj, rel=1e-12)
    assert bool(br.valid[0]) == r.valid


# ------------------------------------------------------- headroom channel

def test_headroom_matches_scalar_and_bounds():
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    br = evaluate_specs_batch(co, arch, Topology(variant="fused_dist"),
                              [1, 4, 64], [1, 2, 2], [1, 1, 1])
    assert br.headroom is not None and br.headroom.shape == br.latency.shape
    for i in range(br.size):
        r = evaluate_mapping(co, arch, br.spec_at(i))
        assert br.headroom[i] == pytest.approx(r.headroom, rel=1e-12)
        assert r.headroom == pytest.approx(
            capacity_headroom(r.root, arch, r.tiling, co.tensors))
    # valid grid points never overflow: headroom >= 0 wherever valid
    assert (br.headroom[br.valid] >= 0).all()
    assert (br.headroom <= 1.0).all()


def test_validity_and_headroom_consistent():
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    spec = MappingSpec(variant="fused_dist", m_tiles=np.array([1, 8, 512]),
                       k_tiles=np.array([1, 2, 2]))
    from repro.core.ir import build_tree
    root, tiling = build_tree(co, arch, spec)
    ok, hr = validity_and_headroom(root, arch, tiling, co.tensors)
    from repro.core.validate import validity_mask
    assert np.array_equal(validity_mask(root, arch, tiling, co.tensors), ok)
    # capacity-overflow points have negative headroom
    assert ((hr >= 0) | ~ok).all()


def test_headroom_levels_unfold_the_scalar():
    """The per-level headroom vector (ROADMAP satellite): GB (cluster
    buffer) and OB (per-core IB+WB+OB) slacks are exposed alongside the
    folded worst-slack scalar, which must equal their min — on both the
    batched and the per-spec paths, bit-identically."""
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    br = evaluate_specs_batch(co, arch, Topology(variant="fused_dist"),
                              [1, 4, 64], [1, 2, 2], [1, 1, 1])
    assert sorted(br.headroom_levels) == ["GB", "OB"]
    folded = np.minimum.reduce(list(br.headroom_levels.values()))
    assert np.array_equal(folded, br.headroom)
    for i in range(br.size):
        r = evaluate_mapping(co, arch, br.spec_at(i))
        assert sorted(r.headroom_levels) == ["GB", "OB"]
        assert min(r.headroom_levels.values()) == r.headroom
        for lvl, v in r.headroom_levels.items():
            assert br.headroom_levels[lvl][i] == pytest.approx(v, rel=1e-12)
    # the two levels genuinely dissociate: a wide-N shape has both a
    # GB-limited point (deep k tiling shrinks the core tiles, the full-N
    # row dominates the cluster buffer) and an OB-limited point
    wide = gemm_softmax(512, 8192, 128)
    grid = evaluate_specs_batch(wide, arch, Topology(variant="fused_dist"),
                                [1, 64], [1, 8], [1, 1])
    gb, ob = grid.headroom_levels["GB"], grid.headroom_levels["OB"]
    assert (gb < ob).any() and (ob < gb).any()


# ------------------------------------------------------- 3-D Pareto front

def _brute_force_front3(pts):
    """O(n^2) reference: indices of points not weakly dominated by any
    distinct point (minimize all three columns)."""
    keep = []
    for i, p in enumerate(pts):
        dominated = False
        for j, q in enumerate(pts):
            if j == i:
                continue
            if all(qc <= pc for qc, pc in zip(q, p)) and (
                    any(qc < pc for qc, pc in zip(q, p)) or j < i):
                dominated = True
                break
        if not dominated:
            keep.append(i)
    return keep


def test_pareto_front3_matches_bruteforce_random():
    rng = np.random.default_rng(7)
    for trial in range(20):
        n = int(rng.integers(1, 60))
        pts = rng.random((n, 3))
        if trial % 3 == 0:   # inject duplicates / ties
            pts = np.round(pts, 1)
        merged = pareto_merge3(
            [(p[0], p[1], -p[2], i) for i, p in enumerate(pts)])
        got = sorted(m[3] for m in merged)
        want = sorted(_brute_force_front3(pts.tolist()))
        assert got == want, trial
        # no member of the returned front dominates another
        for a in merged:
            for b in merged:
                if a is b:
                    continue
                assert not (a[0] <= b[0] and a[1] <= b[1] and a[2] >= b[2])


def test_pareto_front3_on_real_grid():
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    cands = candidate_specs(co, arch)
    topo = batcheval.enumerate_topologies(co, cands)[0]
    br = batcheval.evaluate_topology_grid(co, arch, topo, cands)
    f3 = br.pareto_front3()
    assert f3.size > 0
    lat, en, hr, valid = br.latency, br.energy_pj, br.headroom, br.valid
    # ascending latency; all valid; none dominated by any valid point
    assert (np.diff(lat[f3]) >= 0).all()
    for i in f3:
        assert valid[i]
        dominated = ((lat <= lat[i]) & (en <= en[i]) & (hr >= hr[i]) & valid
                     & ((lat < lat[i]) | (en < en[i]) | (hr > hr[i])))
        assert not dominated.any(), i
    # the 2-D front's points all appear in (or are matched by) the 3-D
    # front's latency/energy projection, and fronts only grow in 3-D
    assert f3.size >= br.pareto_front().size
    # min-latency point matches the scalar optimum
    assert lat[f3].min() == lat[br.best_index("latency")]


def test_search_pareto3_objective():
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    lat = search(co, arch, objective="latency")
    pf3 = search(co, arch, objective="pareto3")
    assert pf3.mode == "exhaustive" and pf3.front
    assert len(pf3.front[0]) == 4          # (lat, en, headroom, spec)
    assert pf3.front[0][0] == pytest.approx(lat.latency, rel=1e-12)
    assert pf3.latency == pytest.approx(pf3.front[0][0], rel=1e-12)
    assert all(0.0 <= p[2] <= 1.0 for p in pf3.front)
    assert pf3.best.valid
    # randomized fallback fills a (bounded, non-dominated) front too
    rd = search(co, arch, mode="randomized", budget=300, seed=0,
                objective="pareto3")
    assert rd.front
    for a in rd.front:
        for b in rd.front:
            if a is not b:
                assert not (a[0] <= b[0] and a[1] <= b[1] and a[2] >= b[2])


def test_pareto_archive_bounded_and_non_dominated():
    rng = np.random.default_rng(0)
    arc = ParetoArchive(dims=3, maxlen=16)
    # anti-correlated objectives => a large true front that must be thinned
    for _ in range(3000):
        x = float(rng.random())
        y = 1.0 - x + 0.01 * float(rng.random())
        h = float(rng.random())
        arc.add((x, y, h, None))
        assert len(arc) <= 2 * 16  # never grows unboundedly
    front = arc.front()
    assert 2 <= len(front) <= 2 * 16
    assert all(a[0] <= b[0] for a, b in zip(front, front[1:]))
    for a in front:
        for b in front:
            if a is not b:
                assert not (a[0] <= b[0] and a[1] <= b[1] and a[2] >= b[2])
    # 2-D archive: duplicates rejected, dominated evicted
    arc2 = ParetoArchive(dims=2, maxlen=8)
    assert arc2.add((1.0, 1.0, "a"))
    assert not arc2.add((1.0, 1.0, "dup"))
    assert arc2.add((0.5, 0.5, "dominator"))
    assert [p[2] for p in arc2.front()] == ["dominator"]
    with pytest.raises(ValueError):
        ParetoArchive(dims=4)


def test_pareto_archive_crowding_beats_decimation_spread():
    """Regression (ROADMAP satellite): thinning is crowding-distance
    pruning, not decimation.  On a front with a dense cluster, decimation
    keeps every other point — halving the sparse stretches while the
    cluster stays dense — whereas crowding pruning eats the cluster first
    and keeps the spread points, so the pruned front's worst gap is
    strictly smaller."""
    xs = [0.0, 0.30, 0.301, 0.302, 0.303, 0.304, 0.305, 0.65, 1.0]
    arc = ParetoArchive(dims=2, maxlen=8)
    for x in xs:
        arc.add((x, 1.0 - x, None))         # all mutually non-dominated
    kept = [p[0] for p in arc.front()]      # 9th add triggered one thin
    assert len(kept) == 4                   # maxlen // 2
    assert kept[0] == 0.0 and kept[-1] == 1.0   # endpoints always survive
    assert 0.65 in kept                     # the isolated interior point
    # decimation (the old _thin) on the same sorted front
    decimated = sorted(xs)[::2]             # -> drops 0.65, keeps cluster
    gap = lambda ks: max(b - a for a, b in zip(ks, ks[1:]))
    assert gap(kept) < gap(decimated)
    # the kept set is still mutually non-dominated and latency-sorted
    front = arc.front()
    assert all(a[0] < b[0] and a[1] > b[1]
               for a, b in zip(front, front[1:]))


# -------------------------------------------- randomized-search satellites

def test_randomized_history_logs_objective_score():
    """Regression (satellite): convergence history must log the OBJECTIVE
    score — an energy search used to log latency, producing misleading
    convergence curves."""
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    rd = search(co, arch, mode="randomized", budget=400, seed=3,
                objective="energy")
    assert rd.history
    scores = [s for _, s in rd.history]
    assert scores == sorted(scores, reverse=True)   # monotone improvement
    assert scores[-1] == pytest.approx(rd.best.energy_pj, rel=1e-12)
    # latency at the energy-best spec differs from its energy => the old
    # (iter, latency) logging cannot produce this final entry
    assert rd.best.latency != pytest.approx(rd.best.energy_pj)


def test_randomized_resamples_duplicates():
    """Regression (satellite): duplicate samples used to burn budget
    iterations; now one iteration resamples (bounded) until it finds an
    unseen spec, so a small budget evaluates ~budget unique specs even in
    a collision-heavy space."""
    co = gemm_softmax(64, 128, 64)
    arch = edge()
    cands = {
        "variant": ["fused_dist"],
        "m_tiles": [1, 2, 4, 8, 16, 32, 64],
        "k_tiles": [1, 2, 4, 8],
        "n_tiles": [1],
        "sp_cluster": [1, 2, 4],
        "sp_core": [1, 2],
        "schedule": ["sequential", "pipelined"],
        "collective_gran": ["tile"],
        "loop_order_gb": [("M", "N")],
    }
    space = 7 * 4 * 3 * 2 * 2  # 336 unique specs
    budget = 60
    # hillclimb_frac=0 keeps every iteration in the full-space sampling
    # phase, where a fresh spec is always reachable; without resampling
    # the expected unique count at budget=60 over 336 specs is ~55 and
    # shrinks every run the moment duplicates land
    r = _search_randomized(co, arch, cands, budget=budget, seed=0,
                           objective="latency", hillclimb_frac=0.0)
    assert r.evaluated == budget < space
    # with hill-climbing the tiny mutation neighborhood saturates — the
    # bounded retry must concede those iterations, not spin forever
    r2 = _search_randomized(co, arch, cands, budget=budget, seed=0,
                            objective="latency", hillclimb_frac=0.5)
    assert 0 < r2.evaluated <= budget
