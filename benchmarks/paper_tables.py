"""Benchmarks reproducing the paper's tables/figures (§V).

Each function prints CSV rows ``name,us_per_call,derived`` and returns a
dict of headline numbers (geomeans compared against the paper's claims).
"""
from __future__ import annotations

import math
import time
from typing import Dict, List, Tuple

from repro.core import (attention, flash_attention, gemm_layernorm,
                        gemm_softmax)
from repro.core.batcheval import Topology, evaluate_specs_batch
from repro.core.hardware import cloud, edge
from repro.core.ir import MappingSpec, evaluate_mapping
from repro.core.search import search_many

# Tables I / II
GEMMS_EDGE = [(1, 1024, 64), (1, 4096, 128), (256, 1024, 128),
              (4, 1024, 128), (512, 1024, 128), (512, 1024, 64)]
GEMMS_CLOUD = [(1, 16384, 128), (1, 2048, 64), (256, 4096, 128),
               (4, 8192, 128), (512, 2048, 64), (512, 4096, 128)]
# Tables III / IV  (M, K, N, L)
ATTN_EDGE = [(1024, 256, 1024, 256), (1, 128, 1024, 128),
             (1, 256, 2048, 256), (1, 256, 512, 256),
             (256, 128, 256, 128), (512, 128, 256, 128)]
ATTN_CLOUD = [(1024, 512, 1024, 512), (1, 128, 16384, 128),
              (1, 512, 4096, 512), (1, 128, 8192, 128),
              (2048, 256, 2048, 256), (256, 512, 256, 512)]

BUDGET = 250

# Full paper-table search axes (PR 4): the m/k/n temporal tilings are
# divisor-extended on top of the divisor-complete spatial fanouts.  The
# exhaustive limit was re-budgeted (EXHAUSTIVE_LIMIT 64k -> 128k) so
# every paper-table cell — including the non-pow2 provisioning GEMMs on
# cloud, whose spaces reach ~117k points — still enumerates exhaustively
# instead of falling back to sampling.  Sweeps fan out through
# ``search_many``'s default executor, i.e. the shared-memory process
# pool for table-sized job counts.
SEARCH_KW = {"divisor_tilings": True}

# Non-pow2 provisioning showcase shapes (M, N, K with 3*2^k factors): the
# divisor-complete fanout axes add 3/6-way unrollings the pow2 sets never
# enumerate.  Shared with benchmarks/search_throughput.py (schema-v4
# provisioning gates).
PROVISIONING_GEMMS = [(384, 768, 96), (768, 1536, 192)]


def _geomean(xs: List[float]) -> float:
    return math.exp(sum(math.log(max(x, 1e-12)) for x in xs) / len(xs))


VARIANTS = ("unfused", "fused_epilogue", "fused_std", "fused_dist")


def fusion_comparison(workload_fn, label: str, paper_claim: float) -> Dict:
    """Figs 10/11: latency & energy of each fusion mapping vs unfused.

    All (shape, arch, variant) cells fan out through the search_many
    sweep driver; each cell is an exhaustive batched search.
    """
    rows = []
    lat_ratios, en_ratios = [], []
    t0 = time.time()
    grids = ((GEMMS_EDGE, edge()), (GEMMS_CLOUD, cloud()))
    jobs = [(workload_fn(M, N, K), arch,
             dict(SEARCH_KW, budget=BUDGET, seed=1, variants=[v]))
            for shapes, arch in grids
            for (M, N, K) in shapes
            for v in VARIANTS]
    results = iter(search_many(jobs))
    for shapes, arch in grids:
        for i, (M, N, K) in enumerate(shapes):
            res = {v: next(results) for v in VARIANTS}
            best_fused = min(("fused_epilogue", "fused_std", "fused_dist"),
                             key=lambda v: res[v].latency)
            lat_r = res["unfused"].latency / res[best_fused].latency
            en_r = res["unfused"].energy_pj / res[best_fused].energy_pj
            lat_ratios.append(lat_r)
            en_ratios.append(en_r)
            rows.append((f"{label}_{arch.name}_G{i+1}",
                         res[best_fused].latency * 1e6,
                         f"best={best_fused};lat_speedup={lat_r:.2f};"
                         f"energy_red={en_r:.2f}"))
    g_lat, g_en = _geomean(lat_ratios), _geomean(en_ratios)
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
    print(f"{label}_geomean,{(time.time()-t0)*1e6/len(rows):.0f},"
          f"lat={g_lat:.2f}x(paper {paper_claim}x);energy={g_en:.2f}x")
    return {"geomean_latency_speedup": g_lat, "geomean_energy": g_en,
            "paper_claim": paper_claim}


def attention_variants() -> Dict:
    """Fig 12: UA / PFA / FA latency & energy (paper: 1.82x / 1.54x FA)."""
    lat_ratios, en_ratios = [], []
    grids = ((ATTN_EDGE, edge()), (ATTN_CLOUD, cloud()))
    jobs = []
    for shapes, arch in grids:
        for (M, K, N, L) in shapes:
            jobs += [
                (attention(M, K, N, L), arch,
                 dict(SEARCH_KW, budget=BUDGET, seed=1, variants=["ua"])),
                (attention(M, K, N, L), arch,
                 dict(SEARCH_KW, budget=BUDGET, seed=1, variants=["pfa"])),
                (flash_attention(M, K, N, L), arch,
                 dict(SEARCH_KW, budget=BUDGET, seed=1, variants=["fa"])),
            ]
    results = iter(search_many(jobs))
    for shapes, arch in grids:
        for i, (M, K, N, L) in enumerate(shapes):
            ua = next(results).best
            pfa = next(results).best
            fa = next(results).best
            lat_ratios.append(ua.latency / fa.latency)
            en_ratios.append(ua.energy_pj / fa.energy_pj)
            print(f"attn_{arch.name}_A{i+1},{fa.latency*1e6:.2f},"
                  f"ua={ua.latency*1e6:.1f}us;pfa={pfa.latency*1e6:.1f}us;"
                  f"fa_speedup={ua.latency/fa.latency:.2f}")
    g_lat, g_en = _geomean(lat_ratios), _geomean(en_ratios)
    print(f"attn_geomean,0,lat={g_lat:.2f}x(paper 1.82x);"
          f"energy={g_en:.2f}x(paper 1.54x)")
    return {"geomean_latency_speedup": g_lat, "geomean_energy": g_en}


def breakdowns() -> Dict:
    """Figs 8/9: latency breakdown of distSM vs SM mappings per GEMM.

    Both mappings of each shape run through the batched SoA evaluator with
    ``track_breakdown=True`` — no scalar tree walk: the per-key breakdown
    arrays come out of the same vectorized pass as the totals.
    """
    out = {}
    for shapes, arch in ((GEMMS_EDGE, edge()), (GEMMS_CLOUD, cloud())):
        for i, (M, N, K) in enumerate(shapes):
            co = gemm_softmax(M, N, K)
            for tag, variant in (("distSM", "fused_dist"), ("SM", "fused_std")):
                br = evaluate_specs_batch(
                    co, arch, Topology(variant=variant),
                    [min(8, M)], [2], [1], track_breakdown=True)
                bd = br.lat_breakdown_at(0)
                top = max(bd, key=bd.get)
                print(f"breakdown_{arch.name}_G{i+1}_{tag},"
                      f"{float(br.latency[0])*1e6:.2f},dominant={top};"
                      + ";".join(f"{k}={v*1e6:.1f}us"
                                 for k, v in bd.items() if v > 0))
                out[f"{arch.name}_G{i+1}_{tag}"] = top
    return out


def pareto_fronts() -> Dict:
    """Beyond-scalar objectives: the latency/energy Pareto front of every
    (shape, arch) gemm_softmax space, extracted vectorized from the SoA
    grids (``objective='pareto'``).  Prints front size and both endpoints;
    the front's min latency always matches the scalar-latency optimum."""
    jobs = [(gemm_softmax(M, N, K), arch, dict(SEARCH_KW, objective="pareto"))
            for shapes, arch in ((GEMMS_EDGE, edge()), (GEMMS_CLOUD, cloud()))
            for (M, N, K) in shapes]
    results = iter(search_many(jobs))
    sizes = []
    for shapes, arch in ((GEMMS_EDGE, edge()), (GEMMS_CLOUD, cloud())):
        for i, (M, N, K) in enumerate(shapes):
            r = next(results)
            front = r.front
            lat_lo, en_hi, _ = front[0]     # min latency end
            lat_hi, en_lo, _ = front[-1]    # min energy end
            sizes.append(len(front))
            print(f"pareto_{arch.name}_G{i+1},{lat_lo*1e6:.2f},"
                  f"front={len(front)};"
                  f"lat_span={lat_lo*1e6:.1f}..{lat_hi*1e6:.1f}us;"
                  f"energy_span={en_lo/1e6:.2f}..{en_hi/1e6:.2f}uJ")
    print(f"pareto_geomean,0,mean_front_size={sum(sizes)/len(sizes):.1f}")
    return {"front_sizes": sizes}


def provisioning_fronts() -> Dict:
    """Provisioning study (beyond-scalar objectives, 3-D): the
    latency/energy/capacity-headroom Pareto front of each gemm_softmax
    space (``objective='pareto3'``), plus non-pow2 shapes where the
    divisor-complete fanout axes (sp_cluster=3/6, ...) genuinely widen
    the space.  For each cell we print the front size, the headroom span
    and the 'knee' trade: how much latency the max-headroom provisioning
    point gives up versus the latency-optimal mapping."""
    cells = [(gemm_softmax(M, N, K), arch)
             for shapes, arch in ((GEMMS_EDGE, edge()), (GEMMS_CLOUD, cloud()))
             for (M, N, K) in shapes]
    # divisor-complete showcase shapes, on both archs
    cells += [(gemm_softmax(*shape), arch)
              for shape in PROVISIONING_GEMMS
              for arch in (edge(), cloud())]
    results = iter(search_many([(co, arch,
                                 dict(SEARCH_KW, objective="pareto3"))
                                for co, arch in cells]))
    sizes, knees = [], []
    for i, (co, arch) in enumerate(cells):
        front = next(results).front
        lat_lo = front[0][0]
        hr = [p[2] for p in front]
        roomy = max(front, key=lambda p: p[2])   # max-headroom point
        knee = roomy[0] / lat_lo                 # latency cost of slack
        sizes.append(len(front))
        knees.append(knee)
        dims = "x".join(str(co.dim_sizes[d]) for d in ("M", "N", "K"))
        print(f"prov3_{arch.name}_{dims},{lat_lo*1e6:.2f},"
              f"front3={len(front)};headroom={min(hr):.3f}..{max(hr):.3f};"
              f"maxroom_lat_cost={knee:.2f}x")
    print(f"prov3_geomean,0,mean_front_size={sum(sizes)/len(sizes):.1f};"
          f"geomean_maxroom_lat_cost={_geomean(knees):.2f}x")
    return {"front_sizes": sizes, "knees": knees}


def mapping_variation() -> Dict:
    """Fig 7: latency/energy spread across sampled mappings (GEMM5 edge)."""
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    lats, ens = [], []
    import random
    from repro.core.search import candidate_specs, _sample
    rng = random.Random(0)
    cands = candidate_specs(co, arch)
    for _ in range(300):
        spec = _sample(rng, cands)
        try:
            r = evaluate_mapping(co, arch, spec)
        except (ValueError, KeyError):
            continue
        if r.valid:
            lats.append(r.latency)
            ens.append(r.energy_pj)
    spread_lat = max(lats) / min(lats)
    spread_en = max(ens) / min(ens)
    print(f"mapping_variation_lat,{min(lats)*1e6:.2f},spread={spread_lat:.1f}x")
    print(f"mapping_variation_energy,{min(ens)/1e6:.2f},spread={spread_en:.1f}x")
    return {"latency_spread": spread_lat, "energy_spread": spread_en}


def beyond_paper_stats_collectives() -> Dict:
    """Beyond-paper: distSM collectives on M×1 stats instead of the paper's
    M×N tile annotation — the framework-level optimization enabled by the
    explicit representation.  Compared at the SAME mapping (fixed tiling)
    so the collective-term change is isolated; we report both the
    collective-term reduction and the total-latency speedup."""
    col_ratios, lat_ratios = [], []
    for shapes, arch in ((GEMMS_EDGE, edge()), (GEMMS_CLOUD, cloud())):
        for (M, N, K) in shapes:
            co = gemm_softmax(M, N, K)
            spec = MappingSpec(variant="fused_dist", m_tiles=min(8, M),
                               k_tiles=2)
            tile = evaluate_mapping(co, arch, spec)
            stats = evaluate_mapping(
                co, arch, MappingSpec(variant="fused_dist",
                                      m_tiles=min(8, M), k_tiles=2,
                                      collective_gran="stats"))
            ct = tile.cost.lat_breakdown["collective"]
            cs = stats.cost.lat_breakdown["collective"]
            if cs > 0:
                col_ratios.append(ct / cs)
            lat_ratios.append(tile.latency / stats.latency)
    g_col = _geomean(col_ratios) if col_ratios else float("nan")
    g_lat = _geomean(lat_ratios)
    print(f"stats_gran_speedup,0,collective_term={g_col:.1f}x;"
          f"total_latency={g_lat:.2f}x_over_paper_faithful")
    return {"collective_term_speedup": g_col, "latency_speedup": g_lat}


def export_plans(out_path: str = "PLANS_kernels.json") -> Dict:
    """MappingPlan bundle export (the search -> serving handoff): solve
    every paper-table kernel block-selection plan through the shared
    :class:`repro.core.plan.PlanCache` — misses fan out through one
    ``search_many(executor='auto')`` sweep — and emit a single-file plan
    bundle.  A serving host imports it (``launch/serve --plan-bundle``,
    or ``PlanCache.import_bundle``) and its startup warmup becomes pure
    cache hits: no search ever runs on the serving side."""
    from repro.core.plan import get_plan_cache
    from repro.kernels.autotune import plan_jobs

    cache = get_plan_cache()
    t0 = time.time()
    # tag the whole sweep as one provenance generation in the durable
    # store ($REPRO_PLAN_SWEEP_ID overrides), so a fleet operator can
    # later `invalidate(sweep_id=...)` or audit it via `store_stats()`
    stats = cache.warmup(plan_jobs(), sweep_id="paper-tables-export")
    n = cache.export_bundle(out_path)
    store = cache.store_stats()["store"]
    print(f"plan_bundle,{(time.time() - t0) * 1e6:.0f},"
          f"plans={n};solved={stats['solved']};hits={stats['hits']};"
          f"store={store.get('backend')};wrote={out_path}")
    return {"plans": n, **stats, "path": out_path,
            "store_backend": store.get("backend")}


def run_all() -> Dict:
    print("# --- Fig 10/11: GEMM-Softmax fusion ---")
    sm = fusion_comparison(gemm_softmax, "gemm_sm", 1.42)
    print("# --- Fig 10/11: GEMM-LayerNorm fusion ---")
    ln = fusion_comparison(gemm_layernorm, "gemm_ln", 3.46)
    print("# --- Fig 12: attention variants ---")
    at = attention_variants()
    print("# --- Fig 8/9: breakdowns (batched) ---")
    bd = breakdowns()
    print("# --- latency/energy Pareto fronts ---")
    pf = pareto_fronts()
    print("# --- provisioning study: 3-D latency/energy/headroom fronts ---")
    pv = provisioning_fronts()
    print("# --- Fig 7: mapping variation ---")
    mv = mapping_variation()
    print("# --- beyond-paper: stats-granularity collectives ---")
    bp = beyond_paper_stats_collectives()
    print("# --- kernel plan bundle (search -> serving handoff) ---")
    ep = export_plans()
    return {"gemm_sm": sm, "gemm_ln": ln, "attention": at,
            "breakdowns": bd, "pareto": pf, "provisioning": pv,
            "variation": mv, "beyond": bp, "plans": ep}


if __name__ == "__main__":
    run_all()
