"""Tests for the vectorized batch map-space evaluation engine
(core/batcheval.py), the exhaustive search mode, the shared evaluation
caches and the parallel sweep driver."""
import math
import random

import numpy as np
import pytest

from repro.core import batcheval
from repro.core.batcheval import (Topology, co_signature,
                                  enumerate_topologies, evaluate_cached,
                                  evaluate_specs_batch,
                                  evaluate_topology_grid)
from repro.core.hardware import cloud, edge
from repro.core.ir import MappingSpec, evaluate_mapping
from repro.core.search import (candidate_specs, search, search_many,
                               _sample)
from repro.core.workload import (attention, flash_attention, gemm_layernorm,
                                 gemm_softmax, ssd_chunk)

WORKLOADS = [
    ("gemm_softmax", gemm_softmax(512, 1024, 128)),
    ("gemm_layernorm", gemm_layernorm(512, 4096, 128)),
    ("attention_prefill", attention(1024, 256, 1024, 256)),
    ("attention_decode", attention(1, 128, 1024, 128)),
    ("flash_attention", flash_attention(2048, 256, 2048, 256)),
]
ARCHS = [edge(), cloud()]


# -------------------------------------------------- vectorized equivalence

@pytest.mark.parametrize("wl_name,co", WORKLOADS,
                         ids=[n for n, _ in WORKLOADS])
@pytest.mark.parametrize("arch", ARCHS, ids=[a.name for a in ARCHS])
def test_batch_matches_tree_path(wl_name, co, arch):
    """Every grid point of every topology matches the per-spec
    build_tree -> validate_tree -> CostModel path to 1e-9 relative
    tolerance (they execute the same formulas, so in practice they are
    bit-identical), including validity."""
    cands = candidate_specs(co, arch)
    rng = random.Random(0)
    for topo in enumerate_topologies(co, cands):
        br = evaluate_topology_grid(co, arch, topo, cands)
        # sample a handful of points per topology to keep runtime down
        idxs = {rng.randrange(br.size) for _ in range(8)} | {0, br.size - 1}
        for i in idxs:
            spec = br.spec_at(i)
            try:
                r = evaluate_mapping(co, arch, spec)
            except (ValueError, KeyError):
                assert not br.valid[i]
                continue
            assert bool(br.valid[i]) == r.valid
            assert br.latency[i] == pytest.approx(r.latency, rel=1e-9)
            assert br.energy_pj[i] == pytest.approx(r.energy_pj, rel=1e-9)


def test_batch_specs_parallel_arrays():
    """evaluate_specs_batch accepts explicit (m, k, n) candidate pairs
    (the autotune use case), not just meshgrids."""
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    topo = Topology(variant="fused_dist", schedule="sequential")
    m = [1, 2, 8, 64]
    k = [1, 4, 2, 8]
    br = evaluate_specs_batch(co, arch, topo, m, k, [1, 1, 1, 1])
    assert br.size == 4
    for i in range(4):
        r = evaluate_mapping(co, arch, br.spec_at(i))
        assert br.latency[i] == pytest.approx(r.latency, rel=1e-9)


# ------------------------------------------- exhaustive vs randomized

@pytest.mark.parametrize("wl_name,co", WORKLOADS,
                         ids=[n for n, _ in WORKLOADS])
@pytest.mark.parametrize("arch", ARCHS, ids=[a.name for a in ARCHS])
def test_exhaustive_no_worse_than_randomized(wl_name, co, arch):
    ex = search(co, arch, mode="exhaustive")
    assert ex.mode == "exhaustive"
    assert ex.best.valid
    for seed in (0, 1, 7):
        rd = search(co, arch, mode="randomized", budget=500, seed=seed)
        assert ex.latency <= rd.latency * (1 + 1e-12), \
            f"exhaustive worse than randomized seed={seed}"


def test_search_auto_picks_exhaustive_and_is_deterministic():
    co = gemm_softmax(512, 2048, 128)
    arch = cloud()
    r1 = search(co, arch)
    r2 = search(co, arch)
    assert r1.mode == "exhaustive" == r2.mode
    assert r1.latency == r2.latency
    assert r1.evaluated == r2.evaluated
    # full space covered: evaluated == topologies x grid
    cands = candidate_specs(co, arch)
    expect = (len(enumerate_topologies(co, cands))
              * batcheval.grid_size(co, cands))
    assert r1.evaluated == expect


def test_search_objectives():
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    lat = search(co, arch, objective="latency")
    en = search(co, arch, objective="energy")
    edp = search(co, arch, objective="edp")
    assert lat.latency <= en.latency * (1 + 1e-12)
    assert en.energy_pj <= lat.energy_pj * (1 + 1e-12)
    assert (edp.latency * edp.energy_pj
            <= lat.latency * lat.energy_pj * (1 + 1e-12))


def test_exhaustive_falls_back_when_space_too_large():
    co = gemm_softmax(512, 1024, 128)
    arch = edge()
    r = search(co, arch, exhaustive_limit=10, budget=200, seed=0)
    assert r.mode == "randomized"


def test_generic_workload_exhaustive():
    co = ssd_chunk(S=2048, H=1, P=64, Dst=128, C=256)
    from repro.core.hardware import tpu_v5e
    arch = tpu_v5e((1, 1))
    r = search(co, arch)
    assert r.mode == "exhaustive"
    assert r.best.valid and r.latency > 0


# ----------------------------------------------------------------- caches

def test_grid_cache_hits():
    batcheval.cache_clear()
    co = gemm_softmax(256, 1024, 64)
    arch = edge()
    cands = candidate_specs(co, arch)
    topo = enumerate_topologies(co, cands)[0]
    br1 = evaluate_topology_grid(co, arch, topo, cands)
    info1 = batcheval.cache_info()["grid"]
    br2 = evaluate_topology_grid(co, arch, topo, cands)
    info2 = batcheval.cache_info()["grid"]
    assert info2["hits"] == info1["hits"] + 1
    assert br2 is br1          # same cached object
    # a different arch is a different cache line
    evaluate_topology_grid(co, cloud(), topo, cands)
    assert batcheval.cache_info()["grid"]["misses"] == info2["misses"] + 1


def test_spec_cache_hits_and_rejections():
    batcheval.cache_clear()
    co = gemm_softmax(256, 1024, 64)
    arch = edge()
    spec = MappingSpec(variant="fused_dist", m_tiles=8, k_tiles=2)
    r1 = evaluate_cached(co, arch, spec)
    h0 = batcheval.cache_info()["spec"]["hits"]
    r2 = evaluate_cached(co, arch, spec)
    assert batcheval.cache_info()["spec"]["hits"] == h0 + 1
    assert r1 == r2
    ref = evaluate_mapping(co, arch, spec)
    assert r1 == (ref.latency, ref.energy_pj, ref.valid)
    # rejected specs (scalar path raises) cache as None both times
    bad = MappingSpec(variant="fa")    # wrong builder family
    assert evaluate_cached(co, arch, bad) is None
    assert evaluate_cached(co, arch, bad) is None


def test_co_signature_distinguishes_shapes():
    assert co_signature(gemm_softmax(256, 1024, 64)) != \
        co_signature(gemm_softmax(256, 1024, 128))
    assert co_signature(gemm_softmax(256, 1024, 64)) == \
        co_signature(gemm_softmax(256, 1024, 64))


# ----------------------------------------------------------- sweep driver

def test_search_many_matches_serial_order():
    jobs = [(gemm_softmax(256, 1024, 128), edge(), {"variants": [v]})
            for v in ("unfused", "fused_epilogue", "fused_std", "fused_dist")]
    par = search_many(jobs)
    ser = search_many(jobs, executor="serial")
    assert [r.latency for r in par] == [r.latency for r in ser]
    assert [r.best.spec.variant for r in par] == \
        ["unfused", "fused_epilogue", "fused_std", "fused_dist"]


# -------------------------------------------------- autotune integration

def test_autotune_uses_shared_engine():
    """Block selection routes through the batched evaluator (no local
    mini cost models) and still respects the kernel VMEM constraints."""
    import inspect

    from repro.kernels import autotune

    src = inspect.getsource(autotune)
    assert "evaluate_specs_batch" in src
    assert "systolic_gemm_cycles" not in src   # the old mini-model hook
    bq, bk = autotune.attention_blocks(1024, 1024, 64)
    assert bq % 128 == 0 and bk % 128 == 0
    bm, bk2 = autotune.gemm_epilogue_blocks(512, 4096, 128)
    assert (bm * 4096 * 4 + bk2 * 4096 * 2 + bm * bk2 * 2
            + bm * 4096 * 2) * 2 <= autotune.VMEM_BUDGET
